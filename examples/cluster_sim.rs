//! Paper-scale cluster sweep: the simulator counterpart of Figs. 4–5 across
//! pipeline depths and domains.
//!
//!     cargo run --release --offline --example cluster_sim

use pipedec::metrics::Table;
use pipedec::sim::{simulate_pipedec, simulate_pp, simulate_slm, simulate_stpp,
    ClusterSpec, HitModel};
use pipedec::util::XorShiftRng;
use pipedec::workload::DOMAINS;

fn main() -> anyhow::Result<()> {
    let tokens = 512;

    println!("== latency vs pipeline depth (domain=math, w=32, c=16) ==");
    let hit = HitModel::default_for("math");
    let mut t = Table::new(&["stages", "pipedec ms/tok", "pp ms/tok", "stpp ms/tok",
        "speedup vs pp", "speedup vs stpp"]);
    for stages in [7usize, 14, 21] {
        let cluster = ClusterSpec::paper(stages);
        let mut rng = XorShiftRng::new(9);
        let pd = simulate_pipedec(&cluster, 32, 16, &hit, tokens, &mut rng);
        let pp = simulate_pp(&cluster, tokens);
        let st = simulate_stpp(&cluster, 16, 4, 4, &hit, tokens, &mut rng);
        t.row(vec![
            stages.to_string(),
            format!("{:.1}", 1e3 * pd.s_per_token()),
            format!("{:.1}", 1e3 * pp.s_per_token()),
            format!("{:.1}", 1e3 * st.s_per_token()),
            format!("{:.2}x", pp.s_per_token() / pd.s_per_token()),
            format!("{:.2}x", st.s_per_token() / pd.s_per_token()),
        ]);
    }
    println!("{}", t.render());

    println!("== per-domain latency at 14 stages (paper Fig. 5 shape) ==");
    let cluster = ClusterSpec::paper(14);
    let mut t = Table::new(&["domain", "pipedec ms/tok", "stpp ms/tok", "pp ms/tok",
        "slm ms/tok"]);
    for (dom, _) in DOMAINS {
        let hit = HitModel::default_for(dom);
        let mut rng = XorShiftRng::new(11);
        let pd = simulate_pipedec(&cluster, 32, 16, &hit, tokens, &mut rng);
        let st = simulate_stpp(&cluster, 16, 4, 4, &hit, tokens, &mut rng);
        let pp = simulate_pp(&cluster, tokens);
        let slm = simulate_slm(tokens);
        t.row(vec![
            dom.to_string(),
            format!("{:.1}", 1e3 * pd.s_per_token()),
            format!("{:.1}", 1e3 * st.s_per_token()),
            format!("{:.1}", 1e3 * pp.s_per_token()),
            format!("{:.1}", 1e3 * slm.s_per_token()),
        ]);
    }
    println!("{}", t.render());
    Ok(())
}
