//! End-to-end serving driver (DESIGN.md "End-to-end validation"): load the
//! build-time model through the PJRT runtime and serve a batch of real
//! requests from all six workload domains through the router + PipeDec
//! engine, reporting per-request latency percentiles and aggregate
//! throughput.
//!
//!     cargo run --release --offline --example serve_batch [-- <k>]
//!
//! `k` = number of concurrent requests submitted up front (default 6).

use pipedec::config::{EngineConfig, TreeConfig};
use pipedec::coordinator::PipeDecEngine;
use pipedec::server::{drain, summarize, Router};
use pipedec::workload::mixed_stream;

fn main() -> anyhow::Result<()> {
    let dir = pipedec::artifacts_dir();
    anyhow::ensure!(
        dir.join("target_config.txt").exists(),
        "artifacts missing — run `make artifacts` first"
    );
    let k: usize = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(6);

    let cfg = EngineConfig {
        stages: 4,
        tree: TreeConfig {
            max_width: 8,
            max_children: 8,
            max_depth: 12,
        },
        max_new_tokens: 32,
        ..EngineConfig::default()
    };
    let mut engine = PipeDecEngine::new(&dir, cfg)?;

    // submit k requests (round-robin over the six domains, as in Fig. 8)
    let prompts = mixed_stream(&dir, (k + 5) / 6)?;
    let mut router = Router::new(64);
    for p in prompts.iter().take(k) {
        router.submit(p)?;
    }
    println!("serving {} queued requests through PipeDec-4-stage...", router.depth());

    let t0 = std::time::Instant::now();
    let mut accept_rates = Vec::new();
    let completions = drain(&mut router, |prompt| {
        let r = engine.decode(prompt)?;
        accept_rates.push(r.accept_rate());
        Ok((r.tokens.len(), r.modeled_s))
    })?;
    let wall = t0.elapsed().as_secs_f64();

    let (metrics, lat) = summarize(&completions, wall);
    println!("\nrequests:  {}", metrics.counter("requests"));
    println!("tokens:    {}", metrics.counter("tokens"));
    println!(
        "latency:   p50={:.2}s p95={:.2}s p99={:.2}s (wall, incl. queueing)",
        lat.percentile(50.0),
        lat.percentile(95.0),
        lat.percentile(99.0)
    );
    println!(
        "throughput: {:.1} tokens/s over {:.2}s wall",
        metrics.counter("tokens") as f64 / wall,
        wall
    );
    println!(
        "mean accept rate: {:.2}",
        accept_rates.iter().sum::<f64>() / accept_rates.len().max(1) as f64
    );
    Ok(())
}
