//! End-to-end serving driver (DESIGN.md "End-to-end validation"): load the
//! build-time model through the PJRT runtime and serve a batch of real
//! requests from all six workload domains through the router + any
//! registered engine, reporting per-request latency percentiles,
//! time-to-first-token, and aggregate throughput.
//!
//!     cargo run --release --offline --example serve_batch [-- <k> [engine]]
//!
//! `k` = number of concurrent requests submitted up front (default 6);
//! `engine` = registry name (pipedec | pp | stpp | slm, default pipedec).

use pipedec::config::{EngineConfig, TreeConfig};
use pipedec::engine::{build_engine, EngineKind};
use pipedec::server::{drain, summarize, Router};
use pipedec::workload::mixed_stream;

fn main() -> anyhow::Result<()> {
    let dir = pipedec::artifacts_dir();
    anyhow::ensure!(
        dir.join("target_config.txt").exists(),
        "artifacts missing — run `make artifacts` first"
    );
    let k: usize = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(6);
    let kind: EngineKind = std::env::args()
        .nth(2)
        .map(|s| s.parse())
        .transpose()?
        .unwrap_or(EngineKind::PipeDec);

    let cfg = EngineConfig {
        stages: 4,
        tree: TreeConfig {
            max_width: 8,
            max_children: 8,
            max_depth: 12,
        },
        max_new_tokens: 32,
        ..EngineConfig::default()
    };
    let mut engine = build_engine(kind, &dir, cfg)?;

    // submit k requests (round-robin over the six domains, as in Fig. 8)
    let prompts = mixed_stream(&dir, (k + 5) / 6)?;
    let mut router = Router::new(64);
    for p in prompts.iter().take(k) {
        router.submit_prompt(p)?;
    }
    println!(
        "serving {} queued requests through {kind} ({})...",
        router.depth(),
        kind.describe()
    );

    let t0 = std::time::Instant::now();
    let completions = drain(&mut router, engine.as_mut())?;
    let wall = t0.elapsed().as_secs_f64();

    let (metrics, lat) = summarize(&completions, wall);
    println!("\nrequests:    {}", metrics.counter("requests"));
    println!("tokens:      {}", metrics.counter("tokens"));
    println!(
        "latency:     p50={:.2}s p95={:.2}s p99={:.2}s (wall, incl. queueing)",
        lat.percentile(50.0),
        lat.percentile(95.0),
        lat.percentile(99.0)
    );
    println!(
        "first token: mean={:.2}s (service start -> first streamed token)",
        metrics.summary("first_token_s").mean()
    );
    println!(
        "throughput:  {:.1} tokens/s over {:.2}s wall",
        metrics.counter("tokens") as f64 / wall,
        wall
    );
    Ok(())
}
