//! End-to-end serving driver (DESIGN.md "End-to-end validation"): load the
//! build-time model through the PJRT runtime and serve a batch of real
//! requests from all six workload domains through the router and the
//! continuous-batching scheduler, reporting per-request latency
//! percentiles, time-to-first-token, time-between-tokens, and aggregate
//! throughput. With `pipedec-db` the pipeline interleaves requests; every
//! other engine serves FIFO one-at-a-time through the same loop.
//!
//!     cargo run --release --offline --example serve_batch [-- <k> [engine]]
//!
//! `k` = number of concurrent requests submitted up front (default 6);
//! `engine` = registry name (pipedec | pipedec-db | pp | stpp | slm,
//! default pipedec-db).

use pipedec::config::{EngineConfig, TreeConfig};
use pipedec::engine::{build_scheduled_engine, EngineKind};
use pipedec::server::{serve_until_idle, summarize, Router};
use pipedec::workload::mixed_stream;

fn main() -> anyhow::Result<()> {
    let dir = pipedec::artifacts_dir();
    anyhow::ensure!(
        dir.join("target_config.txt").exists(),
        "artifacts missing — run `make artifacts` first"
    );
    let k: usize = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(6);
    let kind: EngineKind = std::env::args()
        .nth(2)
        .map(|s| s.parse())
        .transpose()?
        .unwrap_or(EngineKind::PipeDecDb);

    let cfg = EngineConfig {
        stages: 4,
        tree: TreeConfig {
            max_width: 8,
            max_children: 8,
            max_depth: 12,
        },
        max_new_tokens: 32,
        ..EngineConfig::default()
    };
    let mut sched = build_scheduled_engine(kind, &dir, cfg)?;

    // submit k requests (round-robin over the six domains, as in Fig. 8)
    let prompts = mixed_stream(&dir, (k + 5) / 6)?;
    let mut router = Router::new(64);
    for p in prompts.iter().take(k) {
        router.submit_prompt(p)?;
    }
    println!(
        "serving {} queued requests through {kind} ({})...",
        router.depth(),
        kind.describe()
    );

    let t0 = std::time::Instant::now();
    let completions = serve_until_idle(&mut router, sched.as_mut())?;
    let wall = t0.elapsed().as_secs_f64();

    let (metrics, lat) = summarize(&completions, wall);
    println!("\nrequests:    {}", metrics.counter("requests"));
    println!("tokens:      {}", metrics.counter("tokens"));
    println!(
        "latency:     p50={:.2}s p95={:.2}s p99={:.2}s (wall, incl. queueing)",
        lat.percentile(50.0),
        lat.percentile(95.0),
        lat.percentile(99.0)
    );
    println!(
        "first token: mean={:.2}s (admission -> first streamed token)",
        metrics.summary("first_token_s").mean()
    );
    println!(
        "inter-token: mean={:.3}s (mean time between streamed tokens)",
        metrics.summary("tbt_s").mean()
    );
    println!(
        "queue depth: mean={:.1} at admission",
        metrics.summary("queue_depth").mean()
    );
    println!(
        "throughput:  {:.1} tokens/s over {:.2}s wall",
        metrics.counter("tokens") as f64 / wall,
        wall
    );
    Ok(())
}
