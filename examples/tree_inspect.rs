//! Inspect the dynamic prediction tree: run the draft model standalone and
//! print the tree after each expansion and prune — a visual companion to
//! paper §3.3 / Fig. 2.
//!
//!     cargo run --release --offline --example tree_inspect

use pipedec::config::TreeConfig;
use pipedec::coordinator::sampling::top_candidates;
use pipedec::kvcache::TwoLevelCache;
use pipedec::model::{bias, ModelHandles};
use pipedec::runtime::Runtime;
use pipedec::tokenizer;
use pipedec::tree::{PredictionTree, PruneOutcome};

fn render(tree: &PredictionTree) -> String {
    let mut out = String::new();
    for l in 0..tree.depth_count() {
        let toks: Vec<String> = tree
            .layer_range(l)
            .map(|i| {
                let ch = tokenizer::decode(&[tree.token(i)]);
                let ch = if ch.is_empty() { format!("#{}", tree.token(i)) } else { ch };
                format!("{:?}(p={:.2})", ch, tree.cum_logprob(i).exp())
            })
            .collect();
        out.push_str(&format!("  layer {l}: {}\n", toks.join(" ")));
    }
    out
}

fn main() -> anyhow::Result<()> {
    let dir = pipedec::artifacts_dir();
    anyhow::ensure!(
        dir.join("draft_config.txt").exists(),
        "artifacts missing — run `make artifacts` first"
    );
    let rt = Runtime::cpu()?;
    let mut draft = ModelHandles::load(&rt, &dir, "draft")?;
    let dc = draft.cfg.clone();
    let mut cache =
        TwoLevelCache::new(dc.n_layers, dc.n_heads, dc.head_dim, dc.past_cap, dc.tree_cap);

    let prompt = "<translate>\nde: der hund ist";
    let prompt_ids = tokenizer::encode(prompt);
    let logits = draft.full_prefill(&rt, &mut cache, &prompt_ids)?;
    let root = pipedec::util::top_k_indices(&logits, 1)[0] as u32;

    let cfg = TreeConfig { max_width: 6, max_children: 3, max_depth: 8 };
    let mut tree = PredictionTree::new(cfg, 64, root, prompt_ids.len());
    println!("prompt: {prompt:?}\nroot token: {:?}\n", tokenizer::decode(&[root]));

    for step in 0..4 {
        // expand one layer with the draft
        let start = cache.tree_len();
        let indices: Vec<usize> = (start..tree.len()).collect();
        let tokens: Vec<u32> = indices.iter().map(|&i| tree.token(i)).collect();
        let mut pos = vec![0i32; dc.width_cap];
        for (r, &i) in indices.iter().enumerate() {
            pos[r] = tree.position_of(i) as i32;
        }
        let rows = tree.bias_rows(&indices, dc.tree_cap, bias::NEG);
        let tb = bias::pad_tree_bias_rows(rows, indices.len(), start, dc.width_cap, dc.tree_cap);
        let logits = draft.full_forward_tree_block(&rt, &mut cache, &tokens, &pos, &tb)?;
        let cands: Vec<Vec<(u32, f32)>> = (0..indices.len())
            .map(|r| top_candidates(&logits[r * dc.vocab_size..(r + 1) * dc.vocab_size], 3))
            .collect();
        tree.expand_layer(&cands);
        println!("after expansion {step}:\n{}", render(&tree));
    }

    // simulate a verification: accept the most probable depth-1 child
    let best = tree.layer_range(1).max_by(|&a, &b| {
        tree.cum_logprob(a).partial_cmp(&tree.cum_logprob(b)).unwrap()
    });
    if let Some(best) = best {
        let x = tree.token(best);
        println!("verify: target decodes {:?} -> prune", tokenizer::decode(&[x]));
        match tree.prune(x) {
            PruneOutcome::Hit { kept_old, .. } => {
                cache.promote_root_to_past()?;
                cache.compact_tree(&kept_old);
                println!("HIT — subtree survives:\n{}", render(&tree));
            }
            PruneOutcome::Miss => println!("MISS — tree reinitialized"),
        }
        tree.check_invariants().map_err(|e| anyhow::anyhow!(e))?;
        println!("tree invariants hold after prune ✓");
    }
    Ok(())
}
