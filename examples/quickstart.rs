//! Quickstart: decode one prompt with PipeDec and with plain pipeline
//! parallelism (PP) through the unified engine registry, verify the outputs
//! match token-for-token (losslessness), and compare latency.
//!
//!     cargo run --release --offline --example quickstart
//!
//! Requires `make artifacts` to have run.

use pipedec::config::{EngineConfig, TreeConfig};
use pipedec::engine::{build_engine, Engine, EngineKind};

fn main() -> anyhow::Result<()> {
    let dir = pipedec::artifacts_dir();
    anyhow::ensure!(
        dir.join("target_config.txt").exists(),
        "artifacts missing — run `make artifacts` first"
    );

    let cfg = EngineConfig {
        stages: 8,
        tree: TreeConfig {
            max_width: 8,
            max_children: 8,
            max_depth: 12,
        },
        max_new_tokens: 48,
        ..EngineConfig::default()
    };

    let prompt = "<math>\nquestion: carol packs 5 boxes with 6 coins each. total coins?\n";
    println!("prompt:\n{prompt}");

    let kinds = [EngineKind::PipeDec, EngineKind::Pp];
    let mut outputs = Vec::new();
    for (i, kind) in kinds.iter().enumerate() {
        println!("[{}/{}] {} ({})", i + 1, kinds.len(), kind, kind.describe());
        let mut engine = build_engine(*kind, &dir, cfg.clone())?;
        let r = engine.decode_prompt(prompt)?;
        println!("  completion: {:?}", r.text);
        println!(
            "  tokens={} modeled={:.1} ms/token",
            r.tokens.len(),
            1e3 * r.modeled_s_per_token()
        );
        if let Some(spec) = r.spec {
            println!(
                "  timesteps={} accept_rate={:.2}",
                spec.timesteps,
                spec.accept_rate()
            );
        }
        outputs.push(r);
    }

    let (pd, pp) = (&outputs[0], &outputs[1]);
    let n = pd.tokens.len().min(pp.tokens.len());
    anyhow::ensure!(
        pd.tokens[..n] == pp.tokens[..n],
        "losslessness violated: outputs differ"
    );
    println!("\noutputs identical over {n} tokens (lossless OK)");
    println!(
        "modeled speedup: {:.2}x",
        pp.modeled_s_per_token() / pd.modeled_s_per_token()
    );
    Ok(())
}
