//! Quickstart: decode one prompt with PipeDec and with plain pipeline
//! parallelism (PP) over the same artifacts, verify the outputs match
//! token-for-token (losslessness), and compare latency.
//!
//!     cargo run --release --offline --example quickstart
//!
//! Requires `make artifacts` to have run.

use pipedec::baselines::PpEngine;
use pipedec::config::{EngineConfig, TreeConfig};
use pipedec::coordinator::PipeDecEngine;

fn main() -> anyhow::Result<()> {
    let dir = pipedec::artifacts_dir();
    anyhow::ensure!(
        dir.join("target_config.txt").exists(),
        "artifacts missing — run `make artifacts` first"
    );

    let cfg = EngineConfig {
        stages: 8,
        tree: TreeConfig {
            max_width: 8,
            max_children: 8,
            max_depth: 12,
        },
        max_new_tokens: 48,
        ..EngineConfig::default()
    };

    let prompt = "<math>\nquestion: carol packs 5 boxes with 6 coins each. total coins?\n";
    println!("prompt:\n{prompt}");

    println!("[1/2] PipeDec (8-stage pipeline + draft in pipeline + dynamic tree)");
    let mut pipedec = PipeDecEngine::new(&dir, cfg.clone())?;
    let r = pipedec.decode(prompt)?;
    println!("  completion: {:?}", r.text);
    println!(
        "  tokens={} timesteps={} accept_rate={:.2} modeled={:.1} ms/token",
        r.tokens.len(),
        r.timesteps,
        r.accept_rate(),
        1e3 * r.modeled_s_per_token()
    );

    println!("[2/2] PP (same pipeline, no speculation)");
    let mut pp = PpEngine::new(&dir, cfg)?;
    let b = pp.decode(prompt)?;
    println!("  completion: {:?}", b.text);
    println!(
        "  tokens={} modeled={:.1} ms/token",
        b.tokens.len(),
        1e3 * b.modeled_s_per_token()
    );

    let n = r.tokens.len().min(b.tokens.len());
    anyhow::ensure!(
        r.tokens[..n] == b.tokens[..n],
        "losslessness violated: outputs differ"
    );
    println!("\noutputs identical over {n} tokens (lossless OK)");
    println!(
        "modeled speedup: {:.2}x",
        b.modeled_s_per_token() / r.modeled_s_per_token()
    );
    Ok(())
}
