//! Compare every registered engine on one prompt per workload domain — a
//! miniature of the paper's Fig. 5 on the real artifact-backed engines,
//! iterating the `EngineKind` registry instead of naming engines by hand.
//!
//!     cargo run --release --offline --example compare_engines

use pipedec::config::{EngineConfig, TreeConfig};
use pipedec::engine::{build_engine, DecodeOutput, Engine, EngineKind};
use pipedec::metrics::Table;
use pipedec::workload::Workload;

fn main() -> anyhow::Result<()> {
    let dir = pipedec::artifacts_dir();
    anyhow::ensure!(
        dir.join("target_config.txt").exists(),
        "artifacts missing — run `make artifacts` first"
    );
    let cfg = EngineConfig {
        stages: 8,
        tree: TreeConfig {
            max_width: 8,
            max_children: 8,
            max_depth: 12,
        },
        max_new_tokens: 24,
        ..EngineConfig::default()
    };

    let mut engines: Vec<Box<dyn Engine>> = Vec::new();
    for kind in EngineKind::ALL {
        engines.push(build_engine(kind, &dir, cfg.clone())?);
    }

    let mut header: Vec<String> = vec!["domain".into(), "dataset".into()];
    header.extend(EngineKind::ALL.iter().map(|k| format!("{k} ms/tok")));
    header.push("accept".into());
    let header_refs: Vec<&str> = header.iter().map(|s| s.as_str()).collect();
    let mut table = Table::new(&header_refs);

    for wl in Workload::load_all(&dir)? {
        let prompt = &wl.prompts[0];
        let outputs: Vec<DecodeOutput> = engines
            .iter_mut()
            .map(|e| e.decode_prompt(prompt))
            .collect::<anyhow::Result<_>>()?;

        // losslessness: every speculative engine matches PP's greedy prefix
        let idx_of = |kind: EngineKind| {
            EngineKind::ALL.iter().position(|&k| k == kind).unwrap()
        };
        let pp = &outputs[idx_of(EngineKind::Pp)];
        for (kind, out) in EngineKind::ALL.iter().zip(&outputs) {
            if kind.is_speculative() {
                let n = out.tokens.len().min(pp.tokens.len());
                anyhow::ensure!(
                    out.tokens[..n] == pp.tokens[..n],
                    "{kind} != pp on {}",
                    wl.domain
                );
            }
        }

        let mut row = vec![wl.domain.clone(), wl.dataset_analogue.clone()];
        row.extend(
            outputs
                .iter()
                .map(|o| format!("{:.1}", 1e3 * o.modeled_s_per_token())),
        );
        row.push(format!(
            "{:.2}",
            outputs[idx_of(EngineKind::PipeDec)].accept_rate()
        ));
        table.row(row);
    }
    println!("{}", table.render());
    println!("(modeled = parallel-schedule latency from measured per-stage times)");
    Ok(())
}
