//! Compare all four engines (PipeDec / STPP / PP / SLM) on one prompt per
//! workload domain — a miniature of the paper's Fig. 5 on the real
//! artifact-backed engines.
//!
//!     cargo run --release --offline --example compare_engines

use pipedec::baselines::{PpEngine, SlmEngine, StppEngine};
use pipedec::config::{EngineConfig, TreeConfig};
use pipedec::coordinator::PipeDecEngine;
use pipedec::metrics::Table;
use pipedec::workload::Workload;

fn main() -> anyhow::Result<()> {
    let dir = pipedec::artifacts_dir();
    anyhow::ensure!(
        dir.join("target_config.txt").exists(),
        "artifacts missing — run `make artifacts` first"
    );
    let cfg = EngineConfig {
        stages: 8,
        tree: TreeConfig {
            max_width: 8,
            max_children: 8,
            max_depth: 12,
        },
        max_new_tokens: 24,
        ..EngineConfig::default()
    };

    let mut pipedec = PipeDecEngine::new(&dir, cfg.clone())?;
    let mut stpp = StppEngine::new(&dir, cfg.clone())?;
    let mut pp = PpEngine::new(&dir, cfg.clone())?;
    let mut slm = SlmEngine::new(&dir, cfg)?;

    let mut table = Table::new(&[
        "domain", "dataset", "pipedec ms/tok", "stpp ms/tok", "pp ms/tok",
        "slm ms/tok", "accept",
    ]);
    for wl in Workload::load_all(&dir)? {
        let prompt = &wl.prompts[0];
        let r = pipedec.decode(prompt)?;
        let s = stpp.decode(prompt)?;
        let p = pp.decode(prompt)?;
        let l = slm.decode(prompt)?;
        // losslessness across speculative engines
        let n = r.tokens.len().min(p.tokens.len()).min(s.tokens.len());
        anyhow::ensure!(r.tokens[..n] == p.tokens[..n], "pipedec != pp on {}", wl.domain);
        anyhow::ensure!(s.tokens[..n] == p.tokens[..n], "stpp != pp on {}", wl.domain);
        table.row(vec![
            wl.domain.clone(),
            wl.dataset_analogue.clone(),
            format!("{:.1}", 1e3 * r.modeled_s_per_token()),
            format!("{:.1}", 1e3 * s.modeled_s_per_token()),
            format!("{:.1}", 1e3 * p.modeled_s_per_token()),
            format!("{:.1}", 1e3 * l.modeled_s_per_token()),
            format!("{:.2}", r.accept_rate()),
        ]);
    }
    println!("{}", table.render());
    println!("(modeled = parallel-schedule latency from measured per-stage times)");
    Ok(())
}
