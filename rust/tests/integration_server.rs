//! Server front end over the real PipeDec engine: FIFO service, latency
//! accounting, backpressure.

use pipedec::config::{EngineConfig, TreeConfig};
use pipedec::coordinator::PipeDecEngine;
use pipedec::server::{drain, summarize, Router};
use pipedec::workload::mixed_stream;

fn artifacts() -> Option<std::path::PathBuf> {
    let dir = pipedec::artifacts_dir();
    dir.join("target_config.txt").exists().then_some(dir)
}

#[test]
fn serves_a_mixed_queue_end_to_end() {
    let Some(dir) = artifacts() else { eprintln!("skipping: no artifacts"); return };
    let cfg = EngineConfig {
        stages: 2,
        tree: TreeConfig { max_width: 4, max_children: 4, max_depth: 8 },
        max_new_tokens: 12,
        ..EngineConfig::default()
    };
    let mut engine = PipeDecEngine::new(&dir, cfg).unwrap();
    let mut router = Router::new(16);
    for p in mixed_stream(&dir, 1).unwrap().iter().take(3) {
        router.submit(p).unwrap();
    }
    let t0 = std::time::Instant::now();
    let done = drain(&mut router, |p| {
        let r = engine.decode(p)?;
        Ok((r.tokens.len(), r.modeled_s))
    }).unwrap();
    let (m, lat) = summarize(&done, t0.elapsed().as_secs_f64());
    assert_eq!(m.counter("requests"), 3);
    assert!(m.counter("tokens") >= 3 * 12 as u64);
    assert_eq!(lat.len(), 3);
    // FIFO: later arrivals wait longer
    assert!(done[2].latency_s >= done[0].latency_s);
}

#[test]
fn queue_backpressure() {
    let mut router = Router::new(1);
    router.submit("a").unwrap();
    assert!(router.submit("b").is_err());
}
