//! Server front end over real engines behind `Box<dyn Engine>`: FIFO
//! service, latency + first-token accounting, per-request overrides,
//! backpressure.

use pipedec::config::{EngineConfig, TreeConfig};
use pipedec::engine::{build_engine, DecodeRequest, EngineKind};
use pipedec::server::{drain, summarize, Router};
use pipedec::workload::mixed_stream;

fn artifacts() -> Option<std::path::PathBuf> {
    let dir = pipedec::artifacts_dir();
    dir.join("target_config.txt").exists().then_some(dir)
}

fn cfg() -> EngineConfig {
    EngineConfig {
        stages: 2,
        tree: TreeConfig { max_width: 4, max_children: 4, max_depth: 8 },
        max_new_tokens: 12,
        ..EngineConfig::default()
    }
}

#[test]
fn serves_a_mixed_queue_end_to_end() {
    let Some(dir) = artifacts() else { eprintln!("skipping: no artifacts"); return };
    let mut engine = build_engine(EngineKind::PipeDec, &dir, cfg()).unwrap();
    let mut router = Router::new(16);
    for p in mixed_stream(&dir, 1).unwrap().iter().take(3) {
        router.submit_prompt(p).unwrap();
    }
    let t0 = std::time::Instant::now();
    let done = drain(&mut router, engine.as_mut()).unwrap();
    let (m, lat) = summarize(&done, t0.elapsed().as_secs_f64());
    assert_eq!(m.counter("requests"), 3);
    assert!(m.counter("tokens") >= 3 * 12);
    assert_eq!(lat.len(), 3);
    // FIFO: later arrivals wait longer
    assert!(done[2].latency_s >= done[0].latency_s);
    // streaming-aware capture: first token lands before full service ends
    assert!(done.iter().all(|c| c.first_token_s > 0.0));
    assert!(done.iter().all(|c| c.first_token_s <= c.service_s));
    assert!(done.iter().all(|c| c.engine == "pipedec"));
}

#[test]
fn per_request_max_new_override_is_served() {
    let Some(dir) = artifacts() else { eprintln!("skipping: no artifacts"); return };
    let mut engine = build_engine(EngineKind::PipeDec, &dir, cfg()).unwrap();
    let prompt = &mixed_stream(&dir, 1).unwrap()[0];
    let mut router = Router::new(4);
    router.submit(DecodeRequest::new(prompt).with_max_new_tokens(4)).unwrap();
    router.submit_prompt(prompt).unwrap();
    let done = drain(&mut router, engine.as_mut()).unwrap();
    assert!(done[0].tokens <= 4, "override ignored: {} tokens", done[0].tokens);
    assert!(done[1].tokens >= done[0].tokens);
}

#[test]
fn queue_backpressure() {
    let mut router = Router::new(1);
    router.submit_prompt("a").unwrap();
    assert!(router.submit_prompt("b").is_err());
}
