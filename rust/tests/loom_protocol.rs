//! Exhaustive interleaving checks of the decide/commit + worker-handoff
//! protocol (ISSUE 6 tentpole, layer 1).
//!
//! These tests run in every build (`cargo test --test loom_protocol`): the
//! model in `pipedec::concurrency::model` drives the *production* protocol
//! types (`CommitLog`, `CommitCursor`, `verify_drained`) through the
//! in-tree explicit-state explorer, searching every schedule of the
//! coordinator and worker threads. The `RUSTFLAGS="--cfg loom"` CI lane
//! runs the same exhaustive search and additionally builds the rest of the
//! crate against the instrumented `concurrency::sync` shim.
//!
//! Checked properties (each also has seeded-mutation tests proving the
//! search actually distinguishes a broken protocol from a correct one):
//! 1. no commit is skipped or double-applied under any interleaving;
//! 2. no forward runs with an undrained commit suffix;
//! 3. overlap-on and overlap-off reach the same final cache epoch;
//! 4. pool shutdown never drops an in-flight job.
//!
//! The second half of the file covers the continuous-speculation epoch
//! protocol (ISSUE 10) through `SpecModel`, which drives the production
//! acceptance predicate `expansion_applicable`: under every interleaving
//! of the free-running draft against prune/reset/serve rounds, no stale
//! generation is ever applied and no still-valid generation is ever
//! dropped — with seeded mutations proving each defense (epoch tag,
//! frontier equality, divergence guard) is load-bearing.

use pipedec::concurrency::explore::Explorer;
use pipedec::concurrency::model::{
    Mutations, ProtocolModel, SpecEvent, SpecModel, SpecMutations,
};

/// 3 workers (2 stage groups + the pinned draft worker), 2 sync rounds,
/// with a sparse row so one owner lags a full epoch behind — the case the
/// pending-suffix, `commit_target` and trim logic exist for.
fn occupancy() -> Vec<Vec<bool>> {
    vec![vec![true, true, true], vec![true, false, true]]
}

fn explore(m: &ProtocolModel) -> Result<pipedec::concurrency::explore::Stats, String> {
    Explorer::new().explore(m).map_err(|v| v.to_string())
}

#[test]
fn overlap_protocol_safe_under_all_interleavings() {
    let m = ProtocolModel::new(3, true, occupancy());
    let stats = explore(&m).expect("overlap protocol must be safe");
    // Sanity: the search was a real one, not a degenerate walk. (The
    // exact distinct-state count is an implementation detail; a linear
    // walk of this protocol would be ~60 states.)
    assert!(
        stats.states > 300,
        "suspiciously small state space: {stats:?}"
    );
    assert!(stats.transitions > stats.states, "no branching explored");
    assert!(stats.terminals >= 1);
}

#[test]
fn serial_protocol_safe_under_all_interleavings() {
    let m = ProtocolModel::new(3, false, occupancy());
    let stats = explore(&m).expect("serial protocol must be safe");
    assert!(stats.states > 100, "suspiciously small state space: {stats:?}");
}

#[test]
fn overlap_and_serial_reach_the_same_final_epoch_on_every_owner() {
    let on = ProtocolModel::new(3, true, occupancy());
    let off = ProtocolModel::new(3, false, occupancy());
    explore(&on).expect("overlap-on must be safe");
    explore(&off).expect("overlap-off must be safe");
    let on_epochs = on.terminal_epochs.borrow().clone();
    let off_epochs = off.terminal_epochs.borrow().clone();
    // Two sync rounds issued two commits: every owner in every terminal
    // state of either mode ends at exactly epoch 2.
    assert_eq!(on_epochs, off_epochs);
    assert_eq!(on_epochs.into_iter().collect::<Vec<_>>(), vec![vec![2, 2, 2]]);
}

#[test]
fn all_two_worker_occupancy_patterns_are_safe() {
    // Exhaustive over every 2-round occupancy pattern of 2 workers
    // (including rounds that dispatch nobody), both modes.
    for mask in 0u32..16 {
        let occ = vec![
            vec![mask & 1 != 0, mask & 2 != 0],
            vec![mask & 4 != 0, mask & 8 != 0],
        ];
        for overlap in [false, true] {
            let m = ProtocolModel::new(2, overlap, occ.clone());
            explore(&m).unwrap_or_else(|e| {
                panic!("occupancy {occ:?} overlap={overlap} failed: {e}")
            });
        }
    }
}

#[test]
fn shutdown_never_drops_an_inflight_job() {
    // No sync rounds at all: the whole model is dispatch-drain-close-join,
    // maximizing interleavings of the close against the workers' final
    // recv. The terminal check requires every queue empty, every worker
    // exited, and one forward per dispatched job.
    let m = ProtocolModel::new(3, true, vec![]);
    explore(&m).expect("clean shutdown must not drop jobs");
}

// ---- seeded mutations: the search must *fail* on a broken protocol ----

#[test]
fn mutation_over_trimming_the_log_is_caught_by_the_staleness_guard() {
    let m = ProtocolModel::new(3, true, occupancy()).with_mutations(Mutations {
        trim_ahead: true,
        ..Mutations::default()
    });
    let err = explore(&m).expect_err("over-trim must be detected");
    // The production `commit_target` guard fires before any forward runs.
    assert!(
        err.contains("undrained commit suffix"),
        "unexpected violation: {err}"
    );
}

#[test]
fn mutation_dropping_the_staleness_guard_fails_the_model() {
    // With the `commit_target` check deleted, the over-trim hazard it
    // guards against reaches the forward pass — and the model's
    // ground-truth invariant (independent of the production guards)
    // catches the stale forward.
    let m = ProtocolModel::new(3, true, occupancy()).with_mutations(Mutations {
        trim_ahead: true,
        drop_target_check: true,
        ..Mutations::default()
    });
    let err = explore(&m).expect_err("guardless over-trim must fail the model");
    assert!(
        err.contains("ran a forward with an undrained commit suffix"),
        "unexpected violation: {err}"
    );
}

#[test]
fn dropping_the_staleness_guard_alone_is_defense_in_depth() {
    // Without a log-maintenance bug the drained suffix always reaches the
    // target, so removing the guard alone does not break the protocol —
    // it is defense in depth. This test pins that understanding (and the
    // two tests above prove the guard is load-bearing the moment trim
    // maintenance goes wrong).
    let m = ProtocolModel::new(3, true, occupancy()).with_mutations(Mutations {
        drop_target_check: true,
        ..Mutations::default()
    });
    explore(&m).expect("guard removal alone must not change behaviour");
}

#[test]
fn mutation_minting_without_queueing_loses_the_commit() {
    let m = ProtocolModel::new(3, true, occupancy()).with_mutations(Mutations {
        skip_queue: true,
        ..Mutations::default()
    });
    let err = explore(&m).expect_err("a decided-but-unqueued commit must be detected");
    assert!(
        err.contains("undrained commit suffix"),
        "unexpected violation: {err}"
    );
}

#[test]
fn mutation_double_applying_a_commit_is_caught_by_the_cursor() {
    let m = ProtocolModel::new(3, true, occupancy()).with_mutations(Mutations {
        apply_twice: true,
        ..Mutations::default()
    });
    let err = explore(&m).expect_err("double apply must be detected");
    assert!(
        err.contains("in-order replay broken"),
        "unexpected violation: {err}"
    );
}

#[test]
fn mutation_eager_shutdown_drops_an_inflight_job() {
    // Worker checks the disconnect flag before draining its queue: some
    // interleaving closes the channel while a drain job is still queued
    // and the job is dropped on the floor.
    let m = ProtocolModel::new(2, true, vec![vec![true, true]]).with_mutations(Mutations {
        shutdown_drops_queue: true,
        ..Mutations::default()
    });
    let err = explore(&m).expect_err("eager shutdown must be detected");
    assert!(
        err.contains("dropped") || err.contains("forwards"),
        "unexpected violation: {err}"
    );
}

// ---- continuous-speculation epoch protocol (ISSUE 10) ----

fn explore_spec(m: &SpecModel) -> Result<pipedec::concurrency::explore::Stats, String> {
    Explorer::new().explore(m).map_err(|v| v.to_string())
}

/// A script exercising every reconciliation path: an in-flight serve, a
/// filtered serve after a prune, and a Miss reset with id-colliding
/// regrowth before the final serve.
fn spec_events() -> Vec<SpecEvent> {
    vec![
        SpecEvent::Expand,
        SpecEvent::Serve,
        SpecEvent::Hit { keep: 1 },
        SpecEvent::Serve,
        SpecEvent::Miss,
        SpecEvent::Expand,
        SpecEvent::Serve,
    ]
}

#[test]
fn speculation_epochs_safe_under_all_interleavings() {
    let m = SpecModel::new(spec_events(), 2, 2);
    let stats = explore_spec(&m).expect("speculation protocol must be safe");
    assert!(
        stats.states > 300,
        "suspiciously small state space: {stats:?}"
    );
    assert!(stats.transitions > stats.states, "no branching explored");
    // The search must actually reach both outcomes: schedules where a
    // banked generation serves in place of a draft dispatch, and
    // schedules where staleness forces a drop.
    let outs = m.outcomes.borrow();
    assert!(outs.iter().any(|&(served, _)| served > 0), "{outs:?}");
    assert!(outs.iter().any(|&(_, dropped)| dropped > 0), "{outs:?}");
}

#[test]
fn filtered_serve_with_divergence_guard_is_safe() {
    // A prune lands between two in-flight generations: the first serves
    // filtered, the guard must then kill the second (its shadow ids alias
    // fresh canonical nodes of different value).
    let m = SpecModel::new(
        vec![
            SpecEvent::Expand,
            SpecEvent::Hit { keep: 1 },
            SpecEvent::Serve,
            SpecEvent::Serve,
        ],
        1,
        2,
    );
    explore_spec(&m).expect("filtered serve + guard must be safe");
}

#[test]
fn miss_reset_with_id_collisions_is_safe() {
    // Miss restarts node-id minting, so a pre-reset generation's parent
    // ids resolve against (differently-valued) post-reset nodes; the
    // epoch tag must keep it out in every interleaving.
    let m = SpecModel::new(
        vec![
            SpecEvent::Expand,
            SpecEvent::Miss,
            SpecEvent::Expand,
            SpecEvent::Serve,
        ],
        1,
        1,
    );
    let stats = explore_spec(&m).expect("miss reset must be safe");
    assert!(stats.terminals >= 1);
}

// ---- seeded mutations: the search must *fail* on a broken protocol ----

#[test]
fn mutation_serving_without_the_applicability_check_applies_a_stale_generation() {
    let mut m = SpecModel::new(vec![SpecEvent::Expand, SpecEvent::Serve], 1, 1);
    m.mutations = SpecMutations {
        apply_stale: true,
        ..SpecMutations::default()
    };
    let err = explore_spec(&m).expect_err("unchecked serve must be detected");
    assert!(
        err.contains("stale expansion applied"),
        "unexpected violation: {err}"
    );
}

#[test]
fn mutation_rejecting_valid_generations_drops_committed_work() {
    let mut m = SpecModel::new(vec![SpecEvent::Serve, SpecEvent::Serve], 1, 1);
    m.mutations = SpecMutations {
        drop_valid: true,
        ..SpecMutations::default()
    };
    let err = explore_spec(&m).expect_err("dropping valid generations must be detected");
    assert!(
        err.contains("valid expansion dropped"),
        "unexpected violation: {err}"
    );
}

#[test]
fn mutation_skipping_the_divergence_guard_applies_an_aliased_generation() {
    // Same script as `filtered_serve_with_divergence_guard_is_safe`; with
    // the guard gone, the second generation's shadow-minted parent ids
    // alias the canonically-minted survivor children and pass the frontier
    // equality check while carrying the pruned branch's values.
    let mut m = SpecModel::new(
        vec![
            SpecEvent::Expand,
            SpecEvent::Hit { keep: 1 },
            SpecEvent::Serve,
            SpecEvent::Serve,
        ],
        1,
        2,
    );
    m.mutations = SpecMutations {
        skip_divergence_guard: true,
        ..SpecMutations::default()
    };
    let err = explore_spec(&m).expect_err("guardless filtered serve must fail");
    assert!(
        err.contains("stale expansion applied"),
        "unexpected violation: {err}"
    );
}

#[test]
fn mutation_ignoring_the_epoch_tag_applies_a_pre_reset_generation() {
    // Same script as `miss_reset_with_id_collisions_is_safe`; with the
    // epoch mechanism removed the collided node ids pass the frontier
    // equality check and a pre-reset generation lands on the new tree.
    let mut m = SpecModel::new(
        vec![
            SpecEvent::Expand,
            SpecEvent::Miss,
            SpecEvent::Expand,
            SpecEvent::Serve,
        ],
        1,
        1,
    );
    m.mutations = SpecMutations {
        ignore_epoch: true,
        ..SpecMutations::default()
    };
    let err = explore_spec(&m).expect_err("epoch removal must be detected");
    assert!(
        err.contains("stale expansion applied"),
        "unexpected violation: {err}"
    );
}
