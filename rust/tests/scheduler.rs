//! Property tests for the step-driven scheduling surface
//! (`engine::session`): random submit/step/cancel interleavings must
//! preserve FIFO admission order, starve no session, never emit from a
//! cancelled session, and — for the real SpecPipe-DB engine — produce
//! per-session outputs identical to a solo decode under greedy sampling,
//! regardless of what is co-scheduled.

use std::cell::RefCell;
use std::rc::Rc;
use std::sync::Arc;

use pipedec::config::{EngineConfig, TreeConfig};
use pipedec::coordinator::PipeDecDbEngine;
use pipedec::faultinject::{self, FaultPlan};
use pipedec::engine::{
    build_engine, build_scheduled_engine, DecodeOutput, DecodeRequest, Engine, EngineKind,
    OneShotScheduler, ScheduledEngine, SessionId, SessionStatus, TokenSink,
};
use pipedec::metrics::Metrics;
use pipedec::tokenizer;
use pipedec::util::XorShiftRng;

/// Stream buffer shared between a session's sink and the test.
type SharedBuf = Rc<RefCell<Vec<u32>>>;

/// Sink whose contents outlive the scheduler's `Box<dyn TokenSink>`.
#[derive(Clone, Default)]
struct SharedSink(SharedBuf);

impl SharedSink {
    fn new() -> (Self, SharedBuf) {
        let buf = SharedBuf::default();
        (Self(buf.clone()), buf)
    }
}

impl TokenSink for SharedSink {
    fn on_token(&mut self, token: u32) {
        self.0.borrow_mut().push(token);
    }
}

/// Deterministic artifact-free engine: echoes the prompt's token ids.
struct EchoEngine {
    cfg: EngineConfig,
}

impl EchoEngine {
    fn new() -> Self {
        Self {
            cfg: EngineConfig::default(),
        }
    }
}

impl Engine for EchoEngine {
    fn kind(&self) -> EngineKind {
        EngineKind::Pp
    }

    fn config(&self) -> &EngineConfig {
        &self.cfg
    }

    fn decode(
        &mut self,
        req: &DecodeRequest,
        sink: &mut dyn TokenSink,
    ) -> anyhow::Result<DecodeOutput> {
        let (max_new, _, _) = req.resolve(&self.cfg);
        let mut tokens = tokenizer::encode(&req.prompt);
        tokens.truncate(max_new);
        for &t in &tokens {
            sink.on_token(t);
        }
        Ok(DecodeOutput {
            text: tokenizer::decode(&tokens),
            tokens,
            wall_s: 0.0,
            modeled_s: 0.05,
            spec: None,
            metrics: Metrics::new(),
        })
    }
}

#[test]
fn random_interleavings_fifo_no_starvation_cancelled_silent() {
    for trial in 0..25u64 {
        let mut rng = XorShiftRng::new(trial + 1);
        let mut sched = OneShotScheduler::new(Box::new(EchoEngine::new()));
        let mut submitted: Vec<(SessionId, String, SharedBuf)> = Vec::new();
        let mut cancelled: Vec<SessionId> = Vec::new();
        let mut finished_order: Vec<SessionId> = Vec::new();

        let drive = |sched: &mut OneShotScheduler,
                     finished_order: &mut Vec<SessionId>,
                     cancelled: &[SessionId]| {
            let rep = sched.step().unwrap();
            for (sid, _) in &rep.emitted {
                assert!(
                    !cancelled.contains(sid),
                    "trial {trial}: cancelled session {sid} emitted a token"
                );
            }
            finished_order.extend(rep.finished.iter().copied());
        };

        for op in 0..40 {
            match rng.below(3) {
                0 => {
                    let prompt = format!("request {op} of trial {trial}");
                    let (sink, buf) = SharedSink::new();
                    let id = sched
                        .submit(DecodeRequest::new(&prompt), Box::new(sink))
                        .unwrap();
                    submitted.push((id, prompt, buf));
                }
                1 => drive(&mut sched, &mut finished_order, &cancelled),
                _ => {
                    if submitted.is_empty() {
                        continue;
                    }
                    let id = submitted[rng.below(submitted.len())].0;
                    if sched.cancel(id) {
                        assert_eq!(sched.status(id), Some(SessionStatus::Cancelled));
                        cancelled.push(id);
                    }
                }
            }
        }
        // no starvation: draining the scheduler finishes everything left
        while sched.has_work() {
            drive(&mut sched, &mut finished_order, &cancelled);
        }

        // FIFO: completion order == submission order minus cancellations
        let expected: Vec<SessionId> = submitted
            .iter()
            .map(|(id, _, _)| *id)
            .filter(|id| !cancelled.contains(id))
            .collect();
        assert_eq!(finished_order, expected, "trial {trial}: FIFO violated");

        let mut solo = EchoEngine::new();
        for (id, prompt, buf) in &submitted {
            if cancelled.contains(id) {
                assert!(
                    buf.borrow().is_empty(),
                    "trial {trial}: cancelled session {id} streamed tokens"
                );
                assert!(sched.poll(*id).is_none());
                continue;
            }
            // outputs match a solo decode; streams match outputs
            let out = sched.poll(*id).expect("non-cancelled session finishes");
            let solo_out = solo.decode_prompt(prompt).unwrap();
            assert_eq!(out.tokens, solo_out.tokens, "trial {trial}: {id}");
            assert_eq!(*buf.borrow(), out.tokens, "trial {trial}: {id} stream");
        }
    }
}

// ---------------------------------------------------------------------
// SpecPipe-DB: real-engine scheduler properties (artifact-gated)
// ---------------------------------------------------------------------

fn artifacts() -> Option<std::path::PathBuf> {
    let dir = pipedec::artifacts_dir();
    dir.join("target_config.txt").exists().then_some(dir)
}

/// Serialize db-engine tests against the process-global fault-injection
/// state: tests that arm plans hold this guard for their whole body, and
/// every other db test takes it with an empty plan so it can never run
/// concurrently with an armed window (which would skew hit counters and
/// inject faults into the wrong test).
fn fault_quiesce() -> faultinject::FaultGuard {
    let guard = faultinject::install(FaultPlan::default());
    faultinject::disarm(); // hold the lock, but keep fire() on the no-op path
    guard
}

fn cfg() -> EngineConfig {
    EngineConfig {
        stages: 2,
        tree: TreeConfig {
            max_width: 4,
            max_children: 4,
            max_depth: 8,
        },
        max_new_tokens: 10,
        ..EngineConfig::default()
    }
}

const PROMPTS: [&str; 3] = [
    "<math>\nquestion: alice has 4 apples and buys 3 more. how many apples now?\n",
    "<math>\nquestion: bob has 3 coins and finds 2 more. how many coins now?\n",
    "<math>\nquestion: carol packs 5 boxes with 6 coins each. total coins?\n",
];

fn drive_to_idle(sched: &mut dyn ScheduledEngine) -> Vec<SessionId> {
    let mut finished = Vec::new();
    for _ in 0..100_000 {
        if !sched.has_work() {
            return finished;
        }
        let rep = sched.step().unwrap();
        finished.extend(rep.finished.iter().copied());
    }
    panic!("scheduler did not go idle");
}

#[test]
fn db_coscheduled_outputs_match_solo_decode() {
    let Some(dir) = artifacts() else {
        eprintln!("skipping: no artifacts");
        return;
    };
    let _faults = fault_quiesce();
    // solo greedy decodes through the one-shot PipeDec engine
    let mut solo = build_engine(EngineKind::PipeDec, &dir, cfg()).unwrap();
    let expected: Vec<Vec<u32>> = PROMPTS
        .iter()
        .map(|p| solo.decode_prompt(p).unwrap().tokens)
        .collect();

    // the same three requests co-scheduled through SpecPipe-DB
    let mut sched = build_scheduled_engine(EngineKind::PipeDecDb, &dir, cfg()).unwrap();
    let mut handles = Vec::new();
    for p in PROMPTS {
        let (sink, buf) = SharedSink::new();
        let id = sched
            .submit(DecodeRequest::new(p), Box::new(sink))
            .unwrap();
        handles.push((id, buf));
    }
    let finished = drive_to_idle(sched.as_mut());
    assert_eq!(finished.len(), PROMPTS.len(), "every session finishes");

    for ((id, buf), want) in handles.iter().zip(&expected) {
        let out = sched.poll(*id).expect("finished session is pollable");
        assert_eq!(
            &out.tokens, want,
            "{id}: co-scheduled greedy output diverged from solo decode"
        );
        assert_eq!(
            *buf.borrow(),
            out.tokens,
            "{id}: session stream diverged from final tokens"
        );
        let spec = out.spec.expect("db engine reports SpecStats");
        assert!(spec.timesteps > 0, "{id}: db sessions live on timesteps");
        assert_eq!(spec.rounds, 0, "{id}: db engine has no STPP rounds");
    }
}

#[test]
fn db_admission_is_fifo_and_overlaps_decode() {
    let Some(dir) = artifacts() else {
        eprintln!("skipping: no artifacts");
        return;
    };
    let _faults = fault_quiesce();
    let mut sched = build_scheduled_engine(EngineKind::PipeDecDb, &dir, cfg()).unwrap();
    let mut ids = Vec::new();
    for p in PROMPTS {
        ids.push(
            sched
                .submit(DecodeRequest::new(p), Box::new(pipedec::engine::NullSink))
                .unwrap(),
        );
    }
    let mut admitted = Vec::new();
    for _ in 0..100_000 {
        if !sched.has_work() {
            break;
        }
        let rep = sched.step().unwrap();
        admitted.extend(rep.admitted.iter().copied());
    }
    assert_eq!(admitted, ids, "admission must be FIFO in submission order");
}

#[test]
fn db_cancelled_sessions_never_emit_again() {
    let Some(dir) = artifacts() else {
        eprintln!("skipping: no artifacts");
        return;
    };
    let _faults = fault_quiesce();
    let mut sched = build_scheduled_engine(EngineKind::PipeDecDb, &dir, cfg()).unwrap();
    let (sink_a, buf_a) = SharedSink::new();
    let a = sched
        .submit(DecodeRequest::new(PROMPTS[0]), Box::new(sink_a))
        .unwrap();
    let (sink_b, buf_b) = SharedSink::new();
    let b = sched
        .submit(DecodeRequest::new(PROMPTS[1]), Box::new(sink_b))
        .unwrap();

    // cancel b while it is still queued (before any step): silent forever
    assert!(sched.cancel(b));
    assert_eq!(sched.status(b), Some(SessionStatus::Cancelled));

    // cancel a mid-decode: tokens stop at the cancellation point
    sched.step().unwrap();
    sched.step().unwrap();
    assert_eq!(sched.status(a), Some(SessionStatus::Running));
    let before = buf_a.borrow().len();
    assert!(sched.cancel(a));
    let finished = drive_to_idle(sched.as_mut());
    assert!(finished.is_empty(), "cancelled sessions never finish");
    assert_eq!(
        buf_a.borrow().len(),
        before,
        "cancelled session emitted after cancel"
    );
    assert!(buf_b.borrow().is_empty(), "queued-cancelled session emitted");
    assert!(sched.poll(a).is_none());
    assert!(sched.poll(b).is_none());
    assert!(!sched.cancel(SessionId(999)), "unknown ids are not cancellable");
}

/// ISSUE 8: cancellation at any admission stage must not leak a pinned
/// prefix-cache block or a device KV mirror slot.
#[test]
fn db_cancel_during_admission_leaks_no_prefix_pin_or_mirror() {
    let Some(dir) = artifacts() else {
        eprintln!("skipping: no artifacts");
        return;
    };
    let _faults = fault_quiesce();
    let mut eng = PipeDecDbEngine::new(&dir, cfg()).unwrap();

    // A runs to completion: the store warms with the template's blocks
    // and the retired session releases its mirrors — that's the baseline
    let a = eng
        .submit(DecodeRequest::new(PROMPTS[0]), Box::new(pipedec::engine::NullSink))
        .unwrap();
    drive_to_idle(&mut eng);
    assert!(eng.poll(a).is_some());
    let baseline = eng.mirror_counts();
    assert_eq!(eng.pinned_prefix_sessions(), 0);
    let warmed = eng.prefix_store().map_or(0, |s| s.l1_len());
    assert!(warmed > 0, "finished session must leave its prefix blocks");

    // B: same template, cancelled while still queued — it was never
    // admitted, so no pin and no mirror slot may appear
    let b = eng
        .submit(DecodeRequest::new(PROMPTS[0]), Box::new(pipedec::engine::NullSink))
        .unwrap();
    assert!(eng.cancel(b));
    assert_eq!(eng.mirror_counts(), baseline, "queued cancel grew a mirror");
    assert_eq!(eng.pinned_prefix_sessions(), 0);

    // C: admitted (pins the shared blocks, warms mirrors), cancelled
    // before finishing — retire must drop the pins and mirror slots
    let c = eng
        .submit(DecodeRequest::new(PROMPTS[0]), Box::new(pipedec::engine::NullSink))
        .unwrap();
    for _ in 0..100_000 {
        if eng.status(c) == Some(SessionStatus::Running) {
            break;
        }
        eng.step().unwrap();
    }
    assert_eq!(eng.status(c), Some(SessionStatus::Running), "C never admitted");
    assert!(eng.pinned_prefix_sessions() >= 1, "admission must pin blocks");
    assert!(eng.cancel(c));
    assert_eq!(eng.pinned_prefix_sessions(), 0, "cancel leaked a prefix pin");
    assert_eq!(eng.mirror_counts(), baseline, "cancel leaked a mirror slot");

    // only the store's own handle (plus ours) remains on the shared
    // template block once every session is gone
    let store = eng.prefix_store().expect("prefix cache on by default");
    let chunk = store.chunk_tokens();
    let ids = tokenizer::encode(PROMPTS[0]);
    assert!(ids.len() > chunk, "template spans at least one block");
    let blk = store.peek_l1(&ids[..chunk]).expect("template block resident");
    assert_eq!(
        Arc::strong_count(&blk),
        2,
        "cancelled sessions must not hold prefix block references"
    );
}

/// ISSUE 9: an injected mid-decode stage failure retires exactly one
/// session as `Failed` while the FIFO queue refills its slot and every
/// surviving session's greedy output stays bit-identical to the
/// fault-free run.
#[test]
fn db_injected_mid_decode_failure_isolates_one_session() {
    let Some(dir) = artifacts() else {
        eprintln!("skipping: no artifacts");
        return;
    };
    let _faults = fault_quiesce();
    let mut c = cfg();
    c.threads = 1; // inline execution: fault hit counts are deterministic

    // fault-free baseline outputs (greedy => schedule-independent)
    let mut base = PipeDecDbEngine::new(&dir, c.clone()).unwrap();
    let mut base_ids = Vec::new();
    for p in PROMPTS {
        base_ids.push(
            base.submit(DecodeRequest::new(p), Box::new(pipedec::engine::NullSink))
                .unwrap(),
        );
    }
    drive_to_idle(&mut base);
    let expected: Vec<Vec<u32>> = base_ids
        .iter()
        .map(|id| base.poll(*id).expect("baseline session finishes").tokens)
        .collect();

    // same three requests with a stage-job error injected mid-decode
    faultinject::arm("stage_job@4=error".parse().unwrap());
    let mut eng = PipeDecDbEngine::new(&dir, c).unwrap();
    let mut ids = Vec::new();
    for p in PROMPTS {
        ids.push(
            eng.submit(DecodeRequest::new(p), Box::new(pipedec::engine::NullSink))
                .unwrap(),
        );
    }
    let finished = drive_to_idle(&mut eng);
    assert_eq!(
        finished.len(),
        PROMPTS.len(),
        "every session reaches a terminal state (FIFO refilled the slot)"
    );

    let mut failed = 0usize;
    for (i, id) in ids.iter().enumerate() {
        match eng.status(*id) {
            Some(SessionStatus::Failed { reason }) => {
                failed += 1;
                assert!(!reason.is_empty(), "{id}: failure must carry a reason");
                assert!(
                    eng.poll(*id).is_some(),
                    "{id}: failed session still yields its partial output"
                );
            }
            Some(SessionStatus::Finished) => {
                let out = eng.poll(*id).expect("finished session is pollable");
                assert_eq!(
                    out.tokens, expected[i],
                    "{id}: surviving session diverged from the fault-free run"
                );
            }
            s => panic!("{id}: unexpected terminal status {s:?}"),
        }
    }
    assert_eq!(failed, 1, "exactly one session absorbs the injected fault");
}

/// ISSUE 9: the failure path must release device KV mirrors and prefix
/// pins exactly like cancellation does (it reuses the same retire paths).
#[test]
fn db_failed_session_leaks_no_prefix_pin_or_mirror() {
    let Some(dir) = artifacts() else {
        eprintln!("skipping: no artifacts");
        return;
    };
    let _faults = fault_quiesce();
    let mut c = cfg();
    c.threads = 1;
    let mut eng = PipeDecDbEngine::new(&dir, c).unwrap();

    // A completes cleanly: baseline mirror occupancy, no pins
    let a = eng
        .submit(DecodeRequest::new(PROMPTS[0]), Box::new(pipedec::engine::NullSink))
        .unwrap();
    drive_to_idle(&mut eng);
    assert!(eng.poll(a).is_some());
    let baseline = eng.mirror_counts();
    assert_eq!(eng.pinned_prefix_sessions(), 0);

    // B fails mid-decode via an injected stage error
    faultinject::arm("stage_job@3=error".parse().unwrap());
    let b = eng
        .submit(DecodeRequest::new(PROMPTS[0]), Box::new(pipedec::engine::NullSink))
        .unwrap();
    drive_to_idle(&mut eng);
    faultinject::disarm();
    assert!(
        matches!(eng.status(b), Some(SessionStatus::Failed { .. })),
        "B must fail, got {:?}",
        eng.status(b)
    );
    assert_eq!(eng.mirror_counts(), baseline, "failure leaked a mirror slot");
    assert_eq!(eng.pinned_prefix_sessions(), 0, "failure leaked a prefix pin");
}
