//! Property-style conformance of the device KV mirror (ISSUE 2): after
//! every mutation of a [`TwoLevelCache`] — `append_tree_block` →
//! `commit_tree` → `promote_root_to_past` → `compact_tree`, including the
//! clear-on-miss path — the buffers a [`DeviceKvCache`] serves must decode
//! to exactly the host `Vec<f32>` tensors, and clean levels must be served
//! without re-upload.
//!
//! Needs only a PJRT CPU client (no compiled artifacts); skipped when the
//! client cannot boot.

use pipedec::kvcache::device::DeviceKvCache;
use pipedec::kvcache::TwoLevelCache;
use pipedec::runtime::{to_vec_f32, Runtime};
use pipedec::util::XorShiftRng;

const LAYERS: usize = 2;
const HEADS: usize = 2;
const HD: usize = 2;
const PAST_CAP: usize = 6;
const TREE_CAP: usize = 5;
const W: usize = 3;

fn fetch(buf: &pipedec::runtime::DeviceBuffer) -> Vec<f32> {
    to_vec_f32(&buf.to_literal_sync().unwrap()).unwrap()
}

/// Sync every layer of the mirror and compare all four tensors against the
/// host cache.
fn assert_mirror_matches(rt: &Runtime, cache: &TwoLevelCache, dev: &mut DeviceKvCache) {
    for l in 0..cache.layers() {
        dev.ensure_past(rt, cache, l).unwrap();
        dev.ensure_tree(rt, cache, l).unwrap();
        let (pk, pv) = dev.past(l).unwrap();
        assert_eq!(fetch(pk), cache.past_k_layer(l), "past_k layer {l}");
        assert_eq!(fetch(pv), cache.past_v_layer(l), "past_v layer {l}");
        let (tk, tv) = dev.tree(l).unwrap();
        assert_eq!(fetch(tk), cache.tree_k_layer(l), "tree_k layer {l}");
        assert_eq!(fetch(tv), cache.tree_v_layer(l), "tree_v layer {l}");
    }
}

fn rand_block(rng: &mut XorShiftRng) -> Vec<f32> {
    (0..HEADS * W * HD).map(|_| rng.next_f32()).collect()
}

/// Random mutation driver: every reachable cache transition, mirror-checked
/// after each step.
fn drive(seed: u64, steps: usize) {
    let Ok(rt) = Runtime::cpu() else {
        eprintln!("skipping: no PJRT client");
        return;
    };
    let mut rng = XorShiftRng::new(seed);
    let mut cache = TwoLevelCache::new(LAYERS, HEADS, HD, PAST_CAP, TREE_CAP);
    let mut dev = DeviceKvCache::new(LAYERS);
    assert_mirror_matches(&rt, &cache, &mut dev);

    for step in 0..steps {
        match rng.below(6) {
            // append one tree block to every layer, then commit
            0 if cache.tree_len() < cache.tree_cap() => {
                let room = cache.tree_cap() - cache.tree_len();
                let count = 1 + rng.below(room.min(W));
                for l in 0..LAYERS {
                    let (k, v) = (rand_block(&mut rng), rand_block(&mut rng));
                    cache.append_tree_block(l, &k, &v, W, count).unwrap();
                }
                cache.commit_tree(count);
            }
            // prefill-style past append
            1 if cache.past_len() < cache.past_cap() => {
                let room = cache.past_cap() - cache.past_len();
                let count = 1 + rng.below(room.min(W));
                for l in 0..LAYERS {
                    let (k, v) = (rand_block(&mut rng), rand_block(&mut rng));
                    cache.append_past_block(l, &k, &v, W, count).unwrap();
                }
                cache.commit_past(count);
            }
            // sync-point promotion
            2 if cache.tree_len() >= 1 && cache.past_len() < cache.past_cap() => {
                cache.promote_root_to_past().unwrap();
            }
            // hit-path compaction: random ascending survivor subset
            3 if cache.tree_len() > 0 => {
                let kept: Vec<usize> =
                    (0..cache.tree_len()).filter(|_| rng.chance(0.5)).collect();
                cache.compact_tree(&kept);
            }
            // miss path: clear, then (often) immediately overwrite stale
            // slots — the mirror must pick up the overwrite
            4 => {
                cache.clear_tree();
                if rng.chance(0.7) {
                    for l in 0..LAYERS {
                        let (k, v) = (rand_block(&mut rng), rand_block(&mut rng));
                        cache.append_tree_block(l, &k, &v, W, 1).unwrap();
                    }
                    cache.commit_tree(1);
                }
            }
            // new request
            5 if step % 17 == 0 => cache.reset(),
            _ => continue,
        }
        assert_mirror_matches(&rt, &cache, &mut dev);
    }

    // the mirror must have served clean levels from device residency
    let (uploads, reuses) = dev.upload_counts();
    assert!(uploads > 0, "mirror never uploaded");
    assert!(
        reuses > 0,
        "mirror never reused a clean level across {steps} steps"
    );
}

#[test]
fn mirror_matches_host_across_mutation_sequences() {
    for seed in [1u64, 7, 42] {
        drive(seed, 60);
    }
}

#[test]
fn clean_resync_is_upload_free() {
    let Ok(rt) = Runtime::cpu() else {
        eprintln!("skipping: no PJRT client");
        return;
    };
    let mut rng = XorShiftRng::new(3);
    let mut cache = TwoLevelCache::new(LAYERS, HEADS, HD, PAST_CAP, TREE_CAP);
    for l in 0..LAYERS {
        let (k, v) = (rand_block(&mut rng), rand_block(&mut rng));
        cache.append_tree_block(l, &k, &v, W, 2).unwrap();
    }
    cache.commit_tree(2);
    let mut dev = DeviceKvCache::new(LAYERS);
    assert_mirror_matches(&rt, &cache, &mut dev);
    let (uploads_after_first, _) = dev.upload_counts();
    let before = rt.stats().snapshot();
    // no mutations in between: the second sync moves zero bytes
    assert_mirror_matches(&rt, &cache, &mut dev);
    let d = rt.stats().snapshot().delta_since(&before);
    assert_eq!(d.up, 0, "clean resync must not upload");
    assert!(d.saved_kv > 0, "clean resync must credit KV saved bytes");
    assert_eq!(d.saved, d.saved_kv, "only the KV mirror ran here");
    assert_eq!(dev.upload_counts().0, uploads_after_first);
}
