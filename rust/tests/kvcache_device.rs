//! Property-style conformance of the device KV mirror (ISSUE 2): after
//! every mutation of a [`TwoLevelCache`] — `append_tree_block` →
//! `commit_tree` → `promote_root_to_past` → `compact_tree`, including the
//! clear-on-miss path — the buffers a [`DeviceKvCache`] serves must decode
//! to exactly the host `Vec<f32>` tensors, and clean levels must be served
//! without re-upload.
//!
//! ISSUE 5 adds the deferred-commit replay property: for any random
//! accept/prune/miss sequence, a cache that applies its [`CacheCommit`]s
//! late — batched at arbitrary points between forwards, as the overlapped
//! sync phase does on pipeline workers — must end every "forward" with
//! host state *and* device-mirror state identical to a cache that applied
//! each commit eagerly at its sync point.
//!
//! ISSUE 7 adds the in-place update property: at the artifact shapes
//! (where the donated [`KvOps`] entry points apply), a mirror maintained
//! purely through [`DeviceKvCache::append_block`] /
//! [`DeviceKvCache::apply_commit`] must stay bit-identical to the host
//! cache — and to the full re-upload reference mirror — across random
//! accept/prune/miss/reset sequences, under eager *and* deferred commit
//! replay, without ever re-uploading a full level tensor.
//!
//! The host-conformance tests need only a PJRT CPU client (no compiled
//! artifacts; skipped when the client cannot boot); the ISSUE 7 tests
//! additionally need built artifacts with the kv entry points and skip
//! otherwise.

use std::collections::VecDeque;
use std::sync::Arc;

use pipedec::kvcache::device::{DeviceKvCache, KvOps, PreState};
use pipedec::kvcache::{CacheCommit, CommitOp, TwoLevelCache};
use pipedec::model::ModelCore;
use pipedec::runtime::{to_vec_f32, Runtime};
use pipedec::util::XorShiftRng;

const LAYERS: usize = 2;
const HEADS: usize = 2;
const HD: usize = 2;
const PAST_CAP: usize = 6;
const TREE_CAP: usize = 5;
const W: usize = 3;

fn fetch(buf: &pipedec::runtime::DeviceBuffer) -> Vec<f32> {
    to_vec_f32(&buf.to_literal_sync().unwrap()).unwrap()
}

/// Sync the whole mirror (through [`DeviceKvCache::sync`]) and compare
/// all four tensors of every layer against the host cache.
fn assert_mirror_matches(rt: &Runtime, cache: &TwoLevelCache, dev: &mut DeviceKvCache) {
    dev.sync(rt, cache).unwrap();
    for l in 0..cache.layers() {
        let (pk, pv) = dev.past(l).unwrap();
        assert_eq!(fetch(pk), cache.past_k_layer(l), "past_k layer {l}");
        assert_eq!(fetch(pv), cache.past_v_layer(l), "past_v layer {l}");
        let (tk, tv) = dev.tree(l).unwrap();
        assert_eq!(fetch(tk), cache.tree_k_layer(l), "tree_k layer {l}");
        assert_eq!(fetch(tv), cache.tree_v_layer(l), "tree_v layer {l}");
    }
}

fn rand_block(rng: &mut XorShiftRng) -> Vec<f32> {
    (0..HEADS * W * HD).map(|_| rng.next_f32()).collect()
}

/// Random mutation driver: every reachable cache transition, mirror-checked
/// after each step.
fn drive(seed: u64, steps: usize) {
    let Ok(rt) = Runtime::cpu() else {
        eprintln!("skipping: no PJRT client");
        return;
    };
    let mut rng = XorShiftRng::new(seed);
    let mut cache = TwoLevelCache::new(LAYERS, HEADS, HD, PAST_CAP, TREE_CAP);
    let mut dev = DeviceKvCache::new(LAYERS);
    assert_mirror_matches(&rt, &cache, &mut dev);

    for step in 0..steps {
        match rng.below(6) {
            // append one tree block to every layer, then commit
            0 if cache.tree_len() < cache.tree_cap() => {
                let room = cache.tree_cap() - cache.tree_len();
                let count = 1 + rng.below(room.min(W));
                for l in 0..LAYERS {
                    let (k, v) = (rand_block(&mut rng), rand_block(&mut rng));
                    cache.append_tree_block(l, &k, &v, W, count).unwrap();
                }
                cache.commit_tree(count);
            }
            // prefill-style past append
            1 if cache.past_len() < cache.past_cap() => {
                let room = cache.past_cap() - cache.past_len();
                let count = 1 + rng.below(room.min(W));
                for l in 0..LAYERS {
                    let (k, v) = (rand_block(&mut rng), rand_block(&mut rng));
                    cache.append_past_block(l, &k, &v, W, count).unwrap();
                }
                cache.commit_past(count);
            }
            // sync-point promotion
            2 if cache.tree_len() >= 1 && cache.past_len() < cache.past_cap() => {
                cache.promote_root_to_past().unwrap();
            }
            // hit-path compaction: random ascending survivor subset
            3 if cache.tree_len() > 0 => {
                let kept: Vec<usize> =
                    (0..cache.tree_len()).filter(|_| rng.chance(0.5)).collect();
                cache.compact_tree(&kept);
            }
            // miss path: clear, then (often) immediately overwrite stale
            // slots — the mirror must pick up the overwrite
            4 => {
                cache.clear_tree();
                if rng.chance(0.7) {
                    for l in 0..LAYERS {
                        let (k, v) = (rand_block(&mut rng), rand_block(&mut rng));
                        cache.append_tree_block(l, &k, &v, W, 1).unwrap();
                    }
                    cache.commit_tree(1);
                }
            }
            // new request
            5 if step % 17 == 0 => cache.reset(),
            _ => continue,
        }
        assert_mirror_matches(&rt, &cache, &mut dev);
    }

    // the mirror must have served clean levels from device residency
    let c = dev.counts();
    assert!(c.past_uploads + c.tree_uploads > 0, "mirror never uploaded");
    assert!(
        c.past_reuses + c.tree_reuses > 0,
        "mirror never reused a clean level across {steps} steps"
    );
}

#[cfg_attr(miri, ignore)] // PJRT FFI — covered by the host-only tests below under Miri
#[test]
fn mirror_matches_host_across_mutation_sequences() {
    for seed in [1u64, 7, 42] {
        drive(seed, 60);
    }
}

/// Field-wise host equality of two caches (lengths + every live slot of
/// both levels + the commit cursor).
fn assert_caches_equal(a: &TwoLevelCache, b: &TwoLevelCache, what: &str) {
    assert_eq!(a.past_len(), b.past_len(), "{what}: past_len");
    assert_eq!(a.tree_len(), b.tree_len(), "{what}: tree_len");
    assert_eq!(a.commit_epoch(), b.commit_epoch(), "{what}: commit_epoch");
    for l in 0..LAYERS {
        for h in 0..HEADS {
            for s in 0..a.past_len() {
                assert_eq!(
                    a.read_past_slot(l, h, s),
                    b.read_past_slot(l, h, s),
                    "{what}: past l{l} h{h} s{s}"
                );
            }
            for s in 0..a.tree_len() {
                assert_eq!(
                    a.read_tree_slot(l, h, s),
                    b.read_tree_slot(l, h, s),
                    "{what}: tree l{l} h{h} s{s}"
                );
            }
        }
    }
}

/// ISSUE 5 replay property: drive an eager cache and a deferred cache
/// through the same random accept/prune/miss sequence. The eager cache
/// applies every commit at its sync point (the serial reference path);
/// the deferred cache queues commits and drains them only at "forward"
/// boundaries (and random batch points with nothing in between) — the
/// worker-side protocol. Host state, commit cursor, and device mirror
/// must be indistinguishable whenever both caches are drained.
fn drive_commit_replay(seed: u64, steps: usize) {
    let Ok(rt) = Runtime::cpu() else {
        eprintln!("skipping: no PJRT client");
        return;
    };
    let mut rng = XorShiftRng::new(seed);
    let mut eager = TwoLevelCache::new(LAYERS, HEADS, HD, PAST_CAP, TREE_CAP);
    let mut lazy = TwoLevelCache::new(LAYERS, HEADS, HD, PAST_CAP, TREE_CAP);
    let mut eager_dev = DeviceKvCache::new(LAYERS);
    let mut lazy_dev = DeviceKvCache::new(LAYERS);
    let mut queue: VecDeque<CacheCommit> = VecDeque::new();
    let mut epoch = 0u64;

    fn drain(lazy: &mut TwoLevelCache, queue: &mut VecDeque<CacheCommit>) {
        while let Some(c) = queue.pop_front() {
            lazy.apply_commit(&c).unwrap();
        }
    }

    for _ in 0..steps {
        match rng.below(4) {
            // "forward pass": both caches append the same tree block —
            // the deferred cache must drain its queue first, exactly as
            // a worker job drains its commits before running
            0 if eager.tree_len() < eager.tree_cap() => {
                drain(&mut lazy, &mut queue);
                assert_caches_equal(&eager, &lazy, "pre-forward");
                let room = eager.tree_cap() - eager.tree_len();
                let count = 1 + rng.below(room.min(W));
                for l in 0..LAYERS {
                    let (k, v) = (rand_block(&mut rng), rand_block(&mut rng));
                    eager.append_tree_block(l, &k, &v, W, count).unwrap();
                    lazy.append_tree_block(l, &k, &v, W, count).unwrap();
                }
                eager.commit_tree(count);
                lazy.commit_tree(count);
            }
            // sync point, hit: random ascending survivor subset (kept[0]
            // is the new root; indices past the processed prefix are
            // legal and ignored by compact_tree)
            1 if eager.tree_len() >= 2 && eager.past_len() + 1 < eager.past_cap() => {
                let kept: Vec<usize> = (1..eager.tree_len() + 2)
                    .filter(|_| rng.chance(0.6))
                    .collect();
                epoch += 1;
                let c = CacheCommit {
                    epoch,
                    op: CommitOp::Hit {
                        kept_old: Arc::new(kept),
                    },
                };
                eager.apply_commit(&c).unwrap();
                queue.push_back(c);
            }
            // sync point, miss
            2 if eager.tree_len() >= 1 && eager.past_len() + 1 < eager.past_cap() => {
                epoch += 1;
                let c = CacheCommit {
                    epoch,
                    op: CommitOp::Miss,
                };
                eager.apply_commit(&c).unwrap();
                queue.push_back(c);
            }
            // arbitrary batch boundary with no forward in between — the
            // deferred side may also catch up here (a worker whose slot
            // got a flow but whose rows were all pruned in flight)
            3 if rng.chance(0.4) => {
                drain(&mut lazy, &mut queue);
                assert_caches_equal(&eager, &lazy, "batch-drain");
            }
            _ => continue,
        }
        // the mirrors track their own cache; the lazy mirror must stay
        // valid even while host commits are still queued
        assert_mirror_matches(&rt, &eager, &mut eager_dev);
        assert_mirror_matches(&rt, &lazy, &mut lazy_dev);
    }
    drain(&mut lazy, &mut queue);
    assert_caches_equal(&eager, &lazy, "final");
    assert_mirror_matches(&rt, &eager, &mut eager_dev);
    assert_mirror_matches(&rt, &lazy, &mut lazy_dev);
    assert_eq!(eager.commit_epoch(), epoch);
}

#[cfg_attr(miri, ignore)] // PJRT FFI
#[test]
fn deferred_commit_replay_matches_eager_sync() {
    for seed in [2u64, 11, 77, 1234] {
        drive_commit_replay(seed, 80);
    }
}

#[test]
fn commit_epochs_reject_out_of_order_replay() {
    let mut c = TwoLevelCache::new(LAYERS, HEADS, HD, PAST_CAP, TREE_CAP);
    let mut rng = XorShiftRng::new(5);
    for l in 0..LAYERS {
        let (k, v) = (rand_block(&mut rng), rand_block(&mut rng));
        c.append_tree_block(l, &k, &v, W, 2).unwrap();
    }
    c.commit_tree(2);
    let miss = |epoch| CacheCommit {
        epoch,
        op: CommitOp::Miss,
    };
    assert!(c.apply_commit(&miss(2)).is_err(), "skipping epoch 1 rejected");
    c.apply_commit(&miss(1)).unwrap();
    assert!(c.apply_commit(&miss(1)).is_err(), "replaying epoch 1 rejected");
    assert_eq!(c.commit_epoch(), 1);
}

/// ISSUE 6 loom variant of the epoch-order property: two independent
/// cache owners each work through a 3-step drain (append a tree block,
/// commit it, apply that step's `Miss` commit) while a model-checker
/// schedule from [`interleavings`] interleaves their steps every possible
/// way — exactly the shape of two pipeline workers draining their commit
/// suffixes concurrently. Every schedule must succeed, every schedule
/// must produce the bit-identical final state on both owners (owner
/// drains are independent, so interleaving cannot matter), and the
/// duplicate/skip rejections must hold at the end of every schedule.
/// Host-only — this test also runs under the Miri lane.
#[test]
fn interleaved_owner_drains_commute_under_all_schedules() {
    use pipedec::concurrency::explore::interleavings;

    const STEPS: usize = 3;
    let schedules = interleavings(&[STEPS, STEPS]);
    assert_eq!(schedules.len(), 20, "C(6,3) interleavings of two owners");

    let run = |schedule: &[usize]| -> Vec<TwoLevelCache> {
        let mut caches = vec![
            TwoLevelCache::new(LAYERS, HEADS, HD, PAST_CAP, TREE_CAP),
            TwoLevelCache::new(LAYERS, HEADS, HD, PAST_CAP, TREE_CAP),
        ];
        // per-owner deterministic data: the blocks an owner appends depend
        // only on its own step count, never on the schedule
        let mut rngs = [XorShiftRng::new(21), XorShiftRng::new(22)];
        let mut next_epoch = [1u64, 1];
        for &owner in schedule {
            let cache = &mut caches[owner];
            let rng = &mut rngs[owner];
            for l in 0..LAYERS {
                let (k, v) = (rand_block(rng), rand_block(rng));
                cache.append_tree_block(l, &k, &v, W, 1).unwrap();
            }
            cache.commit_tree(1);
            let c = CacheCommit {
                epoch: next_epoch[owner],
                op: CommitOp::Miss,
            };
            cache.apply_commit(&c).unwrap();
            next_epoch[owner] += 1;
        }
        for cache in &mut caches {
            assert_eq!(cache.commit_epoch(), STEPS as u64);
            let miss = |epoch| CacheCommit {
                epoch,
                op: CommitOp::Miss,
            };
            assert!(
                cache.apply_commit(&miss(STEPS as u64)).is_err(),
                "duplicate replay rejected"
            );
            assert!(
                cache.apply_commit(&miss(STEPS as u64 + 2)).is_err(),
                "skipped epoch rejected"
            );
            // rejected commits must leave the cursor untouched
            assert_eq!(cache.commit_epoch(), STEPS as u64);
        }
        caches
    };

    let reference = run(&schedules[0]);
    for schedule in &schedules[1..] {
        let got = run(schedule);
        for (owner, (a, b)) in reference.iter().zip(&got).enumerate() {
            assert_caches_equal(a, b, &format!("owner {owner} under {schedule:?}"));
        }
    }
}

#[cfg_attr(miri, ignore)] // PJRT FFI
#[test]
fn clean_resync_is_upload_free() {
    let Ok(rt) = Runtime::cpu() else {
        eprintln!("skipping: no PJRT client");
        return;
    };
    let mut rng = XorShiftRng::new(3);
    let mut cache = TwoLevelCache::new(LAYERS, HEADS, HD, PAST_CAP, TREE_CAP);
    for l in 0..LAYERS {
        let (k, v) = (rand_block(&mut rng), rand_block(&mut rng));
        cache.append_tree_block(l, &k, &v, W, 2).unwrap();
    }
    cache.commit_tree(2);
    let mut dev = DeviceKvCache::new(LAYERS);
    assert_mirror_matches(&rt, &cache, &mut dev);
    let after_first = dev.counts();
    let before = rt.stats().snapshot();
    // no mutations in between: the second sync moves zero bytes
    assert_mirror_matches(&rt, &cache, &mut dev);
    let d = rt.stats().snapshot().delta_since(&before);
    assert_eq!(d.up, 0, "clean resync must not upload");
    assert!(d.saved_kv > 0, "clean resync must credit KV saved bytes");
    assert_eq!(d.saved, d.saved_kv, "only the KV mirror ran here");
    assert_eq!(dev.counts().past_uploads, after_first.past_uploads);
    assert_eq!(dev.counts().tree_uploads, after_first.tree_uploads);
}

// ---------------------------------------------------------------------------
// ISSUE 7: in-place device updates at the artifact shapes
// ---------------------------------------------------------------------------

/// Layers driven by the in-place tests (any count works; the entry points
/// are per-layer).
const OPS_LAYERS: usize = 2;

fn rand_block_n(rng: &mut XorShiftRng, n: usize) -> Vec<f32> {
    (0..n).map(|_| rng.next_f32()).collect()
}

/// Load the target model's donated KV entry points, or explain why the
/// in-place tests are skipped (no artifacts / artifacts predate ISSUE 7).
fn load_kv_core(rt: &Runtime) -> Option<ModelCore> {
    let dir = pipedec::artifacts_dir();
    if !dir.join("target_config.txt").exists() {
        eprintln!("skipping: no artifacts");
        return None;
    }
    match ModelCore::load_with_width(rt, &dir, "target", 8) {
        Ok(core) if core.kv_ops().is_some() => Some(core),
        Ok(_) => {
            eprintln!("skipping: artifacts lack the kv entry points");
            None
        }
        Err(e) => {
            eprintln!("skipping: {e}");
            None
        }
    }
}

/// Host append of one random tree/past block to every layer, mirrored
/// in place on `fast` through the donated append entry point.
fn append_step(
    rt: &Runtime,
    ops: &KvOps,
    cache: &mut TwoLevelCache,
    fast: &mut DeviceKvCache,
    rng: &mut XorShiftRng,
    to_tree: bool,
    count: usize,
) {
    let w = ops.width;
    let start = if to_tree { cache.tree_len() } else { cache.past_len() };
    for l in 0..cache.layers() {
        let k = rand_block_n(rng, ops.heads * w * ops.head_dim);
        let v = rand_block_n(rng, ops.heads * w * ops.head_dim);
        let pre = if to_tree { cache.tree_epoch(l) } else { cache.past_epoch(l) };
        if to_tree {
            cache.append_tree_block(l, &k, &v, w, count).unwrap();
        } else {
            cache.append_past_block(l, &k, &v, w, count).unwrap();
        }
        fast.append_block(rt, ops, cache, l, to_tree, pre, start, &k, &v, w, count)
            .unwrap();
    }
    if to_tree {
        cache.commit_tree(count);
    } else {
        cache.commit_past(count);
    }
}

/// Replay one commit on host + in-place mirror, exactly the
/// `StageContext::apply_commit` choke-point sequence: capture pre-state,
/// mutate the host, replay on the device.
fn commit_step(
    rt: &Runtime,
    ops: &KvOps,
    cache: &mut TwoLevelCache,
    fast: &mut DeviceKvCache,
    c: &CacheCommit,
) {
    let pre = PreState::capture(cache);
    cache.apply_commit(c).unwrap();
    fast.apply_commit(rt, ops, cache, c, &pre).unwrap();
}

/// ISSUE 7 property driver: a mirror maintained purely in place (`fast`)
/// and a full re-upload reference mirror (`refm`) both track the same
/// host cache through a random accept/prune/miss/reset sequence; after
/// every step both must decode bit-identical to the host. With
/// `deferred`, commits queue and drain only at forward boundaries (the
/// overlapped worker protocol); the device replay runs at drain time with
/// drain-time pre-state, exactly as [`StageContext::apply_commit`] does.
fn drive_inplace(rt: &Runtime, ops: &KvOps, seed: u64, steps: usize, deferred: bool) {
    let w = ops.width;
    let mut rng = XorShiftRng::new(seed);
    let mut cache =
        TwoLevelCache::new(OPS_LAYERS, ops.heads, ops.head_dim, ops.past_cap, ops.tree_cap);
    let mut fast = DeviceKvCache::new(OPS_LAYERS);
    let mut refm = DeviceKvCache::new(OPS_LAYERS);
    fast.sync(rt, &cache).unwrap();
    let warm = fast.counts();
    let mut queue: VecDeque<CacheCommit> = VecDeque::new();
    let mut epoch = cache.commit_epoch();

    macro_rules! drain {
        () => {
            while let Some(c) = queue.pop_front() {
                commit_step(rt, ops, &mut cache, &mut fast, &c);
            }
        };
    }

    for _ in 0..steps {
        match rng.below(8) {
            // forward: drain pending commits, then append a tree block
            0..=2 if cache.tree_len() + w < cache.tree_cap() => {
                drain!();
                let count = 1 + rng.below(w);
                append_step(rt, ops, &mut cache, &mut fast, &mut rng, true, count);
            }
            // prefill-style past append
            3 if cache.past_len() + w < cache.past_cap() => {
                drain!();
                let count = 1 + rng.below(w);
                append_step(rt, ops, &mut cache, &mut fast, &mut rng, false, count);
            }
            // sync point, hit: random ascending survivor subset
            4 | 5
                if queue.is_empty()
                    && cache.tree_len() >= 2
                    && cache.past_len() + 1 < cache.past_cap() =>
            {
                let kept: Vec<usize> = (1..cache.tree_len() + 2)
                    .filter(|_| rng.chance(0.6))
                    .collect();
                epoch += 1;
                let c = CacheCommit {
                    epoch,
                    op: CommitOp::Hit { kept_old: Arc::new(kept) },
                };
                if deferred {
                    queue.push_back(c);
                } else {
                    commit_step(rt, ops, &mut cache, &mut fast, &c);
                }
            }
            // sync point, miss
            6 if queue.is_empty()
                && cache.tree_len() >= 1
                && cache.past_len() + 1 < cache.past_cap() =>
            {
                epoch += 1;
                let c = CacheCommit { epoch, op: CommitOp::Miss };
                if deferred {
                    queue.push_back(c);
                } else {
                    commit_step(rt, ops, &mut cache, &mut fast, &c);
                }
            }
            // new request: drain, then length-only reset
            7 if rng.chance(0.2) => {
                drain!();
                cache.reset();
                epoch = cache.commit_epoch();
            }
            _ => continue,
        }
        // the in-place mirror and the re-upload reference must both agree
        // with the host; a wrong-but-clean fast slot fails the fetch here
        assert_mirror_matches(rt, &cache, &mut fast);
        assert_mirror_matches(rt, &cache, &mut refm);
    }
    drain!();
    assert_mirror_matches(rt, &cache, &mut fast);
    assert_mirror_matches(rt, &cache, &mut refm);

    // every host mutation above was mirrored in place: after the warmup
    // sync the fast mirror must never have re-uploaded a level tensor
    let c = fast.counts();
    assert_eq!(
        c.past_uploads, warm.past_uploads,
        "in-place mirror re-uploaded a past level (seed {seed})"
    );
    assert_eq!(
        c.tree_uploads, warm.tree_uploads,
        "in-place mirror re-uploaded a tree level (seed {seed})"
    );
}

#[cfg_attr(miri, ignore)] // PJRT FFI
#[test]
fn inplace_mirror_matches_reupload_reference_eager() {
    let Ok(rt) = Runtime::cpu() else {
        eprintln!("skipping: no PJRT client");
        return;
    };
    let Some(core) = load_kv_core(&rt) else { return };
    let ops = core.kv_ops().expect("checked by load_kv_core");
    for seed in [3u64, 19] {
        drive_inplace(&rt, ops, seed, 40, false);
    }
}

#[cfg_attr(miri, ignore)] // PJRT FFI
#[test]
fn inplace_mirror_matches_reupload_reference_deferred() {
    let Ok(rt) = Runtime::cpu() else {
        eprintln!("skipping: no PJRT client");
        return;
    };
    let Some(core) = load_kv_core(&rt) else { return };
    let ops = core.kv_ops().expect("checked by load_kv_core");
    for seed in [5u64, 23] {
        drive_inplace(&rt, ops, seed, 40, true);
    }
}

/// The ISSUE 7 acceptance property in isolation: on the steady-state
/// accept path (tree appends + Hit commits), the in-place mirror performs
/// zero full level re-uploads — every promote/compact/append lands on the
/// resident buffers — and a final sync moves zero bytes.
#[cfg_attr(miri, ignore)] // PJRT FFI
#[test]
fn accept_path_steady_state_is_reupload_free() {
    let Ok(rt) = Runtime::cpu() else {
        eprintln!("skipping: no PJRT client");
        return;
    };
    let Some(core) = load_kv_core(&rt) else { return };
    let ops = core.kv_ops().expect("checked by load_kv_core");
    let mut rng = XorShiftRng::new(9);
    let mut cache =
        TwoLevelCache::new(OPS_LAYERS, ops.heads, ops.head_dim, ops.past_cap, ops.tree_cap);
    let mut fast = DeviceKvCache::new(OPS_LAYERS);
    fast.sync(&rt, &cache).unwrap();
    let warm = fast.counts();

    let mut epoch = cache.commit_epoch();
    for _ in 0..6 {
        // grow two tree layers (root + children), then accept child 1
        append_step(&rt, ops, &mut cache, &mut fast, &mut rng, true, 1);
        append_step(&rt, ops, &mut cache, &mut fast, &mut rng, true, 2);
        epoch += 1;
        let c = CacheCommit {
            epoch,
            op: CommitOp::Hit { kept_old: Arc::new(vec![1]) },
        };
        commit_step(&rt, ops, &mut cache, &mut fast, &c);
        assert_mirror_matches(&rt, &cache, &mut fast);
    }

    let c = fast.counts();
    assert_eq!(
        c.past_uploads, warm.past_uploads,
        "accept path re-uploaded a full past tensor"
    );
    assert_eq!(
        c.tree_uploads, warm.tree_uploads,
        "accept path re-uploaded a full tree tensor"
    );
    assert!(c.past_appends > warm.past_appends, "promote never ran in place");
    assert!(c.tree_appends > warm.tree_appends, "append/compact never ran in place");
    assert!(c.appended_bytes > warm.appended_bytes);
    assert_eq!(c.reuploaded_bytes, warm.reuploaded_bytes);

    // and the in-place state is clean: one more sync moves zero bytes
    let before = rt.stats().snapshot();
    fast.sync(&rt, &cache).unwrap();
    let d = rt.stats().snapshot().delta_since(&before);
    assert_eq!(d.up, 0, "steady-state sync after in-place maintenance uploaded");
}
