//! Property-style conformance of the device KV mirror (ISSUE 2): after
//! every mutation of a [`TwoLevelCache`] — `append_tree_block` →
//! `commit_tree` → `promote_root_to_past` → `compact_tree`, including the
//! clear-on-miss path — the buffers a [`DeviceKvCache`] serves must decode
//! to exactly the host `Vec<f32>` tensors, and clean levels must be served
//! without re-upload.
//!
//! ISSUE 5 adds the deferred-commit replay property: for any random
//! accept/prune/miss sequence, a cache that applies its [`CacheCommit`]s
//! late — batched at arbitrary points between forwards, as the overlapped
//! sync phase does on pipeline workers — must end every "forward" with
//! host state *and* device-mirror state identical to a cache that applied
//! each commit eagerly at its sync point.
//!
//! Needs only a PJRT CPU client (no compiled artifacts); skipped when the
//! client cannot boot.

use std::collections::VecDeque;
use std::sync::Arc;

use pipedec::kvcache::device::DeviceKvCache;
use pipedec::kvcache::{CacheCommit, CommitOp, TwoLevelCache};
use pipedec::runtime::{to_vec_f32, Runtime};
use pipedec::util::XorShiftRng;

const LAYERS: usize = 2;
const HEADS: usize = 2;
const HD: usize = 2;
const PAST_CAP: usize = 6;
const TREE_CAP: usize = 5;
const W: usize = 3;

fn fetch(buf: &pipedec::runtime::DeviceBuffer) -> Vec<f32> {
    to_vec_f32(&buf.to_literal_sync().unwrap()).unwrap()
}

/// Sync the whole mirror (through [`DeviceKvCache::sync`]) and compare
/// all four tensors of every layer against the host cache.
fn assert_mirror_matches(rt: &Runtime, cache: &TwoLevelCache, dev: &mut DeviceKvCache) {
    dev.sync(rt, cache).unwrap();
    for l in 0..cache.layers() {
        let (pk, pv) = dev.past(l).unwrap();
        assert_eq!(fetch(pk), cache.past_k_layer(l), "past_k layer {l}");
        assert_eq!(fetch(pv), cache.past_v_layer(l), "past_v layer {l}");
        let (tk, tv) = dev.tree(l).unwrap();
        assert_eq!(fetch(tk), cache.tree_k_layer(l), "tree_k layer {l}");
        assert_eq!(fetch(tv), cache.tree_v_layer(l), "tree_v layer {l}");
    }
}

fn rand_block(rng: &mut XorShiftRng) -> Vec<f32> {
    (0..HEADS * W * HD).map(|_| rng.next_f32()).collect()
}

/// Random mutation driver: every reachable cache transition, mirror-checked
/// after each step.
fn drive(seed: u64, steps: usize) {
    let Ok(rt) = Runtime::cpu() else {
        eprintln!("skipping: no PJRT client");
        return;
    };
    let mut rng = XorShiftRng::new(seed);
    let mut cache = TwoLevelCache::new(LAYERS, HEADS, HD, PAST_CAP, TREE_CAP);
    let mut dev = DeviceKvCache::new(LAYERS);
    assert_mirror_matches(&rt, &cache, &mut dev);

    for step in 0..steps {
        match rng.below(6) {
            // append one tree block to every layer, then commit
            0 if cache.tree_len() < cache.tree_cap() => {
                let room = cache.tree_cap() - cache.tree_len();
                let count = 1 + rng.below(room.min(W));
                for l in 0..LAYERS {
                    let (k, v) = (rand_block(&mut rng), rand_block(&mut rng));
                    cache.append_tree_block(l, &k, &v, W, count).unwrap();
                }
                cache.commit_tree(count);
            }
            // prefill-style past append
            1 if cache.past_len() < cache.past_cap() => {
                let room = cache.past_cap() - cache.past_len();
                let count = 1 + rng.below(room.min(W));
                for l in 0..LAYERS {
                    let (k, v) = (rand_block(&mut rng), rand_block(&mut rng));
                    cache.append_past_block(l, &k, &v, W, count).unwrap();
                }
                cache.commit_past(count);
            }
            // sync-point promotion
            2 if cache.tree_len() >= 1 && cache.past_len() < cache.past_cap() => {
                cache.promote_root_to_past().unwrap();
            }
            // hit-path compaction: random ascending survivor subset
            3 if cache.tree_len() > 0 => {
                let kept: Vec<usize> =
                    (0..cache.tree_len()).filter(|_| rng.chance(0.5)).collect();
                cache.compact_tree(&kept);
            }
            // miss path: clear, then (often) immediately overwrite stale
            // slots — the mirror must pick up the overwrite
            4 => {
                cache.clear_tree();
                if rng.chance(0.7) {
                    for l in 0..LAYERS {
                        let (k, v) = (rand_block(&mut rng), rand_block(&mut rng));
                        cache.append_tree_block(l, &k, &v, W, 1).unwrap();
                    }
                    cache.commit_tree(1);
                }
            }
            // new request
            5 if step % 17 == 0 => cache.reset(),
            _ => continue,
        }
        assert_mirror_matches(&rt, &cache, &mut dev);
    }

    // the mirror must have served clean levels from device residency
    let (uploads, reuses) = dev.upload_counts();
    assert!(uploads > 0, "mirror never uploaded");
    assert!(
        reuses > 0,
        "mirror never reused a clean level across {steps} steps"
    );
}

#[cfg_attr(miri, ignore)] // PJRT FFI — covered by the host-only tests below under Miri
#[test]
fn mirror_matches_host_across_mutation_sequences() {
    for seed in [1u64, 7, 42] {
        drive(seed, 60);
    }
}

/// Field-wise host equality of two caches (lengths + every live slot of
/// both levels + the commit cursor).
fn assert_caches_equal(a: &TwoLevelCache, b: &TwoLevelCache, what: &str) {
    assert_eq!(a.past_len(), b.past_len(), "{what}: past_len");
    assert_eq!(a.tree_len(), b.tree_len(), "{what}: tree_len");
    assert_eq!(a.commit_epoch(), b.commit_epoch(), "{what}: commit_epoch");
    for l in 0..LAYERS {
        for h in 0..HEADS {
            for s in 0..a.past_len() {
                assert_eq!(
                    a.read_past_slot(l, h, s),
                    b.read_past_slot(l, h, s),
                    "{what}: past l{l} h{h} s{s}"
                );
            }
            for s in 0..a.tree_len() {
                assert_eq!(
                    a.read_tree_slot(l, h, s),
                    b.read_tree_slot(l, h, s),
                    "{what}: tree l{l} h{h} s{s}"
                );
            }
        }
    }
}

/// ISSUE 5 replay property: drive an eager cache and a deferred cache
/// through the same random accept/prune/miss sequence. The eager cache
/// applies every commit at its sync point (the serial reference path);
/// the deferred cache queues commits and drains them only at "forward"
/// boundaries (and random batch points with nothing in between) — the
/// worker-side protocol. Host state, commit cursor, and device mirror
/// must be indistinguishable whenever both caches are drained.
fn drive_commit_replay(seed: u64, steps: usize) {
    let Ok(rt) = Runtime::cpu() else {
        eprintln!("skipping: no PJRT client");
        return;
    };
    let mut rng = XorShiftRng::new(seed);
    let mut eager = TwoLevelCache::new(LAYERS, HEADS, HD, PAST_CAP, TREE_CAP);
    let mut lazy = TwoLevelCache::new(LAYERS, HEADS, HD, PAST_CAP, TREE_CAP);
    let mut eager_dev = DeviceKvCache::new(LAYERS);
    let mut lazy_dev = DeviceKvCache::new(LAYERS);
    let mut queue: VecDeque<CacheCommit> = VecDeque::new();
    let mut epoch = 0u64;

    fn drain(lazy: &mut TwoLevelCache, queue: &mut VecDeque<CacheCommit>) {
        while let Some(c) = queue.pop_front() {
            lazy.apply_commit(&c).unwrap();
        }
    }

    for _ in 0..steps {
        match rng.below(4) {
            // "forward pass": both caches append the same tree block —
            // the deferred cache must drain its queue first, exactly as
            // a worker job drains its commits before running
            0 if eager.tree_len() < eager.tree_cap() => {
                drain(&mut lazy, &mut queue);
                assert_caches_equal(&eager, &lazy, "pre-forward");
                let room = eager.tree_cap() - eager.tree_len();
                let count = 1 + rng.below(room.min(W));
                for l in 0..LAYERS {
                    let (k, v) = (rand_block(&mut rng), rand_block(&mut rng));
                    eager.append_tree_block(l, &k, &v, W, count).unwrap();
                    lazy.append_tree_block(l, &k, &v, W, count).unwrap();
                }
                eager.commit_tree(count);
                lazy.commit_tree(count);
            }
            // sync point, hit: random ascending survivor subset (kept[0]
            // is the new root; indices past the processed prefix are
            // legal and ignored by compact_tree)
            1 if eager.tree_len() >= 2 && eager.past_len() + 1 < eager.past_cap() => {
                let kept: Vec<usize> = (1..eager.tree_len() + 2)
                    .filter(|_| rng.chance(0.6))
                    .collect();
                epoch += 1;
                let c = CacheCommit {
                    epoch,
                    op: CommitOp::Hit {
                        kept_old: Arc::new(kept),
                    },
                };
                eager.apply_commit(&c).unwrap();
                queue.push_back(c);
            }
            // sync point, miss
            2 if eager.tree_len() >= 1 && eager.past_len() + 1 < eager.past_cap() => {
                epoch += 1;
                let c = CacheCommit {
                    epoch,
                    op: CommitOp::Miss,
                };
                eager.apply_commit(&c).unwrap();
                queue.push_back(c);
            }
            // arbitrary batch boundary with no forward in between — the
            // deferred side may also catch up here (a worker whose slot
            // got a flow but whose rows were all pruned in flight)
            3 if rng.chance(0.4) => {
                drain(&mut lazy, &mut queue);
                assert_caches_equal(&eager, &lazy, "batch-drain");
            }
            _ => continue,
        }
        // the mirrors track their own cache; the lazy mirror must stay
        // valid even while host commits are still queued
        assert_mirror_matches(&rt, &eager, &mut eager_dev);
        assert_mirror_matches(&rt, &lazy, &mut lazy_dev);
    }
    drain(&mut lazy, &mut queue);
    assert_caches_equal(&eager, &lazy, "final");
    assert_mirror_matches(&rt, &eager, &mut eager_dev);
    assert_mirror_matches(&rt, &lazy, &mut lazy_dev);
    assert_eq!(eager.commit_epoch(), epoch);
}

#[cfg_attr(miri, ignore)] // PJRT FFI
#[test]
fn deferred_commit_replay_matches_eager_sync() {
    for seed in [2u64, 11, 77, 1234] {
        drive_commit_replay(seed, 80);
    }
}

#[test]
fn commit_epochs_reject_out_of_order_replay() {
    let mut c = TwoLevelCache::new(LAYERS, HEADS, HD, PAST_CAP, TREE_CAP);
    let mut rng = XorShiftRng::new(5);
    for l in 0..LAYERS {
        let (k, v) = (rand_block(&mut rng), rand_block(&mut rng));
        c.append_tree_block(l, &k, &v, W, 2).unwrap();
    }
    c.commit_tree(2);
    let miss = |epoch| CacheCommit {
        epoch,
        op: CommitOp::Miss,
    };
    assert!(c.apply_commit(&miss(2)).is_err(), "skipping epoch 1 rejected");
    c.apply_commit(&miss(1)).unwrap();
    assert!(c.apply_commit(&miss(1)).is_err(), "replaying epoch 1 rejected");
    assert_eq!(c.commit_epoch(), 1);
}

/// ISSUE 6 loom variant of the epoch-order property: two independent
/// cache owners each work through a 3-step drain (append a tree block,
/// commit it, apply that step's `Miss` commit) while a model-checker
/// schedule from [`interleavings`] interleaves their steps every possible
/// way — exactly the shape of two pipeline workers draining their commit
/// suffixes concurrently. Every schedule must succeed, every schedule
/// must produce the bit-identical final state on both owners (owner
/// drains are independent, so interleaving cannot matter), and the
/// duplicate/skip rejections must hold at the end of every schedule.
/// Host-only — this test also runs under the Miri lane.
#[test]
fn interleaved_owner_drains_commute_under_all_schedules() {
    use pipedec::concurrency::explore::interleavings;

    const STEPS: usize = 3;
    let schedules = interleavings(&[STEPS, STEPS]);
    assert_eq!(schedules.len(), 20, "C(6,3) interleavings of two owners");

    let run = |schedule: &[usize]| -> Vec<TwoLevelCache> {
        let mut caches = vec![
            TwoLevelCache::new(LAYERS, HEADS, HD, PAST_CAP, TREE_CAP),
            TwoLevelCache::new(LAYERS, HEADS, HD, PAST_CAP, TREE_CAP),
        ];
        // per-owner deterministic data: the blocks an owner appends depend
        // only on its own step count, never on the schedule
        let mut rngs = [XorShiftRng::new(21), XorShiftRng::new(22)];
        let mut next_epoch = [1u64, 1];
        for &owner in schedule {
            let cache = &mut caches[owner];
            let rng = &mut rngs[owner];
            for l in 0..LAYERS {
                let (k, v) = (rand_block(rng), rand_block(rng));
                cache.append_tree_block(l, &k, &v, W, 1).unwrap();
            }
            cache.commit_tree(1);
            let c = CacheCommit {
                epoch: next_epoch[owner],
                op: CommitOp::Miss,
            };
            cache.apply_commit(&c).unwrap();
            next_epoch[owner] += 1;
        }
        for cache in &mut caches {
            assert_eq!(cache.commit_epoch(), STEPS as u64);
            let miss = |epoch| CacheCommit {
                epoch,
                op: CommitOp::Miss,
            };
            assert!(
                cache.apply_commit(&miss(STEPS as u64)).is_err(),
                "duplicate replay rejected"
            );
            assert!(
                cache.apply_commit(&miss(STEPS as u64 + 2)).is_err(),
                "skipped epoch rejected"
            );
            // rejected commits must leave the cursor untouched
            assert_eq!(cache.commit_epoch(), STEPS as u64);
        }
        caches
    };

    let reference = run(&schedules[0]);
    for schedule in &schedules[1..] {
        let got = run(schedule);
        for (owner, (a, b)) in reference.iter().zip(&got).enumerate() {
            assert_caches_equal(a, b, &format!("owner {owner} under {schedule:?}"));
        }
    }
}

#[cfg_attr(miri, ignore)] // PJRT FFI
#[test]
fn clean_resync_is_upload_free() {
    let Ok(rt) = Runtime::cpu() else {
        eprintln!("skipping: no PJRT client");
        return;
    };
    let mut rng = XorShiftRng::new(3);
    let mut cache = TwoLevelCache::new(LAYERS, HEADS, HD, PAST_CAP, TREE_CAP);
    for l in 0..LAYERS {
        let (k, v) = (rand_block(&mut rng), rand_block(&mut rng));
        cache.append_tree_block(l, &k, &v, W, 2).unwrap();
    }
    cache.commit_tree(2);
    let mut dev = DeviceKvCache::new(LAYERS);
    assert_mirror_matches(&rt, &cache, &mut dev);
    let (uploads_after_first, _) = dev.upload_counts();
    let before = rt.stats().snapshot();
    // no mutations in between: the second sync moves zero bytes
    assert_mirror_matches(&rt, &cache, &mut dev);
    let d = rt.stats().snapshot().delta_since(&before);
    assert_eq!(d.up, 0, "clean resync must not upload");
    assert!(d.saved_kv > 0, "clean resync must credit KV saved bytes");
    assert_eq!(d.saved, d.saved_kv, "only the KV mirror ran here");
    assert_eq!(dev.upload_counts().0, uploads_after_first);
}
