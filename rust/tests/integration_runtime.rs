//! Cross-language numerics: replay the golden greedy continuations (written
//! by `python/compile/aot.py` with the python training-path forward) through
//! the Rust runtime + AOT artifacts. Proves prefill, the dynamic-tree
//! attention artifact, KV promotion, and the head all compose to the same
//! argmax sequence as the reference model.

use pipedec::kvcache::TwoLevelCache;
use pipedec::model::{bias, ModelHandles};
use pipedec::runtime::Runtime;
use pipedec::util::top_k_indices;

fn artifacts() -> Option<std::path::PathBuf> {
    let dir = pipedec::artifacts_dir();
    dir.join("target_config.txt").exists().then_some(dir)
}

fn load_golden(dir: &std::path::Path, name: &str) -> (Vec<u32>, Vec<u32>) {
    let text = std::fs::read_to_string(dir.join(format!("golden_{name}.txt"))).unwrap();
    let mut lines = text.lines();
    let parse = |l: &str| -> Vec<u32> {
        l.split_whitespace().map(|t| t.parse().unwrap()).collect()
    };
    (parse(lines.next().unwrap()), parse(lines.next().unwrap()))
}

/// Greedy autoregressive decode through the artifacts: each new token is a
/// width-1 tree block that is immediately promoted to the model level — the
/// degenerate (width=1, always-hit) PipeDec configuration.
fn greedy_decode(model_name: &str, steps: usize) -> (Vec<u32>, Vec<u32>) {
    let dir = artifacts().unwrap();
    let rt = Runtime::cpu().unwrap();
    let mut m = ModelHandles::load(&rt, &dir, model_name).unwrap();
    let c = m.cfg.clone();
    let mut cache =
        TwoLevelCache::new(c.n_layers, c.n_heads, c.head_dim, c.past_cap, c.tree_cap);

    let (prompt, expected) = load_golden(&dir, model_name);
    let logits = m.full_prefill(&rt, &mut cache, &prompt).unwrap();
    let mut next = top_k_indices(&logits, 1)[0] as u32;

    let mut produced = vec![next];
    while produced.len() < steps {
        let pos = cache.past_len() as i32;
        let mut posv = vec![0i32; c.width_cap];
        posv[0] = pos;
        // width-1 block: self-only tree bias at slot 0
        let tree_bias =
            bias::pad_tree_bias_rows(vec![0.0; 0], 0, 0, c.width_cap, c.tree_cap);
        let logits = m
            .full_forward_tree_block(&rt, &mut cache, &[next], &posv, &tree_bias)
            .unwrap();
        next = top_k_indices(&logits[..c.vocab_size], 1)[0] as u32;
        produced.push(next);
        cache.promote_root_to_past().unwrap();
        // tree level now holds only the promoted slot; drop it
        cache.compact_tree(&[]);
    }
    (produced, expected)
}

#[test]
fn target_greedy_matches_python_reference() {
    if artifacts().is_none() {
        eprintln!("skipping: no artifacts");
        return;
    }
    let (produced, expected) = greedy_decode("target", 12);
    assert_eq!(produced, expected, "target artifact decode diverged");
}

#[test]
fn draft_greedy_matches_python_reference() {
    if artifacts().is_none() {
        eprintln!("skipping: no artifacts");
        return;
    }
    let (produced, expected) = greedy_decode("draft", 12);
    assert_eq!(produced, expected, "draft artifact decode diverged");
}

#[test]
fn decoded_text_is_printable_corpus_style() {
    if artifacts().is_none() {
        eprintln!("skipping: no artifacts");
        return;
    }
    let (produced, _) = greedy_decode("target", 12);
    let text = pipedec::tokenizer::decode(&produced);
    assert!(!text.is_empty());
    assert!(text.chars().all(|c| c.is_ascii()));
}
