//! Chaos suite (ISSUE 9): deterministic fault-injection runs over the
//! SpecPipe-DB scheduler asserting the fault-isolation contract:
//!
//! * no injected fault escapes the engine as a panic,
//! * `step()` never returns an error for a session-scoped fault,
//! * the engine always reaches idle (no deadlock, bounded steps),
//! * every failed session reports a non-empty reason,
//! * surviving sessions produce greedy outputs bit-identical to a
//!   fault-free run,
//! * no device KV mirror or prefix pin leaks past retirement,
//! * deadline and shedding outcomes are observable end-to-end through
//!   `server::Completion::status` and the `summarize` counters.
//!
//! Gating tests run fixed plans at `threads = 1` (inline execution makes
//! fault hit counts deterministic). The `#[ignore]`d randomized test is
//! the nightly lane: it derives a `FaultPlan` from `PIPEDEC_CHAOS_SEED`
//! and prints the serialized plan up front so a failing run can be
//! replayed exactly via `PIPEDEC_FAULTS`.
//!
//! Every test takes the install guard for its whole body: the armed plan
//! is process-global, so tests must never overlap an armed window.

use pipedec::config::{EngineConfig, TreeConfig};
use pipedec::coordinator::PipeDecDbEngine;
use pipedec::engine::{
    DecodeRequest, NullSink, ScheduledEngine, SessionId, SessionStatus,
};
use pipedec::server::{serve_until_idle, summarize, CompletionStatus, Router};
use pipedec::util::XorShiftRng;
use pipedec::faultinject::{self, FaultPlan};

fn artifacts() -> Option<std::path::PathBuf> {
    let dir = pipedec::artifacts_dir();
    dir.join("target_config.txt").exists().then_some(dir)
}

/// Hold the process-global fault-injection lock, disarmed. Tests arm
/// their plans inside the guarded scope; the guard disarms on drop.
fn fault_quiesce() -> faultinject::FaultGuard {
    let guard = faultinject::install(FaultPlan::default());
    faultinject::disarm();
    guard
}

fn cfg(threads: usize) -> EngineConfig {
    EngineConfig {
        stages: 2,
        tree: TreeConfig {
            max_width: 4,
            max_children: 4,
            max_depth: 8,
        },
        max_new_tokens: 8,
        threads,
        ..EngineConfig::default()
    }
}

const PROMPTS: [&str; 3] = [
    "<math>\nquestion: alice has 4 apples and buys 3 more. how many apples now?\n",
    "<math>\nquestion: bob has 3 coins and finds 2 more. how many coins now?\n",
    "<math>\nquestion: carol packs 5 boxes with 6 coins each. total coins?\n",
];

/// Fault-free reference run: per-prompt greedy outputs and the engine's
/// post-idle mirror occupancy (the leak baseline). Must be called with
/// the layer disarmed.
fn baseline(dir: &std::path::Path, c: &EngineConfig) -> (Vec<Vec<u32>>, Vec<usize>) {
    assert!(!faultinject::enabled(), "baseline must run fault-free");
    let mut eng = PipeDecDbEngine::new(dir, c.clone()).unwrap();
    let mut ids: Vec<SessionId> = Vec::new();
    drive(&mut eng, &mut XorShiftRng::new(7), &mut ids);
    let outs = ids
        .iter()
        .map(|id| eng.poll(*id).expect("baseline session finishes").tokens)
        .collect();
    (outs, eng.mirror_counts())
}

/// Drive one engine through a random submit/step interleaving until it
/// goes idle and all of `to_submit` has been submitted (ids appended to
/// `ids`). Panics if the engine wedges or a step returns an error.
fn drive(eng: &mut PipeDecDbEngine, rng: &mut XorShiftRng, ids: &mut Vec<SessionId>) {
    let mut next = ids.len();
    let mut budget = 20_000u32;
    while next < PROMPTS.len() || eng.has_work() {
        budget -= 1;
        assert!(budget > 0, "engine wedged: step budget exhausted");
        if next < PROMPTS.len() && rng.below(2) == 0 {
            ids.push(
                eng.submit(DecodeRequest::new(PROMPTS[next]), Box::new(NullSink))
                    .unwrap(),
            );
            next += 1;
        } else if eng.has_work() {
            eng.step()
                .expect("step must never error on a session-scoped fault");
        }
    }
}

/// One chaos run: arm `plan`, run a random schedule, then check the
/// whole fault-isolation contract against the fault-free baseline.
fn chaos_run(
    dir: &std::path::Path,
    c: &EngineConfig,
    plan: FaultPlan,
    seed: u64,
    expected: &[Vec<u32>],
    mirror_base: &[usize],
) -> usize {
    faultinject::arm(plan);
    let mut eng = PipeDecDbEngine::new(dir, c.clone()).unwrap();
    let mut ids = Vec::new();
    drive(&mut eng, &mut XorShiftRng::new(seed), &mut ids);
    faultinject::disarm();

    let mut failed = 0usize;
    for (i, id) in ids.iter().enumerate() {
        match eng.status(*id) {
            Some(SessionStatus::Failed { reason }) => {
                failed += 1;
                assert!(!reason.is_empty(), "{id}: failure must carry a reason");
                assert!(
                    eng.poll(*id).is_some(),
                    "{id}: failed session must still yield its partial output"
                );
            }
            Some(SessionStatus::Finished) => {
                let out = eng.poll(*id).expect("finished session is pollable");
                if c.threads <= 1 {
                    assert_eq!(
                        out.tokens, expected[i],
                        "{id}: surviving session diverged from the fault-free run"
                    );
                }
            }
            s => panic!("{id}: session not terminal after idle: {s:?}"),
        }
    }
    assert_eq!(
        eng.mirror_counts(),
        mirror_base,
        "device KV mirrors leaked past retirement"
    );
    assert_eq!(
        eng.pinned_prefix_sessions(),
        0,
        "prefix pins leaked past retirement"
    );
    failed
}

/// Gating lane: fixed plans over fixed seeds at `threads = 1`.
#[test]
fn chaos_fixed_plans_isolate_faults_and_leak_nothing() {
    let Some(dir) = artifacts() else {
        eprintln!("skipping: no artifacts");
        return;
    };
    let _guard = fault_quiesce();
    let c = cfg(1);
    let (expected, mirror_base) = baseline(&dir, &c);

    // worker-scoped errors and panics must fail exactly one session;
    // fail-soft device/spill faults must fail none
    let plans: &[(&str, bool)] = &[
        ("stage_job@2=error", true),
        ("stage_job@5=panic", true),
        ("draft_job@3=error", true),
        ("draft_job@2=panic", true),
        ("apply_commit@2=error", true),
        ("device_op@1=error", false),
        ("spill_write@1=error", false),
        ("stage_job@1=delay:2,draft_job@2=error", true),
    ];
    for (i, (text, faults_a_session)) in plans.iter().enumerate() {
        let plan: FaultPlan = text.parse().unwrap();
        let failed = chaos_run(&dir, &c, plan, 100 + i as u64, &expected, &mirror_base);
        if *faults_a_session {
            // a lost draft job fails every session with an in-flight
            // candidate, so >= 1 (not == 1) is the portable bound
            assert!(failed >= 1, "plan {text:?} was expected to fail a session");
        } else {
            assert_eq!(failed, 0, "fail-soft plan {text:?} must not fail sessions");
        }
    }
}

/// Pooled lane: worker panics and worker-thread exits at `threads >= 2`
/// must respawn without deadlocking the coordinator. Outputs are not
/// compared (hit attribution is nondeterministic across workers); the
/// invariants are liveness, terminal statuses, and leak-freedom.
#[test]
fn chaos_pooled_worker_faults_recover_without_deadlock() {
    let Some(dir) = artifacts() else {
        eprintln!("skipping: no artifacts");
        return;
    };
    let _guard = fault_quiesce();
    let c = cfg(3);
    let (expected, mirror_base) = baseline(&dir, &c);
    for (i, text) in [
        "stage_job@2=panic",
        "worker_exit@1=error",
        "stage_job@1=panic,stage_job@3=panic",
        "draft_job@2=panic,worker_exit@2=error",
    ]
    .iter()
    .enumerate()
    {
        let plan: FaultPlan = text.parse().unwrap();
        let failed = chaos_run(&dir, &c, plan, 200 + i as u64, &expected, &mirror_base);
        assert!(
            failed <= PROMPTS.len(),
            "plan {text:?}: more failures than sessions"
        );
    }
}

/// Deadlines are observable end-to-end: with an (unmeetable) TTFT
/// deadline every request is retired before admission and surfaces as
/// `DeadlineExceeded` through the serving loop and summarize counters.
#[test]
fn chaos_deadline_outcomes_are_observable_end_to_end() {
    let Some(dir) = artifacts() else {
        eprintln!("skipping: no artifacts");
        return;
    };
    let _guard = fault_quiesce();
    let mut c = cfg(1);
    c.limits.ttft_deadline_s = 1e-9;
    let mut eng = PipeDecDbEngine::new(&dir, c).unwrap();
    let mut router = Router::new(8);
    for p in PROMPTS {
        router.submit_prompt(p).unwrap();
    }
    let done = serve_until_idle(&mut router, &mut eng).unwrap();
    assert_eq!(done.len(), PROMPTS.len());
    for cpl in &done {
        assert_eq!(
            cpl.status,
            CompletionStatus::DeadlineExceeded,
            "request {} should have missed its TTFT deadline",
            cpl.id
        );
        assert_eq!(cpl.tokens, 0, "no tokens before the first-token deadline");
    }
    let (m, _) = summarize(&done, 1.0);
    assert_eq!(m.counter("deadline_exceeded"), PROMPTS.len() as u64);
    assert_eq!(m.counter("completed_ok"), 0);
}

/// Admission-queue shedding is observable end-to-end: with `queue_cap`
/// = 1 the serving loop's bulk admission sheds the overflow as typed
/// `Shed` completions while the admitted request completes normally.
#[test]
fn chaos_shed_outcomes_are_observable_end_to_end() {
    let Some(dir) = artifacts() else {
        eprintln!("skipping: no artifacts");
        return;
    };
    let _guard = fault_quiesce();
    let mut c = cfg(1);
    c.limits.queue_cap = 1;
    let mut eng = PipeDecDbEngine::new(&dir, c).unwrap();
    let mut router = Router::new(8);
    for p in PROMPTS {
        router.submit_prompt(p).unwrap();
    }
    let done = serve_until_idle(&mut router, &mut eng).unwrap();
    assert_eq!(done.len(), PROMPTS.len());
    let (m, _) = summarize(&done, 1.0);
    assert_eq!(m.counter("completed_ok"), 1, "the admitted request completes");
    assert_eq!(m.counter("shed"), 2, "overflow submits are shed, not errors");
    let ok = done
        .iter()
        .find(|cpl| cpl.status.is_ok())
        .expect("one request served");
    assert!(ok.tokens > 0);
}

/// The serving loop never aborts under injected faults: failed sessions
/// surface as `Failed { reason }` completions and the rest serve Ok.
#[test]
fn chaos_serve_until_idle_never_aborts_under_faults() {
    let Some(dir) = artifacts() else {
        eprintln!("skipping: no artifacts");
        return;
    };
    let _guard = fault_quiesce();
    faultinject::arm("stage_job@4=error".parse().unwrap());
    let mut eng = PipeDecDbEngine::new(&dir, cfg(1)).unwrap();
    let mut router = Router::new(8);
    for p in PROMPTS {
        router.submit_prompt(p).unwrap();
    }
    let done = serve_until_idle(&mut router, &mut eng).unwrap();
    faultinject::disarm();
    assert_eq!(done.len(), PROMPTS.len());
    let (m, _) = summarize(&done, 1.0);
    assert_eq!(m.counter("failed"), 1, "exactly one session absorbs the fault");
    assert_eq!(m.counter("completed_ok"), PROMPTS.len() as u64 - 1);
    for cpl in &done {
        if let CompletionStatus::Failed { reason } = &cpl.status {
            assert!(!reason.is_empty(), "failure reason must survive to the server");
        }
    }
}

/// Nightly lane: a randomized plan derived from `PIPEDEC_CHAOS_SEED`
/// (default 1). The plan is printed first so a failing run's exact
/// schedule can be pinned and replayed via `PIPEDEC_FAULTS=<plan>`.
#[test]
#[ignore = "nightly chaos lane: run with --ignored, seed via PIPEDEC_CHAOS_SEED"]
fn chaos_randomized_plan_from_env_seed() {
    let Some(dir) = artifacts() else {
        eprintln!("skipping: no artifacts");
        return;
    };
    let seed: u64 = std::env::var("PIPEDEC_CHAOS_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(1);
    let plan = FaultPlan::random(seed);
    eprintln!(
        "chaos seed {seed}: plan \"{plan}\" — replay with PIPEDEC_FAULTS=\"{plan}\""
    );
    let _guard = fault_quiesce();
    let c = cfg(1);
    let (expected, mirror_base) = baseline(&dir, &c);
    for round in 0..8u64 {
        let failed = chaos_run(
            &dir,
            &c,
            plan.clone(),
            seed.wrapping_mul(31).wrapping_add(round),
            &expected,
            &mirror_base,
        );
        assert!(failed <= PROMPTS.len());
    }
}

/// ISSUE 10 satellite: a panic (or error) inside the *free-running*
/// speculation loop — the `draft_stale` site fires once per extra
/// generation in `draft_speculate` — must retire only the owning session,
/// leave survivors bit-identical, and leak no in-flight generation or
/// device state. The partial speculation is discarded with the job; it
/// must never be banked.
#[test]
fn chaos_speculation_panic_retires_owner_and_leaks_no_generation() {
    let Some(dir) = artifacts() else {
        eprintln!("skipping: no artifacts");
        return;
    };
    let _guard = fault_quiesce();
    let mut c = cfg(1);
    c.spec_inflight = 3;
    let (expected, mirror_base) = baseline(&dir, &c);
    for (i, text) in ["draft_stale@1=panic", "draft_stale@2=error"].iter().enumerate() {
        faultinject::arm(text.parse().unwrap());
        let mut eng = PipeDecDbEngine::new(&dir, c.clone()).unwrap();
        let mut ids = Vec::new();
        drive(&mut eng, &mut XorShiftRng::new(300 + i as u64), &mut ids);
        faultinject::disarm();
        let mut failed = 0usize;
        for (j, id) in ids.iter().enumerate() {
            match eng.status(*id) {
                Some(SessionStatus::Failed { reason }) => {
                    failed += 1;
                    assert!(!reason.is_empty(), "{id}: failure must carry a reason");
                    assert!(
                        eng.poll(*id).is_some(),
                        "{id}: failed session must still yield its partial output"
                    );
                }
                Some(SessionStatus::Finished) => {
                    let out = eng.poll(*id).expect("finished session is pollable");
                    assert_eq!(
                        out.tokens, expected[j],
                        "{id}: survivor diverged from the fault-free run"
                    );
                }
                s => panic!("{id}: session not terminal after idle: {s:?}"),
            }
        }
        assert_eq!(
            failed, 1,
            "plan {text:?}: a speculation fault must fail exactly the owning session"
        );
        assert_eq!(
            eng.inflight_generations(),
            0,
            "plan {text:?}: an in-flight speculative generation leaked past retirement"
        );
        assert_eq!(
            eng.mirror_counts(),
            mirror_base,
            "plan {text:?}: device KV mirrors leaked past retirement"
        );
        assert_eq!(
            eng.pinned_prefix_sessions(),
            0,
            "plan {text:?}: prefix pins leaked past retirement"
        );
    }
}
