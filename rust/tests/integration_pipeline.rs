//! End-to-end PipeDec engine tests over real artifacts.
//!
//! The central property is the paper's losslessness claim: speculative
//! pipeline decoding with the dynamic tree produces *exactly* the sequence
//! that plain greedy decoding of the target model produces, at any pipeline
//! depth and tree configuration — speed changes, output does not.

use pipedec::config::{EngineConfig, TreeConfig};
use pipedec::coordinator::PipeDecEngine;
use pipedec::engine::Engine;

fn artifacts() -> Option<std::path::PathBuf> {
    let dir = pipedec::artifacts_dir();
    dir.join("target_config.txt").exists().then_some(dir)
}

fn engine(stages: usize, width: usize, children: usize) -> PipeDecEngine {
    let cfg = EngineConfig {
        stages,
        tree: TreeConfig { max_width: width, max_children: children, max_depth: 16 },
        max_new_tokens: 32,
        ..EngineConfig::default()
    };
    PipeDecEngine::new(&artifacts().unwrap(), cfg).unwrap()
}

const PROMPT: &str = "<math>\nquestion: alice has 4 apples and buys 3 more. how many apples now?\n";

/// Golden greedy continuation from python (written by aot.py).
fn golden_target() -> Vec<u32> {
    let text =
        std::fs::read_to_string(artifacts().unwrap().join("golden_target.txt")).unwrap();
    text.lines()
        .nth(1)
        .unwrap()
        .split_whitespace()
        .map(|t| t.parse().unwrap())
        .collect()
}

#[test]
fn pipedec_is_lossless_vs_golden() {
    if artifacts().is_none() { eprintln!("skipping: no artifacts"); return; }
    let mut e = engine(4, 8, 8);
    let r = e.decode_prompt(PROMPT).unwrap();
    let golden = golden_target();
    assert!(r.tokens.len() >= golden.len());
    assert_eq!(&r.tokens[..golden.len()], &golden[..],
        "PipeDec output diverged from plain greedy decoding");
}

#[test]
fn losslessness_holds_across_depths_and_trees() {
    if artifacts().is_none() { eprintln!("skipping: no artifacts"); return; }
    let golden = golden_target();
    for (stages, w, c) in [(1, 4, 4), (2, 8, 4), (8, 8, 8)] {
        let mut e = engine(stages, w, c);
        let r = e.decode_prompt(PROMPT).unwrap();
        assert_eq!(&r.tokens[..golden.len()], &golden[..],
            "diverged at stages={stages} w={w} c={c}");
    }
}

#[test]
fn speculation_actually_hits() {
    if artifacts().is_none() { eprintln!("skipping: no artifacts"); return; }
    let mut e = engine(4, 8, 8);
    let r = e.decode_prompt(PROMPT).unwrap();
    assert!(r.hits() > 0, "no speculative hits at all");
    assert!(r.accept_rate() > 0.5,
        "accept rate {:.2} too low for a co-trained draft", r.accept_rate());
    // steady-state pipelining: fewer timesteps than tokens * stages
    assert!(r.timesteps() < (r.tokens.len() * e.stages()) as u64,
        "no pipelining benefit: {} timesteps for {} tokens", r.timesteps(), r.tokens.len());
}

#[test]
fn stochastic_decoding_runs_and_terminates() {
    if artifacts().is_none() { eprintln!("skipping: no artifacts"); return; }
    let cfg = EngineConfig {
        stages: 2,
        tree: TreeConfig { max_width: 8, max_children: 8, max_depth: 16 },
        max_new_tokens: 24,
        temperature: 0.6,
        top_p: 0.9,
        top_k: 80,
        seed: 7,
        ..EngineConfig::default()
    };
    let mut e = PipeDecEngine::new(&artifacts().unwrap(), cfg).unwrap();
    let r = e.decode_prompt(PROMPT).unwrap();
    assert!(!r.tokens.is_empty());
    assert!(r.tokens.iter().all(|&t| (t as usize) < 128));
    // determinism under a fixed seed
    let r2 = e.decode_prompt(PROMPT).unwrap();
    assert_eq!(r.tokens, r2.tokens);
}

#[test]
fn metrics_are_recorded() {
    if artifacts().is_none() { eprintln!("skipping: no artifacts"); return; }
    let mut e = engine(2, 4, 4);
    let r = e.decode_prompt(PROMPT).unwrap();
    assert!(r.modeled_s > 0.0);
    assert!(r.wall_s > 0.0);
    assert_eq!(r.metrics.counter("tokens"), r.tokens.len() as u64);
    assert!(e.link_stats.transfers > 0);
}

#[test]
fn grouped_pipeline_is_lossless_and_faster_per_timestep() {
    // paper §3.1: G_i = {2i-1, 2i} — the 7-stage config over 14 GPUs,
    // here 4 groups over 8 stages
    if artifacts().is_none() { eprintln!("skipping: no artifacts"); return; }
    let golden = golden_target();
    let cfg = EngineConfig {
        stages: 8,
        group_size: 2,
        tree: TreeConfig { max_width: 8, max_children: 8, max_depth: 16 },
        max_new_tokens: 32,
        ..EngineConfig::default()
    };
    let mut e = PipeDecEngine::new(&artifacts().unwrap(), cfg).unwrap();
    assert_eq!(e.groups(), 4);
    let r = e.decode_prompt(PROMPT).unwrap();
    assert_eq!(&r.tokens[..golden.len()], &golden[..],
        "grouped pipeline diverged");
    // groups halve the pipeline depth: fewer timesteps than 1-stage groups
    let mut e1 = engine(8, 8, 8);
    let r1 = e1.decode_prompt(PROMPT).unwrap();
    assert!(r.timesteps() <= r1.timesteps(),
        "grouping should not increase timesteps ({} vs {})", r.timesteps(), r1.timesteps());
}

#[test]
fn ablation_tree_reuse_off_is_lossless_but_slower() {
    // DESIGN.md ablation: disabling dynamic-tree reuse (every sync restarts
    // the pipeline) must not change the output, only the timestep count —
    // this isolates the dynamic prediction tree's contribution.
    if artifacts().is_none() { eprintln!("skipping: no artifacts"); return; }
    let golden = golden_target();
    let mut normal = engine(4, 8, 8);
    let r_norm = normal.decode_prompt(PROMPT).unwrap();
    let cfg = EngineConfig {
        stages: 4,
        tree: TreeConfig { max_width: 8, max_children: 8, max_depth: 16 },
        max_new_tokens: 32,
        ablate_tree_reuse: true,
        ..EngineConfig::default()
    };
    let mut ablated = PipeDecEngine::new(&artifacts().unwrap(), cfg).unwrap();
    let r_abl = ablated.decode_prompt(PROMPT).unwrap();
    assert_eq!(&r_abl.tokens[..golden.len()], &golden[..], "ablation broke losslessness");
    assert_eq!(r_abl.hits(), 0);
    assert!(r_abl.timesteps() > r_norm.timesteps() * 2,
        "reuse should cut timesteps substantially ({} vs {})",
        r_abl.timesteps(), r_norm.timesteps());
}
