//! Paper-scale simulator: reproduce the headline numbers' *shape* (who
//! wins, by what factor, where crossovers fall) per EXPERIMENTS.md.

use pipedec::sim::{simulate_pipedec, simulate_pp, simulate_slm, simulate_stpp,
    throughput_tokens_per_s, ClusterSpec, HitModel};
use pipedec::util::XorShiftRng;
use pipedec::workload::DOMAINS;

#[test]
fn fig5_shape_holds_for_every_domain() {
    let cluster = ClusterSpec::paper(14);
    for (dom, _) in DOMAINS {
        let hit = HitModel::default_for(dom);
        let mut rng = XorShiftRng::new(1);
        let pd = simulate_pipedec(&cluster, 32, 16, &hit, 512, &mut rng);
        let pp = simulate_pp(&cluster, 512);
        let st = simulate_stpp(&cluster, 16, 4, 4, &hit, 512, &mut rng);
        let vs_pp = pp.s_per_token() / pd.s_per_token();
        let vs_st = st.s_per_token() / pd.s_per_token();
        assert!(vs_pp > 2.5, "{dom}: vs PP only {vs_pp:.2}x");
        assert!(vs_st > 1.3, "{dom}: vs STPP only {vs_st:.2}x");
    }
}

#[test]
fn depth_ordering_7_14_21() {
    let hit = HitModel::default_for("math");
    let mut rng = XorShiftRng::new(2);
    let t: Vec<f64> = [7usize, 14, 21].iter().map(|&n| {
        simulate_pipedec(&ClusterSpec::paper(n), 32, 16, &hit, 512, &mut rng)
            .s_per_token()
    }).collect();
    assert!(t[1] < t[0], "14 should beat 7");
    // gains plateau: 14->21 improvement smaller than 7->14
    let g1 = t[0] / t[1];
    let g2 = t[1] / t[2].max(1e-9);
    assert!(g2 < g1, "plateau expected: g1={g1:.2} g2={g2:.2}");
}

#[test]
fn accuracy_improves_with_tree_width() {
    let hit = HitModel::default_for("qa");
    let cluster = ClusterSpec::paper(14);
    let acc = |w: usize| {
        let mut rng = XorShiftRng::new(3);
        simulate_pipedec(&cluster, w, 16, &hit, 2048, &mut rng).accuracy()
    };
    assert!(acc(32) > acc(8));
    assert!(acc(128) >= acc(32) - 0.02);
}

#[test]
fn latency_u_shape_in_width() {
    // latency improves from tiny widths then worsens as verification cost
    // dominates — the Fig. 4 U-shape
    let hit = HitModel::default_for("math");
    let cluster = ClusterSpec::paper(14);
    let lat = |w: usize| {
        let mut rng = XorShiftRng::new(4);
        simulate_pipedec(&cluster, w, 16, &hit, 1024, &mut rng).s_per_token()
    };
    let (l2, l32, l512) = (lat(2), lat(32), lat(512));
    assert!(l32 < l2, "moderate width should beat tiny ({l32} vs {l2})");
    assert!(l512 > l32, "huge width should pay verification cost");
}

#[test]
fn throughput_crossover_in_k() {
    let cluster = ClusterSpec::paper(14);
    let hit = HitModel::default_for("math");
    let mut rng = XorShiftRng::new(5);
    let pd1 = throughput_tokens_per_s(&cluster, "pipedec", 1, 8, &hit, 32, 16, &mut rng);
    let pp1 = throughput_tokens_per_s(&cluster, "pp", 1, 8, &hit, 32, 16, &mut rng);
    let pd16 = throughput_tokens_per_s(&cluster, "pipedec", 16, 8, &hit, 32, 16, &mut rng);
    let pp16 = throughput_tokens_per_s(&cluster, "pp", 16, 8, &hit, 32, 16, &mut rng);
    assert!(pd1 > pp1, "k=1: PipeDec should lead");
    assert!(pp16 > pd16, "k=16: PP should lead");
}

#[test]
fn slm_comparison_point() {
    let s = simulate_slm(256);
    // 8B on L40 ~ 18-20 ms/token
    assert!((0.012..0.03).contains(&s.s_per_token()));
}

#[test]
fn deterministic_under_seed() {
    let cluster = ClusterSpec::paper(14);
    let hit = HitModel::default_for("code");
    let a = simulate_pipedec(&cluster, 32, 16, &hit, 256, &mut XorShiftRng::new(9));
    let b = simulate_pipedec(&cluster, 32, 16, &hit, 256, &mut XorShiftRng::new(9));
    assert_eq!(a.seconds, b.seconds);
    assert_eq!(a.hits, b.hits);
}
