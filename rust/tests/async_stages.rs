//! ISSUE 4: threaded pipeline workers. The contract is twofold:
//!
//! 1. **Determinism** — decoding with `threads >= 2` is token-identical
//!    (and text-identical) to the sequential reference path
//!    (`threads = 1`) for both PipeDec and PipeDec-DB, across seeds and
//!    under both greedy and stochastic sampling. This is by construction
//!    (stage tasks read tree snapshots; verification stays at the sync
//!    phase) and asserted here.
//! 2. **Wall-clock sanity** — on a multi-core host the threaded engine is
//!    not materially slower than sequential (it should be faster once
//!    per-task compute dominates; a generous slack keeps CI noise out).
//!
//! ISSUE 5 extends the determinism contract to the overlapped sync phase:
//! `overlap_sync` on vs off must be bit-identical for both engines at
//! every thread count (greedy and stochastic) — deferring the cache
//! commits moves bookkeeping, never a decision — and the sync-phase
//! breakdown (`t_decide_s` / `t_commit_s` / `sync_overlap_ratio`) must
//! show the commits actually running on workers when a pool exists.

use pipedec::config::{EngineConfig, TreeConfig};
use pipedec::coordinator::Sampling;
use pipedec::engine::{
    build_engine, build_scheduled_engine, DecodeRequest, EngineKind, NullSink,
};

const PROMPT: &str =
    "<math>\nquestion: alice has 4 apples and buys 3 more. how many apples now?\n";

fn artifacts() -> Option<std::path::PathBuf> {
    let dir = pipedec::artifacts_dir();
    dir.join("target_config.txt").exists().then_some(dir)
}

fn cfg_overlap(threads: usize, seed: u64, overlap_sync: bool) -> EngineConfig {
    EngineConfig {
        stages: 2,
        tree: TreeConfig {
            max_width: 4,
            max_children: 4,
            max_depth: 8,
        },
        max_new_tokens: 12,
        seed,
        threads,
        overlap_sync,
        ..EngineConfig::default()
    }
}

fn cfg(threads: usize, seed: u64) -> EngineConfig {
    cfg_overlap(threads, seed, true)
}

#[test]
fn threaded_decode_is_token_identical_to_sequential() {
    let Some(dir) = artifacts() else {
        eprintln!("skipping: no artifacts");
        return;
    };
    for kind in [EngineKind::PipeDec, EngineKind::PipeDecDb] {
        for seed in [0u64, 7, 1234] {
            let req = DecodeRequest::new(PROMPT).with_seed(seed);
            let mut seq = build_engine(kind, &dir, cfg(1, seed)).unwrap();
            let a = seq.decode(&req, &mut NullSink).unwrap();
            // threads >= groups + 1: every task of a timestep on its own
            // worker
            let mut par = build_engine(kind, &dir, cfg(4, seed)).unwrap();
            let b = par.decode(&req, &mut NullSink).unwrap();
            assert_eq!(
                a.tokens, b.tokens,
                "{kind} seed {seed}: threaded tokens diverged from sequential"
            );
            assert_eq!(a.text, b.text, "{kind} seed {seed}: text diverged");
            assert_eq!(
                a.timesteps(),
                b.timesteps(),
                "{kind} seed {seed}: scheduling diverged (timestep count)"
            );
        }
    }
}

#[test]
fn threaded_decode_is_identical_under_stochastic_sampling() {
    // The RNG is consumed only at the coordinator's sync phase, so even
    // stochastic replay must be independent of the thread count.
    let Some(dir) = artifacts() else {
        eprintln!("skipping: no artifacts");
        return;
    };
    let req = DecodeRequest::new(PROMPT)
        .with_seed(42)
        .with_sampling(Sampling::llama_stochastic());
    let mut seq = build_engine(EngineKind::PipeDec, &dir, cfg(1, 42)).unwrap();
    let a = seq.decode(&req, &mut NullSink).unwrap();
    let mut par = build_engine(EngineKind::PipeDec, &dir, cfg(3, 42)).unwrap();
    let b = par.decode(&req, &mut NullSink).unwrap();
    assert_eq!(a.tokens, b.tokens, "stochastic replay diverged across threads");
}

#[test]
fn overlap_sync_is_token_identical_to_serial_sync() {
    // ISSUE 5 acceptance: overlap on vs off is bit-identical for both
    // engines across threads ∈ {1, 2, auto} — the decide phase
    // (verification, sampling, RNG) never moved, only cache bookkeeping.
    let Some(dir) = artifacts() else {
        eprintln!("skipping: no artifacts");
        return;
    };
    for kind in [EngineKind::PipeDec, EngineKind::PipeDecDb] {
        for threads in [1usize, 2, 0] {
            let req = DecodeRequest::new(PROMPT).with_seed(11);
            let mut serial =
                build_engine(kind, &dir, cfg_overlap(threads, 11, false)).unwrap();
            let a = serial.decode(&req, &mut NullSink).unwrap();
            let mut overlapped =
                build_engine(kind, &dir, cfg_overlap(threads, 11, true)).unwrap();
            let b = overlapped.decode(&req, &mut NullSink).unwrap();
            assert_eq!(
                a.tokens, b.tokens,
                "{kind} threads={threads}: overlap_sync changed the tokens"
            );
            assert_eq!(
                a.timesteps(),
                b.timesteps(),
                "{kind} threads={threads}: overlap_sync changed the schedule"
            );
        }
    }
}

#[test]
fn overlap_sync_is_identical_under_stochastic_sampling() {
    // RNG consumption order is a decide-phase property; deferring cache
    // commits must not move a single draw for either engine.
    let Some(dir) = artifacts() else {
        eprintln!("skipping: no artifacts");
        return;
    };
    for kind in [EngineKind::PipeDec, EngineKind::PipeDecDb] {
        let req = DecodeRequest::new(PROMPT)
            .with_seed(23)
            .with_sampling(Sampling::llama_stochastic());
        let mut serial = build_engine(kind, &dir, cfg_overlap(0, 23, false)).unwrap();
        let a = serial.decode(&req, &mut NullSink).unwrap();
        let mut overlapped = build_engine(kind, &dir, cfg_overlap(0, 23, true)).unwrap();
        let b = overlapped.decode(&req, &mut NullSink).unwrap();
        assert_eq!(
            a.tokens, b.tokens,
            "{kind}: stochastic replay diverged between sync modes"
        );
    }
}

#[test]
fn overlap_sync_reports_the_breakdown_and_moves_commits_to_workers() {
    // The observability satellite: with a real pool and overlap on, the
    // commit seconds must show up as worker-side overlap (ratio > 0) and
    // the serial path must report ratio == 0; both report t_decide_s.
    let Some(dir) = artifacts() else {
        eprintln!("skipping: no artifacts");
        return;
    };
    let req = DecodeRequest::new(PROMPT).with_seed(3);
    let mut overlapped =
        build_engine(EngineKind::PipeDec, &dir, cfg_overlap(3, 3, true)).unwrap();
    let a = overlapped.decode(&req, &mut NullSink).unwrap();
    assert!(a.metrics.sample_sum("t_decide_s") > 0.0, "decide timing missing");
    assert!(
        a.metrics.counter("commit_ops") > 0,
        "no commits applied on workers"
    );
    let ratio = a.metrics.samples("sync_overlap_ratio")[0];
    assert!(
        ratio > 0.0 && ratio <= 1.0,
        "overlap ratio {ratio} out of range for the pooled overlapped path"
    );
    let mut serial =
        build_engine(EngineKind::PipeDec, &dir, cfg_overlap(3, 3, false)).unwrap();
    let b = serial.decode(&req, &mut NullSink).unwrap();
    assert_eq!(
        b.metrics.samples("sync_overlap_ratio")[0], 0.0,
        "serial sync must report zero overlap"
    );
    assert!(b.metrics.sample_sum("t_commit_s") > 0.0, "eager commit timing missing");
}

#[test]
fn threaded_db_coscheduling_matches_sequential_per_session() {
    // Three concurrent sessions through the scheduled surface: the dynamic
    // batch must produce the same per-session outputs at every thread
    // count (scheduling decisions — admission, slot grants, sync order —
    // are all coordinator-side).
    let Some(dir) = artifacts() else {
        eprintln!("skipping: no artifacts");
        return;
    };
    let prompts = [
        PROMPT,
        "<math>\nquestion: bob has 3 coins and finds 2 more. total?\n",
        "<math>\nquestion: carol reads 5 pages then 4 pages. how many pages?\n",
    ];
    let mut outputs: Vec<Vec<Vec<u32>>> = Vec::new();
    for threads in [1usize, 4] {
        let mut eng =
            build_scheduled_engine(EngineKind::PipeDecDb, &dir, cfg(threads, 9)).unwrap();
        let ids: Vec<_> = prompts
            .iter()
            .map(|p| {
                eng.submit(DecodeRequest::new(p).with_seed(9), Box::new(NullSink))
                    .unwrap()
            })
            .collect();
        let mut guard = 0;
        while eng.has_work() {
            eng.step().unwrap();
            guard += 1;
            assert!(guard < 10_000, "scheduler failed to drain");
        }
        outputs.push(
            ids.into_iter()
                .map(|id| eng.poll(id).expect("finished session").tokens)
                .collect(),
        );
    }
    assert_eq!(
        outputs[0], outputs[1],
        "per-session DB outputs diverged between threads=1 and threads=4"
    );
}

#[test]
fn threaded_wall_clock_is_sane_on_multicore() {
    // Satellite: wall <= sequential_wall (with slack) on multi-core
    // runners. Skipped on small hosts where the pool cannot actually run
    // the task set concurrently. The slack is generous (1.5x, best-of-3)
    // because shared CI runners are noisy — the load-bearing contract is
    // the token-identity tests above; this one only catches gross
    // regressions (e.g. the pool serializing everything onto one worker).
    let cores = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1);
    if cores < 4 {
        eprintln!("skipping: only {cores} cores");
        return;
    }
    let Some(dir) = artifacts() else {
        eprintln!("skipping: no artifacts");
        return;
    };
    let req = DecodeRequest::new(PROMPT).with_seed(7);
    let wall = |threads: usize| -> f64 {
        let mut eng = build_engine(EngineKind::PipeDec, &dir, cfg(threads, 7)).unwrap();
        eng.decode(&req, &mut NullSink).unwrap(); // warmup
        (0..3)
            .map(|_| eng.decode(&req, &mut NullSink).unwrap().wall_s)
            .fold(f64::INFINITY, f64::min)
    };
    let seq = wall(1);
    let par = wall(3); // groups + 1 for the stages=2 config
    assert!(
        par <= seq * 1.5,
        "threaded decode ({par:.4}s) materially slower than sequential ({seq:.4}s)"
    );
}
