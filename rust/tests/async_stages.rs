//! ISSUE 4: threaded pipeline workers. The contract is twofold:
//!
//! 1. **Determinism** — decoding with `threads >= 2` is token-identical
//!    (and text-identical) to the sequential reference path
//!    (`threads = 1`) for both PipeDec and PipeDec-DB, across seeds and
//!    under both greedy and stochastic sampling. This is by construction
//!    (stage tasks read tree snapshots; verification stays at the sync
//!    phase) and asserted here.
//! 2. **Wall-clock sanity** — on a multi-core host the threaded engine is
//!    not materially slower than sequential (it should be faster once
//!    per-task compute dominates; a generous slack keeps CI noise out).
//!
//! ISSUE 5 extends the determinism contract to the overlapped sync phase:
//! `overlap_sync` on vs off must be bit-identical for both engines at
//! every thread count (greedy and stochastic) — deferring the cache
//! commits moves bookkeeping, never a decision — and the sync-phase
//! breakdown (`t_decide_s` / `t_commit_s` / `sync_overlap_ratio`) must
//! show the commits actually running on workers when a pool exists.
//!
//! ISSUE 10 extends it again to continuous asynchronous speculation:
//! `spec_inflight > 1` (the free-running epoch-tagged draft) must be
//! token-identical to lockstep across engines, thread counts and sync
//! modes — including across Miss-path resets (only stale drops, never a
//! stale apply) and mid-flight session cancels (the bank dies with the
//! session, nothing leaks).

use pipedec::config::{EngineConfig, TreeConfig};
use pipedec::coordinator::{PipeDecDbEngine, Sampling};
use pipedec::engine::{
    build_engine, build_scheduled_engine, DecodeRequest, EngineKind, NullSink, ScheduledEngine,
};

const PROMPT: &str =
    "<math>\nquestion: alice has 4 apples and buys 3 more. how many apples now?\n";

fn artifacts() -> Option<std::path::PathBuf> {
    let dir = pipedec::artifacts_dir();
    dir.join("target_config.txt").exists().then_some(dir)
}

fn cfg_overlap(threads: usize, seed: u64, overlap_sync: bool) -> EngineConfig {
    EngineConfig {
        stages: 2,
        tree: TreeConfig {
            max_width: 4,
            max_children: 4,
            max_depth: 8,
        },
        max_new_tokens: 12,
        seed,
        threads,
        overlap_sync,
        ..EngineConfig::default()
    }
}

fn cfg(threads: usize, seed: u64) -> EngineConfig {
    cfg_overlap(threads, seed, true)
}

#[test]
fn threaded_decode_is_token_identical_to_sequential() {
    let Some(dir) = artifacts() else {
        eprintln!("skipping: no artifacts");
        return;
    };
    for kind in [EngineKind::PipeDec, EngineKind::PipeDecDb] {
        for seed in [0u64, 7, 1234] {
            let req = DecodeRequest::new(PROMPT).with_seed(seed);
            let mut seq = build_engine(kind, &dir, cfg(1, seed)).unwrap();
            let a = seq.decode(&req, &mut NullSink).unwrap();
            // threads >= groups + 1: every task of a timestep on its own
            // worker
            let mut par = build_engine(kind, &dir, cfg(4, seed)).unwrap();
            let b = par.decode(&req, &mut NullSink).unwrap();
            assert_eq!(
                a.tokens, b.tokens,
                "{kind} seed {seed}: threaded tokens diverged from sequential"
            );
            assert_eq!(a.text, b.text, "{kind} seed {seed}: text diverged");
            assert_eq!(
                a.timesteps(),
                b.timesteps(),
                "{kind} seed {seed}: scheduling diverged (timestep count)"
            );
        }
    }
}

#[test]
fn threaded_decode_is_identical_under_stochastic_sampling() {
    // The RNG is consumed only at the coordinator's sync phase, so even
    // stochastic replay must be independent of the thread count.
    let Some(dir) = artifacts() else {
        eprintln!("skipping: no artifacts");
        return;
    };
    let req = DecodeRequest::new(PROMPT)
        .with_seed(42)
        .with_sampling(Sampling::llama_stochastic());
    let mut seq = build_engine(EngineKind::PipeDec, &dir, cfg(1, 42)).unwrap();
    let a = seq.decode(&req, &mut NullSink).unwrap();
    let mut par = build_engine(EngineKind::PipeDec, &dir, cfg(3, 42)).unwrap();
    let b = par.decode(&req, &mut NullSink).unwrap();
    assert_eq!(a.tokens, b.tokens, "stochastic replay diverged across threads");
}

#[test]
fn overlap_sync_is_token_identical_to_serial_sync() {
    // ISSUE 5 acceptance: overlap on vs off is bit-identical for both
    // engines across threads ∈ {1, 2, auto} — the decide phase
    // (verification, sampling, RNG) never moved, only cache bookkeeping.
    let Some(dir) = artifacts() else {
        eprintln!("skipping: no artifacts");
        return;
    };
    for kind in [EngineKind::PipeDec, EngineKind::PipeDecDb] {
        for threads in [1usize, 2, 0] {
            let req = DecodeRequest::new(PROMPT).with_seed(11);
            let mut serial =
                build_engine(kind, &dir, cfg_overlap(threads, 11, false)).unwrap();
            let a = serial.decode(&req, &mut NullSink).unwrap();
            let mut overlapped =
                build_engine(kind, &dir, cfg_overlap(threads, 11, true)).unwrap();
            let b = overlapped.decode(&req, &mut NullSink).unwrap();
            assert_eq!(
                a.tokens, b.tokens,
                "{kind} threads={threads}: overlap_sync changed the tokens"
            );
            assert_eq!(
                a.timesteps(),
                b.timesteps(),
                "{kind} threads={threads}: overlap_sync changed the schedule"
            );
        }
    }
}

#[test]
fn overlap_sync_is_identical_under_stochastic_sampling() {
    // RNG consumption order is a decide-phase property; deferring cache
    // commits must not move a single draw for either engine.
    let Some(dir) = artifacts() else {
        eprintln!("skipping: no artifacts");
        return;
    };
    for kind in [EngineKind::PipeDec, EngineKind::PipeDecDb] {
        let req = DecodeRequest::new(PROMPT)
            .with_seed(23)
            .with_sampling(Sampling::llama_stochastic());
        let mut serial = build_engine(kind, &dir, cfg_overlap(0, 23, false)).unwrap();
        let a = serial.decode(&req, &mut NullSink).unwrap();
        let mut overlapped = build_engine(kind, &dir, cfg_overlap(0, 23, true)).unwrap();
        let b = overlapped.decode(&req, &mut NullSink).unwrap();
        assert_eq!(
            a.tokens, b.tokens,
            "{kind}: stochastic replay diverged between sync modes"
        );
    }
}

#[test]
fn overlap_sync_reports_the_breakdown_and_moves_commits_to_workers() {
    // The observability satellite: with a real pool and overlap on, the
    // commit seconds must show up as worker-side overlap (ratio > 0) and
    // the serial path must report ratio == 0; both report t_decide_s.
    let Some(dir) = artifacts() else {
        eprintln!("skipping: no artifacts");
        return;
    };
    let req = DecodeRequest::new(PROMPT).with_seed(3);
    let mut overlapped =
        build_engine(EngineKind::PipeDec, &dir, cfg_overlap(3, 3, true)).unwrap();
    let a = overlapped.decode(&req, &mut NullSink).unwrap();
    assert!(a.metrics.sample_sum("t_decide_s") > 0.0, "decide timing missing");
    assert!(
        a.metrics.counter("commit_ops") > 0,
        "no commits applied on workers"
    );
    let ratio = a.metrics.samples("sync_overlap_ratio")[0];
    assert!(
        ratio > 0.0 && ratio <= 1.0,
        "overlap ratio {ratio} out of range for the pooled overlapped path"
    );
    let mut serial =
        build_engine(EngineKind::PipeDec, &dir, cfg_overlap(3, 3, false)).unwrap();
    let b = serial.decode(&req, &mut NullSink).unwrap();
    assert_eq!(
        b.metrics.samples("sync_overlap_ratio")[0], 0.0,
        "serial sync must report zero overlap"
    );
    assert!(b.metrics.sample_sum("t_commit_s") > 0.0, "eager commit timing missing");
}

#[test]
fn threaded_db_coscheduling_matches_sequential_per_session() {
    // Three concurrent sessions through the scheduled surface: the dynamic
    // batch must produce the same per-session outputs at every thread
    // count (scheduling decisions — admission, slot grants, sync order —
    // are all coordinator-side).
    let Some(dir) = artifacts() else {
        eprintln!("skipping: no artifacts");
        return;
    };
    let prompts = [
        PROMPT,
        "<math>\nquestion: bob has 3 coins and finds 2 more. total?\n",
        "<math>\nquestion: carol reads 5 pages then 4 pages. how many pages?\n",
    ];
    let mut outputs: Vec<Vec<Vec<u32>>> = Vec::new();
    for threads in [1usize, 4] {
        let mut eng =
            build_scheduled_engine(EngineKind::PipeDecDb, &dir, cfg(threads, 9)).unwrap();
        let ids: Vec<_> = prompts
            .iter()
            .map(|p| {
                eng.submit(DecodeRequest::new(p).with_seed(9), Box::new(NullSink))
                    .unwrap()
            })
            .collect();
        let mut guard = 0;
        while eng.has_work() {
            eng.step().unwrap();
            guard += 1;
            assert!(guard < 10_000, "scheduler failed to drain");
        }
        outputs.push(
            ids.into_iter()
                .map(|id| eng.poll(id).expect("finished session").tokens)
                .collect(),
        );
    }
    assert_eq!(
        outputs[0], outputs[1],
        "per-session DB outputs diverged between threads=1 and threads=4"
    );
}

#[test]
fn threaded_wall_clock_is_sane_on_multicore() {
    // Satellite: wall <= sequential_wall (with slack) on multi-core
    // runners. Skipped on small hosts where the pool cannot actually run
    // the task set concurrently. The slack is generous (1.5x, best-of-3)
    // because shared CI runners are noisy — the load-bearing contract is
    // the token-identity tests above; this one only catches gross
    // regressions (e.g. the pool serializing everything onto one worker).
    let cores = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1);
    if cores < 4 {
        eprintln!("skipping: only {cores} cores");
        return;
    }
    let Some(dir) = artifacts() else {
        eprintln!("skipping: no artifacts");
        return;
    };
    let req = DecodeRequest::new(PROMPT).with_seed(7);
    let wall = |threads: usize| -> f64 {
        let mut eng = build_engine(EngineKind::PipeDec, &dir, cfg(threads, 7)).unwrap();
        eng.decode(&req, &mut NullSink).unwrap(); // warmup
        (0..3)
            .map(|_| eng.decode(&req, &mut NullSink).unwrap().wall_s)
            .fold(f64::INFINITY, f64::min)
    };
    let seq = wall(1);
    let par = wall(3); // groups + 1 for the stages=2 config
    assert!(
        par <= seq * 1.5,
        "threaded decode ({par:.4}s) materially slower than sequential ({seq:.4}s)"
    );
}

// ---- ISSUE 10: continuous asynchronous speculation ----

fn cfg_spec(threads: usize, seed: u64, overlap_sync: bool, spec_inflight: usize) -> EngineConfig {
    EngineConfig {
        spec_inflight,
        ..cfg_overlap(threads, seed, overlap_sync)
    }
}

#[test]
fn continuous_speculation_is_token_identical_to_lockstep() {
    // ISSUE 10 acceptance: greedy outputs at `spec_inflight > 1` are
    // bit-identical to lockstep for both engines, across threads
    // {1, 2, auto} and both sync modes. Timesteps are deliberately *not*
    // compared — a served generation removes a draft dispatch from the
    // schedule, which is the entire point.
    let Some(dir) = artifacts() else {
        eprintln!("skipping: no artifacts");
        return;
    };
    for kind in [EngineKind::PipeDec, EngineKind::PipeDecDb] {
        for threads in [1usize, 2, 0] {
            for overlap in [false, true] {
                let req = DecodeRequest::new(PROMPT).with_seed(31);
                let mut lockstep =
                    build_engine(kind, &dir, cfg_spec(threads, 31, overlap, 1)).unwrap();
                let a = lockstep.decode(&req, &mut NullSink).unwrap();
                let mut spec =
                    build_engine(kind, &dir, cfg_spec(threads, 31, overlap, 3)).unwrap();
                let b = spec.decode(&req, &mut NullSink).unwrap();
                assert_eq!(
                    a.tokens, b.tokens,
                    "{kind} threads={threads} overlap={overlap}: spec_inflight=3 \
                     changed the tokens"
                );
                assert_eq!(
                    a.text, b.text,
                    "{kind} threads={threads} overlap={overlap}: text diverged"
                );
            }
        }
    }
}

#[test]
fn continuous_speculation_is_identical_under_stochastic_sampling() {
    // The RNG is drawn once per emitted token, at the decide phase only;
    // serving a banked generation instead of dispatching the draft must
    // not move a single draw.
    let Some(dir) = artifacts() else {
        eprintln!("skipping: no artifacts");
        return;
    };
    for kind in [EngineKind::PipeDec, EngineKind::PipeDecDb] {
        let req = DecodeRequest::new(PROMPT)
            .with_seed(42)
            .with_sampling(Sampling::llama_stochastic());
        let mut lockstep = build_engine(kind, &dir, cfg_spec(0, 42, true, 1)).unwrap();
        let a = lockstep.decode(&req, &mut NullSink).unwrap();
        let mut spec = build_engine(kind, &dir, cfg_spec(0, 42, true, 3)).unwrap();
        let b = spec.decode(&req, &mut NullSink).unwrap();
        assert_eq!(
            a.tokens, b.tokens,
            "{kind}: stochastic replay diverged under continuous speculation"
        );
    }
}

#[test]
fn speculation_engages_and_occupancy_is_reported() {
    // The free-running draft must actually bank generations (served or
    // dropped, depending on how verification lands), occupancy must be a
    // valid fraction with bubble as its complement, and lockstep
    // (`spec_inflight = 1`) must never bank anything.
    let Some(dir) = artifacts() else {
        eprintln!("skipping: no artifacts");
        return;
    };
    let req = DecodeRequest::new(PROMPT).with_seed(5);
    let mut spec = build_engine(EngineKind::PipeDec, &dir, cfg_spec(2, 5, true, 4)).unwrap();
    let b = spec.decode(&req, &mut NullSink).unwrap();
    let engaged = b.metrics.counter("spec_expansions_served")
        + b.metrics.counter("stale_expansions_dropped");
    assert!(engaged > 0, "free-running speculation never engaged");
    let occ = b.metrics.samples("occupancy")[0];
    assert!(occ > 0.0 && occ <= 1.0, "occupancy {occ} out of range");
    let bubble = b.metrics.samples("bubble_fraction")[0];
    assert!(
        (occ + bubble - 1.0).abs() < 1e-9,
        "bubble {bubble} is not the complement of occupancy {occ}"
    );
    let mut lockstep =
        build_engine(EngineKind::PipeDec, &dir, cfg_spec(2, 5, true, 1)).unwrap();
    let a = lockstep.decode(&req, &mut NullSink).unwrap();
    assert_eq!(a.metrics.counter("spec_expansions_served"), 0);
    assert_eq!(a.metrics.counter("stale_expansions_dropped"), 0);
    assert!(a.metrics.samples("occupancy")[0] > 0.0, "lockstep occupancy missing");
}

#[test]
fn speculation_across_miss_resets_drops_stale_generations_only() {
    // Satellite edge case: `ablate_tree_reuse` sends every verify down
    // the Miss path, so each reset bumps the epoch and invalidates the
    // whole bank. The stale counter must show the drops and the tokens
    // must not move — a stale generation is never applied.
    let Some(dir) = artifacts() else {
        eprintln!("skipping: no artifacts");
        return;
    };
    for kind in [EngineKind::PipeDec, EngineKind::PipeDecDb] {
        let req = DecodeRequest::new(PROMPT).with_seed(13);
        let mut ca = cfg_spec(2, 13, true, 1);
        ca.ablate_tree_reuse = true;
        let mut cb = cfg_spec(2, 13, true, 4);
        cb.ablate_tree_reuse = true;
        let a = build_engine(kind, &dir, ca).unwrap().decode(&req, &mut NullSink).unwrap();
        let b = build_engine(kind, &dir, cb).unwrap().decode(&req, &mut NullSink).unwrap();
        assert_eq!(
            a.tokens, b.tokens,
            "{kind}: a stale generation leaked into the output across a Miss reset"
        );
        assert!(
            b.metrics.counter("stale_expansions_dropped") > 0,
            "{kind}: Miss resets produced no stale drops"
        );
    }
}

#[test]
fn cancel_mid_flight_leaks_no_speculative_generation() {
    // Satellite edge case: cancelling a session with banked generations
    // must drop its bank with it (the `inflight_generations` probe), and
    // the surviving session must decode exactly as if alone.
    let Some(dir) = artifacts() else {
        eprintln!("skipping: no artifacts");
        return;
    };
    let other = "<math>\nquestion: bob has 3 coins and finds 2 more. total?\n";
    let mut c = cfg(1, 9);
    c.spec_inflight = 3;
    let mut solo = PipeDecDbEngine::new(&dir, c.clone()).unwrap();
    let solo_id = solo
        .submit(DecodeRequest::new(other).with_seed(9), Box::new(NullSink))
        .unwrap();
    let mut guard = 0;
    while solo.has_work() {
        solo.step().unwrap();
        guard += 1;
        assert!(guard < 10_000, "solo reference failed to drain");
    }
    let expected = solo.poll(solo_id).expect("solo reference finishes").tokens;

    let mut eng = PipeDecDbEngine::new(&dir, c).unwrap();
    let victim = eng
        .submit(DecodeRequest::new(PROMPT).with_seed(9), Box::new(NullSink))
        .unwrap();
    let survivor = eng
        .submit(DecodeRequest::new(other).with_seed(9), Box::new(NullSink))
        .unwrap();
    let mut guard = 0;
    while eng.inflight_generations() == 0 && eng.has_work() {
        eng.step().unwrap();
        guard += 1;
        assert!(guard < 10_000, "speculation never engaged");
    }
    assert!(eng.inflight_generations() > 0, "no banked generation to cancel under");
    assert!(eng.cancel(victim), "mid-flight cancel must succeed");
    while eng.has_work() {
        eng.step().unwrap();
        guard += 1;
        assert!(guard < 10_000, "engine wedged after cancel");
    }
    assert_eq!(
        eng.inflight_generations(),
        0,
        "a speculative generation leaked past cancel/completion"
    );
    let out = eng.poll(survivor).expect("survivor finishes");
    assert_eq!(
        out.tokens, expected,
        "survivor's tokens changed because a neighbour was cancelled mid-speculation"
    );
}
