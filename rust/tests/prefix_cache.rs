//! Store-level tests for the tiered cross-request prefix cache
//! (`kvcache::prefix`, ISSUE 8): longest-prefix lookup across chunk
//! boundaries, byte-budget eviction, L2 round trips, checksum
//! corruption handling, `Arc`-shared residency, and truncation-robust
//! keying. The final test drives the real engine (artifact-gated) to
//! prove prompts differing only beyond the context-truncation point
//! still share a cached prefix.

use std::path::PathBuf;
use std::sync::Arc;

use pipedec::config::{EngineConfig, TreeConfig};
use pipedec::engine::{build_engine, DecodeRequest, EngineKind, NullSink};
use pipedec::kvcache::prefix::{prefix_key, PrefixEntry, PrefixKv, PrefixStore};

const CHUNK: usize = 4;

/// One single-cache block for the final chunk of `tokens`: layers=1,
/// heads=1, head_dim=2, with tensor values derived from the tokens so
/// different prefixes hold different payloads.
fn block(tokens: &[u32]) -> PrefixEntry {
    assert!(tokens.len() >= CHUNK && tokens.len() % CHUNK == 0);
    let start = tokens.len() - CHUNK;
    let fill = tokens[start] as f32;
    let n = CHUNK * 2;
    PrefixEntry {
        tokens: tokens.to_vec(),
        kv: vec![PrefixKv {
            layers: 1,
            heads: 1,
            head_dim: 2,
            start,
            rows: CHUNK,
            k: (0..n).map(|i| fill + i as f32 * 0.5).collect(),
            v: (0..n).map(|i| -fill - i as f32 * 0.25).collect(),
        }],
    }
}

/// Insert the full block chain for a chunk-aligned prompt, as admission
/// does after prefill.
fn insert_chain(store: &mut PrefixStore, prompt: &[u32]) {
    let mut b = CHUNK;
    while b <= store.align_down(prompt.len()) {
        store.insert(block(&prompt[..b])).unwrap();
        b += CHUNK;
    }
}

fn tmp_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("pipedec_prefix_test_{tag}"));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

#[test]
fn lookup_returns_longest_chain_across_chunk_boundaries() {
    let mut store = PrefixStore::new(CHUNK, 1 << 20, 1 << 20, None).unwrap();
    let prompt: Vec<u32> = (1..=12).collect();
    insert_chain(&mut store, &prompt);

    // full coverage: three consecutive blocks, in seeding order
    let chain = store.lookup(&prompt, prompt.len());
    assert_eq!(chain.len(), 3);
    assert_eq!(chain[0].tokens, prompt[..4]);
    assert_eq!(chain[2].tokens, prompt[..12]);

    // a cap below a boundary drops the partial chunk
    let chain = store.lookup(&prompt, 11);
    assert_eq!(chain.len(), 2, "cap 11 aligns down to 8");

    // a prompt diverging after token 8 stops the chain at the boundary
    let mut diverged = prompt.clone();
    diverged[9] = 99;
    let chain = store.lookup(&diverged, diverged.len());
    assert_eq!(chain.len(), 2, "divergence past row 8 keeps two blocks");
    assert_eq!(chain[1].tokens, prompt[..8]);

    // a prompt diverging inside the first chunk misses entirely
    let miss = store.lookup(&[99, 98, 97, 96, 95], 5);
    assert!(miss.is_empty());
    let s = store.stats();
    assert_eq!((s.l1_hits, s.misses), (3, 1));
}

#[test]
fn eviction_never_exceeds_either_tier_budget() {
    let dir = tmp_dir("evict");
    let b = block(&[1, 2, 3, 4]).bytes();
    // exactly two blocks fit in L1, exactly one spill file fits in L2
    let mut store = PrefixStore::new(CHUNK, 2 * b, b, Some(dir.clone())).unwrap();
    for base in 0u32..4 {
        let prompt: Vec<u32> = (0..CHUNK as u32).map(|i| base * 100 + i).collect();
        store.insert(block(&prompt)).unwrap();
        assert!(store.l1_bytes() <= 2 * b, "L1 over budget after insert {base}");
        assert!(store.l2_bytes() <= b, "L2 over budget after insert {base}");
    }
    assert_eq!((store.l1_len(), store.l2_len()), (2, 1));
    let s = store.stats();
    assert_eq!(s.evictions, 3, "two L1 demotions + one L2 drop");
    assert_eq!(s.spills, 2);
    // dropped spill files are really deleted
    let files = std::fs::read_dir(&dir).unwrap().count();
    assert_eq!(files, store.l2_len());
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn l2_round_trip_restores_bit_identical_tensors() {
    let dir = tmp_dir("roundtrip");
    // zero L1 budget: every insert demotes to disk immediately
    let mut store = PrefixStore::new(CHUNK, 0, 1 << 20, Some(dir.clone())).unwrap();
    let prompt: Vec<u32> = vec![7, 11, 13, 17];
    let original = store.insert(block(&prompt)).unwrap();
    assert_eq!(store.l1_len(), 0);
    assert_eq!(store.l2_len(), 1);

    let chain = store.lookup(&prompt, prompt.len());
    assert_eq!(chain.len(), 1, "spilled block must promote on lookup");
    assert_eq!(store.stats().l2_hits, 1);
    let promoted = &chain[0];
    assert_eq!(**promoted, *original, "promoted block differs from inserted");
    let bits = |xs: &[f32]| xs.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
    assert_eq!(bits(&promoted.kv[0].k), bits(&original.kv[0].k));
    assert_eq!(bits(&promoted.kv[0].v), bits(&original.kv[0].v));
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn checksum_corruption_reads_as_miss_and_deletes_the_file() {
    let dir = tmp_dir("corrupt");
    let mut store = PrefixStore::new(CHUNK, 0, 1 << 20, Some(dir.clone())).unwrap();
    let prompt: Vec<u32> = vec![21, 22, 23, 24];
    store.insert(block(&prompt)).unwrap();
    let file = store.l2_file(&prompt).expect("block spilled to disk");

    // flip one payload byte behind the checksum
    let mut bytes = std::fs::read(&file).unwrap();
    let mid = bytes.len() / 2;
    bytes[mid] ^= 0x20;
    std::fs::write(&file, bytes).unwrap();

    let chain = store.lookup(&prompt, prompt.len());
    assert!(chain.is_empty(), "corrupt spill must degrade to a miss");
    let s = store.stats();
    assert_eq!((s.misses, s.corrupt_dropped), (1, 1));
    assert!(!file.exists(), "corrupt spill file must be deleted");
    assert!(!store.contains(&prompt));
    assert_eq!((store.l2_len(), store.l2_bytes()), (0, 0));
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn concurrent_sessions_share_one_l1_copy_per_block() {
    let mut store = PrefixStore::new(CHUNK, 1 << 20, 1 << 20, None).unwrap();
    let template: Vec<u32> = (100..108).collect();
    insert_chain(&mut store, &template);
    let resident_bytes = store.l1_bytes();

    // two "sessions" probing the same template pin the same Arcs
    let s1 = store.lookup(&template, template.len());
    let s2 = store.lookup(&template, template.len());
    for (a, b) in s1.iter().zip(&s2) {
        assert!(Arc::ptr_eq(a, b), "sessions must share one resident copy");
        // store + two session pins
        assert_eq!(Arc::strong_count(a), 3);
    }
    // a third session re-inserting its own identical blocks converges on
    // the resident copies instead of duplicating them
    let before = store.stats().ref_bumps;
    let again = store.insert(block(&template[..4])).unwrap();
    assert!(Arc::ptr_eq(&again, &s1[0]));
    assert_eq!(store.stats().ref_bumps, before + 1);
    assert_eq!(store.l1_bytes(), resident_bytes, "no duplicate bytes");
    assert_eq!(store.l1_len(), 2);
}

#[test]
fn truncated_prompts_key_independently_of_untruncated_siblings() {
    let mut store = PrefixStore::new(CHUNK, 1 << 20, 1 << 20, None).unwrap();
    let long: Vec<u32> = (1..=12).collect();
    insert_chain(&mut store, &long);

    // a context-truncated sibling (first 8 tokens) covers exactly its
    // own aligned length — never the untruncated entry beyond it
    let truncated = &long[..8];
    let chain = store.lookup(truncated, truncated.len());
    assert_eq!(chain.len(), 2);
    assert_eq!(
        chain.last().unwrap().tokens.len(),
        8,
        "truncated prompt must not match past its own length"
    );

    // keys are position-exact: the full prefix and its truncation never
    // collide, and a probe differing right after the truncation point
    // still shares every block up to it
    assert_ne!(prefix_key(&long), prefix_key(truncated));
    let mut sibling = long.clone();
    sibling[8] = 77; // diverges immediately past the truncation point
    let chain = store.lookup(&sibling, sibling.len());
    assert_eq!(chain.len(), 2, "shared blocks up to the divergence");
}

// ---------------------------------------------------------------------
// Engine-level truncation regression (artifact-gated)
// ---------------------------------------------------------------------

fn artifacts() -> Option<std::path::PathBuf> {
    let dir = pipedec::artifacts_dir();
    dir.join("target_config.txt").exists().then_some(dir)
}

/// Prefix keys must be computed over the *context-truncated* prompt:
/// two prompts that only differ beyond the truncation point truncate to
/// the same token ids, so the second decode must hit the first one's
/// cached prefix and produce bit-identical greedy output.
#[test]
fn prompts_differing_beyond_truncation_point_still_hit() {
    let Some(dir) = artifacts() else {
        eprintln!("skipping: no artifacts");
        return;
    };
    let cfg = EngineConfig {
        stages: 2,
        tree: TreeConfig {
            max_width: 4,
            max_children: 4,
            max_depth: 8,
        },
        max_new_tokens: 8,
        ..EngineConfig::default()
    };
    let mut engine = build_engine(EngineKind::PipeDec, &dir, cfg).unwrap();

    // far longer than any model context: both prompts truncate to the
    // same ids, differing only in the tail the engine never sees
    let base = "the quick brown fox jumps over the lazy dog. ".repeat(4096);
    let p1 = format!("{base}ending one");
    let p2 = format!("{base}ending two");

    let out1 = engine
        .decode(&DecodeRequest::new(&p1), &mut NullSink)
        .unwrap();
    let out2 = engine
        .decode(&DecodeRequest::new(&p2), &mut NullSink)
        .unwrap();

    assert_eq!(out1.metrics.counter("prefix_hit_tokens"), 0, "cold decode");
    assert!(
        out2.metrics.counter("prefix_hit_tokens") > 0,
        "a prompt differing only beyond the truncation point must hit \
         the truncated sibling's cached prefix"
    );
    assert!(
        out2.metrics.counter("prefill_tokens") < out1.metrics.counter("prefill_tokens"),
        "the warm decode must compute fewer prefill tokens"
    );
    assert_eq!(
        out1.tokens, out2.tokens,
        "identical truncated prompts must decode identically through the cache"
    );
}
