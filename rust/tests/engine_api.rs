//! Trait-conformance suite for the unified engine API: every
//! `EngineKind` must (a) stream exactly its final token sequence through
//! the `TokenSink`, (b) if speculative, match PP's greedy prefix
//! (losslessness), and (c) honor per-request `max_new_tokens` overrides
//! without mutating the engine's configuration.

use pipedec::config::{EngineConfig, TreeConfig};
use pipedec::engine::{build_engine, DecodeRequest, Engine, EngineKind, VecSink};

fn artifacts() -> Option<std::path::PathBuf> {
    let dir = pipedec::artifacts_dir();
    dir.join("target_config.txt").exists().then_some(dir)
}

const PROMPT: &str = "<math>\nquestion: alice has 4 apples and buys 3 more. how many apples now?\n";

fn cfg() -> EngineConfig {
    EngineConfig {
        stages: 2,
        tree: TreeConfig { max_width: 4, max_children: 4, max_depth: 8 },
        max_new_tokens: 20,
        ..EngineConfig::default()
    }
}

#[test]
fn registry_builds_all_kinds_with_matching_identity() {
    let Some(dir) = artifacts() else { eprintln!("skipping: no artifacts"); return };
    for kind in EngineKind::ALL {
        let e = build_engine(kind, &dir, cfg()).unwrap();
        assert_eq!(e.kind(), kind);
        assert_eq!(e.name(), kind.name());
        assert_eq!(e.config().stages, cfg().stages);
        // registry names parse back to the same kind (CLI round trip)
        assert_eq!(e.name().parse::<EngineKind>().unwrap(), kind);
    }
}

#[test]
fn streamed_tokens_equal_final_tokens_for_every_kind() {
    let Some(dir) = artifacts() else { eprintln!("skipping: no artifacts"); return };
    for kind in EngineKind::ALL {
        let mut e = build_engine(kind, &dir, cfg()).unwrap();
        let mut sink = VecSink::new();
        let out = e.decode(&DecodeRequest::new(PROMPT), &mut sink).unwrap();
        assert!(!out.tokens.is_empty(), "{kind}: empty decode");
        assert_eq!(sink.tokens(), &out.tokens[..],
            "{kind}: streamed tokens diverge from final output");
    }
}

#[test]
fn speculative_kinds_match_pp_greedy_prefix() {
    let Some(dir) = artifacts() else { eprintln!("skipping: no artifacts"); return };
    let pp = build_engine(EngineKind::Pp, &dir, cfg()).unwrap()
        .decode_prompt(PROMPT).unwrap();
    for kind in EngineKind::ALL.into_iter().filter(|k| k.is_speculative()) {
        let mut e = build_engine(kind, &dir, cfg()).unwrap();
        let out = e.decode_prompt(PROMPT).unwrap();
        let n = out.tokens.len().min(pp.tokens.len());
        assert_eq!(&out.tokens[..n], &pp.tokens[..n],
            "{kind} diverged from PP greedy decoding (losslessness)");
        assert!(out.spec.is_some(), "{kind}: speculative engine must report SpecStats");
    }
}

#[test]
fn spec_stats_presence_matches_registry_split() {
    let Some(dir) = artifacts() else { eprintln!("skipping: no artifacts"); return };
    for kind in EngineKind::ALL {
        let mut e = build_engine(kind, &dir, cfg()).unwrap();
        let out = e.decode_prompt(PROMPT).unwrap();
        assert_eq!(out.spec.is_some(), kind.is_speculative(),
            "{kind}: SpecStats presence disagrees with is_speculative()");
    }
}

#[test]
fn per_request_max_new_tokens_override_is_honored_everywhere() {
    let Some(dir) = artifacts() else { eprintln!("skipping: no artifacts"); return };
    for kind in EngineKind::ALL {
        let mut e = build_engine(kind, &dir, cfg()).unwrap();
        let short = e
            .decode(&DecodeRequest::new(PROMPT).with_max_new_tokens(6),
                &mut pipedec::engine::NullSink)
            .unwrap();
        assert!(short.tokens.len() <= 6,
            "{kind}: override ignored ({} tokens)", short.tokens.len());
        // the engine's own config is untouched by the override
        assert_eq!(e.config().max_new_tokens, cfg().max_new_tokens,
            "{kind}: decode mutated the engine config");
        let full = e.decode_prompt(PROMPT).unwrap();
        assert!(full.tokens.len() >= short.tokens.len(),
            "{kind}: default run shorter than overridden run");
    }
}
