//! Trait-conformance suite for the unified engine API: every
//! `EngineKind` (including `PipeDecDb`) must (a) stream exactly its final
//! token sequence through the `TokenSink`, (b) if speculative, match PP's
//! greedy prefix (losslessness), (c) honor per-request `max_new_tokens`
//! overrides without mutating the engine's configuration, and (d) serve
//! identically through the scheduled (`submit`/`step`/`poll`) surface.

use std::cell::RefCell;
use std::rc::Rc;

use pipedec::config::{EngineConfig, TreeConfig};
use pipedec::engine::{
    build_engine, build_scheduled_engine, DecodeRequest, Engine, EngineKind, TokenSink, VecSink,
};

fn artifacts() -> Option<std::path::PathBuf> {
    let dir = pipedec::artifacts_dir();
    dir.join("target_config.txt").exists().then_some(dir)
}

const PROMPT: &str = "<math>\nquestion: alice has 4 apples and buys 3 more. how many apples now?\n";

fn cfg() -> EngineConfig {
    EngineConfig {
        stages: 2,
        tree: TreeConfig { max_width: 4, max_children: 4, max_depth: 8 },
        max_new_tokens: 20,
        ..EngineConfig::default()
    }
}

#[test]
fn registry_builds_all_kinds_with_matching_identity() {
    let Some(dir) = artifacts() else { eprintln!("skipping: no artifacts"); return };
    for kind in EngineKind::ALL {
        let e = build_engine(kind, &dir, cfg()).unwrap();
        assert_eq!(e.kind(), kind);
        assert_eq!(e.name(), kind.name());
        assert_eq!(e.config().stages, cfg().stages);
        // registry names parse back to the same kind (CLI round trip)
        assert_eq!(e.name().parse::<EngineKind>().unwrap(), kind);
    }
}

#[test]
fn streamed_tokens_equal_final_tokens_for_every_kind() {
    let Some(dir) = artifacts() else { eprintln!("skipping: no artifacts"); return };
    for kind in EngineKind::ALL {
        let mut e = build_engine(kind, &dir, cfg()).unwrap();
        let mut sink = VecSink::new();
        let out = e.decode(&DecodeRequest::new(PROMPT), &mut sink).unwrap();
        assert!(!out.tokens.is_empty(), "{kind}: empty decode");
        assert_eq!(sink.tokens(), &out.tokens[..],
            "{kind}: streamed tokens diverge from final output");
    }
}

#[test]
fn speculative_kinds_match_pp_greedy_prefix() {
    let Some(dir) = artifacts() else { eprintln!("skipping: no artifacts"); return };
    let pp = build_engine(EngineKind::Pp, &dir, cfg()).unwrap()
        .decode_prompt(PROMPT).unwrap();
    for kind in EngineKind::ALL.into_iter().filter(|k| k.is_speculative()) {
        let mut e = build_engine(kind, &dir, cfg()).unwrap();
        let out = e.decode_prompt(PROMPT).unwrap();
        let n = out.tokens.len().min(pp.tokens.len());
        assert_eq!(&out.tokens[..n], &pp.tokens[..n],
            "{kind} diverged from PP greedy decoding (losslessness)");
        assert!(out.spec.is_some(), "{kind}: speculative engine must report SpecStats");
    }
}

#[test]
fn spec_stats_presence_matches_registry_split() {
    let Some(dir) = artifacts() else { eprintln!("skipping: no artifacts"); return };
    for kind in EngineKind::ALL {
        let mut e = build_engine(kind, &dir, cfg()).unwrap();
        let out = e.decode_prompt(PROMPT).unwrap();
        assert_eq!(out.spec.is_some(), kind.is_speculative(),
            "{kind}: SpecStats presence disagrees with is_speculative()");
    }
}

/// Stream buffer shared between a session's sink and the test.
type SharedBuf = Rc<RefCell<Vec<u32>>>;

/// Sink whose contents outlive the scheduler's `Box<dyn TokenSink>`.
#[derive(Clone, Default)]
struct SharedSink(SharedBuf);

impl TokenSink for SharedSink {
    fn on_token(&mut self, token: u32) {
        self.0.borrow_mut().push(token);
    }
}

#[test]
fn scheduled_surface_matches_one_shot_decode_for_every_kind() {
    let Some(dir) = artifacts() else { eprintln!("skipping: no artifacts"); return };
    for kind in EngineKind::ALL {
        let expected = build_engine(kind, &dir, cfg()).unwrap()
            .decode_prompt(PROMPT).unwrap();

        let mut sched = build_scheduled_engine(kind, &dir, cfg()).unwrap();
        assert_eq!(sched.kind(), kind);
        assert_eq!(sched.name(), kind.name());
        let buf = SharedBuf::default();
        let id = sched
            .submit(DecodeRequest::new(PROMPT), Box::new(SharedSink(buf.clone())))
            .unwrap();
        // per-request override rides along as a second session
        let id_short = sched
            .submit(DecodeRequest::new(PROMPT).with_max_new_tokens(6),
                Box::new(pipedec::engine::NullSink))
            .unwrap();
        for _ in 0..100_000 {
            if !sched.has_work() { break }
            sched.step().unwrap();
        }
        assert!(!sched.has_work(), "{kind}: scheduler must go idle");
        let out = sched.poll(id).expect("finished session is pollable");
        assert_eq!(out.tokens, expected.tokens,
            "{kind}: scheduled decode diverged from one-shot decode");
        assert_eq!(*buf.borrow(), out.tokens,
            "{kind}: scheduled stream diverged from final output");
        let short = sched.poll(id_short).expect("override session finishes");
        assert!(short.tokens.len() <= 6,
            "{kind}: scheduled override ignored ({} tokens)", short.tokens.len());
    }
}

#[test]
fn timesteps_and_rounds_split_by_strategy() {
    let Some(dir) = artifacts() else { eprintln!("skipping: no artifacts"); return };
    for (kind, wants_timesteps, wants_rounds) in [
        (EngineKind::PipeDec, true, false),
        (EngineKind::PipeDecDb, true, false),
        (EngineKind::Stpp, false, true),
    ] {
        let out = build_engine(kind, &dir, cfg()).unwrap()
            .decode_prompt(PROMPT).unwrap();
        assert_eq!(out.timesteps() > 0, wants_timesteps,
            "{kind}: timesteps must count pipeline timesteps only");
        assert_eq!(out.rounds() > 0, wants_rounds,
            "{kind}: rounds must count draft-verify rounds only");
    }
}

#[test]
fn per_request_max_new_tokens_override_is_honored_everywhere() {
    let Some(dir) = artifacts() else { eprintln!("skipping: no artifacts"); return };
    for kind in EngineKind::ALL {
        let mut e = build_engine(kind, &dir, cfg()).unwrap();
        let short = e
            .decode(&DecodeRequest::new(PROMPT).with_max_new_tokens(6),
                &mut pipedec::engine::NullSink)
            .unwrap();
        assert!(short.tokens.len() <= 6,
            "{kind}: override ignored ({} tokens)", short.tokens.len());
        // the engine's own config is untouched by the override
        assert_eq!(e.config().max_new_tokens, cfg().max_new_tokens,
            "{kind}: decode mutated the engine config");
        let full = e.decode_prompt(PROMPT).unwrap();
        assert!(full.tokens.len() >= short.tokens.len(),
            "{kind}: default run shorter than overridden run");
    }
}
