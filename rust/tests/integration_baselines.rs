//! Baseline engines over real artifacts: PP losslessness, STPP losslessness
//! + acceptance, SLM sanity, and the cross-engine consistency the paper's
//! comparisons rest on.

use pipedec::baselines::{PpEngine, SlmEngine, StppEngine};
use pipedec::config::{EngineConfig, TreeConfig};
use pipedec::engine::Engine;

fn artifacts() -> Option<std::path::PathBuf> {
    let dir = pipedec::artifacts_dir();
    dir.join("target_config.txt").exists().then_some(dir)
}

const PROMPT: &str = "<math>\nquestion: alice has 4 apples and buys 3 more. how many apples now?\n";

fn golden_target() -> Vec<u32> {
    let text =
        std::fs::read_to_string(artifacts().unwrap().join("golden_target.txt")).unwrap();
    text.lines().nth(1).unwrap().split_whitespace()
        .map(|t| t.parse().unwrap()).collect()
}

fn cfg(stages: usize) -> EngineConfig {
    EngineConfig {
        stages,
        tree: TreeConfig { max_width: 4, max_children: 4, max_depth: 5 },
        max_new_tokens: 20,
        ..EngineConfig::default()
    }
}

#[test]
fn pp_matches_golden_greedy() {
    if artifacts().is_none() { eprintln!("skipping: no artifacts"); return; }
    let mut e = PpEngine::new(&artifacts().unwrap(), cfg(4)).unwrap();
    let r = e.decode_prompt(PROMPT).unwrap();
    let golden = golden_target();
    let n = golden.len().min(r.tokens.len());
    assert_eq!(&r.tokens[..n], &golden[..n]);
    assert!(r.modeled_s > 0.0);
    assert!(r.spec.is_none(), "PP does not speculate");
}

#[test]
fn stpp_is_lossless_and_accepts_multiple_per_round() {
    if artifacts().is_none() { eprintln!("skipping: no artifacts"); return; }
    let mut e = StppEngine::new(&artifacts().unwrap(), cfg(2)).unwrap();
    let r = e.decode_prompt(PROMPT).unwrap();
    let golden = golden_target();
    let n = golden.len().min(r.tokens.len());
    assert_eq!(&r.tokens[..n], &golden[..n], "STPP output diverged");
    assert!(r.accepted_per_round() > 1.0,
        "static tree should accept >1 token/round, got {}", r.accepted_per_round());
}

#[test]
fn slm_decodes_plausibly() {
    if artifacts().is_none() { eprintln!("skipping: no artifacts"); return; }
    let mut e = SlmEngine::new(&artifacts().unwrap(), cfg(1)).unwrap();
    let r = e.decode_prompt(PROMPT).unwrap();
    assert!(r.tokens.len() >= 10);
    assert!(r.text.is_ascii());
}

#[test]
fn pp_stage_count_does_not_change_output() {
    if artifacts().is_none() { eprintln!("skipping: no artifacts"); return; }
    let a = PpEngine::new(&artifacts().unwrap(), cfg(1)).unwrap()
        .decode_prompt(PROMPT).unwrap();
    let b = PpEngine::new(&artifacts().unwrap(), cfg(8)).unwrap()
        .decode_prompt(PROMPT).unwrap();
    assert_eq!(a.tokens, b.tokens);
}
