//! Perf smoke benchmark for the device-resident hot path (ISSUE 2, 7):
//! runs a short fixed-seed PipeDec decode and writes `BENCH_hotpath.json`
//! with per-timestep wall time, modeled parallel latency, and host↔device
//! bytes moved, so the perf trajectory is tracked from this PR onward.
//!
//! Since ISSUE 7 the bench is a CI gate for the KV mirror byte budget: it
//! runs the same decode twice — once with the donated device-side append
//! entry points and once with `PIPEDEC_NO_KV_APPEND=1` (full re-upload
//! baseline) — asserts the token streams are bit-identical, and fails
//! unless the in-place path moves >= 5x fewer KV bytes than the baseline.
//!
//! Without built artifacts the bench still writes a `skipped` marker so the
//! CI artifact step always has a file to collect (and the gate passes
//! trivially — there is nothing to measure).

use pipedec::bench_support::banner;
use pipedec::config::{EngineConfig, TreeConfig};
use pipedec::engine::{build_engine, DecodeRequest, EngineKind, NullSink};
use pipedec::runtime::TransferSnapshot;

const OUT: &str = "BENCH_hotpath.json";
const PROMPT: &str =
    "<math>\nquestion: alice has 4 apples and buys 3 more. how many apples now?\n";
const SEED: u64 = 7;
const MAX_NEW: usize = 16;

/// The KV byte-budget gate: the donated in-place path must beat the full
/// re-upload baseline by at least this factor on steady-state KV bytes.
const KV_GATE: f64 = 5.0;

fn write_out(json: String) {
    println!("{json}");
    if let Err(e) = std::fs::write(OUT, json) {
        eprintln!("warning: could not write {OUT}: {e}");
    } else {
        println!("[json] {OUT}");
    }
}

/// Warmup + measured decode of the fixed-seed request; returns the
/// measured output.
fn run_decode(dir: &std::path::Path) -> pipedec::engine::DecodeOutput {
    let cfg = EngineConfig {
        stages: 2,
        tree: TreeConfig { max_width: 4, max_children: 4, max_depth: 8 },
        max_new_tokens: MAX_NEW,
        seed: SEED,
        ..EngineConfig::default()
    };
    let mut engine = build_engine(EngineKind::PipeDec, dir, cfg).unwrap();
    let req = DecodeRequest::new(PROMPT).with_seed(SEED);
    // one warmup decode (compilation caches, allocator), one measured
    engine.decode(&req, &mut NullSink).unwrap();
    engine.decode(&req, &mut NullSink).unwrap()
}

fn main() {
    banner("bench_hotpath", "device-resident hot path: fixed-seed PipeDec decode");

    let dir = pipedec::artifacts_dir();
    if !dir.join("target_config.txt").exists() {
        write_out(
            "{\n  \"bench\": \"hotpath\",\n  \"skipped\": true,\n  \
             \"reason\": \"no artifacts\"\n}\n"
                .to_string(),
        );
        return;
    }

    // measured run: donated device-side KV append entry points active
    let out = run_decode(&dir);
    // baseline run: force the mirror onto the full re-upload fallback
    std::env::set_var("PIPEDEC_NO_KV_APPEND", "1");
    let base = run_decode(&dir);
    std::env::remove_var("PIPEDEC_NO_KV_APPEND");

    // the optimization must be invisible in the output stream
    assert_eq!(
        out.tokens, base.tokens,
        "in-place KV append changed the decoded token stream"
    );

    let m = &out.metrics;
    let timesteps = m.counter("timesteps").max(1);
    // one definition of moved/unoptimized/reduction: the library's snapshot
    let hd = TransferSnapshot {
        up: m.counter("hd_up_bytes"),
        down: m.counter("hd_down_bytes"),
        saved: m.counter("hd_saved_bytes"),
        saved_kv: m.counter("hd_saved_kv_bytes"),
        kv_appended: m.counter("hd_kv_app_bytes"),
        kv_reuploaded: m.counter("hd_kv_reup_bytes"),
    };
    let (up, down, saved, saved_kv) = (hd.up, hd.down, hd.saved, hd.saved_kv);
    let per_ts = |v: u64| v as f64 / timesteps as f64;
    let reduction = hd.reduction_factor();

    // steady-state KV byte budget: bytes the mirror moved per measured
    // decode, in-place path vs the re-upload baseline
    let kv_opt = hd.kv_appended + hd.kv_reuploaded;
    let kv_base = base.metrics.counter("hd_kv_app_bytes")
        + base.metrics.counter("hd_kv_reup_bytes");
    let kv_factor = kv_base as f64 / (kv_opt.max(1)) as f64;

    println!("kv byte budget (per measured decode):");
    println!("  path        appended      reuploaded         total");
    println!(
        "  in-place  {:>10}  {:>14}  {:>12}",
        hd.kv_appended, hd.kv_reuploaded, kv_opt
    );
    println!(
        "  baseline  {:>10}  {:>14}  {:>12}",
        base.metrics.counter("hd_kv_app_bytes"),
        base.metrics.counter("hd_kv_reup_bytes"),
        kv_base
    );
    println!("  reduction {kv_factor:>10.1}x  (gate: >= {KV_GATE:.0}x)");

    let json = format!(
        "{{\n  \"bench\": \"hotpath\",\n  \"skipped\": false,\n  \
         \"engine\": \"pipedec\",\n  \"seed\": {SEED},\n  \
         \"max_new_tokens\": {MAX_NEW},\n  \"tokens\": {tokens},\n  \
         \"timesteps\": {timesteps},\n  \"wall_s\": {wall:.6},\n  \
         \"per_timestep_wall_us\": {ts_us:.1},\n  \
         \"modeled_s\": {modeled:.6},\n  \
         \"modeled_s_per_token\": {modeled_tok:.6},\n  \
         \"hd_up_bytes\": {up},\n  \"hd_down_bytes\": {down},\n  \
         \"hd_saved_bytes\": {saved},\n  \"hd_saved_kv_bytes\": {saved_kv},\n  \
         \"hd_kv_app_bytes\": {kv_app},\n  \"hd_kv_reup_bytes\": {kv_reup},\n  \
         \"kv_bytes_baseline\": {kv_base},\n  \
         \"kv_reduction_factor\": {kv_factor:.2},\n  \
         \"hd_moved_bytes_per_timestep\": {moved_ts:.0},\n  \
         \"hd_unoptimized_bytes_per_timestep\": {unopt_ts:.0},\n  \
         \"hd_reduction_factor\": {reduction:.2}\n}}\n",
        tokens = out.tokens.len(),
        wall = out.wall_s,
        ts_us = out.wall_s / timesteps as f64 * 1e6,
        modeled = out.modeled_s,
        modeled_tok = out.modeled_s_per_token(),
        kv_app = hd.kv_appended,
        kv_reup = hd.kv_reuploaded,
        moved_ts = per_ts(hd.moved()),
        unopt_ts = per_ts(hd.unoptimized()),
    );
    write_out(json);

    assert!(
        reduction >= 2.0,
        "device-resident path must cut per-timestep host<->device bytes \
         by >= 2x (got {reduction:.2}x)"
    );
    // the >=2x gate is satisfiable by resident weights alone; gate the KV
    // mirror separately so a broken epoch/dirty path fails the bench
    assert!(
        saved_kv > 0,
        "KV device mirror never served a clean level during decode"
    );
    // ISSUE 7 gate: the donated in-place append path must beat the full
    // re-upload baseline by >= KV_GATE on steady-state KV bytes; a
    // silently-falling-back mirror lands at ~1x and fails here
    assert!(
        kv_factor >= KV_GATE,
        "in-place KV append must move >= {KV_GATE:.0}x fewer KV bytes than \
         the re-upload baseline (got {kv_factor:.2}x: {kv_opt} vs {kv_base})"
    );
}
