//! Perf smoke benchmark for the device-resident hot path (ISSUE 2): runs a
//! short fixed-seed PipeDec decode and writes `BENCH_hotpath.json` with
//! per-timestep wall time, modeled parallel latency, and host↔device bytes
//! moved, so the perf trajectory is tracked from this PR onward (CI uploads
//! the file as a workflow artifact; the step is non-gating).
//!
//! Without built artifacts the bench still writes a `skipped` marker so the
//! CI artifact step always has a file to collect.

use pipedec::bench_support::banner;
use pipedec::config::{EngineConfig, TreeConfig};
use pipedec::engine::{build_engine, DecodeRequest, EngineKind, NullSink};
use pipedec::runtime::TransferSnapshot;

const OUT: &str = "BENCH_hotpath.json";
const PROMPT: &str =
    "<math>\nquestion: alice has 4 apples and buys 3 more. how many apples now?\n";
const SEED: u64 = 7;
const MAX_NEW: usize = 16;

fn write_out(json: String) {
    println!("{json}");
    if let Err(e) = std::fs::write(OUT, json) {
        eprintln!("warning: could not write {OUT}: {e}");
    } else {
        println!("[json] {OUT}");
    }
}

fn main() {
    banner("bench_hotpath", "device-resident hot path: fixed-seed PipeDec decode");

    let dir = pipedec::artifacts_dir();
    if !dir.join("target_config.txt").exists() {
        write_out(
            "{\n  \"bench\": \"hotpath\",\n  \"skipped\": true,\n  \
             \"reason\": \"no artifacts\"\n}\n"
                .to_string(),
        );
        return;
    }

    let cfg = EngineConfig {
        stages: 2,
        tree: TreeConfig { max_width: 4, max_children: 4, max_depth: 8 },
        max_new_tokens: MAX_NEW,
        seed: SEED,
        ..EngineConfig::default()
    };
    let mut engine = build_engine(EngineKind::PipeDec, &dir, cfg).unwrap();
    let req = DecodeRequest::new(PROMPT).with_seed(SEED);

    // one warmup decode (compilation caches, allocator), one measured
    engine.decode(&req, &mut NullSink).unwrap();
    let out = engine.decode(&req, &mut NullSink).unwrap();

    let m = &out.metrics;
    let timesteps = m.counter("timesteps").max(1);
    // one definition of moved/unoptimized/reduction: the library's snapshot
    let hd = TransferSnapshot {
        up: m.counter("hd_up_bytes"),
        down: m.counter("hd_down_bytes"),
        saved: m.counter("hd_saved_bytes"),
        saved_kv: m.counter("hd_saved_kv_bytes"),
    };
    let (up, down, saved, saved_kv) = (hd.up, hd.down, hd.saved, hd.saved_kv);
    let per_ts = |v: u64| v as f64 / timesteps as f64;
    let reduction = hd.reduction_factor();

    let json = format!(
        "{{\n  \"bench\": \"hotpath\",\n  \"skipped\": false,\n  \
         \"engine\": \"pipedec\",\n  \"seed\": {SEED},\n  \
         \"max_new_tokens\": {MAX_NEW},\n  \"tokens\": {tokens},\n  \
         \"timesteps\": {timesteps},\n  \"wall_s\": {wall:.6},\n  \
         \"per_timestep_wall_us\": {ts_us:.1},\n  \
         \"modeled_s\": {modeled:.6},\n  \
         \"modeled_s_per_token\": {modeled_tok:.6},\n  \
         \"hd_up_bytes\": {up},\n  \"hd_down_bytes\": {down},\n  \
         \"hd_saved_bytes\": {saved},\n  \"hd_saved_kv_bytes\": {saved_kv},\n  \
         \"hd_moved_bytes_per_timestep\": {moved_ts:.0},\n  \
         \"hd_unoptimized_bytes_per_timestep\": {unopt_ts:.0},\n  \
         \"hd_reduction_factor\": {reduction:.2}\n}}\n",
        tokens = out.tokens.len(),
        wall = out.wall_s,
        ts_us = out.wall_s / timesteps as f64 * 1e6,
        modeled = out.modeled_s,
        modeled_tok = out.modeled_s_per_token(),
        moved_ts = per_ts(hd.moved()),
        unopt_ts = per_ts(hd.unoptimized()),
    );
    write_out(json);

    assert!(
        reduction >= 2.0,
        "device-resident path must cut per-timestep host<->device bytes \
         by >= 2x (got {reduction:.2}x)"
    );
    // the >=2x gate is satisfiable by resident weights alone; gate the KV
    // mirror separately so a broken epoch/dirty path fails the bench
    assert!(
        saved_kv > 0,
        "KV device mirror never served a clean level during decode"
    );
}
