//! L3 hot-path micro-benchmarks (EXPERIMENTS.md §Perf): dynamic-tree
//! update/prune, bit-mask algebra, scheduler dispatch, literal construction,
//! artifact execution overhead, and the device-resident KV/bias caches
//! (dirty re-upload vs clean reuse, incremental past-bias update).

use pipedec::bench_support::{banner, emit, fmt_s, time_fn};
use pipedec::config::TreeConfig;
use pipedec::metrics::Table;
use pipedec::schedule::CentralScheduler;
use pipedec::tree::PredictionTree;
use pipedec::util::XorShiftRng;

fn grown_tree(width: usize, depth: usize) -> PredictionTree {
    let cfg = TreeConfig { max_width: width, max_children: 8, max_depth: depth + 2 };
    let mut t = PredictionTree::new(cfg, width * depth + 8, 0, 0);
    let mut rng = XorShiftRng::new(1);
    for _ in 0..depth {
        let f = t.frontier().len();
        let cands: Vec<Vec<(u32, f32)>> = (0..f)
            .map(|_| (0..8).map(|j| (rng.below(120) as u32 + 4, 1.0 / (j + 2) as f32)).collect())
            .collect();
        t.expand_layer(&cands);
    }
    t
}

fn main() {
    banner("micro_hotpath", "L3 substrate micro-benchmarks");
    let mut table = Table::new(&["op", "config", "mean", "p99"]);

    // tree expansion at paper-scale widths
    for (w, d) in [(32usize, 14usize), (128, 21)] {
        let s = time_fn(2, 10, || {
            std::hint::black_box(grown_tree(w, d));
        });
        table.row(vec!["tree build".into(), format!("w={w} d={d}"),
            fmt_s(s.mean()), fmt_s(s.percentile(99.0))]);
    }

    // prune on a grown tree
    for (w, d) in [(32usize, 14usize), (128, 21)] {
        let proto = grown_tree(w, d);
        let hit_tok = proto.token(proto.layer_range(1).start);
        let s = time_fn(2, 20, || {
            let mut t = proto.clone();
            std::hint::black_box(t.prune(hit_tok));
        });
        table.row(vec!["tree prune".into(), format!("w={w} d={d}"),
            fmt_s(s.mean()), fmt_s(s.percentile(99.0))]);
    }

    // bias-row construction (per-timestep hot path)
    {
        let t = grown_tree(32, 9);
        let frontier: Vec<usize> = t.frontier().collect();
        let s = time_fn(5, 50, || {
            std::hint::black_box(t.bias_rows(&frontier, 288, -1e9));
        });
        table.row(vec!["bias rows".into(), "w=32 cap=288".into(),
            fmt_s(s.mean()), fmt_s(s.percentile(99.0))]);
    }

    // scheduler dispatch throughput
    {
        let s = time_fn(2, 20, || {
            let mut sch = CentralScheduler::new();
            let mut live = Vec::new();
            for i in 0..200usize {
                sch.submit(i % 16, (i + 1) % 16, 1024, 0);
                for d in sch.tick() { live.push(d.task.id); }
                if live.len() > 4 {
                    let id = live.remove(0);
                    sch.notify_finish(id);
                    for d in sch.tick() { live.push(d.task.id); }
                }
            }
            while let Some(id) = live.pop() {
                sch.notify_finish(id);
                sch.tick();
            }
        });
        table.row(vec!["scheduler".into(), "200 transfers".into(),
            fmt_s(s.mean()), fmt_s(s.percentile(99.0))]);
    }

    // runtime: literal construction + layer execution (needs artifacts)
    let dir = pipedec::artifacts_dir();
    if dir.join("target_config.txt").exists() {
        use pipedec::kvcache::TwoLevelCache;
        use pipedec::model::{bias, ModelHandles};
        use pipedec::runtime::Runtime;
        let rt = Runtime::cpu().unwrap();
        let mut m = ModelHandles::load(&rt, &dir, "target").unwrap();
        let c = m.cfg.clone();
        let cache = TwoLevelCache::new(1, c.n_heads, c.head_dim, c.past_cap, c.tree_cap);
        let hidden = vec![0.1f32; c.width_cap * c.dim];
        let pos = vec![0i32; c.width_cap];
        let pb = bias::past_bias(0, c.width_cap, c.past_cap);
        let tb = bias::pad_tree_bias_rows(Vec::new(), 0, 0, c.width_cap, c.tree_cap);
        let s = time_fn(3, 20, || {
            std::hint::black_box(
                m.layer_forward(&rt, 0, 0, &cache, &hidden, &pos, &pb, &tb).unwrap(),
            );
        });
        table.row(vec!["layer exec".into(), format!("W={} d={}", c.width_cap, c.dim),
            fmt_s(s.mean()), fmt_s(s.percentile(99.0))]);

        // narrow width-bucket variant (§Perf iteration 3)
        let mut m8 = ModelHandles::load_with_width(&rt, &dir, "target", 8).unwrap();
        let c8 = m8.cfg.clone();
        let hidden8 = vec![0.1f32; c8.width_cap * c8.dim];
        let pos8 = vec![0i32; c8.width_cap];
        let pb8 = bias::past_bias(0, c8.width_cap, c8.past_cap);
        let tb8 = bias::pad_tree_bias_rows(Vec::new(), 0, 0, c8.width_cap, c8.tree_cap);
        let s = time_fn(3, 20, || {
            std::hint::black_box(
                m8.layer_forward(&rt, 0, 0, &cache, &hidden8, &pos8, &pb8, &tb8).unwrap(),
            );
        });
        table.row(vec!["layer exec".into(), format!("W={} d={}", c8.width_cap, c8.dim),
            fmt_s(s.mean()), fmt_s(s.percentile(99.0))]);

        let s = time_fn(3, 20, || {
            std::hint::black_box(
                pipedec::runtime::lit_f32(cache.past_k_layer(0),
                    &[c.n_heads, c.past_cap, c.head_dim]).unwrap(),
            );
        });
        table.row(vec!["literal build".into(), "past_k [4,512,32]".into(),
            fmt_s(s.mean()), fmt_s(s.percentile(99.0))]);

        // device KV mirror: dirty re-upload vs clean reuse (§Perf iter 4)
        {
            use pipedec::kvcache::device::DeviceKvCache;
            let mut kv =
                TwoLevelCache::new(1, c.n_heads, c.head_dim, c.past_cap, c.tree_cap);
            let mut dev = DeviceKvCache::new(1);
            let block = vec![0.1f32; c.n_heads * c.head_dim];
            let s = time_fn(3, 20, || {
                // count=0 append: dirties the layer without growing it
                kv.append_tree_block(0, &block, &block, 1, 0).unwrap();
                dev.ensure_tree(&rt, &kv, 0).unwrap();
            });
            table.row(vec!["kv mirror dirty".into(), "tree k+v".into(),
                fmt_s(s.mean()), fmt_s(s.percentile(99.0))]);
            let s = time_fn(3, 50, || {
                dev.ensure_tree(&rt, &kv, 0).unwrap();
            });
            table.row(vec!["kv mirror clean".into(), "tree k+v".into(),
                fmt_s(s.mean()), fmt_s(s.percentile(99.0))]);
        }

        // incremental past-bias maintenance vs full rebuild
        {
            use pipedec::model::bias::{past_bias, PastBiasCache};
            let mut pbc = PastBiasCache::new(c.width_cap, c.past_cap);
            let mut len = 0usize;
            let s = time_fn(5, 100, || {
                len = (len + 1) % (c.past_cap + 1);
                std::hint::black_box(pbc.rows(len));
            });
            table.row(vec!["past bias incr".into(),
                format!("W={} P={}", c.width_cap, c.past_cap),
                fmt_s(s.mean()), fmt_s(s.percentile(99.0))]);
            let s = time_fn(5, 100, || {
                std::hint::black_box(past_bias(c.past_cap / 2, c.width_cap, c.past_cap));
            });
            table.row(vec!["past bias full".into(),
                format!("W={} P={}", c.width_cap, c.past_cap),
                fmt_s(s.mean()), fmt_s(s.percentile(99.0))]);
        }
    }

    emit("micro_hotpath", &table);
}
