//! Template-workload benchmark for the cross-request prefix cache
//! (ISSUE 8): one shared system prompt crossed with N distinct user
//! suffixes, decoded three times — prefix cache off, on, and on with a
//! tiny L1 budget that forces every block through an L2 demote/promote
//! cycle. Writes `BENCH_prefix.json` and gates CI on:
//!
//! * bit-identical greedy token streams across all three runs,
//! * the warm path computing >= 2x fewer prefill tokens than cold,
//! * reported L1/L2 resident bytes never exceeding their budgets.
//!
//! Without built artifacts the bench writes a `skipped` marker so the
//! CI artifact step always has a file to collect.

use pipedec::bench_support::banner;
use pipedec::config::{EngineConfig, PrefixCacheConfig, TreeConfig};
use pipedec::coordinator::PipeDecDbEngine;
use pipedec::engine::{DecodeOutput, DecodeRequest, Engine, NullSink};

const OUT: &str = "BENCH_prefix.json";
const SEED: u64 = 11;
const MAX_NEW: usize = 8;

/// Warm-path gate: requests sharing the template must compute at least
/// this factor fewer prefill tokens than the cache-off baseline.
const PREFIX_GATE: f64 = 2.0;

/// The shared template: long relative to the per-request suffixes, so
/// most of each prompt is cacheable prefix.
const TEMPLATE: &str = "<sys>\nyou are a careful math tutor. show your \
    working, keep answers short, and end with the final number on its \
    own line. never apologise, never repeat the question.\n</sys>\n\
    <math>\nquestion: ";

const SUFFIXES: [&str; 5] = [
    "2 + 3?\n",
    "7 - 4?\n",
    "3 * 3?\n",
    "9 / 3?\n",
    "8 - 6?\n",
];

/// Unrelated prompt decoded once per engine before measuring, so
/// allocator/compilation warmup never lands in the cold TTFT sample.
const WARMUP: &str = "<math>\nquestion: warmup, ignore this one?\n";

fn write_out(json: String) {
    println!("{json}");
    if let Err(e) = std::fs::write(OUT, json) {
        eprintln!("warning: could not write {OUT}: {e}");
    } else {
        println!("[json] {OUT}");
    }
}

struct PhaseOut {
    outs: Vec<DecodeOutput>,
    l1_peak: usize,
    l2_peak: usize,
}

/// Decode the full template workload on a fresh engine with the given
/// prefix-cache config; asserts the tier budgets hold after every
/// request and returns per-request outputs plus peak resident bytes.
fn run_phase(dir: &std::path::Path, label: &str, pcfg: PrefixCacheConfig) -> PhaseOut {
    let (l1_budget, l2_budget, enabled) = (pcfg.l1_bytes, pcfg.l2_bytes, pcfg.enabled);
    let cfg = EngineConfig {
        stages: 2,
        tree: TreeConfig { max_width: 4, max_children: 4, max_depth: 8 },
        max_new_tokens: MAX_NEW,
        seed: SEED,
        prefix_cache: pcfg,
        ..EngineConfig::default()
    };
    let mut engine = PipeDecDbEngine::new(dir, cfg).unwrap();
    assert_eq!(
        engine.prefix_store().is_some(),
        enabled,
        "prefix store presence must follow the config"
    );
    engine
        .decode(&DecodeRequest::new(WARMUP).with_seed(SEED), &mut NullSink)
        .unwrap();
    let (mut outs, mut l1_peak, mut l2_peak) = (Vec::new(), 0usize, 0usize);
    for (i, sfx) in SUFFIXES.iter().enumerate() {
        let prompt = format!("{TEMPLATE}{sfx}");
        let out = engine
            .decode(&DecodeRequest::new(&prompt).with_seed(SEED), &mut NullSink)
            .unwrap();
        if let Some(store) = engine.prefix_store() {
            assert!(
                store.l1_bytes() <= l1_budget,
                "[{label}] req {i}: L1 resident {} bytes over budget {l1_budget}",
                store.l1_bytes()
            );
            assert!(
                store.l2_bytes() <= l2_budget,
                "[{label}] req {i}: L2 resident {} bytes over budget {l2_budget}",
                store.l2_bytes()
            );
            l1_peak = l1_peak.max(store.l1_bytes());
            l2_peak = l2_peak.max(store.l2_bytes());
        }
        outs.push(out);
    }
    PhaseOut { outs, l1_peak, l2_peak }
}

fn l2_dir(tag: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir().join(format!("pipedec_bench_prefix_{tag}"));
    let _ = std::fs::remove_dir_all(&dir); // stale spills would fake warm hits
    dir
}

fn main() {
    banner("bench_prefix", "template workload: shared system prompt x N suffixes");

    let dir = pipedec::artifacts_dir();
    if !dir.join("target_config.txt").exists() {
        write_out(
            "{\n  \"bench\": \"prefix\",\n  \"skipped\": true,\n  \
             \"reason\": \"no artifacts\"\n}\n"
                .to_string(),
        );
        return;
    }

    let l1 = 64usize << 20;
    let l2 = 256usize << 20;
    let off = run_phase(
        &dir,
        "off",
        PrefixCacheConfig { enabled: false, ..PrefixCacheConfig::default() },
    );
    let on_dir = l2_dir("on");
    let on = run_phase(
        &dir,
        "on",
        PrefixCacheConfig {
            enabled: true,
            l1_bytes: l1,
            l2_bytes: l2,
            l2_dir: Some(on_dir.to_string_lossy().into_owned()),
            chunk_tokens: 0,
        },
    );
    // tiny L1: every block demotes to disk after insert and every warm
    // request promotes it back — the full L2 round trip, every time
    let cyc_dir = l2_dir("cycle");
    let cycle = run_phase(
        &dir,
        "cycle",
        PrefixCacheConfig {
            enabled: true,
            l1_bytes: 1024,
            l2_bytes: 1usize << 30,
            l2_dir: Some(cyc_dir.to_string_lossy().into_owned()),
            chunk_tokens: 0,
        },
    );

    // the cache must be invisible in the output stream — enabled,
    // disabled, and through the L2 demote/promote cycle
    for i in 0..SUFFIXES.len() {
        assert_eq!(
            off.outs[i].tokens, on.outs[i].tokens,
            "prefix cache changed the token stream for request {i}"
        );
        assert_eq!(
            off.outs[i].tokens, cycle.outs[i].tokens,
            "L2 demote/promote cycle changed the token stream for request {i}"
        );
    }

    let sum = |p: &PhaseOut, name: &str, from: usize| -> u64 {
        p.outs[from..].iter().map(|o| o.metrics.counter(name)).sum()
    };
    let cold_tokens = on.outs[0].metrics.counter("prefill_tokens");
    let warm_on = sum(&on, "prefill_tokens", 1);
    let warm_off = sum(&off, "prefill_tokens", 1);
    let hit_total = sum(&on, "prefix_hit_tokens", 0);
    let l2_hits_cycle = sum(&cycle, "prefix_l2_hits", 0);
    let evictions_cycle = sum(&cycle, "prefix_evictions", 0);
    let reduction = warm_off as f64 / warm_on.max(1) as f64;

    let n_warm = (SUFFIXES.len() - 1) as f64;
    let warm_mean = |p: &PhaseOut| -> f64 {
        p.outs[1..].iter().map(|o| o.metrics.sample_sum("prefill_s")).sum::<f64>() / n_warm
    };
    let cold_ttft = on.outs[0].metrics.sample_sum("prefill_s");
    let warm_ttft = warm_mean(&on);
    let off_cold_ttft = off.outs[0].metrics.sample_sum("prefill_s");
    let off_warm_ttft = warm_mean(&off);

    println!("template workload ({} requests):", SUFFIXES.len());
    println!("  phase   prefill_tokens(warm)   ttft_s(cold)   ttft_s(warm mean)");
    println!("  off     {warm_off:>20}   {off_cold_ttft:>12.6}   {off_warm_ttft:>17.6}");
    println!("  on      {warm_on:>20}   {cold_ttft:>12.6}   {warm_ttft:>17.6}");
    println!("  reduction {reduction:>10.1}x  (gate: >= {PREFIX_GATE:.0}x)");
    println!("  L2 cycle: {l2_hits_cycle} promoted hits, {evictions_cycle} evictions");

    let json = format!(
        "{{\n  \"bench\": \"prefix\",\n  \"skipped\": false,\n  \
         \"engine\": \"pipedec-db\",\n  \"seed\": {SEED},\n  \
         \"requests\": {req},\n  \"max_new_tokens\": {MAX_NEW},\n  \
         \"cold_prefill_tokens\": {cold_tokens},\n  \
         \"warm_prefill_tokens\": {warm_on},\n  \
         \"warm_prefill_tokens_nocache\": {warm_off},\n  \
         \"prefill_reduction_factor\": {reduction:.2},\n  \
         \"prefix_hit_tokens\": {hit_total},\n  \
         \"cold_ttft_s\": {cold_ttft:.6},\n  \
         \"warm_ttft_s_mean\": {warm_ttft:.6},\n  \
         \"l1_budget_bytes\": {l1},\n  \"l1_peak_bytes\": {l1_peak},\n  \
         \"l2_budget_bytes\": {l2},\n  \"l2_peak_bytes\": {l2_peak},\n  \
         \"cycle_l1_peak_bytes\": {cyc_l1},\n  \
         \"cycle_l2_peak_bytes\": {cyc_l2},\n  \
         \"l2_hits_cycle\": {l2_hits_cycle},\n  \
         \"evictions_cycle\": {evictions_cycle}\n}}\n",
        req = SUFFIXES.len(),
        l1_peak = on.l1_peak,
        l2_peak = on.l2_peak,
        cyc_l1 = cycle.l1_peak,
        cyc_l2 = cycle.l2_peak,
    );
    write_out(json);

    // every warm request must actually hit the shared template prefix
    for (i, o) in on.outs.iter().enumerate().skip(1) {
        assert!(
            o.metrics.counter("prefix_hit_tokens") > 0,
            "warm request {i} missed the shared template prefix"
        );
    }
    assert!(
        reduction >= PREFIX_GATE,
        "warm-path prefill must compute >= {PREFIX_GATE:.0}x fewer prompt \
         tokens than cold (got {reduction:.2}x: {warm_on} vs {warm_off})"
    );
    // the tiny-L1 phase must exercise the disk tier, not degrade to misses
    assert!(
        l2_hits_cycle >= 1,
        "demote/promote phase never promoted a block from L2"
    );

    // kill-switch: the env knob must override an enabled config
    std::env::set_var("PIPEDEC_NO_PREFIX_CACHE", "1");
    let cfg = EngineConfig {
        stages: 2,
        tree: TreeConfig { max_width: 4, max_children: 4, max_depth: 8 },
        max_new_tokens: MAX_NEW,
        seed: SEED,
        ..EngineConfig::default()
    };
    let engine = PipeDecDbEngine::new(&dir, cfg).unwrap();
    std::env::remove_var("PIPEDEC_NO_PREFIX_CACHE");
    assert!(
        engine.prefix_store().is_none(),
        "PIPEDEC_NO_PREFIX_CACHE must disable the store"
    );

    let _ = std::fs::remove_dir_all(&on_dir);
    let _ = std::fs::remove_dir_all(&cyc_dir);
}
