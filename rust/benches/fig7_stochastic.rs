//! Fig. 7 reproduction: greedy vs stochastic decoding (temperature 0.6,
//! top-p 0.9, top-k 80 — §4.3.3) for PipeDec and STPP: latency + accuracy,
//! 5 repeats per input under sampling.

use pipedec::bench_support::{banner, emit};
use pipedec::config::{EngineConfig, TreeConfig};
use pipedec::engine::{build_engine, Engine, EngineKind};
use pipedec::metrics::Table;
use pipedec::workload::Workload;

fn main() {
    banner("fig7_stochastic",
        "greedy vs stochastic decoding: PipeDec-8 vs STPP (paper Fig. 7)");
    let dir = pipedec::artifacts_dir();
    if !dir.join("target_config.txt").exists() {
        eprintln!("artifacts missing — run `make artifacts`"); return;
    }
    let base = EngineConfig {
        stages: 8,
        tree: TreeConfig { max_width: 8, max_children: 8, max_depth: 12 },
        max_new_tokens: 24,
        ..EngineConfig::default()
    };
    let stoch = |seed: u64| EngineConfig {
        temperature: 0.6, top_p: 0.9, top_k: 80, seed, ..base.clone()
    };

    let mut t = Table::new(&["domain", "mode", "pipedec ms/tok", "pipedec acc",
        "stpp ms/tok", "stpp accepted/round"]);
    for wl in Workload::load_all(&dir).unwrap().iter().take(3) {
        let p = &wl.prompts[0];
        // greedy
        let mut pd = build_engine(EngineKind::PipeDec, &dir, base.clone()).unwrap();
        let mut st = build_engine(EngineKind::Stpp, &dir, base.clone()).unwrap();
        let r = pd.decode_prompt(p).unwrap();
        let s = st.decode_prompt(p).unwrap();
        t.row(vec![wl.domain.clone(), "greedy".into(),
            format!("{:.1}", 1e3 * r.modeled_s_per_token()),
            format!("{:.2}", r.accept_rate()),
            format!("{:.1}", 1e3 * s.modeled_s_per_token()),
            format!("{:.2}", s.accepted_per_round())]);
        // stochastic: 5 repeats with distinct per-request seed overrides
        // (one engine pair, re-seeded through DecodeRequest)
        let mut pd = build_engine(EngineKind::PipeDec, &dir, stoch(0)).unwrap();
        let mut st = build_engine(EngineKind::Stpp, &dir, stoch(0)).unwrap();
        let (mut lat, mut acc, mut slat, mut sacc) = (0.0, 0.0, 0.0, 0.0);
        for seed in 0..5u64 {
            let req = pipedec::engine::DecodeRequest::new(p).with_seed(seed);
            let r = pd.decode(&req, &mut pipedec::engine::NullSink).unwrap();
            let s = st.decode(&req, &mut pipedec::engine::NullSink).unwrap();
            lat += r.modeled_s_per_token();
            acc += r.accept_rate();
            slat += s.modeled_s_per_token();
            sacc += s.accepted_per_round();
        }
        t.row(vec![wl.domain.clone(), "stochastic".into(),
            format!("{:.1}", 1e3 * lat / 5.0), format!("{:.2}", acc / 5.0),
            format!("{:.1}", 1e3 * slat / 5.0), format!("{:.2}", sacc / 5.0)]);
    }
    emit("fig7_stochastic", &t);
    println!("expected shape: stochastic adds little latency and slightly \
lowers accuracy; PipeDec stays ahead of STPP (paper Fig. 7)");
}
