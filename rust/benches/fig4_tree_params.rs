//! Fig. 4 reproduction: average latency + prediction accuracy vs tree width
//! {8,16,32,64,128} and max children {2,4,8,16}.
//!
//! Widths within the artifact cap (<=32) run on the REAL engine and their
//! measured accept rates calibrate the simulator hit model; wider points
//! extrapolate on the paper-scale 14-stage cluster (DESIGN.md).

use pipedec::bench_support::{banner, emit};
use pipedec::config::{EngineConfig, TreeConfig};
use pipedec::engine::{build_engine, Engine, EngineKind};
use pipedec::metrics::Table;
use pipedec::sim::{simulate_pipedec, ClusterSpec, HitModel};
use pipedec::util::XorShiftRng;
use pipedec::workload::Workload;

fn main() {
    banner("fig4_tree_params",
        "latency + accuracy vs tree width / max children (paper Fig. 4)");
    let dir = pipedec::artifacts_dir();
    if !dir.join("target_config.txt").exists() {
        eprintln!("artifacts missing — run `make artifacts`"); return;
    }
    let prompt = Workload::load(&dir, "math").unwrap().prompts[0].clone();
    let cluster = ClusterSpec::paper(14);

    // --- width sweep at c = 8 ---
    let mut wt = Table::new(&["width", "engine accept", "engine ms/tok (modeled)",
        "sim-14 ms/tok", "source"]);
    let mut cal: Option<HitModel> = None;
    for w in [8usize, 16, 32, 64, 128] {
        if w <= 32 {
            let cfg = EngineConfig {
                stages: 8,
                tree: TreeConfig { max_width: w, max_children: 8, max_depth: 12 },
                max_new_tokens: 24,
                ..EngineConfig::default()
            };
            let mut e = build_engine(EngineKind::PipeDec, &dir, cfg).unwrap();
            let r = e.decode_prompt(&prompt).unwrap();
            let hm = HitModel::calibrated(r.accept_rate(), w, 8);
            if w == 32 { cal = Some(hm); }
            let mut rng = XorShiftRng::new(3);
            let sim = simulate_pipedec(&cluster, w, 8, &hm, 256, &mut rng);
            wt.row(vec![w.to_string(), format!("{:.2}", r.accept_rate()),
                format!("{:.1}", 1e3 * r.modeled_s_per_token()),
                format!("{:.1}", 1e3 * sim.s_per_token()), "real+sim".into()]);
        } else {
            let hm = cal.unwrap_or_else(|| HitModel::default_for("math"));
            let mut rng = XorShiftRng::new(3);
            let sim = simulate_pipedec(&cluster, w, 8, &hm, 256, &mut rng);
            wt.row(vec![w.to_string(), "-".into(), "-".into(),
                format!("{:.1}", 1e3 * sim.s_per_token()), "sim".into()]);
        }
    }
    emit("fig4_width", &wt);

    // --- children sweep at w = 8 (real engine) ---
    let mut ct = Table::new(&["children", "accept", "ms/tok (modeled)"]);
    for c in [2usize, 4, 8, 16] {
        let cfg = EngineConfig {
            stages: 8,
            tree: TreeConfig { max_width: 8, max_children: c, max_depth: 12 },
            max_new_tokens: 24,
            ..EngineConfig::default()
        };
        let mut e = build_engine(EngineKind::PipeDec, &dir, cfg).unwrap();
        let r = e.decode_prompt(&prompt).unwrap();
        ct.row(vec![c.to_string(), format!("{:.2}", r.accept_rate()),
            format!("{:.1}", 1e3 * r.modeled_s_per_token())]);
    }
    emit("fig4_children", &ct);
    println!("expected shape: accuracy rises with both axes; latency dips then \
rises with width (verification cost); paper picks w=32, c=16");
}
