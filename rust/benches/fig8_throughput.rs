//! Fig. 8 reproduction: throughput vs concurrency k under tight memory
//! (batch cap 8). Paper-scale model via the simulator; plus a real-engine
//! demonstration that PipeDec serves a queue one request at a time.

use pipedec::bench_support::{banner, emit};
use pipedec::metrics::Table;
use pipedec::sim::{throughput_tokens_per_s, ClusterSpec, HitModel};
use pipedec::util::XorShiftRng;

fn main() {
    banner("fig8_throughput",
        "throughput vs concurrency under 4GB-free memory (paper Fig. 8)");
    let cluster = ClusterSpec::paper(14);
    let hit = HitModel::default_for("math");
    let mut rng = XorShiftRng::new(8);
    let mut t = Table::new(&["k", "pipedec tok/s", "stpp tok/s", "pp tok/s"]);
    for k in [1usize, 2, 4, 8, 16] {
        let pd = throughput_tokens_per_s(&cluster, "pipedec", k, 8, &hit, 32, 16, &mut rng);
        let st = throughput_tokens_per_s(&cluster, "stpp", k, 8, &hit, 32, 16, &mut rng);
        let pp = throughput_tokens_per_s(&cluster, "pp", k, 8, &hit, 32, 16, &mut rng);
        t.row(vec![k.to_string(), format!("{pd:.1}"), format!("{st:.1}"),
            format!("{pp:.1}")]);
    }
    emit("fig8_throughput", &t);
    println!("expected shape: PipeDec flat in k (single-task design), \
comparable to STPP at the memory-capped batch; PP overtakes at high k");
}
