//! Fig. 8 reproduction: throughput vs concurrency k under tight memory
//! (batch cap 8). Paper-scale model via the simulator; a real-engine
//! demonstration that serves a queue through every registered engine via
//! the router (registry-driven, `EngineKind::ALL`); and the SpecPipe-DB
//! head-to-head — `pipedec-db` continuous batching vs one-at-a-time
//! `pipedec` at k ∈ {1, 4, 8} concurrent requests — written to
//! `BENCH_throughput.json` (throughput tok/s over modeled serving time,
//! mean TTFT, mean TBT) and gated on identical greedy outputs plus a
//! strict k=8 throughput win (CI runs this non-gating and uploads the
//! file as an artifact, mirroring `bench_hotpath`).

use std::path::Path;
use std::time::Instant;

use pipedec::bench_support::{banner, emit};
use pipedec::config::{EngineConfig, TreeConfig};
use pipedec::engine::{build_engine, build_scheduled_engine, DecodeRequest, EngineKind};
use pipedec::metrics::Table;
use pipedec::server::{drain, summarize, Router, StreamProbe};
use pipedec::sim::{throughput_tokens_per_s, ClusterSpec, HitModel};
use pipedec::util::XorShiftRng;
use pipedec::workload::mixed_stream;

const OUT: &str = "BENCH_throughput.json";

fn write_out(json: String) {
    println!("{json}");
    if let Err(e) = std::fs::write(OUT, json) {
        eprintln!("warning: could not write {OUT}: {e}");
    } else {
        println!("[json] {OUT}");
    }
}

fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        0.0
    } else {
        xs.iter().sum::<f64>() / xs.len() as f64
    }
}

/// One serving run through the scheduled surface: per-request token
/// sequences (submit order), per-token timings from the server's own
/// [`StreamProbe`] (sinks fire at verification time, so TTFT and TBT are
/// honest for one-shot and continuous engines alike), total modeled
/// serving seconds, and wall seconds.
struct ServeRun {
    tokens: Vec<Vec<u32>>,
    ttft: Vec<f64>,
    tbt: Vec<f64>,
    modeled_s: f64,
    wall_s: f64,
}

impl ServeRun {
    fn total_tokens(&self) -> usize {
        self.tokens.iter().map(|t| t.len()).sum()
    }

    /// The Fig. 8 y-axis: tokens per modeled parallel-schedule second.
    fn throughput_tok_s(&self) -> f64 {
        self.total_tokens() as f64 / self.modeled_s.max(1e-9)
    }
}

fn serve_scheduled(
    kind: EngineKind,
    dir: &Path,
    cfg: &EngineConfig,
    prompts: &[String],
) -> ServeRun {
    let mut sched = build_scheduled_engine(kind, dir, cfg.clone()).unwrap();
    let t0 = Instant::now();
    let mut probes = Vec::new();
    for p in prompts {
        let (sink, probe) = StreamProbe::new();
        sched
            .submit(DecodeRequest::new(p), Box::new(sink))
            .unwrap();
        probes.push(probe);
    }
    let mut modeled = 0.0;
    for _ in 0..1_000_000 {
        if !sched.has_work() {
            break;
        }
        let rep = sched.step().unwrap();
        modeled += rep.modeled_step_s;
    }
    assert!(!sched.has_work(), "{kind}: serving loop did not drain");
    let wall_s = t0.elapsed().as_secs_f64();
    let tokens: Vec<Vec<u32>> = probes.iter().map(|p| p.borrow().stream().to_vec()).collect();
    let ttft: Vec<f64> = probes
        .iter()
        .map(|p| p.borrow().first_token_s().unwrap_or(0.0))
        .collect();
    let tbt: Vec<f64> = probes.iter().map(|p| p.borrow().tbt_s()).collect();
    ServeRun {
        tokens,
        ttft,
        tbt,
        modeled_s: modeled,
        wall_s,
    }
}

fn main() {
    banner("fig8_throughput",
        "throughput vs concurrency under 4GB-free memory (paper Fig. 8)");
    let cluster = ClusterSpec::paper(14);
    let hit = HitModel::default_for("math");
    let mut rng = XorShiftRng::new(8);
    let mut t = Table::new(&["k", "pipedec tok/s", "stpp tok/s", "pp tok/s"]);
    for k in [1usize, 2, 4, 8, 16] {
        let pd = throughput_tokens_per_s(&cluster, EngineKind::PipeDec.name(), k, 8,
            &hit, 32, 16, &mut rng);
        let st = throughput_tokens_per_s(&cluster, EngineKind::Stpp.name(), k, 8,
            &hit, 32, 16, &mut rng);
        let pp = throughput_tokens_per_s(&cluster, EngineKind::Pp.name(), k, 8,
            &hit, 32, 16, &mut rng);
        t.row(vec![k.to_string(), format!("{pd:.1}"), format!("{st:.1}"),
            format!("{pp:.1}")]);
    }
    emit("fig8_throughput", &t);
    println!("expected shape: PipeDec flat in k (single-task design), \
comparable to STPP at the memory-capped batch; PP overtakes at high k — \
SpecPipe-DB (below) is the variant that lifts the flat line");

    // -- real engines: one router queue served by each registry entry --
    let dir = pipedec::artifacts_dir();
    if !dir.join("target_config.txt").exists() {
        eprintln!("artifacts missing — skipping real-engine serving sections");
        write_out(
            "{\n  \"bench\": \"throughput\",\n  \"skipped\": true,\n  \
             \"reason\": \"no artifacts\"\n}\n"
                .to_string(),
        );
        return;
    }
    let cfg = EngineConfig {
        stages: 4,
        tree: TreeConfig { max_width: 8, max_children: 8, max_depth: 12 },
        max_new_tokens: 16,
        ..EngineConfig::default()
    };
    let k = 3usize;
    let prompts = mixed_stream(&dir, 1).unwrap();
    let mut rt = Table::new(&["engine", "requests", "tok/s", "p50 latency s",
        "mean first-token s", "mean tbt s"]);
    for kind in EngineKind::ALL {
        let mut engine = build_engine(kind, &dir, cfg.clone()).unwrap();
        let mut router = Router::new(16);
        for p in prompts.iter().take(k) {
            router.submit_prompt(p).unwrap();
        }
        let t0 = std::time::Instant::now();
        let done = drain(&mut router, engine.as_mut()).unwrap();
        let wall = t0.elapsed().as_secs_f64();
        let (m, lat) = summarize(&done, wall);
        rt.row(vec![
            kind.name().to_string(),
            m.counter("requests").to_string(),
            format!("{:.1}", m.counter("tokens") as f64 / wall.max(1e-9)),
            format!("{:.2}", lat.percentile(50.0)),
            format!("{:.2}", m.summary("first_token_s").mean()),
            format!("{:.3}", m.summary("tbt_s").mean()),
        ]);
    }
    println!("-- real engines: k={k} queued requests per engine (registry) --");
    emit("fig8_real_serving", &rt);

    // -- SpecPipe-DB vs one-at-a-time PipeDec: continuous batching at
    // k ∈ {1, 4, 8} concurrent requests (BENCH_throughput.json) --
    let db_cfg = EngineConfig {
        stages: 4,
        tree: TreeConfig { max_width: 4, max_children: 4, max_depth: 10 },
        max_new_tokens: 12,
        ..EngineConfig::default()
    };
    let pool = mixed_stream(&dir, 2).unwrap();
    let mut db_table = Table::new(&["k", "engine", "tok/s (modeled)",
        "mean TTFT s", "mean TBT s", "tokens"]);
    let mut run_objs: Vec<String> = Vec::new();
    let (mut solo_k8, mut db_k8) = (0.0f64, 0.0f64);
    for k in [1usize, 4, 8] {
        let prompts: Vec<String> =
            (0..k).map(|i| pool[i % pool.len()].clone()).collect();
        let solo = serve_scheduled(EngineKind::PipeDec, &dir, &db_cfg, &prompts);
        let db = serve_scheduled(EngineKind::PipeDecDb, &dir, &db_cfg, &prompts);
        assert_eq!(
            solo.tokens, db.tokens,
            "k={k}: co-scheduled greedy outputs must equal one-at-a-time outputs"
        );
        for (name, run) in [("pipedec", &solo), ("pipedec-db", &db)] {
            db_table.row(vec![
                k.to_string(),
                name.to_string(),
                format!("{:.1}", run.throughput_tok_s()),
                format!("{:.3}", mean(&run.ttft)),
                format!("{:.4}", mean(&run.tbt)),
                run.total_tokens().to_string(),
            ]);
            run_objs.push(format!(
                "{{\"k\": {k}, \"engine\": \"{name}\", \
                 \"throughput_tok_s\": {tput:.3}, \"tokens\": {toks}, \
                 \"modeled_s\": {modeled:.6}, \"wall_s\": {wall:.6}, \
                 \"ttft_mean_s\": {ttft:.6}, \"tbt_mean_s\": {tbt:.6}}}",
                tput = run.throughput_tok_s(),
                toks = run.total_tokens(),
                modeled = run.modeled_s,
                wall = run.wall_s,
                ttft = mean(&run.ttft),
                tbt = mean(&run.tbt),
            ));
        }
        if k == 8 {
            solo_k8 = solo.throughput_tok_s();
            db_k8 = db.throughput_tok_s();
        }
    }
    println!("-- SpecPipe-DB continuous batching vs one-at-a-time PipeDec --");
    emit("fig8_specpipe_db", &db_table);

    let json = format!(
        "{{\n  \"bench\": \"throughput\",\n  \"skipped\": false,\n  \
         \"engines\": [\"pipedec\", \"pipedec-db\"],\n  \
         \"max_new_tokens\": {max_new},\n  \"stages\": {stages},\n  \
         \"runs\": [\n    {runs}\n  ],\n  \
         \"db_speedup_k8\": {speedup:.3}\n}}\n",
        max_new = db_cfg.max_new_tokens,
        stages = db_cfg.stages,
        runs = run_objs.join(",\n    "),
        speedup = db_k8 / solo_k8.max(1e-9),
    );
    write_out(json);

    assert!(
        db_k8 > solo_k8,
        "SpecPipe-DB must beat one-at-a-time PipeDec at k=8 \
         (db {db_k8:.1} tok/s vs solo {solo_k8:.1} tok/s)"
    );
    println!(
        "k=8: pipedec-db {db_k8:.1} tok/s vs pipedec {solo_k8:.1} tok/s \
         ({:.2}x) with identical per-request greedy outputs",
        db_k8 / solo_k8.max(1e-9)
    );
}
