//! Fig. 8 reproduction: throughput vs concurrency k under tight memory
//! (batch cap 8). Paper-scale model via the simulator; plus a real-engine
//! demonstration that serves a queue through every registered engine via
//! the router (registry-driven, `EngineKind::ALL`).

use pipedec::bench_support::{banner, emit};
use pipedec::config::{EngineConfig, TreeConfig};
use pipedec::engine::{build_engine, EngineKind};
use pipedec::metrics::Table;
use pipedec::server::{drain, summarize, Router};
use pipedec::sim::{throughput_tokens_per_s, ClusterSpec, HitModel};
use pipedec::util::XorShiftRng;
use pipedec::workload::mixed_stream;

fn main() {
    banner("fig8_throughput",
        "throughput vs concurrency under 4GB-free memory (paper Fig. 8)");
    let cluster = ClusterSpec::paper(14);
    let hit = HitModel::default_for("math");
    let mut rng = XorShiftRng::new(8);
    let mut t = Table::new(&["k", "pipedec tok/s", "stpp tok/s", "pp tok/s"]);
    for k in [1usize, 2, 4, 8, 16] {
        let pd = throughput_tokens_per_s(&cluster, EngineKind::PipeDec.name(), k, 8,
            &hit, 32, 16, &mut rng);
        let st = throughput_tokens_per_s(&cluster, EngineKind::Stpp.name(), k, 8,
            &hit, 32, 16, &mut rng);
        let pp = throughput_tokens_per_s(&cluster, EngineKind::Pp.name(), k, 8,
            &hit, 32, 16, &mut rng);
        t.row(vec![k.to_string(), format!("{pd:.1}"), format!("{st:.1}"),
            format!("{pp:.1}")]);
    }
    emit("fig8_throughput", &t);
    println!("expected shape: PipeDec flat in k (single-task design), \
comparable to STPP at the memory-capped batch; PP overtakes at high k");

    // -- real engines: one router queue served by each registry entry --
    let dir = pipedec::artifacts_dir();
    if !dir.join("target_config.txt").exists() {
        eprintln!("artifacts missing — skipping real-engine serving section");
        return;
    }
    let cfg = EngineConfig {
        stages: 4,
        tree: TreeConfig { max_width: 8, max_children: 8, max_depth: 12 },
        max_new_tokens: 16,
        ..EngineConfig::default()
    };
    let k = 3usize;
    let prompts = mixed_stream(&dir, 1).unwrap();
    let mut rt = Table::new(&["engine", "requests", "tok/s", "p50 latency s",
        "mean first-token s"]);
    for kind in EngineKind::ALL {
        let mut engine = build_engine(kind, &dir, cfg.clone()).unwrap();
        let mut router = Router::new(16);
        for p in prompts.iter().take(k) {
            router.submit_prompt(p).unwrap();
        }
        let t0 = std::time::Instant::now();
        let done = drain(&mut router, engine.as_mut()).unwrap();
        let wall = t0.elapsed().as_secs_f64();
        let (m, lat) = summarize(&done, wall);
        rt.row(vec![
            kind.name().to_string(),
            m.counter("requests").to_string(),
            format!("{:.1}", m.counter("tokens") as f64 / wall.max(1e-9)),
            format!("{:.2}", lat.percentile(50.0)),
            format!("{:.2}", m.summary("first_token_s").mean()),
        ]);
    }
    println!("-- real engines: k={k} queued requests per engine (registry) --");
    emit("fig8_real_serving", &rt);
}
