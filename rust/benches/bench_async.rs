//! Async-stage perf smoke (ISSUE 4 + ISSUE 5): fixed-seed PipeDec decode
//! at worker thread counts {1, 2, groups+1} × sync modes {serial,
//! overlapped}, writing `BENCH_async.json` with wall-clock vs modeled
//! parallel latency plus the sync-phase breakdown (`t_decide_s`,
//! `t_commit_s`, overlap ratio) per run, so both the wall/modeled
//! convergence and the overlapped-sync win are tracked from this PR
//! onward.
//!
//! ISSUE 10 adds the continuous-speculation occupancy sweep (and
//! promotes the CI step to gating): with the draft artificially slowed
//! (a `draft_job` delay rule on every dispatch), decode at
//! `spec_inflight` ∈ {1, 4} per thread count. Free-running speculation
//! must raise pipeline occupancy strictly above lockstep at every thread
//! count — banked generations are served on timesteps lockstep would
//! spend waiting for the slow draft — while every run, slowed or not,
//! stays token-identical to the reference output (asserted).
//!
//! `threads = 1` is the sequential reference path; `threads = groups + 1`
//! gives every task of a timestep its own worker. `overlap_sync = false`
//! commits caches at the coordinator's sync point (the PR 4 path);
//! `true` defers commits into the owning workers' next jobs. Outputs must
//! be token-identical across *all* runs (asserted — that part is
//! load-bearing), and at `threads = groups + 1` the overlapped decode
//! must not be slower than the serial-sync decode (asserted with a small
//! timer-noise allowance). The
//! wall/modeled ratios are reported, not gated, since small CI hosts may
//! not have the cores to realize the modeled schedule.
//!
//! Without built artifacts the bench still writes a `skipped` marker so
//! the CI artifact step always has a file to collect.

use pipedec::bench_support::banner;
use pipedec::config::{EngineConfig, TreeConfig};
use pipedec::engine::{build_engine, DecodeRequest, EngineKind, NullSink};
use pipedec::faultinject::{self, FaultKind, FaultPlan, FaultRule, Site};

const OUT: &str = "BENCH_async.json";
const PROMPT: &str =
    "<math>\nquestion: alice has 4 apples and buys 3 more. how many apples now?\n";
const SEED: u64 = 7;
const MAX_NEW: usize = 16;
const STAGES: usize = 2; // group_size 1 -> groups = 2, full pool = 3

fn write_out(json: String) {
    println!("{json}");
    if let Err(e) = std::fs::write(OUT, json) {
        eprintln!("warning: could not write {OUT}: {e}");
    } else {
        println!("[json] {OUT}");
    }
}

fn main() {
    banner(
        "bench_async",
        "threaded pipeline workers + overlapped sync: wall vs modeled latency",
    );

    let dir = pipedec::artifacts_dir();
    if !dir.join("target_config.txt").exists() {
        write_out(
            "{\n  \"bench\": \"async\",\n  \"skipped\": true,\n  \
             \"reason\": \"no artifacts\"\n}\n"
                .to_string(),
        );
        return;
    }

    let groups = STAGES; // group_size = 1
    let cores = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1);
    let thread_counts = [1usize, 2, groups + 1];

    let mut runs = Vec::new();
    let mut reference_tokens: Option<Vec<u32>> = None;
    let mut seq_wall = 0.0f64;
    // serial vs overlapped wall at the full pool (the ISSUE 5 gate)
    let mut full_pool_wall = [0.0f64; 2];
    for &threads in &thread_counts {
        for overlap_sync in [false, true] {
            let cfg = EngineConfig {
                stages: STAGES,
                tree: TreeConfig {
                    max_width: 4,
                    max_children: 4,
                    max_depth: 8,
                },
                max_new_tokens: MAX_NEW,
                seed: SEED,
                threads,
                overlap_sync,
                ..EngineConfig::default()
            };
            let mut engine = build_engine(EngineKind::PipeDec, &dir, cfg).unwrap();
            let req = DecodeRequest::new(PROMPT).with_seed(SEED);
            // one warmup decode (compilation caches, allocator, pool
            // spin-up), then best-of-3 measured
            engine.decode(&req, &mut NullSink).unwrap();
            let mut best = None::<pipedec::engine::DecodeOutput>;
            for _ in 0..3 {
                let out = engine.decode(&req, &mut NullSink).unwrap();
                if best.as_ref().map(|b| out.wall_s < b.wall_s).unwrap_or(true) {
                    best = Some(out);
                }
            }
            let out = best.expect("three measured decodes");

            match &reference_tokens {
                None => reference_tokens = Some(out.tokens.clone()),
                Some(reference) => assert_eq!(
                    reference, &out.tokens,
                    "threads={threads} overlap_sync={overlap_sync} diverged \
                     from the reference output"
                ),
            }
            if threads == 1 && !overlap_sync {
                seq_wall = out.wall_s;
            }
            if threads == groups + 1 {
                full_pool_wall[overlap_sync as usize] = out.wall_s;
            }

            let timesteps = out.timesteps().max(1);
            let wall_over_modeled = if out.modeled_s > 0.0 {
                out.wall_s / out.modeled_s
            } else {
                0.0
            };
            let t_decide = out.metrics.sample_sum("t_decide_s");
            let t_commit = out.metrics.sample_sum("t_commit_s");
            let overlap_ratio = out
                .metrics
                .samples("sync_overlap_ratio")
                .first()
                .copied()
                .unwrap_or(0.0);
            println!(
                "threads={threads} overlap={overlap_sync}: wall={:.4}s \
                 modeled={:.4}s wall/modeled={:.2} speedup_vs_seq={:.2} \
                 decide={:.4}s commit={:.4}s overlap_ratio={:.2}",
                out.wall_s,
                out.modeled_s,
                wall_over_modeled,
                if out.wall_s > 0.0 { seq_wall / out.wall_s } else { 0.0 },
                t_decide,
                t_commit,
                overlap_ratio,
            );
            runs.push(format!(
                "    {{\n      \"threads\": {threads},\n      \
                 \"overlap_sync\": {overlap_sync},\n      \
                 \"tokens\": {tokens},\n      \"timesteps\": {timesteps},\n      \
                 \"wall_s\": {wall:.6},\n      \
                 \"per_timestep_wall_us\": {ts_us:.1},\n      \
                 \"modeled_s\": {modeled:.6},\n      \
                 \"wall_over_modeled\": {ratio:.3},\n      \
                 \"speedup_vs_sequential\": {speedup:.3},\n      \
                 \"t_decide_s\": {t_decide:.6},\n      \
                 \"t_commit_s\": {t_commit:.6},\n      \
                 \"sync_overlap_ratio\": {overlap_ratio:.3}\n    }}",
                tokens = out.tokens.len(),
                wall = out.wall_s,
                ts_us = out.wall_s / timesteps as f64 * 1e6,
                modeled = out.modeled_s,
                ratio = wall_over_modeled,
                speedup = if out.wall_s > 0.0 { seq_wall / out.wall_s } else { 0.0 },
            ));
        }
    }

    // ---- ISSUE 10: slowed-draft occupancy sweep ----
    //
    // Delay every draft dispatch by a fixed 10 ms (one rule per hit; the
    // counter resets at each `arm`, so 512 rules cover any decode here).
    // Bank-served timesteps dispatch no draft job and dodge the delay
    // entirely, which is exactly the occupancy win being measured.
    const DRAFT_DELAY_MS: u64 = 10;
    let slow_plan = FaultPlan::new(
        (1u64..=512)
            .map(|hit| FaultRule {
                site: Site::DraftJob,
                hit,
                kind: FaultKind::Delay(DRAFT_DELAY_MS),
            })
            .collect(),
    );
    let spec_levels = [1usize, 4];
    let mut spec_runs = Vec::new();
    // occupancy[thread index][spec level index]
    let mut occupancy = vec![[0.0f64; 2]; thread_counts.len()];
    for (ti, &threads) in thread_counts.iter().enumerate() {
        for (si, &spec_inflight) in spec_levels.iter().enumerate() {
            let cfg = EngineConfig {
                stages: STAGES,
                tree: TreeConfig {
                    max_width: 4,
                    max_children: 4,
                    max_depth: 8,
                },
                max_new_tokens: MAX_NEW,
                seed: SEED,
                threads,
                overlap_sync: true,
                spec_inflight,
                ..EngineConfig::default()
            };
            let mut engine = build_engine(EngineKind::PipeDec, &dir, cfg).unwrap();
            let req = DecodeRequest::new(PROMPT).with_seed(SEED);
            faultinject::arm(slow_plan.clone());
            let out = engine.decode(&req, &mut NullSink).unwrap();
            faultinject::disarm();
            assert_eq!(
                reference_tokens.as_ref().expect("reference decoded"),
                &out.tokens,
                "threads={threads} spec_inflight={spec_inflight}: slowed-draft \
                 speculative decode diverged from the reference output"
            );
            let occ = out.metrics.samples("occupancy").first().copied().unwrap_or(0.0);
            let bubble = out
                .metrics
                .samples("bubble_fraction")
                .first()
                .copied()
                .unwrap_or(0.0);
            let served = out.metrics.counter("spec_expansions_served");
            let stale = out.metrics.counter("stale_expansions_dropped");
            occupancy[ti][si] = occ;
            println!(
                "slowed draft threads={threads} spec_inflight={spec_inflight}: \
                 wall={:.4}s occupancy={occ:.3} bubble={bubble:.3} \
                 served={served} stale={stale}",
                out.wall_s,
            );
            spec_runs.push(format!(
                "    {{\n      \"threads\": {threads},\n      \
                 \"spec_inflight\": {spec_inflight},\n      \
                 \"draft_delay_ms\": {DRAFT_DELAY_MS},\n      \
                 \"tokens\": {tokens},\n      \"wall_s\": {wall:.6},\n      \
                 \"occupancy\": {occ:.4},\n      \
                 \"bubble_fraction\": {bubble:.4},\n      \
                 \"spec_expansions_served\": {served},\n      \
                 \"stale_expansions_dropped\": {stale}\n    }}",
                tokens = out.tokens.len(),
                wall = out.wall_s,
            ));
        }
    }

    let json = format!(
        "{{\n  \"bench\": \"async\",\n  \"skipped\": false,\n  \
         \"engine\": \"pipedec\",\n  \"seed\": {SEED},\n  \
         \"max_new_tokens\": {MAX_NEW},\n  \"stages\": {STAGES},\n  \
         \"groups\": {groups},\n  \"host_cores\": {cores},\n  \
         \"outputs_identical\": true,\n  \"runs\": [\n{}\n  ],\n  \
         \"spec_runs\": [\n{}\n  ]\n}}\n",
        runs.join(",\n"),
        spec_runs.join(",\n"),
    );
    write_out(json);

    // ISSUE 10 acceptance (gating): under the slowed draft, free-running
    // speculation must beat lockstep occupancy at every thread count.
    // The delay dwarfs timer noise (hundreds of ms against a sub-ms
    // simulated forward), so a strict comparison is stable even on
    // shared runners.
    for (ti, &threads) in thread_counts.iter().enumerate() {
        let [lockstep, spec] = occupancy[ti];
        assert!(
            spec > lockstep,
            "threads={threads}: spec_inflight={} occupancy {spec:.4} not above \
             lockstep {lockstep:.4} under a slowed draft",
            spec_levels[1]
        );
        println!(
            "occupancy gate threads={threads}: spec {spec:.4} > lockstep {lockstep:.4}"
        );
    }

    // ISSUE 5 acceptance: with every task on its own worker, deferring
    // cache maintenance off the coordinator must not cost wall time. A 5%
    // allowance absorbs timer noise on shared runners (the step has been
    // gating since ISSUE 10 promoted this bench).
    let (serial, overlapped) = (full_pool_wall[0], full_pool_wall[1]);
    assert!(
        overlapped <= serial * 1.05,
        "overlapped sync ({overlapped:.4}s) slower than serial sync \
         ({serial:.4}s) at threads={}",
        groups + 1
    );
    println!(
        "overlap check at threads={}: overlapped {:.4}s <= serial {:.4}s",
        groups + 1,
        overlapped,
        serial
    );

    if cores >= groups + 1 {
        println!(
            "note: host has {cores} cores — expect wall/modeled to approach 1 \
             at threads={}",
            groups + 1
        );
    } else {
        println!("note: only {cores} cores — threaded numbers are best-effort");
    }
}
