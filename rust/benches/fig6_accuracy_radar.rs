//! Fig. 6 reproduction: predictive accuracy of speculative decoding per
//! domain — PipeDec at several pipeline depths vs the static tree (STPP).
//! (The paper draws this as a radar chart; we emit the same series as rows.)

use pipedec::bench_support::{banner, emit};
use pipedec::config::{EngineConfig, TreeConfig};
use pipedec::engine::{build_engine, Engine, EngineKind};
use pipedec::metrics::Table;
use pipedec::workload::Workload;

fn main() {
    banner("fig6_accuracy_radar",
        "speculation accuracy per domain: PipeDec depths vs STPP (paper Fig. 6)");
    let dir = pipedec::artifacts_dir();
    if !dir.join("target_config.txt").exists() {
        eprintln!("artifacts missing — run `make artifacts`"); return;
    }
    let mk = |stages: usize| EngineConfig {
        stages,
        tree: TreeConfig { max_width: 8, max_children: 8, max_depth: 12 },
        max_new_tokens: 24,
        ..EngineConfig::default()
    };
    let mut pd2 = build_engine(EngineKind::PipeDec, &dir, mk(2)).unwrap();
    let mut pd4 = build_engine(EngineKind::PipeDec, &dir, mk(4)).unwrap();
    let mut pd8 = build_engine(EngineKind::PipeDec, &dir, mk(8)).unwrap();
    let mut stpp = build_engine(EngineKind::Stpp, &dir, mk(4)).unwrap();

    let mut t = Table::new(&["domain", "pipedec-2", "pipedec-4", "pipedec-8",
        "stpp accepted/round", "stpp per-level acc"]);
    for wl in Workload::load_all(&dir).unwrap() {
        let p = &wl.prompts[0];
        let a2 = pd2.decode_prompt(p).unwrap().accept_rate();
        let a4 = pd4.decode_prompt(p).unwrap().accept_rate();
        let a8 = pd8.decode_prompt(p).unwrap().accept_rate();
        let s = stpp.decode_prompt(p).unwrap();
        // STPP per-level acceptance probability from accepted/round m:
        // m = 1 + p + p^2 ... -> rough invert via m/(depth)
        let per_level = ((s.accepted_per_round() - 1.0)
            / (s.accepted_per_round())).clamp(0.0, 1.0);
        t.row(vec![wl.domain.clone(), format!("{a2:.2}"), format!("{a4:.2}"),
            format!("{a8:.2}"), format!("{:.2}", s.accepted_per_round()),
            format!("{per_level:.2}")]);
    }
    emit("fig6_accuracy_radar", &t);
    println!("expected shape: PipeDec accuracy stays high as depth grows and \
exceeds the static tree's per-level acceptance (paper Fig. 6)");
}
