//! Fig. 5 reproduction: single-task decode latency of PipeDec-7/14/21 vs
//! PP, STPP, and SLM across the six workload domains.
//!
//! Real artifact-backed engines run at 8 stages and provide per-domain
//! accept rates; the paper-scale 7/14/21-stage rows come from the simulator
//! calibrated with those measured rates.

use pipedec::baselines::{PpEngine, SlmEngine, StppEngine};
use pipedec::bench_support::{banner, emit};
use pipedec::config::{EngineConfig, TreeConfig};
use pipedec::coordinator::PipeDecEngine;
use pipedec::metrics::Table;
use pipedec::sim::{simulate_pipedec, simulate_pp, simulate_slm, simulate_stpp,
    ClusterSpec, HitModel};
use pipedec::util::XorShiftRng;
use pipedec::workload::Workload;

fn main() {
    banner("fig5_latency", "single-task latency per domain (paper Fig. 5)");
    let dir = pipedec::artifacts_dir();
    if !dir.join("target_config.txt").exists() {
        eprintln!("artifacts missing — run `make artifacts`"); return;
    }
    let cfg = EngineConfig {
        stages: 8,
        tree: TreeConfig { max_width: 8, max_children: 8, max_depth: 12 },
        max_new_tokens: 24,
        ..EngineConfig::default()
    };
    let mut pd = PipeDecEngine::new(&dir, cfg.clone()).unwrap();
    let mut st = StppEngine::new(&dir, cfg.clone()).unwrap();
    let mut pp = PpEngine::new(&dir, cfg.clone()).unwrap();
    let mut slm = SlmEngine::new(&dir, cfg).unwrap();

    let mut real = Table::new(&["domain", "pipedec-8 ms/tok", "stpp ms/tok",
        "pp ms/tok", "slm ms/tok", "accept"]);
    let mut paper = Table::new(&["domain", "pd-7", "pd-14", "pd-21", "stpp",
        "pp", "slm", "x vs pp", "x vs stpp"]);
    let mut rng = XorShiftRng::new(0x55);

    for wl in Workload::load_all(&dir).unwrap() {
        // measured on the real engines (mean over 2 prompts)
        let mut accept = 0.0;
        let (mut a_pd, mut a_st, mut a_pp, mut a_slm) = (0.0, 0.0, 0.0, 0.0);
        let prompts: Vec<&str> = wl.prompts.iter().take(2).map(|s| s.as_str()).collect();
        for p in &prompts {
            let r = pd.decode(p).unwrap();
            accept += r.accept_rate();
            a_pd += r.modeled_s_per_token();
            a_st += st.decode(p).unwrap().modeled_s_per_token();
            a_pp += pp.decode(p).unwrap().modeled_s_per_token();
            a_slm += slm.decode(p).unwrap().modeled_s_per_token();
        }
        let n = prompts.len() as f64;
        accept /= n;
        real.row(vec![wl.domain.clone(),
            format!("{:.1}", 1e3 * a_pd / n), format!("{:.1}", 1e3 * a_st / n),
            format!("{:.1}", 1e3 * a_pp / n), format!("{:.1}", 1e3 * a_slm / n),
            format!("{:.2}", accept)]);

        // paper-scale rows, hit model calibrated from the measured accept
        let hm = HitModel::calibrated(accept, 8, 8);
        let tokens = 512;
        let per = |stages: usize, rng: &mut XorShiftRng| {
            simulate_pipedec(&ClusterSpec::paper(stages), 32, 16, &hm, tokens, rng)
                .s_per_token()
        };
        let p7 = per(7, &mut rng);
        let p14 = per(14, &mut rng);
        let p21 = per(21, &mut rng);
        let c14 = ClusterSpec::paper(14);
        let stp = simulate_stpp(&c14, 16, 4, 4, &hm, tokens, &mut rng).s_per_token();
        let ppt = simulate_pp(&c14, tokens).s_per_token();
        let slt = simulate_slm(tokens).s_per_token();
        paper.row(vec![wl.domain.clone(),
            format!("{:.0}", 1e3 * p7), format!("{:.0}", 1e3 * p14),
            format!("{:.0}", 1e3 * p21), format!("{:.0}", 1e3 * stp),
            format!("{:.0}", 1e3 * ppt), format!("{:.0}", 1e3 * slt),
            format!("{:.2}", ppt / p14), format!("{:.2}", stp / p14)]);
    }
    println!("-- real engines (build-time model, 8 stages) --");
    emit("fig5_real", &real);
    println!("-- paper scale (70B / RTX3090 cluster, simulator; ms/token) --");
    emit("fig5_paper_scale", &paper);
    println!("expected shape: PipeDec-14 4.46-7.79x over PP, 2.2-2.69x over STPP");
}
