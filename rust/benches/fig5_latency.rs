//! Fig. 5 reproduction: single-task decode latency of PipeDec-7/14/21 vs
//! PP, STPP, and SLM across the six workload domains.
//!
//! Real artifact-backed engines run at 8 stages through the `EngineKind`
//! registry and provide per-domain accept rates; the paper-scale
//! 7/14/21-stage rows come from the simulator calibrated with those
//! measured rates.

use pipedec::bench_support::{banner, emit};
use pipedec::config::{EngineConfig, TreeConfig};
use pipedec::engine::{build_engine, DecodeOutput, Engine, EngineKind};
use pipedec::metrics::Table;
use pipedec::sim::{simulate_pipedec, simulate_pp, simulate_slm, simulate_stpp,
    ClusterSpec, HitModel};
use pipedec::util::XorShiftRng;
use pipedec::workload::Workload;

fn main() {
    banner("fig5_latency", "single-task latency per domain (paper Fig. 5)");
    let dir = pipedec::artifacts_dir();
    if !dir.join("target_config.txt").exists() {
        eprintln!("artifacts missing — run `make artifacts`"); return;
    }
    let cfg = EngineConfig {
        stages: 8,
        tree: TreeConfig { max_width: 8, max_children: 8, max_depth: 12 },
        max_new_tokens: 24,
        ..EngineConfig::default()
    };
    // one engine per registry entry, compared like for like
    let mut engines: Vec<Box<dyn Engine>> = EngineKind::ALL
        .iter()
        .map(|&k| build_engine(k, &dir, cfg.clone()).unwrap())
        .collect();

    let mut header: Vec<String> = vec!["domain".into()];
    header.extend(EngineKind::ALL.iter().map(|k| format!("{k} ms/tok")));
    header.push("accept".into());
    let header_refs: Vec<&str> = header.iter().map(|s| s.as_str()).collect();
    let mut real = Table::new(&header_refs);

    let mut paper = Table::new(&["domain", "pd-7", "pd-14", "pd-21", "stpp",
        "pp", "slm", "x vs pp", "x vs stpp"]);
    let mut rng = XorShiftRng::new(0x55);

    for wl in Workload::load_all(&dir).unwrap() {
        // measured on the real engines (mean over 2 prompts)
        let prompts: Vec<&str> = wl.prompts.iter().take(2).map(|s| s.as_str()).collect();
        let n = prompts.len() as f64;
        let pd_idx = EngineKind::ALL
            .iter()
            .position(|&k| k == EngineKind::PipeDec)
            .unwrap();
        let mut accept = 0.0;
        let mut ms_per_kind = vec![0.0f64; EngineKind::ALL.len()];
        for p in &prompts {
            let outs: Vec<DecodeOutput> = engines
                .iter_mut()
                .map(|e| e.decode_prompt(p).unwrap())
                .collect();
            accept += outs[pd_idx].accept_rate();
            for (ms, out) in ms_per_kind.iter_mut().zip(&outs) {
                *ms += out.modeled_s_per_token();
            }
        }
        accept /= n;
        let mut row = vec![wl.domain.clone()];
        row.extend(ms_per_kind.iter().map(|ms| format!("{:.1}", 1e3 * ms / n)));
        row.push(format!("{accept:.2}"));
        real.row(row);

        // paper-scale rows, hit model calibrated from the measured accept
        let hm = HitModel::calibrated(accept, 8, 8);
        let tokens = 512;
        let per = |stages: usize, rng: &mut XorShiftRng| {
            simulate_pipedec(&ClusterSpec::paper(stages), 32, 16, &hm, tokens, rng)
                .s_per_token()
        };
        let p7 = per(7, &mut rng);
        let p14 = per(14, &mut rng);
        let p21 = per(21, &mut rng);
        let c14 = ClusterSpec::paper(14);
        let stp = simulate_stpp(&c14, 16, 4, 4, &hm, tokens, &mut rng).s_per_token();
        let ppt = simulate_pp(&c14, tokens).s_per_token();
        let slt = simulate_slm(tokens).s_per_token();
        paper.row(vec![wl.domain.clone(),
            format!("{:.0}", 1e3 * p7), format!("{:.0}", 1e3 * p14),
            format!("{:.0}", 1e3 * p21), format!("{:.0}", 1e3 * stp),
            format!("{:.0}", 1e3 * ppt), format!("{:.0}", 1e3 * slt),
            format!("{:.2}", ppt / p14), format!("{:.2}", stp / p14)]);
    }
    println!("-- real engines (build-time model, 8 stages) --");
    emit("fig5_real", &real);
    println!("-- paper scale (70B / RTX3090 cluster, simulator; ms/token) --");
    emit("fig5_paper_scale", &paper);
    println!("expected shape: PipeDec-14 4.46-7.79x over PP, 2.2-2.69x over STPP");
}
