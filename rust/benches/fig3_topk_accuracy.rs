//! Fig. 3 reproduction: draft top-k agreement with the target's greedy
//! output, for long vs short contexts — the "scale effect" motivating the
//! dynamic tree (§3.3). Measured on the real artifact-backed models.

use pipedec::bench_support::{banner, emit};
use pipedec::kvcache::TwoLevelCache;
use pipedec::metrics::Table;
use pipedec::model::{bias, ModelHandles};
use pipedec::runtime::Runtime;
use pipedec::util::top_k_indices;
use pipedec::workload::Workload;

/// Greedy-decode `steps` tokens with the target while recording, at each
/// step, whether the draft's top-k contains the target's choice.
fn agreement(rt: &Runtime, target: &mut ModelHandles, draft: &mut ModelHandles,
             prompt: &str, steps: usize, ks: &[usize]) -> Vec<f64> {
    let tc = target.cfg.clone();
    let dc = draft.cfg.clone();
    let mut tcache = TwoLevelCache::new(tc.n_layers, tc.n_heads, tc.head_dim,
        tc.past_cap, tc.tree_cap);
    let mut dcache = TwoLevelCache::new(dc.n_layers, dc.n_heads, dc.head_dim,
        dc.past_cap, dc.tree_cap);
    let ids = pipedec::tokenizer::encode(prompt);
    let tl = target.full_prefill(rt, &mut tcache, &ids).unwrap();
    let dl = draft.full_prefill(rt, &mut dcache, &ids).unwrap();
    let mut hits = vec![0usize; ks.len()];
    let mut t_next = top_k_indices(&tl, 1)[0] as u32;
    let mut d_logits = dl;
    for _ in 0..steps {
        // draft ranks candidates for the SAME context prefix
        let d_rank = top_k_indices(&d_logits, *ks.last().unwrap());
        for (i, &k) in ks.iter().enumerate() {
            if d_rank[..k.min(d_rank.len())].contains(&(t_next as usize)) {
                hits[i] += 1;
            }
        }
        // advance both models by the target's token
        let step = |m: &mut ModelHandles, cache: &mut TwoLevelCache, tok: u32| {
            let c = m.cfg.clone();
            let mut pos = vec![0i32; c.width_cap];
            pos[0] = cache.past_len() as i32;
            let tb = bias::pad_tree_bias_rows(Vec::new(), 0, 0, c.width_cap, c.tree_cap);
            let lg = m.full_forward_tree_block(rt, cache, &[tok], &pos, &tb).unwrap();
            cache.promote_root_to_past().unwrap();
            cache.compact_tree(&[]);
            lg[..c.vocab_size].to_vec()
        };
        let t_logits = step(target, &mut tcache, t_next);
        d_logits = step(draft, &mut dcache, t_next);
        t_next = top_k_indices(&t_logits, 1)[0] as u32;
    }
    hits.iter().map(|&h| h as f64 / steps as f64).collect()
}

fn main() {
    banner("fig3_topk_accuracy",
        "draft top-k agreement vs k, short and long context (paper Fig. 3)");
    let dir = pipedec::artifacts_dir();
    if !dir.join("target_config.txt").exists() {
        eprintln!("artifacts missing — run `make artifacts`"); return;
    }
    let rt = Runtime::cpu().unwrap();
    let mut target = ModelHandles::load(&rt, &dir, "target").unwrap();
    let mut draft = ModelHandles::load(&rt, &dir, "draft").unwrap();
    let ks = [1usize, 2, 4, 8, 16];

    let short = Workload::load(&dir, "math").unwrap().prompts[0].clone();
    let long: String = Workload::load_all(&dir).unwrap().iter()
        .flat_map(|w| w.prompts.iter().take(2).cloned()).collect::<Vec<_>>().join("");

    let mut table = Table::new(&["context", "k=1", "k=2", "k=4", "k=8", "k=16"]);
    for (name, prompt, steps) in [("short", short.as_str(), 48), ("long", &long[..long.len().min(400)], 48)] {
        let acc = agreement(&rt, &mut target, &mut draft, prompt, steps, &ks);
        table.row(std::iter::once(name.to_string())
            .chain(acc.iter().map(|a| format!("{a:.3}"))).collect());
    }
    emit("fig3_topk_accuracy", &table);
    println!("expected shape: monotone in k, top-8 close to 1 (paper Fig. 3)");
}
