//! Dynamic prediction tree (paper §3.3).
//!
//! Nodes live in BFS order in flat arrays — the GPU-array layout of the
//! paper, kept verbatim on the host:
//!
//! * `tokens`      — **X**, token id per node;
//! * `prob`        — **P**, probability of the node's token given its parent;
//! * `child_count` — **C**;
//! * `mask`        — **M**, bit-packed ancestor-or-self matrix;
//! * `cum_lp`      — **B** = M·log(P), maintained incrementally (the
//!   matrix-product definition is kept as [`PredictionTree::cum_logprob_via_mask`]
//!   and cross-checked in tests).
//!
//! Layer-by-layer growth ([`PredictionTree::expand_layer`], §3.3.3), pruning
//! on a verified token ([`PredictionTree::prune`], §3.3.4), and re-rooting
//! semantics exactly follow the paper: on a hit, the subtree rooted at the
//! matching depth-1 node survives (column M_h of the mask) and becomes the
//! new tree with the hit node as root; on a miss the tree is reinitialized
//! by the engine.
//!
//! Node identity across prunes: every node gets a monotonically increasing
//! `id`. Data flows in the pipeline reference nodes by id; after a prune,
//! stages translate ids through [`PredictionTree::index_of_id`], dropping
//! rows whose node was pruned away.
//!
//! Stage tasks running on pipeline workers never see the canonical tree:
//! they read a [`TreeSnapshot`] — exactly the arrays the stage pass needs
//! (identity, tokens, depths, the ancestor mask) taken at dispatch time —
//! while the coordinator keeps mutating its copy (draft expansion,
//! pruning). Cheaper to build per timestep than cloning the full tree and
//! a hard guarantee that in-flight compute is isolated from the
//! coordinator's decide phase (ISSUE 5).

pub mod bitmatrix;

pub use bitmatrix::BitMatrix;

use crate::config::TreeConfig;
use crate::util::safe_ln;

/// Candidate children proposed by the draft model for one frontier node:
/// (token, probability), at most `max_children` entries, probabilities from
/// the draft's softmax (need not sum to 1 after truncation).
pub type Candidates = Vec<(u32, f32)>;

/// Outcome of [`PredictionTree::prune`].
#[derive(Debug, Clone, PartialEq)]
pub enum PruneOutcome {
    /// hit_index >= 0: token found in the second layer. `kept_old` holds the
    /// pre-prune BFS indices that survive (== tree-KV-cache slots to keep,
    /// in order; `kept_old[0]` is the new root).
    Hit {
        hit_index: usize,
        kept_old: Vec<usize>,
    },
    /// hit_index == -1: prediction failed, the tree must be reinitialized.
    Miss,
}

#[derive(Debug, Clone)]
pub struct PredictionTree {
    cfg: TreeConfig,
    /// Hard cap on total node count (engine: the artifact TREE_CAP;
    /// simulator: effectively unbounded).
    node_budget: usize,

    ids: Vec<u64>,
    tokens: Vec<u32>,
    prob: Vec<f32>,
    child_count: Vec<u32>,
    parent: Vec<i32>,
    depth: Vec<u32>,
    cum_lp: Vec<f32>,
    mask: BitMatrix,
    /// BFS start index of each layer (layer 0 = root). Last entry < node
    /// count; layer l spans `layer_starts[l] .. layer_starts.get(l+1)`.
    layer_starts: Vec<usize>,

    /// Absolute sequence position of the root token (== number of accepted
    /// tokens in the model-level KV cache when this tree was (re)rooted).
    root_pos: usize,
    next_id: u64,
    /// Bumped on prune/reinit; lets stages detect stale data flows.
    version: u64,
}

impl PredictionTree {
    /// §3.3.2: a single root holding the last decoded token.
    pub fn new(cfg: TreeConfig, node_budget: usize, root_token: u32, root_pos: usize) -> Self {
        let mut t = Self {
            cfg,
            node_budget,
            ids: Vec::new(),
            tokens: Vec::new(),
            prob: Vec::new(),
            child_count: Vec::new(),
            parent: Vec::new(),
            depth: Vec::new(),
            cum_lp: Vec::new(),
            mask: BitMatrix::identity(1),
            layer_starts: vec![0],
            root_pos,
            next_id: 0,
            version: 0,
        };
        t.push_node(root_token, 1.0, -1, 0, 0.0);
        t
    }

    /// A minimal stand-in (one root node, unit budget) left behind while a
    /// real tree is lent to the draft task (moved through the worker job
    /// channel, like [`crate::kvcache::TwoLevelCache::placeholder`]).
    pub fn placeholder() -> Self {
        Self::new(TreeConfig::default(), 1, 0, 0)
    }

    fn push_node(&mut self, token: u32, prob: f32, parent: i32, depth: u32, cum: f32) -> u64 {
        let id = self.next_id;
        self.next_id += 1;
        self.ids.push(id);
        self.tokens.push(token);
        self.prob.push(prob);
        self.child_count.push(0);
        self.parent.push(parent);
        self.depth.push(depth);
        self.cum_lp.push(cum);
        id
    }

    // ------------------------------------------------------------------
    // accessors
    // ------------------------------------------------------------------

    pub fn len(&self) -> usize {
        self.tokens.len()
    }

    pub fn is_empty(&self) -> bool {
        self.tokens.is_empty()
    }

    pub fn version(&self) -> u64 {
        self.version
    }

    pub fn config(&self) -> &TreeConfig {
        &self.cfg
    }

    pub fn depth_count(&self) -> usize {
        self.layer_starts.len()
    }

    pub fn root_pos(&self) -> usize {
        self.root_pos
    }

    pub fn token(&self, i: usize) -> u32 {
        self.tokens[i]
    }

    pub fn id(&self, i: usize) -> u64 {
        self.ids[i]
    }

    pub fn parent_of(&self, i: usize) -> Option<usize> {
        (self.parent[i] >= 0).then(|| self.parent[i] as usize)
    }

    pub fn depth_of(&self, i: usize) -> usize {
        self.depth[i] as usize
    }

    pub fn child_count_of(&self, i: usize) -> usize {
        self.child_count[i] as usize
    }

    pub fn cum_logprob(&self, i: usize) -> f32 {
        self.cum_lp[i]
    }

    pub fn mask(&self) -> &BitMatrix {
        &self.mask
    }

    /// Absolute RoPE position of node i.
    pub fn position_of(&self, i: usize) -> usize {
        self.root_pos + self.depth[i] as usize
    }

    /// BFS index range of layer `l` (0-based depth).
    pub fn layer_range(&self, l: usize) -> std::ops::Range<usize> {
        let start = self.layer_starts[l];
        let end = self
            .layer_starts
            .get(l + 1)
            .copied()
            .unwrap_or(self.tokens.len());
        start..end
    }

    /// Indices of the deepest layer (the expansion frontier).
    pub fn frontier(&self) -> std::ops::Range<usize> {
        self.layer_range(self.depth_count() - 1)
    }

    /// Children of node i (BFS indices).
    pub fn children_of(&self, i: usize) -> Vec<usize> {
        (i + 1..self.len())
            .filter(|&j| self.parent[j] == i as i32)
            .collect()
    }

    /// All tokens, BFS order (X array view).
    pub fn tokens(&self) -> &[u32] {
        &self.tokens
    }

    pub fn index_of_id(&self, id: u64) -> Option<usize> {
        // ids are strictly increasing in BFS order within a tree lifetime
        self.ids.binary_search(&id).ok()
    }

    /// **B** recomputed from the mask (the paper's B = M·log P definition);
    /// used by tests to validate the incremental `cum_lp`.
    pub fn cum_logprob_via_mask(&self) -> Vec<f32> {
        (0..self.len())
            .map(|i| {
                self.mask
                    .row_ones(i)
                    .into_iter()
                    .map(|j| safe_ln(self.prob[j]))
                    .sum()
            })
            .collect()
    }

    // ------------------------------------------------------------------
    // §3.3.3 tree update
    // ------------------------------------------------------------------

    /// Expand the tree by one layer. `candidates[k]` holds the draft model's
    /// top-c (token, prob) proposals for the k-th frontier node. Returns the
    /// BFS indices of the newly added nodes (empty when the width/budget
    /// selection keeps nothing).
    pub fn expand_layer(&mut self, candidates: &[Candidates]) -> Vec<usize> {
        let frontier = self.frontier();
        assert_eq!(
            candidates.len(),
            frontier.len(),
            "one candidate set per frontier node"
        );
        let n_old = self.len();

        // B^(l+1)[i][j] = log Q[i][j] + B[parent_i]  (flattened)
        let mut flat: Vec<(usize, usize, u32, f32, f32)> = Vec::new();
        for (k, cands) in candidates.iter().enumerate() {
            let parent_idx = frontier.start + k;
            assert!(
                cands.len() <= self.cfg.max_children,
                "candidate count exceeds max_children"
            );
            for &(tok, q) in cands {
                let b = safe_ln(q) + self.cum_lp[parent_idx];
                flat.push((parent_idx, flat.len(), tok, q, b));
            }
        }
        if flat.is_empty() {
            return Vec::new();
        }

        // top n^(l+1) = min(w, n_l * c) by cumulative log-probability,
        // additionally clamped by the node budget (engine TREE_CAP).
        let budget_room = self.node_budget.saturating_sub(n_old);
        let n_new = self
            .cfg
            .max_width
            .min(flat.len())
            .min(budget_room);
        if n_new == 0 {
            return Vec::new();
        }
        let scores: Vec<f32> = flat.iter().map(|e| e.4).collect();
        let mut picked = crate::util::top_k_indices(&scores, n_new);
        // Keep BFS order: sort selected entries by flattened (parent, slot)
        // position — the paper's selection-mask application preserves it.
        picked.sort_unstable();

        let mut new_indices = Vec::with_capacity(n_new);
        self.mask = self.mask.grown(n_old + n_new);
        let new_depth = self.depth[n_old - 1] + 1;
        for &f in &picked {
            let (parent_idx, _, tok, q, b) = flat[f];
            let idx = self.len();
            self.push_node(tok, q, parent_idx as i32, new_depth, b);
            self.child_count[parent_idx] += 1;
            self.mask.inherit_row(idx, parent_idx, idx);
            new_indices.push(idx);
        }
        self.layer_starts.push(n_old);
        new_indices
    }

    // ------------------------------------------------------------------
    // §3.3.4 tree pruning
    // ------------------------------------------------------------------

    /// Locate `x` in the second layer (depth-1 nodes). Returns the offset
    /// within the layer, or None (paper hit_index = -1).
    pub fn find_in_second_layer(&self, x: u32) -> Option<usize> {
        if self.depth_count() < 2 {
            return None;
        }
        let r = self.layer_range(1);
        self.tokens[r.clone()].iter().position(|&t| t == x)
    }

    /// Prune after the large model verified token `x` at the root
    /// (§3.3.4): on a hit the subtree rooted at the matching depth-1 node
    /// survives and is re-rooted; on a miss the caller must rebuild via
    /// [`PredictionTree::new`]. Advances `root_pos` on hit.
    pub fn prune(&mut self, x: u32) -> PruneOutcome {
        let Some(offset) = self.find_in_second_layer(x) else {
            self.version += 1;
            return PruneOutcome::Miss;
        };
        let hit = self.layer_range(1).start + offset;

        // M_h = column of the hit node: its subtree, BFS-ordered.
        let kept = self.mask.column_ones(hit);
        debug_assert_eq!(kept[0], hit);

        // old -> new index mapping
        let mut old_to_new = vec![usize::MAX; self.len()];
        for (ni, &oi) in kept.iter().enumerate() {
            old_to_new[oi] = ni;
        }

        let base_lp = self.cum_lp[hit];
        let mut ids = Vec::with_capacity(kept.len());
        let mut tokens = Vec::with_capacity(kept.len());
        let mut prob = Vec::with_capacity(kept.len());
        let mut child_count = Vec::with_capacity(kept.len());
        let mut parent = Vec::with_capacity(kept.len());
        let mut depth = Vec::with_capacity(kept.len());
        let mut cum_lp = Vec::with_capacity(kept.len());
        for &oi in &kept {
            ids.push(self.ids[oi]);
            tokens.push(self.tokens[oi]);
            child_count.push(self.child_count[oi]);
            depth.push(self.depth[oi] - 1);
            if oi == hit {
                prob.push(1.0);
                parent.push(-1);
                cum_lp.push(0.0);
            } else {
                prob.push(self.prob[oi]);
                parent.push(old_to_new[self.parent[oi] as usize] as i32);
                cum_lp.push(self.cum_lp[oi] - base_lp);
            }
        }

        // layer starts shift down one level
        let mut layer_starts = vec![0usize];
        for i in 1..kept.len() {
            if depth[i] != depth[i - 1] {
                layer_starts.push(i);
            }
        }

        self.mask = self.mask.select(&kept);
        self.ids = ids;
        self.tokens = tokens;
        self.prob = prob;
        self.child_count = child_count;
        self.parent = parent;
        self.depth = depth;
        self.cum_lp = cum_lp;
        self.layer_starts = layer_starts;
        self.root_pos += 1;
        self.version += 1;

        PruneOutcome::Hit {
            hit_index: offset,
            kept_old: kept,
        }
    }

    // ------------------------------------------------------------------
    // attention-bias helpers (consumed by the engine / model stages)
    // ------------------------------------------------------------------

    /// Additive ancestor bias rows for the given nodes over `cap` tree-cache
    /// slots (slot == BFS index — stages hold the BFS prefix). Row-major
    /// `[nodes.len() x cap]`.
    pub fn bias_rows(&self, nodes: &[usize], cap: usize, neg: f32) -> Vec<f32> {
        mask_bias_rows(&self.mask, nodes, cap, neg)
    }

    /// Immutable view for stage tasks dispatched this timestep (see the
    /// module docs): copies only what [`TreeSnapshot`] serves.
    pub fn snapshot(&self) -> TreeSnapshot {
        TreeSnapshot {
            ids: self.ids.clone(),
            tokens: self.tokens.clone(),
            depth: self.depth.clone(),
            mask: self.mask.clone(),
            root_pos: self.root_pos,
            version: self.version,
        }
    }

    /// Structural invariants; called by tests and debug assertions.
    pub fn check_invariants(&self) -> Result<(), String> {
        let n = self.len();
        if self.mask.size() != n {
            return Err("mask size mismatch".into());
        }
        if self.parent[0] != -1 || self.depth[0] != 0 {
            return Err("bad root".into());
        }
        let mut child_counts = vec![0u32; n];
        for i in 1..n {
            let p = self.parent[i];
            if p < 0 || p as usize >= i {
                return Err(format!("node {i}: parent {p} not earlier in BFS"));
            }
            if self.depth[i] != self.depth[p as usize] + 1 {
                return Err(format!("node {i}: depth != parent depth + 1"));
            }
            child_counts[p as usize] += 1;
        }
        if child_counts != self.child_count {
            return Err("child_count (C) inconsistent".into());
        }
        for i in 0..n {
            // mask row must equal the ancestor chain
            let mut chain = vec![i];
            let mut cur = i;
            while let Some(p) = self.parent_of(cur) {
                chain.push(p);
                cur = p;
            }
            chain.sort_unstable();
            if self.mask.row_ones(i) != chain {
                return Err(format!("node {i}: mask row != ancestor chain"));
            }
        }
        // BFS layer ordering
        for w in self.depth.windows(2) {
            if w[1] < w[0] {
                return Err("depths not non-decreasing in BFS order".into());
            }
        }
        // incremental B matches M·log P
        let via_mask = self.cum_logprob_via_mask();
        for i in 0..n {
            if (via_mask[i] - self.cum_lp[i]).abs() > 1e-4 {
                return Err(format!(
                    "node {i}: cum_lp {} != M·logP {}",
                    self.cum_lp[i], via_mask[i]
                ));
            }
        }
        Ok(())
    }
}

/// Shared bias-row builder: additive ancestor bias over `cap` tree-cache
/// slots from any ancestor-or-self [`BitMatrix`].
fn mask_bias_rows(mask: &BitMatrix, nodes: &[usize], cap: usize, neg: f32) -> Vec<f32> {
    let mut out = vec![neg; nodes.len() * cap];
    for (r, &i) in nodes.iter().enumerate() {
        for j in mask.row_ones(i) {
            debug_assert!(j < cap, "tree larger than cache cap");
            out[r * cap + j] = 0.0;
        }
    }
    out
}

/// Read-only view of a [`PredictionTree`] for in-flight stage tasks
/// (ISSUE 5): node identity, tokens, depths, and the ancestor mask — the
/// exact surface `coordinator::pipeline::run_stage` reads. Built once per
/// request per timestep and shared behind an `Arc` by every occupied
/// pipeline slot, while the coordinator mutates the canonical tree.
#[derive(Debug, Clone)]
pub struct TreeSnapshot {
    ids: Vec<u64>,
    tokens: Vec<u32>,
    depth: Vec<u32>,
    mask: BitMatrix,
    root_pos: usize,
    version: u64,
}

impl TreeSnapshot {
    pub fn len(&self) -> usize {
        self.tokens.len()
    }

    pub fn is_empty(&self) -> bool {
        self.tokens.is_empty()
    }

    /// Prune/reinit version of the tree this snapshot was taken from.
    pub fn version(&self) -> u64 {
        self.version
    }

    pub fn id(&self, i: usize) -> u64 {
        self.ids[i]
    }

    pub fn token(&self, i: usize) -> u32 {
        self.tokens[i]
    }

    /// Absolute RoPE position of node i.
    pub fn position_of(&self, i: usize) -> usize {
        self.root_pos + self.depth[i] as usize
    }

    /// See [`PredictionTree::index_of_id`].
    pub fn index_of_id(&self, id: u64) -> Option<usize> {
        self.ids.binary_search(&id).ok()
    }

    /// See [`PredictionTree::bias_rows`].
    pub fn bias_rows(&self, nodes: &[usize], cap: usize, neg: f32) -> Vec<f32> {
        mask_bias_rows(&self.mask, nodes, cap, neg)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg(w: usize, c: usize) -> TreeConfig {
        TreeConfig {
            max_width: w,
            max_children: c,
            max_depth: 16,
        }
    }

    fn cands(list: &[(u32, f32)]) -> Candidates {
        list.to_vec()
    }

    #[test]
    fn init_matches_paper() {
        let t = PredictionTree::new(cfg(8, 4), 64, 42, 10);
        assert_eq!(t.len(), 1);
        assert_eq!(t.token(0), 42);
        assert_eq!(t.child_count_of(0), 0);
        assert!((t.cum_logprob(0) - 0.0).abs() < 1e-6);
        assert!(t.mask().get(0, 0));
        assert_eq!(t.position_of(0), 10);
        t.check_invariants().unwrap();
    }

    #[test]
    fn expand_selects_top_width_by_cumulative_prob() {
        let mut t = PredictionTree::new(cfg(2, 4), 64, 0, 0);
        let added = t.expand_layer(&[cands(&[(1, 0.5), (2, 0.3), (3, 0.15), (4, 0.05)])]);
        assert_eq!(added.len(), 2); // width cap 2
        assert_eq!(t.token(added[0]), 1);
        assert_eq!(t.token(added[1]), 2);
        assert_eq!(t.child_count_of(0), 2);
        t.check_invariants().unwrap();
    }

    #[test]
    fn expand_two_layers_cumulative() {
        let mut t = PredictionTree::new(cfg(3, 2), 64, 0, 0);
        t.expand_layer(&[cands(&[(1, 0.9), (2, 0.1)])]);
        // frontier = {1:0.9, 2:0.1}; children proposals
        let added = t.expand_layer(&[
            cands(&[(5, 0.6), (6, 0.4)]), // under 0.9: cum 0.54, 0.36
            cands(&[(7, 0.9), (8, 0.1)]), // under 0.1: cum 0.09, 0.01
        ]);
        assert_eq!(added.len(), 3);
        let toks: Vec<u32> = added.iter().map(|&i| t.token(i)).collect();
        assert_eq!(toks, vec![5, 6, 7]); // 0.54, 0.36, 0.09 win over 0.01
        t.check_invariants().unwrap();
    }

    #[test]
    fn prune_hit_keeps_subtree_and_reroots() {
        let mut t = PredictionTree::new(cfg(4, 2), 64, 0, 5);
        t.expand_layer(&[cands(&[(1, 0.7), (2, 0.3)])]);
        t.expand_layer(&[
            cands(&[(3, 0.5), (4, 0.5)]),
            cands(&[(5, 0.9), (6, 0.1)]),
        ]);
        assert_eq!(t.len(), 7);
        // verified token 2 -> subtree of node "2" (index 2) survives
        let out = t.prune(2);
        match out {
            PruneOutcome::Hit { hit_index, kept_old } => {
                assert_eq!(hit_index, 1);
                assert_eq!(kept_old[0], 2);
            }
            _ => panic!("expected hit"),
        }
        assert_eq!(t.token(0), 2);
        assert_eq!(t.depth_of(0), 0);
        assert!((t.prob[0] - 1.0).abs() < 1e-6);
        assert_eq!(t.root_pos(), 6);
        // surviving children are 5 and 6
        let layer1: Vec<u32> = t.layer_range(1).map(|i| t.token(i)).collect();
        assert_eq!(layer1, vec![5, 6]);
        t.check_invariants().unwrap();
    }

    #[test]
    fn prune_miss_reports() {
        let mut t = PredictionTree::new(cfg(4, 2), 64, 0, 0);
        t.expand_layer(&[cands(&[(1, 0.7), (2, 0.3)])]);
        let v0 = t.version();
        assert_eq!(t.prune(99), PruneOutcome::Miss);
        assert!(t.version() > v0);
    }

    #[test]
    fn prune_on_rootonly_tree_is_miss() {
        let mut t = PredictionTree::new(cfg(4, 2), 64, 0, 0);
        assert_eq!(t.prune(1), PruneOutcome::Miss);
    }

    #[test]
    fn node_budget_clamps_expansion() {
        let mut t = PredictionTree::new(cfg(8, 8), 3, 0, 0);
        let added = t.expand_layer(&[cands(&[(1, 0.4), (2, 0.3), (3, 0.2), (4, 0.1)])]);
        assert_eq!(added.len(), 2); // budget 3 - 1 existing
    }

    #[test]
    fn bias_rows_reflect_ancestry() {
        let mut t = PredictionTree::new(cfg(4, 2), 64, 0, 0);
        let l1 = t.expand_layer(&[cands(&[(1, 0.7), (2, 0.3)])]);
        let rows = t.bias_rows(&l1, 8, -1e9);
        // node 1 (idx 1): ancestors {0, 1}
        assert_eq!(rows[0], 0.0);
        assert_eq!(rows[1], 0.0);
        assert_eq!(rows[2], -1e9);
        // node 2 (idx 2): ancestors {0, 2}
        assert_eq!(rows[8], 0.0);
        assert_eq!(rows[9], -1e9);
        assert_eq!(rows[10], 0.0);
    }

    #[test]
    fn children_of_scans_bfs() {
        let mut t = PredictionTree::new(cfg(4, 2), 64, 0, 0);
        t.expand_layer(&[cands(&[(1, 0.7), (2, 0.3)])]);
        t.expand_layer(&[cands(&[(3, 1.0)]), cands(&[(4, 1.0)])]);
        assert_eq!(t.children_of(0), vec![1, 2]);
        assert_eq!(t.children_of(1), vec![3]);
        assert_eq!(t.children_of(3), Vec::<usize>::new());
    }

    #[test]
    fn ids_survive_prune_and_resolve() {
        let mut t = PredictionTree::new(cfg(4, 2), 64, 0, 0);
        t.expand_layer(&[cands(&[(1, 0.7), (2, 0.3)])]);
        t.expand_layer(&[cands(&[(3, 1.0)]), cands(&[(4, 1.0)])]);
        let id4 = t.id(4); // token 4 under node "2"
        let id3 = t.id(3);
        t.prune(2);
        assert_eq!(t.index_of_id(id4), Some(1));
        assert_eq!(t.index_of_id(id3), None); // pruned away
    }

    /// Property: any sequence of expand/prune operations preserves every
    /// structural invariant (BFS order, mask == ancestor chains, C
    /// consistency, B == M·logP) and cache-compaction prefix ordering.
    #[test]
    fn prop_random_op_sequences_preserve_invariants() {
        crate::proputil::forall(
            "tree-op-sequences",
            40,
            0xBEEF,
            |rng| {
                let w = rng.range(2, 9);
                let c = rng.range(2, 5);
                let ops: Vec<u64> = (0..rng.range(4, 14)).map(|_| rng.next_u64()).collect();
                (w, c, ops)
            },
            |(w, c, ops)| {
                let cfg = TreeConfig {
                    max_width: *w,
                    max_children: *c,
                    max_depth: 32,
                };
                let mut t = PredictionTree::new(cfg, 256, 0, 0);
                let mut rng = crate::util::XorShiftRng::new(ops[0] ^ 0x5EED);
                for &op in ops {
                    if op % 3 != 0 || t.depth_count() < 2 {
                        // expand with random distinct-token candidates
                        let f = t.frontier().len();
                        let cands: Vec<Candidates> = (0..f)
                            .map(|_| {
                                let n = rng.range(1, *c + 1);
                                crate::proputil::gen::distinct_tokens(&mut rng, n, 120)
                                    .into_iter()
                                    .zip(crate::proputil::gen::prob_vec(&mut rng, n))
                                    .collect()
                            })
                            .collect();
                        t.expand_layer(&cands);
                    } else {
                        // prune on either a real second-layer token (hit) or
                        // an unlikely one (miss)
                        let x = if rng.chance(0.7) && t.depth_count() >= 2 {
                            let r = t.layer_range(1);
                            t.token(r.start + rng.below(r.len()))
                        } else {
                            125
                        };
                        match t.prune(x) {
                            PruneOutcome::Hit { kept_old, .. } => {
                                // kept_old ascending & unique (cache prefix
                                // compaction relies on it)
                                if kept_old.windows(2).any(|p| p[0] >= p[1]) {
                                    return Err("kept_old not strictly ascending".into());
                                }
                            }
                            PruneOutcome::Miss => {
                                t = PredictionTree::new(cfg, 256, x, t.root_pos() + 1);
                            }
                        }
                    }
                    t.check_invariants()?;
                }
                Ok(())
            },
        );
    }

    #[test]
    fn snapshot_serves_the_stage_surface_and_outlives_mutation() {
        let mut t = PredictionTree::new(cfg(4, 2), 64, 0, 5);
        t.expand_layer(&[cands(&[(1, 0.7), (2, 0.3)])]);
        t.expand_layer(&[
            cands(&[(3, 0.5), (4, 0.5)]),
            cands(&[(5, 0.9), (6, 0.1)]),
        ]);
        let snap = t.snapshot();
        assert_eq!(snap.len(), t.len());
        assert_eq!(snap.version(), t.version());
        let nodes: Vec<usize> = (0..t.len()).collect();
        assert_eq!(
            snap.bias_rows(&nodes, 16, -1e9),
            t.bias_rows(&nodes, 16, -1e9)
        );
        for i in 0..t.len() {
            assert_eq!(snap.id(i), t.id(i));
            assert_eq!(snap.token(i), t.token(i));
            assert_eq!(snap.position_of(i), t.position_of(i));
            assert_eq!(snap.index_of_id(t.id(i)), Some(i));
        }
        // coordinator mutates its copy; the snapshot keeps the old view
        let id5 = t.id(5); // token 5, child of the hit node "2"
        t.prune(2);
        assert_eq!(snap.len(), 7, "snapshot isolated from the prune");
        assert_eq!(snap.index_of_id(id5), Some(5));
        assert_eq!(t.index_of_id(id5), Some(1), "re-rooted under the hit");
    }

    #[test]
    fn cumulative_matches_mask_product_after_ops() {
        let mut t = PredictionTree::new(cfg(8, 4), 256, 0, 0);
        t.expand_layer(&[cands(&[(1, 0.5), (2, 0.25), (3, 0.25)])]);
        t.expand_layer(&[
            cands(&[(4, 0.5), (5, 0.5)]),
            cands(&[(6, 1.0)]),
            cands(&[(7, 0.8), (8, 0.2)]),
        ]);
        t.prune(1);
        t.expand_layer(&[cands(&[(9, 0.6)]), cands(&[(9, 0.6)])]);
        t.check_invariants().unwrap();
    }
}
