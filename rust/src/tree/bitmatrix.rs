//! Bit-packed square boolean matrix for the tree ancestor mask **M**
//! (paper §3.3.1): `get(i, j)` == "node j is an ancestor-or-self of node i".
//!
//! The paper stores M densely on GPU; here it is u64-packed so the §3.3
//! algebra (B = M·log P, pruning via a column, block-structured growth)
//! runs in a few cache lines even for simulator-scale trees (thousands of
//! nodes).

#[derive(Debug, Clone, PartialEq)]
pub struct BitMatrix {
    n: usize,
    words_per_row: usize,
    bits: Vec<u64>,
}

impl BitMatrix {
    pub fn new(n: usize) -> Self {
        let words_per_row = n.div_ceil(64).max(1);
        Self {
            n,
            words_per_row,
            bits: vec![0; n * words_per_row],
        }
    }

    /// Identity matrix of size n (every node is its own ancestor-or-self).
    pub fn identity(n: usize) -> Self {
        let mut m = Self::new(n);
        for i in 0..n {
            m.set(i, i, true);
        }
        m
    }

    pub fn size(&self) -> usize {
        self.n
    }

    #[inline]
    pub fn get(&self, i: usize, j: usize) -> bool {
        debug_assert!(i < self.n && j < self.n);
        let w = self.bits[i * self.words_per_row + j / 64];
        (w >> (j % 64)) & 1 == 1
    }

    #[inline]
    pub fn set(&mut self, i: usize, j: usize, v: bool) {
        debug_assert!(i < self.n && j < self.n);
        let idx = i * self.words_per_row + j / 64;
        if v {
            self.bits[idx] |= 1 << (j % 64);
        } else {
            self.bits[idx] &= !(1 << (j % 64));
        }
    }

    /// Grow to size `n2 >= n`, preserving contents (new bits zero).
    pub fn grown(&self, n2: usize) -> Self {
        assert!(n2 >= self.n);
        let mut out = Self::new(n2);
        for i in 0..self.n {
            let src = &self.bits[i * self.words_per_row..][..self.words_per_row];
            out.bits[i * out.words_per_row..][..self.words_per_row]
                .copy_from_slice(src);
        }
        out
    }

    /// Append a row that copies row `parent` and sets bit `self_col`
    /// (the §3.3.3 bottom-left "repeat parent rows" + bottom-right identity
    /// blocks, one row at a time). Caller must have grown the matrix so that
    /// row `self_col` exists.
    pub fn inherit_row(&mut self, row: usize, parent: usize, self_col: usize) {
        debug_assert!(row < self.n && parent < row);
        let (dst_start, src_start) =
            (row * self.words_per_row, parent * self.words_per_row);
        for w in 0..self.words_per_row {
            self.bits[dst_start + w] = self.bits[src_start + w];
        }
        self.set(row, self_col, true);
    }

    /// Column j as row indices with the bit set (the subtree of j,
    /// §3.3.4 M_h).
    pub fn column_ones(&self, j: usize) -> Vec<usize> {
        (0..self.n).filter(|&i| self.get(i, j)).collect()
    }

    /// Row i as column indices with the bit set (ancestors-or-self of i).
    pub fn row_ones(&self, i: usize) -> Vec<usize> {
        let mut out = Vec::new();
        let base = i * self.words_per_row;
        for w in 0..self.words_per_row {
            let mut bits = self.bits[base + w];
            while bits != 0 {
                let b = bits.trailing_zeros() as usize;
                out.push(w * 64 + b);
                bits &= bits - 1;
            }
        }
        out
    }

    /// Number of set bits in row i.
    pub fn row_count(&self, i: usize) -> usize {
        let base = i * self.words_per_row;
        self.bits[base..base + self.words_per_row]
            .iter()
            .map(|w| w.count_ones() as usize)
            .sum()
    }

    /// Submatrix selection M_h · M · M_h^T (§3.3.4): keep the given
    /// rows/columns (indices must be sorted ascending).
    pub fn select(&self, keep: &[usize]) -> Self {
        let mut out = Self::new(keep.len());
        for (ni, &oi) in keep.iter().enumerate() {
            for (nj, &oj) in keep.iter().enumerate() {
                if self.get(oi, oj) {
                    out.set(ni, nj, true);
                }
            }
        }
        out
    }

    /// Dense row as additive attention bias (0.0 where set, `neg` elsewhere)
    /// into `out` (len >= cap; columns >= n are masked).
    pub fn bias_row_into(&self, i: usize, neg: f32, out: &mut [f32]) {
        for v in out.iter_mut() {
            *v = neg;
        }
        for j in self.row_ones(i) {
            out[j] = 0.0;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn set_get_roundtrip() {
        let mut m = BitMatrix::new(130); // spans three words per row
        m.set(0, 0, true);
        m.set(129, 128, true);
        m.set(65, 64, true);
        assert!(m.get(0, 0));
        assert!(m.get(129, 128));
        assert!(m.get(65, 64));
        assert!(!m.get(1, 0));
        m.set(65, 64, false);
        assert!(!m.get(65, 64));
    }

    #[test]
    fn inherit_row_copies_and_sets_self() {
        let mut m = BitMatrix::identity(2).grown(3);
        // node 2 is a child of node 1
        m.inherit_row(2, 1, 2);
        assert!(m.get(2, 1));
        assert!(m.get(2, 2));
        assert!(!m.get(2, 0));
    }

    #[test]
    fn column_ones_finds_subtree() {
        // chain 0 -> 1 -> 2, plus sibling 3 under 0
        let mut m = BitMatrix::identity(4);
        m.inherit_row(1, 0, 1);
        m.inherit_row(2, 1, 2);
        m.inherit_row(3, 0, 3);
        assert_eq!(m.column_ones(1), vec![1, 2]);
        assert_eq!(m.column_ones(0), vec![0, 1, 2, 3]);
    }

    #[test]
    fn select_submatrix() {
        let mut m = BitMatrix::identity(3);
        m.inherit_row(1, 0, 1);
        m.inherit_row(2, 1, 2);
        let s = m.select(&[1, 2]);
        assert_eq!(s.size(), 2);
        assert!(s.get(0, 0));
        assert!(s.get(1, 0)); // 2 had 1 as ancestor
        assert!(s.get(1, 1));
        assert!(!s.get(0, 1));
    }

    #[test]
    fn row_ones_and_count() {
        let mut m = BitMatrix::identity(70);
        m.inherit_row(69, 0, 69);
        assert_eq!(m.row_ones(69), vec![0, 69]);
        assert_eq!(m.row_count(69), 2);
    }

    #[test]
    fn bias_row() {
        let mut m = BitMatrix::identity(3);
        m.inherit_row(1, 0, 1);
        let mut out = vec![0.0f32; 5];
        m.bias_row_into(1, -1e9, &mut out);
        assert_eq!(out, vec![0.0, 0.0, -1e9, -1e9, -1e9]);
    }
}
