//! Model execution wrappers: compose the per-entry-point HLO artifacts
//! (embed / layer / head) into stage passes, draft steps, and prefill.
//!
//! Argument order of the `*_layer` artifact (mirrored from
//! `python/compile/aot.py::lower_layer` — do not reorder):
//!
//! ```text
//!   attn_norm, wq, wk, wv, wo, mlp_norm, w_gate, w_up, w_down,
//!   h[W,d], past_k[H,P,hd], past_v, tree_k[H,T,hd], tree_v,
//!   tree_len (i32 scalar), pos[W] i32, past_bias[W,P], tree_bias[W,T]
//! -> (h'[W,d], k_new[H,W,hd], v_new[H,W,hd])
//! ```

pub mod bias;

use std::path::Path;

use anyhow::{Context, Result};

use crate::config::ArtifactConfig;
use crate::kvcache::TwoLevelCache;
use crate::runtime::{lit_f32, lit_i32, scalar_i32, to_vec_f32, ArtifactSet, Runtime};
use crate::weights::WeightMap;

/// Names of the nine per-layer weight tensors, in artifact argument order
/// (== `python/compile/model.py::LAYER_WEIGHT_ORDER`).
pub const LAYER_WEIGHT_ORDER: [&str; 9] = [
    "attn_norm", "wq", "wk", "wv", "wo", "mlp_norm", "w_gate", "w_up", "w_down",
];

/// Output of one layer pass over a node block.
pub struct LayerOut {
    pub hidden: Vec<f32>,
    pub k_new: Vec<f32>,
    pub v_new: Vec<f32>,
}

/// One loaded model (target or draft): artifact executables + weight
/// literals built once at load time.
pub struct ModelHandles {
    /// Effective artifact config: `width_cap` reflects the selected width
    /// bucket, so every shape computation below sizes to the loaded variant.
    pub cfg: ArtifactConfig,
    artifacts: ArtifactSet,
    /// Entry-name suffix of the selected width bucket ("" = full cap,
    /// "_w8" = the narrow variant; EXPERIMENTS.md §Perf iteration 3).
    suffix: String,
    emb_lit: xla::Literal,
    final_norm_lit: xla::Literal,
    layer_lits: Vec<Vec<xla::Literal>>,
}

impl ModelHandles {
    /// Load with the full width cap.
    pub fn load(rt: &Runtime, dir: &Path, name: &str) -> Result<Self> {
        Self::load_with_width(rt, dir, name, usize::MAX)
    }

    /// Load config + weights + artifacts for `{name}` from `dir`, selecting
    /// the narrowest width-bucket artifact variant that fits blocks of
    /// `want_width` rows.
    pub fn load_with_width(
        rt: &Runtime,
        dir: &Path,
        name: &str,
        want_width: usize,
    ) -> Result<Self> {
        let mut cfg = ArtifactConfig::load(&dir.join(format!("{name}_config.txt")))?;
        let narrow = dir.join(format!("{name}_layer_w8.hlo.txt"));
        let suffix = if want_width <= 8 && narrow.exists() {
            cfg.width_cap = 8;
            "_w8".to_string()
        } else {
            String::new()
        };
        let weights = WeightMap::load(&dir.join(format!("weights_{name}.pdw")))?;
        let mut artifacts = ArtifactSet::new(dir, name);
        // eagerly compile the three entry points
        for e in ["embed", "layer", "head"] {
            artifacts.entry(rt, &format!("{e}{suffix}"))?;
        }

        let emb = weights.get("emb")?;
        let emb_lit = lit_f32(&emb.data, &[cfg.vocab_size, cfg.dim])?;
        let fnorm = weights.get("final_norm")?;
        let final_norm_lit = lit_f32(&fnorm.data, &[cfg.dim])?;

        let mut layer_lits = Vec::with_capacity(cfg.n_layers);
        for l in 0..cfg.n_layers {
            let mut lits = Vec::with_capacity(9);
            for w in LAYER_WEIGHT_ORDER {
                let t = weights
                    .get(&format!("layers.{l}.{w}"))
                    .with_context(|| format!("layer {l} weight {w}"))?;
                lits.push(lit_f32(&t.data, &t.dims)?);
            }
            layer_lits.push(lits);
        }
        Ok(Self {
            cfg,
            artifacts,
            suffix,
            emb_lit,
            final_norm_lit,
            layer_lits,
        })
    }

    /// Effective block width of the loaded artifact variant.
    pub fn width(&self) -> usize {
        self.cfg.width_cap
    }

    /// Token ids -> hidden states `[W, d]`. Input is padded to `width_cap`.
    pub fn embed(&mut self, rt: &Runtime, tokens: &[u32]) -> Result<Vec<f32>> {
        let w = self.cfg.width_cap;
        anyhow::ensure!(tokens.len() <= w, "block wider than width_cap");
        let mut padded = vec![0i32; w];
        for (i, &t) in tokens.iter().enumerate() {
            padded[i] = t as i32;
        }
        let toks = lit_i32(&padded, &[w])?;
        let args = [&self.emb_lit, &toks];
        let out = self.artifacts.entry(rt, &format!("embed{}", self.suffix))?.run_refs(&args)?;
        to_vec_f32(&out[0])
    }

    /// One transformer layer over a node block with the two-level cache of
    /// the owning stage. `layer` is the model-wide layer index;
    /// `layer_in_stage` indexes into `cache`.
    #[allow(clippy::too_many_arguments)]
    pub fn layer_forward(
        &mut self,
        rt: &Runtime,
        layer: usize,
        layer_in_stage: usize,
        cache: &TwoLevelCache,
        hidden: &[f32],
        pos: &[i32],
        past_bias: &[f32],
        tree_bias: &[f32],
    ) -> Result<LayerOut> {
        let c = &self.cfg;
        let (w, p, t, nh, hd) = (c.width_cap, c.past_cap, c.tree_cap, c.n_heads, c.head_dim);
        anyhow::ensure!(hidden.len() == w * c.dim, "hidden shape");
        anyhow::ensure!(pos.len() == w, "pos shape");
        anyhow::ensure!(past_bias.len() == w * p, "past_bias shape");
        anyhow::ensure!(tree_bias.len() == w * t, "tree_bias shape");

        // dynamic operands are built per call; weight literals are borrowed
        // (a deep literal clone of ~0.9 MB/layer otherwise dominates the
        // call — EXPERIMENTS.md §Perf)
        let dynamic: Vec<xla::Literal> = vec![
            lit_f32(hidden, &[w, c.dim])?,
            lit_f32(cache.past_k_layer(layer_in_stage), &[nh, p, hd])?,
            lit_f32(cache.past_v_layer(layer_in_stage), &[nh, p, hd])?,
            lit_f32(cache.tree_k_layer(layer_in_stage), &[nh, t, hd])?,
            lit_f32(cache.tree_v_layer(layer_in_stage), &[nh, t, hd])?,
            scalar_i32(cache.tree_len() as i32)?,
            lit_i32(pos, &[w])?,
            lit_f32(past_bias, &[w, p])?,
            lit_f32(tree_bias, &[w, t])?,
        ];
        let mut args: Vec<&xla::Literal> = self.layer_lits[layer].iter().collect();
        args.extend(dynamic.iter());

        let out = self.artifacts.entry(rt, &format!("layer{}", self.suffix))?.run_refs(&args)?;
        anyhow::ensure!(out.len() == 3, "layer artifact returns 3 outputs");
        Ok(LayerOut {
            hidden: to_vec_f32(&out[0])?,
            k_new: to_vec_f32(&out[1])?,
            v_new: to_vec_f32(&out[2])?,
        })
    }

    /// Final norm + tied head: hidden `[W, d]` -> logits `[W, V]`.
    pub fn head(&mut self, rt: &Runtime, hidden: &[f32]) -> Result<Vec<f32>> {
        let c = &self.cfg;
        anyhow::ensure!(hidden.len() == c.width_cap * c.dim, "hidden shape");
        let h = lit_f32(hidden, &[c.width_cap, c.dim])?;
        let args = [&self.final_norm_lit, &self.emb_lit, &h];
        let out = self.artifacts.entry(rt, &format!("head{}", self.suffix))?.run_refs(&args)?;
        to_vec_f32(&out[0])
    }

    /// Run a block through a contiguous span of layers (a pipeline stage),
    /// appending the new tree-level KV of each layer to `cache` and
    /// committing `count` slots. Returns the final hidden states.
    #[allow(clippy::too_many_arguments)]
    pub fn stage_forward(
        &mut self,
        rt: &Runtime,
        layer_range: std::ops::Range<usize>,
        cache: &mut TwoLevelCache,
        mut hidden: Vec<f32>,
        count: usize,
        pos: &[i32],
        past_bias: &[f32],
        tree_bias: &[f32],
    ) -> Result<Vec<f32>> {
        let w = self.cfg.width_cap;
        for (lis, layer) in layer_range.enumerate() {
            let out = self.layer_forward(
                rt, layer, lis, cache, &hidden, pos, past_bias, tree_bias,
            )?;
            cache.append_tree_block(lis, &out.k_new, &out.v_new, w, count)?;
            hidden = out.hidden;
        }
        cache.commit_tree(count);
        Ok(hidden)
    }

    /// Prefill a prompt chunk through a span of layers: the chunk plays the
    /// "predicted" segment with a causal in-block bias (see
    /// `python/compile/model.py` docstring), and the resulting KV is
    /// appended to the **model level** of the cache.
    #[allow(clippy::too_many_arguments)]
    pub fn prefill_chunk(
        &mut self,
        rt: &Runtime,
        layer_range: std::ops::Range<usize>,
        cache: &mut TwoLevelCache,
        mut hidden: Vec<f32>,
        count: usize,
        start_pos: usize,
    ) -> Result<Vec<f32>> {
        let c = &self.cfg;
        let w = c.width_cap;
        let pos: Vec<i32> = (0..w).map(|i| (start_pos + i) as i32).collect();
        let past_bias = bias::past_bias(cache.past_len(), w, c.past_cap);
        // in-block causal bias over the tree segment appended at slot 0
        let tree_bias = bias::causal_block_bias(count, 0, w, c.tree_cap);
        anyhow::ensure!(cache.tree_len() == 0, "prefill requires empty tree level");
        for (lis, layer) in layer_range.enumerate() {
            let out = self.layer_forward(
                rt, layer, lis, cache, &hidden, &pos, &past_bias, &tree_bias,
            )?;
            cache.append_past_block(lis, &out.k_new, &out.v_new, w, count)?;
            hidden = out.hidden;
        }
        cache.commit_past(count);
        Ok(hidden)
    }

    /// Full-model pass over a tree block (used by the draft node and the
    /// SLM baseline): embed + all layers + head. Appends tree-level KV.
    pub fn full_forward_tree_block(
        &mut self,
        rt: &Runtime,
        cache: &mut TwoLevelCache,
        tokens: &[u32],
        pos: &[i32],
        tree_bias: &[f32],
    ) -> Result<Vec<f32>> {
        let hidden = self.embed(rt, tokens)?;
        let past_bias =
            bias::past_bias(cache.past_len(), self.cfg.width_cap, self.cfg.past_cap);
        let n = self.cfg.n_layers;
        let h = self.stage_forward(
            rt,
            0..n,
            cache,
            hidden,
            tokens.len(),
            pos,
            &past_bias,
            tree_bias,
        )?;
        self.head(rt, &h)
    }

    /// Full-model prefill of a whole prompt (draft node / SLM baseline).
    /// Returns the logits row of the last prompt token.
    pub fn full_prefill(
        &mut self,
        rt: &Runtime,
        cache: &mut TwoLevelCache,
        prompt: &[u32],
    ) -> Result<Vec<f32>> {
        let w = self.cfg.width_cap;
        let n = self.cfg.n_layers;
        let mut last_h: Option<Vec<f32>> = None;
        let mut last_count = 0;
        for chunk in prompt.chunks(w) {
            let start = cache.past_len();
            let hidden = self.embed(rt, chunk)?;
            let h = self.prefill_chunk(rt, 0..n, cache, hidden, chunk.len(), start)?;
            last_count = chunk.len();
            last_h = Some(h);
        }
        let h = last_h.context("empty prompt")?;
        let logits = self.head(rt, &h)?;
        let v = self.cfg.vocab_size;
        Ok(logits[(last_count - 1) * v..last_count * v].to_vec())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::top_k_indices;

    fn setup() -> Option<(Runtime, ModelHandles)> {
        let dir = crate::artifacts_dir();
        if !dir.join("draft_config.txt").exists() {
            eprintln!("skipping: no artifacts");
            return None;
        }
        let rt = Runtime::cpu().unwrap();
        let m = ModelHandles::load(&rt, &dir, "draft").unwrap();
        Some((rt, m))
    }

    #[test]
    fn draft_loads_and_embeds() {
        let Some((rt, mut m)) = setup() else { return };
        let h = m.embed(&rt, &crate::tokenizer::encode("hi")).unwrap();
        assert_eq!(h.len(), m.cfg.width_cap * m.cfg.dim);
        assert!(h.iter().all(|x| x.is_finite()));
    }

    #[test]
    fn prefill_then_greedy_continuation_is_plausible() {
        // The draft was trained on the corpus; after prefixing a math-style
        // prompt the greedy next token must be a printable id (not PAD) and
        // logits must be finite.
        let Some((rt, mut m)) = setup() else { return };
        let c = m.cfg.clone();
        let mut cache = TwoLevelCache::new(
            c.n_layers, c.n_heads, c.head_dim, c.past_cap, c.tree_cap,
        );
        let prompt = crate::tokenizer::encode("<math>\nquestion: bob has 3 coins");
        let logits = m.full_prefill(&rt, &mut cache, &prompt).unwrap();
        assert_eq!(logits.len(), c.vocab_size);
        assert!(logits.iter().all(|x| x.is_finite()));
        let top = top_k_indices(&logits, 1)[0];
        assert!(top >= 3, "greedy next token {top} should not be PAD/BOS");
        assert_eq!(cache.past_len(), prompt.len());
    }
}
