//! Model execution wrappers: compose the per-entry-point HLO artifacts
//! (embed / layer / head) into stage passes, draft steps, and prefill.
//!
//! Argument order of the `*_layer` artifact (mirrored from
//! `python/compile/aot.py::lower_layer` — do not reorder):
//!
//! ```text
//!   attn_norm, wq, wk, wv, wo, mlp_norm, w_gate, w_up, w_down,
//!   h[W,d], past_k[H,P,hd], past_v, tree_k[H,T,hd], tree_v,
//!   tree_len (i32 scalar), pos[W] i32, past_bias[W,P], tree_bias[W,T]
//! -> (h'[W,d], k_new[H,W,hd], v_new[H,W,hd])
//! ```
//!
//! # Core / context split (ISSUE 4)
//!
//! Threaded stage execution needs the model state partitioned by mutability:
//!
//! * [`ModelCore`] — the shared, **read-only** model: config, the three
//!   pre-resolved entry-point executables, and the device-resident weight
//!   buffers. Built once at load, then shared behind an `Arc` by every
//!   pipeline worker (`Send + Sync` via the audited PJRT wrappers in
//!   [`crate::runtime`]). All forward methods take `&self`.
//! * [`StageContext`] — the per-stage(-group) **mutable** execution state:
//!   the per-cache [`DeviceKvCache`] mirrors, the incremental
//!   [`bias::PastBiasCache`] with its cached device buffer. Each pipeline
//!   worker task owns exactly one context for the duration of a timestep,
//!   so `run_stage` / `draft_expand` dispatch across threads without
//!   locks.
//! * [`ModelHandles`] — the original single-threaded surface, now a thin
//!   `Arc<ModelCore>` + one `StageContext` pair, kept so the baselines,
//!   benches, and tests that execute sequentially are untouched.
//!
//! # Device-resident hot path (EXPERIMENTS.md §Perf iteration 4)
//!
//! Every artifact call runs through [`crate::runtime::Executable::run_bufs`]
//! with device-resident arguments:
//!
//! * **weights** — the nine per-layer tensors plus `emb` / `final_norm`
//!   are uploaded once at load and never marshalled again;
//! * **KV cache** — each [`TwoLevelCache`] gets a [`DeviceKvCache`] mirror
//!   (keyed by [`TwoLevelCache::id`], owned by the [`StageContext`] that
//!   executes the cache's stage), updated **in place** through the donated
//!   `kv_append`/`kv_promote`/`kv_gather` entry points
//!   ([`crate::kvcache::device::KvOps`], loaded best-effort alongside the
//!   model artifacts): the span runner scatters each layer's new KV block
//!   into the resident tensors right after the host append, and
//!   [`StageContext::apply_commit`] replays sync commits on-device. The
//!   epoch-diff full re-upload survives as the fallback for stale or
//!   shape-mismatched mirrors (and when the kv artifacts are absent or
//!   `PIPEDEC_NO_KV_APPEND` is set);
//! * **past bias** — a grow-only [`bias::PastBiasCache`] row block with a
//!   cached device buffer, re-uploaded only when `past_len` changed;
//! * **hidden states** — inside a stage span the running hidden block is
//!   handed from layer to layer without a host `Vec<f32>` round-trip.
//!   Note the honest limit: the layer artifact returns one *tuple*
//!   (`h'`, `k_new`, `v_new`) and this `xla` wrapper has no buffer-level
//!   tuple split, so the tuple is fetched to a host literal once per
//!   layer regardless (the new KV must reach the host cache anyway);
//!   the handoff re-uploads the fetched `h'` literal directly (`W·d`
//!   bytes, counted by [`TransferStats`]) instead of decoding, padding,
//!   revalidating, and re-encoding it. The `Vec<f32>` conversion happens
//!   once, at the stage boundary where the result crosses the pipeline
//!   link.
//!
//! Per-span dynamics (`pos`, `tree_bias`, `tree_len`) upload once per
//! stage pass instead of once per layer.

pub mod bias;

use std::collections::HashMap;
use std::path::Path;
use std::sync::Arc;

use anyhow::{Context, Result};

use crate::config::ArtifactConfig;
use crate::kvcache::device::{DeviceKvCache, KvOps, PreState};
use crate::kvcache::TwoLevelCache;
use crate::runtime::{to_vec_f32, DeviceBuffer, Executable, Runtime, TransferStats};
use crate::weights::WeightMap;

use self::bias::PastBiasCache;

/// Names of the nine per-layer weight tensors, in artifact argument order
/// (== `python/compile/model.py::LAYER_WEIGHT_ORDER`).
pub const LAYER_WEIGHT_ORDER: [&str; 9] = [
    "attn_norm", "wq", "wk", "wv", "wo", "mlp_norm", "w_gate", "w_up", "w_down",
];

/// Output of one layer pass over a node block.
pub struct LayerOut {
    pub hidden: Vec<f32>,
    pub k_new: Vec<f32>,
    pub v_new: Vec<f32>,
}

/// Execute one `*_layer` call with device-resident arguments. The single
/// place that knows the artifact argument order (9 weights + 9 dynamics,
/// see the module header) and the per-call transfer accounting — both the
/// span runner and [`ModelCore::layer_forward`] go through here.
#[allow(clippy::too_many_arguments)]
fn exec_layer(
    layer_exe: &Executable,
    weight_bufs: &[DeviceBuffer],
    weight_bytes: usize,
    stats: &TransferStats,
    fetch_bytes: usize,
    dynamics: [&DeviceBuffer; 9], // h, past_k, past_v, tree_k, tree_v, tree_len, pos, past_bias, tree_bias
) -> Result<Vec<xla::Literal>> {
    let mut args: Vec<&DeviceBuffer> = weight_bufs.iter().collect();
    args.extend(dynamics);
    stats.add_saved(weight_bytes); // resident weights
    let out = layer_exe.run_bufs(&args)?;
    anyhow::ensure!(out.len() == 3, "layer artifact returns 3 outputs");
    stats.add_down(fetch_bytes);
    Ok(out)
}

/// Per-stage(-group) mutable execution state: the device KV mirrors of the
/// caches this stage executes, plus the incremental past bias and its
/// cached device buffer. One context is owned by exactly one pipeline
/// worker task at a time (lent by move through the job channel), which is
/// what makes concurrent stage execution safe without locking.
pub struct StageContext {
    /// Block width / past capacity of the owning model (bias row shape).
    w: usize,
    p: usize,
    past_bias: PastBiasCache,
    past_bias_buf: Option<(u64, DeviceBuffer)>,
    /// Per-cache KV mirrors, keyed by [`TwoLevelCache::id`]. Lifetime
    /// contract: an entry lives until [`StageContext::release_cache`]
    /// evicts it, so engines with long-lived caches create them once and
    /// `reset()` between requests, while schedulers that mint per-session
    /// caches (SpecPipe-DB) must release each cache's mirror at session
    /// teardown or the device buffers leak for the engine's lifetime.
    dev_kv: HashMap<u64, DeviceKvCache>,
}

impl StageContext {
    pub fn new(width_cap: usize, past_cap: usize) -> Self {
        Self {
            w: width_cap,
            p: past_cap,
            past_bias: PastBiasCache::new(width_cap, past_cap),
            past_bias_buf: None,
            dev_kv: HashMap::new(),
        }
    }

    /// Apply one sync decision to `cache` — the worker-side commit entry
    /// point of the ISSUE 5 decide/commit protocol, called at job start
    /// *before* any forward pass over the cache (or eagerly at the sync
    /// point on the serial path). The host cache is mutated first; then,
    /// if this context holds a device mirror for the cache and `core`
    /// loaded the donated KV entry points, the same promotion/compaction
    /// is replayed **in place** on the resident mirror buffers
    /// ([`DeviceKvCache::apply_commit`]) so the next forward pass serves
    /// them from residency instead of re-uploading the dirtied levels.
    /// Without ops or a mirror, the epoch bump alone routes the next
    /// `ensure_*` through the full re-upload fallback, and the
    /// incremental past bias catches the new `past_len` on its next
    /// `ensure_past_bias` — no explicit invalidation needed. In-order
    /// replay (and therefore never running a context against a stale
    /// tree) is enforced by [`TwoLevelCache::apply_commit`].
    pub fn apply_commit(
        &mut self,
        rt: &Runtime,
        core: &ModelCore,
        cache: &mut TwoLevelCache,
        commit: &crate::kvcache::CacheCommit,
    ) -> Result<()> {
        crate::faultinject::fire(crate::faultinject::Site::ApplyCommit)?;
        let dev = self.dev_kv.get_mut(&cache.id());
        let pre = match (&dev, core.kv_ops()) {
            (Some(_), Some(_)) => Some(PreState::capture(cache)),
            _ => None,
        };
        cache.apply_commit(commit)?;
        if let (Some(dev), Some(ops), Some(pre)) = (dev, core.kv_ops(), pre) {
            dev.apply_commit(rt, ops, cache, commit, &pre)?;
        }
        Ok(())
    }

    /// Evict the device KV mirror of cache `cache_id` (the value of
    /// [`TwoLevelCache::id`]); returns whether a mirror existed. Dropping
    /// the mirror frees its device buffers; the next forward pass over a
    /// cache with that id would transparently rebuild it with one full
    /// upload. Sessions that mint per-request caches (SpecPipe-DB) call
    /// this at teardown.
    pub fn release_cache(&mut self, cache_id: u64) -> bool {
        self.dev_kv.remove(&cache_id).is_some()
    }

    /// Number of live device KV mirrors (leak accounting in tests).
    pub fn mirror_count(&self) -> usize {
        self.dev_kv.len()
    }

    /// Bring the cached `[W, P]` past-bias device buffer up to date with
    /// `past_len` (incremental host update + upload only on change).
    fn ensure_past_bias(&mut self, rt: &Runtime, past_len: usize) -> Result<()> {
        let _ = self.past_bias.rows(past_len);
        let epoch = self.past_bias.epoch();
        match &self.past_bias_buf {
            Some((e, _)) if *e == epoch => rt.stats().add_saved(self.w * self.p * 4),
            _ => {
                let buf = rt.upload_f32(self.past_bias.rows(past_len), &[self.w, self.p])?;
                self.past_bias_buf = Some((epoch, buf));
            }
        }
        Ok(())
    }
}

/// The shared, read-only core of one loaded model (target or draft):
/// effective config, pre-resolved entry-point executables, and the
/// device-resident weight buffers — everything built once at load and only
/// ever *read* afterwards, so pipeline workers share it behind an `Arc`.
/// All mutable execution state lives in [`StageContext`].
pub struct ModelCore {
    /// Effective artifact config: `width_cap` reflects the selected width
    /// bucket, so every shape computation below sizes to the loaded variant.
    pub cfg: ArtifactConfig,
    // Entry points resolved once at load (the old per-call ArtifactSet
    // lookup paid a format! + double HashMap probe per layer call).
    embed_exe: Executable,
    layer_exe: Executable,
    head_exe: Executable,
    // Device-resident weights.
    emb_buf: DeviceBuffer,
    emb_bytes: usize,
    final_norm_buf: DeviceBuffer,
    final_norm_bytes: usize,
    layer_bufs: Vec<Vec<DeviceBuffer>>,
    layer_bytes: Vec<usize>,
    /// Donated device-side KV update entry points; `None` when the kv
    /// artifacts are absent (older artifact sets) or `PIPEDEC_NO_KV_APPEND`
    /// is set (the bench baseline) — the mirror then falls back to full
    /// re-uploads everywhere.
    kv_ops: Option<KvOps>,
}

impl ModelCore {
    /// Load with the full width cap.
    pub fn load(rt: &Runtime, dir: &Path, name: &str) -> Result<Self> {
        Self::load_with_width(rt, dir, name, usize::MAX)
    }

    /// Load config + weights + artifacts for `{name}` from `dir`, selecting
    /// the narrowest width-bucket artifact variant that fits blocks of
    /// `want_width` rows.
    pub fn load_with_width(
        rt: &Runtime,
        dir: &Path,
        name: &str,
        want_width: usize,
    ) -> Result<Self> {
        let mut cfg = ArtifactConfig::load(&dir.join(format!("{name}_config.txt")))?;
        let narrow = dir.join(format!("{name}_layer_w8.hlo.txt"));
        let suffix = if want_width <= 8 && narrow.exists() {
            cfg.width_cap = 8;
            "_w8"
        } else {
            ""
        };
        let weights = WeightMap::load(&dir.join(format!("weights_{name}.pdw")))?;

        let embed_exe =
            rt.load_hlo_text(&dir.join(format!("{name}_embed{suffix}.hlo.txt")))?;
        let layer_exe =
            rt.load_hlo_text(&dir.join(format!("{name}_layer{suffix}.hlo.txt")))?;
        let head_exe = rt.load_hlo_text(&dir.join(format!("{name}_head{suffix}.hlo.txt")))?;

        let emb = weights.get("emb")?;
        let emb_bytes = emb.data.len() * 4;
        let emb_buf = rt.upload_f32(&emb.data, &[cfg.vocab_size, cfg.dim])?;
        let fnorm = weights.get("final_norm")?;
        let final_norm_bytes = fnorm.data.len() * 4;
        let final_norm_buf = rt.upload_f32(&fnorm.data, &[cfg.dim])?;

        let mut layer_bufs = Vec::with_capacity(cfg.n_layers);
        let mut layer_bytes = Vec::with_capacity(cfg.n_layers);
        for l in 0..cfg.n_layers {
            let mut bufs = Vec::with_capacity(9);
            let mut bytes = 0usize;
            for wname in LAYER_WEIGHT_ORDER {
                let t = weights
                    .get(&format!("layers.{l}.{wname}"))
                    .with_context(|| format!("layer {l} weight {wname}"))?;
                bytes += t.data.len() * 4;
                bufs.push(rt.upload_f32(&t.data, &t.dims)?);
            }
            layer_bufs.push(bufs);
            layer_bytes.push(bytes);
        }
        rt.stats().add_resident(
            emb_bytes + final_norm_bytes + layer_bytes.iter().sum::<usize>(),
        );

        // Donated KV update entry points (ISSUE 7): best-effort — all four
        // artifacts present or the mirror keeps the re-upload fallback.
        let kv_paths = [
            dir.join(format!("{name}_kvapp_past{suffix}.hlo.txt")),
            dir.join(format!("{name}_kvapp_tree{suffix}.hlo.txt")),
            dir.join(format!("{name}_kvprom.hlo.txt")),
            dir.join(format!("{name}_kvcompact.hlo.txt")),
        ];
        let kv_ops = if std::env::var_os("PIPEDEC_NO_KV_APPEND").is_some()
            || !kv_paths.iter().all(|p| p.exists())
        {
            None
        } else {
            Some(KvOps {
                app_past: rt.load_hlo_text(&kv_paths[0])?,
                app_tree: rt.load_hlo_text(&kv_paths[1])?,
                promote: rt.load_hlo_text(&kv_paths[2])?,
                compact: rt.load_hlo_text(&kv_paths[3])?,
                heads: cfg.n_heads,
                head_dim: cfg.head_dim,
                past_cap: cfg.past_cap,
                tree_cap: cfg.tree_cap,
                width: cfg.width_cap,
            })
        };

        Ok(Self {
            cfg,
            embed_exe,
            layer_exe,
            head_exe,
            emb_buf,
            emb_bytes,
            final_norm_buf,
            final_norm_bytes,
            layer_bufs,
            layer_bytes,
            kv_ops,
        })
    }

    /// Effective block width of the loaded artifact variant.
    pub fn width(&self) -> usize {
        self.cfg.width_cap
    }

    /// The donated device-side KV update entry points, when loaded.
    pub fn kv_ops(&self) -> Option<&KvOps> {
        self.kv_ops.as_ref()
    }

    /// A fresh mutable execution context shaped for this model.
    pub fn context(&self) -> StageContext {
        StageContext::new(self.cfg.width_cap, self.cfg.past_cap)
    }

    /// Token ids -> hidden states `[W, d]`. Input is padded to `width_cap`.
    pub fn embed(&self, rt: &Runtime, tokens: &[u32]) -> Result<Vec<f32>> {
        let w = self.cfg.width_cap;
        anyhow::ensure!(tokens.len() <= w, "block wider than width_cap");
        let mut padded = vec![0i32; w];
        for (i, &t) in tokens.iter().enumerate() {
            padded[i] = t as i32;
        }
        let toks = rt.upload_i32(&padded, &[w])?;
        rt.stats().add_saved(self.emb_bytes); // emb matrix is resident
        let out = self.embed_exe.run_bufs(&[&self.emb_buf, &toks])?;
        rt.stats().add_down(w * self.cfg.dim * 4);
        to_vec_f32(&out[0])
    }

    /// One transformer layer over a node block with the two-level cache of
    /// the owning stage. `layer` is the model-wide layer index;
    /// `layer_in_stage` indexes into `cache`. Explicit bias rows are
    /// uploaded per call — stage spans should prefer
    /// [`ModelCore::stage_forward`], which reuses cached device state.
    #[allow(clippy::too_many_arguments)]
    pub fn layer_forward(
        &self,
        rt: &Runtime,
        ctx: &mut StageContext,
        layer: usize,
        layer_in_stage: usize,
        cache: &TwoLevelCache,
        hidden: &[f32],
        pos: &[i32],
        past_bias: &[f32],
        tree_bias: &[f32],
    ) -> Result<LayerOut> {
        let c = &self.cfg;
        let (w, p, t, nh, hd, dim) =
            (c.width_cap, c.past_cap, c.tree_cap, c.n_heads, c.head_dim, c.dim);
        anyhow::ensure!(hidden.len() == w * dim, "hidden shape");
        anyhow::ensure!(pos.len() == w, "pos shape");
        anyhow::ensure!(past_bias.len() == w * p, "past_bias shape");
        anyhow::ensure!(tree_bias.len() == w * t, "tree_bias shape");

        let h_buf = rt.upload_f32(hidden, &[w, dim])?;
        let tlen_buf = rt.upload_i32(&[cache.tree_len() as i32], &[])?;
        let pos_buf = rt.upload_i32(pos, &[w])?;
        let pb_buf = rt.upload_f32(past_bias, &[w, p])?;
        let tb_buf = rt.upload_f32(tree_bias, &[w, t])?;

        let dev = ctx
            .dev_kv
            .entry(cache.id())
            .or_insert_with(|| DeviceKvCache::new(cache.layers()));
        dev.ensure_past(rt, cache, layer_in_stage)?;
        dev.ensure_tree(rt, cache, layer_in_stage)?;
        let (pk, pv) = dev.past(layer_in_stage).expect("ensured above");
        let (tk, tv) = dev.tree(layer_in_stage).expect("ensured above");

        let out = exec_layer(
            &self.layer_exe,
            &self.layer_bufs[layer],
            self.layer_bytes[layer],
            rt.stats(),
            (w * dim + 2 * nh * w * hd) * 4,
            [&h_buf, pk, pv, tk, tv, &tlen_buf, &pos_buf, &pb_buf, &tb_buf],
        )?;
        Ok(LayerOut {
            hidden: to_vec_f32(&out[0])?,
            k_new: to_vec_f32(&out[1])?,
            v_new: to_vec_f32(&out[2])?,
        })
    }

    /// Shared span runner for decode (`to_tree`) and prefill (`!to_tree`):
    /// uploads the dynamic operands once, walks the layer span handing the
    /// hidden block layer→layer without `Vec<f32>` round-trips (see the
    /// module header for the per-layer tuple-fetch caveat), appends each
    /// layer's new KV to `cache`, and converts the hidden block to a host
    /// `Vec` once at the span boundary. The caller commits the cache.
    #[allow(clippy::too_many_arguments)]
    fn run_span(
        &self,
        rt: &Runtime,
        ctx: &mut StageContext,
        layer_range: std::ops::Range<usize>,
        cache: &mut TwoLevelCache,
        hidden: Vec<f32>,
        count: usize,
        pos: &[i32],
        tree_bias: &[f32],
        to_tree: bool,
    ) -> Result<Vec<f32>> {
        let (w, t, nh, hd, dim) = (
            self.cfg.width_cap,
            self.cfg.tree_cap,
            self.cfg.n_heads,
            self.cfg.head_dim,
            self.cfg.dim,
        );
        anyhow::ensure!(hidden.len() == w * dim, "hidden shape");
        anyhow::ensure!(pos.len() == w, "pos shape");
        anyhow::ensure!(tree_bias.len() == w * t, "tree_bias shape");
        anyhow::ensure!(layer_range.end <= self.cfg.n_layers, "layer range out of bounds");
        let span = layer_range.len();
        anyhow::ensure!(span >= 1, "empty layer range");

        ctx.ensure_past_bias(rt, cache.past_len())?;

        // per-span dynamic operands: uploaded once, not once per layer
        let mut h_buf = rt.upload_f32(&hidden, &[w, dim])?;
        let tlen_buf = rt.upload_i32(&[cache.tree_len() as i32], &[])?;
        let pos_buf = rt.upload_i32(pos, &[w])?;
        let tb_buf = rt.upload_f32(tree_bias, &[w, t])?;

        let dev = ctx
            .dev_kv
            .entry(cache.id())
            .or_insert_with(|| DeviceKvCache::new(cache.layers()));
        let stats = rt.stats();
        let mut h_last: Option<xla::Literal> = None;
        for (lis, layer) in layer_range.enumerate() {
            dev.ensure_past(rt, cache, lis)?;
            dev.ensure_tree(rt, cache, lis)?;
            let (pk, pv) = dev.past(lis).expect("ensured above");
            let (tk, tv) = dev.tree(lis).expect("ensured above");
            let pb_buf = &ctx.past_bias_buf.as_ref().expect("ensured above").1;

            let out = exec_layer(
                &self.layer_exe,
                &self.layer_bufs[layer],
                self.layer_bytes[layer],
                stats,
                (w * dim + 2 * nh * w * hd) * 4,
                [&h_buf, pk, pv, tk, tv, &tlen_buf, &pos_buf, pb_buf, &tb_buf],
            )?;

            let k_new = to_vec_f32(&out[1])?;
            let v_new = to_vec_f32(&out[2])?;
            // host append (bumps the level epoch) + in-place device append
            // of the same block; `start`/`pre_epoch` are pre-append state
            let (pre_epoch, start) = if to_tree {
                (cache.tree_epoch(lis), cache.tree_len())
            } else {
                (cache.past_epoch(lis), cache.past_len())
            };
            if to_tree {
                cache.append_tree_block(lis, &k_new, &v_new, w, count)?;
            } else {
                cache.append_past_block(lis, &k_new, &v_new, w, count)?;
            }
            if let Some(ops) = self.kv_ops.as_ref() {
                dev.append_block(
                    rt, ops, cache, lis, to_tree, pre_epoch, start, &k_new, &v_new, w,
                    count,
                )?;
            }

            let h_lit = out.into_iter().next().expect("len checked");
            if lis + 1 < span {
                // handoff: the next layer consumes the fetched h' literal
                // directly — no Vec<f32> decode/pad/re-encode
                h_buf = rt.upload_literal(&h_lit)?;
                stats.add_up(w * dim * 4);
            }
            h_last = Some(h_lit);
        }
        // single Vec<f32> conversion at the span boundary
        to_vec_f32(&h_last.expect("span >= 1"))
    }

    /// Final norm + tied head: hidden `[W, d]` -> logits `[W, V]`.
    pub fn head(&self, rt: &Runtime, hidden: &[f32]) -> Result<Vec<f32>> {
        let c = &self.cfg;
        anyhow::ensure!(hidden.len() == c.width_cap * c.dim, "hidden shape");
        let h = rt.upload_f32(hidden, &[c.width_cap, c.dim])?;
        rt.stats().add_saved(self.final_norm_bytes + self.emb_bytes);
        let out = self.head_exe.run_bufs(&[&self.final_norm_buf, &self.emb_buf, &h])?;
        rt.stats().add_down(c.width_cap * c.vocab_size * 4);
        to_vec_f32(&out[0])
    }

    /// Run a block through a contiguous span of layers (a pipeline stage),
    /// appending the new tree-level KV of each layer to `cache` and
    /// committing `count` slots. The past bias is derived internally from
    /// `cache.past_len()` via the context's incremental bias cache.
    /// Returns the final hidden states.
    #[allow(clippy::too_many_arguments)]
    pub fn stage_forward(
        &self,
        rt: &Runtime,
        ctx: &mut StageContext,
        layer_range: std::ops::Range<usize>,
        cache: &mut TwoLevelCache,
        hidden: Vec<f32>,
        count: usize,
        pos: &[i32],
        tree_bias: &[f32],
    ) -> Result<Vec<f32>> {
        let h =
            self.run_span(rt, ctx, layer_range, cache, hidden, count, pos, tree_bias, true)?;
        cache.commit_tree(count);
        Ok(h)
    }

    /// Prefill a prompt chunk through a span of layers: the chunk plays the
    /// "predicted" segment with a causal in-block bias (see
    /// `python/compile/model.py` docstring), and the resulting KV is
    /// appended to the **model level** of the cache.
    #[allow(clippy::too_many_arguments)]
    pub fn prefill_chunk(
        &self,
        rt: &Runtime,
        ctx: &mut StageContext,
        layer_range: std::ops::Range<usize>,
        cache: &mut TwoLevelCache,
        hidden: Vec<f32>,
        count: usize,
        start_pos: usize,
    ) -> Result<Vec<f32>> {
        let (w, t) = (self.cfg.width_cap, self.cfg.tree_cap);
        let pos: Vec<i32> = (0..w).map(|i| (start_pos + i) as i32).collect();
        // in-block causal bias over the tree segment appended at slot 0
        let tree_bias = bias::causal_block_bias(count, 0, w, t);
        anyhow::ensure!(cache.tree_len() == 0, "prefill requires empty tree level");
        let h =
            self.run_span(rt, ctx, layer_range, cache, hidden, count, &pos, &tree_bias, false)?;
        cache.commit_past(count);
        Ok(h)
    }

    /// Full-model pass over a tree block (used by the draft node and the
    /// SLM baseline): embed + all layers + head. Appends tree-level KV.
    pub fn full_forward_tree_block(
        &self,
        rt: &Runtime,
        ctx: &mut StageContext,
        cache: &mut TwoLevelCache,
        tokens: &[u32],
        pos: &[i32],
        tree_bias: &[f32],
    ) -> Result<Vec<f32>> {
        let hidden = self.embed(rt, tokens)?;
        let n = self.cfg.n_layers;
        let h =
            self.stage_forward(rt, ctx, 0..n, cache, hidden, tokens.len(), pos, tree_bias)?;
        self.head(rt, &h)
    }

    /// Full-model prefill of a whole prompt (draft node / SLM baseline).
    /// Returns the logits row of the last prompt token.
    pub fn full_prefill(
        &self,
        rt: &Runtime,
        ctx: &mut StageContext,
        cache: &mut TwoLevelCache,
        prompt: &[u32],
    ) -> Result<Vec<f32>> {
        let w = self.cfg.width_cap;
        let n = self.cfg.n_layers;
        let mut last_h: Option<Vec<f32>> = None;
        let mut last_count = 0;
        for chunk in prompt.chunks(w) {
            let start = cache.past_len();
            let hidden = self.embed(rt, chunk)?;
            let h = self.prefill_chunk(rt, ctx, 0..n, cache, hidden, chunk.len(), start)?;
            last_count = chunk.len();
            last_h = Some(h);
        }
        let h = last_h.context("empty prompt")?;
        let logits = self.head(rt, &h)?;
        let v = self.cfg.vocab_size;
        Ok(logits[(last_count - 1) * v..last_count * v].to_vec())
    }
}

/// One loaded model behind the original single-threaded surface: an
/// `Arc<ModelCore>` plus one [`StageContext`]. The baselines (PP / STPP /
/// SLM), benches, and tests run sequentially and keep using this; the
/// threaded PipeDec engines hold the `Arc<ModelCore>` directly and one
/// context per stage group (see `coordinator::workers`).
pub struct ModelHandles {
    /// Copy of [`ModelCore::cfg`] kept as a public field for the
    /// pre-split callers that read `handles.cfg` directly.
    pub cfg: ArtifactConfig,
    core: Arc<ModelCore>,
    ctx: StageContext,
}

impl ModelHandles {
    /// Load with the full width cap.
    pub fn load(rt: &Runtime, dir: &Path, name: &str) -> Result<Self> {
        Self::load_with_width(rt, dir, name, usize::MAX)
    }

    /// See [`ModelCore::load_with_width`].
    pub fn load_with_width(
        rt: &Runtime,
        dir: &Path,
        name: &str,
        want_width: usize,
    ) -> Result<Self> {
        let core = Arc::new(ModelCore::load_with_width(rt, dir, name, want_width)?);
        let ctx = core.context();
        Ok(Self {
            cfg: core.cfg.clone(),
            core,
            ctx,
        })
    }

    /// The shared read-only core (for callers that go threaded).
    pub fn core(&self) -> &Arc<ModelCore> {
        &self.core
    }

    /// Effective block width of the loaded artifact variant.
    pub fn width(&self) -> usize {
        self.cfg.width_cap
    }

    /// See [`StageContext::release_cache`].
    pub fn release_cache(&mut self, cache_id: u64) -> bool {
        self.ctx.release_cache(cache_id)
    }

    /// See [`StageContext::mirror_count`].
    pub fn mirror_count(&self) -> usize {
        self.ctx.mirror_count()
    }

    /// See [`ModelCore::embed`].
    pub fn embed(&mut self, rt: &Runtime, tokens: &[u32]) -> Result<Vec<f32>> {
        self.core.embed(rt, tokens)
    }

    /// See [`ModelCore::head`].
    pub fn head(&mut self, rt: &Runtime, hidden: &[f32]) -> Result<Vec<f32>> {
        self.core.head(rt, hidden)
    }

    /// See [`ModelCore::layer_forward`].
    #[allow(clippy::too_many_arguments)]
    pub fn layer_forward(
        &mut self,
        rt: &Runtime,
        layer: usize,
        layer_in_stage: usize,
        cache: &TwoLevelCache,
        hidden: &[f32],
        pos: &[i32],
        past_bias: &[f32],
        tree_bias: &[f32],
    ) -> Result<LayerOut> {
        self.core.layer_forward(
            rt,
            &mut self.ctx,
            layer,
            layer_in_stage,
            cache,
            hidden,
            pos,
            past_bias,
            tree_bias,
        )
    }

    /// See [`ModelCore::stage_forward`].
    #[allow(clippy::too_many_arguments)]
    pub fn stage_forward(
        &mut self,
        rt: &Runtime,
        layer_range: std::ops::Range<usize>,
        cache: &mut TwoLevelCache,
        hidden: Vec<f32>,
        count: usize,
        pos: &[i32],
        tree_bias: &[f32],
    ) -> Result<Vec<f32>> {
        self.core.stage_forward(
            rt,
            &mut self.ctx,
            layer_range,
            cache,
            hidden,
            count,
            pos,
            tree_bias,
        )
    }

    /// See [`ModelCore::prefill_chunk`].
    pub fn prefill_chunk(
        &mut self,
        rt: &Runtime,
        layer_range: std::ops::Range<usize>,
        cache: &mut TwoLevelCache,
        hidden: Vec<f32>,
        count: usize,
        start_pos: usize,
    ) -> Result<Vec<f32>> {
        self.core.prefill_chunk(
            rt,
            &mut self.ctx,
            layer_range,
            cache,
            hidden,
            count,
            start_pos,
        )
    }

    /// See [`ModelCore::full_forward_tree_block`].
    pub fn full_forward_tree_block(
        &mut self,
        rt: &Runtime,
        cache: &mut TwoLevelCache,
        tokens: &[u32],
        pos: &[i32],
        tree_bias: &[f32],
    ) -> Result<Vec<f32>> {
        self.core
            .full_forward_tree_block(rt, &mut self.ctx, cache, tokens, pos, tree_bias)
    }

    /// See [`ModelCore::full_prefill`].
    pub fn full_prefill(
        &mut self,
        rt: &Runtime,
        cache: &mut TwoLevelCache,
        prompt: &[u32],
    ) -> Result<Vec<f32>> {
        self.core.full_prefill(rt, &mut self.ctx, cache, prompt)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::top_k_indices;

    fn setup() -> Option<(Runtime, ModelHandles)> {
        let dir = crate::artifacts_dir();
        if !dir.join("draft_config.txt").exists() {
            eprintln!("skipping: no artifacts");
            return None;
        }
        let rt = Runtime::cpu().unwrap();
        let m = ModelHandles::load(&rt, &dir, "draft").unwrap();
        Some((rt, m))
    }

    #[test]
    fn draft_loads_and_embeds() {
        let Some((rt, mut m)) = setup() else { return };
        let h = m.embed(&rt, &crate::tokenizer::encode("hi")).unwrap();
        assert_eq!(h.len(), m.cfg.width_cap * m.cfg.dim);
        assert!(h.iter().all(|x| x.is_finite()));
    }

    #[test]
    fn prefill_then_greedy_continuation_is_plausible() {
        // The draft was trained on the corpus; after prefixing a math-style
        // prompt the greedy next token must be a printable id (not PAD) and
        // logits must be finite.
        let Some((rt, mut m)) = setup() else { return };
        let c = m.cfg.clone();
        let mut cache = TwoLevelCache::new(
            c.n_layers, c.n_heads, c.head_dim, c.past_cap, c.tree_cap,
        );
        let prompt = crate::tokenizer::encode("<math>\nquestion: bob has 3 coins");
        let logits = m.full_prefill(&rt, &mut cache, &prompt).unwrap();
        assert_eq!(logits.len(), c.vocab_size);
        assert!(logits.iter().all(|x| x.is_finite()));
        let top = top_k_indices(&logits, 1)[0];
        assert!(top >= 3, "greedy next token {top} should not be PAD/BOS");
        assert_eq!(cache.past_len(), prompt.len());
    }

    #[test]
    fn release_cache_evicts_the_device_mirror_and_rebuilds_on_reuse() {
        // Per-session cache churn (SpecPipe-DB) must not strand mirrors:
        // release drops the entry, a second release is a no-op, and a new
        // cache (fresh id) builds a fresh mirror transparently.
        let Some((rt, mut m)) = setup() else { return };
        let c = m.cfg.clone();
        let prompt = crate::tokenizer::encode("<math>\nquestion: 1 + 1?");
        let mut cache = TwoLevelCache::new(
            c.n_layers, c.n_heads, c.head_dim, c.past_cap, c.tree_cap,
        );
        assert_eq!(m.mirror_count(), 0);
        m.full_prefill(&rt, &mut cache, &prompt).unwrap();
        assert_eq!(m.mirror_count(), 1, "prefill mints one mirror per cache");
        assert!(m.release_cache(cache.id()));
        assert_eq!(m.mirror_count(), 0, "release must evict the mirror");
        assert!(
            !m.release_cache(cache.id()),
            "double release is a reported no-op"
        );
        // a fresh per-session cache rebuilds its own mirror on first use
        let mut cache2 = TwoLevelCache::new(
            c.n_layers, c.n_heads, c.head_dim, c.past_cap, c.tree_cap,
        );
        m.full_prefill(&rt, &mut cache2, &prompt).unwrap();
        assert_eq!(m.mirror_count(), 1);
        assert!(m.release_cache(cache2.id()));
    }

    #[test]
    fn device_cache_skips_clean_reuploads_across_prefill_chunks() {
        // During prefill the tree level never mutates, so after the first
        // chunk the tree tensors must be served from the device mirror.
        let Some((rt, mut m)) = setup() else { return };
        let c = m.cfg.clone();
        let mut cache = TwoLevelCache::new(
            c.n_layers, c.n_heads, c.head_dim, c.past_cap, c.tree_cap,
        );
        let prompt: Vec<u32> = crate::tokenizer::encode(
            "<math>\nquestion: a long enough prompt to span several chunks",
        );
        let before = rt.stats().snapshot();
        m.full_prefill(&rt, &mut cache, &prompt).unwrap();
        let d = rt.stats().snapshot().delta_since(&before);
        assert!(
            d.saved > 0,
            "prefill should serve some operands from device residency"
        );
        assert!(d.reduction_factor() > 1.0);
    }

    #[test]
    fn core_is_shareable_across_threads() {
        // The Send + Sync audit in `runtime` must actually let a core be
        // used from a spawned thread (compile-time property exercised at
        // runtime when artifacts exist).
        let Some((rt, m)) = setup() else { return };
        let core = Arc::clone(m.core());
        let rt = Arc::new(rt);
        let rt2 = Arc::clone(&rt);
        let h = std::thread::spawn(move || {
            let toks = crate::tokenizer::encode("hi");
            core.embed(&rt2, &toks).unwrap().len()
        });
        let len = h.join().unwrap();
        assert_eq!(len, m.cfg.width_cap * m.cfg.dim);
    }
}
