//! Attention-bias builders, host-side mirrors of the helpers in
//! `python/compile/model.py` (`past_bias_for`, `causal_block_bias`).

use crate::config::TreeConfig;

pub const NEG: f32 = -1e9;

/// `[W, P]` additive validity mask: column j is open iff `j < past_len`.
pub fn past_bias(past_len: usize, w: usize, p: usize) -> Vec<f32> {
    let mut out = vec![NEG; w * p];
    for r in 0..w {
        for v in &mut out[r * p..r * p + past_len.min(p)] {
            *v = 0.0;
        }
    }
    out
}

/// Incrementally maintained `[W, P]` past-validity bias (ISSUE 2
/// satellite): `past_len` only grows during a request, so instead of
/// rebuilding the full `W×P` row block every prefill chunk and every
/// timestep ([`past_bias`] from scratch), the cache opens just the newly
/// valid columns. A shrink (new request) re-masks the now-invalid columns
/// — still touching only the delta. `epoch()` lets a device mirror skip
/// re-uploading an unchanged row block.
#[derive(Debug, Clone)]
pub struct PastBiasCache {
    w: usize,
    p: usize,
    len: usize,
    rows: Vec<f32>,
    epoch: u64,
}

impl PastBiasCache {
    pub fn new(w: usize, p: usize) -> Self {
        Self {
            w,
            p,
            len: 0,
            rows: vec![NEG; w * p],
            epoch: 0,
        }
    }

    /// Bumped every time the row block's contents change.
    pub fn epoch(&self) -> u64 {
        self.epoch
    }

    /// The `[W, P]` bias rows for `past_len`, updated incrementally.
    pub fn rows(&mut self, past_len: usize) -> &[f32] {
        let new = past_len.min(self.p);
        let old = self.len;
        if new != old {
            let (lo, hi, val) = if new > old {
                (old, new, 0.0) // grew: open the fresh columns
            } else {
                (new, old, NEG) // shrank (new request): re-mask
            };
            for r in 0..self.w {
                for v in &mut self.rows[r * self.p + lo..r * self.p + hi] {
                    *v = val;
                }
            }
            self.len = new;
            self.epoch += 1;
        }
        &self.rows
    }
}

/// `[W, T]` prefill bias: the current chunk is appended at `tree_len`;
/// row i attends causally to block columns `tree_len..=tree_len+i` while
/// `i < valid`. Fully-masked padding rows keep self-attention open so the
/// kernel's softmax stays finite.
pub fn causal_block_bias(valid: usize, tree_len: usize, w: usize, t: usize) -> Vec<f32> {
    let mut out = vec![NEG; w * t];
    for r in 0..w {
        if r < valid {
            for c in 0..=r.min(t.saturating_sub(tree_len + 1)) {
                out[r * t + tree_len + c] = 0.0;
            }
        } else if tree_len + r < t {
            out[r * t + tree_len + r] = 0.0; // padding row: self only
        }
    }
    out
}

/// `[W, T]` tree bias for padding rows beyond the valid block: open the
/// self slot so softmax stays finite (mirrors the python helper's
/// `self_ok` clause). `rows` already hold the ancestor bias of the valid
/// block from [`crate::tree::PredictionTree::bias_rows`].
pub fn pad_tree_bias_rows(
    mut rows: Vec<f32>,
    valid: usize,
    tree_len: usize,
    w: usize,
    t: usize,
) -> Vec<f32> {
    debug_assert_eq!(rows.len(), valid * t);
    rows.resize(w * t, NEG);
    for r in valid..w {
        let c = tree_len + r;
        if c < t {
            rows[r * t + c] = 0.0;
        }
    }
    rows
}

/// Effective tree width cap for a [`TreeConfig`] against the artifact cap.
pub fn effective_width(tree: &TreeConfig, width_cap: usize) -> usize {
    tree.max_width.min(width_cap)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn past_bias_opens_prefix() {
        let b = past_bias(2, 2, 4);
        assert_eq!(b, vec![0.0, 0.0, NEG, NEG, 0.0, 0.0, NEG, NEG]);
    }

    #[test]
    fn past_bias_cache_matches_rebuild_through_grow_and_shrink() {
        let (w, p) = (3, 6);
        let mut cache = PastBiasCache::new(w, p);
        let e0 = cache.epoch();
        // grow-only sequence, then a shrink (new request), then regrow
        for len in [0usize, 2, 2, 5, 6, 8, 1, 4] {
            let got = cache.rows(len).to_vec();
            assert_eq!(got, past_bias(len, w, p), "len={len}");
        }
        assert!(cache.epoch() > e0);
        // unchanged length does not bump the epoch
        let e = cache.epoch();
        cache.rows(4);
        assert_eq!(cache.epoch(), e);
    }

    #[test]
    fn causal_block_is_triangular() {
        let b = causal_block_bias(3, 1, 4, 6);
        // row 0 attends col 1 only
        assert_eq!(&b[0..6], &[NEG, 0.0, NEG, NEG, NEG, NEG]);
        // row 1 attends cols 1..=2
        assert_eq!(&b[6..12], &[NEG, 0.0, 0.0, NEG, NEG, NEG]);
        // row 3 is padding: self slot open at col 4
        assert_eq!(b[3 * 6 + 4], 0.0);
    }

    #[test]
    fn pad_rows_open_self() {
        let rows = vec![0.0f32; 1 * 8]; // one valid row
        let padded = pad_tree_bias_rows(rows, 1, 3, 4, 8);
        assert_eq!(padded.len(), 32);
        assert_eq!(padded[1 * 8 + 4], 0.0); // row1 self at 3+1
        assert_eq!(padded[2 * 8 + 5], 0.0);
        assert_eq!(padded[1 * 8 + 3], NEG);
    }
}
