//! Deterministic fault injection for the fault-isolated serving core
//! (ISSUE 9).
//!
//! A [`FaultPlan`] is a small list of rules, each naming a choke point
//! ([`Site`]), the 1-based hit count at which it fires, and what happens
//! ([`FaultKind`]): a panic, an `anyhow` error, or a delay. The
//! production code calls [`fire`] at each choke point; when no plan is
//! armed that is a single relaxed atomic load and an immediate return,
//! so the layer costs nothing on the hot path.
//!
//! Plans serialize to a compact text grammar so a failing randomized run
//! can be replayed exactly:
//!
//! ```text
//! stage_job@3=panic,spill_read@1=error,device_op@2=delay:5
//! ```
//!
//! i.e. comma-separated `site@hit=kind` rules, where `kind` is `panic`,
//! `error`, or `delay:MS`. [`FaultPlan`] round-trips through
//! `Display`/`FromStr`; the chaos suite prints the plan of any failing
//! seed so it can be pinned as a fixed regression.
//!
//! Arming is process-global (the counters and plan live in statics, the
//! same way the runtime's transfer stats do): engines arm from the
//! `PIPEDEC_FAULTS` env var or the `[faultinject] plan` config key at
//! construction, and tests use [`install`], which additionally holds a
//! global lock so concurrent `#[test]`s cannot interleave plans.

use std::fmt;
use std::str::FromStr;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Mutex, MutexGuard};
use std::time::Duration;

use anyhow::{bail, Context, Result};

use crate::util::XorShiftRng;

/// A named choke point the production code guards with [`fire`].
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Site {
    /// Top of a pipeline stage job (`workers::exec_stage_job`).
    StageJob,
    /// One draft candidate's visit inside `workers::exec_draft_job`.
    DraftJob,
    /// `StageContext::apply_commit` — the commit-replay choke point.
    ApplyCommit,
    /// A device KV mirror update (`DeviceKvCache` around
    /// `run_bufs_to_bufs`).
    DeviceOp,
    /// Prefix-cache L2 spill write (`PrefixStore::spill`).
    SpillWrite,
    /// Prefix-cache L2 promote read (`PrefixStore::promote_l2`).
    SpillRead,
    /// Top of the pipeline worker loop, *between* jobs — an injected
    /// error here makes the worker thread exit; a panic kills it
    /// abruptly. Both exercise the coordinator's respawn path.
    WorkerExit,
    /// One free-running speculative generation inside
    /// `pipeline::draft_speculate` (ISSUE 10): fires once per generation
    /// beyond the in-step expansion, so a plan can kill or slow the
    /// draft exactly while it is ahead of the committed tree.
    DraftStale,
}

impl Site {
    pub const ALL: [Site; 8] = [
        Site::StageJob,
        Site::DraftJob,
        Site::ApplyCommit,
        Site::DeviceOp,
        Site::SpillWrite,
        Site::SpillRead,
        Site::WorkerExit,
        Site::DraftStale,
    ];

    /// Stable grammar name (`stage_job`, `spill_read`, ...).
    pub fn name(self) -> &'static str {
        match self {
            Site::StageJob => "stage_job",
            Site::DraftJob => "draft_job",
            Site::ApplyCommit => "apply_commit",
            Site::DeviceOp => "device_op",
            Site::SpillWrite => "spill_write",
            Site::SpillRead => "spill_read",
            Site::WorkerExit => "worker_exit",
            Site::DraftStale => "draft_stale",
        }
    }

    fn index(self) -> usize {
        Site::ALL.iter().position(|&s| s == self).expect("site in ALL")
    }

    /// Whether the site runs inside a pipeline worker job, where a panic
    /// is caught (`catch_unwind` inline, thread supervision pooled) and
    /// converted into a per-session failure. Panics at coordinator-side
    /// sites are genuine crashes, so randomized plans only place `Panic`
    /// on worker-scoped sites.
    pub fn worker_scoped(self) -> bool {
        matches!(
            self,
            Site::StageJob | Site::DraftJob | Site::WorkerExit | Site::DraftStale
        )
    }
}

impl fmt::Display for Site {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.name())
    }
}

impl FromStr for Site {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> Result<Self> {
        Site::ALL
            .into_iter()
            .find(|site| site.name() == s)
            .with_context(|| {
                format!(
                    "unknown fault site {s:?} (expected one of: {})",
                    Site::ALL.map(Site::name).join(", ")
                )
            })
    }
}

/// What an armed rule does when its hit count comes up.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultKind {
    /// `panic!` at the choke point (tests the catch/respawn paths).
    Panic,
    /// Return an `anyhow` error from [`fire`].
    Error,
    /// Sleep this many milliseconds, then succeed (slow-stage model).
    Delay(u64),
}

impl fmt::Display for FaultKind {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            FaultKind::Panic => f.write_str("panic"),
            FaultKind::Error => f.write_str("error"),
            FaultKind::Delay(ms) => write!(f, "delay:{ms}"),
        }
    }
}

impl FromStr for FaultKind {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> Result<Self> {
        match s {
            "panic" => Ok(FaultKind::Panic),
            "error" => Ok(FaultKind::Error),
            _ => {
                let ms = s
                    .strip_prefix("delay:")
                    .with_context(|| {
                        format!("unknown fault kind {s:?} (panic | error | delay:MS)")
                    })?
                    .parse::<u64>()
                    .with_context(|| format!("bad delay millis in {s:?}"))?;
                Ok(FaultKind::Delay(ms))
            }
        }
    }
}

/// One rule: at the `hit`-th (1-based) call of [`fire`] for `site`,
/// inject `kind`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct FaultRule {
    pub site: Site,
    pub hit: u64,
    pub kind: FaultKind,
}

impl fmt::Display for FaultRule {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}@{}={}", self.site, self.hit, self.kind)
    }
}

impl FromStr for FaultRule {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> Result<Self> {
        let (site_hit, kind) = s
            .split_once('=')
            .with_context(|| format!("fault rule {s:?} is not site@hit=kind"))?;
        let (site, hit) = site_hit
            .split_once('@')
            .with_context(|| format!("fault rule {s:?} is not site@hit=kind"))?;
        Ok(FaultRule {
            site: site.parse()?,
            hit: hit
                .parse::<u64>()
                .with_context(|| format!("bad hit count in {s:?}"))?,
            kind: kind.parse()?,
        })
    }
}

/// A deterministic schedule of injected faults; see the module docs for
/// the text grammar.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct FaultPlan {
    pub rules: Vec<FaultRule>,
}

impl FaultPlan {
    pub fn new(rules: Vec<FaultRule>) -> Self {
        Self { rules }
    }

    /// A small random plan for the nightly chaos lane: 1–3 rules over
    /// random sites/hit counts, biased toward errors (the common case)
    /// with panics and short delays mixed in. Deterministic in `seed`,
    /// so a failing seed's plan can be reprinted and pinned.
    pub fn random(seed: u64) -> Self {
        let mut rng = XorShiftRng::new(seed ^ 0x9e37_79b9_7f4a_7c15);
        let n = 1 + rng.below(3);
        let rules = (0..n)
            .map(|_| {
                let site = Site::ALL[rng.below(Site::ALL.len())];
                let kind = match rng.below(10) {
                    0..=4 => FaultKind::Error,
                    // panics are survivable only inside worker jobs;
                    // elsewhere degrade the roll to an error
                    5..=7 if site.worker_scoped() => FaultKind::Panic,
                    5..=7 => FaultKind::Error,
                    _ => FaultKind::Delay(1 + rng.below(5) as u64),
                };
                FaultRule {
                    site,
                    hit: 1 + rng.below(6) as u64,
                    kind,
                }
            })
            .collect();
        Self { rules }
    }
}

impl fmt::Display for FaultPlan {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        for (i, r) in self.rules.iter().enumerate() {
            if i > 0 {
                f.write_str(",")?;
            }
            write!(f, "{r}")?;
        }
        Ok(())
    }
}

impl FromStr for FaultPlan {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> Result<Self> {
        let s = s.trim();
        if s.is_empty() {
            return Ok(Self::default());
        }
        let rules = s
            .split(',')
            .map(|r| r.trim().parse())
            .collect::<Result<Vec<FaultRule>>>()?;
        Ok(Self { rules })
    }
}

// ---------------------------------------------------------------------
// Global armed state
// ---------------------------------------------------------------------

/// The one hot-path cost: a relaxed load of this flag per choke point.
static ENABLED: AtomicBool = AtomicBool::new(false);

/// Per-site hit counters, indexed by [`Site::index`]; only touched once
/// the layer is enabled.
static HITS: [AtomicU64; 8] = [
    AtomicU64::new(0),
    AtomicU64::new(0),
    AtomicU64::new(0),
    AtomicU64::new(0),
    AtomicU64::new(0),
    AtomicU64::new(0),
    AtomicU64::new(0),
    AtomicU64::new(0),
];

static PLAN: Mutex<Option<FaultPlan>> = Mutex::new(None);

/// Serializes [`install`]-scoped tests so two `#[test]`s cannot
/// interleave plans (the armed state is process-global).
static INSTALL_LOCK: Mutex<()> = Mutex::new(());

fn lock_plan() -> MutexGuard<'static, Option<FaultPlan>> {
    PLAN.lock().unwrap_or_else(|e| e.into_inner())
}

fn reset_counters() {
    for h in &HITS {
        h.store(0, Ordering::SeqCst);
    }
}

/// Arm `plan` process-wide (replacing any armed plan) and reset the hit
/// counters. Engines call this for env/config-driven plans; tests should
/// prefer the scoped [`install`].
pub fn arm(plan: FaultPlan) {
    let mut slot = lock_plan();
    reset_counters();
    *slot = Some(plan);
    ENABLED.store(true, Ordering::SeqCst);
}

/// Disarm the layer: [`fire`] reverts to the single-load no-op.
pub fn disarm() {
    ENABLED.store(false, Ordering::SeqCst);
    *lock_plan() = None;
    reset_counters();
}

/// Arm from the `PIPEDEC_FAULTS` env var if it is set and non-empty.
/// A malformed plan is an error (silently ignoring a typo'd plan would
/// make a chaos run vacuously green).
pub fn arm_from_env() -> Result<()> {
    match std::env::var("PIPEDEC_FAULTS") {
        Ok(s) if !s.trim().is_empty() => {
            let plan: FaultPlan = s.parse().context("parsing PIPEDEC_FAULTS")?;
            arm(plan);
            Ok(())
        }
        _ => Ok(()),
    }
}

/// Whether a plan is currently armed.
pub fn enabled() -> bool {
    ENABLED.load(Ordering::SeqCst)
}

/// Hits recorded at `site` since the last arm/reset (test observability).
pub fn hits(site: Site) -> u64 {
    HITS[site.index()].load(Ordering::SeqCst)
}

/// RAII guard for test-scoped plans; disarms on drop. Holds the global
/// install lock, so guard lifetimes serialize across threads.
pub struct FaultGuard {
    _lock: MutexGuard<'static, ()>,
}

impl Drop for FaultGuard {
    fn drop(&mut self) {
        disarm();
    }
}

/// Arm `plan` for the lifetime of the returned guard. Tests use this so
/// the process-global state cannot leak between `#[test]`s (the guard
/// holds a global lock and disarms on drop).
pub fn install(plan: FaultPlan) -> FaultGuard {
    let lock = INSTALL_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    arm(plan);
    FaultGuard { _lock: lock }
}

/// The choke-point call: no-op (one relaxed load) when disarmed;
/// otherwise bump `site`'s hit counter and run the matching rule, if
/// any — sleeping for `Delay`, returning `Err` for `Error`, panicking
/// for `Panic`.
#[inline]
pub fn fire(site: Site) -> Result<()> {
    if !ENABLED.load(Ordering::Relaxed) {
        return Ok(());
    }
    fire_armed(site)
}

#[cold]
fn fire_armed(site: Site) -> Result<()> {
    let hit = HITS[site.index()].fetch_add(1, Ordering::SeqCst) + 1;
    let kind = lock_plan().as_ref().and_then(|p| {
        p.rules
            .iter()
            .find(|r| r.site == site && r.hit == hit)
            .map(|r| r.kind)
    });
    match kind {
        None => Ok(()),
        Some(FaultKind::Delay(ms)) => {
            std::thread::sleep(Duration::from_millis(ms));
            Ok(())
        }
        Some(FaultKind::Error) => bail!("injected fault: {site} hit {hit}"),
        Some(FaultKind::Panic) => panic!("injected fault: {site} hit {hit}"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn plan_grammar_round_trips() {
        let text = "stage_job@3=panic,spill_read@1=error,device_op@2=delay:5";
        let plan: FaultPlan = text.parse().unwrap();
        assert_eq!(plan.rules.len(), 3);
        assert_eq!(plan.to_string(), text);
        assert_eq!(plan.to_string().parse::<FaultPlan>().unwrap(), plan);
        assert_eq!("".parse::<FaultPlan>().unwrap(), FaultPlan::default());
    }

    #[test]
    fn malformed_plans_are_rejected() {
        assert!("bogus_site@1=error".parse::<FaultPlan>().is_err());
        assert!("stage_job@x=error".parse::<FaultPlan>().is_err());
        assert!("stage_job@1=explode".parse::<FaultPlan>().is_err());
        assert!("stage_job@1".parse::<FaultPlan>().is_err());
    }

    #[test]
    fn random_plans_are_deterministic_and_replayable() {
        for seed in 0..50 {
            let a = FaultPlan::random(seed);
            assert_eq!(a, FaultPlan::random(seed), "seed {seed} not deterministic");
            assert!(!a.rules.is_empty() && a.rules.len() <= 3);
            let round: FaultPlan = a.to_string().parse().unwrap();
            assert_eq!(round, a, "seed {seed} plan did not round-trip");
        }
    }

    #[test]
    fn disabled_fire_is_a_no_op_and_counts_nothing() {
        let _guard = INSTALL_LOCK.lock().unwrap_or_else(|e| e.into_inner());
        disarm();
        for site in Site::ALL {
            fire(site).unwrap();
            assert_eq!(hits(site), 0, "{site}: disabled fire must not count");
        }
    }

    #[test]
    fn rules_fire_on_their_exact_hit() {
        let plan: FaultPlan = "apply_commit@2=error".parse().unwrap();
        let _g = install(plan);
        assert!(fire(Site::ApplyCommit).is_ok(), "hit 1 passes");
        let err = fire(Site::ApplyCommit).unwrap_err().to_string();
        assert!(err.contains("apply_commit"), "reason names the site: {err}");
        assert!(fire(Site::ApplyCommit).is_ok(), "hit 3 passes again");
        assert_eq!(hits(Site::ApplyCommit), 3);
        assert_eq!(hits(Site::StageJob), 0);
    }

    #[test]
    fn install_guard_disarms_on_drop() {
        {
            let _g = install("stage_job@1=error".parse().unwrap());
            assert!(enabled());
        }
        assert!(!enabled());
        assert!(fire(Site::StageJob).is_ok());
    }
}
