//! SLM baseline (paper §4.2): the small model served standalone on a single
//! device — the paper's "LLaMA 3.1-8B on one L40" comparison point, here the
//! draft-size model running plain autoregressive decoding.

use std::path::Path;
use std::time::Instant;

use anyhow::Result;

use crate::config::EngineConfig;
use crate::coordinator::sampling::select_token;
use crate::engine::{DecodeOutput, DecodeRequest, Engine, EngineKind, TokenSink};
use crate::kvcache::TwoLevelCache;
use crate::metrics::Metrics;
use crate::model::{bias, ModelHandles};
use crate::runtime::Runtime;
use crate::tokenizer;
use crate::util::XorShiftRng;

pub struct SlmEngine {
    rt: Runtime,
    model: ModelHandles,
    pub cfg: EngineConfig,
    cache: TwoLevelCache,
    rng: XorShiftRng,
}

impl SlmEngine {
    pub fn new(artifact_dir: &Path, cfg: EngineConfig) -> Result<Self> {
        cfg.validate()?;
        let rt = Runtime::cpu()?;
        // width-1 autoregression: the narrow artifact bucket suffices
        let model = ModelHandles::load_with_width(&rt, artifact_dir, "draft", 1)?;
        let c = &model.cfg;
        let cache =
            TwoLevelCache::new(c.n_layers, c.n_heads, c.head_dim, c.past_cap, c.tree_cap);
        let rng = XorShiftRng::new(cfg.seed);
        Ok(Self {
            rt,
            model,
            cfg,
            cache,
            rng,
        })
    }
}

impl Engine for SlmEngine {
    fn kind(&self) -> EngineKind {
        EngineKind::Slm
    }

    fn config(&self) -> &EngineConfig {
        &self.cfg
    }

    fn decode(&mut self, req: &DecodeRequest, sink: &mut dyn TokenSink) -> Result<DecodeOutput> {
        let (max_new, sampling, seed) = req.resolve(&self.cfg);
        anyhow::ensure!(max_new >= 1, "max_new_tokens must be >= 1");
        self.cache.reset();
        self.rng = XorShiftRng::new(seed);
        let mut metrics = Metrics::new();
        let c = self.model.cfg.clone();

        anyhow::ensure!(
            max_new + 2 < c.past_cap,
            "max_new_tokens {max_new} exceeds the model context budget ({})",
            c.past_cap
        );
        let max_prompt = c.past_cap - max_new - 2;
        let mut ids = tokenizer::encode(&req.prompt);
        ids.truncate(max_prompt);
        anyhow::ensure!(!ids.is_empty(), "empty prompt");

        let logits = self.model.full_prefill(&self.rt, &mut self.cache, &ids)?;
        let mut next = select_token(&logits, &sampling, &mut self.rng);

        let hd_prefill = self.rt.stats().snapshot();
        let wall0 = Instant::now();
        let mut modeled_s = 0.0;
        let mut decoded = vec![next];
        sink.on_token(next);
        while decoded.len() < max_new && next != tokenizer::EOS_ID {
            let t0 = Instant::now();
            let mut pos = vec![0i32; c.width_cap];
            pos[0] = self.cache.past_len() as i32;
            let tree_bias =
                bias::pad_tree_bias_rows(Vec::new(), 0, 0, c.width_cap, c.tree_cap);
            let logits = self.model.full_forward_tree_block(
                &self.rt,
                &mut self.cache,
                &[next],
                &pos,
                &tree_bias,
            )?;
            next = select_token(&logits[..c.vocab_size], &sampling, &mut self.rng);
            decoded.push(next);
            sink.on_token(next);
            self.cache.promote_root_to_past()?;
            self.cache.compact_tree(&[]);
            let dt = t0.elapsed().as_secs_f64();
            modeled_s += dt;
            metrics.record("token_s", dt);
        }

        metrics.incr("tokens", decoded.len() as u64);
        self.rt
            .stats()
            .snapshot()
            .delta_since(&hd_prefill)
            .record_hd_metrics(&mut metrics);
        Ok(DecodeOutput {
            text: tokenizer::decode(&decoded),
            tokens: decoded,
            wall_s: wall0.elapsed().as_secs_f64(),
            modeled_s,
            spec: None,
            metrics,
        })
    }
}
