//! STPP baseline: Static Tree Pipeline Parallelism (paper §4.2), inspired by
//! SpecInfer's tree-based speculative decoding.
//!
//! Per round: the draft model builds a *complete* prediction tree serially
//! (depth-by-depth, all layers before verification), bounded by the single
//! verification batch the hardware admits — here the artifact `width_cap`,
//! exactly the "limited number of tree nodes" constraint the paper contrasts
//! PipeDec against. The whole tree then traverses the pipeline once; the
//! target's logits are walked from the root along matching children, and
//! the longest accepted path is committed (and streamed to the caller's
//! `TokenSink` as one burst per round).

use std::path::Path;
use std::time::Instant;

use anyhow::{Context, Result};

use crate::config::EngineConfig;
use crate::coordinator::sampling::{select_token, top_candidates};
use crate::engine::{DecodeOutput, DecodeRequest, Engine, EngineKind, SpecStats, TokenSink};
use crate::kvcache::TwoLevelCache;
use crate::metrics::Metrics;
use crate::model::{bias, ModelHandles};
use crate::runtime::Runtime;
use crate::tokenizer;
use crate::transport::{LinkModel, LinkStats};
use crate::tree::PredictionTree;
use crate::util::XorShiftRng;

pub struct StppEngine {
    rt: Runtime,
    target: ModelHandles,
    draft: ModelHandles,
    pub cfg: EngineConfig,
    layers_per_stage: usize,
    stage_caches: Vec<TwoLevelCache>,
    draft_cache: TwoLevelCache,
    link: LinkModel,
    pub link_stats: LinkStats,
    rng: XorShiftRng,
    /// Static tree depth per round.
    pub tree_depth: usize,
}

impl StppEngine {
    pub fn new(artifact_dir: &Path, mut cfg: EngineConfig) -> Result<Self> {
        cfg.validate()?;
        let rt = Runtime::cpu()?;
        let target = ModelHandles::load(&rt, artifact_dir, "target")?;
        let draft = ModelHandles::load(&rt, artifact_dir, "draft")?;
        anyhow::ensure!(
            target.cfg.n_layers % cfg.stages == 0,
            "stages must divide layer count"
        );
        // the whole static tree must fit one verification batch
        cfg.tree.max_width = cfg.tree.max_width.min(target.cfg.width_cap / 2);
        let layers_per_stage = target.cfg.n_layers / cfg.stages;
        let tc = &target.cfg;
        let stage_caches = (0..cfg.stages)
            .map(|_| {
                TwoLevelCache::new(
                    layers_per_stage,
                    tc.n_heads,
                    tc.head_dim,
                    tc.past_cap,
                    tc.tree_cap,
                )
            })
            .collect();
        let dc = &draft.cfg;
        let draft_cache =
            TwoLevelCache::new(dc.n_layers, dc.n_heads, dc.head_dim, dc.past_cap, dc.tree_cap);
        let rng = XorShiftRng::new(cfg.seed);
        let tree_depth = cfg.tree.max_depth.min(6);
        Ok(Self {
            rt,
            target,
            draft,
            cfg,
            layers_per_stage,
            stage_caches,
            draft_cache,
            link: LinkModel::pcie_p2p(),
            link_stats: LinkStats::default(),
            rng,
            tree_depth,
        })
    }

    fn layer_range(&self, s: usize) -> std::ops::Range<usize> {
        s * self.layers_per_stage..(s + 1) * self.layers_per_stage
    }

    /// Build the static tree for one round with serial draft inference.
    /// Returns (tree, draft seconds).
    fn build_static_tree(&mut self, root: u32, root_pos: usize) -> Result<(PredictionTree, f64)> {
        let dc = self.draft.cfg.clone();
        let budget = self.target.cfg.width_cap; // one verification batch
        let mut tree = PredictionTree::new(self.cfg.tree, budget, root, root_pos);
        self.draft_cache.clear_tree();
        let mut secs = 0.0;
        for _ in 0..self.tree_depth {
            let start = self.draft_cache.tree_len();
            if start >= tree.len() || tree.len() >= budget {
                break;
            }
            let t0 = Instant::now();
            let indices: Vec<usize> = (start..tree.len()).collect();
            let tokens: Vec<u32> = indices.iter().map(|&i| tree.token(i)).collect();
            let mut pos = vec![0i32; dc.width_cap];
            for (r, &i) in indices.iter().enumerate() {
                pos[r] = tree.position_of(i) as i32;
            }
            let rows = tree.bias_rows(&indices, dc.tree_cap, bias::NEG);
            let tree_bias = bias::pad_tree_bias_rows(
                rows,
                indices.len(),
                start,
                dc.width_cap,
                dc.tree_cap,
            );
            let logits = self.draft.full_forward_tree_block(
                &self.rt,
                &mut self.draft_cache,
                &tokens,
                &pos,
                &tree_bias,
            )?;
            let v = dc.vocab_size;
            let cands: Vec<Vec<(u32, f32)>> = (0..indices.len())
                .map(|r| top_candidates(&logits[r * v..(r + 1) * v], self.cfg.tree.max_children))
                .collect();
            let added = tree.expand_layer(&cands);
            secs += t0.elapsed().as_secs_f64();
            if added.is_empty() {
                break;
            }
        }
        Ok((tree, secs))
    }
}

impl Engine for StppEngine {
    fn kind(&self) -> EngineKind {
        EngineKind::Stpp
    }

    fn config(&self) -> &EngineConfig {
        &self.cfg
    }

    fn decode(&mut self, req: &DecodeRequest, sink: &mut dyn TokenSink) -> Result<DecodeOutput> {
        let (max_new, sampling, seed) = req.resolve(&self.cfg);
        anyhow::ensure!(max_new >= 1, "max_new_tokens must be >= 1");
        for c in &mut self.stage_caches {
            c.reset();
        }
        self.draft_cache.reset();
        self.rng = XorShiftRng::new(seed);
        let mut metrics = Metrics::new();
        let tc = self.target.cfg.clone();
        let (w, v) = (tc.width_cap, tc.vocab_size);

        anyhow::ensure!(
            max_new + 2 < tc.past_cap,
            "max_new_tokens {max_new} exceeds the model context budget ({})",
            tc.past_cap
        );
        let max_prompt = tc.past_cap - max_new - 2;
        let mut ids = tokenizer::encode(&req.prompt);
        ids.truncate(max_prompt);
        anyhow::ensure!(!ids.is_empty(), "empty prompt");

        // target prefill
        let mut last_h = None;
        let mut last_count = 0;
        for chunk in ids.chunks(w) {
            let start = self.stage_caches[0].past_len();
            let mut h = self.target.embed(&self.rt, chunk)?;
            for s in 0..self.cfg.stages {
                let r = self.layer_range(s);
                h = self.target.prefill_chunk(
                    &self.rt,
                    r,
                    &mut self.stage_caches[s],
                    h,
                    chunk.len(),
                    start,
                )?;
            }
            last_count = chunk.len();
            last_h = Some(h);
        }
        let logits = self.target.head(&self.rt, &last_h.context("empty prompt")?)?;
        let mut next = select_token(
            &logits[(last_count - 1) * v..last_count * v],
            &sampling,
            &mut self.rng,
        );
        self.draft.full_prefill(&self.rt, &mut self.draft_cache, &ids)?;

        let hd_prefill = self.rt.stats().snapshot();
        let wall0 = Instant::now();
        let mut modeled_s = 0.0;
        let mut decoded = vec![next];
        sink.on_token(next);
        let mut rounds = 0u64;
        let d_bytes = tc.dim * w * 4;

        while decoded.len() < max_new && next != tokenizer::EOS_ID {
            rounds += 1;
            let root_pos = self.stage_caches[0].past_len();
            let (tree, draft_s) = self.build_static_tree(next, root_pos)?;
            modeled_s += draft_s;

            // one pipeline verification pass over the whole tree
            let count = tree.len();
            let all: Vec<usize> = (0..count).collect();
            let tokens: Vec<u32> = tree.tokens().to_vec();
            let mut pos = vec![0i32; w];
            for (r, &i) in all.iter().enumerate() {
                pos[r] = tree.position_of(i) as i32;
            }
            let rows = tree.bias_rows(&all, tc.tree_cap, bias::NEG);
            let tree_bias = bias::pad_tree_bias_rows(rows, count, 0, w, tc.tree_cap);

            let mut h = self.target.embed(&self.rt, &tokens)?;
            let mut pass_s = 0.0;
            for s in 0..self.cfg.stages {
                let t0 = Instant::now();
                let r = self.layer_range(s);
                h = self.target.stage_forward(
                    &self.rt,
                    r,
                    &mut self.stage_caches[s],
                    h,
                    count,
                    &pos,
                    &tree_bias,
                )?;
                pass_s += t0.elapsed().as_secs_f64();
                if s + 1 < self.cfg.stages {
                    pass_s += self.link.transfer_time(d_bytes);
                    self.link_stats.record(d_bytes, &self.link);
                }
            }
            let t0 = Instant::now();
            let logits = self.target.head(&self.rt, &h)?;
            pass_s += t0.elapsed().as_secs_f64();
            modeled_s += pass_s;

            // walk the tree from the root along matching children
            let mut node = 0usize;
            let mut path = vec![0usize];
            let mut accepted = Vec::new();
            loop {
                let x = select_token(&logits[node * v..(node + 1) * v], &sampling, &mut self.rng);
                accepted.push(x);
                if decoded.len() + accepted.len() >= max_new || x == tokenizer::EOS_ID {
                    break;
                }
                match tree.children_of(node).into_iter().find(|&c| tree.token(c) == x) {
                    Some(child) => {
                        path.push(child);
                        node = child;
                    }
                    None => break,
                }
            }

            // promote the accepted path's KV (root + matched children)
            for c in &mut self.stage_caches {
                for &slot in &path {
                    c.promote_slot_to_past(slot)?;
                }
                c.clear_tree();
            }
            // keep the draft's model-level cache in sync: replay accepted
            // tokens through the draft as width-1 prefill-style blocks
            {
                let dc = self.draft.cfg.clone();
                self.draft_cache.clear_tree();
                for (k, &_slot) in path.iter().enumerate() {
                    let tok = if k == 0 { next } else { accepted[k - 1] };
                    let start = self.draft_cache.past_len();
                    let hlocal = self.draft.embed(&self.rt, &[tok])?;
                    self.draft.prefill_chunk(
                        &self.rt,
                        0..dc.n_layers,
                        &mut self.draft_cache,
                        hlocal,
                        1,
                        start,
                    )?;
                }
            }

            metrics.record("accepted_per_round", accepted.len() as f64);
            decoded.extend(&accepted);
            for &t in &accepted {
                sink.on_token(t);
            }
            next = *accepted.last().unwrap();
        }

        let acc = metrics.summary("accepted_per_round").mean();
        metrics.incr("rounds", rounds);
        metrics.incr("tokens", decoded.len() as u64);
        self.rt
            .stats()
            .snapshot()
            .delta_since(&hd_prefill)
            .record_hd_metrics(&mut metrics);
        Ok(DecodeOutput {
            text: tokenizer::decode(&decoded),
            tokens: decoded,
            wall_s: wall0.elapsed().as_secs_f64(),
            modeled_s,
            spec: Some(SpecStats {
                timesteps: 0, // STPP has no pipeline-timestep notion
                rounds,
                hits: 0,
                misses: 0,
                accepted_per_round: acc,
            }),
            metrics,
        })
    }
}
