//! PP baseline: standard pipeline parallelism (paper §4.2, "Pipeline
//! Parallelism"). One token decodes per full pipeline traversal — the
//! latency the paper's §2.4 motivation formula describes:
//! `sum_i T_c,i + sum_i T_t,i` per token.

use std::path::Path;
use std::time::Instant;

use anyhow::{Context, Result};

use crate::config::EngineConfig;
use crate::coordinator::sampling::select_token;
use crate::engine::{DecodeOutput, DecodeRequest, Engine, EngineKind, TokenSink};
use crate::kvcache::TwoLevelCache;
use crate::metrics::Metrics;
use crate::model::{bias, ModelHandles};
use crate::runtime::Runtime;
use crate::tokenizer;
use crate::transport::{LinkModel, LinkStats};
use crate::util::XorShiftRng;

pub struct PpEngine {
    rt: Runtime,
    target: ModelHandles,
    pub cfg: EngineConfig,
    layers_per_stage: usize,
    stage_caches: Vec<TwoLevelCache>,
    link: LinkModel,
    pub link_stats: LinkStats,
    rng: XorShiftRng,
}

impl PpEngine {
    pub fn new(artifact_dir: &Path, cfg: EngineConfig) -> Result<Self> {
        cfg.validate()?;
        let rt = Runtime::cpu()?;
        // PP decodes width-1 blocks: the narrow artifact bucket suffices
        let target = ModelHandles::load_with_width(&rt, artifact_dir, "target", 1)?;
        anyhow::ensure!(
            target.cfg.n_layers % cfg.stages == 0,
            "stages must divide layer count"
        );
        let layers_per_stage = target.cfg.n_layers / cfg.stages;
        let tc = &target.cfg;
        let stage_caches = (0..cfg.stages)
            .map(|_| {
                TwoLevelCache::new(
                    layers_per_stage,
                    tc.n_heads,
                    tc.head_dim,
                    tc.past_cap,
                    tc.tree_cap,
                )
            })
            .collect();
        let rng = XorShiftRng::new(cfg.seed);
        Ok(Self {
            rt,
            target,
            cfg,
            layers_per_stage,
            stage_caches,
            link: LinkModel::pcie_p2p(),
            link_stats: LinkStats::default(),
            rng,
        })
    }

    fn layer_range(&self, s: usize) -> std::ops::Range<usize> {
        s * self.layers_per_stage..(s + 1) * self.layers_per_stage
    }
}

impl Engine for PpEngine {
    fn kind(&self) -> EngineKind {
        EngineKind::Pp
    }

    fn config(&self) -> &EngineConfig {
        &self.cfg
    }

    fn decode(&mut self, req: &DecodeRequest, sink: &mut dyn TokenSink) -> Result<DecodeOutput> {
        let (max_new, sampling, seed) = req.resolve(&self.cfg);
        anyhow::ensure!(max_new >= 1, "max_new_tokens must be >= 1");
        for c in &mut self.stage_caches {
            c.reset();
        }
        self.rng = XorShiftRng::new(seed);
        let mut metrics = Metrics::new();
        let tc = self.target.cfg.clone();
        let w = tc.width_cap;

        anyhow::ensure!(
            max_new + 2 < tc.past_cap,
            "max_new_tokens {max_new} exceeds the model context budget ({})",
            tc.past_cap
        );
        let max_prompt = tc.past_cap - max_new - 2;
        let mut ids = tokenizer::encode(&req.prompt);
        ids.truncate(max_prompt);
        anyhow::ensure!(!ids.is_empty(), "empty prompt");

        // prefill
        let mut last_h = None;
        let mut last_count = 0;
        for chunk in ids.chunks(w) {
            let start = self.stage_caches[0].past_len();
            let mut h = self.target.embed(&self.rt, chunk)?;
            for s in 0..self.cfg.stages {
                let r = self.layer_range(s);
                h = self.target.prefill_chunk(
                    &self.rt,
                    r,
                    &mut self.stage_caches[s],
                    h,
                    chunk.len(),
                    start,
                )?;
            }
            last_count = chunk.len();
            last_h = Some(h);
        }
        let logits = self.target.head(&self.rt, &last_h.context("empty prompt")?)?;
        let v = tc.vocab_size;
        let mut next = select_token(
            &logits[(last_count - 1) * v..last_count * v],
            &sampling,
            &mut self.rng,
        );

        // decode: one token per full pipeline pass
        let hd_prefill = self.rt.stats().snapshot();
        let wall0 = Instant::now();
        let mut modeled_s = 0.0;
        let mut decoded = vec![next];
        sink.on_token(next);
        let d_bytes = tc.dim * w * 4;
        while decoded.len() < max_new && next != tokenizer::EOS_ID {
            let pos0 = self.stage_caches[0].past_len();
            let mut pos = vec![0i32; w];
            pos[0] = pos0 as i32;
            let tree_bias = bias::pad_tree_bias_rows(Vec::new(), 0, 0, w, tc.tree_cap);

            let mut h = self.target.embed(&self.rt, &[next])?;
            let mut token_s = 0.0;
            for s in 0..self.cfg.stages {
                let t0 = Instant::now();
                let r = self.layer_range(s);
                h = self.target.stage_forward(
                    &self.rt,
                    r,
                    &mut self.stage_caches[s],
                    h,
                    1,
                    &pos,
                    &tree_bias,
                )?;
                token_s += t0.elapsed().as_secs_f64();
                if s + 1 < self.cfg.stages {
                    let t = self.link.transfer_time(d_bytes);
                    self.link_stats.record(d_bytes, &self.link);
                    token_s += t;
                }
            }
            let t0 = Instant::now();
            let logits = self.target.head(&self.rt, &h)?;
            token_s += t0.elapsed().as_secs_f64();
            next = select_token(&logits[..v], &sampling, &mut self.rng);
            decoded.push(next);
            sink.on_token(next);
            for c in &mut self.stage_caches {
                c.promote_root_to_past()?;
                c.clear_tree();
            }
            // PP latency = sum of stage computes + sum of transfers
            modeled_s += token_s;
            metrics.record("token_s", token_s);
        }

        metrics.incr("tokens", decoded.len() as u64);
        self.rt
            .stats()
            .snapshot()
            .delta_since(&hd_prefill)
            .record_hd_metrics(&mut metrics);
        Ok(DecodeOutput {
            text: tokenizer::decode(&decoded),
            tokens: decoded,
            wall_s: wall0.elapsed().as_secs_f64(),
            modeled_s,
            spec: None,
            metrics,
        })
    }
}
