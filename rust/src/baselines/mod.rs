//! Comparison baselines (paper §4.2):
//!
//! * **PP** — standard pipeline parallelism: one token per full pipeline
//!   traversal, no speculation ([`pp::PpEngine`]).
//! * **STPP** — static-tree pipeline speculative decoding, the SpecInfer-
//!   inspired baseline: the draft builds a whole bounded tree serially, the
//!   pipeline verifies it in a single pass, the longest matching root path
//!   is accepted ([`stpp::StppEngine`]).
//! * **SLM** — the small model served standalone on one device
//!   ([`slm::SlmEngine`]).
//!
//! All engines share the artifact runtime, sampling, and reporting so the
//! figure benches compare like for like.

pub mod pp;
pub mod slm;
pub mod stpp;

pub use pp::PpEngine;
pub use slm::SlmEngine;
pub use stpp::StppEngine;

use crate::metrics::Metrics;

/// Common result shape for baseline decodes.
#[derive(Debug, Clone)]
pub struct BaselineResult {
    pub tokens: Vec<u32>,
    pub text: String,
    /// Wall-clock decode seconds.
    pub wall_s: f64,
    /// Modeled parallel-schedule seconds (pipeline-aware; equals wall-ish
    /// time for SLM).
    pub modeled_s: f64,
    /// Accepted speculative tokens per verification round (STPP only; 0
    /// elsewhere).
    pub accepted_per_round: f64,
    pub metrics: Metrics,
}

impl BaselineResult {
    pub fn modeled_s_per_token(&self) -> f64 {
        if self.tokens.is_empty() {
            0.0
        } else {
            self.modeled_s / self.tokens.len() as f64
        }
    }
}
