//! Comparison baselines (paper §4.2):
//!
//! * **PP** — standard pipeline parallelism: one token per full pipeline
//!   traversal, no speculation ([`pp::PpEngine`]).
//! * **STPP** — static-tree pipeline speculative decoding, the SpecInfer-
//!   inspired baseline: the draft builds a whole bounded tree serially, the
//!   pipeline verifies it in a single pass, the longest matching root path
//!   is accepted ([`stpp::StppEngine`]).
//! * **SLM** — the small model served standalone on one device
//!   ([`slm::SlmEngine`]).
//!
//! All baselines implement the crate-wide [`crate::engine::Engine`] trait
//! and return the unified [`crate::engine::DecodeOutput`], so the figure
//! benches, server, and CLI compare like for like through
//! [`crate::engine::build_engine`].

pub mod pp;
pub mod slm;
pub mod stpp;

pub use pp::PpEngine;
pub use slm::SlmEngine;
pub use stpp::StppEngine;
