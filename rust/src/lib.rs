//! PipeDec / SpecPipe: pipeline-parallel LLM inference accelerated with
//! dynamic-tree speculative decoding.
//!
//! Reproduction of "PipeDec: Low-Latency Pipeline-based Inference with
//! Dynamic Speculative Decoding towards Large-scale Models" as a
//! three-layer Rust + JAX + Pallas stack:
//!
//! * L1 (build time) — Pallas dynamic tree attention kernel
//!   (`python/compile/kernels/`);
//! * L2 (build time) — LLaMA-style decoder lowered per entry point to HLO
//!   text artifacts (`python/compile/model.py`, `aot.py`);
//! * L3 (this crate) — the serving system, organized around the public
//!   inference API in [`engine`].
//!
//! # Module map
//!
//! The API layer every caller goes through:
//!
//! * [`engine`] — the crate's public inference surface: the [`engine::Engine`]
//!   trait, unified [`engine::DecodeRequest`] / [`engine::DecodeOutput`]
//!   shapes, the [`engine::TokenSink`] streaming observer, and the
//!   [`engine::EngineKind`] registry + [`engine::build_engine`] factory.
//!   On top of it, [`engine::session`] is the step-driven scheduling
//!   surface: [`engine::ScheduledEngine`]
//!   (`submit`/`step`/`cancel`/`poll` over per-request
//!   [`engine::Session`]s) built by [`engine::build_scheduled_engine`] —
//!   SpecPipe-DB schedules natively, every one-shot kind rides the
//!   [`engine::OneShotScheduler`] adapter. New decoding strategies (async
//!   stages, alternative backends) plug in here.
//!
//! The strategies served behind it:
//!
//! * [`coordinator`] — the PipeDec engines: the single-task engine
//!   (timestep groups, draft in the pipeline, dynamic prediction tree,
//!   hit/miss synchronization), the SpecPipe-DB continuous-batching
//!   scheduler ([`coordinator::PipeDecDbEngine`], per-session caches and
//!   trees interleaved over the pipeline slots), the per-request
//!   mechanics they share ([`coordinator::pipeline`]), and token sampling.
//!   Both engines execute each timestep's task set on the persistent
//!   pipeline worker pool ([`coordinator::workers::WorkerPool`], one
//!   thread per stage group plus the draft node, `EngineConfig::threads`):
//!   state moves into jobs and back by ownership, verification stays at
//!   the coordinator's sync phase, and `threads = 1` runs the identical
//!   jobs inline as the sequential reference path — token outputs are
//!   identical at every thread count. The sync phase itself is split
//!   decide/commit (ISSUE 5, `EngineConfig::overlap_sync`): the
//!   coordinator keeps the decision (verify/sample/prune) and issues a
//!   replayable [`kvcache::CacheCommit`] that each cache owner drains at
//!   the start of its next job, overlapping cache maintenance (KV
//!   promotion + tree compaction + mirror re-upload) with the next
//!   timestep's compute; stage tasks read [`tree::TreeSnapshot`]s, never
//!   the canonical tree. Outputs are bit-identical with the overlap on
//!   or off. With `EngineConfig::spec_inflight > 1` (ISSUE 10) the draft
//!   additionally free-runs when idle, banking epoch-tagged speculative
//!   tree generations ([`coordinator::spec::SpecBank`]) that the
//!   coordinator serves in place of the next draft dispatch when still
//!   valid — and drops whole when stale — keeping outputs bit-identical
//!   to lockstep while raising pipeline occupancy.
//! * [`baselines`] — PP / STPP / SLM comparison engines (paper §4.2).
//!
//! The substrate they share:
//!
//! * [`runtime`], [`model`], [`weights`] — PJRT execution of the AOT
//!   artifacts (Python never runs on the request path). The model state is
//!   split for threaded execution: [`model::ModelCore`] is the shared
//!   read-only core (config, resolved executables, resident weight
//!   buffers; `Send + Sync` via the audited PJRT wrappers in [`runtime`])
//!   behind an `Arc`, [`model::StageContext`] is the per-stage-group
//!   mutable state (device KV mirrors, incremental bias) each worker task
//!   owns while it runs, and [`model::ModelHandles`] is the sequential
//!   pairing of the two kept for baselines/benches. The hot path is
//!   **device-resident**: [`runtime::Executable::run_bufs`] executes with
//!   [`runtime::DeviceBuffer`] arguments, weights upload once at load,
//!   and per-cache [`kvcache::device::DeviceKvCache`] mirrors are updated
//!   **in place** (ISSUE 7): donated single-output entry points —
//!   executed through [`runtime::Executable::run_bufs_to_bufs`], which
//!   consumes the donated buffer by move — scatter each freshly computed
//!   KV block into the resident tensors and replay sync commits
//!   (promote + compact) on-device, so steady-state decode moves only the
//!   appended rows; a full level re-upload remains the fallback for
//!   stale/shape-mismatched mirrors. The past bias grows incrementally
//!   ([`model::bias::PastBiasCache`]), and hidden states hand off between
//!   a stage's layers without host `Vec` round-trips (the output tuple
//!   still crosses to the host once per layer — see the [`model`] docs
//!   for the exact boundary).
//!   [`runtime::TransferStats`] accounts the host↔device traffic
//!   (`rust/benches/bench_hotpath.rs` → `BENCH_hotpath.json`;
//!   `rust/benches/bench_async.rs` → `BENCH_async.json` for wall vs
//!   modeled latency per worker-thread count).
//! * [`tree`], [`kvcache`], [`schedule`], [`transport`], [`workflow`] — the
//!   dynamic prediction tree (plus the [`tree::TreeSnapshot`] read view
//!   stage tasks run against), two-level KV cache (with per-layer dirty
//!   epochs feeding the device mirror, and the epoch-ordered
//!   [`kvcache::CacheCommit`] replay protocol for the overlapped sync
//!   phase), transmission scheduler, link model, and the workflow DAG
//!   controller. [`kvcache::prefix`] (ISSUE 8) is the tiered
//!   cross-request prefix cache: a content-addressed
//!   [`kvcache::prefix::PrefixStore`] keys chunk-aligned token prefixes
//!   of the context-truncated prompt by rolling hash and holds one
//!   chunk's past-KV per block — L1 as `Arc`-shared read-only host
//!   tensors, L2 as a checksummed disk spill directory, LRU eviction
//!   against per-tier byte budgets, promotion back to L1 on hit.
//!   Engines probe it at admission, seed session caches from the hit
//!   chain, prefill only the uncovered suffix, and insert the session's
//!   own blocks afterward; configured by the `[prefix_cache]` TOML
//!   section / `--prefix-*` CLI flags, measured by
//!   `rust/benches/bench_prefix.rs` → `BENCH_prefix.json`.
//!
//! * [`config`], [`tokenizer`], [`metrics`], [`util`] — configuration
//!   (TOML subset), byte-level tokenizer, metrics/tables (including the
//!   thread-safe [`metrics::SharedMetrics`] sink the pipeline workers
//!   record into), numeric helpers.
//! * [`faultinject`] — the deterministic fault-injection layer (ISSUE 9):
//!   a seeded, text-serializable [`faultinject::FaultPlan`] injects
//!   panics, errors, and delays at named choke points (stage/draft jobs,
//!   commit replay, device KV ops, prefix spill I/O, worker exit) so the
//!   chaos suite (`tests/chaos.rs`) can drive per-session failure
//!   domains deterministically. Disarmed (the default) it costs one
//!   relaxed atomic load per choke point.
//! * [`concurrency`] — the concurrency-correctness harness (ISSUE 6):
//!   the [`concurrency::sync`] facade every threaded module imports its
//!   primitives through (std normally, schedule-perturbing shim under
//!   `--cfg loom`), the pure decide/commit protocol core
//!   ([`concurrency::protocol::CommitLog`] /
//!   [`concurrency::protocol::CommitCursor`] /
//!   [`concurrency::protocol::verify_drained`]) shared by the engines and
//!   cache owners, and the explicit-state model checker
//!   ([`concurrency::explore`], driven by `tests/loom_protocol.rs`) that
//!   exhaustively verifies the protocol's invariants. The crate-wide
//!   unsafe-audit wall (`unsafe_op_in_unsafe_fn`,
//!   `clippy::undocumented_unsafe_blocks`) is declared below; the
//!   Send/Sync audit, job-ownership protocol, commit-epoch invariants,
//!   and instructions for the loom/Miri/TSan lanes live in
//!   `rust/CONCURRENCY.md`.
//!
//! Serving, evaluation, and paper-scale extrapolation:
//!
//! * [`server`] — router (bounded FIFO admission) + the continuous-batching
//!   event loop [`server::serve_until_idle`] over any `dyn ScheduledEngine`,
//!   with per-request overrides, per-request TTFT / time-between-tokens
//!   capture (the Fig. 8 serving metrics), and the per-decode sync-phase
//!   breakdown (`t_decide_s` / `t_commit_s` / overlap ratio);
//!   [`server::drain`] remains the closed-batch convenience over a plain
//!   `dyn Engine`.
//! * [`sim`] — calibrated cluster simulator for paper-scale figures.
//! * [`workload`], [`bench_support`] — the six evaluation domains and the
//!   bench harness used by `rust/benches/fig*.rs`.
//!
//! # Environment knobs
//!
//! Every `PIPEDEC_*` variable the crate reads:
//!
//! * `PIPEDEC_ARTIFACTS` — artifacts directory override (see
//!   [`artifacts_dir`]); tests and benches skip gracefully when the
//!   directory has no built artifacts.
//! * `PIPEDEC_NO_KV_APPEND` — force the device KV mirror onto the full
//!   re-upload fallback instead of the donated in-place append path
//!   (ISSUE 7 baseline; read once at model load).
//! * `PIPEDEC_NO_PREFIX_CACHE` — kill-switch for the cross-request
//!   prefix cache, overriding an enabled `[prefix_cache]` config (read
//!   once at engine construction; ISSUE 8).
//! * `PIPEDEC_LOOM_SEED` — schedule seed for the loom-style
//!   schedule-perturbing shim in [`concurrency::sync`] (only meaningful
//!   under `--cfg loom`).
//! * `PIPEDEC_FAULTS` — arm a [`faultinject::FaultPlan`] (grammar:
//!   `site@hit=kind,...`, e.g. `stage_job@3=panic`) at engine
//!   construction; empty/unset leaves fault injection disarmed (ISSUE 9).
//! * `PIPEDEC_CHAOS_SEED` — seed for the randomized nightly chaos lane
//!   in `tests/chaos.rs` (`--ignored` test); the failing plan is printed
//!   serialized for replay through `PIPEDEC_FAULTS`.

// Unsafe-audit wall (ISSUE 6): every `unsafe` block, fn, and impl in
// this crate must carry a `// SAFETY:` comment, and unsafe operations
// inside `unsafe fn` bodies need their own explicit `unsafe {}` scope.
// CI runs clippy with `-D warnings -D clippy::undocumented_unsafe_blocks`
// so an undocumented block is a build failure, not a review nit.
#![deny(unsafe_op_in_unsafe_fn)]
#![deny(clippy::undocumented_unsafe_blocks)]

pub mod baselines;
pub mod bench_support;
pub mod concurrency;
pub mod config;
pub mod coordinator;
pub mod engine;
pub mod faultinject;
pub mod kvcache;
pub mod metrics;
pub mod model;
pub mod proputil;
pub mod runtime;
pub mod schedule;
pub mod server;
pub mod sim;
pub mod tokenizer;
pub mod transport;
pub mod tree;
pub mod util;
pub mod weights;
pub mod workflow;
pub mod workload;

/// Crate version (for the CLI banner).
pub fn version() -> &'static str {
    env!("CARGO_PKG_VERSION")
}

/// Default artifacts directory, overridable with `PIPEDEC_ARTIFACTS`.
pub fn artifacts_dir() -> std::path::PathBuf {
    std::env::var_os("PIPEDEC_ARTIFACTS")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(|| std::path::PathBuf::from("artifacts"))
}
