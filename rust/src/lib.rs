//! PipeDec / SpecPipe: pipeline-parallel LLM inference accelerated with
//! dynamic-tree speculative decoding.
//!
//! Reproduction of "PipeDec: Low-Latency Pipeline-based Inference with
//! Dynamic Speculative Decoding towards Large-scale Models" as a
//! three-layer Rust + JAX + Pallas stack:
//!
//! * L1 (build time) — Pallas dynamic tree attention kernel
//!   (`python/compile/kernels/`);
//! * L2 (build time) — LLaMA-style decoder lowered per entry point to HLO
//!   text artifacts (`python/compile/model.py`, `aot.py`);
//! * L3 (this crate) — the serving system: dynamic prediction tree,
//!   two-level KV cache, pipeline engine with timestep groups, transmission
//!   scheduler, workflow DAG controller, baselines (PP / STPP / SLM), a
//!   calibrated cluster simulator for paper-scale figures, and a request
//!   server.
//!
//! Python never runs on the request path: artifacts are loaded and executed
//! through the PJRT CPU client (`runtime`).

pub mod baselines;
pub mod bench_support;
pub mod config;
pub mod coordinator;
pub mod kvcache;
pub mod metrics;
pub mod model;
pub mod proputil;
pub mod runtime;
pub mod schedule;
pub mod server;
pub mod sim;
pub mod tokenizer;
pub mod transport;
pub mod tree;
pub mod util;
pub mod weights;
pub mod workflow;
pub mod workload;

/// Crate version (for the CLI banner).
pub fn version() -> &'static str {
    env!("CARGO_PKG_VERSION")
}

/// Default artifacts directory, overridable with `PIPEDEC_ARTIFACTS`.
pub fn artifacts_dir() -> std::path::PathBuf {
    std::env::var_os("PIPEDEC_ARTIFACTS")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(|| std::path::PathBuf::from("artifacts"))
}
