//! Request server: router + FIFO batcher + engine worker.
//!
//! PipeDec is a *single-task* accelerator (it commits every pipeline stage
//! to one request), so the server runs one engine worker and a bounded
//! admission queue; the paper's Fig. 8 process-pool experiment maps to
//! submitting `k` concurrent requests and measuring completion throughput.
//! The router is engine-agnostic: it queues [`DecodeRequest`]s (prompt plus
//! per-request overrides) and [`drain`] serves them through any
//! `&mut dyn Engine` — all four [`crate::engine::EngineKind`]s go through
//! the same front end via [`crate::engine::build_engine`]. Service is
//! streaming-aware: the worker observes the engine's token stream and
//! records time-to-first-token on every [`Completion`].

use std::collections::VecDeque;
use std::time::Instant;

use anyhow::Result;

use crate::engine::{DecodeRequest, Engine, TokenSink};
use crate::metrics::Metrics;
use crate::util::Summary;

/// A queued request.
#[derive(Debug, Clone)]
pub struct Request {
    pub id: u64,
    pub req: DecodeRequest,
    pub arrived_at: f64,
}

/// A finished request.
#[derive(Debug, Clone)]
pub struct Completion {
    pub id: u64,
    /// Registry name of the engine that served the request.
    pub engine: &'static str,
    pub tokens: usize,
    /// queueing delay + service, seconds
    pub latency_s: f64,
    pub service_s: f64,
    /// Service start until the first streamed token, seconds.
    pub first_token_s: f64,
    /// Modeled parallel-schedule decode seconds reported by the engine.
    pub modeled_s: f64,
}

/// FIFO admission queue with a capacity bound (backpressure).
#[derive(Debug)]
pub struct Router {
    queue: VecDeque<Request>,
    capacity: usize,
    next_id: u64,
    clock0: Instant,
}

impl Router {
    pub fn new(capacity: usize) -> Self {
        Self {
            queue: VecDeque::new(),
            capacity,
            next_id: 0,
            clock0: Instant::now(),
        }
    }

    /// Queue a full decode request (prompt + per-request overrides).
    /// Returns the request id, or Err when the queue is full.
    pub fn submit(&mut self, req: DecodeRequest) -> Result<u64> {
        anyhow::ensure!(self.queue.len() < self.capacity, "queue full");
        let id = self.next_id;
        self.next_id += 1;
        self.queue.push_back(Request {
            id,
            req,
            arrived_at: self.clock0.elapsed().as_secs_f64(),
        });
        Ok(id)
    }

    /// Convenience: queue a bare prompt with no overrides.
    pub fn submit_prompt(&mut self, prompt: &str) -> Result<u64> {
        self.submit(DecodeRequest::new(prompt))
    }

    pub fn depth(&self) -> usize {
        self.queue.len()
    }

    pub fn pop(&mut self) -> Option<Request> {
        self.queue.pop_front()
    }

    pub fn now(&self) -> f64 {
        self.clock0.elapsed().as_secs_f64()
    }
}

/// Records the instant of the first streamed token relative to service
/// start — the server's time-to-first-token probe.
struct FirstTokenProbe {
    start: Instant,
    first_s: Option<f64>,
    tokens: usize,
}

impl FirstTokenProbe {
    fn new() -> Self {
        Self {
            start: Instant::now(),
            first_s: None,
            tokens: 0,
        }
    }
}

impl TokenSink for FirstTokenProbe {
    fn on_token(&mut self, _token: u32) {
        if self.first_s.is_none() {
            self.first_s = Some(self.start.elapsed().as_secs_f64());
        }
        self.tokens += 1;
    }
}

/// Serve everything currently queued through an engine, FIFO. Returns
/// per-request completions with full-latency and first-token timings.
pub fn drain(router: &mut Router, engine: &mut dyn Engine) -> Result<Vec<Completion>> {
    let mut out = Vec::new();
    while let Some(req) = router.pop() {
        let mut probe = FirstTokenProbe::new();
        let result = engine.decode(&req.req, &mut probe)?;
        let service = probe.start.elapsed().as_secs_f64();
        debug_assert_eq!(probe.tokens, result.tokens.len());
        out.push(Completion {
            id: req.id,
            engine: engine.name(),
            tokens: result.tokens.len(),
            latency_s: router.now() - req.arrived_at,
            service_s: service,
            first_token_s: probe.first_s.unwrap_or(service),
            modeled_s: result.modeled_s,
        });
    }
    Ok(out)
}

/// Aggregate a batch of completions into the numbers Fig. 8 reports.
/// Returns counters/series (including `first_token_s`) and the full-latency
/// sample summary.
pub fn summarize(completions: &[Completion], wall_s: f64) -> (Metrics, Summary) {
    let mut m = Metrics::new();
    let mut lat = Vec::new();
    let mut total_tokens = 0usize;
    for c in completions {
        m.incr("requests", 1);
        m.incr("tokens", c.tokens as u64);
        m.record("latency_s", c.latency_s);
        m.record("first_token_s", c.first_token_s);
        lat.push(c.latency_s);
        total_tokens += c.tokens;
    }
    if wall_s > 0.0 {
        m.record("throughput_tok_s", total_tokens as f64 / wall_s);
    }
    (m, Summary::from_samples(lat))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::EngineConfig;
    use crate::engine::{DecodeOutput, EngineKind};
    use crate::tokenizer;

    /// Test double: "decodes" by echoing the prompt's token ids, streaming
    /// each one — exercises the trait-object service path without artifacts.
    struct EchoEngine {
        cfg: EngineConfig,
    }

    impl EchoEngine {
        fn new() -> Self {
            Self {
                cfg: EngineConfig::default(),
            }
        }
    }

    impl Engine for EchoEngine {
        fn kind(&self) -> EngineKind {
            EngineKind::Pp
        }

        fn config(&self) -> &EngineConfig {
            &self.cfg
        }

        fn decode(
            &mut self,
            req: &DecodeRequest,
            sink: &mut dyn TokenSink,
        ) -> Result<DecodeOutput> {
            let (max_new, _, _) = req.resolve(&self.cfg);
            let mut tokens = tokenizer::encode(&req.prompt);
            tokens.truncate(max_new);
            for &t in &tokens {
                sink.on_token(t);
            }
            Ok(DecodeOutput {
                text: tokenizer::decode(&tokens),
                tokens,
                wall_s: 0.0,
                modeled_s: 0.0,
                spec: None,
                metrics: Metrics::new(),
            })
        }
    }

    #[test]
    fn fifo_order_and_ids() {
        let mut r = Router::new(4);
        let a = r.submit_prompt("a").unwrap();
        let b = r.submit_prompt("b").unwrap();
        assert!(a < b);
        assert_eq!(r.pop().unwrap().req.prompt, "a");
        assert_eq!(r.pop().unwrap().req.prompt, "b");
        assert!(r.pop().is_none());
    }

    #[test]
    fn backpressure_rejects_overflow() {
        let mut r = Router::new(2);
        r.submit_prompt("a").unwrap();
        r.submit_prompt("b").unwrap();
        assert!(r.submit_prompt("c").is_err());
    }

    #[test]
    fn drain_serves_all_and_measures() {
        let mut r = Router::new(8);
        for i in 0..3 {
            r.submit_prompt(&format!("p{i}")).unwrap();
        }
        let mut engine = EchoEngine::new();
        let done = drain(&mut r, &mut engine).unwrap();
        assert_eq!(done.len(), 3);
        assert!(done.iter().all(|c| c.latency_s >= 0.0));
        assert!(done.iter().all(|c| c.first_token_s <= c.service_s));
        assert!(done.iter().all(|c| c.engine == "pp"));
        let (m, lat) = summarize(&done, 1.0);
        assert_eq!(m.counter("requests"), 3);
        assert_eq!(m.samples("first_token_s").len(), 3);
        assert_eq!(lat.len(), 3);
    }

    #[test]
    fn per_request_override_is_carried_through_the_queue() {
        let mut r = Router::new(4);
        r.submit(DecodeRequest::new("hello world").with_max_new_tokens(3))
            .unwrap();
        let mut engine = EchoEngine::new();
        let done = drain(&mut r, &mut engine).unwrap();
        assert_eq!(done[0].tokens, 3);
    }
}
