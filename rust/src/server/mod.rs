//! Request server: router + continuous-batching event loop.
//!
//! The router is engine-agnostic: it queues [`DecodeRequest`]s (prompt plus
//! per-request overrides) with a bounded FIFO admission queue
//! (backpressure). Service happens through the step-driven scheduling
//! surface ([`crate::engine::ScheduledEngine`]):
//!
//! * [`serve_until_idle`] — the continuous-batching event loop: it moves
//!   queued requests from the router into the scheduler (recording the
//!   queue depth each request saw at admission), then drives
//!   `scheduler.step()` until everything finished, so admission overlaps
//!   with decode. With `EngineKind::PipeDecDb` the pipeline carries
//!   several requests at once; every other kind degrades gracefully to
//!   FIFO one-at-a-time through the `OneShotScheduler` adapter.
//! * [`drain`] — the closed-batch convenience over a plain
//!   `&mut dyn Engine` (kept for single-engine callers and benches).
//!
//! Service is streaming-aware: every request decodes through a
//! [`StreamProbe`] sink that timestamps each token, so each
//! [`Completion`] reports time-to-first-token *and* mean time-between-
//! tokens — the paper's Fig. 8 serving metrics — alongside full latency.
//!
//! The loop itself stays single-threaded: engine-level parallelism (the
//! ISSUE 4 pipeline worker pool, `EngineConfig::threads`) lives *inside*
//! `scheduler.step()`, so the server gets threaded stage execution for
//! free without touching admission or streaming order.

use std::cell::RefCell;
use std::collections::VecDeque;
use std::rc::Rc;
use std::time::Instant;

use anyhow::Result;

use crate::engine::{
    DecodeRequest, Engine, ScheduledEngine, SessionId, SessionStatus, ShedError, TokenSink,
};
use crate::metrics::Metrics;
use crate::util::Summary;

/// A queued request.
#[derive(Debug, Clone)]
pub struct Request {
    pub id: u64,
    pub req: DecodeRequest,
    pub arrived_at: f64,
}

/// How a request's service ended (ISSUE 9). The serving loop never aborts
/// on a per-session fault: a failed, shed, or over-deadline request still
/// produces a [`Completion`] carrying this status, and [`summarize`]
/// counts each class (`completed_ok` / `failed` / `shed` /
/// `deadline_exceeded`).
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum CompletionStatus {
    /// Served to completion; token/latency fields are the full decode.
    Ok,
    /// The session failed inside the engine (worker fault, device error,
    /// admission failure); fields cover the partial decode.
    Failed { reason: String },
    /// Rejected at admission: the scheduler queue was at capacity
    /// (`limits.queue_cap`). No tokens were produced.
    Shed,
    /// Retired by the scheduler for exceeding a configured deadline
    /// (`limits.ttft_deadline_s` / `deadline_s` / `queue_max_wait_s`).
    DeadlineExceeded,
}

impl CompletionStatus {
    pub fn is_ok(&self) -> bool {
        matches!(self, CompletionStatus::Ok)
    }
}

/// A finished request.
#[derive(Debug, Clone)]
pub struct Completion {
    pub id: u64,
    /// How service ended; every non-`Ok` class is also counted by
    /// [`summarize`].
    pub status: CompletionStatus,
    /// Registry name of the engine that served the request.
    pub engine: &'static str,
    pub tokens: usize,
    /// queueing delay + service, seconds
    pub latency_s: f64,
    /// Admission into the engine until completion, seconds.
    pub service_s: f64,
    /// Admission into the engine until the first streamed token, seconds
    /// (TTFT).
    pub first_token_s: f64,
    /// Mean time between consecutive streamed tokens, seconds (TBT);
    /// 0 when the request produced fewer than two tokens.
    pub tbt_s: f64,
    /// Router queue depth this request saw at admission into the engine
    /// (itself included) — the Fig. 8 concurrency axis as observed.
    pub queue_depth: usize,
    /// Modeled parallel-schedule decode seconds reported by the engine.
    pub modeled_s: f64,
    /// Sync-phase breakdown (ISSUE 5): coordinator decide seconds
    /// (verify + sample + prune) over the whole decode.
    pub t_decide_s: f64,
    /// Cache-commit seconds (KV promotion + tree compaction) wherever
    /// they ran — coordinator (serial sync) or pipeline workers
    /// (overlapped).
    pub t_commit_s: f64,
    /// Fraction of sync-phase seconds that ran on pipeline workers,
    /// overlapped with the next timestep's compute (0 = fully serial).
    pub sync_overlap_ratio: f64,
    /// Host->device bytes the KV mirror moved through the donated
    /// in-place append/replay entry points (ISSUE 7) — the small
    /// per-token residual.
    pub kv_app_bytes: u64,
    /// Host->device bytes the KV mirror moved through full-tensor
    /// re-uploads (the ISSUE 7 fallback path; ~0 in steady state when
    /// the device-side append entry points are loaded).
    pub kv_reup_bytes: u64,
    /// Prompt tokens covered by a cross-request prefix-cache hit at
    /// admission (ISSUE 8); 0 on a miss or with the cache disabled.
    pub prefix_hit_tokens: u64,
    /// Prompt tokens prefill never re-computed thanks to the prefix
    /// cache (today identical to `prefix_hit_tokens`; kept separate so a
    /// partial-seed policy can diverge without a wire change).
    pub prefill_tokens_saved: u64,
    /// Fraction of wall-clock pipeline slot-seconds that were busy over
    /// this request's decode (ISSUE 10); 0 for engines without the
    /// pipeline occupancy accounting.
    pub occupancy: f64,
    /// `1 − occupancy`: the pipeline-bubble share of the decode.
    pub bubble_fraction: f64,
    /// Free-running speculative generations dropped as stale (assumed
    /// epoch or attach point no longer live) instead of applied
    /// (ISSUE 10); 0 at `spec_inflight = 1`.
    pub stale_expansions_dropped: u64,
}

/// FIFO admission queue with a capacity bound (backpressure).
#[derive(Debug)]
pub struct Router {
    queue: VecDeque<Request>,
    capacity: usize,
    next_id: u64,
    clock0: Instant,
}

impl Router {
    pub fn new(capacity: usize) -> Self {
        Self {
            queue: VecDeque::new(),
            capacity,
            next_id: 0,
            clock0: Instant::now(),
        }
    }

    /// Queue a full decode request (prompt + per-request overrides).
    /// Returns the request id, or Err when the queue is full.
    pub fn submit(&mut self, req: DecodeRequest) -> Result<u64> {
        anyhow::ensure!(self.queue.len() < self.capacity, "queue full");
        let id = self.next_id;
        self.next_id += 1;
        self.queue.push_back(Request {
            id,
            req,
            arrived_at: self.clock0.elapsed().as_secs_f64(),
        });
        Ok(id)
    }

    /// Convenience: queue a bare prompt with no overrides.
    pub fn submit_prompt(&mut self, prompt: &str) -> Result<u64> {
        self.submit(DecodeRequest::new(prompt))
    }

    pub fn depth(&self) -> usize {
        self.queue.len()
    }

    pub fn pop(&mut self) -> Option<Request> {
        self.queue.pop_front()
    }

    pub fn now(&self) -> f64 {
        self.clock0.elapsed().as_secs_f64()
    }
}

/// Per-token record of one request's stream: the tokens and a timestamp
/// per token, relative to admission. The server's TTFT / TBT probe; also
/// usable directly as a [`TokenSink`] for synchronous (closed-batch)
/// service, and by benches that need the stream *and* its timing (the
/// fig8 SpecPipe-DB head-to-head).
#[derive(Debug)]
pub struct ProbeState {
    start: Instant,
    stamps: Vec<f64>,
    stream: Vec<u32>,
}

impl ProbeState {
    pub fn new() -> Self {
        Self {
            start: Instant::now(),
            stamps: Vec::new(),
            stream: Vec::new(),
        }
    }

    pub fn tokens(&self) -> usize {
        self.stamps.len()
    }

    /// The streamed tokens, in emission order.
    pub fn stream(&self) -> &[u32] {
        &self.stream
    }

    /// Seconds from admission to the first token, or `None` before it.
    pub fn first_token_s(&self) -> Option<f64> {
        self.stamps.first().copied()
    }

    /// Mean gap between consecutive tokens (0 with fewer than 2 tokens).
    pub fn tbt_s(&self) -> f64 {
        if self.stamps.len() < 2 {
            return 0.0;
        }
        let span = self.stamps[self.stamps.len() - 1] - self.stamps[0];
        span / (self.stamps.len() - 1) as f64
    }

    pub fn elapsed_s(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }
}

impl Default for ProbeState {
    fn default() -> Self {
        Self::new()
    }
}

impl TokenSink for ProbeState {
    fn on_token(&mut self, token: u32) {
        self.stamps.push(self.start.elapsed().as_secs_f64());
        self.stream.push(token);
    }
}

/// Shared-handle wrapper so the server can hand a probe to the scheduler
/// as the session's sink while keeping a reader for completion time.
/// (The server is single-threaded; `Rc<RefCell>` is the honest cost.)
pub struct StreamProbe(pub Rc<RefCell<ProbeState>>);

impl StreamProbe {
    pub fn new() -> (Self, Rc<RefCell<ProbeState>>) {
        let state = Rc::new(RefCell::new(ProbeState::new()));
        (Self(state.clone()), state)
    }
}

impl TokenSink for StreamProbe {
    fn on_token(&mut self, token: u32) {
        self.0.borrow_mut().on_token(token);
    }
}

/// Pull the per-decode sync-phase breakdown out of an engine's metrics:
/// (decide seconds, commit seconds, overlap ratio). The ratio is recorded
/// once per decode; decodes without a sync point report (0, 0, 0).
fn sync_breakdown(m: &Metrics) -> (f64, f64, f64) {
    (
        m.sample_sum("t_decide_s"),
        m.sample_sum("t_commit_s"),
        m.samples("sync_overlap_ratio").first().copied().unwrap_or(0.0),
    )
}

/// Pull the KV-mirror upload split out of an engine's metrics:
/// (bytes moved by the donated in-place append/replay paths, bytes moved
/// by full-tensor re-uploads). Engines without a device mirror report
/// (0, 0).
fn kv_byte_split(m: &Metrics) -> (u64, u64) {
    (m.counter("hd_kv_app_bytes"), m.counter("hd_kv_reup_bytes"))
}

/// Pull the cross-request prefix-cache accounting out of an engine's
/// metrics: (hit tokens, prefill tokens saved). Engines without a prefix
/// cache report (0, 0).
fn prefix_stats(m: &Metrics) -> (u64, u64) {
    (
        m.counter("prefix_hit_tokens"),
        m.counter("prefill_tokens_saved"),
    )
}

/// Pull the continuous-speculation accounting out of an engine's metrics
/// (ISSUE 10): (occupancy, bubble fraction, stale expansions dropped).
/// Engines without the occupancy accounting report (0, 0, 0) — bubble
/// fraction is only meaningful alongside a recorded occupancy sample.
fn spec_stats(m: &Metrics) -> (f64, f64, u64) {
    let occ = m.samples("occupancy").first().copied().unwrap_or(0.0);
    let bubble = m.samples("bubble_fraction").first().copied().unwrap_or(0.0);
    (occ, bubble, m.counter("stale_expansions_dropped"))
}

/// Bookkeeping for one request in flight inside the scheduler.
struct Ticket {
    router_id: u64,
    sid: SessionId,
    arrived_at: f64,
    queue_depth: usize,
    probe: Rc<RefCell<ProbeState>>,
}

/// A zero-token completion for a request that never produced service —
/// shed at admission, rejected by the scheduler, or torn down by an
/// engine-level step failure.
fn unserved(
    id: u64,
    engine: &'static str,
    status: CompletionStatus,
    latency_s: f64,
    queue_depth: usize,
) -> Completion {
    Completion {
        id,
        status,
        engine,
        tokens: 0,
        latency_s,
        service_s: 0.0,
        first_token_s: 0.0,
        tbt_s: 0.0,
        queue_depth,
        modeled_s: 0.0,
        t_decide_s: 0.0,
        t_commit_s: 0.0,
        sync_overlap_ratio: 0.0,
        kv_app_bytes: 0,
        kv_reup_bytes: 0,
        prefix_hit_tokens: 0,
        prefill_tokens_saved: 0,
        occupancy: 0.0,
        bubble_fraction: 0.0,
        stale_expansions_dropped: 0,
    }
}

/// Continuous-batching event loop: admit everything the router holds into
/// the scheduler, then step the scheduler until idle, collecting
/// per-request completions as sessions finish. Admission overlaps with
/// decode — the scheduler admits sessions into pipeline slots per step —
/// and requests submitted to the router *between* calls are picked up by
/// the next call.
///
/// Fault isolation (ISSUE 9): the loop never aborts on a per-request
/// fault. A submit rejected by admission control becomes a
/// [`CompletionStatus::Shed`] completion; a session the scheduler retires
/// as failed or over-deadline becomes `Failed { reason }` /
/// `DeadlineExceeded` with its partial decode; co-scheduled requests are
/// untouched. Only an engine-level `step()` error — the scheduler itself,
/// not a session, is broken — ends the loop early, and even then every
/// outstanding request is returned as a `Failed` completion rather than
/// an `Err`.
pub fn serve_until_idle(
    router: &mut Router,
    sched: &mut dyn ScheduledEngine,
) -> Result<Vec<Completion>> {
    let mut tickets: Vec<Ticket> = Vec::new();
    let mut out = Vec::new();
    loop {
        // admission: hand queued requests to the scheduler, tagging each
        // with the queue depth it observed (itself included)
        while router.depth() > 0 {
            let depth = router.depth();
            let req = router.pop().expect("depth > 0");
            let (probe_sink, probe) = StreamProbe::new();
            match sched.submit(req.req, Box::new(probe_sink)) {
                Ok(sid) => tickets.push(Ticket {
                    router_id: req.id,
                    sid,
                    arrived_at: req.arrived_at,
                    queue_depth: depth,
                    probe,
                }),
                Err(e) => {
                    let status = if e.downcast_ref::<ShedError>().is_some() {
                        CompletionStatus::Shed
                    } else {
                        CompletionStatus::Failed {
                            reason: format!("submit rejected: {e:#}"),
                        }
                    };
                    out.push(unserved(
                        req.id,
                        sched.name(),
                        status,
                        router.now() - req.arrived_at,
                        depth,
                    ));
                }
            }
        }
        if !sched.has_work() {
            break;
        }
        let rep = match sched.step() {
            Ok(rep) => rep,
            Err(e) => {
                // the scheduler itself broke: fail every outstanding
                // request instead of returning an error that drops them
                let reason = format!("engine step failed: {e:#}");
                for ticket in tickets.drain(..) {
                    let latency = router.now() - ticket.arrived_at;
                    let mut c = unserved(
                        ticket.router_id,
                        sched.name(),
                        CompletionStatus::Failed {
                            reason: reason.clone(),
                        },
                        latency,
                        ticket.queue_depth,
                    );
                    let probe = ticket.probe.borrow();
                    c.tokens = probe.tokens();
                    c.service_s = probe.elapsed_s();
                    c.first_token_s = probe.first_token_s().unwrap_or(c.service_s);
                    c.tbt_s = probe.tbt_s();
                    out.push(c);
                }
                break;
            }
        };
        for fid in &rep.finished {
            let Some(ti) = tickets.iter().position(|t| t.sid == *fid) else {
                continue; // not ours (caller submitted directly)
            };
            let ticket = tickets.remove(ti);
            // status must be read before poll — poll forgets the session
            let status = match sched.status(ticket.sid) {
                Some(SessionStatus::Failed { reason }) => {
                    if reason.starts_with("deadline") {
                        CompletionStatus::DeadlineExceeded
                    } else {
                        CompletionStatus::Failed { reason }
                    }
                }
                _ => CompletionStatus::Ok,
            };
            let output = match sched.poll(ticket.sid) {
                Some(o) => o,
                None => {
                    anyhow::ensure!(
                        !status.is_ok(),
                        "finished session must be pollable"
                    );
                    crate::engine::DecodeOutput {
                        tokens: Vec::new(),
                        text: String::new(),
                        wall_s: 0.0,
                        modeled_s: 0.0,
                        spec: None,
                        metrics: Metrics::new(),
                    }
                }
            };
            let probe = ticket.probe.borrow();
            let service = probe.elapsed_s();
            debug_assert!(
                !status.is_ok() || probe.tokens() == output.tokens.len(),
                "streamed {} tokens but output has {}",
                probe.tokens(),
                output.tokens.len()
            );
            let (t_decide_s, t_commit_s, sync_overlap_ratio) =
                sync_breakdown(&output.metrics);
            let (kv_app_bytes, kv_reup_bytes) = kv_byte_split(&output.metrics);
            let (prefix_hit_tokens, prefill_tokens_saved) = prefix_stats(&output.metrics);
            let (occupancy, bubble_fraction, stale_expansions_dropped) =
                spec_stats(&output.metrics);
            out.push(Completion {
                id: ticket.router_id,
                status,
                engine: sched.name(),
                tokens: output.tokens.len(),
                latency_s: router.now() - ticket.arrived_at,
                service_s: service,
                first_token_s: probe.first_token_s().unwrap_or(service),
                tbt_s: probe.tbt_s(),
                queue_depth: ticket.queue_depth,
                modeled_s: output.modeled_s,
                t_decide_s,
                t_commit_s,
                sync_overlap_ratio,
                kv_app_bytes,
                kv_reup_bytes,
                prefix_hit_tokens,
                prefill_tokens_saved,
                occupancy,
                bubble_fraction,
                stale_expansions_dropped,
            });
        }
    }
    Ok(out)
}

/// Closed-batch convenience: serve everything currently queued through a
/// one-shot engine, FIFO, one request at a time. Same [`Completion`]
/// shape (TTFT, TBT, queue depth) as the continuous loop.
pub fn drain(router: &mut Router, engine: &mut dyn Engine) -> Result<Vec<Completion>> {
    let mut out = Vec::new();
    while let Some(req) = router.pop() {
        let depth = router.depth() + 1; // this request + those behind it
        let mut probe = ProbeState::new();
        let result = engine.decode(&req.req, &mut probe)?;
        let service = probe.elapsed_s();
        debug_assert_eq!(probe.tokens(), result.tokens.len());
        let (t_decide_s, t_commit_s, sync_overlap_ratio) = sync_breakdown(&result.metrics);
        let (kv_app_bytes, kv_reup_bytes) = kv_byte_split(&result.metrics);
        let (prefix_hit_tokens, prefill_tokens_saved) = prefix_stats(&result.metrics);
        let (occupancy, bubble_fraction, stale_expansions_dropped) = spec_stats(&result.metrics);
        out.push(Completion {
            id: req.id,
            status: CompletionStatus::Ok,
            engine: engine.name(),
            tokens: result.tokens.len(),
            latency_s: router.now() - req.arrived_at,
            service_s: service,
            first_token_s: probe.first_token_s().unwrap_or(service),
            tbt_s: probe.tbt_s(),
            queue_depth: depth,
            modeled_s: result.modeled_s,
            t_decide_s,
            t_commit_s,
            sync_overlap_ratio,
            kv_app_bytes,
            kv_reup_bytes,
            prefix_hit_tokens,
            prefill_tokens_saved,
            occupancy,
            bubble_fraction,
            stale_expansions_dropped,
        });
    }
    Ok(out)
}

/// Aggregate a batch of completions into the numbers Fig. 8 reports:
/// counters plus `latency_s`, `first_token_s`, `tbt_s`, and `queue_depth`
/// series, the per-decode sync-phase breakdown (`t_decide_s`,
/// `t_commit_s`, `sync_overlap_ratio` — ISSUE 5), the KV-mirror upload
/// split (`kv_app_bytes` / `kv_reup_bytes` counters — ISSUE 7), the
/// prefix-cache reuse counters (`prefix_hit_tokens` /
/// `prefill_tokens_saved` — ISSUE 8), and the
/// full-latency sample summary. `tbt_s` samples only requests that
/// streamed at least two tokens; the sync series sample only requests
/// that hit a sync point (decodes of a single token have none).
pub fn summarize(completions: &[Completion], wall_s: f64) -> (Metrics, Summary) {
    let mut m = Metrics::new();
    let mut lat = Vec::new();
    let mut total_tokens = 0usize;
    for c in completions {
        m.incr("requests", 1);
        match &c.status {
            CompletionStatus::Ok => m.incr("completed_ok", 1),
            CompletionStatus::Failed { .. } => m.incr("failed", 1),
            CompletionStatus::Shed => m.incr("shed", 1),
            CompletionStatus::DeadlineExceeded => m.incr("deadline_exceeded", 1),
        }
        m.incr("tokens", c.tokens as u64);
        m.record("latency_s", c.latency_s);
        m.record("first_token_s", c.first_token_s);
        if c.tokens >= 2 {
            m.record("tbt_s", c.tbt_s);
        }
        m.record("queue_depth", c.queue_depth as f64);
        if c.t_decide_s + c.t_commit_s > 0.0 {
            m.record("t_decide_s", c.t_decide_s);
            m.record("t_commit_s", c.t_commit_s);
            m.record("sync_overlap_ratio", c.sync_overlap_ratio);
        }
        m.incr("kv_app_bytes", c.kv_app_bytes);
        m.incr("kv_reup_bytes", c.kv_reup_bytes);
        m.incr("prefix_hit_tokens", c.prefix_hit_tokens);
        m.incr("prefill_tokens_saved", c.prefill_tokens_saved);
        // continuous-speculation series (ISSUE 10): occupancy/bubble only
        // from engines that record them (a zero sample would skew means)
        if c.occupancy > 0.0 {
            m.record("occupancy", c.occupancy);
            m.record("bubble_fraction", c.bubble_fraction);
        }
        m.incr("stale_expansions_dropped", c.stale_expansions_dropped);
        lat.push(c.latency_s);
        total_tokens += c.tokens;
    }
    if wall_s > 0.0 {
        m.record("throughput_tok_s", total_tokens as f64 / wall_s);
    }
    (m, Summary::from_samples(lat))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::EngineConfig;
    use crate::engine::{DecodeOutput, EngineKind, OneShotScheduler};
    use crate::tokenizer;

    /// Test double: "decodes" by echoing the prompt's token ids, streaming
    /// each one — exercises the service paths without artifacts.
    struct EchoEngine {
        cfg: EngineConfig,
    }

    impl EchoEngine {
        fn new() -> Self {
            Self {
                cfg: EngineConfig::default(),
            }
        }
    }

    impl Engine for EchoEngine {
        fn kind(&self) -> EngineKind {
            EngineKind::Pp
        }

        fn config(&self) -> &EngineConfig {
            &self.cfg
        }

        fn decode(
            &mut self,
            req: &DecodeRequest,
            sink: &mut dyn TokenSink,
        ) -> Result<DecodeOutput> {
            let (max_new, _, _) = req.resolve(&self.cfg);
            let mut tokens = tokenizer::encode(&req.prompt);
            tokens.truncate(max_new);
            for &t in &tokens {
                sink.on_token(t);
            }
            Ok(DecodeOutput {
                text: tokenizer::decode(&tokens),
                tokens,
                wall_s: 0.0,
                modeled_s: 0.0,
                spec: None,
                metrics: Metrics::new(),
            })
        }
    }

    #[test]
    fn fifo_order_and_ids() {
        let mut r = Router::new(4);
        let a = r.submit_prompt("a").unwrap();
        let b = r.submit_prompt("b").unwrap();
        assert!(a < b);
        assert_eq!(r.pop().unwrap().req.prompt, "a");
        assert_eq!(r.pop().unwrap().req.prompt, "b");
        assert!(r.pop().is_none());
    }

    #[test]
    fn backpressure_rejects_overflow() {
        let mut r = Router::new(2);
        r.submit_prompt("a").unwrap();
        r.submit_prompt("b").unwrap();
        assert!(r.submit_prompt("c").is_err());
    }

    #[test]
    fn drain_serves_all_and_measures() {
        let mut r = Router::new(8);
        for i in 0..3 {
            r.submit_prompt(&format!("p{i}")).unwrap();
        }
        let mut engine = EchoEngine::new();
        let done = drain(&mut r, &mut engine).unwrap();
        assert_eq!(done.len(), 3);
        assert!(done.iter().all(|c| c.latency_s >= 0.0));
        assert!(done.iter().all(|c| c.first_token_s <= c.service_s));
        assert!(done.iter().all(|c| c.tbt_s >= 0.0));
        assert!(done.iter().all(|c| c.engine == "pp"));
        // first in line saw the full queue; last saw only itself
        assert_eq!(done[0].queue_depth, 3);
        assert_eq!(done[2].queue_depth, 1);
        let (m, lat) = summarize(&done, 1.0);
        assert_eq!(m.counter("requests"), 3);
        assert_eq!(m.samples("first_token_s").len(), 3);
        assert_eq!(m.samples("tbt_s").len(), 3);
        assert_eq!(m.samples("queue_depth").len(), 3);
        assert_eq!(lat.len(), 3);
    }

    #[test]
    fn serve_until_idle_matches_drain_for_one_shot_engines() {
        let mut r = Router::new(8);
        for i in 0..3 {
            r.submit_prompt(&format!("prompt number {i}")).unwrap();
        }
        let mut sched = OneShotScheduler::new(Box::new(EchoEngine::new()));
        let done = serve_until_idle(&mut r, &mut sched).unwrap();
        assert_eq!(done.len(), 3);
        // FIFO service through the adapter; ids preserved from the router
        assert_eq!(
            done.iter().map(|c| c.id).collect::<Vec<_>>(),
            vec![0, 1, 2]
        );
        assert!(done.iter().all(|c| c.tokens > 0));
        assert!(done.iter().all(|c| c.first_token_s <= c.service_s));
        // all three entered the scheduler while the router held all three
        assert_eq!(done[0].queue_depth, 3);
        assert_eq!(done[1].queue_depth, 2);
        assert_eq!(done[2].queue_depth, 1);
        assert_eq!(r.depth(), 0);
    }

    #[test]
    fn serve_until_idle_on_empty_router_is_a_noop() {
        let mut r = Router::new(2);
        let mut sched = OneShotScheduler::new(Box::new(EchoEngine::new()));
        let done = serve_until_idle(&mut r, &mut sched).unwrap();
        assert!(done.is_empty());
    }

    #[test]
    fn per_request_override_is_carried_through_the_queue() {
        let mut r = Router::new(4);
        r.submit(DecodeRequest::new("hello world").with_max_new_tokens(3))
            .unwrap();
        let mut sched = OneShotScheduler::new(Box::new(EchoEngine::new()));
        let done = serve_until_idle(&mut r, &mut sched).unwrap();
        assert_eq!(done[0].tokens, 3);
    }

    #[test]
    fn summarize_counts_terminal_statuses() {
        let done = vec![
            unserved(0, "pp", CompletionStatus::Ok, 0.0, 1),
            unserved(
                1,
                "pp",
                CompletionStatus::Failed {
                    reason: "worker lost".into(),
                },
                0.0,
                1,
            ),
            unserved(2, "pp", CompletionStatus::Shed, 0.0, 1),
            unserved(3, "pp", CompletionStatus::DeadlineExceeded, 0.0, 1),
        ];
        let (m, _) = summarize(&done, 1.0);
        assert_eq!(m.counter("requests"), 4);
        assert_eq!(m.counter("completed_ok"), 1);
        assert_eq!(m.counter("failed"), 1);
        assert_eq!(m.counter("shed"), 1);
        assert_eq!(m.counter("deadline_exceeded"), 1);
        assert!(done[0].status.is_ok());
        assert!(!done[2].status.is_ok());
    }

    #[test]
    fn probe_reports_ttft_and_tbt() {
        let mut p = ProbeState::new();
        assert_eq!(p.tbt_s(), 0.0);
        assert!(p.first_token_s().is_none());
        p.on_token(1);
        assert!(p.first_token_s().is_some());
        assert_eq!(p.tbt_s(), 0.0, "one token has no inter-token gap");
        p.on_token(2);
        p.on_token(3);
        assert_eq!(p.tokens(), 3);
        let span = p.stamps[2] - p.stamps[0];
        assert!((p.tbt_s() - span / 2.0).abs() < 1e-12);
        assert!(p.tbt_s() >= 0.0);
    }
}
