//! Request server: router + FIFO batcher + engine worker.
//!
//! PipeDec is a *single-task* accelerator (it commits every pipeline stage
//! to one request), so the server runs one engine worker and a bounded
//! admission queue; the paper's Fig. 8 process-pool experiment maps to
//! submitting `k` concurrent requests and measuring completion throughput.
//! The router is engine-agnostic: any `FnMut(&str) -> Result<(Vec<u32>,
//! f64)>` can serve, which lets tests and benches run PP/STPP/SLM behind
//! the same front end.

use std::collections::VecDeque;
use std::time::Instant;

use anyhow::Result;

use crate::metrics::Metrics;
use crate::util::Summary;

/// A queued request.
#[derive(Debug, Clone)]
pub struct Request {
    pub id: u64,
    pub prompt: String,
    pub arrived_at: f64,
}

/// A finished request.
#[derive(Debug, Clone)]
pub struct Completion {
    pub id: u64,
    pub tokens: usize,
    /// queueing delay + service, seconds
    pub latency_s: f64,
    pub service_s: f64,
}

/// FIFO admission queue with a capacity bound (backpressure).
#[derive(Debug)]
pub struct Router {
    queue: VecDeque<Request>,
    capacity: usize,
    next_id: u64,
    clock0: Instant,
}

impl Router {
    pub fn new(capacity: usize) -> Self {
        Self {
            queue: VecDeque::new(),
            capacity,
            next_id: 0,
            clock0: Instant::now(),
        }
    }

    /// Returns the request id, or Err when the queue is full.
    pub fn submit(&mut self, prompt: &str) -> Result<u64> {
        anyhow::ensure!(self.queue.len() < self.capacity, "queue full");
        let id = self.next_id;
        self.next_id += 1;
        self.queue.push_back(Request {
            id,
            prompt: prompt.to_string(),
            arrived_at: self.clock0.elapsed().as_secs_f64(),
        });
        Ok(id)
    }

    pub fn depth(&self) -> usize {
        self.queue.len()
    }

    pub fn pop(&mut self) -> Option<Request> {
        self.queue.pop_front()
    }

    pub fn now(&self) -> f64 {
        self.clock0.elapsed().as_secs_f64()
    }
}

/// Serve everything currently queued through a decode function, FIFO.
/// Returns per-request completions.
pub fn drain<F>(router: &mut Router, mut decode: F) -> Result<Vec<Completion>>
where
    F: FnMut(&str) -> Result<(usize, f64)>,
{
    let mut out = Vec::new();
    while let Some(req) = router.pop() {
        let t0 = Instant::now();
        let (tokens, _modeled) = decode(&req.prompt)?;
        let service = t0.elapsed().as_secs_f64();
        out.push(Completion {
            id: req.id,
            tokens,
            latency_s: router.now() - req.arrived_at,
            service_s: service,
        });
    }
    Ok(out)
}

/// Aggregate a batch of completions into the numbers Fig. 8 reports.
pub fn summarize(completions: &[Completion], wall_s: f64) -> (Metrics, Summary) {
    let mut m = Metrics::new();
    let mut lat = Vec::new();
    let mut total_tokens = 0usize;
    for c in completions {
        m.incr("requests", 1);
        m.incr("tokens", c.tokens as u64);
        m.record("latency_s", c.latency_s);
        lat.push(c.latency_s);
        total_tokens += c.tokens;
    }
    if wall_s > 0.0 {
        m.record("throughput_tok_s", total_tokens as f64 / wall_s);
    }
    (m, Summary::from_samples(lat))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fifo_order_and_ids() {
        let mut r = Router::new(4);
        let a = r.submit("a").unwrap();
        let b = r.submit("b").unwrap();
        assert!(a < b);
        assert_eq!(r.pop().unwrap().prompt, "a");
        assert_eq!(r.pop().unwrap().prompt, "b");
        assert!(r.pop().is_none());
    }

    #[test]
    fn backpressure_rejects_overflow() {
        let mut r = Router::new(2);
        r.submit("a").unwrap();
        r.submit("b").unwrap();
        assert!(r.submit("c").is_err());
    }

    #[test]
    fn drain_serves_all_and_measures() {
        let mut r = Router::new(8);
        for i in 0..3 {
            r.submit(&format!("p{i}")).unwrap();
        }
        let done = drain(&mut r, |p| Ok((p.len(), 0.0))).unwrap();
        assert_eq!(done.len(), 3);
        assert!(done.iter().all(|c| c.latency_s >= 0.0));
        let (m, lat) = summarize(&done, 1.0);
        assert_eq!(m.counter("requests"), 3);
        assert_eq!(lat.len(), 3);
    }
}
