//! Loader for the `pdweights` (.pdw) container written by
//! `python/compile/pdw.py`.
//!
//! Layout (little-endian): magic `PDW1`, u32 tensor count, then per tensor
//! u16 name-len + name, u8 ndim, u32 dims[ndim], f32 data (row-major).

use std::collections::HashMap;
use std::io::Read;
use std::path::Path;

use anyhow::{bail, Context, Result};

/// A named host tensor (f32, row-major).
#[derive(Debug, Clone)]
pub struct Tensor {
    pub name: String,
    pub dims: Vec<usize>,
    pub data: Vec<f32>,
}

impl Tensor {
    pub fn element_count(&self) -> usize {
        self.dims.iter().product::<usize>().max(1)
    }
}

/// A loaded weight file: name -> tensor.
#[derive(Debug, Default)]
pub struct WeightMap {
    tensors: HashMap<String, Tensor>,
}

impl WeightMap {
    pub fn load(path: &Path) -> Result<Self> {
        let mut f = std::fs::File::open(path)
            .with_context(|| format!("open weights {}", path.display()))?;
        let mut buf = Vec::new();
        f.read_to_end(&mut buf)?;
        Self::parse(&buf).with_context(|| format!("parse {}", path.display()))
    }

    pub fn parse(buf: &[u8]) -> Result<Self> {
        let mut r = Cursor { buf, pos: 0 };
        if r.take(4)? != b"PDW1" {
            bail!("bad magic");
        }
        let count = r.u32()? as usize;
        let mut tensors = HashMap::with_capacity(count);
        for _ in 0..count {
            let nlen = r.u16()? as usize;
            let name = String::from_utf8(r.take(nlen)?.to_vec())?;
            let ndim = r.u8()? as usize;
            let mut dims = Vec::with_capacity(ndim);
            for _ in 0..ndim {
                dims.push(r.u32()? as usize);
            }
            let n: usize = dims.iter().product::<usize>().max(1);
            let raw = r.take(4 * n)?;
            let mut data = vec![0f32; n];
            for (i, chunk) in raw.chunks_exact(4).enumerate() {
                data[i] = f32::from_le_bytes(chunk.try_into().unwrap());
            }
            tensors.insert(
                name.clone(),
                Tensor { name, dims, data },
            );
        }
        Ok(Self { tensors })
    }

    pub fn get(&self, name: &str) -> Result<&Tensor> {
        self.tensors
            .get(name)
            .with_context(|| format!("missing tensor '{name}'"))
    }

    pub fn len(&self) -> usize {
        self.tensors.len()
    }

    pub fn is_empty(&self) -> bool {
        self.tensors.is_empty()
    }

    pub fn names(&self) -> Vec<&str> {
        let mut v: Vec<&str> = self.tensors.keys().map(|s| s.as_str()).collect();
        v.sort();
        v
    }
}

struct Cursor<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Cursor<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.pos + n > self.buf.len() {
            bail!("truncated pdw file at offset {}", self.pos);
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }
    fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }
    fn u16(&mut self) -> Result<u16> {
        Ok(u16::from_le_bytes(self.take(2)?.try_into().unwrap()))
    }
    fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_pdw() -> Vec<u8> {
        // one tensor "w" of shape [2,2]
        let mut b = Vec::new();
        b.extend(b"PDW1");
        b.extend(1u32.to_le_bytes());
        b.extend(1u16.to_le_bytes());
        b.extend(b"w");
        b.push(2u8);
        b.extend(2u32.to_le_bytes());
        b.extend(2u32.to_le_bytes());
        for v in [1.0f32, 2.0, 3.0, 4.0] {
            b.extend(v.to_le_bytes());
        }
        b
    }

    #[test]
    fn parse_roundtrip() {
        let wm = WeightMap::parse(&sample_pdw()).unwrap();
        let t = wm.get("w").unwrap();
        assert_eq!(t.dims, vec![2, 2]);
        assert_eq!(t.data, vec![1.0, 2.0, 3.0, 4.0]);
    }

    #[test]
    fn bad_magic_rejected() {
        let mut b = sample_pdw();
        b[0] = b'X';
        assert!(WeightMap::parse(&b).is_err());
    }

    #[test]
    fn truncated_rejected() {
        let b = sample_pdw();
        assert!(WeightMap::parse(&b[..b.len() - 2]).is_err());
    }

    #[test]
    fn missing_tensor_is_error() {
        let wm = WeightMap::parse(&sample_pdw()).unwrap();
        assert!(wm.get("nope").is_err());
    }
}
