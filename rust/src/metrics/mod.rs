//! Metrics: counters, latency recorders, and table/CSV output for the
//! benches and examples. [`Metrics`] is the single-threaded per-decode
//! accumulator; [`SharedMetrics`] is the thread-safe sink the pipeline
//! workers record into directly (ISSUE 4), drained into a [`Metrics`] at
//! the coordinator's sync points.

use std::collections::BTreeMap;
use std::time::Instant;

use crate::concurrency::sync::atomic::{AtomicU64, Ordering};
use crate::concurrency::sync::{Mutex, RwLock};
use crate::util::Summary;

/// Accumulates named counters and sample series.
#[derive(Debug, Default, Clone)]
pub struct Metrics {
    counters: BTreeMap<String, u64>,
    samples: BTreeMap<String, Vec<f64>>,
}

impl Metrics {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn incr(&mut self, name: &str, by: u64) {
        *self.counters.entry(name.to_string()).or_insert(0) += by;
    }

    pub fn counter(&self, name: &str) -> u64 {
        self.counters.get(name).copied().unwrap_or(0)
    }

    pub fn record(&mut self, name: &str, value: f64) {
        self.samples.entry(name.to_string()).or_default().push(value);
    }

    pub fn samples(&self, name: &str) -> &[f64] {
        self.samples.get(name).map(|v| v.as_slice()).unwrap_or(&[])
    }

    /// Sum of a sample series (0 when absent) — the natural reading for
    /// per-event duration series like the sync-phase breakdown
    /// (`t_decide_s` / `t_commit_s`), where total seconds matter more
    /// than the per-event distribution.
    pub fn sample_sum(&self, name: &str) -> f64 {
        self.samples(name).iter().sum()
    }

    pub fn summary(&self, name: &str) -> Summary {
        Summary::from_samples(self.samples(name).to_vec())
    }

    pub fn merge(&mut self, other: &Metrics) {
        for (k, v) in &other.counters {
            *self.counters.entry(k.clone()).or_insert(0) += v;
        }
        for (k, v) in &other.samples {
            self.samples.entry(k.clone()).or_default().extend(v);
        }
    }

    pub fn report(&self) -> String {
        let mut out = String::new();
        for (k, v) in &self.counters {
            out.push_str(&format!("{k}: {v}\n"));
        }
        for k in self.samples.keys() {
            out.push_str(&format!("{k}: {}\n", self.summary(k)));
        }
        out
    }
}

/// Thread-safe metrics sink: counters are atomics behind an `RwLock`ed
/// name table (lock-free on the hot path once a name exists), sample
/// series sit behind a `Mutex`. Pipeline workers record into a shared
/// `Arc<SharedMetrics>` without funneling through the coordinator thread;
/// the coordinator folds [`SharedMetrics::drain`] into the per-decode
/// [`Metrics`] when it assembles a `DecodeOutput`.
///
/// Sample *order* across workers is nondeterministic; consumers read
/// order-independent aggregates ([`Metrics::summary`], counters).
///
/// # Memory-ordering audit (ISSUE 6)
///
/// Counter bumps use `Ordering::Relaxed`, which is sufficient because the
/// counters are pure statistics: nothing *reads* a counter to make a
/// control-flow decision concurrently with writers, so no cross-counter or
/// counter-to-data ordering is required — only per-counter atomicity,
/// which every RMW ordering provides (each `fetch_add` is observed exactly
/// once). The reads that matter ([`counter`](Self::counter),
/// [`drain`](Self::drain)) happen at coordinator sync points, after the
/// workers' replies have already been received over an mpsc channel — the
/// channel's synchronization makes every worker bump visible to the
/// coordinator regardless of the counter's own ordering.
#[derive(Debug, Default)]
pub struct SharedMetrics {
    counters: RwLock<BTreeMap<String, AtomicU64>>,
    samples: Mutex<BTreeMap<String, Vec<f64>>>,
}

impl SharedMetrics {
    pub fn new() -> Self {
        Self::default()
    }

    /// Bump a counter, creating it on first use.
    ///
    /// Concurrency note: the read-lock fast path and the write-lock upsert
    /// cannot double-create or lose a counter. Two threads missing the
    /// same name under the read lock both fall through to the write lock,
    /// but `entry().or_insert_with()` runs under the *exclusive* write
    /// lock, so the second thread finds the first thread's entry and bumps
    /// it — creation is effectively once-only and every increment lands on
    /// the single `AtomicU64` for that name (asserted by the
    /// `concurrent_counter_creation_loses_no_increment` test).
    pub fn incr(&self, name: &str, by: u64) {
        {
            let map = self.counters.read().unwrap_or_else(|e| e.into_inner());
            if let Some(c) = map.get(name) {
                c.fetch_add(by, Ordering::Relaxed);
                return;
            }
        }
        let mut map = self.counters.write().unwrap_or_else(|e| e.into_inner());
        map.entry(name.to_string())
            .or_insert_with(|| AtomicU64::new(0))
            .fetch_add(by, Ordering::Relaxed);
    }

    pub fn counter(&self, name: &str) -> u64 {
        self.counters
            .read()
            .unwrap_or_else(|e| e.into_inner())
            .get(name)
            .map(|c| c.load(Ordering::Relaxed))
            .unwrap_or(0)
    }

    pub fn record(&self, name: &str, value: f64) {
        self.samples
            .lock()
            .unwrap_or_else(|e| e.into_inner())
            .entry(name.to_string())
            .or_default()
            .push(value);
    }

    /// Sum of a sample series without draining (0 when absent): lets a
    /// coordinator peek at worker-recorded duration totals mid-decode
    /// without disturbing the per-decode drain cycle.
    pub fn sample_sum(&self, name: &str) -> f64 {
        self.samples
            .lock()
            .unwrap_or_else(|e| e.into_inner())
            .get(name)
            .map(|v| v.iter().sum())
            .unwrap_or(0.0)
    }

    /// Move everything recorded so far into a plain [`Metrics`], leaving
    /// this sink empty (so successive decodes see only their own deltas).
    pub fn drain(&self) -> Metrics {
        let mut out = Metrics::new();
        let counters = std::mem::take(
            &mut *self.counters.write().unwrap_or_else(|e| e.into_inner()),
        );
        for (k, v) in counters {
            let n = v.into_inner();
            if n > 0 {
                out.incr(&k, n);
            }
        }
        let samples =
            std::mem::take(&mut *self.samples.lock().unwrap_or_else(|e| e.into_inner()));
        for (k, vs) in samples {
            for v in vs {
                out.record(&k, v);
            }
        }
        out
    }
}

/// Scope timer recording elapsed seconds into a metric on drop.
pub struct ScopedTimer<'a> {
    metrics: &'a mut Metrics,
    name: String,
    start: Instant,
}

impl<'a> ScopedTimer<'a> {
    pub fn new(metrics: &'a mut Metrics, name: &str) -> Self {
        Self {
            metrics,
            name: name.to_string(),
            start: Instant::now(),
        }
    }
}

impl Drop for ScopedTimer<'_> {
    fn drop(&mut self) {
        self.metrics
            .record(&self.name, self.start.elapsed().as_secs_f64());
    }
}

/// Fixed-width text table used by the figure benches to print paper-style
/// rows.
#[derive(Debug, Default)]
pub struct Table {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(header: &[&str]) -> Self {
        Self {
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.header.len());
        self.rows.push(cells);
    }

    pub fn to_csv(&self) -> String {
        let mut s = self.header.join(",");
        s.push('\n');
        for r in &self.rows {
            s.push_str(&r.join(","));
            s.push('\n');
        }
        s
    }

    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for r in &self.rows {
            for (i, c) in r.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let line = |cells: &[String]| -> String {
            cells
                .iter()
                .enumerate()
                .map(|(i, c)| format!("{:>w$}", c, w = widths[i]))
                .collect::<Vec<_>>()
                .join("  ")
        };
        let mut out = line(&self.header);
        out.push('\n');
        out.push_str(&"-".repeat(out.len().saturating_sub(1)));
        out.push('\n');
        for r in &self.rows {
            out.push_str(&line(r));
            out.push('\n');
        }
        out
    }

    pub fn write_csv(&self, path: &std::path::Path) -> std::io::Result<()> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        std::fs::write(path, self.to_csv())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate() {
        let mut m = Metrics::new();
        m.incr("tokens", 3);
        m.incr("tokens", 2);
        assert_eq!(m.counter("tokens"), 5);
        assert_eq!(m.counter("missing"), 0);
    }

    #[test]
    fn samples_summarize() {
        let mut m = Metrics::new();
        for v in [1.0, 2.0, 3.0] {
            m.record("lat", v);
        }
        assert!((m.summary("lat").mean() - 2.0).abs() < 1e-9);
        assert!((m.sample_sum("lat") - 6.0).abs() < 1e-9);
        assert_eq!(m.sample_sum("missing"), 0.0);
    }

    #[test]
    fn shared_sample_sum_peeks_without_draining() {
        let m = SharedMetrics::new();
        m.record("t_commit_s", 0.25);
        m.record("t_commit_s", 0.75);
        assert!((m.sample_sum("t_commit_s") - 1.0).abs() < 1e-12);
        assert_eq!(m.sample_sum("absent"), 0.0);
        // peeking must not drain
        assert_eq!(m.drain().samples("t_commit_s").len(), 2);
    }

    #[test]
    fn merge_combines() {
        let mut a = Metrics::new();
        a.incr("x", 1);
        a.record("s", 1.0);
        let mut b = Metrics::new();
        b.incr("x", 2);
        b.record("s", 3.0);
        a.merge(&b);
        assert_eq!(a.counter("x"), 3);
        assert_eq!(a.samples("s").len(), 2);
    }

    #[test]
    fn timer_records() {
        let mut m = Metrics::new();
        {
            let _t = ScopedTimer::new(&mut m, "dur");
        }
        assert_eq!(m.samples("dur").len(), 1);
    }

    #[test]
    fn shared_metrics_accumulate_across_threads() {
        use std::sync::Arc;
        let m = Arc::new(SharedMetrics::new());
        let handles: Vec<_> = (0..4)
            .map(|_| {
                let m = Arc::clone(&m);
                std::thread::spawn(move || {
                    for _ in 0..100 {
                        m.incr("jobs", 1);
                        m.record("lat", 1.0);
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(m.counter("jobs"), 400);
        let drained = m.drain();
        assert_eq!(drained.counter("jobs"), 400);
        assert_eq!(drained.samples("lat").len(), 400);
        // drain leaves the sink empty for the next decode
        assert_eq!(m.counter("jobs"), 0);
        assert_eq!(m.drain().samples("lat").len(), 0);
    }

    #[test]
    fn concurrent_counter_creation_loses_no_increment() {
        // Hammer the *creation* path: every thread races to be the first
        // to insert each name (a Barrier lines them up per round), so the
        // read-miss -> write-lock upsert in `incr` runs under maximal
        // contention. If a counter could be created twice, one thread's
        // increments would land on a shadowed atomic and the totals below
        // would come up short.
        use std::sync::{Arc, Barrier};
        const THREADS: usize = 8;
        const NAMES: usize = 16;
        let m = Arc::new(SharedMetrics::new());
        let barrier = Arc::new(Barrier::new(THREADS));
        let handles: Vec<_> = (0..THREADS)
            .map(|_| {
                let m = Arc::clone(&m);
                let barrier = Arc::clone(&barrier);
                std::thread::spawn(move || {
                    for i in 0..NAMES {
                        let name = format!("ctr_{i}");
                        barrier.wait(); // all threads hit the fresh name at once
                        m.incr(&name, 1);
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        for i in 0..NAMES {
            assert_eq!(
                m.counter(&format!("ctr_{i}")),
                THREADS as u64,
                "counter ctr_{i} lost increments under creation contention"
            );
        }
        let drained = m.drain();
        let total: u64 = (0..NAMES).map(|i| drained.counter(&format!("ctr_{i}"))).sum();
        assert_eq!(total, (THREADS * NAMES) as u64);
    }

    #[test]
    fn table_renders_and_csvs() {
        let mut t = Table::new(&["a", "bb"]);
        t.row(vec!["1".into(), "2".into()]);
        assert!(t.render().contains("bb"));
        assert_eq!(t.to_csv(), "a,bb\n1,2\n");
    }
}
