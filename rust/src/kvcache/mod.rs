//! Two-level KV cache (paper §3.2 / §3.4.3).
//!
//! Each pipeline stage owns one [`TwoLevelCache`] covering its contiguous
//! layer span:
//!
//! * **model level** (`past_*`) — keys/values of accepted tokens, the
//!   conventional KV cache;
//! * **tree level** (`tree_*`) — keys/values of prediction-tree nodes,
//!   slot-indexed by the node's BFS index (stages hold a BFS prefix of the
//!   tree, so one global slot numbering works everywhere).
//!
//! Following the paper's layout note ("storing all layers for a
//! computational node in a tensor, with the highest dimension representing
//! the number of Transformer blocks"), all layers live in one contiguous
//! buffer, so promotion and pruning are single passes and per-layer views
//! for the PJRT runtime are zero-copy slices.
//!
//! Synchronization semantics (§3.4.3): on a verified token, the old root
//! (tree slot 0) is promoted to the model level — `promote_root_to_past` —
//! then the tree level is compacted to the surviving subtree
//! (`compact_tree` with the `kept_old` list from
//! [`crate::tree::PredictionTree::prune`]) or cleared on a miss.
//!
//! # Replayable sync commits (ISSUE 5)
//!
//! That promote+compact pair is reified as a [`CacheCommit`]: the
//! coordinator *decides* once per verified token and every cache owner
//! *applies* the same op later — eagerly at the sync point (the serial
//! reference path) or deferred until just before the owner's next forward
//! pass (the overlapped path, where timestep t+1's compute runs
//! concurrently with timestep t's cache maintenance). Commits carry a
//! 1-based `epoch` in the owning request's commit sequence and each cache
//! tracks the epoch it has applied ([`TwoLevelCache::commit_epoch`]), so
//! replay is strictly in-order and a stale cache is detectable before it
//! is run against a newer tree. Deferral is sound because nothing reads a
//! cache between its sync point and its next forward — the decision
//! itself (verification, sampling, pruning) never depends on cache
//! contents, only on the exiting flow's logits and the tree.
//!
//! # Dirty tracking for the device mirror
//!
//! Each cache carries per-layer **mutation epochs** for both levels
//! (`past_epoch` / `tree_epoch`), bumped by exactly the mutations that
//! change tensor *contents*:
//!
//! * `append_tree_block` / `append_past_block` — that layer only;
//! * `promote_root_to_past` / `promote_slot_to_past` — the past level of
//!   every layer (one slot written per layer);
//! * `compact_tree` — the tree level of every layer, but only when a slot
//!   actually moved;
//! * `clear_tree` / `reset` / `commit_*` — lengths only, **no** epoch bump:
//!   stale device data past the active length is masked by the attention
//!   biases, so the device copy stays valid.
//!
//! [`device::DeviceKvCache`] compares these epochs against the epoch it
//! last uploaded and re-uploads a layer's tensors only when they diverge.
//!
//! Epochs are per layer × level, so a single-row promotion still dirties
//! the whole past level — but since ISSUE 7 that no longer implies a full
//! re-upload: the device mirror replays the same mutation *in place*
//! through donated `kv_append`/`kv_promote`/`kv_gather` entry points
//! ([`device::DeviceKvCache::append_block`] /
//! [`device::DeviceKvCache::apply_commit`]) and restamps its copy with
//! the post-mutation epoch, so `ensure_*` sees a clean level. The
//! epoch-diff re-upload survives as the fallback (stale mirror, shape
//! mismatch, or missing kv artifacts) and the conformance reference.
//! Caches also carry a process-unique [`TwoLevelCache::id`] so one model
//! can keep independent device mirrors for many caches (per pipeline
//! stage, draft vs target); cloning a cache assigns a fresh id so a clone
//! never aliases the original's device state.

pub mod device;
pub mod prefix;

use anyhow::{ensure, Result};

use crate::concurrency::protocol::{CommitCursor, Epoched};
use crate::concurrency::sync::atomic::{AtomicU64, Ordering};

static NEXT_CACHE_ID: AtomicU64 = AtomicU64::new(1);

fn fresh_cache_id() -> u64 {
    NEXT_CACHE_ID.fetch_add(1, Ordering::Relaxed)
}

/// What a verified token does to a request's tree-level cache (after the
/// mandatory root promotion). `kept_old` is shared behind an `Arc` because
/// one decision fans out to every stage cache plus the draft cache.
#[derive(Debug, Clone, PartialEq)]
pub enum CommitOp {
    /// Verified hit: compact the tree level to the surviving pre-prune
    /// slots (ascending, from [`crate::tree::PruneOutcome::Hit`]).
    Hit { kept_old: std::sync::Arc<Vec<usize>> },
    /// Verified miss: drop the tree level (the tree is reinitialized).
    Miss,
}

/// One sync-phase cache maintenance decision, replayable on any cache of
/// the owning request: promote the old root to the model level, then
/// apply [`CommitOp`] to the tree level. Issued by the coordinator with a
/// dense 1-based `epoch`; applied strictly in order via
/// [`TwoLevelCache::apply_commit`].
#[derive(Debug, Clone, PartialEq)]
pub struct CacheCommit {
    /// Position in the owning request's commit sequence (1-based, dense).
    pub epoch: u64,
    pub op: CommitOp,
}

impl Epoched for CacheCommit {
    fn epoch(&self) -> u64 {
        self.epoch
    }
}

#[derive(Debug)]
pub struct TwoLevelCache {
    id: u64,
    layers: usize,
    heads: usize,
    head_dim: usize,
    past_cap: usize,
    tree_cap: usize,

    past_k: Vec<f32>,
    past_v: Vec<f32>,
    past_len: usize,

    tree_k: Vec<f32>,
    tree_v: Vec<f32>,
    tree_len: usize,

    /// Monotonic per-cache mutation clock feeding the per-layer epochs.
    clock: u64,
    past_epoch: Vec<u64>,
    tree_epoch: Vec<u64>,

    /// In-order replay cursor for [`CacheCommit`]s: epoch of the last one
    /// applied (0 = none this request). The dense/in-order/exactly-once
    /// rules live in [`CommitCursor`], shared with the model checker.
    commit_cursor: CommitCursor,
}

impl Clone for TwoLevelCache {
    /// Clones get a fresh [`TwoLevelCache::id`] so device mirrors keyed by
    /// id never alias across clones (their epochs advance independently).
    fn clone(&self) -> Self {
        Self {
            id: fresh_cache_id(),
            layers: self.layers,
            heads: self.heads,
            head_dim: self.head_dim,
            past_cap: self.past_cap,
            tree_cap: self.tree_cap,
            past_k: self.past_k.clone(),
            past_v: self.past_v.clone(),
            past_len: self.past_len,
            tree_k: self.tree_k.clone(),
            tree_v: self.tree_v.clone(),
            tree_len: self.tree_len,
            clock: self.clock,
            past_epoch: self.past_epoch.clone(),
            tree_epoch: self.tree_epoch.clone(),
            commit_cursor: self.commit_cursor,
        }
    }
}

impl TwoLevelCache {
    pub fn new(
        layers: usize,
        heads: usize,
        head_dim: usize,
        past_cap: usize,
        tree_cap: usize,
    ) -> Self {
        let past = layers * heads * past_cap * head_dim;
        let tree = layers * heads * tree_cap * head_dim;
        Self {
            id: fresh_cache_id(),
            layers,
            heads,
            head_dim,
            past_cap,
            tree_cap,
            past_k: vec![0.0; past],
            past_v: vec![0.0; past],
            past_len: 0,
            tree_k: vec![0.0; tree],
            tree_v: vec![0.0; tree],
            tree_len: 0,
            clock: 0,
            past_epoch: vec![0; layers],
            tree_epoch: vec![0; layers],
            commit_cursor: CommitCursor::new(),
        }
    }

    /// A zero-capacity stand-in left behind while a real cache is lent to
    /// a pipeline worker (moved through the job channel); allocates
    /// nothing. Any forward pass over a placeholder fails shape checks
    /// immediately, so accidental use is loud.
    pub fn placeholder() -> Self {
        Self::new(0, 0, 0, 0, 0)
    }

    /// Process-unique identity of this cache (stable across mutations,
    /// fresh on clone) — the key for per-cache device mirrors.
    pub fn id(&self) -> u64 {
        self.id
    }

    pub fn past_len(&self) -> usize {
        self.past_len
    }

    pub fn tree_len(&self) -> usize {
        self.tree_len
    }

    pub fn past_cap(&self) -> usize {
        self.past_cap
    }

    pub fn tree_cap(&self) -> usize {
        self.tree_cap
    }

    pub fn layers(&self) -> usize {
        self.layers
    }

    pub fn heads(&self) -> usize {
        self.heads
    }

    pub fn head_dim(&self) -> usize {
        self.head_dim
    }

    /// Epoch of the last sync commit this cache applied (0 before the
    /// first); the in-order replay cursor for deferred [`CacheCommit`]s.
    pub fn commit_epoch(&self) -> u64 {
        self.commit_cursor.epoch()
    }

    /// Apply one sync decision: promote the old root to the model level,
    /// then compact (hit) or clear (miss) the tree level. Commits must
    /// arrive in issue order — `c.epoch == commit_epoch() + 1`, enforced by
    /// the [`CommitCursor`] — so a deferred replay can never skip or
    /// reorder cache maintenance. The cursor advances only after the
    /// promotion succeeded: a failed promote (e.g. past level full) leaves
    /// the cache at its old epoch so the commit can be retried or the
    /// request aborted coherently.
    pub fn apply_commit(&mut self, c: &CacheCommit) -> Result<()> {
        self.commit_cursor.check_next(c.epoch)?;
        self.promote_root_to_past()?;
        match &c.op {
            CommitOp::Hit { kept_old } => self.compact_tree(kept_old),
            CommitOp::Miss => self.clear_tree(),
        }
        self.commit_cursor.advance(c.epoch);
        Ok(())
    }

    /// Mutation epoch of layer `l`'s model-level (past) tensors.
    pub fn past_epoch(&self, l: usize) -> u64 {
        self.past_epoch[l]
    }

    /// Mutation epoch of layer `l`'s tree-level tensors.
    pub fn tree_epoch(&self, l: usize) -> u64 {
        self.tree_epoch[l]
    }

    fn bump_past(&mut self, l: usize) {
        self.clock += 1;
        self.past_epoch[l] = self.clock;
    }

    fn bump_tree(&mut self, l: usize) {
        self.clock += 1;
        self.tree_epoch[l] = self.clock;
    }

    fn bump_past_all(&mut self) {
        self.clock += 1;
        let c = self.clock;
        for e in &mut self.past_epoch {
            *e = c;
        }
    }

    fn bump_tree_all(&mut self) {
        self.clock += 1;
        let c = self.clock;
        for e in &mut self.tree_epoch {
            *e = c;
        }
    }

    #[inline]
    fn past_layer_stride(&self) -> usize {
        self.heads * self.past_cap * self.head_dim
    }

    #[inline]
    fn tree_layer_stride(&self) -> usize {
        self.heads * self.tree_cap * self.head_dim
    }

    /// Per-layer views [H, CAP, hd] for runtime arguments (zero-copy).
    pub fn past_k_layer(&self, l: usize) -> &[f32] {
        let s = self.past_layer_stride();
        &self.past_k[l * s..(l + 1) * s]
    }

    pub fn past_v_layer(&self, l: usize) -> &[f32] {
        let s = self.past_layer_stride();
        &self.past_v[l * s..(l + 1) * s]
    }

    pub fn tree_k_layer(&self, l: usize) -> &[f32] {
        let s = self.tree_layer_stride();
        &self.tree_k[l * s..(l + 1) * s]
    }

    pub fn tree_v_layer(&self, l: usize) -> &[f32] {
        let s = self.tree_layer_stride();
        &self.tree_v[l * s..(l + 1) * s]
    }

    /// Write a new KV block `[H, W, hd]` (first `count` rows valid) for
    /// layer `l` into tree slots `tree_len..tree_len+count`. All layers of
    /// the stage must append the same count before [`Self::commit_tree`].
    pub fn append_tree_block(
        &mut self,
        l: usize,
        k_block: &[f32],
        v_block: &[f32],
        block_w: usize,
        count: usize,
    ) -> Result<()> {
        ensure!(
            self.tree_len + count <= self.tree_cap,
            "tree cache overflow: {} + {count} > {}",
            self.tree_len,
            self.tree_cap
        );
        self.copy_block(l, k_block, v_block, block_w, count, true)?;
        self.bump_tree(l);
        Ok(())
    }

    /// Write a new KV block into the model level at
    /// `past_len..past_len+count` (prefill path). Commit with
    /// [`Self::commit_past`].
    pub fn append_past_block(
        &mut self,
        l: usize,
        k_block: &[f32],
        v_block: &[f32],
        block_w: usize,
        count: usize,
    ) -> Result<()> {
        ensure!(
            self.past_len + count <= self.past_cap,
            "past cache overflow: {} + {count} > {}",
            self.past_len,
            self.past_cap
        );
        self.copy_block(l, k_block, v_block, block_w, count, false)?;
        self.bump_past(l);
        Ok(())
    }

    fn copy_block(
        &mut self,
        l: usize,
        k_block: &[f32],
        v_block: &[f32],
        block_w: usize,
        count: usize,
        to_tree: bool,
    ) -> Result<()> {
        ensure!(count <= block_w, "count > block width");
        ensure!(
            k_block.len() == self.heads * block_w * self.head_dim,
            "bad block size"
        );
        let hd = self.head_dim;
        let (cap, base_len, stride) = if to_tree {
            (self.tree_cap, self.tree_len, self.tree_layer_stride())
        } else {
            (self.past_cap, self.past_len, self.past_layer_stride())
        };
        let (dst_k, dst_v) = if to_tree {
            (&mut self.tree_k, &mut self.tree_v)
        } else {
            (&mut self.past_k, &mut self.past_v)
        };
        for h in 0..self.heads {
            for r in 0..count {
                let src = (h * block_w + r) * hd;
                let dst = l * stride + (h * cap + base_len + r) * hd;
                dst_k[dst..dst + hd].copy_from_slice(&k_block[src..src + hd]);
                dst_v[dst..dst + hd].copy_from_slice(&v_block[src..src + hd]);
            }
        }
        Ok(())
    }

    /// Advance the tree length after all layers appended a block.
    pub fn commit_tree(&mut self, count: usize) {
        self.tree_len += count;
        debug_assert!(self.tree_len <= self.tree_cap);
    }

    /// Advance the model-level length (prefill).
    pub fn commit_past(&mut self, count: usize) {
        self.past_len += count;
        debug_assert!(self.past_len <= self.past_cap);
    }

    /// §3.4.3: transfer the first tree element (the old root, slot 0) to the
    /// model-level cache — one pass over all layers.
    pub fn promote_root_to_past(&mut self) -> Result<()> {
        ensure!(self.tree_len >= 1, "no tree entries to promote");
        ensure!(self.past_len < self.past_cap, "past cache full");
        let hd = self.head_dim;
        let ts = self.tree_layer_stride();
        let ps = self.past_layer_stride();
        for l in 0..self.layers {
            for h in 0..self.heads {
                let src = l * ts + (h * self.tree_cap) * hd; // slot 0
                let dst = l * ps + (h * self.past_cap + self.past_len) * hd;
                let (k, v) = (&self.tree_k[src..src + hd], &self.tree_v[src..src + hd]);
                // split borrows: copy via temporaries (hd is tiny)
                let kt: Vec<f32> = k.to_vec();
                let vt: Vec<f32> = v.to_vec();
                self.past_k[dst..dst + hd].copy_from_slice(&kt);
                self.past_v[dst..dst + hd].copy_from_slice(&vt);
            }
        }
        self.past_len += 1;
        self.bump_past_all();
        Ok(())
    }

    /// Promote an arbitrary tree slot to the model level (used by the
    /// static-tree STPP baseline, which accepts a whole path per round).
    pub fn promote_slot_to_past(&mut self, slot: usize) -> Result<()> {
        ensure!(slot < self.tree_len, "slot {slot} >= tree_len {}", self.tree_len);
        ensure!(self.past_len < self.past_cap, "past cache full");
        let hd = self.head_dim;
        let ts = self.tree_layer_stride();
        let ps = self.past_layer_stride();
        for l in 0..self.layers {
            for h in 0..self.heads {
                let src = l * ts + (h * self.tree_cap + slot) * hd;
                let dst = l * ps + (h * self.past_cap + self.past_len) * hd;
                let kt: Vec<f32> = self.tree_k[src..src + hd].to_vec();
                let vt: Vec<f32> = self.tree_v[src..src + hd].to_vec();
                self.past_k[dst..dst + hd].copy_from_slice(&kt);
                self.past_v[dst..dst + hd].copy_from_slice(&vt);
            }
        }
        self.past_len += 1;
        self.bump_past_all();
        Ok(())
    }

    /// Compact the tree level to the surviving slots (ascending `kept_old`
    /// from the prune). Only entries below the stage's current `tree_len`
    /// apply — those form a prefix of `kept_old` thanks to BFS ordering —
    /// so slot numbering stays equal to the new BFS index everywhere.
    pub fn compact_tree(&mut self, kept_old: &[usize]) {
        let hd = self.head_dim;
        let ts = self.tree_layer_stride();
        let keep: Vec<usize> = kept_old
            .iter()
            .copied()
            .take_while(|&s| s < self.tree_len)
            .collect();
        let moved = keep.iter().enumerate().any(|(n, &o)| n != o);
        for l in 0..self.layers {
            for h in 0..self.heads {
                let base = l * ts + h * self.tree_cap * hd;
                for (new_slot, &old_slot) in keep.iter().enumerate() {
                    if new_slot == old_slot {
                        continue;
                    }
                    let (dst, src) = (base + new_slot * hd, base + old_slot * hd);
                    self.tree_k.copy_within(src..src + hd, dst);
                    self.tree_v.copy_within(src..src + hd, dst);
                }
            }
        }
        self.tree_len = keep.len();
        if moved {
            self.bump_tree_all();
        }
    }

    /// Drop all tree-level entries (miss path). Length-only: device
    /// mirrors stay valid because stale slots are bias-masked.
    pub fn clear_tree(&mut self) {
        self.tree_len = 0;
    }

    /// Reset everything (new request). Length-only — see
    /// [`TwoLevelCache::clear_tree`]; subsequent appends overwrite slot 0
    /// onward and bump epochs then. The commit cursor restarts with the
    /// new request's commit sequence.
    pub fn reset(&mut self) {
        self.past_len = 0;
        self.tree_len = 0;
        self.commit_cursor.reset();
    }

    /// Read one (k, v) vector pair for tests.
    pub fn read_tree_slot(&self, l: usize, h: usize, slot: usize) -> (Vec<f32>, Vec<f32>) {
        let hd = self.head_dim;
        let base = l * self.tree_layer_stride() + (h * self.tree_cap + slot) * hd;
        (
            self.tree_k[base..base + hd].to_vec(),
            self.tree_v[base..base + hd].to_vec(),
        )
    }

    pub fn read_past_slot(&self, l: usize, h: usize, slot: usize) -> (Vec<f32>, Vec<f32>) {
        let hd = self.head_dim;
        let base = l * self.past_layer_stride() + (h * self.past_cap + slot) * hd;
        (
            self.past_k[base..base + hd].to_vec(),
            self.past_v[base..base + hd].to_vec(),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn block(heads: usize, w: usize, hd: usize, seed: f32) -> Vec<f32> {
        (0..heads * w * hd).map(|i| seed + i as f32).collect()
    }

    #[test]
    fn append_and_read_tree() {
        let mut c = TwoLevelCache::new(2, 2, 4, 16, 8);
        let k = block(2, 3, 4, 100.0);
        let v = block(2, 3, 4, 200.0);
        for l in 0..2 {
            c.append_tree_block(l, &k, &v, 3, 2).unwrap();
        }
        c.commit_tree(2);
        assert_eq!(c.tree_len(), 2);
        // head 1, row 1 of the block -> slot 1
        let (ks, vs) = c.read_tree_slot(0, 1, 1);
        let src = (1 * 3 + 1) * 4;
        assert_eq!(ks, k[src..src + 4].to_vec());
        assert_eq!(vs, v[src..src + 4].to_vec());
    }

    #[test]
    fn promote_moves_root_across_all_layers() {
        let mut c = TwoLevelCache::new(2, 1, 4, 8, 8);
        let k = block(1, 1, 4, 7.0);
        let v = block(1, 1, 4, 9.0);
        for l in 0..2 {
            c.append_tree_block(l, &k, &v, 1, 1).unwrap();
        }
        c.commit_tree(1);
        c.promote_root_to_past().unwrap();
        assert_eq!(c.past_len(), 1);
        for l in 0..2 {
            let (ks, _) = c.read_past_slot(l, 0, 0);
            assert_eq!(ks, k[..4].to_vec());
        }
    }

    #[test]
    fn compact_tree_keeps_prefix_of_kept() {
        let mut c = TwoLevelCache::new(1, 1, 2, 8, 8);
        // append 4 slots with recognizable values
        for slot in 0..4 {
            let k = vec![slot as f32; 2];
            let v = vec![slot as f32 + 0.5; 2];
            c.append_tree_block(0, &k, &v, 1, 1).unwrap();
            c.commit_tree(1);
        }
        // prune keeps old slots [1, 3]
        c.compact_tree(&[1, 3]);
        assert_eq!(c.tree_len(), 2);
        assert_eq!(c.read_tree_slot(0, 0, 0).0, vec![1.0, 1.0]);
        assert_eq!(c.read_tree_slot(0, 0, 1).0, vec![3.0, 3.0]);
    }

    #[test]
    fn compact_tree_ignores_unprocessed_suffix() {
        let mut c = TwoLevelCache::new(1, 1, 2, 8, 8);
        for slot in 0..2 {
            let k = vec![slot as f32; 2];
            c.append_tree_block(0, &k, &k, 1, 1).unwrap();
            c.commit_tree(1);
        }
        // kept list references slots this stage has not processed (>= 2)
        c.compact_tree(&[1, 5, 6]);
        assert_eq!(c.tree_len(), 1);
        assert_eq!(c.read_tree_slot(0, 0, 0).0, vec![1.0, 1.0]);
    }

    #[test]
    fn promote_arbitrary_slot() {
        let mut c = TwoLevelCache::new(1, 1, 2, 8, 8);
        for slot in 0..3 {
            let k = vec![slot as f32; 2];
            c.append_tree_block(0, &k, &k, 1, 1).unwrap();
            c.commit_tree(1);
        }
        c.promote_slot_to_past(2).unwrap();
        assert_eq!(c.read_past_slot(0, 0, 0).0, vec![2.0, 2.0]);
        assert!(c.promote_slot_to_past(5).is_err());
    }

    #[test]
    fn overflow_rejected() {
        let mut c = TwoLevelCache::new(1, 1, 2, 2, 2);
        let k = vec![0.0; 1 * 3 * 2];
        assert!(c.append_tree_block(0, &k, &k, 3, 3).is_err());
    }

    #[test]
    fn epochs_track_only_content_mutations() {
        let mut c = TwoLevelCache::new(2, 1, 2, 8, 8);
        let (p0, t0) = (c.past_epoch(0), c.tree_epoch(0));

        // append to layer 0's tree: only that layer's tree epoch moves
        let k = vec![1.0f32; 2];
        c.append_tree_block(0, &k, &k, 1, 1).unwrap();
        assert!(c.tree_epoch(0) > t0);
        assert_eq!(c.tree_epoch(1), 0);
        assert_eq!(c.past_epoch(0), p0);

        // commit / clear are length-only
        let t1 = c.tree_epoch(0);
        c.commit_tree(1);
        c.clear_tree();
        assert_eq!(c.tree_epoch(0), t1);

        // promote touches the past level of every layer, not the tree
        c.append_tree_block(0, &k, &k, 1, 1).unwrap();
        c.append_tree_block(1, &k, &k, 1, 1).unwrap();
        c.commit_tree(1);
        let t2 = c.tree_epoch(0);
        c.promote_root_to_past().unwrap();
        assert!(c.past_epoch(0) > p0);
        assert!(c.past_epoch(1) > 0);
        assert_eq!(c.tree_epoch(0), t2);

        // identity compaction leaves tree epochs alone; a real move bumps
        c.compact_tree(&[]);
        assert_eq!(c.tree_epoch(0), t2);
        for slot in 0..3 {
            let kk = vec![slot as f32; 2];
            c.append_tree_block(0, &kk, &kk, 1, 1).unwrap();
            c.append_tree_block(1, &kk, &kk, 1, 1).unwrap();
            c.commit_tree(1);
        }
        let t3 = c.tree_epoch(0);
        c.compact_tree(&[0, 1, 2]); // identity prefix: nothing moved
        assert_eq!(c.tree_epoch(0), t3);
        c.append_tree_block(0, &k, &k, 1, 1).unwrap();
        c.append_tree_block(1, &k, &k, 1, 1).unwrap();
        c.commit_tree(1);
        let t4 = c.tree_epoch(1);
        c.compact_tree(&[1, 3]); // slots move: all layers bump
        assert!(c.tree_epoch(0) > t4);
        assert!(c.tree_epoch(1) > t4);
    }

    #[test]
    fn clone_gets_fresh_identity() {
        let c = TwoLevelCache::new(1, 1, 2, 4, 4);
        let d = c.clone();
        assert_ne!(c.id(), d.id(), "clones must not alias device mirrors");
        assert_eq!(c.past_len(), d.past_len());
    }

    #[test]
    fn apply_commit_matches_manual_promote_compact_and_orders_epochs() {
        use std::sync::Arc;
        let mut a = TwoLevelCache::new(2, 1, 2, 8, 8);
        let mut b = TwoLevelCache::new(2, 1, 2, 8, 8);
        for slot in 0..3 {
            let k = vec![slot as f32; 2];
            for l in 0..2 {
                a.append_tree_block(l, &k, &k, 1, 1).unwrap();
                b.append_tree_block(l, &k, &k, 1, 1).unwrap();
            }
            a.commit_tree(1);
            b.commit_tree(1);
        }
        // manual eager sequence on `a`...
        a.promote_root_to_past().unwrap();
        a.compact_tree(&[1, 2]);
        // ...must equal the reified commit on `b`
        let hit = CacheCommit {
            epoch: 1,
            op: CommitOp::Hit {
                kept_old: Arc::new(vec![1, 2]),
            },
        };
        // out-of-order / replayed epochs are rejected
        assert!(b
            .apply_commit(&CacheCommit {
                epoch: 2,
                op: CommitOp::Miss
            })
            .is_err());
        b.apply_commit(&hit).unwrap();
        assert!(b.apply_commit(&hit).is_err(), "same epoch twice rejected");
        assert_eq!(b.commit_epoch(), 1);
        assert_eq!((a.past_len(), a.tree_len()), (b.past_len(), b.tree_len()));
        for l in 0..2 {
            assert_eq!(a.read_past_slot(l, 0, 0), b.read_past_slot(l, 0, 0));
            for s in 0..a.tree_len() {
                assert_eq!(a.read_tree_slot(l, 0, s), b.read_tree_slot(l, 0, s));
            }
        }
        // miss commit clears the tree level after promoting
        b.apply_commit(&CacheCommit {
            epoch: 2,
            op: CommitOp::Miss,
        })
        .unwrap();
        assert_eq!(b.tree_len(), 0);
        assert_eq!(b.past_len(), 2);
        assert_eq!(b.commit_epoch(), 2);
        // reset restarts the commit cursor for the next request
        b.reset();
        assert_eq!(b.commit_epoch(), 0);
    }

    #[test]
    fn prefill_appends_to_past() {
        let mut c = TwoLevelCache::new(1, 2, 2, 8, 4);
        let k = block(2, 2, 2, 1.0);
        c.append_past_block(0, &k, &k, 2, 2).unwrap();
        c.commit_past(2);
        assert_eq!(c.past_len(), 2);
        let (ks, _) = c.read_past_slot(0, 1, 1);
        let src = (1 * 2 + 1) * 2;
        assert_eq!(ks, k[src..src + 2].to_vec());
    }
}
