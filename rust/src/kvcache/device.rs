//! Device-resident mirror of one [`TwoLevelCache`] (ISSUE 2 tentpole;
//! in-place updates since ISSUE 7).
//!
//! PJRT buffers are immutable, but the KV update entry points
//! (`python/compile/kvops.py`) are lowered with argument 0 *donated*, so
//! the runtime may reuse the donated input's storage for the output. The
//! mirror exploits that to keep each layer's four tensors (`past_k/past_v`
//! `[H, P, hd]`, `tree_k/tree_v` `[H, T, hd]`) device-resident and update
//! them **in place**:
//!
//! * [`DeviceKvCache::append_block`] — a stage's freshly computed KV block
//!   is scattered into the resident level tensor right after the host
//!   append; only the `[H, W, hd]` block crosses the bus.
//! * [`DeviceKvCache::apply_commit`] — replays a [`super::CacheCommit`]
//!   on-device: the old tree root is promoted into the past tensors
//!   (scalar operands only) and a `Hit`'s surviving slots are compacted
//!   through a gather index vector. Zero level-tensor bytes move.
//!
//! Both fast paths require the resident copy to be *current* (its epoch
//! equals the host epoch before the mutation being mirrored); otherwise
//! they leave the slot for the **full re-upload fallback**
//! ([`DeviceKvCache::ensure_past`] / [`DeviceKvCache::ensure_tree`]),
//! which remains the conformance reference and the only path for
//! miss/reset recovery or shape-mismatched caches (tests use tiny shapes
//! that no lowered [`KvOps`] artifact matches). Every mutation is stamped
//! with the host cache's post-mutation epoch, so `ensure_*` sees a clean
//! level and serves it from residency.
//!
//! The mirror is keyed off-device by [`TwoLevelCache::id`] (see
//! [`crate::model::ModelHandles`]), holds no reference to the host cache,
//! and is safe to drop and rebuild at any time — worst case is one full
//! re-upload.
//!
//! Deferred sync commits need no special handling: a late
//! [`super::CacheCommit`] reaches [`DeviceKvCache::apply_commit`] through
//! the same [`crate::model::StageContext::apply_commit`] choke point as an
//! eager one, with the pre-mutation epochs captured immediately before
//! the host replay — so the device replay is identical either way
//! (asserted by the replay property tests in `tests/kvcache_device.rs`).

use anyhow::{ensure, Result};

use super::{CacheCommit, CommitOp, TwoLevelCache};
use crate::runtime::{DeviceBuffer, Executable, Runtime};

/// The compiled device-side KV update entry points for one model, plus
/// the shapes they were lowered for. Loaded by
/// [`crate::model::ModelCore::load_with_width`] when all four artifacts
/// exist; absent (and the mirror falls back to full re-uploads) otherwise
/// or when `PIPEDEC_NO_KV_APPEND` is set (the bench baseline).
pub struct KvOps {
    pub app_past: Executable,
    pub app_tree: Executable,
    pub promote: Executable,
    pub compact: Executable,
    pub heads: usize,
    pub head_dim: usize,
    pub past_cap: usize,
    pub tree_cap: usize,
    /// Width bucket of the `kv_append` src block (= the layer artifact's
    /// width, since the block is the layer's `k_new`/`v_new` output).
    pub width: usize,
}

impl KvOps {
    /// Whether these entry points were lowered for `cache`'s shapes. A
    /// mismatch (e.g. the tiny caches in unit tests) disables the device
    /// fast paths for that cache; the re-upload fallback still works.
    pub fn matches(&self, cache: &TwoLevelCache) -> bool {
        cache.heads() == self.heads
            && cache.head_dim() == self.head_dim
            && cache.past_cap() == self.past_cap
            && cache.tree_cap() == self.tree_cap
    }
}

/// Host epochs/lengths captured immediately *before* a host-side
/// [`TwoLevelCache::apply_commit`], so the device replay can check its
/// resident copies were current and address rows by their pre-commit
/// positions (the promote target row is the pre-commit `past_len`).
pub struct PreState {
    pub past_len: usize,
    pub tree_len: usize,
    pub past_epochs: Vec<u64>,
    pub tree_epochs: Vec<u64>,
}

impl PreState {
    pub fn capture(cache: &TwoLevelCache) -> Self {
        Self {
            past_len: cache.past_len(),
            tree_len: cache.tree_len(),
            past_epochs: (0..cache.layers()).map(|l| cache.past_epoch(l)).collect(),
            tree_epochs: (0..cache.layers()).map(|l| cache.tree_epoch(l)).collect(),
        }
    }
}

/// One level's device copy: the epoch it was last synced at plus k/v
/// buffers.
struct LevelSlot {
    epoch: u64,
    k: DeviceBuffer,
    v: DeviceBuffer,
}

#[derive(Default)]
struct LayerSlot {
    past: Option<LevelSlot>,
    tree: Option<LevelSlot>,
}

/// Per-level mirror traffic counters (monotonic; see
/// [`DeviceKvCache::counts`]).
#[derive(Debug, Default, Clone, Copy, PartialEq, Eq)]
pub struct MirrorCounts {
    /// Full k/v re-uploads of a past level (fallback path).
    pub past_uploads: u64,
    /// Full k/v re-uploads of a tree level (fallback path).
    pub tree_uploads: u64,
    /// Clean past levels served from residency by `ensure_past`.
    pub past_reuses: u64,
    /// Clean tree levels served from residency by `ensure_tree`.
    pub tree_reuses: u64,
    /// In-place device updates of a past level (append or promote).
    pub past_appends: u64,
    /// In-place device updates of a tree level (append or compact).
    pub tree_appends: u64,
    /// Host→device bytes moved by the in-place paths (blocks + operands).
    pub appended_bytes: u64,
    /// Host→device bytes moved by full level re-uploads.
    pub reuploaded_bytes: u64,
}

/// Per-cache device mirror; one slot pair (past/tree) per stage layer.
pub struct DeviceKvCache {
    slots: Vec<LayerSlot>,
    counts: MirrorCounts,
}

impl DeviceKvCache {
    pub fn new(layers: usize) -> Self {
        Self {
            slots: (0..layers).map(|_| LayerSlot::default()).collect(),
            counts: MirrorCounts::default(),
        }
    }

    pub fn layers(&self) -> usize {
        self.slots.len()
    }

    /// Per-level upload/reuse/append counters since construction.
    pub fn counts(&self) -> MirrorCounts {
        self.counts
    }

    /// Bring layer `l`'s past-level device copy up to date with `cache`.
    pub fn ensure_past(&mut self, rt: &Runtime, cache: &TwoLevelCache, l: usize) -> Result<()> {
        self.ensure_level(rt, cache, l, true)
    }

    /// Bring layer `l`'s tree-level device copy up to date with `cache`.
    pub fn ensure_tree(&mut self, rt: &Runtime, cache: &TwoLevelCache, l: usize) -> Result<()> {
        self.ensure_level(rt, cache, l, false)
    }

    /// Bring *every* layer's device copy (both levels) up to date with
    /// `cache` through the re-upload fallback. This is the mirror's
    /// recovery and conformance entry point: whatever the in-place paths
    /// did (or skipped), a `sync` afterwards must be a no-op on a clean
    /// mirror and must restore bit-identical device state on a stale one —
    /// the property `tests/kvcache_device.rs` checks the append/commit
    /// fast paths against. Engines do not call it on the hot path (they
    /// sync lazily per layer via `ensure_past`/`ensure_tree`); it is for
    /// warming a cache outside a latency-sensitive window and for tests.
    pub fn sync(&mut self, rt: &Runtime, cache: &TwoLevelCache) -> Result<()> {
        for l in 0..self.slots.len() {
            self.ensure_past(rt, cache, l)?;
            self.ensure_tree(rt, cache, l)?;
        }
        Ok(())
    }

    /// Shared sync for one layer × level: clean ⇒ credit `saved_kv` and
    /// reuse the buffers; dirty ⇒ upload a fresh k/v pair tagged with the
    /// host epoch (counted into the re-upload byte bucket).
    fn ensure_level(
        &mut self,
        rt: &Runtime,
        cache: &TwoLevelCache,
        l: usize,
        past: bool,
    ) -> Result<()> {
        let epoch = if past { cache.past_epoch(l) } else { cache.tree_epoch(l) };
        let slot = if past { &self.slots[l].past } else { &self.slots[l].tree };
        if let Some(s) = slot {
            if s.epoch == epoch {
                if past {
                    self.counts.past_reuses += 1;
                } else {
                    self.counts.tree_reuses += 1;
                }
                rt.stats().add_saved_kv(2 * level_bytes(cache, past));
                return Ok(());
            }
        }
        let cap = if past { cache.past_cap() } else { cache.tree_cap() };
        let dims = [cache.heads(), cap, cache.head_dim()];
        let (ks, vs) = if past {
            (cache.past_k_layer(l), cache.past_v_layer(l))
        } else {
            (cache.tree_k_layer(l), cache.tree_v_layer(l))
        };
        let k = rt.upload_f32(ks, &dims)?;
        let v = rt.upload_f32(vs, &dims)?;
        let bytes = 2 * level_bytes(cache, past);
        rt.stats().add_kv_reuploaded(bytes);
        self.counts.reuploaded_bytes += bytes as u64;
        let slot = if past { &mut self.slots[l].past } else { &mut self.slots[l].tree };
        *slot = Some(LevelSlot { epoch, k, v });
        if past {
            self.counts.past_uploads += 1;
        } else {
            self.counts.tree_uploads += 1;
        }
        Ok(())
    }

    /// In-place append fast path: mirror a host
    /// [`TwoLevelCache::append_tree_block`] / `append_past_block` that
    /// just ran, by scattering the same `[H, W, hd]` block into the
    /// resident level tensor through the donated `kv_append` entry point.
    ///
    /// `pre_epoch` is the level's host epoch captured *before* the host
    /// append; the fast path only fires when the resident copy was
    /// current at that epoch (otherwise the slot is left as-is and the
    /// next `ensure_*` re-uploads). `start` is the row the host wrote at
    /// (the pre-append level length). On success the slot is restamped
    /// with the post-append host epoch, so `ensure_*` treats it as clean.
    /// Any device-op failure drops the slot — never poisons it — and the
    /// fallback rebuilds from host truth.
    #[allow(clippy::too_many_arguments)]
    pub fn append_block(
        &mut self,
        rt: &Runtime,
        ops: &KvOps,
        cache: &TwoLevelCache,
        l: usize,
        to_tree: bool,
        pre_epoch: u64,
        start: usize,
        k_block: &[f32],
        v_block: &[f32],
        block_w: usize,
        count: usize,
    ) -> Result<()> {
        let post = if to_tree { cache.tree_epoch(l) } else { cache.past_epoch(l) };
        let lvl = if to_tree { &mut self.slots[l].tree } else { &mut self.slots[l].past };
        let Some(slot) = lvl.take() else {
            return Ok(()); // nothing resident yet: lazy ensure will upload
        };
        if slot.epoch != pre_epoch || !ops.matches(cache) || block_w != ops.width
            || count > block_w
        {
            // resident copy already stale (or shapes off): keep it; the
            // epoch mismatch routes the next ensure through the fallback
            *lvl = Some(slot);
            return Ok(());
        }
        if count == 0 {
            // host bumped the epoch but wrote nothing: contents still match
            *lvl = Some(LevelSlot { epoch: post, ..slot });
            return Ok(());
        }
        let exe = if to_tree { &ops.app_tree } else { &ops.app_past };
        let LevelSlot { k, v, .. } = slot;
        let run = (|| -> Result<(DeviceBuffer, DeviceBuffer)> {
            crate::faultinject::fire(crate::faultinject::Site::DeviceOp)?;
            let dims = [ops.heads, block_w, ops.head_dim];
            let k_src = rt.upload_f32(k_block, &dims)?;
            let v_src = rt.upload_f32(v_block, &dims)?;
            let start_b = rt.upload_i32(&[start as i32], &[])?;
            let count_b = rt.upload_i32(&[count as i32], &[])?;
            let k2 = exe.run_bufs_to_bufs(k, &[&k_src, &start_b, &count_b])?;
            let v2 = exe.run_bufs_to_bufs(v, &[&v_src, &start_b, &count_b])?;
            Ok((k2, v2))
        })();
        match run {
            Ok((k, v)) => {
                let bytes = 2 * k_block.len() * 4 + 8;
                rt.stats().add_kv_appended(bytes);
                self.counts.appended_bytes += bytes as u64;
                if to_tree {
                    self.counts.tree_appends += 1;
                } else {
                    self.counts.past_appends += 1;
                }
                *lvl = Some(LevelSlot { epoch: post, k, v });
                Ok(())
            }
            // slot dropped: fall back to a clean re-upload on next ensure
            Err(_) => Ok(()),
        }
    }

    /// In-place replay of one [`CacheCommit`] that the host cache has
    /// *already* applied (`pre` holds the epochs/lengths from just before
    /// that replay): promote the old tree root into the resident past
    /// tensors, then compact a `Hit`'s surviving tree slots through a
    /// gather index. Only scalar operands and one `[T]` i32 index vector
    /// cross the bus — zero level-tensor bytes.
    ///
    /// Per layer, each step fires only when the resident copies it reads
    /// and writes were current at their `pre` epochs; otherwise the slot
    /// keeps its stale stamp and the next `ensure_*` re-uploads it. A
    /// `Miss`'s `clear_tree` and identity compactions are length-only on
    /// the host (no epoch bump), so they need no device work at all.
    pub fn apply_commit(
        &mut self,
        rt: &Runtime,
        ops: &KvOps,
        cache: &TwoLevelCache,
        commit: &CacheCommit,
        pre: &PreState,
    ) -> Result<()> {
        if !ops.matches(cache) || pre.past_epochs.len() != self.slots.len() {
            return Ok(());
        }
        ensure!(
            cache.layers() == self.slots.len(),
            "mirror layers {} != cache layers {}",
            self.slots.len(),
            cache.layers()
        );
        // operands shared by every layer's promote: tree slot 0 -> past
        // row `pre.past_len`
        let slot_b = rt.upload_i32(&[0], &[])?;
        let pos_b = rt.upload_i32(&[pre.past_len as i32], &[])?;
        rt.stats().add_kv_appended(8);
        self.counts.appended_bytes += 8;

        // Hit compaction: surviving pre-commit slots below this cache's
        // processed prefix (same take_while as the host compact_tree)
        let keep: Option<Vec<usize>> = match &commit.op {
            CommitOp::Hit { kept_old } => Some(
                kept_old
                    .iter()
                    .copied()
                    .take_while(|&s| s < pre.tree_len)
                    .collect(),
            ),
            CommitOp::Miss => None,
        };
        let moved = keep
            .as_ref()
            .is_some_and(|k| k.iter().enumerate().any(|(n, &o)| n != o));
        let idx_b = if moved {
            let keep = keep.as_ref().expect("moved implies hit");
            let mut idx: Vec<i32> = (0..ops.tree_cap as i32).collect();
            for (new, &old) in keep.iter().enumerate() {
                idx[new] = old as i32;
            }
            let b = rt.upload_i32(&idx, &[ops.tree_cap])?;
            rt.stats().add_kv_appended(idx.len() * 4);
            self.counts.appended_bytes += (idx.len() * 4) as u64;
            Some(b)
        } else {
            None
        };

        for l in 0..self.slots.len() {
            let LayerSlot { past, tree } = &mut self.slots[l];
            // promote: donates past k/v, reads tree k/v at their pre state
            let tree_current = tree.as_ref().is_some_and(|t| t.epoch == pre.tree_epochs[l]);
            if tree_current {
                if let Some(p) = past.take() {
                    if p.epoch == pre.past_epochs[l] {
                        let t = tree.as_ref().expect("checked current");
                        let LevelSlot { k, v, .. } = p;
                        let run = (|| -> Result<(DeviceBuffer, DeviceBuffer)> {
                            crate::faultinject::fire(crate::faultinject::Site::DeviceOp)?;
                            let k2 = ops
                                .promote
                                .run_bufs_to_bufs(k, &[&t.k, &slot_b, &pos_b])?;
                            let v2 = ops
                                .promote
                                .run_bufs_to_bufs(v, &[&t.v, &slot_b, &pos_b])?;
                            Ok((k2, v2))
                        })();
                        if let Ok((k, v)) = run {
                            self.counts.past_appends += 1;
                            *past = Some(LevelSlot {
                                epoch: cache.past_epoch(l),
                                k,
                                v,
                            });
                        } // else: slot dropped, ensure_past re-uploads
                    } else {
                        *past = Some(p); // stale stamp routes to fallback
                    }
                }
            }
            // compact: donates tree k/v (only when the host really moved
            // slots — identity compactions left the epoch alone)
            if moved {
                if let Some(t) = tree.take() {
                    if t.epoch == pre.tree_epochs[l] {
                        let idx = idx_b.as_ref().expect("moved implies idx");
                        let LevelSlot { k, v, .. } = t;
                        let run = (|| -> Result<(DeviceBuffer, DeviceBuffer)> {
                            crate::faultinject::fire(crate::faultinject::Site::DeviceOp)?;
                            let k2 = ops.compact.run_bufs_to_bufs(k, &[idx])?;
                            let v2 = ops.compact.run_bufs_to_bufs(v, &[idx])?;
                            Ok((k2, v2))
                        })();
                        if let Ok((k, v)) = run {
                            self.counts.tree_appends += 1;
                            *tree = Some(LevelSlot {
                                epoch: cache.tree_epoch(l),
                                k,
                                v,
                            });
                        }
                    } else {
                        *tree = Some(t);
                    }
                }
            }
        }
        Ok(())
    }

    /// Device (k, v) of layer `l`'s past level; `None` before the first
    /// [`DeviceKvCache::ensure_past`].
    pub fn past(&self, l: usize) -> Option<(&DeviceBuffer, &DeviceBuffer)> {
        self.slots[l].past.as_ref().map(|s| (&s.k, &s.v))
    }

    /// Device (k, v) of layer `l`'s tree level.
    pub fn tree(&self, l: usize) -> Option<(&DeviceBuffer, &DeviceBuffer)> {
        self.slots[l].tree.as_ref().map(|s| (&s.k, &s.v))
    }
}

/// Bytes of one `[H, CAP, hd]` f32 tensor for a level of `cache`.
fn level_bytes(cache: &TwoLevelCache, past: bool) -> usize {
    let cap = if past { cache.past_cap() } else { cache.tree_cap() };
    cache.heads() * cap * cache.head_dim() * 4
}
