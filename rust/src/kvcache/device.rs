//! Device-resident mirror of one [`TwoLevelCache`] (ISSUE 2 tentpole).
//!
//! PJRT buffers are immutable, so the mirror is a *versioned* copy: each
//! layer's four tensors (`past_k/past_v` `[H, P, hd]`, `tree_k/tree_v`
//! `[H, T, hd]`) are uploaded tagged with the host cache's mutation epoch
//! for that layer/level, and re-uploaded only when the host epoch has
//! moved on. The seed path re-marshalled all four tensors for every layer
//! on every `layer_forward` call; with the mirror, a clean level costs
//! nothing and its would-be bytes are credited to
//! [`crate::runtime::TransferStats::add_saved`] so benches can report the
//! reduction.
//!
//! The mirror is keyed off-device by [`TwoLevelCache::id`] (see
//! [`crate::model::ModelHandles`]), holds no reference to the host cache,
//! and is safe to drop and rebuild at any time — worst case is one full
//! re-upload.
//!
//! Deferred sync commits (ISSUE 5) need no special handling here: a
//! [`super::CacheCommit`] applied late mutates the host tensors through
//! the same `promote`/`compact` entry points, bumping the same per-layer
//! epochs, so the mirror re-uploads exactly what an eager sync would have
//! — only later, right before the next forward pass that reads it
//! (asserted by the replay property test in `tests/kvcache_device.rs`).

use anyhow::Result;

use super::TwoLevelCache;
use crate::runtime::{DeviceBuffer, Runtime};

/// One level's device copy: the epoch it was uploaded at plus k/v buffers.
struct LevelSlot {
    epoch: u64,
    k: DeviceBuffer,
    v: DeviceBuffer,
}

#[derive(Default)]
struct LayerSlot {
    past: Option<LevelSlot>,
    tree: Option<LevelSlot>,
}

/// Per-cache device mirror; one slot pair (past/tree) per stage layer.
pub struct DeviceKvCache {
    slots: Vec<LayerSlot>,
    uploads: u64,
    reuses: u64,
}

impl DeviceKvCache {
    pub fn new(layers: usize) -> Self {
        Self {
            slots: (0..layers).map(|_| LayerSlot::default()).collect(),
            uploads: 0,
            reuses: 0,
        }
    }

    pub fn layers(&self) -> usize {
        self.slots.len()
    }

    /// (full uploads performed, clean reuses served) across both levels.
    pub fn upload_counts(&self) -> (u64, u64) {
        (self.uploads, self.reuses)
    }

    /// Bring layer `l`'s past-level device copy up to date with `cache`.
    pub fn ensure_past(&mut self, rt: &Runtime, cache: &TwoLevelCache, l: usize) -> Result<()> {
        self.ensure_level(rt, cache, l, true)
    }

    /// Bring layer `l`'s tree-level device copy up to date with `cache`.
    pub fn ensure_tree(&mut self, rt: &Runtime, cache: &TwoLevelCache, l: usize) -> Result<()> {
        self.ensure_level(rt, cache, l, false)
    }

    /// Bring *every* layer's device copy (both levels) up to date with
    /// `cache`. Convenience only — the engine hot path syncs lazily per
    /// layer (`ensure_past`/`ensure_tree`) and does not call this; it
    /// exists for warming a cache outside a latency-sensitive window and
    /// as the sync entry point of the mirror conformance tests in
    /// `tests/kvcache_device.rs`.
    pub fn sync(&mut self, rt: &Runtime, cache: &TwoLevelCache) -> Result<()> {
        for l in 0..self.slots.len() {
            self.ensure_past(rt, cache, l)?;
            self.ensure_tree(rt, cache, l)?;
        }
        Ok(())
    }

    /// Shared sync for one layer × level: clean ⇒ credit `saved_kv` and
    /// reuse the buffers; dirty ⇒ upload a fresh k/v pair tagged with the
    /// host epoch.
    fn ensure_level(
        &mut self,
        rt: &Runtime,
        cache: &TwoLevelCache,
        l: usize,
        past: bool,
    ) -> Result<()> {
        let epoch = if past { cache.past_epoch(l) } else { cache.tree_epoch(l) };
        let slot = if past { &self.slots[l].past } else { &self.slots[l].tree };
        if let Some(s) = slot {
            if s.epoch == epoch {
                self.reuses += 1;
                rt.stats().add_saved_kv(2 * level_bytes(cache, past));
                return Ok(());
            }
        }
        let cap = if past { cache.past_cap() } else { cache.tree_cap() };
        let dims = [cache.heads(), cap, cache.head_dim()];
        let (ks, vs) = if past {
            (cache.past_k_layer(l), cache.past_v_layer(l))
        } else {
            (cache.tree_k_layer(l), cache.tree_v_layer(l))
        };
        let k = rt.upload_f32(ks, &dims)?;
        let v = rt.upload_f32(vs, &dims)?;
        let slot = if past { &mut self.slots[l].past } else { &mut self.slots[l].tree };
        *slot = Some(LevelSlot { epoch, k, v });
        self.uploads += 1;
        Ok(())
    }

    /// Device (k, v) of layer `l`'s past level; `None` before the first
    /// [`DeviceKvCache::ensure_past`].
    pub fn past(&self, l: usize) -> Option<(&DeviceBuffer, &DeviceBuffer)> {
        self.slots[l].past.as_ref().map(|s| (&s.k, &s.v))
    }

    /// Device (k, v) of layer `l`'s tree level.
    pub fn tree(&self, l: usize) -> Option<(&DeviceBuffer, &DeviceBuffer)> {
        self.slots[l].tree.as_ref().map(|s| (&s.k, &s.v))
    }
}

/// Bytes of one `[H, CAP, hd]` f32 tensor for a level of `cache`.
fn level_bytes(cache: &TwoLevelCache, past: bool) -> usize {
    let cap = if past { cache.past_cap() } else { cache.tree_cap() };
    cache.heads() * cap * cache.head_dim() * 4
}
