//! Tiered cross-request KV prefix cache (ISSUE 8).
//!
//! Template-heavy traffic (shared system prompts, few-shot scaffolds)
//! re-computes the same prefill KV for nearly every session. This module
//! turns that into a cache problem: a content-addressed [`PrefixStore`]
//! keys **chunk-aligned token prefixes** by rolling hash. Storage is
//! block-granular: each [`PrefixEntry`] holds exactly one chunk's worth
//! of past-KV rows for every pipeline cache (all stage caches plus the
//! draft cache), keyed by the hash of the *entire* prefix up to that
//! block's end boundary. Two prompts that share a template but diverge
//! in their suffixes therefore share every template block — the store
//! converges on one resident copy per block, and a lookup walks the
//! chain of consecutive blocks to cover the longest cached prefix.
//!
//! Blocks live in two tiers:
//!
//! * **L1** — host memory, `Arc`-shared read-only [`PrefixEntry`]s.
//!   Concurrent sessions seeding from the same template share one
//!   resident copy per block; sessions copy-on-seed into their private
//!   [`TwoLevelCache`]s, so entries are never mutated after insert
//!   (see `rust/CONCURRENCY.md`).
//! * **L2** — a disk spill directory. Blocks evicted from L1 under the
//!   byte budget are serialized with a whole-payload checksum; a hit
//!   verifies, promotes back to L1, and deletes the spill file. A
//!   corrupt or truncated file fails verification, is deleted, and the
//!   probe degrades to a miss — the store never returns bad tensors.
//!
//! Both tiers run LRU eviction against configurable byte budgets
//! ([`config::PrefixCacheConfig`](crate::config::PrefixCacheConfig),
//! `[prefix_cache]` in TOML, `PIPEDEC_NO_PREFIX_CACHE` kill-switch).
//!
//! Keys are computed over the **context-truncated** prompt (the
//! scheduler truncates `prompt_ids` before admission), so a prompt that
//! only differs beyond the truncation point still hits, and a truncated
//! prompt can never alias an untruncated sibling: every entry stores its
//! exact token prefix and every probe compares tokens, not just hashes.
//!
//! Lookup covers the **longest** chain of consecutive cached blocks no
//! longer than the caller's cap (the caller keeps at least the final
//! prompt token uncovered so prefill still produces logits). Engines
//! seed each session cache block-by-block via [`PrefixKv::seed`] (host
//! append + commit; device mirrors warm lazily through the existing
//! epoch-diff upload path) and insert the session's own uncovered
//! blocks after prefill via [`PrefixKv::extract_range`].

use anyhow::{bail, ensure, Context, Result};
use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::Arc;

use super::TwoLevelCache;
use crate::runtime::bytes::as_byte_slice;

const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
const FNV_PRIME: u64 = 0x0000_0100_0000_01b3;
const MAGIC: &[u8; 8] = b"PDPFXV1\0";

/// Incremental FNV-1a over token ids — "rolling" in the sense that the
/// key for `tokens[..n+chunk]` extends the key for `tokens[..n]` without
/// re-hashing the shared prefix.
fn hash_extend(mut h: u64, tokens: &[u32]) -> u64 {
    for t in tokens {
        for b in t.to_le_bytes() {
            h ^= u64::from(b);
            h = h.wrapping_mul(FNV_PRIME);
        }
    }
    h
}

/// Content key for an exact token prefix.
pub fn prefix_key(tokens: &[u32]) -> u64 {
    hash_extend(FNV_OFFSET, tokens)
}

/// One block of past-KV rows for one [`TwoLevelCache`] (one pipeline
/// stage cache or the draft cache), covering prompt rows
/// `start..start + rows`. Layout matches
/// `TwoLevelCache::append_past_block` with `block_w == rows`: per layer
/// `[heads, rows, head_dim]`, layers contiguous.
#[derive(Debug, Clone, PartialEq)]
pub struct PrefixKv {
    pub layers: usize,
    pub heads: usize,
    pub head_dim: usize,
    /// Absolute row offset of this block in the prompt.
    pub start: usize,
    /// Rows held by this block.
    pub rows: usize,
    pub k: Vec<f32>,
    pub v: Vec<f32>,
}

impl PrefixKv {
    fn layer_stride(&self) -> usize {
        self.heads * self.rows * self.head_dim
    }

    /// Resident size of the tensor payload in bytes.
    pub fn bytes(&self) -> usize {
        (self.k.len() + self.v.len()) * std::mem::size_of::<f32>()
    }

    /// Copy rows `start..end` of the cache's model level out into a
    /// standalone block (the cache keeps its copy).
    pub fn extract_range(cache: &TwoLevelCache, start: usize, end: usize) -> Result<Self> {
        ensure!(
            start < end && end <= cache.past_len(),
            "prefix extract: rows {start}..{end} out of past_len {}",
            cache.past_len()
        );
        let (layers, heads, hd) = (cache.layers(), cache.heads(), cache.head_dim());
        let cap = cache.past_cap();
        let rows = end - start;
        let stride = heads * rows * hd;
        let mut k = vec![0.0f32; layers * stride];
        let mut v = vec![0.0f32; layers * stride];
        for l in 0..layers {
            let (src_k, src_v) = (cache.past_k_layer(l), cache.past_v_layer(l));
            for h in 0..heads {
                for r in 0..rows {
                    let src = (h * cap + start + r) * hd;
                    let dst = l * stride + (h * rows + r) * hd;
                    k[dst..dst + hd].copy_from_slice(&src_k[src..src + hd]);
                    v[dst..dst + hd].copy_from_slice(&src_v[src..src + hd]);
                }
            }
        }
        Ok(Self {
            layers,
            heads,
            head_dim: hd,
            start,
            rows,
            k,
            v,
        })
    }

    /// Seed a session cache's model level from this block: append rows
    /// `start..start + rows` to every layer and commit. The cache's
    /// past length must equal `start` (blocks seed in chain order onto a
    /// fresh cache). The host-side epoch bump makes the device mirror
    /// re-upload lazily through the existing path on first use.
    pub fn seed(&self, cache: &mut TwoLevelCache) -> Result<()> {
        ensure!(
            cache.past_len() == self.start,
            "prefix seed out of order: block starts at row {} but cache holds {}",
            self.start,
            cache.past_len()
        );
        ensure!(
            self.layers == cache.layers()
                && self.heads == cache.heads()
                && self.head_dim == cache.head_dim(),
            "prefix seed shape mismatch: block [{}x{}x{}] vs cache [{}x{}x{}]",
            self.layers,
            self.heads,
            self.head_dim,
            cache.layers(),
            cache.heads(),
            cache.head_dim()
        );
        ensure!(
            self.start + self.rows <= cache.past_cap(),
            "prefix seed overflow: {} rows > past_cap {}",
            self.start + self.rows,
            cache.past_cap()
        );
        let stride = self.layer_stride();
        for l in 0..self.layers {
            cache.append_past_block(
                l,
                &self.k[l * stride..(l + 1) * stride],
                &self.v[l * stride..(l + 1) * stride],
                self.rows,
                self.rows,
            )?;
        }
        cache.commit_past(self.rows);
        Ok(())
    }
}

/// One cached block: the exact (context-truncated, chunk-aligned) token
/// prefix it extends — the block holds the KV rows for the *last* chunk
/// of `tokens`, for every pipeline cache of the producing engine (stage
/// caches in order, then the draft cache). Read-only after insert;
/// shared by `Arc`.
#[derive(Debug, Clone, PartialEq)]
pub struct PrefixEntry {
    pub tokens: Vec<u32>,
    pub kv: Vec<PrefixKv>,
}

impl PrefixEntry {
    /// Resident size in bytes (tensor payload + token key).
    pub fn bytes(&self) -> usize {
        self.kv.iter().map(PrefixKv::bytes).sum::<usize>()
            + self.tokens.len() * std::mem::size_of::<u32>()
    }
}

/// Monotonic counters describing store behaviour; flow into per-session
/// metrics and `BENCH_prefix.json`.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct PrefixStats {
    /// Lookups that covered at least one block without touching disk.
    pub l1_hits: u64,
    /// Lookups that covered at least one block from disk (verified +
    /// promoted to L1).
    pub l2_hits: u64,
    /// Lookups with no usable cached prefix.
    pub misses: u64,
    /// New blocks admitted to L1.
    pub inserts: u64,
    /// Insert/bump calls that found the block already resident (shared
    /// template converging on one copy).
    pub ref_bumps: u64,
    /// Blocks evicted from a tier under its byte budget (an L1→L2
    /// demotion counts once; dropping from L2 counts once more).
    pub evictions: u64,
    /// L1 evictions that landed on disk instead of being dropped.
    pub spills: u64,
    /// L2 blocks deleted because verification failed (corrupt or
    /// truncated spill files).
    pub corrupt_dropped: u64,
}

struct L1Slot {
    entry: Arc<PrefixEntry>,
    last_used: u64,
}

struct L2Slot {
    path: PathBuf,
    bytes: usize,
    last_used: u64,
}

/// Content-addressed two-tier store for prefill prefix KV blocks.
///
/// Single-owner (one per engine, probed at admission on the coordinator
/// thread); the `Arc`s it hands out are what cross threads, and those
/// are read-only. Not a `Sync` structure by design.
pub struct PrefixStore {
    chunk: usize,
    l1_budget: usize,
    l2_budget: usize,
    l2_dir: Option<PathBuf>,
    tick: u64,
    l1: HashMap<u64, L1Slot>,
    l2: HashMap<u64, L2Slot>,
    l1_bytes: usize,
    l2_bytes: usize,
    stats: PrefixStats,
}

impl PrefixStore {
    /// `chunk_tokens` is the block granularity: every stored block holds
    /// exactly this many rows and is keyed at a boundary that is a
    /// multiple of it. `l2_dir = None` disables the disk tier (L1
    /// evictions drop instead of spilling).
    pub fn new(
        chunk_tokens: usize,
        l1_budget: usize,
        l2_budget: usize,
        l2_dir: Option<PathBuf>,
    ) -> Result<Self> {
        ensure!(chunk_tokens >= 1, "prefix chunk_tokens must be >= 1");
        if let Some(dir) = &l2_dir {
            std::fs::create_dir_all(dir)
                .with_context(|| format!("create prefix L2 dir {}", dir.display()))?;
        }
        Ok(Self {
            chunk: chunk_tokens,
            l1_budget,
            l2_budget,
            l2_dir,
            tick: 0,
            l1: HashMap::new(),
            l2: HashMap::new(),
            l1_bytes: 0,
            l2_bytes: 0,
            stats: PrefixStats::default(),
        })
    }

    /// Build from the engine's `[prefix_cache]` config. Returns `None`
    /// when disabled (by config or the `PIPEDEC_NO_PREFIX_CACHE`
    /// kill-switch, read once here at engine construction). A nonzero
    /// `chunk_tokens` is rounded down to a multiple of the model's
    /// prefill chunk width (minimum one width): seeded prefixes then
    /// end exactly on a prefill chunk boundary, so the uncovered suffix
    /// re-runs with the same chunk splits — and the same float summation
    /// order, hence bit-identical tokens — as the uncached path.
    pub fn from_config(
        cfg: &crate::config::PrefixCacheConfig,
        prefill_width: usize,
    ) -> Result<Option<Self>> {
        if !cfg.runtime_enabled() {
            return Ok(None);
        }
        let w = prefill_width.max(1);
        let chunk = if cfg.chunk_tokens == 0 {
            w
        } else {
            (cfg.chunk_tokens / w).max(1) * w
        };
        Self::new(
            chunk,
            cfg.l1_bytes,
            cfg.l2_bytes,
            cfg.l2_dir.clone().map(PathBuf::from),
        )
        .map(Some)
    }

    pub fn chunk_tokens(&self) -> usize {
        self.chunk
    }

    /// Largest chunk-aligned length `<= n`.
    pub fn align_down(&self, n: usize) -> usize {
        n / self.chunk * self.chunk
    }

    pub fn l1_bytes(&self) -> usize {
        self.l1_bytes
    }

    pub fn l2_bytes(&self) -> usize {
        self.l2_bytes
    }

    pub fn l1_len(&self) -> usize {
        self.l1.len()
    }

    pub fn l2_len(&self) -> usize {
        self.l2.len()
    }

    pub fn stats(&self) -> PrefixStats {
        self.stats
    }

    fn touch(&mut self) -> u64 {
        self.tick += 1;
        self.tick
    }

    /// Longest chain of consecutive cached blocks covering a prefix of
    /// `prompt` no longer than `max_tokens`, in seeding order (block at
    /// `0..chunk` first). The walk extends the rolling hash one chunk at
    /// a time and stops at the first boundary with no verified block;
    /// every candidate compares exact tokens so hash collisions read as
    /// misses. Per call, exactly one of {l1_hits, l2_hits, misses}
    /// advances: a miss if the chain is empty, an l2 hit if any block
    /// was promoted from disk, an l1 hit otherwise.
    pub fn lookup(&mut self, prompt: &[u32], max_tokens: usize) -> Vec<Arc<PrefixEntry>> {
        let cap = self.align_down(max_tokens.min(prompt.len()));
        let mut chain = Vec::new();
        let mut used_l2 = false;
        let mut h = FNV_OFFSET;
        let mut len = 0;
        while len < cap {
            h = hash_extend(h, &prompt[len..len + self.chunk]);
            len += self.chunk;
            let now = self.touch();
            if let Some(slot) = self.l1.get_mut(&h) {
                if slot.entry.tokens == prompt[..len] {
                    slot.last_used = now;
                    chain.push(Arc::clone(&slot.entry));
                    continue;
                }
                break; // hash collision — different content
            }
            if self.l2.contains_key(&h) {
                if let Some(entry) = self.promote_l2(h, &prompt[..len]) {
                    used_l2 = true;
                    chain.push(entry);
                    continue;
                }
            }
            break; // first uncovered boundary ends the chain
        }
        if chain.is_empty() {
            self.stats.misses += 1;
        } else if used_l2 {
            self.stats.l2_hits += 1;
        } else {
            self.stats.l1_hits += 1;
        }
        chain
    }

    /// Peek an L1 block without touching LRU state or counters (test /
    /// diagnostics hook).
    pub fn peek_l1(&self, tokens: &[u32]) -> Option<Arc<PrefixEntry>> {
        let slot = self.l1.get(&prefix_key(tokens))?;
        (slot.entry.tokens == tokens).then(|| Arc::clone(&slot.entry))
    }

    /// Is a block for this exact prefix resident in either tier? (L2
    /// presence is judged by key only; verification happens on the hit
    /// path.)
    pub fn contains(&self, tokens: &[u32]) -> bool {
        let key = prefix_key(tokens);
        self.l1.get(&key).is_some_and(|s| s.entry.tokens == tokens)
            || self.l2.contains_key(&key)
    }

    /// Spill-file path for an L2-resident block (test hook for the
    /// corruption path).
    pub fn l2_file(&self, tokens: &[u32]) -> Option<PathBuf> {
        self.l2.get(&prefix_key(tokens)).map(|s| s.path.clone())
    }

    /// Reference-bump an L1-resident block: LRU-touch it and return the
    /// shared handle (sessions pin it for their lifetime). `None` when
    /// the block is not in L1 — callers fall back to [`Self::insert`].
    pub fn bump(&mut self, tokens: &[u32]) -> Option<Arc<PrefixEntry>> {
        let now = self.touch();
        let slot = self.l1.get_mut(&prefix_key(tokens))?;
        if slot.entry.tokens != tokens {
            return None;
        }
        slot.last_used = now;
        self.stats.ref_bumps += 1;
        Some(Arc::clone(&slot.entry))
    }

    /// Admit a block (or reference-bump the resident copy). The entry's
    /// token length must be a positive multiple of `chunk_tokens` and
    /// every per-cache tensor must hold exactly the final chunk's rows;
    /// misaligned entries are rejected so every stored key is probe-able
    /// and every block seeds in chain order. Returns the store's shared
    /// handle — sessions pin it for their lifetime.
    pub fn insert(&mut self, entry: PrefixEntry) -> Result<Arc<PrefixEntry>> {
        let len = entry.tokens.len();
        ensure!(
            len > 0 && len % self.chunk == 0,
            "prefix insert: length {len} not a positive multiple of chunk {}",
            self.chunk
        );
        ensure!(
            !entry.kv.is_empty()
                && entry
                    .kv
                    .iter()
                    .all(|kv| kv.rows == self.chunk && kv.start + kv.rows == len),
            "prefix insert: blocks must cover exactly rows {}..{len}",
            len - self.chunk
        );
        let key = prefix_key(&entry.tokens);
        let now = self.touch();
        if let Some(slot) = self.l1.get_mut(&key) {
            if slot.entry.tokens == entry.tokens {
                slot.last_used = now;
                self.stats.ref_bumps += 1;
                return Ok(Arc::clone(&slot.entry));
            }
            bail!("prefix key collision on insert");
        }
        // A fresh copy supersedes a spilled one: drop the file, keep L1.
        if let Some(slot) = self.l2.remove(&key) {
            self.l2_bytes -= slot.bytes;
            let _ = std::fs::remove_file(&slot.path);
        }
        let bytes = entry.bytes();
        let arc = Arc::new(entry);
        self.l1.insert(
            key,
            L1Slot {
                entry: Arc::clone(&arc),
                last_used: now,
            },
        );
        self.l1_bytes += bytes;
        self.stats.inserts += 1;
        self.evict_l1();
        Ok(arc)
    }

    fn lru_key(map_last_used: impl Iterator<Item = (u64, u64)>) -> Option<u64> {
        map_last_used.min_by_key(|&(_, used)| used).map(|(k, _)| k)
    }

    fn evict_l1(&mut self) {
        while self.l1_bytes > self.l1_budget {
            let Some(key) = Self::lru_key(self.l1.iter().map(|(k, s)| (*k, s.last_used)))
            else {
                break;
            };
            let slot = self.l1.remove(&key).expect("lru key present");
            self.l1_bytes -= slot.entry.bytes();
            self.stats.evictions += 1;
            self.spill(key, &slot.entry);
        }
    }

    fn evict_l2(&mut self) {
        while self.l2_bytes > self.l2_budget {
            let Some(key) = Self::lru_key(self.l2.iter().map(|(k, s)| (*k, s.last_used)))
            else {
                break;
            };
            let slot = self.l2.remove(&key).expect("lru key present");
            self.l2_bytes -= slot.bytes;
            let _ = std::fs::remove_file(&slot.path);
            self.stats.evictions += 1;
        }
    }

    fn l2_path(dir: &Path, key: u64) -> PathBuf {
        dir.join(format!("pfx_{key:016x}.bin"))
    }

    fn spill(&mut self, key: u64, entry: &PrefixEntry) {
        let Some(dir) = self.l2_dir.clone() else {
            return; // no disk tier: demotion is a drop
        };
        let bytes = entry.bytes();
        if bytes > self.l2_budget {
            return; // can never fit; don't churn the tier
        }
        let path = Self::l2_path(&dir, key);
        if crate::faultinject::fire(crate::faultinject::Site::SpillWrite).is_err()
            || std::fs::write(&path, serialize(entry)).is_err()
        {
            let _ = std::fs::remove_file(&path);
            return; // spill failure degrades to a drop, never an error
        }
        let now = self.touch();
        self.l2.insert(
            key,
            L2Slot {
                path,
                bytes,
                last_used: now,
            },
        );
        self.l2_bytes += bytes;
        self.stats.spills += 1;
        self.evict_l2();
    }

    /// Read, verify, and promote an L2 block back into L1. Any read,
    /// parse, or checksum failure deletes the spill file and reports a
    /// miss; a token mismatch (hash collision) leaves the file alone.
    fn promote_l2(&mut self, key: u64, expect: &[u32]) -> Option<Arc<PrefixEntry>> {
        let slot = self.l2.get(&key)?;
        let path = slot.path.clone();
        let read = crate::faultinject::fire(crate::faultinject::Site::SpillRead)
            .ok()
            .and_then(|()| std::fs::read(&path).ok());
        match read.and_then(|b| deserialize(&b).ok()) {
            Some(entry) if entry.tokens == expect => {
                let slot = self.l2.remove(&key).expect("probed above");
                self.l2_bytes -= slot.bytes;
                let _ = std::fs::remove_file(&slot.path);
                let bytes = entry.bytes();
                let arc = Arc::new(entry);
                let now = self.touch();
                self.l1.insert(
                    key,
                    L1Slot {
                        entry: Arc::clone(&arc),
                        last_used: now,
                    },
                );
                self.l1_bytes += bytes;
                self.evict_l1();
                Some(arc)
            }
            Some(_) => None, // collision: different content, keep the file
            None => {
                let slot = self.l2.remove(&key).expect("probed above");
                self.l2_bytes -= slot.bytes;
                let _ = std::fs::remove_file(&slot.path);
                self.stats.corrupt_dropped += 1;
                None
            }
        }
    }
}

// ---------------------------------------------------------------------------
// L2 serialization: [magic | checksum(u64) | payload], checksum = FNV-1a
// over the payload bytes. Scalars cross through the audited
// `runtime::bytes::as_byte_slice` choke point on write and safe
// `from_ne_bytes` loops on read (spill files never leave the machine
// that wrote them, so native endianness is self-consistent).
// ---------------------------------------------------------------------------

fn checksum(bytes: &[u8]) -> u64 {
    let mut h = FNV_OFFSET;
    for &b in bytes {
        h ^= u64::from(b);
        h = h.wrapping_mul(FNV_PRIME);
    }
    h
}

fn serialize(entry: &PrefixEntry) -> Vec<u8> {
    let mut payload = Vec::new();
    payload.extend_from_slice(as_byte_slice(&[entry.tokens.len() as u64]));
    payload.extend_from_slice(as_byte_slice(&[entry.kv.len() as u64]));
    payload.extend_from_slice(as_byte_slice(&entry.tokens));
    for kv in &entry.kv {
        let dims = [
            kv.layers as u64,
            kv.heads as u64,
            kv.head_dim as u64,
            kv.start as u64,
            kv.rows as u64,
        ];
        payload.extend_from_slice(as_byte_slice(&dims));
        payload.extend_from_slice(as_byte_slice(&kv.k));
        payload.extend_from_slice(as_byte_slice(&kv.v));
    }
    let mut out = Vec::with_capacity(MAGIC.len() + 8 + payload.len());
    out.extend_from_slice(MAGIC);
    out.extend_from_slice(as_byte_slice(&[checksum(&payload)]));
    out.extend_from_slice(&payload);
    out
}

struct Reader<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        ensure!(self.pos + n <= self.bytes.len(), "truncated prefix entry");
        let s = &self.bytes[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    fn u64(&mut self) -> Result<u64> {
        let b = self.take(8)?;
        Ok(u64::from_ne_bytes(b.try_into().expect("8-byte slice")))
    }

    fn u32s(&mut self, n: usize) -> Result<Vec<u32>> {
        let b = self.take(n.checked_mul(4).context("length overflow")?)?;
        Ok(b.chunks_exact(4)
            .map(|c| u32::from_ne_bytes(c.try_into().expect("4-byte chunk")))
            .collect())
    }

    fn f32s(&mut self, n: usize) -> Result<Vec<f32>> {
        let b = self.take(n.checked_mul(4).context("length overflow")?)?;
        Ok(b.chunks_exact(4)
            .map(|c| f32::from_ne_bytes(c.try_into().expect("4-byte chunk")))
            .collect())
    }
}

fn deserialize(bytes: &[u8]) -> Result<PrefixEntry> {
    ensure!(bytes.len() >= MAGIC.len() + 8, "truncated prefix entry");
    ensure!(&bytes[..MAGIC.len()] == MAGIC, "bad prefix entry magic");
    let mut r = Reader {
        bytes,
        pos: MAGIC.len(),
    };
    let sum = r.u64()?;
    ensure!(
        checksum(&bytes[MAGIC.len() + 8..]) == sum,
        "prefix entry checksum mismatch"
    );
    let n_tokens = usize::try_from(r.u64()?)?;
    let n_caches = usize::try_from(r.u64()?)?;
    ensure!(n_caches <= 4096, "implausible cache count");
    let tokens = r.u32s(n_tokens)?;
    let mut kv = Vec::with_capacity(n_caches);
    for _ in 0..n_caches {
        let layers = usize::try_from(r.u64()?)?;
        let heads = usize::try_from(r.u64()?)?;
        let head_dim = usize::try_from(r.u64()?)?;
        let start = usize::try_from(r.u64()?)?;
        let rows = usize::try_from(r.u64()?)?;
        ensure!(start + rows == n_tokens, "block row range mismatch");
        let n = layers
            .checked_mul(heads)
            .and_then(|x| x.checked_mul(rows))
            .and_then(|x| x.checked_mul(head_dim))
            .context("tensor size overflow")?;
        kv.push(PrefixKv {
            layers,
            heads,
            head_dim,
            start,
            rows,
            k: r.f32s(n)?,
            v: r.f32s(n)?,
        });
    }
    ensure!(r.pos == bytes.len(), "trailing bytes in prefix entry");
    Ok(PrefixEntry { tokens, kv })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn kv(start: usize, rows: usize, fill: f32) -> PrefixKv {
        let n = 2 * rows * 2; // layers=2, heads=1, hd=2
        PrefixKv {
            layers: 2,
            heads: 1,
            head_dim: 2,
            start,
            rows,
            k: (0..n).map(|i| fill + i as f32).collect(),
            v: (0..n).map(|i| -fill - i as f32).collect(),
        }
    }

    fn entry(tokens: &[u32], rows: usize) -> PrefixEntry {
        PrefixEntry {
            tokens: tokens.to_vec(),
            kv: vec![kv(tokens.len() - rows, rows, tokens[0] as f32)],
        }
    }

    #[test]
    fn serialize_round_trips_bit_identically() {
        let e = entry(&[1, 2, 3, 4], 2);
        let got = deserialize(&serialize(&e)).unwrap();
        assert_eq!(got, e);
    }

    #[test]
    fn truncated_or_flipped_bytes_fail_verification() {
        let e = entry(&[9, 8, 7, 6], 2);
        let bytes = serialize(&e);
        assert!(deserialize(&bytes[..bytes.len() - 1]).is_err());
        let mut bad = bytes.clone();
        let mid = bad.len() / 2;
        bad[mid] ^= 0x40;
        assert!(deserialize(&bad).is_err());
    }

    #[test]
    fn rolling_hash_extends_incrementally() {
        let p = [5u32, 6, 7, 8, 9, 10];
        let h4 = prefix_key(&p[..4]);
        assert_eq!(hash_extend(h4, &p[4..6]), prefix_key(&p[..6]));
        assert_ne!(prefix_key(&p[..4]), prefix_key(&p[..6]));
    }

    #[test]
    fn extract_then_seed_round_trips_block_by_block() {
        let mut src = TwoLevelCache::new(2, 2, 3, 8, 4);
        let n = 4usize;
        for l in 0..2 {
            let block: Vec<f32> = (0..2 * n * 3).map(|i| (l * 100 + i) as f32).collect();
            let neg: Vec<f32> = block.iter().map(|x| -x).collect();
            src.append_past_block(l, &block, &neg, n, n).unwrap();
        }
        src.commit_past(n);
        // two chunk blocks, seeded in chain order onto a fresh cache
        let b0 = PrefixKv::extract_range(&src, 0, 2).unwrap();
        let b1 = PrefixKv::extract_range(&src, 2, 4).unwrap();
        let mut dst = TwoLevelCache::new(2, 2, 3, 8, 4);
        // out-of-order seeding is rejected
        assert!(b1.seed(&mut dst).is_err());
        b0.seed(&mut dst).unwrap();
        b1.seed(&mut dst).unwrap();
        assert_eq!(dst.past_len(), n);
        for l in 0..2 {
            for h in 0..2 {
                for r in 0..n {
                    assert_eq!(dst.read_past_slot(l, h, r), src.read_past_slot(l, h, r));
                }
            }
        }
    }
}
