//! Minimal TOML-subset parser: `[section]` headers, `key = value` lines,
//! values are integers, floats, booleans, quoted strings, or flat arrays of
//! those. Comments (`#`) and blank lines are skipped. This covers everything
//! the repo's config files use; it is not a general TOML implementation.

use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;

#[derive(Debug, Clone, PartialEq)]
pub enum TomlValue {
    Int(i64),
    Float(f64),
    Bool(bool),
    Str(String),
    Array(Vec<TomlValue>),
}

impl TomlValue {
    pub fn as_usize(&self) -> Result<usize> {
        match self {
            TomlValue::Int(i) if *i >= 0 => Ok(*i as usize),
            _ => bail!("expected non-negative integer, got {self:?}"),
        }
    }

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            TomlValue::Int(i) => Ok(*i as f64),
            TomlValue::Float(f) => Ok(*f),
            _ => bail!("expected number, got {self:?}"),
        }
    }

    pub fn as_bool(&self) -> Result<bool> {
        match self {
            TomlValue::Bool(b) => Ok(*b),
            _ => bail!("expected bool, got {self:?}"),
        }
    }

    pub fn as_str(&self) -> Result<&str> {
        match self {
            TomlValue::Str(s) => Ok(s),
            _ => bail!("expected string, got {self:?}"),
        }
    }

    pub fn as_array(&self) -> Result<&[TomlValue]> {
        match self {
            TomlValue::Array(a) => Ok(a),
            _ => bail!("expected array, got {self:?}"),
        }
    }
}

#[derive(Debug, Default)]
pub struct TomlDoc {
    /// section -> key -> value; top-level keys live under section "".
    sections: BTreeMap<String, BTreeMap<String, TomlValue>>,
}

impl TomlDoc {
    pub fn parse(text: &str) -> Result<Self> {
        let mut doc = TomlDoc::default();
        let mut section = String::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = strip_comment(raw).trim();
            if line.is_empty() {
                continue;
            }
            if let Some(rest) = line.strip_prefix('[') {
                let name = rest
                    .strip_suffix(']')
                    .with_context(|| format!("line {}: bad section", lineno + 1))?;
                section = name.trim().to_string();
                doc.sections.entry(section.clone()).or_default();
                continue;
            }
            let (key, value) = line
                .split_once('=')
                .with_context(|| format!("line {}: expected key = value", lineno + 1))?;
            let v = parse_value(value.trim())
                .with_context(|| format!("line {}: bad value", lineno + 1))?;
            doc.sections
                .entry(section.clone())
                .or_default()
                .insert(key.trim().to_string(), v);
        }
        Ok(doc)
    }

    pub fn get(&self, section: &str, key: &str) -> Option<&TomlValue> {
        self.sections.get(section)?.get(key)
    }

    pub fn sections(&self) -> impl Iterator<Item = &str> {
        self.sections.keys().map(|s| s.as_str())
    }

    pub fn keys(&self, section: &str) -> Vec<&str> {
        self.sections
            .get(section)
            .map(|m| m.keys().map(|s| s.as_str()).collect())
            .unwrap_or_default()
    }
}

fn strip_comment(line: &str) -> &str {
    // naive: '#' inside quoted strings is not supported by this subset
    match line.find('#') {
        Some(i) if !line[..i].contains('"') => &line[..i],
        _ => line,
    }
}

fn parse_value(s: &str) -> Result<TomlValue> {
    if s.is_empty() {
        bail!("empty value");
    }
    if let Some(inner) = s.strip_prefix('"') {
        let inner = inner
            .strip_suffix('"')
            .context("unterminated string")?;
        return Ok(TomlValue::Str(inner.to_string()));
    }
    if let Some(inner) = s.strip_prefix('[') {
        let inner = inner.strip_suffix(']').context("unterminated array")?;
        let mut items = Vec::new();
        let trimmed = inner.trim();
        if !trimmed.is_empty() {
            for part in trimmed.split(',') {
                let p = part.trim();
                if !p.is_empty() {
                    items.push(parse_value(p)?);
                }
            }
        }
        return Ok(TomlValue::Array(items));
    }
    match s {
        "true" => return Ok(TomlValue::Bool(true)),
        "false" => return Ok(TomlValue::Bool(false)),
        _ => {}
    }
    if let Ok(i) = s.parse::<i64>() {
        return Ok(TomlValue::Int(i));
    }
    if let Ok(f) = s.parse::<f64>() {
        return Ok(TomlValue::Float(f));
    }
    bail!("cannot parse value: {s}")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_sections_and_types() {
        let doc = TomlDoc::parse(
            r#"
            top = 1
            [a]
            x = 2          # comment
            y = 3.5
            s = "hi"
            flag = true
            arr = [1, 2, 3]
            [b]
            z = -4
            "#,
        )
        .unwrap();
        assert_eq!(doc.get("", "top").unwrap().as_usize().unwrap(), 1);
        assert_eq!(doc.get("a", "x").unwrap().as_usize().unwrap(), 2);
        assert!((doc.get("a", "y").unwrap().as_f64().unwrap() - 3.5).abs() < 1e-12);
        assert_eq!(doc.get("a", "s").unwrap().as_str().unwrap(), "hi");
        assert!(doc.get("a", "flag").unwrap().as_bool().unwrap());
        assert_eq!(doc.get("a", "arr").unwrap().as_array().unwrap().len(), 3);
        assert_eq!(doc.get("b", "z").unwrap().as_f64().unwrap(), -4.0);
    }

    #[test]
    fn rejects_garbage() {
        assert!(TomlDoc::parse("[unclosed\n").is_err());
        assert!(TomlDoc::parse("novalue\n").is_err());
        assert!(TomlDoc::parse("k = @@\n").is_err());
    }

    #[test]
    fn empty_array() {
        let doc = TomlDoc::parse("a = []\n").unwrap();
        assert!(doc.get("", "a").unwrap().as_array().unwrap().is_empty());
    }
}
