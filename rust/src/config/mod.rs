//! Configuration system: a small TOML-subset parser (no external crates in
//! the offline vendor set) plus the typed configs used across the crate.

pub mod artifact;
pub mod toml;

pub use artifact::ArtifactConfig;
pub use toml::{TomlDoc, TomlValue};

use anyhow::{Context, Result};
use std::path::Path;

/// Prediction-tree parameters (paper §3.3 / §4.3.1).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TreeConfig {
    /// Maximum nodes per tree layer (w). Paper sweeps {8,16,32,64,128},
    /// picks 32.
    pub max_width: usize,
    /// Maximum candidate children per node (c). Paper sweeps {2,4,8,16},
    /// picks 16.
    pub max_children: usize,
    /// Maximum tree depth kept ahead of verification (d); in PipeDec this
    /// tracks the number of pipeline groups.
    pub max_depth: usize,
}

impl Default for TreeConfig {
    fn default() -> Self {
        Self {
            max_width: 8,
            max_children: 8,
            max_depth: 9,
        }
    }
}

/// Tiered cross-request KV prefix cache (ISSUE 8,
/// [`crate::kvcache::prefix`]): `[prefix_cache]` in TOML. The engines
/// consult [`Self::runtime_enabled`], so the `PIPEDEC_NO_PREFIX_CACHE`
/// environment kill-switch wins over both the TOML section and the CLI
/// flags.
#[derive(Debug, Clone, PartialEq)]
pub struct PrefixCacheConfig {
    /// Master switch (`enabled` key / `--no-prefix-cache` CLI flag).
    pub enabled: bool,
    /// L1 (host memory) byte budget for resident prefix entries.
    pub l1_bytes: usize,
    /// L2 (disk spill) byte budget; only meaningful with `l2_dir` set.
    pub l2_bytes: usize,
    /// Spill directory for the disk tier; `None` disables L2 (entries
    /// evicted from L1 are dropped instead of demoted).
    pub l2_dir: Option<String>,
    /// Key granularity in tokens; `0` = auto (the model's prefill chunk
    /// width). Engines round a nonzero value to a multiple of the
    /// prefill width so seeded prefixes keep chunk boundaries — and
    /// therefore float summation order and token outputs — bit-identical
    /// to the uncached path.
    pub chunk_tokens: usize,
}

impl Default for PrefixCacheConfig {
    fn default() -> Self {
        Self {
            enabled: true,
            l1_bytes: 64 << 20,
            l2_bytes: 256 << 20,
            l2_dir: None,
            chunk_tokens: 0,
        }
    }
}

impl PrefixCacheConfig {
    /// `enabled`, unless the `PIPEDEC_NO_PREFIX_CACHE` kill-switch is set
    /// in the environment (any value). Engines read this once at
    /// construction.
    pub fn runtime_enabled(&self) -> bool {
        self.enabled && std::env::var_os("PIPEDEC_NO_PREFIX_CACHE").is_none()
    }
}

/// Deadline and admission limits for the fault-isolated serving core
/// (ISSUE 9): `[limits]` in TOML, `--ttft-deadline` / `--deadline` /
/// `--queue-max-wait` / `--max-queue` on the CLI. Every limit defaults
/// to 0 = disabled, so existing configs and tests are unaffected.
///
/// Deadlines are *checked at scheduler step boundaries* (the engine is
/// step-driven; nothing preempts a running forward pass), so enforcement
/// granularity is one timestep. An over-deadline session retires as
/// [`crate::engine::SessionStatus::Failed`] with a reason starting with
/// `"deadline"`; an over-capacity submit is rejected with
/// [`crate::engine::ShedError`] carrying the queue depth.
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub struct LimitsConfig {
    /// Seconds a session may wait for its *first* token, measured from
    /// submit. 0 = no TTFT deadline.
    pub ttft_deadline_s: f64,
    /// Total wall seconds a session may live, measured from submit.
    /// 0 = no total deadline.
    pub deadline_s: f64,
    /// Seconds a queued session may wait for admission before the
    /// scheduler sheds it. 0 = wait forever.
    pub queue_max_wait_s: f64,
    /// Maximum queued (not yet admitted) sessions; submits beyond this
    /// are rejected with [`crate::engine::ShedError`]. 0 = unbounded.
    pub queue_cap: usize,
}

/// Engine/topology parameters for the real (artifact-backed) engine.
#[derive(Debug, Clone, PartialEq)]
pub struct EngineConfig {
    /// Number of pipeline stages the target model is split into. Must divide
    /// the layer count (8 for the build-time target).
    pub stages: usize,
    /// Stages per timestep group G_i (paper §3.1): stages inside a group
    /// execute sequentially within one timestep; data flows cross group
    /// boundaries between timesteps. 1 = every stage its own group (the
    /// paper's 14/21-stage configs); 2 over 14 GPUs = the 7-stage config.
    pub group_size: usize,
    pub tree: TreeConfig,
    /// Maximum new tokens per request.
    pub max_new_tokens: usize,
    /// Sampling settings (greedy when `temperature == 0`).
    pub temperature: f32,
    pub top_p: f32,
    pub top_k: usize,
    pub seed: u64,
    /// Ablation: when true, tree pruning never reuses the surviving subtree
    /// — every verified token restarts the pipeline as if it missed. Output
    /// is unchanged (losslessness is independent of reuse); only latency
    /// suffers. Quantifies the dynamic tree's contribution (DESIGN.md).
    pub ablate_tree_reuse: bool,
    /// Pipeline worker threads for the PipeDec engines (ISSUE 4): `0` =
    /// auto (one per available core), `1` = the sequential reference path
    /// (no pool), `>= 2` = a persistent pool of
    /// `min(threads, groups + 1)` workers executing each timestep's task
    /// set concurrently. Outputs are token-identical at every setting.
    pub threads: usize,
    /// Overlapped sync phase (ISSUE 5, default on): the coordinator keeps
    /// only the sync decision (verify/sample/prune) and defers the cache
    /// maintenance (KV promotion + tree compaction) into each cache
    /// owner's next pipeline job, overlapping it with the next timestep's
    /// compute. `false` applies commits at the sync point — the PR 4
    /// serial reference path. Outputs are bit-identical either way.
    pub overlap_sync: bool,
    /// Continuous asynchronous speculation (ISSUE 10): maximum draft tree
    /// generations in flight per session. `1` = lockstep (the draft
    /// expands exactly one layer per timestep, today's behavior,
    /// bit-identical). `> 1` = after its in-step expansion the draft
    /// free-runs ahead, speculatively expanding up to `spec_inflight - 1`
    /// further generations against a shadow of the tree it just returned;
    /// the coordinator banks them epoch-tagged and serves them on later
    /// timesteps without paying the draft again, dropping any that went
    /// stale (Miss reset, pruned attach point, cancel). Greedy outputs
    /// are bit-identical at every setting.
    pub spec_inflight: usize,
    /// Tiered cross-request KV prefix cache (ISSUE 8).
    pub prefix_cache: PrefixCacheConfig,
    /// Deadlines and admission shedding (ISSUE 9); all-zero = disabled.
    pub limits: LimitsConfig,
    /// Fault-injection plan armed at engine construction (ISSUE 9):
    /// `[faultinject] plan = "site@hit=kind,..."`. The `PIPEDEC_FAULTS`
    /// env var overrides it; `None`/empty leaves the layer disarmed.
    pub fault_plan: Option<String>,
}

impl Default for EngineConfig {
    fn default() -> Self {
        Self {
            stages: 4,
            group_size: 1,
            tree: TreeConfig::default(),
            max_new_tokens: 48,
            temperature: 0.0,
            top_p: 0.9,
            top_k: 80,
            seed: 0,
            ablate_tree_reuse: false,
            threads: 0,
            overlap_sync: true,
            spec_inflight: 1,
            prefix_cache: PrefixCacheConfig::default(),
            limits: LimitsConfig::default(),
            fault_plan: None,
        }
    }
}

impl EngineConfig {
    /// Load from a TOML file with `[engine]` / `[tree]` / `[sampling]`
    /// sections; missing keys keep defaults.
    pub fn from_toml_file(path: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("read config {}", path.display()))?;
        Self::from_toml_str(&text)
    }

    pub fn from_toml_str(text: &str) -> Result<Self> {
        let doc = TomlDoc::parse(text)?;
        let mut cfg = Self::default();
        if let Some(v) = doc.get("engine", "stages") {
            cfg.stages = v.as_usize()?;
        }
        if let Some(v) = doc.get("engine", "group_size") {
            cfg.group_size = v.as_usize()?;
        }
        if let Some(v) = doc.get("engine", "max_new_tokens") {
            cfg.max_new_tokens = v.as_usize()?;
        }
        if let Some(v) = doc.get("engine", "seed") {
            cfg.seed = v.as_usize()? as u64;
        }
        if let Some(v) = doc.get("engine", "threads") {
            cfg.threads = v.as_usize()?;
        }
        if let Some(v) = doc.get("engine", "overlap_sync") {
            cfg.overlap_sync = v.as_bool()?;
        }
        if let Some(v) = doc.get("engine", "spec_inflight") {
            cfg.spec_inflight = v.as_usize()?;
        }
        if let Some(v) = doc.get("prefix_cache", "enabled") {
            cfg.prefix_cache.enabled = v.as_bool()?;
        }
        if let Some(v) = doc.get("prefix_cache", "l1_bytes") {
            cfg.prefix_cache.l1_bytes = v.as_usize()?;
        }
        if let Some(v) = doc.get("prefix_cache", "l2_bytes") {
            cfg.prefix_cache.l2_bytes = v.as_usize()?;
        }
        if let Some(v) = doc.get("prefix_cache", "l2_dir") {
            cfg.prefix_cache.l2_dir = Some(v.as_str()?.to_string());
        }
        if let Some(v) = doc.get("prefix_cache", "chunk_tokens") {
            cfg.prefix_cache.chunk_tokens = v.as_usize()?;
        }
        if let Some(v) = doc.get("limits", "ttft_deadline_s") {
            cfg.limits.ttft_deadline_s = v.as_f64()?;
        }
        if let Some(v) = doc.get("limits", "deadline_s") {
            cfg.limits.deadline_s = v.as_f64()?;
        }
        if let Some(v) = doc.get("limits", "queue_max_wait_s") {
            cfg.limits.queue_max_wait_s = v.as_f64()?;
        }
        if let Some(v) = doc.get("limits", "queue_cap") {
            cfg.limits.queue_cap = v.as_usize()?;
        }
        if let Some(v) = doc.get("faultinject", "plan") {
            cfg.fault_plan = Some(v.as_str()?.to_string());
        }
        if let Some(v) = doc.get("tree", "max_width") {
            cfg.tree.max_width = v.as_usize()?;
        }
        if let Some(v) = doc.get("tree", "max_children") {
            cfg.tree.max_children = v.as_usize()?;
        }
        if let Some(v) = doc.get("tree", "max_depth") {
            cfg.tree.max_depth = v.as_usize()?;
        }
        if let Some(v) = doc.get("sampling", "temperature") {
            cfg.temperature = v.as_f64()? as f32;
        }
        if let Some(v) = doc.get("sampling", "top_p") {
            cfg.top_p = v.as_f64()? as f32;
        }
        if let Some(v) = doc.get("sampling", "top_k") {
            cfg.top_k = v.as_usize()?;
        }
        cfg.validate()?;
        Ok(cfg)
    }

    pub fn validate(&self) -> Result<()> {
        anyhow::ensure!(self.stages >= 1, "stages must be >= 1");
        anyhow::ensure!(
            self.group_size >= 1 && self.stages % self.group_size == 0,
            "group_size must divide stages"
        );
        anyhow::ensure!(self.tree.max_width >= 1, "tree.max_width must be >= 1");
        anyhow::ensure!(
            self.tree.max_children >= 1,
            "tree.max_children must be >= 1"
        );
        anyhow::ensure!(self.tree.max_depth >= 2, "tree.max_depth must be >= 2");
        anyhow::ensure!(
            self.spec_inflight >= 1,
            "spec_inflight must be >= 1 (1 = lockstep)"
        );
        anyhow::ensure!(
            (0.0..=2.0).contains(&self.temperature),
            "temperature out of range"
        );
        anyhow::ensure!((0.0..=1.0).contains(&self.top_p), "top_p out of range");
        anyhow::ensure!(
            self.prefix_cache
                .l2_dir
                .as_deref()
                .is_none_or(|d| !d.is_empty()),
            "prefix_cache.l2_dir must be non-empty when set"
        );
        anyhow::ensure!(
            self.limits.ttft_deadline_s >= 0.0
                && self.limits.deadline_s >= 0.0
                && self.limits.queue_max_wait_s >= 0.0,
            "limits must be >= 0 (0 disables)"
        );
        if let Some(p) = &self.fault_plan {
            p.parse::<crate::faultinject::FaultPlan>()
                .context("validating [faultinject] plan")?;
        }
        Ok(())
    }

    /// Resolve the `threads` knob: `0` means one worker per available core
    /// (falling back to the sequential path when parallelism is unknown).
    pub fn effective_threads(&self) -> usize {
        if self.threads == 0 {
            std::thread::available_parallelism()
                .map(|n| n.get())
                .unwrap_or(1)
        } else {
            self.threads
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_are_valid() {
        EngineConfig::default().validate().unwrap();
    }

    #[test]
    fn group_size_must_divide_stages() {
        let mut c = EngineConfig::default();
        c.stages = 4;
        c.group_size = 3;
        assert!(c.validate().is_err());
        c.group_size = 2;
        assert!(c.validate().is_ok());
    }

    #[test]
    fn parse_full_config() {
        let cfg = EngineConfig::from_toml_str(
            r#"
            [engine]
            stages = 8
            max_new_tokens = 64
            seed = 42
            [tree]
            max_width = 16
            max_children = 4
            max_depth = 10
            [sampling]
            temperature = 0.6
            top_p = 0.9
            top_k = 80
            "#,
        )
        .unwrap();
        assert_eq!(cfg.stages, 8);
        assert_eq!(cfg.tree.max_width, 16);
        assert_eq!(cfg.tree.max_children, 4);
        assert!((cfg.temperature - 0.6).abs() < 1e-6);
        assert_eq!(cfg.seed, 42);
    }

    #[test]
    fn partial_config_keeps_defaults() {
        let cfg = EngineConfig::from_toml_str("[tree]\nmax_width = 64\n").unwrap();
        assert_eq!(cfg.tree.max_width, 64);
        assert_eq!(cfg.stages, EngineConfig::default().stages);
    }

    #[test]
    fn invalid_rejected() {
        assert!(EngineConfig::from_toml_str("[engine]\nstages = 0\n").is_err());
    }

    #[test]
    fn overlap_sync_parses_and_defaults_on() {
        assert!(
            EngineConfig::default().overlap_sync,
            "overlapped sync is the default"
        );
        let off =
            EngineConfig::from_toml_str("[engine]\noverlap_sync = false\n").unwrap();
        assert!(!off.overlap_sync);
        let on = EngineConfig::from_toml_str("[engine]\noverlap_sync = true\n").unwrap();
        assert!(on.overlap_sync);
    }

    #[test]
    fn spec_inflight_parses_and_defaults_to_lockstep() {
        assert_eq!(
            EngineConfig::default().spec_inflight,
            1,
            "lockstep is the default"
        );
        let cfg = EngineConfig::from_toml_str("[engine]\nspec_inflight = 3\n").unwrap();
        assert_eq!(cfg.spec_inflight, 3);
        assert!(
            EngineConfig::from_toml_str("[engine]\nspec_inflight = 0\n").is_err(),
            "0 generations in flight is rejected"
        );
    }

    #[test]
    fn prefix_cache_section_parses_and_defaults_on() {
        let d = PrefixCacheConfig::default();
        assert!(d.enabled, "prefix cache defaults on");
        assert_eq!(d.chunk_tokens, 0, "default chunk is auto");
        assert!(d.l2_dir.is_none(), "disk tier defaults off");
        let cfg = EngineConfig::from_toml_str(
            r#"
            [prefix_cache]
            enabled = false
            l1_bytes = 1024
            l2_bytes = 4096
            l2_dir = "/tmp/pfx"
            chunk_tokens = 8
            "#,
        )
        .unwrap();
        assert!(!cfg.prefix_cache.enabled);
        assert_eq!(cfg.prefix_cache.l1_bytes, 1024);
        assert_eq!(cfg.prefix_cache.l2_bytes, 4096);
        assert_eq!(cfg.prefix_cache.l2_dir.as_deref(), Some("/tmp/pfx"));
        assert_eq!(cfg.prefix_cache.chunk_tokens, 8);
        assert!(
            EngineConfig::from_toml_str("[prefix_cache]\nl2_dir = \"\"\n").is_err(),
            "empty l2_dir rejected"
        );
    }

    #[test]
    fn limits_section_parses_and_defaults_off() {
        let d = LimitsConfig::default();
        assert_eq!(d.ttft_deadline_s, 0.0);
        assert_eq!(d.deadline_s, 0.0);
        assert_eq!(d.queue_max_wait_s, 0.0);
        assert_eq!(d.queue_cap, 0);
        let cfg = EngineConfig::from_toml_str(
            r#"
            [limits]
            ttft_deadline_s = 1.5
            deadline_s = 30.0
            queue_max_wait_s = 2.0
            queue_cap = 8
            "#,
        )
        .unwrap();
        assert!((cfg.limits.ttft_deadline_s - 1.5).abs() < 1e-12);
        assert!((cfg.limits.deadline_s - 30.0).abs() < 1e-12);
        assert!((cfg.limits.queue_max_wait_s - 2.0).abs() < 1e-12);
        assert_eq!(cfg.limits.queue_cap, 8);
        assert!(
            EngineConfig::from_toml_str("[limits]\ndeadline_s = -1.0\n").is_err(),
            "negative deadlines rejected"
        );
    }

    #[test]
    fn fault_plan_key_is_validated() {
        let cfg =
            EngineConfig::from_toml_str("[faultinject]\nplan = \"stage_job@1=error\"\n").unwrap();
        assert_eq!(cfg.fault_plan.as_deref(), Some("stage_job@1=error"));
        assert!(
            EngineConfig::from_toml_str("[faultinject]\nplan = \"bogus@1=error\"\n").is_err(),
            "malformed plan rejected at parse time"
        );
    }

    #[test]
    fn threads_parse_and_resolve() {
        let cfg = EngineConfig::from_toml_str("[engine]\nthreads = 3\n").unwrap();
        assert_eq!(cfg.threads, 3);
        assert_eq!(cfg.effective_threads(), 3);
        let auto = EngineConfig::default();
        assert_eq!(auto.threads, 0, "default is auto");
        assert!(auto.effective_threads() >= 1);
        let seq = EngineConfig {
            threads: 1,
            ..EngineConfig::default()
        };
        assert_eq!(seq.effective_threads(), 1);
    }
}
