//! Parser for `artifacts/{target,draft}_config.txt` — the static-shape
//! contract emitted by `python/compile/aot.py` (`configs.config_lines`).

use anyhow::{Context, Result};
use std::collections::HashMap;
use std::path::Path;

/// Model + shape-cap description for one artifact set.
#[derive(Debug, Clone, PartialEq)]
pub struct ArtifactConfig {
    pub name: String,
    pub dim: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub head_dim: usize,
    pub mlp_hidden: usize,
    pub vocab_size: usize,
    pub rope_theta: f64,
    pub norm_eps: f64,
    pub width_cap: usize,
    pub tree_cap: usize,
    pub past_cap: usize,
    pub prefill_chunk: usize,
}

impl ArtifactConfig {
    pub fn load(path: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("read {}", path.display()))?;
        Self::parse(&text).with_context(|| format!("parse {}", path.display()))
    }

    pub fn parse(text: &str) -> Result<Self> {
        let mut kv = HashMap::new();
        for line in text.lines() {
            let line = line.trim();
            if line.is_empty() {
                continue;
            }
            let (k, v) = line
                .split_once('=')
                .with_context(|| format!("bad line: {line}"))?;
            kv.insert(k.trim().to_string(), v.trim().to_string());
        }
        let get = |k: &str| -> Result<&String> {
            kv.get(k).with_context(|| format!("missing key {k}"))
        };
        let usz = |k: &str| -> Result<usize> { Ok(get(k)?.parse::<usize>()?) };
        let flt = |k: &str| -> Result<f64> { Ok(get(k)?.parse::<f64>()?) };
        let cfg = Self {
            name: get("name")?.clone(),
            dim: usz("dim")?,
            n_layers: usz("n_layers")?,
            n_heads: usz("n_heads")?,
            head_dim: usz("head_dim")?,
            mlp_hidden: usz("mlp_hidden")?,
            vocab_size: usz("vocab_size")?,
            rope_theta: flt("rope_theta")?,
            norm_eps: flt("norm_eps")?,
            width_cap: usz("width_cap")?,
            tree_cap: usz("tree_cap")?,
            past_cap: usz("past_cap")?,
            prefill_chunk: usz("prefill_chunk")?,
        };
        anyhow::ensure!(
            cfg.dim == cfg.n_heads * cfg.head_dim,
            "dim != n_heads * head_dim"
        );
        Ok(cfg)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = "name=target\ndim=128\nn_layers=8\nn_heads=4\n\
        head_dim=32\nmlp_hidden=384\nvocab_size=128\nrope_theta=10000.0\n\
        norm_eps=1e-05\nwidth_cap=32\ntree_cap=288\npast_cap=512\n\
        prefill_chunk=32\n";

    #[test]
    fn parse_sample() {
        let c = ArtifactConfig::parse(SAMPLE).unwrap();
        assert_eq!(c.name, "target");
        assert_eq!(c.dim, 128);
        assert_eq!(c.n_layers, 8);
        assert_eq!(c.head_dim, 32);
        assert_eq!(c.tree_cap, 288);
    }

    #[test]
    fn missing_key_rejected() {
        assert!(ArtifactConfig::parse("name=x\ndim=8\n").is_err());
    }

    #[test]
    fn dim_consistency_enforced() {
        let bad = SAMPLE.replace("head_dim=32", "head_dim=31");
        assert!(ArtifactConfig::parse(&bad).is_err());
    }
}
