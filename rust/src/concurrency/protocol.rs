//! The decide/commit protocol, extracted as pure data structures.
//!
//! PR 5 split the sync phase into *decide* (pick the accepted path, mint a
//! [`CacheCommit`](crate::kvcache::CacheCommit)) and *commit* (replay that
//! decision against every cache owner, possibly much later and on another
//! thread). Three rules make the overlap mode bit-identical to the serial
//! mode, and before this PR they lived as duplicated ad-hoc code in
//! `coordinator/engine.rs`, `coordinator/db.rs`, `kvcache/mod.rs` and
//! `coordinator/workers.rs`:
//!
//! 1. **Dense epochs** — commits are numbered 1, 2, 3, … by the issuing
//!    coordinator ([`CommitLog::issue_with`]). There are no gaps.
//! 2. **In-order replay** — a cache owner at commit epoch `e` may apply only
//!    the commit with epoch `e + 1` ([`CommitCursor`]). Applying anything
//!    else means a commit was skipped, double-applied or reordered, and the
//!    replayed cache would diverge from the serial reference.
//! 3. **Drain before forward** — a worker must have applied every commit
//!    issued before its job was dispatched (`commit_target`) before running
//!    the forward pass ([`verify_drained`]); otherwise the forward reads a
//!    stale cache layout.
//!
//! This module is the single home for those rules. The production engines
//! ([`PipeDecEngine`](crate::coordinator::PipeDecEngine), `DbSession`) hold a
//! [`CommitLog`]; [`TwoLevelCache`](crate::kvcache::TwoLevelCache) holds a
//! [`CommitCursor`]; `apply_job_commits` calls [`verify_drained`]. The model
//! checked by `tests/loom_protocol.rs` (see [`super::model`]) drives the
//! *same* types, so the exhaustive interleaving search exercises the code the
//! engines run, not a transliteration of it.

use std::collections::VecDeque;

/// Anything stamped with a commit epoch. Implemented by
/// [`CacheCommit`](crate::kvcache::CacheCommit) and by the model-checker's
/// commit stand-in.
pub trait Epoched {
    fn epoch(&self) -> u64;
}

/// In-order replay was violated: a commit with epoch `offered` was applied
/// to an owner whose cursor sits at `applied` (rule 2 above requires
/// `offered == applied + 1`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct CommitOrderError {
    pub applied: u64,
    pub offered: u64,
}

impl std::fmt::Display for CommitOrderError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "commit epoch {} applied to a cache at epoch {} (in-order replay broken)",
            self.offered, self.applied
        )
    }
}

impl std::error::Error for CommitOrderError {}

/// A job reached its forward pass with an undrained commit suffix: the
/// owning cache sits at `cache_epoch` but every commit up to `target` was
/// issued before the job was dispatched (rule 3 above).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct StaleCacheError {
    pub cache_epoch: u64,
    pub target: u64,
}

impl std::fmt::Display for StaleCacheError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "stale cache after commit replay: cache at epoch {} but job was \
             issued at commit epoch {} (undrained commit suffix)",
            self.cache_epoch, self.target
        )
    }
}

impl std::error::Error for StaleCacheError {}

/// The staleness guard carried by every dispatched job: before the forward
/// runs, the owner's cache must have drained every commit issued up to
/// `target` (the issuer's [`CommitLog::seq`] at dispatch time).
pub fn verify_drained(cache_epoch: u64, target: u64) -> Result<(), StaleCacheError> {
    if cache_epoch == target {
        Ok(())
    } else {
        Err(StaleCacheError {
            cache_epoch,
            target,
        })
    }
}

/// Per-owner replay position: the epoch of the last commit this owner
/// applied. Enforces rule 2 (dense, in-order, exactly-once replay).
///
/// The check and the advance are split so a caller can validate the epoch
/// *before* mutating its own state and advance only after the mutation
/// succeeded (`TwoLevelCache::apply_commit` promotes the root layer between
/// the two, and a failed promotion must not advance the cursor).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq, Hash)]
pub struct CommitCursor {
    applied: u64,
}

impl CommitCursor {
    pub const fn new() -> Self {
        Self { applied: 0 }
    }

    /// Epoch of the last applied commit (0 = nothing applied yet).
    pub fn epoch(&self) -> u64 {
        self.applied
    }

    /// Validate that `offered` is the next epoch in sequence, without
    /// advancing.
    pub fn check_next(&self, offered: u64) -> Result<(), CommitOrderError> {
        if offered == self.applied + 1 {
            Ok(())
        } else {
            Err(CommitOrderError {
                applied: self.applied,
                offered,
            })
        }
    }

    /// Record that `offered` was applied. Callers must have called
    /// [`check_next`](Self::check_next) first; this is debug-asserted.
    pub fn advance(&mut self, offered: u64) {
        debug_assert_eq!(
            offered,
            self.applied + 1,
            "CommitCursor::advance without a passing check_next"
        );
        self.applied = offered;
    }

    /// [`check_next`](Self::check_next) + [`advance`](Self::advance) in one
    /// step, for callers whose apply is atomic (the protocol model).
    pub fn admit(&mut self, offered: u64) -> Result<(), CommitOrderError> {
        self.check_next(offered)?;
        self.advance(offered);
        Ok(())
    }

    /// Forget all progress (cache reset between sequences).
    pub fn reset(&mut self) {
        self.applied = 0;
    }
}

/// The issuing side of the protocol: a dense epoch counter plus the queue of
/// commits not yet applied by every owner.
///
/// Owned by the coordinator (`PipeDecEngine` / `DbSession`). In overlap-sync
/// mode minted commits are [`queue`](Self::queue)d and owners drain their
/// pending suffix ([`pending`](Self::pending)) at the start of their next
/// job; in serial mode commits are applied eagerly at issue time and the
/// queue stays empty. Either way the epoch counter advances identically, so
/// both modes produce the same commit sequence — the equivalence checked
/// exhaustively in `tests/loom_protocol.rs`.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct CommitLog<C> {
    entries: VecDeque<C>,
    seq: u64,
}

impl<C> Default for CommitLog<C> {
    fn default() -> Self {
        Self::new()
    }
}

impl<C> CommitLog<C> {
    pub fn new() -> Self {
        Self {
            entries: VecDeque::new(),
            seq: 0,
        }
    }

    /// Epoch of the most recently issued commit (0 = none yet). Dispatched
    /// jobs carry this as their `commit_target`.
    pub fn seq(&self) -> u64 {
        self.seq
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Forget all queued commits and restart the epoch sequence (engine
    /// reset between decode runs; caches reset their cursors in lockstep).
    pub fn clear(&mut self) {
        self.entries.clear();
        self.seq = 0;
    }
}

impl<C: Epoched + Clone> CommitLog<C> {
    /// Mint the next commit: advances the dense epoch counter and builds the
    /// commit via `make` (which receives the new epoch). The commit is *not*
    /// queued — serial mode applies it eagerly instead; overlap mode must
    /// follow up with [`queue`](Self::queue).
    pub fn issue_with(&mut self, make: impl FnOnce(u64) -> C) -> C {
        self.seq += 1;
        let c = make(self.seq);
        debug_assert_eq!(
            c.epoch(),
            self.seq,
            "issued commit must carry the epoch it was minted with"
        );
        c
    }

    /// Queue a minted commit for deferred replay (overlap mode).
    pub fn queue(&mut self, c: C) {
        debug_assert!(
            c.epoch() <= self.seq,
            "queued commit epoch {} was never issued (seq {})",
            c.epoch(),
            self.seq
        );
        debug_assert!(
            !self.entries.back().is_some_and(|b| b.epoch() >= c.epoch()),
            "commit log must stay strictly epoch-ordered"
        );
        self.entries.push_back(c);
    }

    /// The suffix of queued commits an owner at epoch `applied` still has to
    /// replay, oldest first.
    pub fn pending(&self, applied: u64) -> Vec<C> {
        self.entries
            .iter()
            .filter(|c| c.epoch() > applied)
            .cloned()
            .collect()
    }

    /// Number of queued commits an owner at epoch `applied` still has to
    /// replay.
    pub fn depth(&self, applied: u64) -> usize {
        self.entries.iter().filter(|c| c.epoch() > applied).count()
    }

    /// Drop queued commits every owner has applied (`min_applied` = the
    /// minimum cursor epoch across all owners). Trimming more than this
    /// would lose entries a lagging owner still needs — exactly the
    /// `TrimAhead` mutation the model checker demonstrates to be unsound.
    pub fn trim(&mut self, min_applied: u64) {
        while self
            .entries
            .front()
            .is_some_and(|c| c.epoch() <= min_applied)
        {
            self.entries.pop_front();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[derive(Debug, Clone, PartialEq, Eq, Hash)]
    struct C(u64);
    impl Epoched for C {
        fn epoch(&self) -> u64 {
            self.0
        }
    }

    #[test]
    fn cursor_admits_only_dense_in_order_epochs() {
        let mut cur = CommitCursor::new();
        assert_eq!(cur.epoch(), 0);
        assert!(cur.admit(1).is_ok());
        assert!(cur.admit(3).is_err(), "skip must be rejected");
        assert!(cur.admit(1).is_err(), "double-apply must be rejected");
        assert!(cur.admit(2).is_ok());
        assert_eq!(cur.epoch(), 2);
        cur.reset();
        assert_eq!(cur.epoch(), 0);
        assert!(cur.admit(1).is_ok());
    }

    #[test]
    fn check_next_does_not_advance() {
        let cur = CommitCursor::new();
        assert!(cur.check_next(1).is_ok());
        assert!(cur.check_next(1).is_ok(), "check alone must not advance");
        assert!(cur.check_next(2).is_err());
    }

    #[test]
    fn log_issues_dense_epochs_and_tracks_pending_suffix() {
        let mut log: CommitLog<C> = CommitLog::new();
        assert_eq!(log.seq(), 0);
        for want in 1..=3u64 {
            let c = log.issue_with(C);
            assert_eq!(c.epoch(), want);
            log.queue(c);
        }
        assert_eq!(log.len(), 3);
        assert_eq!(log.pending(0).len(), 3);
        assert_eq!(log.pending(2), vec![C(3)]);
        assert_eq!(log.depth(1), 2);
        assert!(log.pending(3).is_empty());
    }

    #[test]
    fn trim_keeps_entries_for_the_slowest_owner() {
        let mut log: CommitLog<C> = CommitLog::new();
        for _ in 0..4 {
            let c = log.issue_with(C);
            log.queue(c);
        }
        log.trim(2);
        assert_eq!(log.pending(2), vec![C(3), C(4)]);
        // The suffix a lagging owner needs survives the trim.
        assert_eq!(log.len(), 2);
        log.trim(4);
        assert!(log.is_empty());
        assert_eq!(log.seq(), 4, "trim never rewinds the epoch counter");
    }

    #[test]
    fn clear_restarts_the_epoch_sequence() {
        let mut log: CommitLog<C> = CommitLog::new();
        let c = log.issue_with(C);
        log.queue(c);
        log.clear();
        assert_eq!(log.seq(), 0);
        assert!(log.is_empty());
        assert_eq!(log.issue_with(C).epoch(), 1);
    }

    #[test]
    fn serial_mode_leaves_queue_empty_but_advances_seq() {
        let mut log: CommitLog<C> = CommitLog::new();
        let _ = log.issue_with(C); // applied eagerly, never queued
        let _ = log.issue_with(C);
        assert_eq!(log.seq(), 2);
        assert!(log.is_empty());
        assert!(verify_drained(2, log.seq()).is_ok());
        assert!(verify_drained(1, log.seq()).is_err());
    }
}
