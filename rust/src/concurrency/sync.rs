//! The crate's single switch point for synchronization primitives.
//!
//! Code that synchronizes between threads (`coordinator/workers.rs`,
//! `metrics`, `transport`, the kvcache id generator, runtime transfer
//! counters) imports `Mutex`/`RwLock`/`atomic`/`mpsc` from here instead of
//! `std::sync`. Normally these are plain re-exports of std — zero-cost. A
//! `RUSTFLAGS="--cfg loom"` build routes them to the instrumented wrappers
//! in [`super::shim`], which perturb the OS schedule at every blocking or
//! racy operation (and this is the one line to change if the real `loom`
//! crate is ever vendored: point the `cfg(loom)` branch at `loom::sync`).
//!
//! `Arc` is re-exported from std in both modes on purpose: the engine's
//! ownership-passing protocol moves state through channels and never
//! synchronizes via refcount ordering, so there is nothing for a shim to
//! perturb (see `CONCURRENCY.md`).

pub use std::sync::Arc;

#[cfg(not(loom))]
pub use std::sync::{mpsc, Mutex, RwLock};

#[cfg(not(loom))]
pub use std::sync::atomic;

#[cfg(loom)]
pub use super::shim::sync::{atomic, mpsc, Mutex, RwLock};
