//! Instrumented drop-in replacements for the `std::sync` / `std::thread`
//! types the engine uses, active under `--cfg loom`.
//!
//! The vendored dependency set has no `loom` crate, so the `#[cfg(loom)]`
//! branch of [`super::sync`] routes here instead: thin wrappers around the
//! std types that insert a seeded, per-thread pseudo-random
//! [`sched::yield_point`] before every blocking or racy operation (lock
//! acquisition, channel send/recv, atomic RMW, thread start). That is *not*
//! an exhaustive schedule search over the real binary — exhaustiveness
//! comes from the pure protocol model in [`super::model`], which the
//! `loom_protocol` tests drive through the [`Explorer`](super::explore) in
//! every build. What the shim adds in the `--cfg loom` lane is schedule
//! perturbation on the *real* `std` primitives, so the threaded suites run
//! under many more distinct interleavings than an idle machine would
//! produce.
//!
//! The wrappers expose exactly the std surface the crate uses (see
//! [`super::sync`]), so swapping in the real `loom` crate later is a
//! one-line change in that module, not a code change here or in the
//! engines. `Arc` is deliberately *not* wrapped: the pool's ownership-
//! passing protocol moves state through channels and never relies on
//! refcount ordering, so `std::sync::Arc` is used in both modes (see
//! `CONCURRENCY.md`).
//!
//! The module is compiled (and unit-tested) in every build so the `--cfg
//! loom` lane cannot rot; without the cfg the yield points are no-ops and
//! the wrappers behave identically to std.

/// Seeded per-thread schedule perturbation.
pub mod sched {
    use std::cell::Cell;
    use std::sync::atomic::{AtomicU64, Ordering};

    /// Seeds handed to threads as they first hit a yield point; the base
    /// can be pinned via `PIPEDEC_LOOM_SEED` for reproducing a schedule.
    static NEXT_SEED: AtomicU64 = AtomicU64::new(0);

    thread_local! {
        static RNG: Cell<u64> = const { Cell::new(0) };
    }

    fn base_seed() -> u64 {
        std::env::var("PIPEDEC_LOOM_SEED")
            .ok()
            .and_then(|s| s.parse::<u64>().ok())
            .unwrap_or(0x9E37_79B9_7F4A_7C15)
    }

    fn next(cell: &Cell<u64>) -> u64 {
        let mut x = cell.get();
        if x == 0 {
            // First yield point on this thread: derive a per-thread stream
            // from the (env-pinnable) base seed.
            let n = NEXT_SEED.fetch_add(1, Ordering::Relaxed);
            x = base_seed() ^ (n.wrapping_add(1)).wrapping_mul(0x9E37_79B9);
        }
        x ^= x << 13;
        x ^= x >> 7;
        x ^= x << 17;
        cell.set(x);
        x
    }

    /// Under `--cfg loom`, yield the OS scheduler at a seeded pseudo-random
    /// subset of call sites; otherwise a no-op (the RNG still advances so
    /// both cfgs execute the same code paths).
    pub fn yield_point() {
        let r = RNG.with(next);
        if cfg!(loom) && r & 0b11 == 0 {
            std::thread::yield_now();
        }
    }
}

/// Instrumented `std::sync` subset.
pub mod sync {
    use super::sched::yield_point;
    use std::sync::LockResult;

    /// [`std::sync::Mutex`] with a yield point before each acquisition.
    #[derive(Debug, Default)]
    pub struct Mutex<T>(std::sync::Mutex<T>);

    impl<T> Mutex<T> {
        pub const fn new(t: T) -> Self {
            Self(std::sync::Mutex::new(t))
        }

        pub fn lock(&self) -> LockResult<std::sync::MutexGuard<'_, T>> {
            yield_point();
            self.0.lock()
        }
    }

    /// [`std::sync::RwLock`] with a yield point before each acquisition.
    #[derive(Debug, Default)]
    pub struct RwLock<T>(std::sync::RwLock<T>);

    impl<T> RwLock<T> {
        pub const fn new(t: T) -> Self {
            Self(std::sync::RwLock::new(t))
        }

        pub fn read(&self) -> LockResult<std::sync::RwLockReadGuard<'_, T>> {
            yield_point();
            self.0.read()
        }

        pub fn write(&self) -> LockResult<std::sync::RwLockWriteGuard<'_, T>> {
            yield_point();
            self.0.write()
        }
    }

    /// Instrumented `std::sync::atomic` subset.
    pub mod atomic {
        pub use std::sync::atomic::Ordering;

        /// [`std::sync::atomic::AtomicU64`] with a yield point before each
        /// read-modify-write.
        #[derive(Debug, Default)]
        pub struct AtomicU64(std::sync::atomic::AtomicU64);

        impl AtomicU64 {
            pub const fn new(v: u64) -> Self {
                Self(std::sync::atomic::AtomicU64::new(v))
            }

            pub fn load(&self, order: Ordering) -> u64 {
                self.0.load(order)
            }

            pub fn store(&self, v: u64, order: Ordering) {
                self.0.store(v, order)
            }

            pub fn fetch_add(&self, v: u64, order: Ordering) -> u64 {
                super::super::sched::yield_point();
                self.0.fetch_add(v, order)
            }

            pub fn into_inner(self) -> u64 {
                self.0.into_inner()
            }
        }
    }

    /// Instrumented `std::sync::mpsc` subset.
    pub mod mpsc {
        use super::super::sched::yield_point;
        pub use std::sync::mpsc::{RecvError, SendError, TryRecvError};

        pub fn channel<T>() -> (Sender<T>, Receiver<T>) {
            let (tx, rx) = std::sync::mpsc::channel();
            (Sender(tx), Receiver(rx))
        }

        /// [`std::sync::mpsc::Sender`] with a yield point before each send.
        #[derive(Debug)]
        pub struct Sender<T>(std::sync::mpsc::Sender<T>);

        // Manual impl: derived Clone would require `T: Clone`, but channel
        // handles clone independently of the payload type.
        impl<T> Clone for Sender<T> {
            fn clone(&self) -> Self {
                Self(self.0.clone())
            }
        }

        impl<T> Sender<T> {
            pub fn send(&self, t: T) -> Result<(), SendError<T>> {
                yield_point();
                self.0.send(t)
            }
        }

        /// [`std::sync::mpsc::Receiver`] with a yield point before each
        /// receive.
        #[derive(Debug)]
        pub struct Receiver<T>(std::sync::mpsc::Receiver<T>);

        impl<T> Receiver<T> {
            pub fn recv(&self) -> Result<T, RecvError> {
                yield_point();
                self.0.recv()
            }

            pub fn try_recv(&self) -> Result<T, TryRecvError> {
                yield_point();
                self.0.try_recv()
            }

            pub fn iter(&self) -> std::sync::mpsc::Iter<'_, T> {
                yield_point();
                self.0.iter()
            }
        }
    }
}

/// Instrumented `std::thread` subset.
pub mod thread {
    use super::sched::yield_point;
    pub use std::thread::JoinHandle;

    /// [`std::thread::Builder`] whose spawned threads hit a yield point
    /// before running their closure (perturbs startup order).
    #[derive(Debug)]
    pub struct Builder(std::thread::Builder);

    // Manual impl: `std::thread::Builder` does not implement `Default`.
    impl Default for Builder {
        fn default() -> Self {
            Self::new()
        }
    }

    impl Builder {
        pub fn new() -> Self {
            Self(std::thread::Builder::new())
        }

        pub fn name(self, name: String) -> Self {
            Self(self.0.name(name))
        }

        pub fn spawn<F, T>(self, f: F) -> std::io::Result<JoinHandle<T>>
        where
            F: FnOnce() -> T + Send + 'static,
            T: Send + 'static,
        {
            self.0.spawn(move || {
                yield_point();
                f()
            })
        }
    }

    pub fn spawn<F, T>(f: F) -> JoinHandle<T>
    where
        F: FnOnce() -> T + Send + 'static,
        T: Send + 'static,
    {
        std::thread::spawn(move || {
            yield_point();
            f()
        })
    }

    pub fn yield_now() {
        std::thread::yield_now();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn mutex_and_rwlock_delegate() {
        let m = sync::Mutex::new(1u32);
        *m.lock().unwrap() += 1;
        assert_eq!(*m.lock().unwrap(), 2);
        let rw = sync::RwLock::new(3u32);
        assert_eq!(*rw.read().unwrap(), 3);
        *rw.write().unwrap() = 4;
        assert_eq!(*rw.read().unwrap(), 4);
    }

    #[test]
    fn atomic_u64_delegates() {
        use sync::atomic::{AtomicU64, Ordering};
        static S: AtomicU64 = AtomicU64::new(5); // exercises const-ness
        assert_eq!(S.fetch_add(2, Ordering::Relaxed), 5);
        assert_eq!(S.load(Ordering::Relaxed), 7);
        let a = AtomicU64::new(1);
        a.store(9, Ordering::Relaxed);
        assert_eq!(a.into_inner(), 9);
    }

    #[test]
    fn channels_move_values_across_instrumented_threads() {
        let (tx, rx) = sync::mpsc::channel::<u64>();
        let tx2 = tx.clone();
        let h = thread::Builder::new()
            .name("shim-test".into())
            .spawn(move || {
                tx2.send(11).unwrap();
            })
            .unwrap();
        tx.send(22).unwrap();
        let mut got = vec![rx.recv().unwrap(), rx.recv().unwrap()];
        got.sort_unstable();
        assert_eq!(got, vec![11, 22]);
        h.join().unwrap();
        drop(tx);
        assert!(rx.recv().is_err(), "closed channel reports disconnect");
    }

    #[test]
    fn yield_points_are_cheap_and_deterministic_per_thread() {
        // Just exercise the RNG path from several threads.
        let hs: Vec<_> = (0..4)
            .map(|_| {
                thread::spawn(|| {
                    for _ in 0..100 {
                        sched::yield_point();
                    }
                })
            })
            .collect();
        for h in hs {
            h.join().unwrap();
        }
        let _ = Arc::new(0u8); // Arc intentionally unwrapped; see module docs
    }
}
