//! Model of the engine's decide/commit + worker-handoff protocol, checked
//! exhaustively by `tests/loom_protocol.rs` via [`super::explore`].
//!
//! The model mirrors `coordinator/workers.rs` + the commit plumbing shared
//! by `PipeDecEngine` and `DbSession`, at the granularity where the races
//! live:
//!
//! * **Thread 0, the coordinator**, runs rounds: dispatch one job per
//!   occupied worker (each job carries the worker's pending commit suffix
//!   and a `commit_target`), collect one reply per dispatched job, then run
//!   the sync phase — mint the round's commit ([`CommitLog::issue_with`]),
//!   queue it (overlap mode) or apply it eagerly to every owner (serial
//!   mode) — and trim the log to the slowest owner. After the last round it
//!   dispatches one final drain job to every worker, closes the job
//!   channels one by one (`txs.clear()` in `WorkerPool::drop`), and joins.
//! * **Threads 1..=W, the workers**, loop: receive a job, drain its commit
//!   suffix through their cache's [`CommitCursor`] one commit at a time,
//!   run the `commit_target` staleness guard ([`verify_drained`]), run the
//!   forward, reply. A closed channel with an empty queue means exit.
//!
//! Crucially the model drives the *production* protocol types
//! ([`CommitLog`], [`CommitCursor`], [`verify_drained`]) — the checked
//! guards are the shipped ones, not re-implementations. The checked
//! properties (ISSUE 6):
//!
//! 1. no commit is skipped or double-applied under any interleaving (the
//!    cursor errors inside [`Model::step`]);
//! 2. no forward runs with an undrained commit suffix (ground-truth check
//!    against the job's `issued_seq`, independent of the production
//!    guards, so deleting a guard is *detected* rather than silently
//!    accepted);
//! 3. overlap-on and overlap-off reach the same final cache epoch on every
//!    owner (terminal check + [`ProtocolModel::terminal_epochs`]);
//! 4. pool shutdown never drops an in-flight job (terminal check on queues,
//!    forward counts and exit states).
//!
//! [`Mutations`] seeds protocol bugs — dropping the staleness guard,
//! over-trimming the log, forgetting to queue a minted commit, applying a
//! commit twice, exiting on channel close without draining the queue — and
//! the tests assert the explorer *fails* on each, which is what makes the
//! passing runs meaningful.
//!
//! A second lane, [`SpecModel`], covers the continuous-speculation epoch
//! protocol (ISSUE 10): a free-running draft thread banks epoch-tagged
//! generations against possibly-stale snapshots while the coordinator
//! prunes, resets and serves. It drives the production acceptance
//! predicate [`crate::coordinator::spec::expansion_applicable`] and checks,
//! against an independent node-identity ground truth, that no stale
//! generation is ever applied and no still-valid generation is ever
//! dropped, under every interleaving. [`SpecMutations`] seeds the
//! corresponding bugs.

use super::explore::Model;
use super::protocol::{verify_drained, CommitCursor, CommitLog, Epoched};
use std::cell::RefCell;
use std::collections::{BTreeSet, VecDeque};

/// Minimal commit carrying only its epoch — the protocol never inspects
/// the payload (`CommitOp` in production), so the model elides it.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct ModelCommit(pub u64);

impl Epoched for ModelCommit {
    fn epoch(&self) -> u64 {
        self.0
    }
}

/// Seeded protocol bugs. Each one makes some interleaving (or every
/// interleaving) violate a checked property; the loom tests assert the
/// explorer catches all of them.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct Mutations {
    /// Worker skips the `commit_target` staleness guard before its forward
    /// (deleting the `verify_drained` call in `apply_job_commits`).
    pub drop_target_check: bool,
    /// Coordinator trims the commit log one epoch past the slowest owner
    /// (an off-by-one in `trim_commit_log`), losing entries a lagging
    /// owner still needs.
    pub trim_ahead: bool,
    /// Coordinator mints a commit but forgets to queue it in overlap mode
    /// (decide without commit) — the epoch counter advances, the replay
    /// data is gone.
    pub skip_queue: bool,
    /// Worker applies each pending commit twice (lost idempotence
    /// assumption in the drain loop).
    pub apply_twice: bool,
    /// Worker checks the disconnect flag before its queue and exits on
    /// channel close even with jobs still queued (breaking the
    /// `while let Ok(job) = rx.recv()` drain discipline).
    pub shutdown_drops_queue: bool,
}

/// A dispatched job, as seen by the protocol: the pending commit suffix,
/// the `commit_target` staleness guard value, and `issued_seq` — the
/// ground-truth issuer epoch at dispatch, which the model checks at the
/// forward *independently of the production guards* (mutations may disable
/// guards, never the ground truth).
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct Job {
    commits: Vec<ModelCommit>,
    commit_target: u64,
    issued_seq: u64,
}

/// What a worker thread is doing.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
enum Task {
    Idle,
    /// Draining the job's commit suffix; `next` indexes `job.commits`.
    Drain { job: Job, next: usize },
    /// Commits drained and staleness guard passed; forward not yet run.
    Forward { job: Job },
    Exited,
}

#[derive(Debug, Clone, PartialEq, Eq, Hash)]
struct WorkerState {
    /// The worker's job channel buffer (sender side lives with the
    /// coordinator; `closed` models dropping the sender).
    queue: VecDeque<Job>,
    task: Task,
    /// The commit cursor of the cache this worker owns while running — the
    /// same [`CommitCursor`] type `TwoLevelCache` embeds.
    cursor: CommitCursor,
    forwards: u64,
}

/// Coordinator phase machine. One enabled transition per state keeps
/// threads deterministic; all nondeterminism is schedule choice.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
enum Coord {
    /// Dispatching round `round`: next send goes to worker `next`.
    /// `round == timesteps` is the final drain round (all workers, no sync
    /// after, fire-and-forget before close).
    Dispatch { round: usize, next: usize },
    /// Waiting for `outstanding` replies of round `round`.
    Collect { round: usize, outstanding: usize },
    /// Sync decide: mint round `round`'s commit.
    Mint { round: usize },
    /// Serial mode only: apply the minted commit to owner `next`.
    Apply { round: usize, next: usize },
    /// Trim the commit log to the slowest owner.
    Trim { round: usize },
    /// Closing job channels one by one (`txs.clear()`).
    Close { next: usize },
    /// Joining worker threads (blocks until all exited).
    Join,
    Done,
}

#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct ProtoState {
    coord: Coord,
    log: CommitLog<ModelCommit>,
    closed: Vec<bool>,
    workers: Vec<WorkerState>,
    /// The shared reply channel (worker index per reply, FIFO).
    done_q: VecDeque<usize>,
}

/// The checkable system: W workers, `occupancy.len()` sync rounds, overlap
/// on or off, plus seeded [`Mutations`].
#[derive(Debug)]
pub struct ProtocolModel {
    pub workers: usize,
    pub overlap: bool,
    /// `occupancy[round][w]`: dispatch a job to worker `w` in that round.
    /// Sparse rows create lagging owners, the interesting case for the
    /// pending-suffix and trim logic.
    pub occupancy: Vec<Vec<bool>>,
    pub mutations: Mutations,
    /// Distinct `[cursor epochs per owner]` observed at clean terminals —
    /// read after exploration to compare overlap-on vs overlap-off.
    pub terminal_epochs: RefCell<BTreeSet<Vec<u64>>>,
}

impl ProtocolModel {
    pub fn new(workers: usize, overlap: bool, occupancy: Vec<Vec<bool>>) -> Self {
        assert!(workers >= 1);
        assert!(occupancy.iter().all(|row| row.len() == workers));
        Self {
            workers,
            overlap,
            occupancy,
            mutations: Mutations::default(),
            terminal_epochs: RefCell::new(BTreeSet::new()),
        }
    }

    pub fn with_mutations(mut self, m: Mutations) -> Self {
        self.mutations = m;
        self
    }

    /// Number of sync rounds (the drain round comes after these).
    fn rounds(&self) -> usize {
        self.occupancy.len()
    }

    /// Occupancy lookup covering the drain round (everyone gets a drain
    /// job, mirroring the engines' final `drain_pending_commits` pass).
    fn occupied(&self, round: usize, w: usize) -> bool {
        if round == self.rounds() {
            true
        } else {
            self.occupancy[round][w]
        }
    }

    fn dispatched_in(&self, round: usize) -> usize {
        (0..self.workers)
            .filter(|&w| self.occupied(round, w))
            .count()
    }

    fn step_coord(&self, s: &mut ProtoState) -> Result<(), String> {
        match s.coord.clone() {
            Coord::Dispatch { round, next } => {
                if next < self.workers {
                    if self.occupied(round, next) {
                        let cur = s.workers[next].cursor.epoch();
                        let job = Job {
                            commits: s.log.pending(cur),
                            commit_target: s.log.seq(),
                            issued_seq: s.log.seq(),
                        };
                        s.workers[next].queue.push_back(job);
                    }
                    s.coord = Coord::Dispatch {
                        round,
                        next: next + 1,
                    };
                } else if round < self.rounds() {
                    s.coord = Coord::Collect {
                        round,
                        outstanding: self.dispatched_in(round),
                    };
                } else {
                    // Drain round is fire-and-forget: replies are never
                    // read (the pool is being dropped); go close channels.
                    s.coord = Coord::Close { next: 0 };
                }
            }
            Coord::Collect { round, outstanding } => {
                if outstanding > 0 {
                    let w = s
                        .done_q
                        .pop_front()
                        .expect("Collect enabled only with a reply queued");
                    debug_assert!(w < self.workers);
                    s.coord = Coord::Collect {
                        round,
                        outstanding: outstanding - 1,
                    };
                } else {
                    s.coord = Coord::Mint { round };
                }
            }
            Coord::Mint { round } => {
                let c = s.log.issue_with(ModelCommit);
                if self.overlap {
                    if !self.mutations.skip_queue {
                        s.log.queue(c);
                    }
                    s.coord = Coord::Trim { round };
                } else {
                    s.coord = Coord::Apply { round, next: 0 };
                }
            }
            Coord::Apply { round, next } => {
                if next < self.workers {
                    // Serial mode: the coordinator owns every cache between
                    // timesteps and replays the fresh commit eagerly.
                    let epoch = s.log.seq();
                    s.workers[next]
                        .cursor
                        .admit(epoch)
                        .map_err(|e| format!("serial apply to owner {next}: {e}"))?;
                    s.coord = Coord::Apply {
                        round,
                        next: next + 1,
                    };
                } else {
                    s.coord = Coord::Trim { round };
                }
            }
            Coord::Trim { round } => {
                let min = s
                    .workers
                    .iter()
                    .map(|w| w.cursor.epoch())
                    .min()
                    .unwrap_or(0);
                let min = if self.mutations.trim_ahead {
                    min + 1
                } else {
                    min
                };
                s.log.trim(min);
                s.coord = Coord::Dispatch {
                    round: round + 1,
                    next: 0,
                };
            }
            Coord::Close { next } => {
                if next < self.workers {
                    s.closed[next] = true;
                    s.coord = Coord::Close { next: next + 1 };
                } else {
                    s.coord = Coord::Join;
                }
            }
            Coord::Join => {
                debug_assert!(s.workers.iter().all(|w| w.task == Task::Exited));
                s.coord = Coord::Done;
            }
            Coord::Done => unreachable!("Done has no enabled transition"),
        }
        Ok(())
    }

    fn step_worker(&self, s: &mut ProtoState, w: usize) -> Result<(), String> {
        let ws = &mut s.workers[w];
        match ws.task.clone() {
            Task::Idle => {
                if self.mutations.shutdown_drops_queue && s.closed[w] {
                    // Seeded bug: disconnect checked before the queue.
                    ws.task = Task::Exited;
                } else if let Some(job) = ws.queue.pop_front() {
                    ws.task = Task::Drain { job, next: 0 };
                } else {
                    debug_assert!(s.closed[w], "Idle enabled only with work or close");
                    ws.task = Task::Exited;
                }
            }
            Task::Drain { job, next } => {
                if next < job.commits.len() {
                    let epoch = job.commits[next].epoch();
                    ws.cursor
                        .admit(epoch)
                        .map_err(|e| format!("worker {w} drain: {e}"))?;
                    if self.mutations.apply_twice {
                        ws.cursor
                            .admit(epoch)
                            .map_err(|e| format!("worker {w} drain (2nd apply): {e}"))?;
                    }
                    ws.task = Task::Drain {
                        job,
                        next: next + 1,
                    };
                } else {
                    // Production staleness guard (mutable away — the
                    // ground-truth check at the forward still stands).
                    if !self.mutations.drop_target_check {
                        verify_drained(ws.cursor.epoch(), job.commit_target)
                            .map_err(|e| format!("worker {w}: {e}"))?;
                    }
                    ws.task = Task::Forward { job };
                }
            }
            Task::Forward { job } => {
                // Ground truth for property 2: every commit issued before
                // this job was dispatched must be applied, or the forward
                // reads a stale cache layout.
                if ws.cursor.epoch() != job.issued_seq {
                    return Err(format!(
                        "worker {w} ran a forward with an undrained commit suffix \
                         (cache epoch {}, commits issued {})",
                        ws.cursor.epoch(),
                        job.issued_seq
                    ));
                }
                ws.forwards += 1;
                ws.task = Task::Idle;
                s.done_q.push_back(w);
            }
            Task::Exited => unreachable!("Exited has no enabled transition"),
        }
        Ok(())
    }
}

impl Model for ProtocolModel {
    type State = ProtoState;

    fn initial(&self) -> ProtoState {
        ProtoState {
            coord: Coord::Dispatch { round: 0, next: 0 },
            log: CommitLog::new(),
            closed: vec![false; self.workers],
            workers: (0..self.workers)
                .map(|_| WorkerState {
                    queue: VecDeque::new(),
                    task: Task::Idle,
                    cursor: CommitCursor::new(),
                    forwards: 0,
                })
                .collect(),
            done_q: VecDeque::new(),
        }
    }

    fn threads(&self) -> usize {
        self.workers + 1
    }

    fn enabled(&self, s: &ProtoState, tid: usize) -> bool {
        if tid == 0 {
            match &s.coord {
                Coord::Done => false,
                // recv on the reply channel blocks until a reply arrives
                Coord::Collect { outstanding, .. } => {
                    *outstanding == 0 || !s.done_q.is_empty()
                }
                // join blocks until every worker exited
                Coord::Join => s.workers.iter().all(|w| w.task == Task::Exited),
                _ => true,
            }
        } else {
            let w = &s.workers[tid - 1];
            match &w.task {
                Task::Exited => false,
                // recv on the job channel blocks until a job or a close
                Task::Idle => !w.queue.is_empty() || s.closed[tid - 1],
                _ => true,
            }
        }
    }

    fn step(&self, s: &mut ProtoState, tid: usize) -> Result<(), String> {
        if tid == 0 {
            self.step_coord(s)
        } else {
            self.step_worker(s, tid - 1)
        }
    }

    fn check(&self, s: &ProtoState) -> Result<(), String> {
        for (i, w) in s.workers.iter().enumerate() {
            if w.cursor.epoch() > s.log.seq() {
                return Err(format!(
                    "owner {i} is ahead of the issuer: cursor {} > seq {}",
                    w.cursor.epoch(),
                    s.log.seq()
                ));
            }
        }
        Ok(())
    }

    fn check_terminal(&self, s: &ProtoState) -> Result<(), String> {
        if s.coord != Coord::Done {
            return Err(format!(
                "deadlock: nothing can run but the coordinator is in {:?}",
                s.coord
            ));
        }
        let total = s.log.seq();
        let mut expected_forwards = 0u64;
        for (i, w) in s.workers.iter().enumerate() {
            if w.task != Task::Exited {
                return Err(format!("worker {i} never exited: {:?}", w.task));
            }
            if !w.queue.is_empty() {
                return Err(format!(
                    "pool shutdown dropped {} in-flight job(s) on worker {i}",
                    w.queue.len()
                ));
            }
            if w.cursor.epoch() != total {
                return Err(format!(
                    "owner {i} finished at commit epoch {} but {} commits were \
                     issued (skipped commit)",
                    w.cursor.epoch(),
                    total
                ));
            }
            expected_forwards += (0..=self.rounds())
                .filter(|&r| self.occupied(r, i))
                .count() as u64;
        }
        let forwards: u64 = s.workers.iter().map(|w| w.forwards).sum();
        if forwards != expected_forwards {
            return Err(format!(
                "{forwards} forwards ran but {expected_forwards} jobs were dispatched"
            ));
        }
        // Drain-round replies are fire-and-forget; exactly one per worker
        // must still sit in the reply channel. Fewer means a job vanished.
        if s.done_q.len() != self.workers {
            return Err(format!(
                "expected {} unread drain-round replies, found {}",
                self.workers,
                s.done_q.len()
            ));
        }
        self.terminal_epochs
            .borrow_mut()
            .insert(s.workers.iter().map(|w| w.cursor.epoch()).collect());
        Ok(())
    }
}

// ---------------------------------------------------------------------------
// Continuous-speculation epoch protocol (ISSUE 10)
// ---------------------------------------------------------------------------

/// Seeded bugs for the speculation-epoch lane ([`SpecModel`]). Each makes
/// some interleaving apply a stale expansion or drop a valid one; the loom
/// tests assert the explorer fails on each.
#[derive(Debug, Clone, Copy, Default)]
pub struct SpecMutations {
    /// Serve the head-of-bank generation without consulting
    /// [`expansion_applicable`] at all.
    pub apply_stale: bool,
    /// Reject every banked generation even when the verdict says it still
    /// applies (lockstep would have produced the identical layer).
    pub drop_valid: bool,
    /// Skip the divergence guard: after a *filtered* serve (a prune
    /// removed some of the expansion's parents while it was in flight)
    /// keep the deeper banked generations, whose shadow-minted parent ids
    /// now collide with differently-shaped canonical nodes.
    pub skip_divergence_guard: bool,
    /// Remove the epoch mechanism entirely: Miss stops clearing the bank,
    /// arrivals are banked regardless of tag, and applicability is
    /// evaluated with the live epoch substituted for the expansion's.
    /// Node-id collisions across a Miss reset then pass the frontier
    /// equality check — proving the tag (not id matching) is what keeps
    /// pre-reset generations out.
    pub ignore_epoch: bool,
}

/// One scripted coordinator action per sync round.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum SpecEvent {
    /// Lockstep draft expansion: every frontier node mints its children
    /// on the canonical tree (the fallback when no banked generation
    /// serves).
    Expand,
    /// Hit-path prune: keep only the `keep % frontier.len()`-th frontier
    /// node, discarding the sibling subtrees.
    Hit { keep: usize },
    /// Miss-path reset: bump the live epoch, rebuild the tree from a
    /// fresh root, and clear the bank. Node ids restart, so ids from the
    /// old tree *collide* with differently-valued new nodes — the epoch
    /// tag is what keeps pre-reset generations out.
    Miss,
    /// Sync-phase serve attempt: absorb draft arrivals into the bank,
    /// then pop generations until one applies (mirrors
    /// `SpecBank::try_serve`).
    Serve,
}

/// A free-running draft generation: the epoch it assumed, the snapshot
/// frontier it expanded (`(node_id, value)` pairs), and the child values
/// it computed per parent. `value` is a ground-truth-only node identity —
/// unique across the whole run even where node *ids* collide across Miss
/// resets — standing in for the token content the real draft derives from
/// the node's path.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct SpecExp {
    epoch: u64,
    parents: Vec<(u64, u64)>,
    children: Vec<Vec<u64>>,
}

/// Draft-thread program counter: snapshot the committed state, then
/// produce `gens` generations against a private shadow of it.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
enum DraftPc {
    Snap,
    Produce {
        gen: usize,
        snap_epoch: u64,
        shadow: Vec<(u64, u64)>,
        shadow_next_id: u64,
    },
}

/// Shared state for [`SpecModel`].
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct SpecState {
    epoch: u64,
    next_id: u64,
    /// Canonical frontier, `(id, value)` in BFS order.
    frontier: Vec<(u64, u64)>,
    /// Ids alive in the *current* tree instance (cleared on Miss).
    alive: BTreeSet<u64>,
    /// Arrived-but-unbanked generations (the draft reply in flight).
    inflight: VecDeque<SpecExp>,
    bank: VecDeque<SpecExp>,
    next_event: usize,
    draft: DraftPc,
    dispatches_left: usize,
    served: u64,
    dropped: u64,
}

/// Model of the free-running speculation protocol (ISSUE 10), driving the
/// production acceptance predicate
/// [`crate::coordinator::spec::expansion_applicable`] under every
/// interleaving of a snapshotting draft thread against a coordinator
/// running scripted Expand / Hit / Miss / Serve rounds.
///
/// * **Thread 0, the coordinator**, executes `events` in order. `Serve`
///   mirrors `SpecBank::try_serve`: resolve each banked generation's
///   parents against the live tree, ask `expansion_applicable`, apply the
///   survivors' children or drop the generation, and clear the remaining
///   bank after a filtered serve (the divergence guard).
/// * **Thread 1, the draft**, free-runs `dispatches` cycles: atomically
///   snapshot `(epoch, frontier, next_id)`, then mint `gens` generations
///   against a private shadow — exactly like `draft_speculate`'s
///   `tree.clone()` — publishing each into the in-flight queue. The
///   explorer chooses when the snapshot lands relative to coordinator
///   rounds, which is where every staleness case comes from.
///
/// Ground truth is independent of the checked predicate: every node
/// carries a run-unique `value` (node ids deliberately restart on Miss so
/// they collide across resets, as production tree ids do). An applied
/// generation must have expanded, value-for-value, exactly the nodes that
/// are the canonical frontier *now* — i.e. lockstep would have produced
/// the identical layer; a dropped generation must not have. `check_terminal`
/// records `(served, dropped)` into [`SpecModel::outcomes`] so tests can
/// assert both outcomes are actually reachable.
pub struct SpecModel {
    pub events: Vec<SpecEvent>,
    /// Draft snapshot/produce cycles.
    pub dispatches: usize,
    /// Generations minted per dispatch.
    pub gens: usize,
    pub mutations: SpecMutations,
    /// `(served, dropped)` pairs over all terminal states.
    pub outcomes: RefCell<BTreeSet<(u64, u64)>>,
}

/// Child fan-out: keep the frontier at most two wide so prunes have a
/// sibling to discard without blowing up the state space.
fn spec_fanout(frontier_len: usize) -> u64 {
    if frontier_len <= 1 {
        2
    } else {
        1
    }
}

/// Deterministic per-node child value — the model's stand-in for the
/// draft model being a pure function of the parent's path. Injective for
/// the shallow trees explored here, so two distinct nodes never mint
/// equal-valued children.
fn spec_child_value(parent_value: u64, child: u64) -> u64 {
    parent_value.wrapping_mul(31).wrapping_add(child + 7)
}

/// Root value for the tree instance of `epoch` — distinct per reset.
fn spec_root_value(epoch: u64) -> u64 {
    (epoch + 1) << 32
}

impl SpecModel {
    pub fn new(events: Vec<SpecEvent>, dispatches: usize, gens: usize) -> Self {
        Self {
            events,
            dispatches,
            gens,
            mutations: SpecMutations::default(),
            outcomes: RefCell::new(BTreeSet::new()),
        }
    }

    /// Ground truth for an applied generation: the survivors it expanded
    /// must be, value-for-value and in order, the canonical frontier.
    fn check_apply(s: &SpecState, exp: &SpecExp, survivors: &[usize]) -> Result<(), String> {
        let surv_values: Vec<u64> = survivors.iter().map(|&i| exp.parents[i].1).collect();
        let frontier_values: Vec<u64> = s.frontier.iter().map(|n| n.1).collect();
        if surv_values != frontier_values {
            return Err(format!(
                "stale expansion applied: epoch-{} generation expanded nodes \
                 {surv_values:?} but the committed frontier at epoch {} is \
                 {frontier_values:?}",
                exp.epoch, s.epoch
            ));
        }
        Ok(())
    }

    /// Ground truth for a dropped generation: lockstep from the current
    /// committed state must *not* have produced the identical layer.
    fn check_drop(s: &SpecState, exp: &SpecExp) -> Result<(), String> {
        let surv_values: Vec<u64> = exp
            .parents
            .iter()
            .filter(|p| s.alive.contains(&p.0))
            .map(|p| p.1)
            .collect();
        let frontier_values: Vec<u64> = s.frontier.iter().map(|n| n.1).collect();
        if !surv_values.is_empty() && surv_values == frontier_values {
            return Err(format!(
                "valid expansion dropped: epoch-{} generation for frontier \
                 {frontier_values:?} was discarded at live epoch {}",
                exp.epoch, s.epoch
            ));
        }
        Ok(())
    }

    /// Mirror of `SpecBank::try_serve` + the Done-arm arrival filter.
    fn serve(&self, s: &mut SpecState) -> Result<(), String> {
        while let Some(exp) = s.inflight.pop_front() {
            if exp.epoch == s.epoch || self.mutations.ignore_epoch {
                s.bank.push_back(exp);
            } else {
                s.dropped += 1;
                Self::check_drop(s, &exp)?;
            }
        }
        while let Some(exp) = s.bank.pop_front() {
            let survivors: Vec<usize> = (0..exp.parents.len())
                .filter(|&i| s.alive.contains(&exp.parents[i].0))
                .collect();
            let surviving_ids: Vec<u64> =
                survivors.iter().map(|&i| exp.parents[i].0).collect();
            let frontier_ids: Vec<u64> = s.frontier.iter().map(|n| n.0).collect();
            let tag = if self.mutations.ignore_epoch {
                s.epoch
            } else {
                exp.epoch
            };
            let verdict = crate::coordinator::spec::expansion_applicable(
                tag,
                s.epoch,
                &surviving_ids,
                &frontier_ids,
            );
            let apply = (verdict || self.mutations.apply_stale) && !self.mutations.drop_valid;
            if !apply {
                s.dropped += 1;
                Self::check_drop(s, &exp)?;
                continue; // stale: fall through to the next generation
            }
            Self::check_apply(s, &exp, &survivors)?;
            let mut minted = Vec::with_capacity(survivors.len());
            for &i in &survivors {
                for &value in &exp.children[i] {
                    minted.push((s.next_id, value));
                    s.alive.insert(s.next_id);
                    s.next_id += 1;
                }
            }
            s.frontier = minted;
            s.served += 1;
            if survivors.len() < exp.parents.len() && !self.mutations.skip_divergence_guard {
                // Divergence guard: deeper generations assumed the
                // unfiltered tree; their shadow ids alias fresh canonical
                // nodes, so they must die with this serve.
                while let Some(rest) = s.bank.pop_front() {
                    s.dropped += 1;
                    Self::check_drop(s, &rest)?;
                }
            }
            break;
        }
        Ok(())
    }
}

impl Model for SpecModel {
    type State = SpecState;

    fn initial(&self) -> SpecState {
        let root = (0u64, spec_root_value(0));
        SpecState {
            epoch: 0,
            next_id: 1,
            frontier: vec![root],
            alive: BTreeSet::from([0]),
            inflight: VecDeque::new(),
            bank: VecDeque::new(),
            next_event: 0,
            draft: DraftPc::Snap,
            dispatches_left: self.dispatches,
            served: 0,
            dropped: 0,
        }
    }

    fn threads(&self) -> usize {
        2
    }

    fn enabled(&self, s: &SpecState, tid: usize) -> bool {
        match tid {
            0 => s.next_event < self.events.len(),
            _ => s.dispatches_left > 0,
        }
    }

    fn step(&self, s: &mut SpecState, tid: usize) -> Result<(), String> {
        if tid == 0 {
            let ev = self.events[s.next_event];
            s.next_event += 1;
            match ev {
                SpecEvent::Expand => {
                    let fan = spec_fanout(s.frontier.len());
                    let mut minted = Vec::new();
                    for &(_, value) in &s.frontier.clone() {
                        for c in 0..fan {
                            minted.push((s.next_id, spec_child_value(value, c)));
                            s.alive.insert(s.next_id);
                            s.next_id += 1;
                        }
                    }
                    s.frontier = minted;
                }
                SpecEvent::Hit { keep } => {
                    let k = keep % s.frontier.len();
                    for (i, &(id, _)) in s.frontier.clone().iter().enumerate() {
                        if i != k {
                            s.alive.remove(&id);
                        }
                    }
                    s.frontier = vec![s.frontier[k]];
                }
                SpecEvent::Miss => {
                    s.epoch += 1;
                    s.alive.clear();
                    s.next_id = 1;
                    let root = (0u64, spec_root_value(s.epoch));
                    s.alive.insert(0);
                    s.frontier = vec![root];
                    if !self.mutations.ignore_epoch {
                        s.bank.clear(); // SpecBank::bump_epoch drops the bank
                    }
                }
                SpecEvent::Serve => self.serve(s)?,
            }
            return Ok(());
        }
        match s.draft.clone() {
            DraftPc::Snap => {
                s.draft = DraftPc::Produce {
                    gen: 0,
                    snap_epoch: s.epoch,
                    shadow: s.frontier.clone(),
                    shadow_next_id: s.next_id,
                };
            }
            DraftPc::Produce {
                gen,
                snap_epoch,
                shadow,
                mut shadow_next_id,
            } => {
                let fan = spec_fanout(shadow.len());
                let mut children = Vec::with_capacity(shadow.len());
                let mut next_shadow = Vec::new();
                for &(_, value) in &shadow {
                    let vals: Vec<u64> =
                        (0..fan).map(|c| spec_child_value(value, c)).collect();
                    for &v in &vals {
                        next_shadow.push((shadow_next_id, v));
                        shadow_next_id += 1;
                    }
                    children.push(vals);
                }
                s.inflight.push_back(SpecExp {
                    epoch: snap_epoch,
                    parents: shadow,
                    children,
                });
                if gen + 1 == self.gens {
                    s.dispatches_left -= 1;
                    s.draft = DraftPc::Snap;
                } else {
                    s.draft = DraftPc::Produce {
                        gen: gen + 1,
                        snap_epoch,
                        shadow: next_shadow,
                        shadow_next_id,
                    };
                }
            }
        }
        Ok(())
    }

    fn check_terminal(&self, s: &SpecState) -> Result<(), String> {
        self.outcomes.borrow_mut().insert((s.served, s.dropped));
        Ok(())
    }
}
