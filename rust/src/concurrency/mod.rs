//! Concurrency correctness toolkit (ISSUE 6): the extracted decide/commit
//! protocol, an in-tree explicit-state model checker, and the crate's
//! single switch point for synchronization primitives.
//!
//! * [`protocol`] — the commit-epoch rules as pure data structures
//!   ([`CommitLog`](protocol::CommitLog),
//!   [`CommitCursor`](protocol::CommitCursor),
//!   [`verify_drained`](protocol::verify_drained)), shared by the
//!   production engines and the model checker.
//! * [`explore`] — exhaustive interleaving search over a
//!   [`Model`](explore::Model) (the vendored dependency set has no `loom`
//!   crate, so the checker is in-tree).
//! * [`model`] — the protocol model driven by `tests/loom_protocol.rs`:
//!   coordinator + worker threads, commit drains, staleness guards,
//!   channel-close shutdown, plus seeded mutations that must fail.
//! * [`sync`] / [`thread`] — re-export `std::sync` / `std::thread`
//!   normally; under `RUSTFLAGS="--cfg loom"` they route to the
//!   instrumented [`shim`] wrappers that perturb the OS schedule at every
//!   blocking or racy operation.
//!
//! See `rust/CONCURRENCY.md` for the full audit: Send/Sync reasoning for
//! the PJRT wrappers, the ownership-passing job protocol, the commit-epoch
//! invariants, and how to run the loom/Miri/TSan lanes locally.

pub mod explore;
pub mod model;
pub mod protocol;
pub mod shim;
pub mod sync;

/// Thread spawning, switched like [`sync`]: std normally, instrumented
/// under `--cfg loom`.
pub mod thread {
    #[cfg(not(loom))]
    pub use std::thread::{spawn, yield_now, Builder, JoinHandle};

    #[cfg(loom)]
    pub use super::shim::thread::{spawn, yield_now, Builder, JoinHandle};
}
