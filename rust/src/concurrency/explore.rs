//! A small explicit-state model checker: exhaustive interleaving search
//! over a [`Model`]'s thread transitions.
//!
//! This is the loom-style engine behind `tests/loom_protocol.rs`. The
//! offline vendor set has no `loom` crate, so instead of depending on one we
//! keep the checker in-tree: a model describes a fixed set of logical
//! threads, each with at most one enabled transition per state, and the
//! [`Explorer`] runs a depth-first search over *every* schedule (which
//! thread moves next), deduplicating identical states so the search is
//! exhaustive over distinct behaviours rather than over raw schedules.
//!
//! Properties come in three flavours:
//! * [`Model::step`] returns `Err` when a transition itself detects a
//!   violation (e.g. a production guard like
//!   [`CommitCursor::admit`](super::protocol::CommitCursor::admit) fires);
//! * [`Model::check`] is a safety invariant evaluated on every reached
//!   state;
//! * [`Model::check_terminal`] is evaluated on states with no enabled
//!   transitions — which makes deadlocks and dropped-work bugs visible: a
//!   state where nothing can move but the protocol has not finished fails
//!   here.
//!
//! On failure the [`Violation`] carries the full schedule (sequence of
//! thread ids) that reproduces the bug, so a counterexample can be replayed
//! by hand.

use std::collections::HashSet;
use std::hash::Hash;

/// A finite concurrent system to check. `State` must be cheap to clone and
/// hashable; the explorer memoizes visited states by equality.
pub trait Model {
    type State: Clone + Eq + Hash + std::fmt::Debug;

    /// The single initial state.
    fn initial(&self) -> Self::State;

    /// Number of logical threads. Thread ids are `0..threads()`.
    fn threads(&self) -> usize;

    /// Whether thread `tid` has a transition enabled in `s`. A thread must
    /// be deterministic: at most one transition per (state, tid).
    fn enabled(&self, s: &Self::State, tid: usize) -> bool;

    /// Apply thread `tid`'s transition to `s`. Only called when
    /// [`enabled`](Self::enabled) returned true. `Err` is a violation.
    fn step(&self, s: &mut Self::State, tid: usize) -> Result<(), String>;

    /// Safety invariant, evaluated on every reached state.
    fn check(&self, _s: &Self::State) -> Result<(), String> {
        Ok(())
    }

    /// Evaluated on states with no enabled transitions. Distinguishes a
    /// clean protocol shutdown from a deadlock or dropped work.
    fn check_terminal(&self, _s: &Self::State) -> Result<(), String> {
        Ok(())
    }
}

/// Search statistics for a passing exploration.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct Stats {
    /// Distinct states reached (after dedup).
    pub states: usize,
    /// Transitions executed.
    pub transitions: usize,
    /// Distinct terminal states.
    pub terminals: usize,
    /// Longest schedule explored.
    pub max_depth: usize,
}

/// A property failure plus the schedule (thread-id sequence from the
/// initial state) that reproduces it.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Violation {
    pub message: String,
    pub schedule: Vec<usize>,
}

impl std::fmt::Display for Violation {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "model violation: {} (schedule {:?})",
            self.message, self.schedule
        )
    }
}

impl std::error::Error for Violation {}

/// Exhaustive DFS over all interleavings of a [`Model`].
#[derive(Debug, Clone, Copy)]
pub struct Explorer {
    /// Abort (as a violation) if the distinct-state count exceeds this —
    /// a guard against accidentally unbounded models, not a sampling knob.
    pub max_states: usize,
}

impl Default for Explorer {
    fn default() -> Self {
        Self::new()
    }
}

impl Explorer {
    pub fn new() -> Self {
        Self {
            max_states: 5_000_000,
        }
    }

    /// Explore every reachable state of `m`; returns search [`Stats`] if
    /// all properties hold in all interleavings, or the first [`Violation`]
    /// found with its reproducing schedule.
    pub fn explore<M: Model>(&self, m: &M) -> Result<Stats, Violation> {
        let init = m.initial();
        m.check(&init).map_err(|message| Violation {
            message,
            schedule: Vec::new(),
        })?;

        let mut visited: HashSet<M::State> = HashSet::new();
        visited.insert(init.clone());
        // Each frame carries the state and the schedule that reached it so
        // violations report a full counterexample trace.
        let mut stack: Vec<(M::State, Vec<usize>)> = vec![(init, Vec::new())];
        let mut stats = Stats::default();

        while let Some((s, sched)) = stack.pop() {
            stats.states += 1;
            stats.max_depth = stats.max_depth.max(sched.len());

            let mut any_enabled = false;
            for tid in 0..m.threads() {
                if !m.enabled(&s, tid) {
                    continue;
                }
                any_enabled = true;
                let mut next = s.clone();
                let mut next_sched = sched.clone();
                next_sched.push(tid);
                m.step(&mut next, tid).map_err(|message| Violation {
                    message,
                    schedule: next_sched.clone(),
                })?;
                stats.transitions += 1;
                m.check(&next).map_err(|message| Violation {
                    message,
                    schedule: next_sched.clone(),
                })?;
                if visited.insert(next.clone()) {
                    if visited.len() > self.max_states {
                        return Err(Violation {
                            message: format!(
                                "state space exceeded max_states = {}",
                                self.max_states
                            ),
                            schedule: next_sched,
                        });
                    }
                    stack.push((next, next_sched));
                }
            }

            if !any_enabled {
                stats.terminals += 1;
                m.check_terminal(&s).map_err(|message| Violation {
                    message,
                    schedule: sched.clone(),
                })?;
            }
        }

        Ok(stats)
    }
}

/// All interleavings of `counts.len()` sequences with the given lengths, as
/// sequences of sequence-indices. E.g. `interleavings(&[2, 1])` yields
/// `[0,0,1]`, `[0,1,0]`, `[1,0,0]`. Used by tests that replay a fixed
/// per-owner workload under every schedule against real (non-`Hash`able)
/// structures like `TwoLevelCache`, where the [`Explorer`]'s state dedup
/// cannot apply.
pub fn interleavings(counts: &[usize]) -> Vec<Vec<usize>> {
    fn rec(remaining: &mut [usize], prefix: &mut Vec<usize>, out: &mut Vec<Vec<usize>>) {
        if remaining.iter().all(|&r| r == 0) {
            out.push(prefix.clone());
            return;
        }
        for i in 0..remaining.len() {
            if remaining[i] > 0 {
                remaining[i] -= 1;
                prefix.push(i);
                rec(remaining, prefix, out);
                prefix.pop();
                remaining[i] += 1;
            }
        }
    }
    let mut out = Vec::new();
    rec(&mut counts.to_vec(), &mut Vec::new(), &mut out);
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Two threads, each doing a non-atomic read-modify-write on a shared
    /// counter. The classic lost-update race: the explorer must find the
    /// interleaving where both read before either writes.
    struct LostUpdate;

    #[derive(Debug, Clone, PartialEq, Eq, Hash)]
    struct LuState {
        shared: u32,
        // Per-thread: None = not yet read, Some(v) = read v, done flag.
        read: [Option<u32>; 2],
        done: [bool; 2],
    }

    impl Model for LostUpdate {
        type State = LuState;

        fn initial(&self) -> LuState {
            LuState {
                shared: 0,
                read: [None, None],
                done: [false, false],
            }
        }

        fn threads(&self) -> usize {
            2
        }

        fn enabled(&self, s: &LuState, tid: usize) -> bool {
            !s.done[tid]
        }

        fn step(&self, s: &mut LuState, tid: usize) -> Result<(), String> {
            match s.read[tid] {
                None => s.read[tid] = Some(s.shared),
                Some(v) => {
                    s.shared = v + 1;
                    s.done[tid] = true;
                }
            }
            Ok(())
        }

        fn check_terminal(&self, s: &LuState) -> Result<(), String> {
            if s.shared == 2 {
                Ok(())
            } else {
                Err(format!("lost update: final counter {}", s.shared))
            }
        }
    }

    #[test]
    fn explorer_finds_the_lost_update_interleaving() {
        let v = Explorer::new()
            .explore(&LostUpdate)
            .expect_err("the race must be found");
        assert!(v.message.contains("lost update"), "{v}");
        assert!(!v.schedule.is_empty());
    }

    /// Same system but with an atomic increment: passes, and the explorer
    /// visits both orders.
    struct AtomicIncr;

    impl Model for AtomicIncr {
        type State = (u32, [bool; 2]);

        fn initial(&self) -> Self::State {
            (0, [false, false])
        }

        fn threads(&self) -> usize {
            2
        }

        fn enabled(&self, s: &Self::State, tid: usize) -> bool {
            !s.1[tid]
        }

        fn step(&self, s: &mut Self::State, tid: usize) -> Result<(), String> {
            s.0 += 1;
            s.1[tid] = true;
            Ok(())
        }

        fn check_terminal(&self, s: &Self::State) -> Result<(), String> {
            if s.0 == 2 {
                Ok(())
            } else {
                Err(format!("final counter {}", s.0))
            }
        }
    }

    #[test]
    fn explorer_passes_atomic_version_and_counts_states() {
        let stats = Explorer::new().explore(&AtomicIncr).expect("no race");
        // States: (0,[f,f]), (1,[t,f]), (1,[f,t]), (2,[t,t]) = 4 distinct.
        assert_eq!(stats.states, 4);
        assert_eq!(stats.terminals, 1);
        assert_eq!(stats.transitions, 4);
    }

    #[test]
    fn max_states_guard_trips() {
        let v = Explorer { max_states: 1 }
            .explore(&AtomicIncr)
            .expect_err("guard must trip");
        assert!(v.message.contains("max_states"));
    }

    #[test]
    fn interleavings_enumerates_all_merges() {
        let all = interleavings(&[2, 1]);
        assert_eq!(
            all,
            vec![vec![0, 0, 1], vec![0, 1, 0], vec![1, 0, 0]]
        );
        // C(6,3) = 20 merges of two length-3 sequences.
        assert_eq!(interleavings(&[3, 3]).len(), 20);
        // Each schedule uses every element of every sequence exactly once.
        for sched in interleavings(&[3, 3]) {
            assert_eq!(sched.iter().filter(|&&t| t == 0).count(), 3);
            assert_eq!(sched.iter().filter(|&&t| t == 1).count(), 3);
        }
        assert_eq!(interleavings(&[]), vec![Vec::<usize>::new()]);
    }
}
