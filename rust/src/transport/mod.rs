//! Transport layer: the in-process stand-in for NCCL point-to-point
//! transfers (DESIGN.md §Model scale substitution).
//!
//! Messages move over `std::sync::mpsc` channels between pipeline node
//! threads. Every link carries a (latency, bandwidth) cost model so the
//! engine can account the *modeled* wire time of each transfer in its
//! metrics without sleeping on the real path; the cluster simulator uses
//! the same [`LinkModel`] numbers for paper-scale runs.
//!
//! Transfers are admitted through the central scheduler
//! ([`crate::schedule::CentralScheduler`]) so the endpoint-conflict
//! discipline of Appendix A governs the real engine too.

use crate::concurrency::sync::mpsc::{channel, Receiver, Sender};

/// Cost model of one directed link.
#[derive(Debug, Clone, Copy)]
pub struct LinkModel {
    /// One-way latency in seconds.
    pub latency_s: f64,
    /// Bandwidth in bytes/second.
    pub bandwidth_bps: f64,
}

impl LinkModel {
    /// 10 Gbps Ethernet with typical small-cluster latency (the paper's
    /// inter-server fabric).
    pub fn ethernet_10g() -> Self {
        Self {
            latency_s: 100e-6,
            bandwidth_bps: 10e9 / 8.0,
        }
    }

    /// PCIe 4.0 x16 peer-to-peer (intra-server GPU pairs).
    pub fn pcie_p2p() -> Self {
        Self {
            latency_s: 5e-6,
            bandwidth_bps: 25e9,
        }
    }

    /// Modeled wire time for a payload.
    pub fn transfer_time(&self, bytes: usize) -> f64 {
        self.latency_s + bytes as f64 / self.bandwidth_bps
    }
}

/// A typed duplex mailbox pair for one pipeline edge.
pub struct Mailbox<T> {
    pub tx: Sender<T>,
    pub rx: Receiver<T>,
}

/// Build the chain of channels for an n+1-node pipeline (rank 0 = draft,
/// ranks 1..=n = stages): returns per-rank (incoming receiver, outgoing
/// sender to rank+1). The last rank's outgoing sender loops back to rank 0
/// conceptually; here it reports to the engine instead, so `senders[n]` is
/// None.
pub struct PipelineChannels<T> {
    pub incoming: Vec<Option<Receiver<T>>>,
    pub outgoing: Vec<Option<Sender<T>>>,
}

pub fn pipeline_channels<T>(n_ranks: usize) -> PipelineChannels<T> {
    let mut incoming: Vec<Option<Receiver<T>>> = (0..n_ranks).map(|_| None).collect();
    let mut outgoing: Vec<Option<Sender<T>>> = (0..n_ranks).map(|_| None).collect();
    for rank in 0..n_ranks.saturating_sub(1) {
        let (tx, rx) = channel::<T>();
        outgoing[rank] = Some(tx);
        incoming[rank + 1] = Some(rx);
    }
    PipelineChannels { incoming, outgoing }
}

/// Per-link transfer accounting: modeled seconds and bytes moved.
#[derive(Debug, Default, Clone)]
pub struct LinkStats {
    pub transfers: u64,
    pub bytes: u64,
    pub modeled_seconds: f64,
}

impl LinkStats {
    pub fn record(&mut self, bytes: usize, model: &LinkModel) {
        self.transfers += 1;
        self.bytes += bytes as u64;
        self.modeled_seconds += model.transfer_time(bytes);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn link_time_scales_with_bytes() {
        let l = LinkModel::ethernet_10g();
        let t1 = l.transfer_time(1_000);
        let t2 = l.transfer_time(10_000_000);
        assert!(t2 > t1);
        // 10 MB over 1.25 GB/s ~ 8 ms
        assert!((t2 - (100e-6 + 0.008)).abs() < 1e-4);
    }

    #[test]
    fn pcie_faster_than_ethernet() {
        let bytes = 1 << 20;
        assert!(
            LinkModel::pcie_p2p().transfer_time(bytes)
                < LinkModel::ethernet_10g().transfer_time(bytes)
        );
    }

    #[test]
    fn channels_form_a_chain() {
        let chans = pipeline_channels::<u32>(4);
        assert!(chans.outgoing[0].is_some());
        assert!(chans.incoming[0].is_none());
        assert!(chans.outgoing[3].is_none());
        assert!(chans.incoming[3].is_some());
        chans.outgoing[0].as_ref().unwrap().send(7).unwrap();
        assert_eq!(chans.incoming[1].as_ref().unwrap().recv().unwrap(), 7);
    }

    #[test]
    fn stats_accumulate() {
        let mut st = LinkStats::default();
        let l = LinkModel::pcie_p2p();
        st.record(100, &l);
        st.record(200, &l);
        assert_eq!(st.transfers, 2);
        assert_eq!(st.bytes, 300);
        assert!(st.modeled_seconds > 0.0);
    }
}
