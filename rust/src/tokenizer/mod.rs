//! Byte-level tokenizer, mirror of `python/compile/tokenizer.py`.
//!
//! ids: 0=PAD 1=BOS 2=EOS 3='\n', 4..=98 map printable ASCII 32..=126.
//! Characters outside the alphabet encode as ' '.

pub const PAD_ID: u32 = 0;
pub const BOS_ID: u32 = 1;
pub const EOS_ID: u32 = 2;
pub const NEWLINE_ID: u32 = 3;
pub const VOCAB_SIZE: usize = 128;

const OFFSET: u32 = 4;
const FIRST: u32 = 32;
const LAST: u32 = 126;

/// Encode text to token ids.
pub fn encode(text: &str) -> Vec<u32> {
    text.chars()
        .map(|ch| {
            if ch == '\n' {
                NEWLINE_ID
            } else {
                let o = ch as u32;
                if (FIRST..=LAST).contains(&o) {
                    o - FIRST + OFFSET
                } else {
                    b' ' as u32 - FIRST + OFFSET
                }
            }
        })
        .collect()
}

/// Encode with optional BOS/EOS wrapping.
pub fn encode_with(text: &str, bos: bool, eos: bool) -> Vec<u32> {
    let mut out = Vec::with_capacity(text.len() + 2);
    if bos {
        out.push(BOS_ID);
    }
    out.extend(encode(text));
    if eos {
        out.push(EOS_ID);
    }
    out
}

/// Decode ids back to text (control ids other than newline are dropped).
pub fn decode(ids: &[u32]) -> String {
    let mut s = String::with_capacity(ids.len());
    for &id in ids {
        if id == NEWLINE_ID {
            s.push('\n');
        } else if id >= OFFSET && id < OFFSET + (LAST - FIRST + 1) {
            s.push(char::from_u32(id - OFFSET + FIRST).unwrap());
        }
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_ascii() {
        let text = "hello, world! 123\nsecond line ~";
        assert_eq!(decode(&encode(text)), text);
    }

    #[test]
    fn non_ascii_maps_to_space() {
        assert_eq!(decode(&encode("a\u{00e9}b")), "a b");
    }

    #[test]
    fn bos_eos_wrapping() {
        let ids = encode_with("x", true, true);
        assert_eq!(ids[0], BOS_ID);
        assert_eq!(*ids.last().unwrap(), EOS_ID);
        assert_eq!(decode(&ids), "x");
    }

    #[test]
    fn ids_in_vocab() {
        for id in encode("The AI ~!") {
            assert!((id as usize) < VOCAB_SIZE);
        }
    }
}
