//! Persistent pipeline worker pool (ISSUE 4 tentpole): real threads for
//! the per-timestep task set, so wall-clock approaches the paper's modeled
//! parallel-schedule latency `max(T_draft, max_i(T_group_i) + max_i(T_t,i))`
//! instead of the sequential sum the single-threaded engines pay.
//!
//! # Execution model
//!
//! A timestep's task set is one [`DraftJob`] (the draft node: entry grant
//! or one tree expansion) plus one [`StageJob`] per occupied timestep group
//! (the group's member stages run sequentially inside the job, exactly as
//! in the paper's §3.1 grouping). Tasks of one timestep are mutually
//! independent by construction:
//!
//! * stage jobs *read* an immutable `Arc<TreeSnapshot>` of the owning
//!   request's prediction tree (one snapshot per request per timestep);
//!   the draft job takes the canonical tree by move, mutates it, and the
//!   coordinator adopts it back. Appending a BFS layer never changes
//!   the indices, ancestor masks, or positions of existing nodes, so a
//!   stage pass over the pre-expansion snapshot is bit-identical to the
//!   sequential engine's pass over the post-expansion tree;
//! * jobs carry the deferred [`CacheCommit`]s their lent caches have not
//!   applied yet (ISSUE 5) and drain them *before* any forward pass, so
//!   the previous timestep's cache maintenance executes on the owning
//!   worker concurrently with the rest of this timestep's compute instead
//!   of serializing at the coordinator; `commit_target` asserts no task
//!   ever runs a cache that lags the issued commit sequence;
//! * every job *owns* its mutable state while it runs: the member stages'
//!   KV caches and the group's [`StageContext`] (device KV mirrors +
//!   incremental bias) move into the job through the channel and move
//!   back in the [`StageDone`] / [`DraftDone`] reply — no locks, no
//!   sharing;
//! * the shared model ([`ModelCore`]) and the PJRT [`Runtime`] are
//!   read-only and `Send + Sync` (see the audit in `crate::runtime`).
//!
//! The coordinator blocks on the full reply set each timestep (the sync
//! phase needs every cache back), then does transfer accounting, the
//! latency model, and verification alone — those are host-math
//! microseconds. Because all model math is in the jobs and the reply
//! order is normalized by group index, **threaded decode is
//! token-identical to sequential decode by construction**; with
//! `threads = 1` the engines skip the pool and run the same jobs inline
//! ([`run_inline`]), which is the reference path.
//!
//! Model-level failures travel back as `Result`s inside the replies. A
//! *panic* inside a task is caught on the worker, reported as a
//! [`Done`]-level reply, and re-raised as a panic on the coordinator once
//! the rest of the timestep's replies have drained — matching the inline
//! path's panic semantics instead of deadlocking the reply loop (the
//! panicked task's lent state is lost, so the engine is poisoned, exactly
//! as it would be mid-panic single-threaded).
//!
//! Worker-side timings land in a thread-safe
//! [`crate::metrics::SharedMetrics`] carried by each job, so workers
//! record without funneling through the coordinator.

use std::time::Instant;

use anyhow::Result;

use super::pipeline::{self, DataFlow};
use crate::concurrency::protocol::verify_drained;
use crate::concurrency::sync::mpsc::{channel, Receiver, Sender};
use crate::concurrency::sync::Arc;
use crate::concurrency::thread::{Builder, JoinHandle};
use crate::kvcache::{CacheCommit, TwoLevelCache};
use crate::metrics::SharedMetrics;
use crate::model::{ModelCore, StageContext};
use crate::runtime::Runtime;
use crate::tree::{PredictionTree, TreeSnapshot};

/// One timestep group's task: run the incoming flow through the group's
/// member stages (span order). State fields move in and move back out via
/// [`StageDone`].
pub struct StageJob {
    /// Timestep group index (reply routing + deterministic post-order).
    pub group: usize,
    pub core: Arc<ModelCore>,
    pub ctx: StageContext,
    /// Member stages' KV caches, in span order.
    pub caches: Vec<TwoLevelCache>,
    /// Member stages' layer spans, in span order (same length as `caches`).
    pub layer_ranges: Vec<std::ops::Range<usize>>,
    /// Global stage index of each member (intra-group hop endpoints).
    pub stage_ids: Vec<usize>,
    /// Deferred sync commits the member caches have not applied yet
    /// (oldest first, ISSUE 5); applied via
    /// [`StageContext::apply_commit`] *before* the member stages run, so
    /// this timestep's compute on other workers overlaps the previous
    /// sync's cache maintenance. Empty on the serial-sync path.
    pub commits: Vec<CacheCommit>,
    /// Commit epoch every member cache must sit at after applying
    /// `commits` — the staleness guard: a task never runs a cache that
    /// lags the coordinator's issued commit sequence.
    pub commit_target: u64,
    pub df: DataFlow,
    /// Read snapshot of the owning request's tree — `Arc`, because every
    /// occupied slot of one request shares the same immutable snapshot
    /// (the draft task gets the owned canonical tree to mutate).
    pub tree: Arc<TreeSnapshot>,
    pub metrics: Arc<SharedMetrics>,
}

/// What a [`StageJob`] computed (state first — it must come home even when
/// the forward pass failed).
pub struct StageDone {
    pub group: usize,
    pub ctx: StageContext,
    pub caches: Vec<TwoLevelCache>,
    /// Seconds this job spent applying deferred sync commits before its
    /// forward (0 when none were pending) — reply-side, so the
    /// coordinator can attribute commit time to the owning request
    /// precisely instead of batch-wide.
    pub commit_s: f64,
    pub res: Result<GroupOutcome>,
}

/// Successful result of one group task.
pub struct GroupOutcome {
    /// Outgoing flow (`None` when every row was pruned away in flight).
    pub flow: Option<DataFlow>,
    /// Sum of the member stages' measured compute seconds.
    pub compute_s: f64,
    /// Intra-group hop endpoints `(src, dst)` the coordinator must account
    /// through the central scheduler (same timestep, same group).
    pub hops: Vec<(usize, usize)>,
}

/// One session's claim on the draft node this timestep, visited in the
/// engine's round-robin order.
pub struct DraftCandidate {
    /// Caller-defined tag identifying the owner (live index for
    /// SpecPipe-DB, 0 for the solo engine).
    pub tag: usize,
    /// Pending root flow (fresh admission / miss restart) — granted as-is,
    /// without draft compute.
    pub entry: Option<DataFlow>,
    /// The owner's canonical tree, taken by move (a placeholder stands in
    /// at the owner); the visited candidate's tree is expanded in place
    /// and every tree is adopted back from [`DraftDone`].
    pub tree: PredictionTree,
    /// The owner's draft KV cache.
    pub cache: TwoLevelCache,
    /// Deferred sync commits the draft cache has not applied yet (oldest
    /// first); applied before any expansion of this candidate's tree.
    pub commits: Vec<CacheCommit>,
    /// Commit epoch the draft cache must sit at after applying `commits`.
    pub commit_target: u64,
    /// Reply-side: seconds spent applying this candidate's deferred
    /// commits (dispatched as 0, filled in by [`exec_draft_job`]).
    pub commit_s: f64,
}

/// The draft node's task: grant pipeline slot 0 to the first candidate
/// with a pending entry flow or a successful tree expansion.
pub struct DraftJob {
    pub core: Arc<ModelCore>,
    pub ctx: StageContext,
    pub candidates: Vec<DraftCandidate>,
    pub max_children: usize,
    pub metrics: Arc<SharedMetrics>,
}

/// Reply to a [`DraftJob`]; candidates come back in submission order with
/// their (possibly expanded) trees and mutated caches.
pub struct DraftDone {
    pub ctx: StageContext,
    pub candidates: Vec<DraftCandidate>,
    pub res: Result<DraftOutcome>,
}

/// Successful result of the draft task.
pub struct DraftOutcome {
    /// `(candidate tag, flow)` granted pipeline slot 0, if any.
    pub granted: Option<(usize, DataFlow)>,
    /// Total measured draft compute seconds across visited candidates.
    pub draft_s: f64,
}

/// Apply a job's pending sync commits to its lent caches (in issue
/// order, every cache per commit), then assert every cache reached the
/// coordinator's issued epoch — the "never run against a stale tree"
/// guard. Returns the seconds spent applying (0 when nothing was
/// pending); the caller ships them home in the reply so the coordinator
/// attributes commit time to the owning request precisely.
fn apply_job_commits(
    rt: &Runtime,
    core: &ModelCore,
    ctx: &mut StageContext,
    caches: &mut [TwoLevelCache],
    commits: &[CacheCommit],
    target: u64,
    metrics: &SharedMetrics,
) -> Result<f64> {
    let mut secs = 0.0;
    if !commits.is_empty() {
        let t0 = Instant::now();
        for commit in commits {
            for cache in caches.iter_mut() {
                ctx.apply_commit(rt, core, cache, commit)?;
            }
        }
        secs = t0.elapsed().as_secs_f64();
        metrics.incr("commit_ops", (commits.len() * caches.len()) as u64);
    }
    for cache in caches.iter() {
        // The "never run against a stale tree" guard, shared with the
        // model checker (see concurrency::protocol).
        verify_drained(cache.commit_epoch(), target)?;
    }
    Ok(secs)
}

/// Execute one group task (worker thread or inline reference path):
/// drain the group's deferred sync commits, then run the member stages.
pub fn exec_stage_job(rt: &Runtime, mut job: StageJob) -> StageDone {
    debug_assert_eq!(job.caches.len(), job.layer_ranges.len());
    let n = job.caches.len();
    let mut compute_s = 0.0f64;
    let mut hops = Vec::new();
    let mut commit_s = 0.0f64;
    let mut err = None;
    match apply_job_commits(
        rt,
        &job.core,
        &mut job.ctx,
        &mut job.caches,
        &job.commits,
        job.commit_target,
        &job.metrics,
    ) {
        Ok(secs) => commit_s = secs,
        Err(e) => err = Some(e),
    }
    let mut df = if err.is_none() { Some(job.df) } else { None };
    for k in 0..n {
        let Some(cur) = df.take() else { break };
        match pipeline::run_stage(
            &job.core,
            rt,
            &mut job.ctx,
            job.layer_ranges[k].clone(),
            &mut job.caches[k],
            cur,
            &job.tree,
        ) {
            Ok((out, secs)) => {
                compute_s += secs;
                if out.is_some() && k + 1 < n {
                    // intra-group hop: same timestep, scheduled transfer
                    hops.push((job.stage_ids[k] + 1, job.stage_ids[k] + 2));
                }
                df = out;
            }
            Err(e) => {
                err = Some(e);
                df = None;
                break;
            }
        }
    }
    job.metrics.incr("worker_stage_tasks", 1);
    job.metrics.record("worker_group_s", compute_s);
    StageDone {
        group: job.group,
        ctx: job.ctx,
        caches: job.caches,
        commit_s,
        res: match err {
            None => Ok(GroupOutcome {
                flow: df,
                compute_s,
                hops,
            }),
            Some(e) => Err(e),
        },
    }
}

/// Execute the draft task (worker thread or inline reference path):
/// visit candidates in order, grant slot 0 to the first pending entry
/// flow or successful expansion — the same loop both engines ran
/// sequentially.
pub fn exec_draft_job(rt: &Runtime, mut job: DraftJob) -> DraftDone {
    let mut draft_s = 0.0f64;
    let mut granted = None;
    let mut err = None;
    // Drain every candidate's deferred commits first — a visited
    // candidate's expansion must see its post-sync draft cache, and
    // applying the unvisited candidates' commits early is harmless (the
    // commits touch only that session's draft cache).
    for cand in job.candidates.iter_mut() {
        match apply_job_commits(
            rt,
            &job.core,
            &mut job.ctx,
            std::slice::from_mut(&mut cand.cache),
            &cand.commits,
            cand.commit_target,
            &job.metrics,
        ) {
            Ok(secs) => cand.commit_s = secs,
            Err(e) => {
                err = Some(e);
                break;
            }
        }
    }
    for cand in job.candidates.iter_mut() {
        if err.is_some() {
            break;
        }
        if let Some(df) = cand.entry.take() {
            granted = Some((cand.tag, df));
            break;
        }
        match pipeline::draft_expand(
            &job.core,
            rt,
            &mut job.ctx,
            &mut cand.cache,
            &mut cand.tree,
            job.max_children,
        ) {
            Ok((flow, secs)) => {
                draft_s += secs;
                if let Some(df) = flow {
                    granted = Some((cand.tag, df));
                    break;
                }
            }
            Err(e) => {
                err = Some(e);
                break;
            }
        }
    }
    job.metrics.incr("worker_draft_tasks", 1);
    job.metrics.record("worker_draft_s", draft_s);
    DraftDone {
        ctx: job.ctx,
        candidates: job.candidates,
        res: match err {
            None => Ok(DraftOutcome { granted, draft_s }),
            Some(e) => Err(e),
        },
    }
}

/// Reference path (`threads = 1`): execute the timestep's task set on the
/// caller thread, draft first — byte-identical results to the pool, same
/// job plumbing, zero concurrency.
pub fn run_inline(
    rt: &Runtime,
    draft: DraftJob,
    stages: Vec<StageJob>,
) -> (DraftDone, Vec<StageDone>) {
    let d = exec_draft_job(rt, draft);
    let s = stages.into_iter().map(|j| exec_stage_job(rt, j)).collect();
    (d, s)
}

/// Execute a timestep's task set on the pool when one exists, inline
/// otherwise — the single dispatch seam both engines go through.
pub fn run_tasks(
    pool: Option<&WorkerPool>,
    rt: &Runtime,
    draft: DraftJob,
    stages: Vec<StageJob>,
) -> (DraftDone, Vec<StageDone>) {
    match pool {
        Some(pool) => pool.run_timestep(draft, stages),
        None => run_inline(rt, draft, stages),
    }
}

/// Reabsorb stage replies: hand each reply's lent state (plus its
/// measured deferred-commit seconds) to `restore` *before* looking at its
/// result — the invariant that keeps a failed decode from stranding
/// caches/contexts — and collect the outcomes in group order plus the
/// first task error, if any.
pub fn absorb_stage_dones(
    groups: usize,
    dones: Vec<StageDone>,
    mut restore: impl FnMut(usize, StageContext, Vec<TwoLevelCache>, f64),
) -> (Vec<Option<GroupOutcome>>, Option<anyhow::Error>) {
    let mut outcomes: Vec<Option<GroupOutcome>> = (0..groups).map(|_| None).collect();
    let mut first_err = None;
    for done in dones {
        restore(done.group, done.ctx, done.caches, done.commit_s);
        match done.res {
            Ok(oc) => outcomes[done.group] = Some(oc),
            Err(e) => first_err = first_err.or(Some(e)),
        }
    }
    (outcomes, first_err)
}

/// Final step of reabsorbing a timestep: combine the draft reply's result
/// with any stage-side error (stage errors win — they were dispatched
/// first), yielding the draft outcome only when every task succeeded.
/// Callers restore all lent state *before* calling this.
pub fn finish_absorb(
    draft_res: Result<DraftOutcome>,
    stage_err: Option<anyhow::Error>,
) -> Result<DraftOutcome> {
    match stage_err {
        Some(e) => Err(e),
        None => draft_res,
    }
}

enum Job {
    Stage(StageJob),
    Draft(DraftJob),
}

enum Done {
    Stage(StageDone),
    Draft(DraftDone),
    /// A task panicked on the worker; carries the panic payload text. The
    /// coordinator re-raises it after draining the timestep's replies.
    Panicked(String),
}

fn panic_message(payload: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".to_string()
    }
}

/// The persistent pool: one thread per pipeline worker, fed over
/// per-worker channels, replying on one shared channel. The draft task is
/// pinned to the last worker; stage tasks round-robin over the rest in
/// dispatch order, so with `workers >= groups + 1` every task of a
/// timestep runs on its own thread (the paper's one-device-per-node
/// deployment) and no stage worker queues two tasks while another idles.
pub struct WorkerPool {
    txs: Vec<Sender<Job>>,
    rx: Receiver<Done>,
    handles: Vec<JoinHandle<()>>,
}

impl WorkerPool {
    pub fn new(workers: usize, rt: Arc<Runtime>) -> Result<Self> {
        anyhow::ensure!(workers >= 1, "worker pool needs >= 1 worker");
        let (done_tx, done_rx) = channel::<Done>();
        let mut txs = Vec::with_capacity(workers);
        let mut handles = Vec::with_capacity(workers);
        for i in 0..workers {
            let (tx, rx) = channel::<Job>();
            let done_tx = done_tx.clone();
            let rt = Arc::clone(&rt);
            let handle = Builder::new()
                .name(format!("pipedec-worker-{i}"))
                .spawn(move || {
                    while let Ok(job) = rx.recv() {
                        // Contain task panics: the coordinator counts on one
                        // reply per job, so a panicking task must still
                        // answer or the reply loop would block forever.
                        let done = std::panic::catch_unwind(
                            std::panic::AssertUnwindSafe(|| match job {
                                Job::Stage(j) => Done::Stage(exec_stage_job(&rt, j)),
                                Job::Draft(j) => Done::Draft(exec_draft_job(&rt, j)),
                            }),
                        )
                        .unwrap_or_else(|p| Done::Panicked(panic_message(p.as_ref())));
                        if done_tx.send(done).is_err() {
                            break; // pool dropped
                        }
                    }
                })?;
            txs.push(tx);
            handles.push(handle);
        }
        Ok(Self {
            txs,
            rx: done_rx,
            handles,
        })
    }

    pub fn workers(&self) -> usize {
        self.txs.len()
    }

    /// Dispatch one timestep's task set and block until every task
    /// replied. Panics only if a worker thread died (a worker never
    /// panics on model errors — those come back in `res`).
    pub fn run_timestep(
        &self,
        draft: DraftJob,
        stages: Vec<StageJob>,
    ) -> (DraftDone, Vec<StageDone>) {
        let n = self.txs.len();
        let mut sent = 1usize;
        self.txs[n - 1]
            .send(Job::Draft(draft))
            .expect("pipeline worker exited");
        // round-robin over *dispatched* tasks (not group ids): with sparse
        // occupancy, assigning by group id would pile same-residue groups
        // onto one worker while others idle
        let stage_workers = (n - 1).max(1);
        for (i, job) in stages.into_iter().enumerate() {
            let w = if n == 1 { 0 } else { i % stage_workers };
            self.txs[w]
                .send(Job::Stage(job))
                .expect("pipeline worker exited");
            sent += 1;
        }
        let mut draft_done = None;
        let mut stage_dones = Vec::with_capacity(sent - 1);
        let mut panicked: Option<String> = None;
        for _ in 0..sent {
            match self.rx.recv().expect("pipeline worker exited") {
                Done::Draft(d) => draft_done = Some(d),
                Done::Stage(s) => stage_dones.push(s),
                Done::Panicked(msg) => panicked = Some(msg),
            }
        }
        if let Some(msg) = panicked {
            // mirror the inline path: a panicking task panics the decode
            // (after draining every reply, so no worker is left mid-send)
            panic!("pipeline worker task panicked: {msg}");
        }
        (
            draft_done.expect("draft task is always dispatched"),
            stage_dones,
        )
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        self.txs.clear(); // close the job channels; workers drain and exit
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}
