//! Persistent pipeline worker pool (ISSUE 4 tentpole): real threads for
//! the per-timestep task set, so wall-clock approaches the paper's modeled
//! parallel-schedule latency `max(T_draft, max_i(T_group_i) + max_i(T_t,i))`
//! instead of the sequential sum the single-threaded engines pay.
//!
//! # Execution model
//!
//! A timestep's task set is one [`DraftJob`] (the draft node: entry grant
//! or one tree expansion) plus one [`StageJob`] per occupied timestep group
//! (the group's member stages run sequentially inside the job, exactly as
//! in the paper's §3.1 grouping). Tasks of one timestep are mutually
//! independent by construction:
//!
//! * stage jobs *read* an immutable `Arc<TreeSnapshot>` of the owning
//!   request's prediction tree (one snapshot per request per timestep);
//!   the draft job takes the canonical tree by move, mutates it, and the
//!   coordinator adopts it back. Appending a BFS layer never changes
//!   the indices, ancestor masks, or positions of existing nodes, so a
//!   stage pass over the pre-expansion snapshot is bit-identical to the
//!   sequential engine's pass over the post-expansion tree;
//! * jobs carry the deferred [`CacheCommit`]s their lent caches have not
//!   applied yet (ISSUE 5) and drain them *before* any forward pass, so
//!   the previous timestep's cache maintenance executes on the owning
//!   worker concurrently with the rest of this timestep's compute instead
//!   of serializing at the coordinator; `commit_target` asserts no task
//!   ever runs a cache that lags the issued commit sequence;
//! * every job *owns* its mutable state while it runs: the member stages'
//!   KV caches and the group's [`StageContext`] (device KV mirrors +
//!   incremental bias) move into the job through the channel and move
//!   back in the [`StageDone`] / [`DraftDone`] reply — no locks, no
//!   sharing;
//! * the shared model ([`ModelCore`]) and the PJRT [`Runtime`] are
//!   read-only and `Send + Sync` (see the audit in `crate::runtime`).
//!
//! The coordinator blocks on the full reply set each timestep (the sync
//! phase needs every cache back), then does transfer accounting, the
//! latency model, and verification alone — those are host-math
//! microseconds. Because all model math is in the jobs and the reply
//! order is normalized by group index, **threaded decode is
//! token-identical to sequential decode by construction**; with
//! `threads = 1` the engines skip the pool and run the same jobs inline
//! ([`run_inline`]), which is the reference path.
//!
//! # Failure domains (ISSUE 9)
//!
//! Model-level failures travel back as `Result`s inside the replies,
//! state-first: a failed task's lent caches and context still come home.
//! A *panic* inside a task is caught on the worker and comes back as a
//! [`StageReply::Lost`] / [`DraftReply::Lost`] for just that job — the
//! lent state died with the task, so the coordinator rebuilds the
//! group's [`StageContext`] from host truth (a fresh context re-uploads
//! lazily via the device mirror's full re-upload fallback) and fails
//! only the session(s) whose state was in the job; co-scheduled sessions
//! continue untouched. A worker thread that dies *between* jobs
//! announces its exit on the reply channel (a drop guard, so abrupt
//! deaths announce too); the coordinator flushes that worker's in-flight
//! jobs as `Lost` instead of blocking forever, and respawns the worker
//! at its next dispatch. The inline path wraps job execution in the same
//! panic catch, so no panic escapes the engine at any thread count.
//! The named fault-injection choke points ([`crate::faultinject::Site`]:
//! `stage_job`, `draft_job`, `worker_exit`) let the chaos suite drive
//! every one of these paths deterministically.
//!
//! Worker-side timings land in a thread-safe
//! [`crate::metrics::SharedMetrics`] carried by each job, so workers
//! record without funneling through the coordinator.

use std::time::Instant;

use anyhow::Result;

use super::pipeline::{self, DataFlow};
use super::spec::{SpecEpoch, SpecExpansion};
use crate::concurrency::protocol::verify_drained;
use crate::faultinject::{self, Site};
use crate::concurrency::sync::mpsc::{channel, Receiver, Sender};
use crate::concurrency::sync::Arc;
use crate::concurrency::thread::{Builder, JoinHandle};
use crate::kvcache::{CacheCommit, TwoLevelCache};
use crate::metrics::SharedMetrics;
use crate::model::{ModelCore, StageContext};
use crate::runtime::Runtime;
use crate::tree::{PredictionTree, TreeSnapshot};

/// One timestep group's task: run the incoming flow through the group's
/// member stages (span order). State fields move in and move back out via
/// [`StageDone`].
pub struct StageJob {
    /// Timestep group index (reply routing + deterministic post-order).
    pub group: usize,
    pub core: Arc<ModelCore>,
    pub ctx: StageContext,
    /// Member stages' KV caches, in span order.
    pub caches: Vec<TwoLevelCache>,
    /// Member stages' layer spans, in span order (same length as `caches`).
    pub layer_ranges: Vec<std::ops::Range<usize>>,
    /// Global stage index of each member (intra-group hop endpoints).
    pub stage_ids: Vec<usize>,
    /// Deferred sync commits the member caches have not applied yet
    /// (oldest first, ISSUE 5); applied via
    /// [`StageContext::apply_commit`] *before* the member stages run, so
    /// this timestep's compute on other workers overlaps the previous
    /// sync's cache maintenance. Empty on the serial-sync path.
    pub commits: Vec<CacheCommit>,
    /// Commit epoch every member cache must sit at after applying
    /// `commits` — the staleness guard: a task never runs a cache that
    /// lags the coordinator's issued commit sequence.
    pub commit_target: u64,
    pub df: DataFlow,
    /// Read snapshot of the owning request's tree — `Arc`, because every
    /// occupied slot of one request shares the same immutable snapshot
    /// (the draft task gets the owned canonical tree to mutate).
    pub tree: Arc<TreeSnapshot>,
    pub metrics: Arc<SharedMetrics>,
}

/// What a [`StageJob`] computed (state first — it must come home even when
/// the forward pass failed).
pub struct StageDone {
    pub group: usize,
    pub ctx: StageContext,
    pub caches: Vec<TwoLevelCache>,
    /// Seconds this job spent applying deferred sync commits before its
    /// forward (0 when none were pending) — reply-side, so the
    /// coordinator can attribute commit time to the owning request
    /// precisely instead of batch-wide.
    pub commit_s: f64,
    pub res: Result<GroupOutcome>,
}

/// Successful result of one group task.
pub struct GroupOutcome {
    /// Outgoing flow (`None` when every row was pruned away in flight).
    pub flow: Option<DataFlow>,
    /// Sum of the member stages' measured compute seconds.
    pub compute_s: f64,
    /// Intra-group hop endpoints `(src, dst)` the coordinator must account
    /// through the central scheduler (same timestep, same group).
    pub hops: Vec<(usize, usize)>,
}

/// One session's claim on the draft node this timestep, visited in the
/// engine's round-robin order.
pub struct DraftCandidate {
    /// Caller-defined tag identifying the owner (live index for
    /// SpecPipe-DB, 0 for the solo engine).
    pub tag: usize,
    /// Pending root flow (fresh admission / miss restart) — granted as-is,
    /// without draft compute.
    pub entry: Option<DataFlow>,
    /// The owner's canonical tree, taken by move (a placeholder stands in
    /// at the owner); the visited candidate's tree is expanded in place
    /// and every tree is adopted back from [`DraftDone`].
    pub tree: PredictionTree,
    /// The owner's draft KV cache.
    pub cache: TwoLevelCache,
    /// Deferred sync commits the draft cache has not applied yet (oldest
    /// first); applied before any expansion of this candidate's tree.
    pub commits: Vec<CacheCommit>,
    /// Commit epoch the draft cache must sit at after applying `commits`.
    pub commit_target: u64,
    /// Reply-side: seconds spent applying this candidate's deferred
    /// commits (dispatched as 0, filled in by [`exec_draft_job`]).
    pub commit_s: f64,
    /// Total generations this candidate may produce (ISSUE 10): 1 =
    /// lockstep (the in-step expansion only); `K > 1` lets the draft
    /// free-run `K - 1` further generations after a successful
    /// expansion grant.
    pub spec_gens: usize,
    /// The [`SpecEpoch`] the owner's bank was at when this job was
    /// dispatched — stamped onto every speculative generation.
    pub spec_epoch: SpecEpoch,
    /// Reply-side: the free-running generations the draft banked for
    /// this candidate (dispatched empty, filled in by
    /// [`exec_draft_job`]).
    pub spec: Vec<SpecExpansion>,
}

/// The draft node's task: grant pipeline slot 0 to the first candidate
/// with a pending entry flow or a successful tree expansion.
pub struct DraftJob {
    pub core: Arc<ModelCore>,
    pub ctx: StageContext,
    pub candidates: Vec<DraftCandidate>,
    pub max_children: usize,
    pub metrics: Arc<SharedMetrics>,
}

/// Reply to a [`DraftJob`]; candidates come back in submission order with
/// their (possibly expanded) trees and mutated caches.
pub struct DraftDone {
    pub ctx: StageContext,
    pub candidates: Vec<DraftCandidate>,
    pub res: Result<DraftOutcome>,
    /// When `res` is an error: the tag of the candidate being processed
    /// when it struck, so the scheduler can fail only that session (its
    /// draft cache may be mid-mutation). `None` means no candidate's
    /// state was touched — the error is benign to every session.
    pub failed_tag: Option<usize>,
}

/// A stage task's reply, or the news that the task died with its lent
/// state (worker panic / thread death) and the group context must be
/// rebuilt from host truth.
pub enum StageReply {
    Done(StageDone),
    Lost { group: usize, reason: String },
}

/// The draft task's reply, or the news that it died with every dispatched
/// candidate's tree and draft cache.
pub enum DraftReply {
    Done(DraftDone),
    Lost { reason: String },
}

/// One group task that failed, as digested by [`absorb_stage_dones`].
pub struct StageFailure {
    pub group: usize,
    pub reason: String,
    /// True when the group's lent state (context + member caches) was
    /// destroyed with the job and must be rebuilt from host truth; false
    /// when the state came home in an error reply.
    pub state_lost: bool,
}

/// Successful result of the draft task.
pub struct DraftOutcome {
    /// `(candidate tag, flow)` granted pipeline slot 0, if any.
    pub granted: Option<(usize, DataFlow)>,
    /// Total measured draft compute seconds across visited candidates.
    pub draft_s: f64,
}

/// Apply a job's pending sync commits to its lent caches (in issue
/// order, every cache per commit), then assert every cache reached the
/// coordinator's issued epoch — the "never run against a stale tree"
/// guard. Returns the seconds spent applying (0 when nothing was
/// pending); the caller ships them home in the reply so the coordinator
/// attributes commit time to the owning request precisely.
fn apply_job_commits(
    rt: &Runtime,
    core: &ModelCore,
    ctx: &mut StageContext,
    caches: &mut [TwoLevelCache],
    commits: &[CacheCommit],
    target: u64,
    metrics: &SharedMetrics,
) -> Result<f64> {
    let mut secs = 0.0;
    if !commits.is_empty() {
        let t0 = Instant::now();
        for commit in commits {
            for cache in caches.iter_mut() {
                ctx.apply_commit(rt, core, cache, commit)?;
            }
        }
        secs = t0.elapsed().as_secs_f64();
        metrics.incr("commit_ops", (commits.len() * caches.len()) as u64);
    }
    for cache in caches.iter() {
        // The "never run against a stale tree" guard, shared with the
        // model checker (see concurrency::protocol).
        verify_drained(cache.commit_epoch(), target)?;
    }
    Ok(secs)
}

/// Execute one group task (worker thread or inline reference path):
/// drain the group's deferred sync commits, then run the member stages.
pub fn exec_stage_job(rt: &Runtime, mut job: StageJob) -> StageDone {
    debug_assert_eq!(job.caches.len(), job.layer_ranges.len());
    let n = job.caches.len();
    let mut compute_s = 0.0f64;
    let mut hops = Vec::new();
    let mut commit_s = 0.0f64;
    // chaos choke point: fires before any of the job's state is mutated
    let mut err = faultinject::fire(Site::StageJob).err();
    if err.is_none() {
        match apply_job_commits(
            rt,
            &job.core,
            &mut job.ctx,
            &mut job.caches,
            &job.commits,
            job.commit_target,
            &job.metrics,
        ) {
            Ok(secs) => commit_s = secs,
            Err(e) => err = Some(e),
        }
    }
    let mut df = if err.is_none() { Some(job.df) } else { None };
    for k in 0..n {
        let Some(cur) = df.take() else { break };
        match pipeline::run_stage(
            &job.core,
            rt,
            &mut job.ctx,
            job.layer_ranges[k].clone(),
            &mut job.caches[k],
            cur,
            &job.tree,
        ) {
            Ok((out, secs)) => {
                compute_s += secs;
                if out.is_some() && k + 1 < n {
                    // intra-group hop: same timestep, scheduled transfer
                    hops.push((job.stage_ids[k] + 1, job.stage_ids[k] + 2));
                }
                df = out;
            }
            Err(e) => {
                err = Some(e);
                df = None;
                break;
            }
        }
    }
    job.metrics.incr("worker_stage_tasks", 1);
    job.metrics.record("worker_group_s", compute_s);
    StageDone {
        group: job.group,
        ctx: job.ctx,
        caches: job.caches,
        commit_s,
        res: match err {
            None => Ok(GroupOutcome {
                flow: df,
                compute_s,
                hops,
            }),
            Some(e) => Err(e),
        },
    }
}

/// Execute the draft task (worker thread or inline reference path):
/// visit candidates in order, grant slot 0 to the first pending entry
/// flow or successful expansion — the same loop both engines ran
/// sequentially.
pub fn exec_draft_job(rt: &Runtime, mut job: DraftJob) -> DraftDone {
    let mut draft_s = 0.0f64;
    let mut granted = None;
    let mut err = None;
    // Which candidate's state the error struck (its cache/tree may be
    // mid-mutation); errors before any candidate mutation leave it None.
    let mut failed_tag = None;
    // Drain every candidate's deferred commits first — a visited
    // candidate's expansion must see its post-sync draft cache, and
    // applying the unvisited candidates' commits early is harmless (the
    // commits touch only that session's draft cache). A failed drain
    // taints only the owning candidate: later candidates keep their
    // undrained suffix and re-receive it at the next dispatch.
    for cand in job.candidates.iter_mut() {
        match apply_job_commits(
            rt,
            &job.core,
            &mut job.ctx,
            std::slice::from_mut(&mut cand.cache),
            &cand.commits,
            cand.commit_target,
            &job.metrics,
        ) {
            Ok(secs) => cand.commit_s = secs,
            Err(e) => {
                err = Some(e);
                failed_tag = Some(cand.tag);
                break;
            }
        }
    }
    for cand in job.candidates.iter_mut() {
        if err.is_some() {
            break;
        }
        // chaos choke point, per candidate visit so the injected fault is
        // attributable to one session
        if let Err(e) = faultinject::fire(Site::DraftJob) {
            err = Some(e);
            failed_tag = Some(cand.tag);
            break;
        }
        if let Some(df) = cand.entry.take() {
            granted = Some((cand.tag, df));
            break;
        }
        match pipeline::draft_expand(
            &job.core,
            rt,
            &mut job.ctx,
            &mut cand.cache,
            &mut cand.tree,
            job.max_children,
        ) {
            Ok((flow, secs)) => {
                draft_s += secs;
                if let Some(df) = flow {
                    // Free-running speculation (ISSUE 10): with the
                    // expansion granted, the draft's thread would
                    // otherwise idle while the pipeline works — keep
                    // expanding shadow generations for the bank. A
                    // panic here is contained to the owning candidate
                    // (same failure domain as an expansion error):
                    // partial speculation is discarded and only this
                    // session retires.
                    if cand.spec_gens > 1 {
                        match std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                            pipeline::draft_speculate(
                                &job.core,
                                rt,
                                &mut job.ctx,
                                &mut cand.cache,
                                &cand.tree,
                                job.max_children,
                                cand.spec_epoch,
                                cand.spec_gens - 1,
                            )
                        })) {
                            Ok(Ok((exps, secs))) => {
                                cand.spec = exps;
                                job.metrics.record("worker_spec_s", secs);
                            }
                            Ok(Err(e)) => {
                                err = Some(e);
                                failed_tag = Some(cand.tag);
                                break;
                            }
                            Err(p) => {
                                err = Some(anyhow::anyhow!(
                                    "draft speculation panicked: {}",
                                    panic_message(p.as_ref())
                                ));
                                failed_tag = Some(cand.tag);
                                break;
                            }
                        }
                    }
                    granted = Some((cand.tag, df));
                    break;
                }
            }
            Err(e) => {
                err = Some(e);
                failed_tag = Some(cand.tag);
                break;
            }
        }
    }
    job.metrics.incr("worker_draft_tasks", 1);
    job.metrics.record("worker_draft_s", draft_s);
    DraftDone {
        ctx: job.ctx,
        candidates: job.candidates,
        res: match err {
            None => Ok(DraftOutcome { granted, draft_s }),
            Some(e) => Err(e),
        },
        failed_tag,
    }
}

/// Reference path (`threads = 1`): execute the timestep's task set on the
/// caller thread, draft first — byte-identical results to the pool, same
/// job plumbing, zero concurrency. Panics are caught into `Lost` replies
/// exactly as on the pool, so no panic escapes the engine at any thread
/// count.
pub fn run_inline(
    rt: &Runtime,
    draft: Option<DraftJob>,
    stages: Vec<StageJob>,
) -> (Option<DraftReply>, Vec<StageReply>) {
    let d = draft.map(|draft| {
        match std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            exec_draft_job(rt, draft)
        })) {
            Ok(d) => DraftReply::Done(d),
            Err(p) => DraftReply::Lost {
                reason: panic_message(p.as_ref()),
            },
        }
    });
    let s = stages
        .into_iter()
        .map(|j| {
            let group = j.group;
            match std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                exec_stage_job(rt, j)
            })) {
                Ok(d) => StageReply::Done(d),
                Err(p) => StageReply::Lost {
                    group,
                    reason: panic_message(p.as_ref()),
                },
            }
        })
        .collect();
    (d, s)
}

/// Execute a timestep's task set on the pool when one exists, inline
/// otherwise — the single dispatch seam both engines go through. `draft`
/// is `None` on timesteps a banked speculative expansion served (ISSUE
/// 10): the pipeline's layer came from the bank, so no draft task runs
/// and no draft reply comes back.
pub fn run_tasks(
    pool: Option<&mut WorkerPool>,
    rt: &Runtime,
    draft: Option<DraftJob>,
    stages: Vec<StageJob>,
) -> (Option<DraftReply>, Vec<StageReply>) {
    match pool {
        Some(pool) => pool.run_timestep(draft, stages),
        None => run_inline(rt, draft, stages),
    }
}

/// Reabsorb stage replies: hand each surviving reply's lent state (plus
/// its measured deferred-commit seconds) to `restore` *before* looking at
/// its result — the invariant that keeps a failed decode from stranding
/// caches/contexts — and collect the outcomes in group order plus every
/// per-group failure. A `Lost` reply has no state to restore; its
/// [`StageFailure::state_lost`] tells the caller to rebuild the group
/// context from host truth.
pub fn absorb_stage_dones(
    groups: usize,
    replies: Vec<StageReply>,
    mut restore: impl FnMut(usize, StageContext, Vec<TwoLevelCache>, f64),
) -> (Vec<Option<GroupOutcome>>, Vec<StageFailure>) {
    let mut outcomes: Vec<Option<GroupOutcome>> = (0..groups).map(|_| None).collect();
    let mut failures = Vec::new();
    for reply in replies {
        match reply {
            StageReply::Done(done) => {
                restore(done.group, done.ctx, done.caches, done.commit_s);
                if let Err(e) = done.res.map(|oc| outcomes[done.group] = Some(oc)) {
                    failures.push(StageFailure {
                        group: done.group,
                        reason: format!("{e:#}"),
                        state_lost: false,
                    });
                }
            }
            StageReply::Lost { group, reason } => failures.push(StageFailure {
                group,
                reason,
                state_lost: true,
            }),
        }
    }
    (outcomes, failures)
}

/// Final step of reabsorbing a timestep: combine the draft reply's result
/// with any stage-side error (stage errors win — they were dispatched
/// first), yielding the draft outcome only when every task succeeded.
/// Callers restore all lent state *before* calling this.
pub fn finish_absorb(
    draft_res: Result<DraftOutcome>,
    stage_err: Option<anyhow::Error>,
) -> Result<DraftOutcome> {
    match stage_err {
        Some(e) => Err(e),
        None => draft_res,
    }
}

enum Job {
    Stage(StageJob),
    Draft(DraftJob),
}

/// What kind of job a worker held — captured *before* execution so a
/// panic (which consumes the job) can still be attributed.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum JobTag {
    Stage { group: usize },
    Draft,
}

impl Job {
    fn tag(&self) -> JobTag {
        match self {
            Job::Stage(j) => JobTag::Stage { group: j.group },
            Job::Draft(_) => JobTag::Draft,
        }
    }
}

enum Done {
    Stage(StageDone),
    Draft(DraftDone),
    /// A task panicked on the worker (thread survived); the job's lent
    /// state died with it. The coordinator turns this into a `Lost` reply
    /// for just that job.
    Panicked { tag: JobTag, msg: String },
    /// The worker thread itself is exiting (clean or unwinding) — sent by
    /// a drop guard so it cannot be skipped. `gen` distinguishes a stale
    /// announcement from a respawned worker's current incarnation.
    Exited { worker: usize, gen: u64 },
}

fn panic_message(payload: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".to_string()
    }
}

/// Body of one pool thread. Every exit path — clean shutdown, injected
/// exit, even an abrupt panic — announces itself via the drop guard's
/// `Done::Exited`, which is what lets the coordinator's reply loop flush
/// a dead worker's jobs instead of blocking forever. Replies and the
/// exit announcement go through the *same* `Sender`, so the announcement
/// is ordered after every reply this worker produced.
fn worker_loop(rx: Receiver<Job>, done_tx: Sender<Done>, rt: Arc<Runtime>, worker: usize, gen: u64) {
    struct ExitGuard {
        tx: Sender<Done>,
        worker: usize,
        gen: u64,
    }
    impl Drop for ExitGuard {
        fn drop(&mut self) {
            let _ = self.tx.send(Done::Exited {
                worker: self.worker,
                gen: self.gen,
            });
        }
    }
    let guard = ExitGuard {
        tx: done_tx,
        worker,
        gen,
    };
    loop {
        // chaos choke point: an injected error here exits the thread
        // cleanly between jobs; an injected panic kills it abruptly.
        // Both exercise the coordinator's flush-and-respawn path.
        if faultinject::fire(Site::WorkerExit).is_err() {
            break;
        }
        let Ok(job) = rx.recv() else { break };
        // Contain task panics: the coordinator counts on one reply per
        // job, so a panicking task must still answer.
        let tag = job.tag();
        let done = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| match job {
            Job::Stage(j) => Done::Stage(exec_stage_job(&rt, j)),
            Job::Draft(j) => Done::Draft(exec_draft_job(&rt, j)),
        }))
        .unwrap_or_else(|p| Done::Panicked {
            tag,
            msg: panic_message(p.as_ref()),
        });
        if guard.tx.send(done).is_err() {
            break; // pool dropped
        }
    }
}

/// The persistent pool: one thread per pipeline worker, fed over
/// per-worker channels, replying on one shared channel. The draft task is
/// pinned to the last worker; stage tasks round-robin over the rest in
/// dispatch order, so with `workers >= groups + 1` every task of a
/// timestep runs on its own thread (the paper's one-device-per-node
/// deployment) and no stage worker queues two tasks while another idles.
///
/// The pool is self-healing (ISSUE 9): a dead worker is respawned at its
/// next dispatch (the failed send returns the job, which is retried once
/// on the fresh thread), and a worker that dies mid-timestep has its
/// in-flight jobs flushed as `Lost` replies via its `Done::Exited`
/// announcement — `run_timestep` always returns one reply per dispatched
/// job and never panics on worker death.
pub struct WorkerPool {
    txs: Vec<Sender<Job>>,
    rx: Receiver<Done>,
    /// Kept so worker death can never close the reply channel under the
    /// coordinator, and cloned into respawned workers.
    done_tx: Sender<Done>,
    handles: Vec<JoinHandle<()>>,
    /// Incarnation counter per worker slot; bumped on respawn so stale
    /// `Exited` announcements from a replaced thread are ignored.
    gens: Vec<u64>,
    rt: Arc<Runtime>,
}

impl WorkerPool {
    pub fn new(workers: usize, rt: Arc<Runtime>) -> Result<Self> {
        anyhow::ensure!(workers >= 1, "worker pool needs >= 1 worker");
        let (done_tx, done_rx) = channel::<Done>();
        let mut txs = Vec::with_capacity(workers);
        let mut handles = Vec::with_capacity(workers);
        for i in 0..workers {
            let (tx, handle) = spawn_worker(i, 0, Arc::clone(&rt), done_tx.clone())?;
            txs.push(tx);
            handles.push(handle);
        }
        Ok(Self {
            txs,
            rx: done_rx,
            done_tx,
            handles,
            gens: vec![0; workers],
            rt,
        })
    }

    pub fn workers(&self) -> usize {
        self.txs.len()
    }

    /// Replace a dead worker slot with a fresh thread (new job channel,
    /// bumped generation); joins the old handle, which has already
    /// exited — a failed send is the only caller, and a closed job
    /// channel means the thread is gone.
    fn respawn(&mut self, w: usize) -> Result<()> {
        self.gens[w] += 1;
        let (tx, handle) = spawn_worker(w, self.gens[w], Arc::clone(&self.rt), self.done_tx.clone())?;
        self.txs[w] = tx;
        let old = std::mem::replace(&mut self.handles[w], handle);
        let _ = old.join();
        Ok(())
    }

    /// Send `job` to worker `w`, respawning it once if it died between
    /// jobs. Returns `None` when the job is in flight; otherwise a
    /// synthesized error reply carrying the job's (untouched) state back —
    /// the session layer fails gracefully instead of the pool panicking.
    fn dispatch(&mut self, w: usize, job: Job) -> Option<Done> {
        let job = match self.txs[w].send(job) {
            Ok(()) => return None,
            Err(e) => e.0,
        };
        let job = match self.respawn(w) {
            Ok(()) => match self.txs[w].send(job) {
                Ok(()) => return None,
                Err(e) => e.0,
            },
            Err(spawn_err) => {
                return Some(undispatched_reply(
                    job,
                    &format!("pipeline worker {w} respawn failed: {spawn_err:#}"),
                ))
            }
        };
        Some(undispatched_reply(
            job,
            &format!("pipeline worker {w} exited immediately after respawn"),
        ))
    }

    /// Dispatch one timestep's task set and block until every task
    /// replied or was flushed. Worker deaths and task panics surface as
    /// `Lost` replies (or error replies with state, when the job never
    /// left the coordinator) — never as a coordinator panic or hang.
    pub fn run_timestep(
        &mut self,
        draft: Option<DraftJob>,
        stages: Vec<StageJob>,
    ) -> (Option<DraftReply>, Vec<StageReply>) {
        let n = self.txs.len();
        let draft_worker = n - 1;
        let draft_dispatched = draft.is_some();
        // per-worker sets of in-flight jobs, so an `Exited` announcement
        // can flush exactly the jobs that died with the thread
        let mut outstanding: Vec<Vec<JobTag>> = vec![Vec::new(); n];
        let mut group_worker: Vec<(usize, usize)> = Vec::new();
        let mut draft_reply: Option<DraftReply> = None;
        let mut stage_replies: Vec<StageReply> = Vec::new();
        let mut pending = 0usize;

        let mut absorb = |done: Done,
                          draft_reply: &mut Option<DraftReply>,
                          stage_replies: &mut Vec<StageReply>| match done {
            Done::Stage(d) => stage_replies.push(StageReply::Done(d)),
            Done::Draft(d) => *draft_reply = Some(DraftReply::Done(d)),
            Done::Panicked { tag, msg } => match tag {
                JobTag::Stage { group } => stage_replies.push(StageReply::Lost {
                    group,
                    reason: format!("stage task panicked: {msg}"),
                }),
                JobTag::Draft => {
                    *draft_reply = Some(DraftReply::Lost {
                        reason: format!("draft task panicked: {msg}"),
                    })
                }
            },
            Done::Exited { .. } => unreachable!("exit announcements handled by the reply loop"),
        };

        if let Some(draft) = draft {
            match self.dispatch(draft_worker, Job::Draft(draft)) {
                Some(done) => absorb(done, &mut draft_reply, &mut stage_replies),
                None => {
                    outstanding[draft_worker].push(JobTag::Draft);
                    pending += 1;
                }
            }
        }
        // round-robin over *dispatched* tasks (not group ids): with sparse
        // occupancy, assigning by group id would pile same-residue groups
        // onto one worker while others idle
        let stage_workers = (n - 1).max(1);
        for (i, job) in stages.into_iter().enumerate() {
            let w = if n == 1 { 0 } else { i % stage_workers };
            let group = match job.tag() {
                JobTag::Stage { group } => group,
                JobTag::Draft => unreachable!("stage list holds stage jobs"),
            };
            match self.dispatch(w, Job::Stage(job)) {
                Some(done) => absorb(done, &mut draft_reply, &mut stage_replies),
                None => {
                    outstanding[w].push(JobTag::Stage { group });
                    group_worker.push((group, w));
                    pending += 1;
                }
            }
        }

        while pending > 0 {
            let Ok(done) = self.rx.recv() else {
                break; // unreachable: the pool holds a live done_tx
            };
            match done {
                Done::Exited { worker, gen } => {
                    if gen != self.gens[worker] {
                        continue; // stale announcement from a replaced thread
                    }
                    // the thread died with these jobs: flush them as Lost
                    for tag in std::mem::take(&mut outstanding[worker]) {
                        pending -= 1;
                        match tag {
                            JobTag::Stage { group } => stage_replies.push(StageReply::Lost {
                                group,
                                reason: format!("pipeline worker {worker} died mid-timestep"),
                            }),
                            JobTag::Draft => {
                                draft_reply = Some(DraftReply::Lost {
                                    reason: format!("pipeline worker {worker} died mid-timestep"),
                                })
                            }
                        }
                    }
                }
                done => {
                    let (w, tag) = match &done {
                        Done::Stage(d) => (
                            worker_of_group(&group_worker, d.group, draft_worker),
                            JobTag::Stage { group: d.group },
                        ),
                        Done::Draft(_) => (draft_worker, JobTag::Draft),
                        Done::Panicked { tag, .. } => match tag {
                            JobTag::Stage { group } => (
                                worker_of_group(&group_worker, *group, draft_worker),
                                *tag,
                            ),
                            JobTag::Draft => (draft_worker, *tag),
                        },
                        Done::Exited { .. } => unreachable!("matched above"),
                    };
                    if let Some(i) = outstanding[w].iter().position(|t| *t == tag) {
                        outstanding[w].swap_remove(i);
                        pending -= 1;
                    }
                    absorb(done, &mut draft_reply, &mut stage_replies);
                }
            }
        }

        let draft_reply = draft_dispatched.then(|| {
            draft_reply.unwrap_or(DraftReply::Lost {
                reason: "draft reply missing (worker pool reply channel closed)".to_string(),
            })
        });
        (draft_reply, stage_replies)
    }
}

/// Which worker a stage group was dispatched to (draft worker as the
/// never-matching fallback — group ids are always in the map when their
/// dispatch succeeded).
fn worker_of_group(group_worker: &[(usize, usize)], group: usize, fallback: usize) -> usize {
    group_worker
        .iter()
        .find(|(g, _)| *g == group)
        .map(|&(_, w)| w)
        .unwrap_or(fallback)
}

/// Spawn one pool thread (initial construction and respawn share this).
fn spawn_worker(
    i: usize,
    gen: u64,
    rt: Arc<Runtime>,
    done_tx: Sender<Done>,
) -> Result<(Sender<Job>, JoinHandle<()>)> {
    let (tx, rx) = channel::<Job>();
    let handle = Builder::new()
        .name(format!("pipedec-worker-{i}"))
        .spawn(move || worker_loop(rx, done_tx, rt, i, gen))?;
    Ok((tx, handle))
}

/// Synthesize an error reply for a job that could not be dispatched at
/// all — its state never left the coordinator, so it comes home intact
/// inside a normal state-carrying reply with `res: Err`.
fn undispatched_reply(job: Job, reason: &str) -> Done {
    match job {
        Job::Stage(j) => Done::Stage(StageDone {
            group: j.group,
            ctx: j.ctx,
            caches: j.caches,
            commit_s: 0.0,
            res: Err(anyhow::anyhow!("{reason}")),
        }),
        Job::Draft(j) => Done::Draft(DraftDone {
            ctx: j.ctx,
            candidates: j.candidates,
            res: Err(anyhow::anyhow!("{reason}")),
            failed_tag: None,
        }),
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        self.txs.clear(); // close the job channels; workers drain and exit
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}
