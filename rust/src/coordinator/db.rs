//! SpecPipe-DB: the paper's multi-request variant — PipeDec with dynamic
//! batching, filling pipeline slots with speculative tokens from
//! *different* requests.
//!
//! The single-task [`super::PipeDecEngine`] commits every pipeline stage to
//! one request: after a miss the pipeline refills for `groups` timesteps
//! producing nothing, and at the start/end of a request most slots idle.
//! SpecPipe-DB serves the same per-request math (shared with the solo
//! engine via [`super::pipeline`]) but schedules it *continuously*:
//!
//! * every live [`Session`] owns its prediction tree plus a full set of
//!   per-request [`TwoLevelCache`]s (one per stage + the draft cache), so
//!   requests never share KV state — device mirrors are released at
//!   session teardown via [`StageContext::release_cache`];
//! * the pipeline itself is a ring of `groups` slots, each holding one
//!   in-flight [`DataFlow`] tagged with its owning session; per timestep
//!   every occupied slot advances one group (possibly a different session
//!   per slot — the dynamic batch);
//! * pipeline slot 0 is granted round-robin: a session's pending root flow
//!   (fresh admission or miss restart) or one draft expansion of its tree
//!   (the draft device serves one session per timestep, exactly like rank
//!   0 in the paper);
//! * queued sessions are admitted whenever a session slot frees up, so
//!   admission overlaps with decode — the refill/idle timesteps that solo
//!   PipeDec wastes now carry other requests' flows, which is where the
//!   Fig. 8 throughput gain over one-at-a-time serving comes from;
//! * sync points (verify / prune / promote) are per-session, so pruning
//!   propagation never crosses sessions and greedy outputs are identical
//!   to a solo decode (asserted by `rust/tests/scheduler.rs` and the
//!   `fig8_throughput` bench).
//!
//! Since ISSUE 4 each `step()` executes its task set — the draft/entry
//! grant plus one task per occupied pipeline slot — on the persistent
//! worker pool ([`super::workers`]) when `threads >= 2`, exactly like the
//! solo engine: per-session caches and the per-group [`StageContext`]s
//! move into the jobs and back, stage tasks read tree snapshots, and all
//! verification stays in the coordinator's sync phase, so scheduling
//! (and outputs) are identical to the sequential reference path.
//!
//! Since ISSUE 5 the per-session sync is split decide/commit: the
//! coordinator verifies, samples, and prunes, then issues the cache
//! maintenance as a per-session [`CacheCommit`]; with
//! `EngineConfig::overlap_sync` (default) each cache owner applies its
//! pending commits at the start of its next job, so one session's cache
//! maintenance overlaps every other session's (and its own next) compute
//! instead of serializing the whole batch at the coordinator.
//!
//! Since ISSUE 9 the scheduler is a *fault-isolated* serving core: a
//! task panic, model/device error, admission failure, missed deadline,
//! or stalled flow retires only the owning session(s) as
//! [`SessionStatus::Failed`] — partial output pollable, reason recorded,
//! mirrors/pins/slots released through the same teardown as `cancel` —
//! while co-scheduled sessions continue bit-identically, and `step()`
//! never fails the batch for a per-session fault. Lost worker state
//! (a panicked task destroys its lent caches and group context) is
//! rebuilt from host truth: a fresh [`StageContext`] re-uploads the
//! surviving sessions' mirrors lazily through the full re-upload
//! fallback. Admission limits (`LimitsConfig`) shed over-capacity
//! submits with [`ShedError`] and retire over-deadline sessions with a
//! reason starting `"deadline"`.
//!
//! Served both ways: natively as a [`ScheduledEngine`] (the continuous
//! server loop) and as a one-shot [`Engine`] (a decode = one session
//! stepped to completion), so `EngineKind::PipeDecDb` passes the same
//! conformance suite as every other registry entry.

use std::collections::VecDeque;
use std::path::Path;
use std::sync::Arc;
use std::time::Instant;

use anyhow::{Context, Result};

use super::pipeline::DataFlow;
use super::sampling::{select_token, Sampling};
use super::spec::SpecBank;
use super::workers::{
    self, DraftCandidate, DraftJob, DraftOutcome, DraftReply, GroupOutcome, StageJob, WorkerPool,
};
use crate::concurrency::protocol::CommitLog;
use crate::config::EngineConfig;
use crate::engine::{
    DecodeOutput, DecodeRequest, Engine, EngineKind, NullSink, ScheduledEngine, Session,
    SessionId, SessionRecord, SessionStatus, ShedError, SpecStats, StepReport, TokenSink,
};
use crate::kvcache::prefix::{PrefixEntry, PrefixKv, PrefixStore};
use crate::kvcache::{CacheCommit, CommitOp, TwoLevelCache};
use crate::metrics::{Metrics, SharedMetrics};
use crate::model::{ModelCore, StageContext};
use crate::runtime::Runtime;
use crate::schedule::CentralScheduler;
use crate::tokenizer;
use crate::transport::{LinkModel, LinkStats};
use crate::tree::{PredictionTree, PruneOutcome};
use crate::util::XorShiftRng;

/// One in-flight data flow, tagged with its owning session.
struct SlotFlow {
    session: SessionId,
    df: DataFlow,
}

/// How a live session leaves the scheduler (ISSUE 9). `Finished` and
/// `Failed` both produce a pollable output (full vs partial tokens);
/// `Cancelled` produces none.
enum Retire {
    Finished,
    Cancelled,
    Failed(String),
}

/// A live session: the shared [`Session`] shell plus the SpecPipe-DB
/// decode state (tree, per-request sampling/RNG, counters).
/// `base.caches` holds one cache per pipeline stage plus the draft cache
/// last (index `cfg.stages`).
struct DbSession {
    base: Session,
    tree: PredictionTree,
    rng: XorShiftRng,
    sampling: Sampling,
    max_new: usize,
    budget: usize,
    /// Flow waiting to enter pipeline slot 0 (root after admission or a
    /// miss restart).
    entry: Option<DataFlow>,
    /// Deferred sync commits not yet applied by every one of this
    /// session's cache owners (ISSUE 5, `overlap_sync`), oldest first.
    /// The epoch counter (`seq()` = every job's `commit_target`) and the
    /// queue discipline live in [`CommitLog`], shared with
    /// `PipeDecEngine` and the model checker.
    commit_log: CommitLog<CacheCommit>,
    /// This session's bank of free-running speculative generations
    /// (ISSUE 10): served in place of a draft grant when the session's
    /// rotation turn comes up, epoch-bumped on every Miss reset; dies
    /// with the session at retire/cancel so no generation outlives its
    /// owner. Idle at `spec_inflight = 1`.
    spec: SpecBank,
    timesteps: u64,
    hits: u64,
    misses: u64,
    modeled_s: f64,
    prefill_s: f64,
    /// Coordinator decide seconds (verify + sample + prune) for this
    /// session's sync points.
    t_decide_s: f64,
    /// Eager commit seconds for this session (serial-sync path only).
    t_commit_eager_s: f64,
    /// Deferred commit seconds this session's jobs reported (overlap
    /// path) — attributed per session from the job replies.
    t_commit_worker_s: f64,
    /// Cache-commit applications counted on the eager path (overlap-path
    /// ops are counted by the workers into the shared metrics).
    commit_ops_eager: u64,
    /// Prompt tokens covered by a prefix-cache hit at admission (ISSUE 8);
    /// 0 on a miss or with the cache disabled.
    prefix_hit_tokens: u64,
    /// Prompt tokens the prefill actually computed (the uncovered
    /// suffix; the full prompt without a hit).
    prefill_tokens: u64,
    /// Which tier answered the admission probe (at most one is set).
    prefix_l1_hit: bool,
    prefix_l2_hit: bool,
    /// Whether the store was probed at all (distinguishes a miss from a
    /// disabled cache in the per-session metrics).
    prefix_probed: bool,
    /// Store-wide eviction count snapshot at admission, so retire can
    /// attribute the delta to this session's metrics.
    prefix_evictions_before: u64,
    /// Pins on the shared L1 prefix blocks this session seeded from or
    /// inserted (read-only; dropped at retire/cancel). Keeps each `Arc`
    /// strong count an observable proxy for "sessions sharing this
    /// template block".
    prefix_pins: Vec<Arc<PrefixEntry>>,
    /// Wall seconds this session's stage tasks spent busy in pipeline
    /// slots (occupancy numerator, ISSUE 10).
    busy_group_s: f64,
    wall0: Instant,
}

impl DbSession {
    /// Clone the commit-log suffix a cache at `epoch` still has to apply.
    fn pending_commits(&self, epoch: u64) -> Vec<CacheCommit> {
        self.commit_log.pending(epoch)
    }

    /// Undrained commit depth for a cache at `epoch` (stall diagnostics).
    fn pending_depth(&self, epoch: u64) -> usize {
        self.commit_log.depth(epoch)
    }

    /// Drop commit-log entries every one of this session's cache owners
    /// (all stage caches + the draft cache) has applied.
    fn trim_commit_log(&mut self) {
        if self.commit_log.is_empty() {
            return;
        }
        let min_ep = self
            .base
            .caches
            .iter()
            .map(|c| c.commit_epoch())
            .min()
            .unwrap_or(0);
        self.commit_log.trim(min_ep);
    }
}

/// The SpecPipe-DB engine over AOT artifacts.
pub struct PipeDecDbEngine {
    rt: Arc<Runtime>,
    target: Arc<ModelCore>,
    draft: Arc<ModelCore>,
    pub cfg: EngineConfig,
    layers_per_stage: usize,
    /// Per-group execution contexts (device KV mirrors of the member
    /// stages' session caches, incremental bias); `None` while lent to a
    /// worker.
    group_ctxs: Vec<Option<StageContext>>,
    draft_ctx: Option<StageContext>,
    link: LinkModel,
    pub link_stats: LinkStats,
    scheduler: CentralScheduler,
    next_id: u64,
    queue: VecDeque<Session>,
    live: Vec<DbSession>,
    done: Vec<SessionRecord>,
    /// Pipeline ring: one in-flight flow per timestep group.
    slots: Vec<Option<SlotFlow>>,
    /// Round-robin cursor over `live` for granting slot 0.
    entry_cursor: usize,
    /// Maximum concurrently admitted sessions (= pipeline groups).
    max_live: usize,
    steps: u64,
    stalled_for: u64,
    pool: Option<WorkerPool>,
    worker_metrics: Arc<SharedMetrics>,
    /// Cross-request KV prefix cache (ISSUE 8); `None` when disabled by
    /// config or the `PIPEDEC_NO_PREFIX_CACHE` kill-switch.
    prefix: Option<PrefixStore>,
}

impl PipeDecDbEngine {
    pub fn new(artifact_dir: &Path, mut cfg: EngineConfig) -> Result<Self> {
        cfg.validate()?;
        // chaos layer (ISSUE 9): config-armed plan, env var wins
        if let Some(plan) = &cfg.fault_plan {
            crate::faultinject::arm(plan.parse()?);
        }
        crate::faultinject::arm_from_env()?;
        let rt = Arc::new(Runtime::cpu()?);
        let target = Arc::new(ModelCore::load_with_width(
            &rt,
            artifact_dir,
            "target",
            cfg.tree.max_width,
        )?);
        let draft = Arc::new(ModelCore::load_with_width(
            &rt,
            artifact_dir,
            "draft",
            cfg.tree.max_width,
        )?);
        anyhow::ensure!(
            target.cfg.n_layers % cfg.stages == 0,
            "stages {} must divide target layers {}",
            cfg.stages,
            target.cfg.n_layers
        );
        let layers_per_stage = target.cfg.n_layers / cfg.stages;
        cfg.tree.max_width = cfg
            .tree
            .max_width
            .min(target.cfg.width_cap)
            .min(draft.cfg.width_cap);
        cfg.tree.max_children = cfg.tree.max_children.min(target.cfg.vocab_size);
        let groups = cfg.stages / cfg.group_size;
        let group_ctxs = (0..groups).map(|_| Some(target.context())).collect();
        let draft_ctx = Some(draft.context());
        let threads = cfg.effective_threads();
        let pool = if threads >= 2 {
            Some(WorkerPool::new(threads.min(groups + 1), Arc::clone(&rt))?)
        } else {
            None
        };
        let prefix = PrefixStore::from_config(&cfg.prefix_cache, target.cfg.width_cap)?;
        Ok(Self {
            rt,
            target,
            draft,
            cfg,
            layers_per_stage,
            group_ctxs,
            draft_ctx,
            link: LinkModel::pcie_p2p(),
            link_stats: LinkStats::default(),
            scheduler: CentralScheduler::new(),
            next_id: 0,
            queue: VecDeque::new(),
            live: Vec::new(),
            done: Vec::new(),
            slots: (0..groups).map(|_| None).collect(),
            entry_cursor: 0,
            max_live: groups,
            steps: 0,
            stalled_for: 0,
            pool,
            worker_metrics: Arc::new(SharedMetrics::new()),
            prefix,
        })
    }

    /// Device-mirror occupancy per context (stage groups in order, then
    /// the draft) — leak probe for tests and stall diagnostics.
    pub fn mirror_counts(&self) -> Vec<usize> {
        self.group_ctxs
            .iter()
            .map(|c| c.as_ref().map_or(0, StageContext::mirror_count))
            .chain([self.draft_ctx.as_ref().map_or(0, StageContext::mirror_count)])
            .collect()
    }

    /// The cross-request prefix store, when enabled (test hook).
    pub fn prefix_store(&self) -> Option<&PrefixStore> {
        self.prefix.as_ref()
    }

    /// Live sessions currently pinning a shared prefix entry (test hook:
    /// a cancelled session must drop its pin).
    pub fn pinned_prefix_sessions(&self) -> usize {
        self.live.iter().filter(|s| !s.prefix_pins.is_empty()).count()
    }

    /// Total banked speculative generations across live sessions
    /// (ISSUE 10 test hook: a retired session must leak no in-flight
    /// generation — its bank dies with it).
    pub fn inflight_generations(&self) -> usize {
        self.live.iter().map(|s| s.spec.depth()).sum()
    }

    fn groups(&self) -> usize {
        self.cfg.stages / self.cfg.group_size
    }

    /// Worker threads actually running (1 = sequential reference path).
    pub fn worker_threads(&self) -> usize {
        self.pool.as_ref().map(|p| p.workers()).unwrap_or(1)
    }

    fn live_index(&self, id: SessionId) -> Option<usize> {
        self.live.iter().position(|s| s.base.id == id)
    }

    /// Account one inter-node transfer through the central scheduler and
    /// the link model; returns the modeled wire seconds.
    fn account_transfer(&mut self, src: usize, dst: usize, bytes: usize, seq: u64) -> f64 {
        let id = self.scheduler.submit(src, dst, bytes, seq);
        let dispatched = self.scheduler.tick();
        debug_assert!(dispatched.iter().any(|d| d.task.id == id));
        self.scheduler.notify_finish(id);
        self.scheduler.tick();
        self.link_stats.record(bytes, &self.link);
        self.link.transfer_time(bytes)
    }

    /// Admit one queued session: mint its per-request caches, run the
    /// pipeline prefill (emitting the first token), and build its tree.
    /// On error the shell comes back with whatever caches were minted, so
    /// the caller can release its device mirrors and fail only this
    /// session (admission containment, ISSUE 9).
    fn admit(&mut self, mut shell: Session) -> std::result::Result<DbSession, Box<(Session, anyhow::Error)>> {
        let (max_new, sampling, seed) = shell.req.resolve(&self.cfg);
        let tc = self.target.cfg.clone();
        let dc = self.draft.cfg.clone();
        let lps = self.layers_per_stage;
        let gs = self.cfg.group_size;
        let stages = self.cfg.stages;
        let mut rng = XorShiftRng::new(seed);

        // per-session caches: one per pipeline stage + the draft cache last
        let mut caches: Vec<TwoLevelCache> = (0..stages)
            .map(|_| TwoLevelCache::new(lps, tc.n_heads, tc.head_dim, tc.past_cap, tc.tree_cap))
            .collect();
        caches.push(TwoLevelCache::new(
            dc.n_layers,
            dc.n_heads,
            dc.head_dim,
            dc.past_cap,
            dc.tree_cap,
        ));
        shell.caches = caches;

        // pipeline prefill through all target stages (plain sequential
        // pre-filling, §3.4.1), as in the solo engine's prefill; each
        // stage runs with its group's context so the device mirrors live
        // where the stage tasks will look for them
        let w = tc.width_cap;
        let t0 = Instant::now();
        let prompt = shell.prompt_ids.clone();

        // Cross-request prefix reuse (ISSUE 8): probe the store for the
        // longest chain of cached blocks covering the (context-truncated)
        // prompt and seed every per-session cache — stage caches and the
        // draft cache — block by block. The probe is capped at `len - 1`
        // so the final prompt token is always re-computed: the last
        // prefill chunk must still produce logits for the first sampled
        // token. Device mirrors warm lazily through the existing
        // epoch-diff upload path on the session's first forward.
        let mut covered = 0usize;
        let mut prefix_pins: Vec<Arc<PrefixEntry>> = Vec::new();
        let (mut prefix_l1_hit, mut prefix_l2_hit) = (false, false);
        let prefix_probed = self.prefix.is_some();
        let prefix_evictions_before = self
            .prefix
            .as_ref()
            .map_or(0, |store| store.stats().evictions);
        // Everything fallible — prefix seeding, pipeline + draft prefill,
        // block insertion — runs inside this closure, so an error hands
        // the shell (with its partially-mirrored caches) back to the
        // caller for release instead of dropping it here and stranding
        // device mirrors.
        let mut run = || -> Result<(u32, f64)> {
            if let Some(store) = self.prefix.as_mut() {
                let before = store.stats();
                let chain = store.lookup(&prompt, prompt.len().saturating_sub(1));
                for entry in &chain {
                    anyhow::ensure!(
                        entry.kv.len() == shell.caches.len(),
                        "prefix block holds {} caches, session has {}",
                        entry.kv.len(),
                        shell.caches.len()
                    );
                    for (kv, cache) in entry.kv.iter().zip(shell.caches.iter_mut()) {
                        kv.seed(cache)?;
                    }
                }
                if let Some(last) = chain.last() {
                    covered = last.tokens.len();
                }
                prefix_l1_hit = store.stats().l1_hits > before.l1_hits;
                prefix_l2_hit = store.stats().l2_hits > before.l2_hits;
                prefix_pins = chain;
            }

            let mut last_h = None;
            let mut last_count = 0;
            for chunk in prompt[covered..].chunks(w) {
                let start = shell.caches[0].past_len();
                let mut h = self.target.embed(&self.rt, chunk)?;
                for s in 0..stages {
                    let range = s * lps..(s + 1) * lps;
                    let ctx = self.group_ctxs[s / gs]
                        .as_mut()
                        .expect("group ctx in residence");
                    h = self.target.prefill_chunk(
                        &self.rt,
                        ctx,
                        range,
                        &mut shell.caches[s],
                        h,
                        chunk.len(),
                        start,
                    )?;
                }
                last_count = chunk.len();
                last_h = Some(h);
            }
            let h = last_h.context("empty prompt")?;
            let logits = self.target.head(&self.rt, &h)?;
            let v = tc.vocab_size;
            let row = &logits[(last_count - 1) * v..last_count * v];
            let first = select_token(row, &sampling, &mut rng);
            // draft prefill (parallel with the target on the real testbed);
            // with a prefix hit the draft cache was seeded too, so it also
            // runs only the uncovered suffix (positions derive from the
            // cache's past length)
            self.draft.full_prefill(
                &self.rt,
                self.draft_ctx.as_mut().expect("draft ctx in residence"),
                &mut shell.caches[stages],
                &prompt[covered..],
            )?;
            let prefill_s = t0.elapsed().as_secs_f64();

            // Insert (or reference-bump) this session's own uncovered blocks
            // so concurrent sessions sharing a template converge on one
            // resident copy per block. Blocks at boundaries <= covered were
            // just returned (and LRU-bumped) by the admission lookup.
            if let Some(store) = self.prefix.as_mut() {
                let chunk = store.chunk_tokens();
                let insert_len = store.align_down(prompt.len());
                let mut b = covered + chunk;
                while b <= insert_len {
                    let pfx = &prompt[..b];
                    if let Some(arc) = store.bump(pfx) {
                        prefix_pins.push(arc);
                    } else if !store.contains(pfx) {
                        let kv = shell
                            .caches
                            .iter()
                            .map(|c| PrefixKv::extract_range(c, b - chunk, b))
                            .collect::<Result<Vec<_>>>()?;
                        let entry = PrefixEntry {
                            tokens: pfx.to_vec(),
                            kv,
                        };
                        // A key collision only forfeits caching for this
                        // block; the decode itself is unaffected.
                        if let Ok(arc) = store.insert(entry) {
                            prefix_pins.push(arc);
                        }
                    }
                    b += chunk;
                }
            }
            Ok((first, prefill_s))
        };
        let (first, prefill_s) = match run() {
            Ok(v) => v,
            Err(e) => return Err(Box::new((shell, e))),
        };

        let budget = tc.tree_cap.min(dc.tree_cap);
        let tree = PredictionTree::new(self.cfg.tree, budget, first, prompt.len());
        shell.status = SessionStatus::Running;
        shell.emit(first);
        Ok(DbSession {
            entry: Some(DataFlow::root(&tree)),
            tree,
            rng,
            sampling,
            max_new,
            budget,
            commit_log: CommitLog::new(),
            spec: SpecBank::new(),
            timesteps: 0,
            hits: 0,
            misses: 0,
            modeled_s: 0.0,
            prefill_s,
            t_decide_s: 0.0,
            t_commit_eager_s: 0.0,
            t_commit_worker_s: 0.0,
            commit_ops_eager: 0,
            prefix_hit_tokens: covered as u64,
            prefill_tokens: (prompt.len() - covered) as u64,
            prefix_l1_hit,
            prefix_l2_hit,
            prefix_probed,
            prefix_evictions_before,
            prefix_pins,
            busy_group_s: 0.0,
            wall0: Instant::now(),
            base: shell,
        })
    }

    /// Remove a live session: purge its in-flight flows, release its
    /// device KV mirrors, drop its host caches (and prefix pins, which
    /// drop with the session), and build the final [`DecodeOutput`] —
    /// full for `Finished`, partial for `Failed`, none for `Cancelled`.
    /// Returns the session id.
    fn retire(&mut self, si: usize, how: Retire, next_slots: &mut [Option<SlotFlow>]) -> SessionId {
        let sess = self.live.remove(si);
        let id = sess.base.id;
        if self.entry_cursor > si {
            self.entry_cursor -= 1;
        }
        for slot in self.slots.iter_mut().chain(next_slots.iter_mut()) {
            if slot.as_ref().is_some_and(|f| f.session == id) {
                *slot = None;
            }
        }
        // per-request cache churn would leak device mirrors without this
        // (the ROADMAP eviction-hook note from PR 2); each stage cache's
        // mirror lives in its group's context, the draft cache's in the
        // draft context
        let stages = self.cfg.stages;
        let gs = self.cfg.group_size;
        for (i, c) in sess.base.caches.iter().enumerate() {
            if i < stages {
                self.group_ctxs[i / gs]
                    .as_mut()
                    .expect("group ctx in residence")
                    .release_cache(c.id());
            } else {
                self.draft_ctx
                    .as_mut()
                    .expect("draft ctx in residence")
                    .release_cache(c.id());
            }
        }
        let record = if !matches!(how, Retire::Cancelled) {
            let mut metrics = Metrics::new();
            if matches!(how, Retire::Failed(_)) {
                metrics.incr("failed_sessions", 1);
            }
            metrics.incr("tokens", sess.base.tokens.len() as u64);
            metrics.incr("timesteps", sess.timesteps);
            metrics.incr("hits", sess.hits);
            metrics.incr("misses", sess.misses);
            metrics.record("prefill_s", sess.prefill_s);
            metrics.incr("prefill_tokens", sess.prefill_tokens);
            // prefix-cache accounting (ISSUE 8): hit tokens double as
            // prompt tokens the prefill never re-computed; tier bytes are
            // point-in-time store gauges, evictions the store delta since
            // this session's admission
            if sess.prefix_probed {
                metrics.incr("prefix_hit_tokens", sess.prefix_hit_tokens);
                metrics.incr("prefill_tokens_saved", sess.prefix_hit_tokens);
                if sess.prefix_l1_hit {
                    metrics.incr("prefix_l1_hits", 1);
                } else if sess.prefix_l2_hit {
                    metrics.incr("prefix_l2_hits", 1);
                } else {
                    metrics.incr("prefix_misses", 1);
                }
                if let Some(store) = self.prefix.as_ref() {
                    metrics.record("prefix_l1_bytes", store.l1_bytes() as f64);
                    metrics.record("prefix_l2_bytes", store.l2_bytes() as f64);
                    let delta = store.stats().evictions - sess.prefix_evictions_before;
                    metrics.incr("prefix_evictions", delta);
                }
            }
            // continuous-speculation accounting (ISSUE 10): occupancy is
            // this session's busy slot-seconds over its wall-clock share
            // of the pipeline (`wall × groups` slot-seconds); banked
            // generations dropped as stale / served in place of a draft
            // dispatch are counted per owning session
            let wall_s = sess.wall0.elapsed().as_secs_f64();
            let occupancy = if wall_s > 0.0 {
                (sess.busy_group_s / (wall_s * self.groups() as f64)).min(1.0)
            } else {
                0.0
            };
            metrics.record("occupancy", occupancy);
            metrics.record("bubble_fraction", 1.0 - occupancy);
            metrics.incr("stale_expansions_dropped", sess.spec.stale_dropped());
            metrics.incr("spec_expansions_served", sess.spec.served());
            // per-session sync breakdown: decide at the coordinator, the
            // commit wherever it ran — eager at the sync point (serial
            // path) or inside this session's jobs (overlap path, seconds
            // attributed precisely from the job replies)
            metrics.record("t_decide_s", sess.t_decide_s);
            let commit_total = sess.t_commit_eager_s + sess.t_commit_worker_s;
            if commit_total > 0.0 {
                metrics.record("t_commit_s", commit_total);
            }
            if sess.commit_ops_eager > 0 {
                metrics.incr("commit_ops", sess.commit_ops_eager);
            }
            let sync_s = sess.t_decide_s + commit_total;
            metrics.record(
                "sync_overlap_ratio",
                if self.pool.is_some() && self.cfg.overlap_sync && sync_s > 0.0 {
                    sess.t_commit_worker_s / sync_s
                } else {
                    0.0
                },
            );
            // engine-level worker timings accumulated since the last
            // finished session (generic task timings stay batch-wide)
            metrics.merge(&self.worker_metrics.drain());
            let output = DecodeOutput {
                text: tokenizer::decode(&sess.base.tokens),
                tokens: sess.base.tokens.clone(),
                wall_s: sess.wall0.elapsed().as_secs_f64(),
                modeled_s: sess.modeled_s,
                spec: Some(SpecStats {
                    timesteps: sess.timesteps,
                    rounds: 0,
                    hits: sess.hits,
                    misses: sess.misses,
                    accepted_per_round: 0.0,
                }),
                metrics,
            };
            let status = match how {
                Retire::Finished => SessionStatus::Finished,
                Retire::Failed(reason) => SessionStatus::Failed { reason },
                Retire::Cancelled => unreachable!("cancelled handled below"),
            };
            sess.base.into_record(status, Some(output))
        } else {
            sess.base.into_record(SessionStatus::Cancelled, None)
        };
        self.done.push(record);
        id
    }

    /// Retire a *queued* (never admitted) session as `Failed` — deadline
    /// or queue-wait shedding. A queued shell owns no caches, mirrors, or
    /// pins, so teardown is just the record.
    fn fail_queued(&mut self, qi: usize, reason: String) -> SessionId {
        let shell = self.queue.remove(qi).expect("queue index in bounds");
        let id = shell.id;
        let mut metrics = Metrics::new();
        metrics.incr("failed_sessions", 1);
        let output = DecodeOutput {
            text: tokenizer::decode(&shell.tokens),
            tokens: shell.tokens.clone(),
            wall_s: shell.queued_at.elapsed().as_secs_f64(),
            modeled_s: 0.0,
            spec: None,
            metrics,
        };
        self.done
            .push(shell.into_record(SessionStatus::Failed { reason }, Some(output)));
        id
    }

    /// Retire a shell whose *admission* failed (prefill/model error, bad
    /// prefix block): release whatever device mirrors the partial prefill
    /// minted for its caches, then record it as `Failed`. The admission
    /// loop continues, so a poisoned request cannot block the queue
    /// behind it.
    fn fail_admission(&mut self, shell: Session, reason: String) -> SessionId {
        let id = shell.id;
        let stages = self.cfg.stages;
        let gs = self.cfg.group_size;
        for (i, c) in shell.caches.iter().enumerate() {
            if i < stages {
                self.group_ctxs[i / gs]
                    .as_mut()
                    .expect("group ctx in residence")
                    .release_cache(c.id());
            } else {
                self.draft_ctx
                    .as_mut()
                    .expect("draft ctx in residence")
                    .release_cache(c.id());
            }
        }
        let mut metrics = Metrics::new();
        metrics.incr("failed_sessions", 1);
        let output = DecodeOutput {
            text: tokenizer::decode(&shell.tokens),
            tokens: shell.tokens.clone(),
            wall_s: shell.queued_at.elapsed().as_secs_f64(),
            modeled_s: 0.0,
            spec: None,
            metrics,
        };
        self.done
            .push(shell.into_record(SessionStatus::Failed { reason }, Some(output)));
        id
    }

    /// Build, execute, and reabsorb one step's task set: one task per
    /// occupied pipeline slot plus the draft/entry task over all live
    /// sessions in round-robin order. Returns the draft outcome, the
    /// per-group outcomes, each dispatched group's owning session, and
    /// the sessions a task failure implicated (ISSUE 9) — the caller
    /// retires exactly those as `Failed` and keeps serving the rest, so
    /// this function never fails the batch: lost contexts are rebuilt
    /// from host truth right here.
    #[allow(clippy::type_complexity)]
    fn run_step_tasks(
        &mut self,
    ) -> (
        DraftOutcome,
        Vec<Option<GroupOutcome>>,
        Vec<Option<SessionId>>,
        Vec<(SessionId, String)>,
    ) {
        let groups = self.groups();
        let gs = self.cfg.group_size;
        let lps = self.layers_per_stage;
        let di = self.cfg.stages; // draft cache index in session caches

        // ---- continuous speculation (ISSUE 10): if the rotation-front
        // session has a banked generation that still applies to its live
        // tree, serve it in place of this step's draft dispatch (the same
        // rule as the solo engine: the pipeline entry comes for free and
        // the draft device idles the step). Served before the stage
        // snapshots are taken, so the appended layer — which never
        // disturbs existing node indices — is simply part of this step's
        // view. Sessions with a pending entry flow keep entry priority.
        let mut banked: Option<(usize, DataFlow)> = None;
        if self.cfg.spec_inflight > 1 && !self.live.is_empty() {
            let si = self.entry_cursor % self.live.len();
            let sess = &mut self.live[si];
            if sess.entry.is_none() {
                if let Some(df) = sess.spec.try_serve(&mut sess.tree) {
                    banked = Some((si, df));
                }
            }
        }

        let mut slot_owner: Vec<Option<SessionId>> = vec![None; groups];
        let mut stage_jobs = Vec::new();
        // one immutable snapshot per session, shared by all of that
        // session's occupied slots this step
        let mut snapshots: Vec<Option<Arc<crate::tree::TreeSnapshot>>> =
            vec![None; self.live.len()];
        for g in 0..groups {
            let Some(flow) = self.slots[g].take() else { continue };
            let owner = flow.session;
            let Some(si) = self.live_index(owner) else {
                continue; // owner retired while the flow was in flight
            };
            let ctx = self.group_ctxs[g].take().expect("group ctx in residence");
            let snap = match &snapshots[si] {
                Some(s) => Arc::clone(s),
                None => {
                    let s = Arc::new(self.live[si].tree.snapshot());
                    snapshots[si] = Some(Arc::clone(&s));
                    s
                }
            };
            let sess = &mut self.live[si];
            let stage_ids: Vec<usize> = (g * gs..(g + 1) * gs).collect();
            let caches: Vec<TwoLevelCache> = stage_ids
                .iter()
                .map(|&s| {
                    std::mem::replace(&mut sess.base.caches[s], TwoLevelCache::placeholder())
                })
                .collect();
            let layer_ranges = stage_ids
                .iter()
                .map(|&s| s * lps..(s + 1) * lps)
                .collect();
            // this session's sync commits the group's caches still owe
            // (member caches commit in lockstep, any one's epoch stands in)
            let commits = sess.pending_commits(caches[0].commit_epoch());
            stage_jobs.push(StageJob {
                group: g,
                core: Arc::clone(&self.target),
                ctx,
                caches,
                layer_ranges,
                stage_ids,
                commits,
                commit_target: sess.commit_log.seq(),
                df: flow.df,
                tree: snap,
                metrics: Arc::clone(&self.worker_metrics),
            });
            slot_owner[g] = Some(owner);
        }

        // draft/entry candidates, visited from the round-robin cursor (the
        // draft device — pipeline rank 0 — serves one session per
        // timestep; pending root flows take priority over tree expansion).
        // A pending entry flow is granted as soon as it is visited, so
        // sessions *after* the first entry-carrying one can never be
        // reached this step — the candidate list stops there. On a
        // bank-served step no draft task is built at all: every session's
        // draft state stays resident and deferred commits wait for the
        // owner's next dispatch.
        let mut candidates = Vec::new();
        if banked.is_none() {
            let n = self.live.len();
            for k in 0..n {
                let si = (self.entry_cursor + k) % n;
                let sess = &mut self.live[si];
                let has_entry = sess.entry.is_some();
                let cache = std::mem::replace(
                    &mut sess.base.caches[di],
                    TwoLevelCache::placeholder(),
                );
                let commits = sess.pending_commits(cache.commit_epoch());
                candidates.push(DraftCandidate {
                    tag: si,
                    entry: sess.entry.take(),
                    // moved, not cloned: stage jobs hold their Arc snapshots
                    // already, and the reabsorb loop adopts every tree back
                    tree: std::mem::replace(&mut sess.tree, PredictionTree::placeholder()),
                    cache,
                    commits,
                    commit_target: sess.commit_log.seq(),
                    commit_s: 0.0,
                    spec_gens: self.cfg.spec_inflight,
                    spec_epoch: sess.spec.epoch(),
                    spec: Vec::new(),
                });
                if has_entry {
                    break;
                }
            }
        }
        // dispatched candidate tags, for failure attribution when the
        // whole draft task is lost with its state
        let cand_tags: Vec<usize> = candidates.iter().map(|c| c.tag).collect();
        let draft_job = (!candidates.is_empty()).then(|| DraftJob {
            core: Arc::clone(&self.draft),
            ctx: self.draft_ctx.take().expect("draft ctx in residence"),
            candidates,
            max_children: self.cfg.tree.max_children,
            metrics: Arc::clone(&self.worker_metrics),
        });

        let (draft_reply, stage_replies) =
            workers::run_tasks(self.pool.as_mut(), &self.rt, draft_job, stage_jobs);

        // Reabsorb every lent piece — rebuilding from host truth what died
        // with a lost task — and attribute each failure to the session(s)
        // whose state it touched.
        let mut failures: Vec<(SessionId, String)> = Vec::new();
        let draft_oc = match draft_reply {
            // a bank-served step dispatched no draft task: the grant is
            // the banked flow, with zero draft seconds (the speculation
            // that produced it ran during an earlier step's idle time)
            None => DraftOutcome {
                granted: banked,
                draft_s: 0.0,
            },
            Some(DraftReply::Done(done)) => {
                self.draft_ctx = Some(done.ctx);
                for cand in done.candidates {
                    let sess = &mut self.live[cand.tag];
                    sess.base.caches[di] = cand.cache;
                    sess.tree = cand.tree; // adopt the (possibly expanded) tree
                    sess.entry = cand.entry; // unconsumed entry flows come back
                    sess.t_commit_worker_s += cand.commit_s;
                    // bank the granted candidate's free-running generations
                    // (empty for everyone else); arrival-time epoch filtering
                    // happens inside the bank
                    sess.spec.bank(cand.spec);
                }
                match done.res {
                    Ok(oc) => oc,
                    Err(e) => {
                        // The error struck one candidate's state (its
                        // draft cache / tree may be mid-mutation): fail
                        // exactly that session. `failed_tag: None` means
                        // no candidate was touched — benign to every
                        // session; entries were restored above and the
                        // next step re-dispatches them.
                        if let Some(tag) = done.failed_tag {
                            failures.push((
                                self.live[tag].base.id,
                                format!("draft task failed: {e:#}"),
                            ));
                        }
                        DraftOutcome {
                            granted: None,
                            draft_s: 0.0,
                        }
                    }
                }
            }
            Some(DraftReply::Lost { reason }) => {
                // The draft context and every dispatched candidate's
                // state (tree, draft cache, pending entry flow) died with
                // the task: rebuild the context from host truth and fail
                // exactly the dispatched sessions — undispatched sessions
                // never lent anything and continue untouched.
                self.draft_ctx = Some(self.draft.context());
                for &tag in &cand_tags {
                    failures.push((
                        self.live[tag].base.id,
                        format!("draft task lost: {reason}"),
                    ));
                }
                DraftOutcome {
                    granted: None,
                    draft_s: 0.0,
                }
            }
        };
        let group_ctxs = &mut self.group_ctxs;
        let live = &mut self.live;
        let (outcomes, stage_failures) =
            workers::absorb_stage_dones(groups, stage_replies, |g, ctx, caches, commit_s| {
                group_ctxs[g] = Some(ctx);
                if let Some(owner) = slot_owner[g] {
                    if let Some(si) = live.iter().position(|s| s.base.id == owner) {
                        for (k, c) in caches.into_iter().enumerate() {
                            live[si].base.caches[g * gs + k] = c;
                        }
                        live[si].t_commit_worker_s += commit_s;
                    }
                }
            });
        for f in stage_failures {
            if f.state_lost {
                // the group context (and the owner's member caches) died
                // with the task: a fresh context rebuilds the surviving
                // sessions' device mirrors lazily through the full
                // re-upload fallback — host caches are the truth
                self.group_ctxs[f.group] = Some(self.target.context());
            }
            if let Some(owner) = slot_owner[f.group] {
                failures.push((owner, format!("group {} task failed: {}", f.group, f.reason)));
            }
        }
        // retire commit-log entries every owner of a session has applied
        for sess in self.live.iter_mut() {
            sess.trim_commit_log();
        }
        (draft_oc, outcomes, slot_owner, failures)
    }

    /// One pipeline timestep across all live sessions (Fig. 2, batched):
    /// admission → concurrent task set (stage phase per occupied slot +
    /// draft/entry grant of slot 0) → per-session sync of exiting flows.
    fn step_impl(&mut self) -> Result<StepReport> {
        let mut report = StepReport::default();
        self.steps += 1;
        let seq = self.steps;
        let groups = self.groups();
        let gs = self.cfg.group_size;
        let d_bytes = self.target.cfg.dim * self.target.cfg.width_cap * 4;
        let mut next_slots: Vec<Option<SlotFlow>> = (0..groups).map(|_| None).collect();

        // ---- deadlines (ISSUE 9, `LimitsConfig`): enforced at step
        // boundaries — queued sessions against the queue max-wait and the
        // TTFT deadline (admission is what produces the first token),
        // live sessions against the total-wall deadline ----
        let lim = self.cfg.limits;
        if lim.queue_max_wait_s > 0.0 || lim.ttft_deadline_s > 0.0 || lim.deadline_s > 0.0 {
            let mut qi = 0;
            while qi < self.queue.len() {
                let waited = self.queue[qi].queued_at.elapsed().as_secs_f64();
                let over = |limit: f64| limit > 0.0 && waited > limit;
                let reason = if over(lim.queue_max_wait_s) {
                    Some(format!(
                        "deadline: queued {waited:.3}s > queue_max_wait_s {}",
                        lim.queue_max_wait_s
                    ))
                } else if over(lim.ttft_deadline_s) {
                    Some(format!(
                        "deadline: no first token after {waited:.3}s > ttft_deadline_s {}",
                        lim.ttft_deadline_s
                    ))
                } else if over(lim.deadline_s) {
                    Some(format!(
                        "deadline: queued {waited:.3}s > deadline_s {}",
                        lim.deadline_s
                    ))
                } else {
                    None
                };
                match reason {
                    Some(reason) => {
                        let fid = self.fail_queued(qi, reason);
                        report.finished.push(fid);
                    }
                    None => qi += 1,
                }
            }
        }
        if lim.deadline_s > 0.0 {
            let over: Vec<SessionId> = self
                .live
                .iter()
                .filter(|s| s.base.queued_at.elapsed().as_secs_f64() > lim.deadline_s)
                .map(|s| s.base.id)
                .collect();
            for id in over {
                if let Some(si) = self.live_index(id) {
                    let elapsed = self.live[si].base.queued_at.elapsed().as_secs_f64();
                    let reason = format!(
                        "deadline: session wall {elapsed:.3}s > deadline_s {}",
                        lim.deadline_s
                    );
                    let fid = self.retire(si, Retire::Failed(reason), &mut next_slots);
                    report.finished.push(fid);
                }
            }
        }

        // ---- admission: fill free session slots from the FIFO queue; a
        // failed admission retires only that session and the loop keeps
        // refilling, so a poisoned request never blocks the queue ----
        while self.live.len() < self.max_live && !self.queue.is_empty() {
            let shell = self.queue.pop_front().expect("non-empty queue");
            match self.admit(shell) {
                Ok(sess) => {
                    let id = sess.base.id;
                    let first = *sess.base.tokens.last().expect("prefill emits a token");
                    report.admitted.push(id);
                    report.emitted.push((id, first));
                    self.live.push(sess);
                    let si = self.live.len() - 1;
                    if self.live[si].base.tokens.len() >= self.live[si].max_new {
                        let fid = self.retire(si, Retire::Finished, &mut next_slots);
                        report.finished.push(fid);
                    }
                }
                Err(boxed) => {
                    let (shell, e) = *boxed;
                    let fid = self.fail_admission(shell, format!("admission failed: {e:#}"));
                    report.finished.push(fid);
                }
            }
        }

        // ---- stage + draft/entry phases: the step's task set, executed
        // concurrently on the worker pool (inline when threads = 1) ----
        let (draft_oc, outcomes, slot_owner, failures) = if self.live.is_empty() {
            (
                DraftOutcome {
                    granted: None,
                    draft_s: 0.0,
                },
                (0..groups).map(|_| None).collect(),
                vec![None; groups],
                Vec::new(),
            )
        } else {
            self.run_step_tasks()
        };

        // ---- deterministic post-order: transfer accounting and flow
        // routing in group index order, then the draft grant ----
        let mut exits: Vec<(SessionId, DataFlow)> = Vec::new();
        let mut group_times = vec![0.0f64; groups];
        let mut transfer_times: Vec<f64> = Vec::new();
        for (g, oc) in outcomes.into_iter().enumerate() {
            let Some(oc) = oc else { continue };
            group_times[g] = oc.compute_s;
            for (src, dst) in oc.hops {
                // intra-group hop: same timestep, scheduled transfer
                group_times[g] += self.account_transfer(src, dst, d_bytes, seq);
            }
            // occupancy numerator (ISSUE 10): the busy slot-seconds are
            // attributed to the session whose flow occupied the slot
            if let Some(owner) = slot_owner[g] {
                if let Some(si) = self.live_index(owner) {
                    self.live[si].busy_group_s += group_times[g];
                }
            }
            let Some(out) = oc.flow else { continue };
            let owner = slot_owner[g].expect("an outcome implies a dispatched owner");
            if g + 1 < groups {
                let span_end = (g + 1) * gs;
                transfer_times.push(self.account_transfer(span_end, span_end + 1, d_bytes, seq));
                next_slots[g + 1] = Some(SlotFlow {
                    session: owner,
                    df: out,
                });
            } else {
                exits.push((owner, out));
            }
        }
        let draft_s = draft_oc.draft_s;
        if let Some((si, df)) = draft_oc.granted {
            let id = self.live[si].base.id;
            // draft (rank 0) -> L_1: token ids only
            transfer_times.push(self.account_transfer(0, 1, df.entry_bytes(), seq));
            next_slots[0] = Some(SlotFlow { session: id, df });
            self.entry_cursor = (si + 1) % self.live.len();
        }

        // ---- failure domains (ISSUE 9): a session whose task errored or
        // was lost with a worker retires here as `Failed`, releasing its
        // mirrors/pins/slot; the exits below look sessions up by id, so a
        // failed session's in-flight results are skipped and every other
        // session proceeds bit-identically ----
        for (id, reason) in failures {
            if let Some(si) = self.live_index(id) {
                let fid = self.retire(si, Retire::Failed(reason), &mut next_slots);
                report.finished.push(fid);
            }
        }

        // paper latency model: max(T_draft, C·max(T_group_i) + max(T_t,i))
        let max_group = group_times.iter().cloned().fold(0.0, f64::max);
        let max_tx = transfer_times.iter().cloned().fold(0.0, f64::max);
        let mut step_modeled = draft_s.max(max_group + max_tx);

        // ---- sync phase, split decide/commit (ISSUE 5): each exiting
        // flow verifies one token for its session at the coordinator; the
        // session-scoped cache maintenance becomes a CacheCommit that the
        // owning workers apply before their next forward (overlap_sync
        // on) or that applies right here (the serial reference path) ----
        let mut to_finish: Vec<SessionId> = Vec::new();
        let mut sync_failures: Vec<(SessionId, String)> = Vec::new();
        let overlap = self.cfg.overlap_sync;
        for (id, df) in exits {
            let Some(si) = self.live_index(id) else { continue };
            let decide0 = Instant::now();
            let head_t = Instant::now();
            let hidden = df.hidden.as_ref().context("exit flow carries hidden states")?;
            let logits = match self.target.head(&self.rt, hidden) {
                Ok(l) => l,
                Err(e) => {
                    // per-session decide failure (ISSUE 9): only this
                    // session's verification is poisoned
                    sync_failures.push((id, format!("verify head failed: {e:#}")));
                    continue;
                }
            };
            step_modeled += head_t.elapsed().as_secs_f64();
            let v = self.target.cfg.vocab_size;
            let ablate = self.cfg.ablate_tree_reuse;
            let sess = &mut self.live[si];
            let root_id = sess.tree.id(0);
            let Some(row) = df.ids.iter().position(|&x| x == root_id) else {
                continue; // stale exit (root pruned away earlier)
            };
            let x = select_token(
                &logits[row * v..(row + 1) * v],
                &sess.sampling,
                &mut sess.rng,
            );
            sess.base.emit(x);
            report.emitted.push((id, x));
            let outcome = if ablate {
                PruneOutcome::Miss
            } else {
                sess.tree.prune(x)
            };
            let (op, missed) = match outcome {
                PruneOutcome::Hit { kept_old, .. } => {
                    sess.hits += 1;
                    (
                        CommitOp::Hit {
                            kept_old: Arc::new(kept_old),
                        },
                        false,
                    )
                }
                PruneOutcome::Miss => {
                    sess.misses += 1;
                    (CommitOp::Miss, true)
                }
            };
            let commit = sess
                .commit_log
                .issue_with(|epoch| CacheCommit { epoch, op });
            let mut commit_s = 0.0;
            if overlap {
                sess.commit_log.queue(commit);
            } else {
                // eager path goes through each cache's owning context (the
                // stage's group ctx / the draft ctx) so the device mirrors
                // replay the commit in place instead of re-uploading. A
                // replay error poisons only this session (ISSUE 9): its
                // caches may have applied a prefix of the commit, so the
                // session fails, but co-scheduled caches were untouched.
                let t0 = Instant::now();
                let stages = self.cfg.stages;
                let mut ops = 0usize;
                let mut apply = || -> Result<()> {
                    for (i, cache) in sess.base.caches.iter_mut().enumerate() {
                        if i < stages {
                            self.group_ctxs[i / gs]
                                .as_mut()
                                .expect("group ctx in residence")
                                .apply_commit(&self.rt, &self.target, cache, &commit)?;
                        } else {
                            self.draft_ctx
                                .as_mut()
                                .expect("draft ctx in residence")
                                .apply_commit(&self.rt, &self.draft, cache, &commit)?;
                        }
                        ops += 1;
                    }
                    Ok(())
                };
                if let Err(e) = apply() {
                    sync_failures.push((id, format!("commit replay failed: {e:#}")));
                    continue;
                }
                commit_s = t0.elapsed().as_secs_f64();
                sess.t_commit_eager_s += commit_s;
                sess.commit_ops_eager += ops as u64;
            }
            if missed {
                // the tree is rebuilt from scratch: every banked
                // speculative generation assumed state that no longer
                // exists (ISSUE 10)
                sess.spec.bump_epoch();
                // authoritative past length without reading a cache that
                // may still owe deferred commits: every emitted token
                // after the prefill's first promoted exactly one root
                let root_pos = sess.base.prompt_ids.len() + sess.base.tokens.len() - 1;
                sess.tree = PredictionTree::new(self.cfg.tree, sess.budget, x, root_pos);
                // in-flight flows of this session are stale: restart
                for slot in next_slots.iter_mut() {
                    if slot.as_ref().is_some_and(|f| f.session == id) {
                        *slot = None;
                    }
                }
                sess.entry = Some(DataFlow::root(&sess.tree));
            }
            sess.t_decide_s += decide0.elapsed().as_secs_f64() - commit_s;
            if sess.base.tokens.len() >= sess.max_new || x == tokenizer::EOS_ID {
                to_finish.push(id);
            }
        }

        // attribute the step's modeled cost evenly across the sessions that
        // were live this step — including the ones about to finish, so the
        // per-session shares sum exactly to the total modeled serving time
        // and a finishing session's last timestep is counted
        if !self.live.is_empty() {
            let share = step_modeled / self.live.len() as f64;
            for s in &mut self.live {
                s.timesteps += 1;
                s.modeled_s += share;
            }
        }
        for (id, reason) in sync_failures {
            if let Some(si) = self.live_index(id) {
                let fid = self.retire(si, Retire::Failed(reason), &mut next_slots);
                report.finished.push(fid);
            }
        }
        for id in to_finish {
            if let Some(si) = self.live_index(id) {
                let fid = self.retire(si, Retire::Finished, &mut next_slots);
                report.finished.push(fid);
            }
        }

        self.slots = next_slots;
        report.live = self.live.len();
        report.queued = self.queue.len();
        report.modeled_step_s = step_modeled;

        // stall detection: with live sessions, some token must appear
        // within one entry round-trip (slot-0 wait + pipeline traversal)
        if report.made_progress() || self.live.is_empty() {
            self.stalled_for = 0;
        } else {
            self.stalled_for += 1;
            let limit = ((self.max_live + groups) as u64) * 4 + 64;
            let live_tokens: usize = self.live.iter().map(|s| s.base.tokens.len()).sum();
            let tree_nodes: usize = self.live.iter().map(|s| s.tree.len()).sum();
            if self.stalled_for > limit {
                // undrained commit depth per cache owner (summed over
                // sessions; the draft column last) — a deadlock in the
                // decide/commit protocol shows up here as a group whose
                // depth never drains
                let di = self.cfg.stages;
                let pending: Vec<usize> = (0..groups)
                    .map(|g| {
                        self.live
                            .iter()
                            .map(|s| s.pending_depth(s.base.caches[g * gs].commit_epoch()))
                            .sum()
                    })
                    .collect();
                let pending_draft: usize = self
                    .live
                    .iter()
                    .map(|s| s.pending_depth(s.base.caches[di].commit_epoch()))
                    .sum();
                // in-flight speculation per session (ISSUE 10): banked
                // (gen, assumed epoch) pairs against each live epoch — a
                // bank that never drains or an epoch that never advances
                // shows up here
                let spec_state: Vec<(Vec<(usize, u64)>, u64)> = self
                    .live
                    .iter()
                    .map(|s| (s.spec.inflight(), s.spec.epoch()))
                    .collect();
                let diag = format!(
                    "scheduler stalled at step {}: {} steps without progress \
                     ({} live sessions holding {live_tokens} decoded tokens and \
                     {tree_nodes} tree nodes, {} queued, {} occupied pipeline \
                     slots, undrained commits per group {pending:?} + draft \
                     {pending_draft}, speculative generations in flight per \
                     session [(gen, epoch) pairs, live epoch] {spec_state:?})",
                    self.steps,
                    self.stalled_for,
                    self.live.len(),
                    self.queue.len(),
                    self.slots.iter().flatten().count(),
                );
                // scoped guard (ISSUE 9): fail only the implicated sessions
                // — those holding undrained commits or sitting idle with no
                // entry and no in-flight flow — instead of bailing out the
                // whole batch. If nothing is clearly implicated (a scheduler
                // bug rather than a stuck session), fail every live session
                // so the engine still never wedges.
                let mut victims: Vec<SessionId> = self
                    .live
                    .iter()
                    .filter(|s| {
                        let undrained = !s.commit_log.is_empty();
                        let idle = s.entry.is_none()
                            && !self
                                .slots
                                .iter()
                                .flatten()
                                .any(|f| f.session == s.base.id);
                        undrained || idle
                    })
                    .map(|s| s.base.id)
                    .collect();
                if victims.is_empty() {
                    victims = self.live.iter().map(|s| s.base.id).collect();
                }
                let mut slots = std::mem::take(&mut self.slots);
                for id in victims {
                    if let Some(si) = self.live_index(id) {
                        let fid =
                            self.retire(si, Retire::Failed(format!("stalled: {diag}")), &mut slots);
                        report.finished.push(fid);
                    }
                }
                self.slots = slots;
                self.stalled_for = 0;
                report.live = self.live.len();
                report.queued = self.queue.len();
            }
        }
        Ok(report)
    }
}

impl ScheduledEngine for PipeDecDbEngine {
    fn kind(&self) -> EngineKind {
        EngineKind::PipeDecDb
    }

    fn config(&self) -> &EngineConfig {
        &self.cfg
    }

    fn submit(&mut self, req: DecodeRequest, sink: Box<dyn TokenSink>) -> Result<SessionId> {
        // admission control (ISSUE 9): shed over-capacity submits with a
        // typed error callers can downcast, rather than growing the queue
        // without bound
        let cap = self.cfg.limits.queue_cap;
        if cap > 0 && self.queue.len() >= cap {
            return Err(ShedError {
                queue_depth: self.queue.len(),
            }
            .into());
        }
        let (max_new, _, _) = req.resolve(&self.cfg);
        anyhow::ensure!(max_new >= 1, "max_new_tokens must be >= 1");
        anyhow::ensure!(
            max_new + 2 < self.target.cfg.past_cap,
            "max_new_tokens {} exceeds the model context budget ({})",
            max_new,
            self.target.cfg.past_cap
        );
        let max_prompt = self.target.cfg.past_cap - max_new - 2;
        let id = SessionId(self.next_id);
        self.next_id += 1;
        let mut shell = Session::new(id, req, sink);
        shell.prompt_ids.truncate(max_prompt);
        anyhow::ensure!(!shell.prompt_ids.is_empty(), "empty prompt");
        self.queue.push_back(shell);
        Ok(id)
    }

    fn step(&mut self) -> Result<StepReport> {
        let r = self.step_impl();
        if r.is_err() {
            // a failed step's partial worker timings must not leak into
            // the next finished session's metrics
            let _ = self.worker_metrics.drain();
        }
        r
    }

    fn cancel(&mut self, id: SessionId) -> bool {
        if let Some(qi) = self.queue.iter().position(|s| s.id == id) {
            let shell = self.queue.remove(qi).expect("position is in bounds");
            self.done
                .push(shell.into_record(SessionStatus::Cancelled, None));
            return true;
        }
        if let Some(si) = self.live_index(id) {
            self.retire(si, Retire::Cancelled, &mut []);
            return true;
        }
        false
    }

    fn poll(&mut self, id: SessionId) -> Option<DecodeOutput> {
        let i = self
            .done
            .iter()
            .position(|s| s.id == id && s.output.is_some())?;
        self.done.remove(i).output
    }

    fn status(&self, id: SessionId) -> Option<SessionStatus> {
        if self.queue.iter().any(|s| s.id == id) {
            return Some(SessionStatus::Queued);
        }
        if self.live.iter().any(|s| s.base.id == id) {
            return Some(SessionStatus::Running);
        }
        self.done.iter().find(|s| s.id == id).map(|s| s.status.clone())
    }

    fn has_work(&self) -> bool {
        !self.queue.is_empty() || !self.live.is_empty()
    }
}

impl Engine for PipeDecDbEngine {
    fn kind(&self) -> EngineKind {
        EngineKind::PipeDecDb
    }

    fn config(&self) -> &EngineConfig {
        &self.cfg
    }

    /// One-shot conformance surface: a decode is one session stepped to
    /// completion, streaming each verified token as its step reports it.
    fn decode(&mut self, req: &DecodeRequest, sink: &mut dyn TokenSink) -> Result<DecodeOutput> {
        let (max_new, _, _) = req.resolve(&self.cfg);
        let id = ScheduledEngine::submit(self, req.clone(), Box::new(NullSink))?;
        let groups = (self.cfg.stages / self.cfg.group_size) as u64;
        let max_steps = (max_new as u64 + 8) * (groups + 2) * 4 + 64;
        let mut steps = 0u64;
        let mut emitted = 0usize;
        loop {
            let rep = ScheduledEngine::step(self)?;
            for &(sid, tok) in &rep.emitted {
                if sid == id {
                    sink.on_token(tok);
                    emitted += 1;
                }
            }
            if rep.finished.contains(&id) {
                // a scheduled session that failed still produces a record
                // (partial output); the one-shot surface reports it as an
                // error so `decode` callers keep their Ok-means-complete
                // contract
                if let Some(SessionStatus::Failed { reason }) = ScheduledEngine::status(self, id) {
                    let _ = ScheduledEngine::poll(self, id);
                    anyhow::bail!("session failed: {reason}");
                }
                return ScheduledEngine::poll(self, id)
                    .context("finished session lost its output");
            }
            steps += 1;
            anyhow::ensure!(
                steps <= max_steps,
                "step budget ({max_steps}) exceeded — engine stalled with \
                 {emitted}/{max_new} tokens emitted for session {id} \
                 ({} live, {} queued after the last step)",
                rep.live,
                rep.queued,
            );
        }
    }
}
