//! SpecPipe-DB: the paper's multi-request variant — PipeDec with dynamic
//! batching, filling pipeline slots with speculative tokens from
//! *different* requests.
//!
//! The single-task [`super::PipeDecEngine`] commits every pipeline stage to
//! one request: after a miss the pipeline refills for `groups` timesteps
//! producing nothing, and at the start/end of a request most slots idle.
//! SpecPipe-DB serves the same per-request math (shared with the solo
//! engine via [`super::pipeline`]) but schedules it *continuously*:
//!
//! * every live [`Session`] owns its prediction tree plus a full set of
//!   per-request [`TwoLevelCache`]s (one per stage + the draft cache), so
//!   requests never share KV state — device mirrors are released at
//!   session teardown via [`ModelHandles::release_cache`];
//! * the pipeline itself is a ring of `groups` slots, each holding one
//!   in-flight [`DataFlow`] tagged with its owning session; per timestep
//!   every occupied slot advances one group (possibly a different session
//!   per slot — the dynamic batch);
//! * pipeline slot 0 is granted round-robin: a session's pending root flow
//!   (fresh admission or miss restart) or one draft expansion of its tree
//!   (the draft device serves one session per timestep, exactly like rank
//!   0 in the paper);
//! * queued sessions are admitted whenever a session slot frees up, so
//!   admission overlaps with decode — the refill/idle timesteps that solo
//!   PipeDec wastes now carry other requests' flows, which is where the
//!   Fig. 8 throughput gain over one-at-a-time serving comes from;
//! * sync points (verify / prune / promote) are per-session, so pruning
//!   propagation never crosses sessions and greedy outputs are identical
//!   to a solo decode (asserted by `rust/tests/scheduler.rs` and the
//!   `fig8_throughput` bench).
//!
//! Served both ways: natively as a [`ScheduledEngine`] (the continuous
//! server loop) and as a one-shot [`Engine`] (a decode = one session
//! stepped to completion), so `EngineKind::PipeDecDb` passes the same
//! conformance suite as every other registry entry.

use std::collections::VecDeque;
use std::path::Path;
use std::time::Instant;

use anyhow::{Context, Result};

use super::pipeline::{self, DataFlow};
use super::sampling::{select_token, Sampling};
use crate::config::EngineConfig;
use crate::engine::{
    DecodeOutput, DecodeRequest, Engine, EngineKind, NullSink, ScheduledEngine, Session,
    SessionId, SessionRecord, SessionStatus, SpecStats, StepReport, TokenSink,
};
use crate::kvcache::TwoLevelCache;
use crate::metrics::Metrics;
use crate::model::ModelHandles;
use crate::runtime::Runtime;
use crate::schedule::CentralScheduler;
use crate::tokenizer;
use crate::transport::{LinkModel, LinkStats};
use crate::tree::{PredictionTree, PruneOutcome};
use crate::util::XorShiftRng;

/// One in-flight data flow, tagged with its owning session.
struct SlotFlow {
    session: SessionId,
    df: DataFlow,
}

/// A live session: the shared [`Session`] shell plus the SpecPipe-DB
/// decode state (tree, per-request sampling/RNG, counters).
/// `base.caches` holds one cache per pipeline stage plus the draft cache
/// last (index `cfg.stages`).
struct DbSession {
    base: Session,
    tree: PredictionTree,
    rng: XorShiftRng,
    sampling: Sampling,
    max_new: usize,
    budget: usize,
    /// Flow waiting to enter pipeline slot 0 (root after admission or a
    /// miss restart).
    entry: Option<DataFlow>,
    timesteps: u64,
    hits: u64,
    misses: u64,
    modeled_s: f64,
    prefill_s: f64,
    wall0: Instant,
}

/// The SpecPipe-DB engine over AOT artifacts.
pub struct PipeDecDbEngine {
    rt: Runtime,
    target: ModelHandles,
    draft: ModelHandles,
    pub cfg: EngineConfig,
    layers_per_stage: usize,
    link: LinkModel,
    pub link_stats: LinkStats,
    scheduler: CentralScheduler,
    next_id: u64,
    queue: VecDeque<Session>,
    live: Vec<DbSession>,
    done: Vec<SessionRecord>,
    /// Pipeline ring: one in-flight flow per timestep group.
    slots: Vec<Option<SlotFlow>>,
    /// Round-robin cursor over `live` for granting slot 0.
    entry_cursor: usize,
    /// Maximum concurrently admitted sessions (= pipeline groups).
    max_live: usize,
    steps: u64,
    stalled_for: u64,
}

impl PipeDecDbEngine {
    pub fn new(artifact_dir: &Path, mut cfg: EngineConfig) -> Result<Self> {
        cfg.validate()?;
        let rt = Runtime::cpu()?;
        let target =
            ModelHandles::load_with_width(&rt, artifact_dir, "target", cfg.tree.max_width)?;
        let draft =
            ModelHandles::load_with_width(&rt, artifact_dir, "draft", cfg.tree.max_width)?;
        anyhow::ensure!(
            target.cfg.n_layers % cfg.stages == 0,
            "stages {} must divide target layers {}",
            cfg.stages,
            target.cfg.n_layers
        );
        let layers_per_stage = target.cfg.n_layers / cfg.stages;
        cfg.tree.max_width = cfg
            .tree
            .max_width
            .min(target.cfg.width_cap)
            .min(draft.cfg.width_cap);
        cfg.tree.max_children = cfg.tree.max_children.min(target.cfg.vocab_size);
        let groups = cfg.stages / cfg.group_size;
        Ok(Self {
            rt,
            target,
            draft,
            cfg,
            layers_per_stage,
            link: LinkModel::pcie_p2p(),
            link_stats: LinkStats::default(),
            scheduler: CentralScheduler::new(),
            next_id: 0,
            queue: VecDeque::new(),
            live: Vec::new(),
            done: Vec::new(),
            slots: (0..groups).map(|_| None).collect(),
            entry_cursor: 0,
            max_live: groups,
            steps: 0,
            stalled_for: 0,
        })
    }

    fn groups(&self) -> usize {
        self.cfg.stages / self.cfg.group_size
    }

    fn live_index(&self, id: SessionId) -> Option<usize> {
        self.live.iter().position(|s| s.base.id == id)
    }

    /// Account one inter-node transfer through the central scheduler and
    /// the link model; returns the modeled wire seconds.
    fn account_transfer(&mut self, src: usize, dst: usize, bytes: usize, seq: u64) -> f64 {
        let id = self.scheduler.submit(src, dst, bytes, seq);
        let dispatched = self.scheduler.tick();
        debug_assert!(dispatched.iter().any(|d| d.task.id == id));
        self.scheduler.notify_finish(id);
        self.scheduler.tick();
        self.link_stats.record(bytes, &self.link);
        self.link.transfer_time(bytes)
    }

    /// Admit one queued session: mint its per-request caches, run the
    /// pipeline prefill (emitting the first token), and build its tree.
    fn admit(&mut self, mut shell: Session) -> Result<DbSession> {
        let (max_new, sampling, seed) = shell.req.resolve(&self.cfg);
        let tc = self.target.cfg.clone();
        let dc = self.draft.cfg.clone();
        let lps = self.layers_per_stage;
        let stages = self.cfg.stages;
        let mut rng = XorShiftRng::new(seed);

        // per-session caches: one per pipeline stage + the draft cache last
        let mut caches: Vec<TwoLevelCache> = (0..stages)
            .map(|_| TwoLevelCache::new(lps, tc.n_heads, tc.head_dim, tc.past_cap, tc.tree_cap))
            .collect();
        caches.push(TwoLevelCache::new(
            dc.n_layers,
            dc.n_heads,
            dc.head_dim,
            dc.past_cap,
            dc.tree_cap,
        ));
        shell.caches = caches;

        // pipeline prefill through all target stages (plain sequential
        // pre-filling, §3.4.1), as in the solo engine's prefill
        let w = tc.width_cap;
        let t0 = Instant::now();
        let prompt = shell.prompt_ids.clone();
        let mut last_h = None;
        let mut last_count = 0;
        for chunk in prompt.chunks(w) {
            let start = shell.caches[0].past_len();
            let mut h = self.target.embed(&self.rt, chunk)?;
            for s in 0..stages {
                let range = s * lps..(s + 1) * lps;
                h = self.target.prefill_chunk(
                    &self.rt,
                    range,
                    &mut shell.caches[s],
                    h,
                    chunk.len(),
                    start,
                )?;
            }
            last_count = chunk.len();
            last_h = Some(h);
        }
        let h = last_h.context("empty prompt")?;
        let logits = self.target.head(&self.rt, &h)?;
        let v = tc.vocab_size;
        let row = &logits[(last_count - 1) * v..last_count * v];
        let first = select_token(row, &sampling, &mut rng);
        // draft prefill (parallel with the target on the real testbed)
        self.draft
            .full_prefill(&self.rt, &mut shell.caches[stages], &prompt)?;
        let prefill_s = t0.elapsed().as_secs_f64();

        let budget = tc.tree_cap.min(dc.tree_cap);
        let tree = PredictionTree::new(self.cfg.tree, budget, first, prompt.len());
        shell.status = SessionStatus::Running;
        shell.emit(first);
        Ok(DbSession {
            entry: Some(DataFlow::root(&tree)),
            tree,
            rng,
            sampling,
            max_new,
            budget,
            timesteps: 0,
            hits: 0,
            misses: 0,
            modeled_s: 0.0,
            prefill_s,
            wall0: Instant::now(),
            base: shell,
        })
    }

    /// Remove a live session: purge its in-flight flows, release its
    /// device KV mirrors, drop its host caches, and (when finished) build
    /// the final [`DecodeOutput`]. Returns the session id.
    fn retire(
        &mut self,
        si: usize,
        finished: bool,
        next_slots: &mut [Option<SlotFlow>],
    ) -> SessionId {
        let sess = self.live.remove(si);
        let id = sess.base.id;
        if self.entry_cursor > si {
            self.entry_cursor -= 1;
        }
        for slot in self.slots.iter_mut().chain(next_slots.iter_mut()) {
            if slot.as_ref().is_some_and(|f| f.session == id) {
                *slot = None;
            }
        }
        // per-request cache churn would leak device mirrors without this
        // (the ROADMAP eviction-hook note from PR 2)
        let stages = self.cfg.stages;
        for (i, c) in sess.base.caches.iter().enumerate() {
            if i < stages {
                self.target.release_cache(c.id());
            } else {
                self.draft.release_cache(c.id());
            }
        }
        let record = if finished {
            let mut metrics = Metrics::new();
            metrics.incr("tokens", sess.base.tokens.len() as u64);
            metrics.incr("timesteps", sess.timesteps);
            metrics.incr("hits", sess.hits);
            metrics.incr("misses", sess.misses);
            metrics.record("prefill_s", sess.prefill_s);
            let output = DecodeOutput {
                text: tokenizer::decode(&sess.base.tokens),
                tokens: sess.base.tokens.clone(),
                wall_s: sess.wall0.elapsed().as_secs_f64(),
                modeled_s: sess.modeled_s,
                spec: Some(SpecStats {
                    timesteps: sess.timesteps,
                    rounds: 0,
                    hits: sess.hits,
                    misses: sess.misses,
                    accepted_per_round: 0.0,
                }),
                metrics,
            };
            sess.base.into_record(SessionStatus::Finished, Some(output))
        } else {
            sess.base.into_record(SessionStatus::Cancelled, None)
        };
        self.done.push(record);
        id
    }

    /// One pipeline timestep across all live sessions (Fig. 2, batched):
    /// admission → stage phase per occupied slot → draft/entry grant of
    /// slot 0 → per-session sync of exiting flows.
    fn step_impl(&mut self) -> Result<StepReport> {
        let mut report = StepReport::default();
        self.steps += 1;
        let seq = self.steps;
        let groups = self.groups();
        let gs = self.cfg.group_size;
        let lps = self.layers_per_stage;
        let d_bytes = self.target.cfg.dim * self.target.cfg.width_cap * 4;
        let mut next_slots: Vec<Option<SlotFlow>> = (0..groups).map(|_| None).collect();

        // ---- admission: fill free session slots from the FIFO queue ----
        while self.live.len() < self.max_live && !self.queue.is_empty() {
            let shell = self.queue.pop_front().expect("non-empty queue");
            let sess = self.admit(shell)?;
            let id = sess.base.id;
            let first = *sess.base.tokens.last().expect("prefill emits a token");
            report.admitted.push(id);
            report.emitted.push((id, first));
            self.live.push(sess);
            let si = self.live.len() - 1;
            if self.live[si].base.tokens.len() >= self.live[si].max_new {
                let fid = self.retire(si, true, &mut next_slots);
                report.finished.push(fid);
            }
        }

        // ---- stage phase: every occupied slot advances one group ----
        let mut exits: Vec<(SessionId, DataFlow)> = Vec::new();
        let mut group_times = vec![0.0f64; groups];
        let mut transfer_times: Vec<f64> = Vec::new();
        for g in 0..groups {
            let Some(flow) = self.slots[g].take() else { continue };
            let owner = flow.session;
            let Some(si) = self.live_index(owner) else {
                continue; // owner retired while the flow was in flight
            };
            let span = g * gs..(g + 1) * gs;
            let mut df = Some(flow.df);
            for stage in span.clone() {
                let Some(cur) = df.take() else { break };
                let range = stage * lps..(stage + 1) * lps;
                let sess = &mut self.live[si];
                let (out, secs) = pipeline::run_stage(
                    &mut self.target,
                    &self.rt,
                    range,
                    &mut sess.base.caches[stage],
                    cur,
                    &sess.tree,
                )?;
                group_times[g] += secs;
                if out.is_some() && stage + 1 < span.end {
                    // intra-group hop: same timestep, scheduled transfer
                    group_times[g] += self.account_transfer(stage + 1, stage + 2, d_bytes, seq);
                }
                df = out;
            }
            let Some(out) = df else { continue };
            if g + 1 < groups {
                transfer_times.push(self.account_transfer(span.end, span.end + 1, d_bytes, seq));
                next_slots[g + 1] = Some(SlotFlow {
                    session: owner,
                    df: out,
                });
            } else {
                exits.push((owner, out));
            }
        }

        // ---- draft/entry phase: grant slot 0 to one live session ----
        // (the draft device — pipeline rank 0 — serves one session per
        // timestep; pending root flows take priority over tree expansion)
        let mut draft_s = 0.0f64;
        if next_slots[0].is_none() {
            let n = self.live.len();
            let mc = self.cfg.tree.max_children;
            let di = self.cfg.stages; // draft cache index in session caches
            for k in 0..n {
                let si = (self.entry_cursor + k) % n;
                let (id, df) = if let Some(df) = self.live[si].entry.take() {
                    (self.live[si].base.id, df)
                } else {
                    let sess = &mut self.live[si];
                    let (flow, secs) = pipeline::draft_expand(
                        &mut self.draft,
                        &self.rt,
                        &mut sess.base.caches[di],
                        &mut sess.tree,
                        mc,
                    )?;
                    draft_s += secs;
                    let Some(df) = flow else { continue };
                    (self.live[si].base.id, df)
                };
                // draft (rank 0) -> L_1: token ids only
                transfer_times.push(self.account_transfer(0, 1, df.entry_bytes(), seq));
                next_slots[0] = Some(SlotFlow { session: id, df });
                self.entry_cursor = (si + 1) % n;
                break;
            }
        }

        // paper latency model: max(T_draft, C·max(T_group_i) + max(T_t,i))
        let max_group = group_times.iter().cloned().fold(0.0, f64::max);
        let max_tx = transfer_times.iter().cloned().fold(0.0, f64::max);
        let mut step_modeled = draft_s.max(max_group + max_tx);

        // ---- sync phase: each exiting flow verifies one token for its
        // session; pruning propagation is scoped to that session ----
        let mut to_finish: Vec<SessionId> = Vec::new();
        for (id, df) in exits {
            let Some(si) = self.live_index(id) else { continue };
            let head_t = Instant::now();
            let hidden = df.hidden.as_ref().context("exit flow carries hidden states")?;
            let logits = self.target.head(&self.rt, hidden)?;
            step_modeled += head_t.elapsed().as_secs_f64();
            let v = self.target.cfg.vocab_size;
            let ablate = self.cfg.ablate_tree_reuse;
            let sess = &mut self.live[si];
            let root_id = sess.tree.id(0);
            let Some(row) = df.ids.iter().position(|&x| x == root_id) else {
                continue; // stale exit (root pruned away earlier)
            };
            let x = select_token(
                &logits[row * v..(row + 1) * v],
                &sess.sampling,
                &mut sess.rng,
            );
            sess.base.emit(x);
            report.emitted.push((id, x));
            let outcome = if ablate {
                PruneOutcome::Miss
            } else {
                sess.tree.prune(x)
            };
            match outcome {
                PruneOutcome::Hit { kept_old, .. } => {
                    sess.hits += 1;
                    // all stage caches and the draft cache promote/compact
                    for c in &mut sess.base.caches {
                        c.promote_root_to_past()?;
                        c.compact_tree(&kept_old);
                    }
                }
                PruneOutcome::Miss => {
                    sess.misses += 1;
                    for c in &mut sess.base.caches {
                        c.promote_root_to_past()?;
                        c.clear_tree();
                    }
                    let root_pos = sess.base.caches[0].past_len();
                    sess.tree = PredictionTree::new(self.cfg.tree, sess.budget, x, root_pos);
                    // in-flight flows of this session are stale: restart
                    for slot in next_slots.iter_mut() {
                        if slot.as_ref().is_some_and(|f| f.session == id) {
                            *slot = None;
                        }
                    }
                    sess.entry = Some(DataFlow::root(&sess.tree));
                }
            }
            if sess.base.tokens.len() >= sess.max_new || x == tokenizer::EOS_ID {
                to_finish.push(id);
            }
        }

        // attribute the step's modeled cost evenly across the sessions that
        // were live this step — including the ones about to finish, so the
        // per-session shares sum exactly to the total modeled serving time
        // and a finishing session's last timestep is counted
        if !self.live.is_empty() {
            let share = step_modeled / self.live.len() as f64;
            for s in &mut self.live {
                s.timesteps += 1;
                s.modeled_s += share;
            }
        }
        for id in to_finish {
            if let Some(si) = self.live_index(id) {
                let fid = self.retire(si, true, &mut next_slots);
                report.finished.push(fid);
            }
        }

        self.slots = next_slots;
        report.live = self.live.len();
        report.queued = self.queue.len();
        report.modeled_step_s = step_modeled;

        // stall detection: with live sessions, some token must appear
        // within one entry round-trip (slot-0 wait + pipeline traversal)
        if report.made_progress() || self.live.is_empty() {
            self.stalled_for = 0;
        } else {
            self.stalled_for += 1;
            let limit = ((self.max_live + groups) as u64) * 4 + 64;
            anyhow::ensure!(
                self.stalled_for <= limit,
                "scheduler stalled: {} steps without progress ({} live sessions)",
                self.stalled_for,
                self.live.len()
            );
        }
        Ok(report)
    }
}

impl ScheduledEngine for PipeDecDbEngine {
    fn kind(&self) -> EngineKind {
        EngineKind::PipeDecDb
    }

    fn config(&self) -> &EngineConfig {
        &self.cfg
    }

    fn submit(&mut self, req: DecodeRequest, sink: Box<dyn TokenSink>) -> Result<SessionId> {
        let (max_new, _, _) = req.resolve(&self.cfg);
        anyhow::ensure!(max_new >= 1, "max_new_tokens must be >= 1");
        anyhow::ensure!(
            max_new + 2 < self.target.cfg.past_cap,
            "max_new_tokens {} exceeds the model context budget ({})",
            max_new,
            self.target.cfg.past_cap
        );
        let max_prompt = self.target.cfg.past_cap - max_new - 2;
        let id = SessionId(self.next_id);
        self.next_id += 1;
        let mut shell = Session::new(id, req, sink);
        shell.prompt_ids.truncate(max_prompt);
        anyhow::ensure!(!shell.prompt_ids.is_empty(), "empty prompt");
        self.queue.push_back(shell);
        Ok(id)
    }

    fn step(&mut self) -> Result<StepReport> {
        self.step_impl()
    }

    fn cancel(&mut self, id: SessionId) -> bool {
        if let Some(qi) = self.queue.iter().position(|s| s.id == id) {
            let shell = self.queue.remove(qi).expect("position is in bounds");
            self.done
                .push(shell.into_record(SessionStatus::Cancelled, None));
            return true;
        }
        if let Some(si) = self.live_index(id) {
            self.retire(si, false, &mut []);
            return true;
        }
        false
    }

    fn poll(&mut self, id: SessionId) -> Option<DecodeOutput> {
        let i = self
            .done
            .iter()
            .position(|s| s.id == id && s.output.is_some())?;
        self.done.remove(i).output
    }

    fn status(&self, id: SessionId) -> Option<SessionStatus> {
        if self.queue.iter().any(|s| s.id == id) {
            return Some(SessionStatus::Queued);
        }
        if self.live.iter().any(|s| s.base.id == id) {
            return Some(SessionStatus::Running);
        }
        self.done.iter().find(|s| s.id == id).map(|s| s.status)
    }

    fn has_work(&self) -> bool {
        !self.queue.is_empty() || !self.live.is_empty()
    }
}

impl Engine for PipeDecDbEngine {
    fn kind(&self) -> EngineKind {
        EngineKind::PipeDecDb
    }

    fn config(&self) -> &EngineConfig {
        &self.cfg
    }

    /// One-shot conformance surface: a decode is one session stepped to
    /// completion, streaming each verified token as its step reports it.
    fn decode(&mut self, req: &DecodeRequest, sink: &mut dyn TokenSink) -> Result<DecodeOutput> {
        let (max_new, _, _) = req.resolve(&self.cfg);
        let id = ScheduledEngine::submit(self, req.clone(), Box::new(NullSink))?;
        let groups = (self.cfg.stages / self.cfg.group_size) as u64;
        let max_steps = (max_new as u64 + 8) * (groups + 2) * 4 + 64;
        let mut steps = 0u64;
        loop {
            let rep = self.step_impl()?;
            for &(sid, tok) in &rep.emitted {
                if sid == id {
                    sink.on_token(tok);
                }
            }
            if rep.finished.contains(&id) {
                return ScheduledEngine::poll(self, id)
                    .context("finished session lost its output");
            }
            steps += 1;
            anyhow::ensure!(
                steps <= max_steps,
                "timestep budget exceeded — engine stalled"
            );
        }
    }
}
