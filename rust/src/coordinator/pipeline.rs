//! Per-request pipeline mechanics shared by the single-task PipeDec engine
//! and the multi-request SpecPipe-DB scheduler: the [`DataFlow`] unit that
//! travels between pipeline nodes, the draft phase (expand one tree layer),
//! and the stage phase (run one stage's layer span over a flow).
//!
//! Both engines own *which* flows run *when* (one request's successive tree
//! layers vs. a dynamic batch of flows from different sessions); the
//! per-flow math here is identical, so extracting it guarantees the DB
//! scheduler's per-session outputs match solo PipeDec token-for-token.
//!
//! Since ISSUE 4 both entry points take the split model state — a shared
//! read-only [`ModelCore`] plus the caller's mutable [`StageContext`] — so
//! a timestep's task set dispatches onto the pipeline worker pool
//! ([`super::workers`]) as well as running inline on one thread. Since
//! ISSUE 5 the stage phase reads a [`TreeSnapshot`] (never the canonical
//! tree), and the sync phase's cache maintenance is a replayable
//! [`crate::kvcache::CacheCommit`] — applied at the sync point by the
//! owning [`StageContext::apply_commit`] (eager path) or deferred into
//! the owning worker's next job (the overlapped path). Both routes go
//! through the context so the device KV mirror replays each commit in
//! place (ISSUE 7) instead of re-uploading.

use std::time::Instant;

use anyhow::Result;

use super::sampling::top_candidates;
use super::spec::{SpecEpoch, SpecExpansion};
use crate::faultinject::{self, Site};
use crate::kvcache::TwoLevelCache;
use crate::model::{bias, ModelCore, StageContext};
use crate::runtime::Runtime;
use crate::tree::{PredictionTree, TreeSnapshot};

/// A data flow between pipeline nodes: the node ids of one tree layer plus
/// the hidden states produced by the previous stage (absent for the
/// draft -> L_1 edge, which carries token ids resolved through the tree).
#[derive(Debug, Clone)]
pub struct DataFlow {
    pub ids: Vec<u64>,
    /// `[W, d]` padded; rows `0..ids.len()` valid.
    pub hidden: Option<Vec<f32>>,
}

impl DataFlow {
    /// The entry flow carrying a (re)initialized tree's root.
    pub fn root(tree: &PredictionTree) -> Self {
        Self {
            ids: vec![tree.id(0)],
            hidden: None,
        }
    }

    /// Modeled wire bytes of this flow on the draft -> L_1 edge (token ids
    /// only).
    pub fn entry_bytes(&self) -> usize {
        self.ids.len() * 8
    }
}

/// Forward one contiguous block of unprocessed tree rows (`indices`, a
/// BFS suffix starting at the cache's tree length) through the draft
/// model and return its logits. Shared by the in-step expansion and the
/// free-running speculation path (ISSUE 10).
fn draft_forward_rows(
    draft: &ModelCore,
    rt: &Runtime,
    ctx: &mut StageContext,
    cache: &mut TwoLevelCache,
    tree: &PredictionTree,
    indices: &[usize],
) -> Result<Vec<f32>> {
    let dc = &draft.cfg;
    let start = cache.tree_len();
    anyhow::ensure!(
        indices.len() <= dc.width_cap,
        "frontier wider than width cap"
    );
    let tokens: Vec<u32> = indices.iter().map(|&i| tree.token(i)).collect();
    let mut pos = vec![0i32; dc.width_cap];
    for (r, &i) in indices.iter().enumerate() {
        pos[r] = tree.position_of(i) as i32;
    }
    let rows = tree.bias_rows(indices, dc.tree_cap, bias::NEG);
    let tree_bias =
        bias::pad_tree_bias_rows(rows, indices.len(), start, dc.width_cap, dc.tree_cap);
    draft.full_forward_tree_block(rt, ctx, cache, &tokens, &pos, &tree_bias)
}

/// Draft phase: process the unprocessed BFS suffix of `tree` through the
/// draft model, expand the tree by one width-capped layer of
/// top-`max_children` candidates, and return the new layer's data flow
/// plus the measured draft seconds.
///
/// The suffix normally is exactly the frontier layer, but it can span
/// several layers when banked speculative expansions (ISSUE 10) were
/// applied to the tree after a prune dropped the draft cache's shadow
/// rows; intermediate layers are then caught up one at a time (cache
/// rows only, logits discarded) before the frontier is expanded.
pub fn draft_expand(
    draft: &ModelCore,
    rt: &Runtime,
    ctx: &mut StageContext,
    cache: &mut TwoLevelCache,
    tree: &mut PredictionTree,
    max_children: usize,
) -> Result<(Option<DataFlow>, f64)> {
    let dc = &draft.cfg;
    if cache.tree_len() >= tree.len() || tree.len() >= cache.tree_cap() {
        return Ok((None, 0.0)); // frontier already processed or budget full
    }
    let t0 = Instant::now();
    while cache.tree_len() < tree.frontier().start {
        let start = cache.tree_len();
        let l = (0..tree.depth_count())
            .find(|&l| tree.layer_range(l).start == start)
            .ok_or_else(|| {
                anyhow::anyhow!("draft cache boundary {start} is not layer-aligned")
            })?;
        let indices: Vec<usize> = tree.layer_range(l).collect();
        draft_forward_rows(draft, rt, ctx, cache, tree, &indices)?;
    }
    let indices: Vec<usize> = tree.frontier().collect();
    let logits = draft_forward_rows(draft, rt, ctx, cache, tree, &indices)?;
    let v = dc.vocab_size;
    let cands: Vec<Vec<(u32, f32)>> = (0..indices.len())
        .map(|r| top_candidates(&logits[r * v..(r + 1) * v], max_children))
        .collect();
    let new_nodes = tree.expand_layer(&cands);
    let elapsed = t0.elapsed().as_secs_f64();
    if new_nodes.is_empty() {
        return Ok((None, elapsed));
    }
    let ids = new_nodes.iter().map(|&i| tree.id(i)).collect();
    Ok((Some(DataFlow { ids, hidden: None }), elapsed))
}

/// Free-running speculation (ISSUE 10): after the in-step expansion,
/// keep expanding up to `extra_gens` further generations against a
/// *shadow* clone of `tree`, forwarding each shadow frontier through the
/// draft's cache (so the rows are banked for later reuse) and returning
/// one epoch-tagged [`SpecExpansion`] per generation. The canonical tree
/// is never touched; the coordinator decides at serve time whether each
/// generation still applies. Returns the speculation seconds alongside
/// (modeled as free — it runs while the pipeline is busy — but measured
/// for the occupancy accounting).
pub fn draft_speculate(
    draft: &ModelCore,
    rt: &Runtime,
    ctx: &mut StageContext,
    cache: &mut TwoLevelCache,
    tree: &PredictionTree,
    max_children: usize,
    epoch: SpecEpoch,
    extra_gens: usize,
) -> Result<(Vec<SpecExpansion>, f64)> {
    let dc = &draft.cfg;
    let t0 = Instant::now();
    let mut shadow = tree.clone();
    let mut out = Vec::with_capacity(extra_gens);
    for gen in 0..extra_gens {
        if cache.tree_len() >= shadow.len() || shadow.len() >= cache.tree_cap() {
            break; // shadow frontier already processed or budget full
        }
        faultinject::fire(Site::DraftStale)?;
        let indices: Vec<usize> = shadow.frontier().collect();
        let logits = draft_forward_rows(draft, rt, ctx, cache, &shadow, &indices)?;
        let v = dc.vocab_size;
        let parents: Vec<u64> = indices.iter().map(|&i| shadow.id(i)).collect();
        let cands: Vec<Vec<(u32, f32)>> = (0..indices.len())
            .map(|r| top_candidates(&logits[r * v..(r + 1) * v], max_children))
            .collect();
        let minted = shadow.expand_layer(&cands);
        if minted.is_empty() {
            break;
        }
        out.push(SpecExpansion {
            epoch,
            parents,
            cands,
            children: minted.len(),
            gen: gen + 2, // generation 1 was the in-step expansion
        });
    }
    Ok((out, t0.elapsed().as_secs_f64()))
}

/// Stage phase for one stage: filter rows whose nodes were pruned while in
/// flight (ids resolved through the dispatch-time [`TreeSnapshot`]), run
/// the stage's layer span over the survivors with the stage's
/// (per-request) cache, and return the outgoing data flow (`None` if
/// everything was pruned away) plus the measured stage seconds. The past
/// bias comes from the context's incremental bias cache keyed off the
/// cache's `past_len` (all of one request's stages agree on it because
/// every pending [`CacheCommit`] is applied before the forward runs).
pub fn run_stage(
    target: &ModelCore,
    rt: &Runtime,
    ctx: &mut StageContext,
    layer_range: std::ops::Range<usize>,
    cache: &mut TwoLevelCache,
    df: DataFlow,
    tree: &TreeSnapshot,
) -> Result<(Option<DataFlow>, f64)> {
    let tc = &target.cfg;
    let w = tc.width_cap;
    let d = tc.dim;

    // translate ids -> current indices; collect surviving rows
    let mut indices = Vec::with_capacity(df.ids.len());
    let mut kept_rows = Vec::with_capacity(df.ids.len());
    for (r, &id) in df.ids.iter().enumerate() {
        if let Some(i) = tree.index_of_id(id) {
            indices.push(i);
            kept_rows.push(r);
        }
    }
    if indices.is_empty() {
        return Ok((None, 0.0));
    }
    let t0 = Instant::now();
    let count = indices.len();

    let hidden = match &df.hidden {
        None => {
            let tokens: Vec<u32> = indices.iter().map(|&i| tree.token(i)).collect();
            target.embed(rt, &tokens)?
        }
        Some(h) => {
            // compact surviving rows into a fresh padded block
            let mut out = vec![0f32; w * d];
            for (nr, &or) in kept_rows.iter().enumerate() {
                out[nr * d..(nr + 1) * d].copy_from_slice(&h[or * d..(or + 1) * d]);
            }
            out
        }
    };

    anyhow::ensure!(
        cache.tree_len() == indices[0],
        "layers {:?}: BFS prefix broken (cache {} vs first index {})",
        layer_range,
        cache.tree_len(),
        indices[0]
    );
    let mut pos = vec![0i32; w];
    for (r, &i) in indices.iter().enumerate() {
        pos[r] = tree.position_of(i) as i32;
    }
    let rows = tree.bias_rows(&indices, tc.tree_cap, bias::NEG);
    let tree_bias = bias::pad_tree_bias_rows(rows, count, cache.tree_len(), w, tc.tree_cap);

    let h_out =
        target.stage_forward(rt, ctx, layer_range, cache, hidden, count, &pos, &tree_bias)?;
    let ids = indices.iter().map(|&i| tree.id(i)).collect();
    Ok((
        Some(DataFlow {
            ids,
            hidden: Some(h_out),
        }),
        t0.elapsed().as_secs_f64(),
    ))
}
