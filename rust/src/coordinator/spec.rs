//! Continuous asynchronous speculation (ISSUE 10): the epoch-tagged bank
//! of free-running draft expansions.
//!
//! With `[engine] spec_inflight = K > 1`, a draft job does not stop after
//! its in-step expansion: it keeps speculating up to `K - 1` further tree
//! generations against a *shadow* clone of the tree it just returned,
//! forwarding each shadow frontier through its own KV cache and banking
//! the resulting candidate sets as [`SpecExpansion`]s. The coordinator
//! holds them in a [`SpecBank`] (one per session) and, on later
//! timesteps, serves a banked generation instead of dispatching the
//! draft — the pipeline gets its next layer without paying `T_draft`.
//!
//! Ownership and staleness rules (see CONCURRENCY.md §6):
//!
//! * The **draft** tags every expansion with the [`SpecEpoch`] it assumed
//!   (the value at dispatch). It never touches the bank.
//! * The **coordinator** owns the bank and the live epoch. The epoch is
//!   bumped — and the bank drained as stale — only when speculation's
//!   whole basis disappears: a Miss-path tree reset or a session cancel.
//!   Hit prunes keep the epoch: their staleness is caught structurally,
//!   by resolving the expansion's parent ids against the live tree
//!   ([`expansion_applicable`]); survivors must cover the post-prune
//!   frontier exactly, or the expansion is dropped unapplied.
//! * A prune between banked generations makes the *deeper* generations'
//!   node ids untrustworthy (the canonical tree and the draft's shadow
//!   mint ids independently once an apply is filtered), so any serve
//!   that applied fewer parents or minted a different node count than
//!   the shadow did clears the remainder of the bank ([divergence
//!   guard](SpecBank::try_serve)).
//!
//! Greedy outputs are bit-identical to lockstep: a served expansion is
//! exactly the layer the lockstep draft would have produced from the
//! same committed state (same candidate sets, same width selection), and
//! anything else is dropped, never applied.

use std::collections::VecDeque;

use super::pipeline::DataFlow;
use crate::tree::{Candidates, PredictionTree};

/// The epoch a speculative expansion assumed: bumped by the coordinator
/// whenever the tree's identity space resets (Miss rebuild, session
/// cancel), which invalidates every in-flight generation at once.
pub type SpecEpoch = u64;

/// One free-running draft generation: the candidate children proposed
/// for each parent (a shadow-frontier node, identified by tree node id),
/// tagged with the epoch the draft assumed.
#[derive(Debug, Clone)]
pub struct SpecExpansion {
    /// [`SpecEpoch`] observed at draft dispatch.
    pub epoch: SpecEpoch,
    /// Node ids of the shadow frontier this generation expands
    /// (ascending — BFS order of the shadow layer).
    pub parents: Vec<u64>,
    /// `cands[k]` = draft top-c proposals for `parents[k]`.
    pub cands: Vec<Candidates>,
    /// How many nodes the shadow's width/budget selection minted for this
    /// layer. A serve that mints a different count has diverged from the
    /// shadow (post-prune budget or filtered parents) and poisons any
    /// deeper banked generation.
    pub children: usize,
    /// 1-based generation index within the owning draft job (generation
    /// 1 is the in-step expansion, so banked generations start at 2).
    pub gen: usize,
}

/// The pure acceptance rule, shared with the concurrency model checker
/// (`concurrency::model`): an expansion may be applied iff its epoch
/// matches the live epoch and its surviving parents (the banked parent
/// ids that still resolve in the live tree, order preserved) are exactly
/// the live frontier. Everything else is stale and must be dropped
/// without being applied.
pub fn expansion_applicable(
    exp_epoch: SpecEpoch,
    live_epoch: SpecEpoch,
    surviving_parents: &[u64],
    frontier_ids: &[u64],
) -> bool {
    exp_epoch == live_epoch
        && !frontier_ids.is_empty()
        && surviving_parents == frontier_ids
}

/// Per-session bank of in-flight speculative generations, owned by the
/// coordinator's sync side. FIFO: generations are banked and served in
/// the order the draft produced them.
#[derive(Debug, Default)]
pub struct SpecBank {
    epoch: SpecEpoch,
    bank: VecDeque<SpecExpansion>,
    stale_dropped: u64,
    served: u64,
}

impl SpecBank {
    pub fn new() -> Self {
        Self::default()
    }

    /// The live epoch the next draft dispatch should tag with.
    pub fn epoch(&self) -> SpecEpoch {
        self.epoch
    }

    /// In-flight (banked, not yet served or dropped) generation count.
    pub fn depth(&self) -> usize {
        self.bank.len()
    }

    /// `(gen, assumed epoch)` per in-flight generation, oldest first —
    /// the stall guards report this so an async-draft livelock names
    /// what the draft was assuming.
    pub fn inflight(&self) -> Vec<(usize, SpecEpoch)> {
        self.bank.iter().map(|e| (e.gen, e.epoch)).collect()
    }

    /// Expansions dropped as stale since construction/reset.
    pub fn stale_dropped(&self) -> u64 {
        self.stale_dropped
    }

    /// Expansions served (applied to the live tree) since
    /// construction/reset.
    pub fn served(&self) -> u64 {
        self.served
    }

    /// Bank a draft job's speculative generations. Expansions tagged
    /// with a dead epoch (the session reset while the job was in
    /// flight) are dropped here, on arrival, and never enter the bank.
    pub fn bank(&mut self, exps: Vec<SpecExpansion>) {
        for exp in exps {
            if exp.epoch == self.epoch {
                self.bank.push_back(exp);
            } else {
                self.stale_dropped += 1;
            }
        }
    }

    /// Coordinator-side epoch bump: the tree's identity space is gone
    /// (Miss rebuild / cancel), so every in-flight generation is stale.
    pub fn bump_epoch(&mut self) {
        self.epoch += 1;
        self.drop_all();
    }

    /// Drop everything in flight (counted as stale) without bumping.
    fn drop_all(&mut self) {
        self.stale_dropped += self.bank.len() as u64;
        self.bank.clear();
    }

    /// Full reset (engine re-seed): counters included.
    pub fn reset(&mut self) {
        *self = Self::default();
    }

    /// Try to serve one banked generation onto the live tree. On
    /// success the layer is applied ([`PredictionTree::expand_layer`])
    /// and the new layer's data flow is returned — the caller routes it
    /// exactly like a draft-granted flow and skips the draft dispatch.
    /// Stale generations encountered on the way are dropped unapplied.
    pub fn try_serve(&mut self, tree: &mut PredictionTree) -> Option<DataFlow> {
        while let Some(exp) = self.bank.pop_front() {
            let frontier_ids: Vec<u64> = tree.frontier().map(|i| tree.id(i)).collect();
            // Surviving parents, order preserved: ids minted after a
            // prune can collide numerically with pruned ones only across
            // an epoch bump, which the epoch check already rejects.
            let surviving: Vec<u64> = exp
                .parents
                .iter()
                .copied()
                .filter(|&id| tree.index_of_id(id).is_some())
                .collect();
            if !expansion_applicable(exp.epoch, self.epoch, &surviving, &frontier_ids) {
                self.stale_dropped += 1;
                continue;
            }
            let keep: Vec<Candidates> = exp
                .parents
                .iter()
                .zip(&exp.cands)
                .filter(|(id, _)| tree.index_of_id(**id).is_some())
                .map(|(_, c)| c.clone())
                .collect();
            let minted = tree.expand_layer(&keep);
            if minted.is_empty() {
                // Node budget exhausted: nothing applied. Deeper
                // generations assumed this layer existed, so they are
                // stale too; fall back to the draft (which will also
                // decline, matching lockstep's idle step).
                self.stale_dropped += 1;
                self.drop_all();
                return None;
            }
            // Divergence guard: once an apply is filtered (pruned
            // parents) or mints a different count than the shadow did
            // (post-prune node budget), the canonical tree and the
            // draft's shadow assign node ids independently — deeper
            // banked generations could resolve numerically-equal ids to
            // different nodes, so they must not be trusted.
            if keep.len() < exp.parents.len() || minted.len() != exp.children {
                self.drop_all();
            }
            self.served += 1;
            let ids = minted.iter().map(|&i| tree.id(i)).collect();
            return Some(DataFlow { ids, hidden: None });
        }
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::TreeConfig;

    fn tree(w: usize, c: usize) -> PredictionTree {
        PredictionTree::new(
            TreeConfig {
                max_width: w,
                max_children: c,
                max_depth: 16,
            },
            64,
            0,
            0,
        )
    }

    fn exp_for_frontier(t: &PredictionTree, epoch: SpecEpoch, gen: usize) -> SpecExpansion {
        let parents: Vec<u64> = t.frontier().map(|i| t.id(i)).collect();
        let cands: Vec<Candidates> = (0..parents.len())
            .map(|k| vec![(100 + 2 * k as u32, 0.6), (101 + 2 * k as u32, 0.4)])
            .collect();
        // shadow-apply to learn the minted count
        let mut shadow = t.clone();
        let children = shadow.expand_layer(&cands).len();
        SpecExpansion {
            epoch,
            parents,
            cands,
            children,
            gen,
        }
    }

    #[test]
    fn matching_expansion_is_served_and_applied() {
        let mut t = tree(8, 2);
        let mut b = SpecBank::new();
        let exp = exp_for_frontier(&t, b.epoch(), 2);
        let want_children = exp.children;
        b.bank(vec![exp]);
        assert_eq!(b.depth(), 1);
        let df = b.try_serve(&mut t).expect("served");
        assert_eq!(df.ids.len(), want_children);
        assert_eq!(t.depth_count(), 2, "layer applied");
        assert_eq!(b.depth(), 0);
        assert_eq!(b.served(), 1);
        assert_eq!(b.stale_dropped(), 0);
    }

    #[test]
    fn epoch_bump_drops_everything_unapplied() {
        let mut t = tree(8, 2);
        let mut b = SpecBank::new();
        b.bank(vec![
            exp_for_frontier(&t, b.epoch(), 2),
            exp_for_frontier(&t, b.epoch(), 3),
        ]);
        b.bump_epoch();
        assert_eq!(b.depth(), 0);
        assert_eq!(b.stale_dropped(), 2);
        assert!(b.try_serve(&mut t).is_none());
        assert_eq!(t.depth_count(), 1, "nothing applied");
    }

    #[test]
    fn stale_epoch_rejected_at_bank_time() {
        let t = tree(8, 2);
        let mut b = SpecBank::new();
        let exp = exp_for_frontier(&t, b.epoch(), 2);
        b.bump_epoch();
        b.bank(vec![exp]);
        assert_eq!(b.depth(), 0, "dead-epoch expansion never enters");
        assert_eq!(b.stale_dropped(), 1);
    }

    #[test]
    fn pruned_attach_point_drops_expansion() {
        let mut t = tree(8, 2);
        t.expand_layer(&[vec![(1, 0.7), (2, 0.3)]]);
        // deepest layer lives only under token 2
        t.expand_layer(&[vec![], vec![(5, 0.9), (6, 0.1)]]);
        let mut b = SpecBank::new();
        // speculate off the {5, 6} frontier, then verify token 1: the hit
        // subtree has no nodes in that layer, so every banked parent is
        // pruned away and the expansion has nowhere to attach
        let exp = exp_for_frontier(&t, b.epoch(), 2);
        b.bank(vec![exp]);
        t.prune(1);
        assert!(b.try_serve(&mut t).is_none());
        assert_eq!(b.stale_dropped(), 1);
        assert_eq!(t.depth_count(), 1, "nothing applied");
    }

    #[test]
    fn prune_to_exact_frontier_still_serves() {
        let mut t = tree(8, 2);
        t.expand_layer(&[vec![(1, 0.7), (2, 0.3)]]);
        let mut b = SpecBank::new();
        // banked parents {1, 2}; verifying token 1 re-roots at node 1,
        // whose surviving parent set exactly covers the new frontier —
        // a filtered but valid serve (lockstep would expand the same
        // node from the same committed state)
        let exp = exp_for_frontier(&t, b.epoch(), 2);
        b.bank(vec![exp]);
        t.prune(1);
        let df = b.try_serve(&mut t).expect("filtered serve");
        assert!(!df.ids.is_empty());
        assert_eq!(t.depth_count(), 2, "layer applied under the new root");
        assert_eq!(b.served(), 1);
    }

    #[test]
    fn filtered_or_diverged_apply_clears_deeper_generations() {
        let mut t = tree(8, 2);
        t.expand_layer(&[vec![(1, 0.7), (2, 0.3)]]);
        t.expand_layer(&[vec![(3, 0.9), (4, 0.1)], vec![(5, 1.0)]]);
        let mut b = SpecBank::new();
        let g2 = exp_for_frontier(&t, b.epoch(), 2);
        // a deeper generation banked off the shadow of g2
        let mut shadow = t.clone();
        shadow.expand_layer(&g2.cands);
        let g3 = exp_for_frontier(&shadow, b.epoch(), 3);
        b.bank(vec![g2, g3]);
        // Hit on token 1: frontier shrinks to {3, 4}; g2's survivors
        // still cover it exactly, so g2 serves — filtered.
        t.prune(1);
        let df = b.try_serve(&mut t).expect("filtered serve");
        assert!(!df.ids.is_empty());
        assert_eq!(
            b.depth(),
            0,
            "divergence guard cleared the deeper generation"
        );
        assert_eq!(b.served(), 1);
        assert_eq!(b.stale_dropped(), 1);
    }

    #[test]
    fn budget_exhaustion_clears_bank_and_serves_nothing() {
        let mut t = PredictionTree::new(
            TreeConfig {
                max_width: 8,
                max_children: 2,
                max_depth: 16,
            },
            1, // budget already full at the root
            0,
            0,
        );
        let mut b = SpecBank::new();
        b.bank(vec![
            exp_for_frontier(&t, b.epoch(), 2),
            exp_for_frontier(&t, b.epoch(), 3),
        ]);
        assert!(b.try_serve(&mut t).is_none());
        assert_eq!(b.depth(), 0);
        assert_eq!(b.stale_dropped(), 2);
        assert_eq!(t.depth_count(), 1, "nothing applied");
    }

    #[test]
    fn inflight_reports_gens_and_epochs() {
        let t = tree(8, 2);
        let mut b = SpecBank::new();
        b.bank(vec![
            exp_for_frontier(&t, b.epoch(), 2),
            exp_for_frontier(&t, b.epoch(), 3),
        ]);
        assert_eq!(b.inflight(), vec![(2, 0), (3, 0)]);
    }

    #[test]
    fn applicability_rule_matches_doc() {
        assert!(expansion_applicable(4, 4, &[7, 9], &[7, 9]));
        assert!(!expansion_applicable(3, 4, &[7, 9], &[7, 9]), "dead epoch");
        assert!(!expansion_applicable(4, 4, &[7], &[7, 9]), "partial cover");
        assert!(!expansion_applicable(4, 4, &[9, 7], &[7, 9]), "order");
        assert!(!expansion_applicable(4, 4, &[], &[]), "empty frontier");
    }
}
