//! L3 coordinator: the PipeDec engine (paper §3) and its token-selection
//! policies.
//!
//! * [`engine::PipeDecEngine`] — the paper's system contribution: a
//!   pipeline-parallel decoder for a single request with the draft model
//!   integrated as pipeline rank 0, a dynamic prediction tree, two-level
//!   KV caches, scheduled transfers, and hit/miss synchronization. It is
//!   served through the crate-wide [`crate::engine::Engine`] trait and
//!   returns the unified [`crate::engine::DecodeOutput`].
//! * [`sampling`] — greedy and stochastic (temperature/top-p/top-k) token
//!   selection shared with the baselines.

pub mod engine;
pub mod sampling;

pub use engine::PipeDecEngine;
pub use sampling::{select_token, top_candidates, Sampling};
