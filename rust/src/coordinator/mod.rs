//! L3 coordinator: the PipeDec engines (paper §3) and their token-selection
//! policies.
//!
//! * [`engine::PipeDecEngine`] — the paper's system contribution: a
//!   pipeline-parallel decoder for a single request with the draft model
//!   integrated as pipeline rank 0, a dynamic prediction tree, two-level
//!   KV caches, scheduled transfers, and hit/miss synchronization. It is
//!   served through the crate-wide [`crate::engine::Engine`] trait and
//!   returns the unified [`crate::engine::DecodeOutput`].
//! * [`db::PipeDecDbEngine`] — SpecPipe-DB, the multi-request variant:
//!   continuous batching of concurrent sessions into pipeline slots behind
//!   the step-driven [`crate::engine::ScheduledEngine`] surface (and the
//!   one-shot `Engine` trait for conformance).
//! * [`pipeline`] — the per-request mechanics ([`pipeline::DataFlow`],
//!   draft expansion, stage execution) both engines share, so their
//!   per-session outputs are identical by construction. Sync commits are
//!   applied by each cache's owning [`crate::model::StageContext`]
//!   (eagerly at the sync point or deferred into the owner's next job),
//!   which also replays them onto the device KV mirror in place
//!   (ISSUE 7).
//! * [`spec`] — continuous asynchronous speculation (ISSUE 10): the
//!   epoch-tagged bank of free-running draft expansions
//!   ([`spec::SpecBank`]) the coordinators serve in place of a draft
//!   dispatch, dropping stale generations without applying them.
//! * [`workers`] — the persistent pipeline worker pool (ISSUE 4): a
//!   timestep's task set (draft + one task per timestep group) executes on
//!   real threads, state moving in and out of jobs by ownership, with
//!   `threads = 1` running the identical jobs inline as the sequential
//!   reference path. Both engines dispatch through it. Since ISSUE 5 each
//!   job also drains its caches' deferred sync commits before running, so
//!   cache maintenance (KV promotion + tree compaction) overlaps the next
//!   timestep's compute (`EngineConfig::overlap_sync`).
//! * [`sampling`] — greedy and stochastic (temperature/top-p/top-k) token
//!   selection shared with the baselines.

pub mod db;
pub mod engine;
pub mod pipeline;
pub mod sampling;
pub mod spec;
pub mod workers;

pub use db::PipeDecDbEngine;
pub use engine::PipeDecEngine;
pub use sampling::{select_token, top_candidates, Sampling};
pub use workers::WorkerPool;
