//! The PipeDec engine (paper §3.2–§3.4): timestep-synchronous pipeline
//! decoding of a single request with the draft model integrated into the
//! pipeline and a dynamic prediction tree coordinating speculative state.
//! Served through the crate-wide [`Engine`] trait.
//!
//! Execution model (ISSUE 4): the per-timestep task set — one draft task
//! plus one task per timestep group — dispatches onto the persistent
//! pipeline worker pool ([`super::workers`]), so with `threads >= groups + 1`
//! every task of a timestep runs concurrently on its own thread and
//! wall-clock approaches the paper's latency model (§2.4):
//!
//! ```text
//!   T_timestep = max(T_draft, max_i(T_group_i) + max_i(T_transfer_i))
//! ```
//!
//! With `threads = 1` the identical jobs run inline on the caller thread
//! (the sequential reference path). Either way the engine still *reports*
//! the modeled parallel latency computed from the measured per-task times
//! — on a loaded or small host the pool can't reach the model's bound, so
//! both numbers stay honest. Outputs are token-identical at every thread
//! count: stage tasks read tree snapshots, verification and pruning happen
//! only at the coordinator's sync phase, and reply processing is
//! normalized to group order. The distributed control plane (transmission
//! scheduling, endpoint conflicts) is exercised through
//! [`crate::schedule::CentralScheduler`] on every transfer.
//!
//! Per timestep (Fig. 2):
//! 1. **draft phase** — the draft node processes the newest tree layer it
//!    has not seen, proposes top-c children per frontier node, and the tree
//!    expands by one width-capped layer (§3.3.3);
//! 2. **stage phase** — every pipeline stage processes the data flow it
//!    received last timestep (dropping rows pruned while in flight);
//! 3. **sync phase** — when a data flow exits the last stage, the verified
//!    token is decoded from the current root's logits row and the tree is
//!    pruned (hit) or reinitialized (miss). Since ISSUE 5 the phase is
//!    split decide/commit: the coordinator keeps only that cheap decision
//!    and issues the cache maintenance (root promotion + tree compaction,
//!    §3.4.3) as a replayable [`CacheCommit`]; with
//!    `EngineConfig::overlap_sync` (default) the commit defers into each
//!    cache owner's next job — applied on the worker right before its
//!    forward — so timestep t+1's draft expansion and early-stage compute
//!    overlap timestep t's cache maintenance, mirroring the paper's
//!    pruning-propagation stage instead of a global barrier. With the
//!    knob off, the commit applies at the sync point (the PR 4 reference
//!    path). Either way each verified token is streamed to the caller's
//!    [`TokenSink`] at the decision, and outputs are bit-identical: all
//!    verification and RNG stay here, only cache bookkeeping moves.

use std::path::Path;
use std::sync::Arc;
use std::time::Instant;

use anyhow::{Context, Result};

use super::pipeline::DataFlow;
use super::sampling::{select_token, Sampling};
use super::spec::SpecBank;
use super::workers::{
    self, DraftCandidate, DraftJob, DraftOutcome, DraftReply, GroupOutcome, StageJob, WorkerPool,
};
use crate::concurrency::protocol::CommitLog;
use crate::config::EngineConfig;
use crate::engine::{DecodeOutput, DecodeRequest, Engine, EngineKind, SpecStats, TokenSink};
use crate::kvcache::prefix::{PrefixEntry, PrefixKv, PrefixStore};
use crate::kvcache::{CacheCommit, CommitOp, TwoLevelCache};
use crate::metrics::{Metrics, SharedMetrics};
use crate::model::{ModelCore, StageContext};
use crate::runtime::Runtime;
use crate::schedule::CentralScheduler;
use crate::tokenizer;
use crate::transport::{LinkModel, LinkStats};
use crate::tree::{PredictionTree, PruneOutcome};
use crate::util::XorShiftRng;

/// One timestep group's resident state: the member stages' KV caches (in
/// span order) plus the group's [`StageContext`]. Owned by the engine
/// between timesteps and lent to a pipeline worker — by move, through the
/// job channel — while the group's task executes; `None` marks state
/// currently on loan.
struct GroupState {
    ctx: StageContext,
    caches: Vec<TwoLevelCache>,
}

/// The PipeDec engine over AOT artifacts.
pub struct PipeDecEngine {
    rt: Arc<Runtime>,
    target: Arc<ModelCore>,
    draft: Arc<ModelCore>,
    pub cfg: EngineConfig,
    layers_per_stage: usize,
    groups_state: Vec<Option<GroupState>>,
    draft_cache: Option<TwoLevelCache>,
    draft_ctx: Option<StageContext>,
    link: LinkModel,
    pub link_stats: LinkStats,
    scheduler: CentralScheduler,
    rng: XorShiftRng,
    /// `Some` when `cfg.effective_threads() >= 2`; `None` runs the same
    /// jobs inline (the sequential reference path).
    pool: Option<WorkerPool>,
    worker_metrics: Arc<SharedMetrics>,
    /// Deferred sync commits (ISSUE 5, `cfg.overlap_sync`): issued by the
    /// sync phase, drained into each cache owner's next job, retired once
    /// every owner applied them. Always empty on the serial-sync path.
    /// The epoch counter and queue discipline live in
    /// [`CommitLog`] (shared with `DbSession` and the model checker);
    /// `commit_log.seq()` is every job's `commit_target`.
    commit_log: CommitLog<CacheCommit>,
    /// Continuous asynchronous speculation (ISSUE 10,
    /// `cfg.spec_inflight > 1`): the epoch-tagged bank of free-running
    /// draft generations. Served at the top of a timestep in place of a
    /// draft dispatch; bumped (and drained) on every Miss-path tree
    /// reset. Idle at `spec_inflight = 1`.
    spec: SpecBank,
    /// Cross-request KV prefix cache (ISSUE 8). Unlike the per-request
    /// caches it is *not* cleared by [`Self::reset`] — persisting across
    /// decodes is the point. `None` when disabled by config or the
    /// `PIPEDEC_NO_PREFIX_CACHE` kill-switch.
    prefix: Option<PrefixStore>,
}

impl PipeDecEngine {
    pub fn new(artifact_dir: &Path, mut cfg: EngineConfig) -> Result<Self> {
        cfg.validate()?;
        // chaos layer (ISSUE 9): config-armed plan, env var wins
        if let Some(plan) = &cfg.fault_plan {
            crate::faultinject::arm(plan.parse()?);
        }
        crate::faultinject::arm_from_env()?;
        let rt = Arc::new(Runtime::cpu()?);
        // pick the narrowest artifact width bucket that fits the tree layer
        let target = Arc::new(ModelCore::load_with_width(
            &rt,
            artifact_dir,
            "target",
            cfg.tree.max_width,
        )?);
        let draft = Arc::new(ModelCore::load_with_width(
            &rt,
            artifact_dir,
            "draft",
            cfg.tree.max_width,
        )?);
        anyhow::ensure!(
            target.cfg.n_layers % cfg.stages == 0,
            "stages {} must divide target layers {}",
            cfg.stages,
            target.cfg.n_layers
        );
        let layers_per_stage = target.cfg.n_layers / cfg.stages;
        // the real engine is bounded by the artifact static shapes; wider
        // sweeps run in the cluster simulator (DESIGN.md)
        cfg.tree.max_width = cfg
            .tree
            .max_width
            .min(target.cfg.width_cap)
            .min(draft.cfg.width_cap);
        cfg.tree.max_children = cfg.tree.max_children.min(target.cfg.vocab_size);
        let groups = cfg.stages / cfg.group_size;
        let tc = &target.cfg;
        let groups_state = (0..groups)
            .map(|_| {
                let caches = (0..cfg.group_size)
                    .map(|_| {
                        TwoLevelCache::new(
                            layers_per_stage,
                            tc.n_heads,
                            tc.head_dim,
                            tc.past_cap,
                            tc.tree_cap,
                        )
                    })
                    .collect();
                Some(GroupState {
                    ctx: target.context(),
                    caches,
                })
            })
            .collect();
        let dc = &draft.cfg;
        let draft_cache =
            TwoLevelCache::new(dc.n_layers, dc.n_heads, dc.head_dim, dc.past_cap, dc.tree_cap);
        let draft_ctx = draft.context();
        let rng = XorShiftRng::new(cfg.seed);
        let threads = cfg.effective_threads();
        let pool = if threads >= 2 {
            Some(WorkerPool::new(threads.min(groups + 1), Arc::clone(&rt))?)
        } else {
            None
        };
        let prefix = PrefixStore::from_config(&cfg.prefix_cache, target.cfg.width_cap)?;
        Ok(Self {
            rt,
            target,
            draft,
            cfg,
            layers_per_stage,
            groups_state,
            draft_cache: Some(draft_cache),
            draft_ctx: Some(draft_ctx),
            link: LinkModel::pcie_p2p(),
            link_stats: LinkStats::default(),
            scheduler: CentralScheduler::new(),
            rng,
            pool,
            worker_metrics: Arc::new(SharedMetrics::new()),
            commit_log: CommitLog::new(),
            spec: SpecBank::new(),
            prefix,
        })
    }

    /// The cross-request prefix store, when enabled (test hook).
    pub fn prefix_store(&self) -> Option<&PrefixStore> {
        self.prefix.as_ref()
    }

    pub fn stages(&self) -> usize {
        self.cfg.stages
    }

    /// Number of timestep groups G_i (paper §3.1).
    pub fn groups(&self) -> usize {
        self.cfg.stages / self.cfg.group_size
    }

    /// Worker threads actually running (1 = sequential reference path).
    pub fn worker_threads(&self) -> usize {
        self.pool.as_ref().map(|p| p.workers()).unwrap_or(1)
    }

    fn reset(&mut self, seed: u64) {
        for st in self.groups_state.iter_mut() {
            let st = st.as_mut().expect("group state in residence");
            for c in &mut st.caches {
                c.reset();
            }
        }
        self.draft_cache
            .as_mut()
            .expect("draft cache in residence")
            .reset();
        self.rng = XorShiftRng::new(seed);
        // commits belong to one request's epoch sequence: a previous
        // decode's undrained tail is irrelevant once every cache reset
        self.commit_log.clear();
        // in-flight speculation belonged to the previous request's tree
        self.spec.reset();
        // a previously *failed* decode never reached the drain at its end;
        // discard its leftover worker timings so they can't pollute this one
        let _ = self.worker_metrics.drain();
    }

    /// Pipeline prefill of the prompt through all target stages (the paper
    /// adopts plain sequential pre-filling, §3.4.1) plus draft prefill.
    /// Probes the cross-request prefix store first (ISSUE 8): on a hit
    /// every stage cache and the draft cache are seeded with the cached
    /// rows and only the uncovered suffix is computed. Returns the first
    /// decoded token and the prefill seconds; prefix-cache counters go
    /// into `metrics`.
    fn prefill(
        &mut self,
        prompt_ids: &[u32],
        sampling: &Sampling,
        metrics: &mut Metrics,
    ) -> Result<(u32, f64)> {
        let w = self.target.cfg.width_cap;
        let gs = self.cfg.group_size;
        let lps = self.layers_per_stage;
        let t0 = Instant::now();

        // probe capped at len - 1: the final prompt token is always
        // re-computed so the last chunk yields logits for the first token
        let mut chain: Vec<Arc<PrefixEntry>> = Vec::new();
        let (mut l1_hit, mut l2_hit) = (false, false);
        let prefix_probed = self.prefix.is_some();
        let evictions_before = self.prefix.as_ref().map_or(0, |s| s.stats().evictions);
        if let Some(store) = self.prefix.as_mut() {
            let before = store.stats();
            chain = store.lookup(prompt_ids, prompt_ids.len().saturating_sub(1));
            l1_hit = store.stats().l1_hits > before.l1_hits;
            l2_hit = store.stats().l2_hits > before.l2_hits;
        }
        let mut covered = 0usize;
        for entry in &chain {
            anyhow::ensure!(
                entry.kv.len() == self.cfg.stages + 1,
                "prefix block holds {} caches, engine has {}",
                entry.kv.len(),
                self.cfg.stages + 1
            );
            for s in 0..self.cfg.stages {
                let st = self.groups_state[s / gs]
                    .as_mut()
                    .expect("group state in residence");
                entry.kv[s].seed(&mut st.caches[s % gs])?;
            }
            entry.kv[self.cfg.stages]
                .seed(self.draft_cache.as_mut().expect("draft cache in residence"))?;
            covered = entry.tokens.len();
        }
        drop(chain); // solo sessions don't outlive prefill; no pin needed

        let mut last_h = None;
        let mut last_count = 0;
        for chunk in prompt_ids[covered..].chunks(w) {
            let start = self.groups_state[0]
                .as_ref()
                .expect("group state in residence")
                .caches[0]
                .past_len();
            let mut h = self.target.embed(&self.rt, chunk)?;
            for s in 0..self.cfg.stages {
                let range = s * lps..(s + 1) * lps;
                let st = self.groups_state[s / gs]
                    .as_mut()
                    .expect("group state in residence");
                h = self.target.prefill_chunk(
                    &self.rt,
                    &mut st.ctx,
                    range,
                    &mut st.caches[s % gs],
                    h,
                    chunk.len(),
                    start,
                )?;
            }
            last_count = chunk.len();
            last_h = Some(h);
        }
        let h = last_h.context("empty prompt")?;
        let logits = self.target.head(&self.rt, &h)?;
        let v = self.target.cfg.vocab_size;
        let row = &logits[(last_count - 1) * v..last_count * v];
        let first = select_token(row, sampling, &mut self.rng);

        // draft prefill (runs in parallel with the target on the real
        // testbed; sequential here, and excluded from decode latency);
        // a seeded draft cache runs only the uncovered suffix as well
        self.draft.full_prefill(
            &self.rt,
            self.draft_ctx.as_mut().expect("draft ctx in residence"),
            self.draft_cache.as_mut().expect("draft cache in residence"),
            &prompt_ids[covered..],
        )?;
        let prefill_s = t0.elapsed().as_secs_f64();

        // insert (or keep) this prompt's own uncovered blocks so the
        // next decode sharing the template skips straight to its suffix
        if let Some(store) = self.prefix.as_mut() {
            let chunk = store.chunk_tokens();
            let insert_len = store.align_down(prompt_ids.len());
            let mut b = covered + chunk;
            while b <= insert_len {
                let pfx = &prompt_ids[..b];
                if store.bump(pfx).is_none() && !store.contains(pfx) {
                    let mut kv = Vec::with_capacity(self.cfg.stages + 1);
                    for s in 0..self.cfg.stages {
                        let st = self.groups_state[s / gs]
                            .as_ref()
                            .expect("group state in residence");
                        kv.push(PrefixKv::extract_range(&st.caches[s % gs], b - chunk, b)?);
                    }
                    kv.push(PrefixKv::extract_range(
                        self.draft_cache.as_ref().expect("draft cache in residence"),
                        b - chunk,
                        b,
                    )?);
                    let entry = PrefixEntry {
                        tokens: pfx.to_vec(),
                        kv,
                    };
                    // a key collision only forfeits caching for this block
                    let _ = store.insert(entry);
                }
                b += chunk;
            }
        }
        metrics.incr("prefill_tokens", (prompt_ids.len() - covered) as u64);
        if prefix_probed {
            metrics.incr("prefix_hit_tokens", covered as u64);
            metrics.incr("prefill_tokens_saved", covered as u64);
            if l1_hit {
                metrics.incr("prefix_l1_hits", 1);
            } else if l2_hit {
                metrics.incr("prefix_l2_hits", 1);
            } else {
                metrics.incr("prefix_misses", 1);
            }
            if let Some(store) = self.prefix.as_ref() {
                metrics.record("prefix_l1_bytes", store.l1_bytes() as f64);
                metrics.record("prefix_l2_bytes", store.l2_bytes() as f64);
                let delta = store.stats().evictions - evictions_before;
                metrics.incr("prefix_evictions", delta);
            }
        }
        Ok((first, prefill_s))
    }

    /// Account one inter-node transfer through the central scheduler and the
    /// link model; returns the modeled wire seconds.
    fn account_transfer(&mut self, src: usize, dst: usize, bytes: usize, seq: u64) -> f64 {
        let id = self.scheduler.submit(src, dst, bytes, seq);
        let dispatched = self.scheduler.tick();
        debug_assert!(dispatched.iter().any(|d| d.task.id == id));
        self.scheduler.notify_finish(id);
        self.scheduler.tick();
        self.link_stats.record(bytes, &self.link);
        self.link.transfer_time(bytes)
    }

    /// Build this timestep's task set (one draft task + one task per group
    /// with an input flow), execute it — on the pool when present, inline
    /// otherwise — and hand every piece of lent state back. Returns the
    /// draft outcome, the per-group outcomes in group order, and the
    /// seconds the jobs spent applying deferred sync commits.
    ///
    /// With `dispatch_draft = false` (a banked speculative expansion
    /// served this timestep, ISSUE 10) no draft task is built: the tree
    /// stays resident, the draft cache keeps its deferred commits for
    /// the next real dispatch, and the returned outcome carries no grant
    /// and zero draft seconds.
    fn run_timestep_tasks(
        &mut self,
        tree: &mut PredictionTree,
        inputs: &mut [Option<DataFlow>],
        dispatch_draft: bool,
    ) -> Result<(DraftOutcome, Vec<Option<GroupOutcome>>, f64)> {
        let groups = self.groups();
        let gs = self.cfg.group_size;
        let lps = self.layers_per_stage;

        let mut stage_jobs = Vec::new();
        // one immutable snapshot shared by every occupied slot (built only
        // when some slot is occupied)
        let mut snapshot: Option<Arc<crate::tree::TreeSnapshot>> = None;
        for (g, slot) in inputs.iter_mut().enumerate() {
            let Some(df) = slot.take() else { continue };
            let st = self.groups_state[g]
                .take()
                .expect("group state in residence");
            let stage_ids: Vec<usize> = (0..gs).map(|k| g * gs + k).collect();
            let layer_ranges = stage_ids
                .iter()
                .map(|&s| s * lps..(s + 1) * lps)
                .collect();
            let snap = snapshot
                .get_or_insert_with(|| Arc::new(tree.snapshot()))
                .clone();
            // sync commits this group's caches still owe (all member
            // caches commit in lockstep, so any one's epoch stands in)
            let commits = self.commit_log.pending(st.caches[0].commit_epoch());
            stage_jobs.push(StageJob {
                group: g,
                core: Arc::clone(&self.target),
                ctx: st.ctx,
                caches: st.caches,
                layer_ranges,
                stage_ids,
                commits,
                commit_target: self.commit_log.seq(),
                df,
                tree: snap,
                metrics: Arc::clone(&self.worker_metrics),
            });
        }
        let draft_job = if dispatch_draft {
            let draft_cache = self.draft_cache.take().expect("draft cache in residence");
            let draft_commits = self.commit_log.pending(draft_cache.commit_epoch());
            Some(DraftJob {
                core: Arc::clone(&self.draft),
                ctx: self.draft_ctx.take().expect("draft ctx in residence"),
                candidates: vec![DraftCandidate {
                    tag: 0,
                    entry: None,
                    // moved, not cloned: the stage jobs already hold their Arc
                    // snapshot, and the coordinator adopts the tree back below
                    tree: std::mem::replace(tree, PredictionTree::placeholder()),
                    cache: draft_cache,
                    commits: draft_commits,
                    commit_target: self.commit_log.seq(),
                    commit_s: 0.0,
                    spec_gens: self.cfg.spec_inflight,
                    spec_epoch: self.spec.epoch(),
                    spec: Vec::new(),
                }],
                max_children: self.cfg.tree.max_children,
                metrics: Arc::clone(&self.worker_metrics),
            })
        } else {
            None
        };

        let (draft_reply, stage_replies) =
            workers::run_tasks(self.pool.as_mut(), &self.rt, draft_job, stage_jobs);

        // Bring every lent piece home — or rebuild it from host truth when
        // it died with its task (worker panic / thread death) — before
        // surfacing any error, so a failed decode leaves the engine
        // structurally intact for the next one.
        let mut commit_s = 0.0f64;
        let draft_res = match draft_reply {
            None => Ok(DraftOutcome {
                granted: None,
                draft_s: 0.0,
            }),
            Some(DraftReply::Done(done)) => {
                self.draft_ctx = Some(done.ctx);
                let mut cands = done.candidates;
                let cand = cands.pop().expect("solo draft job has one candidate");
                self.draft_cache = Some(cand.cache);
                commit_s += cand.commit_s;
                *tree = cand.tree; // adopt the (possibly expanded) tree
                // bank the free-running generations (empty in lockstep or
                // on a failed visit; dead-epoch ones are dropped inside)
                self.spec.bank(cand.spec);
                done.res
            }
            Some(DraftReply::Lost { reason }) => {
                // the canonical tree and draft cache died with the task;
                // restart them fresh (the decode fails below and the next
                // decode resets every cache anyway), and let the fresh
                // StageContext re-upload device mirrors lazily
                let dc = &self.draft.cfg;
                self.draft_cache = Some(TwoLevelCache::new(
                    dc.n_layers,
                    dc.n_heads,
                    dc.head_dim,
                    dc.past_cap,
                    dc.tree_cap,
                ));
                self.draft_ctx = Some(self.draft.context());
                Err(anyhow::anyhow!("draft task lost: {reason}"))
            }
        };
        let groups_state = &mut self.groups_state;
        let (outcomes, failures) =
            workers::absorb_stage_dones(groups, stage_replies, |g, ctx, caches, job_commit_s| {
                groups_state[g] = Some(GroupState { ctx, caches });
                commit_s += job_commit_s;
            });
        // groups whose lent state died with their task restart from host
        // truth: fresh context (device mirrors rebuild via the full
        // re-upload fallback), fresh member caches
        for f in &failures {
            if f.state_lost {
                let fresh = self.rebuild_group_state();
                self.groups_state[f.group] = Some(fresh);
            }
        }
        let stage_err = failures
            .into_iter()
            .next()
            .map(|f| anyhow::anyhow!("group {} task failed: {}", f.group, f.reason));
        // retire commits every cache owner has now applied
        self.trim_commit_log();
        let draft_oc = workers::finish_absorb(draft_res, stage_err)?;
        Ok((draft_oc, outcomes, commit_s))
    }

    /// Rebuild one group's resident state from host truth after its lent
    /// state was destroyed with a panicked task. The caches restart empty
    /// — sound for the solo engine, whose decode fails on any lost task
    /// and resets every cache at the next request.
    fn rebuild_group_state(&self) -> GroupState {
        let tc = &self.target.cfg;
        let caches = (0..self.cfg.group_size)
            .map(|_| {
                TwoLevelCache::new(
                    self.layers_per_stage,
                    tc.n_heads,
                    tc.head_dim,
                    tc.past_cap,
                    tc.tree_cap,
                )
            })
            .collect();
        GroupState {
            ctx: self.target.context(),
            caches,
        }
    }

    /// Drop commit-log entries every owner (all group caches + the draft
    /// cache) has applied. Cheap: the log holds at most the few commits
    /// issued while a cache owner went undispatched.
    fn trim_commit_log(&mut self) {
        if self.commit_log.is_empty() {
            return;
        }
        let mut min_ep = self
            .draft_cache
            .as_ref()
            .expect("draft cache in residence")
            .commit_epoch();
        for st in &self.groups_state {
            let st = st.as_ref().expect("group state in residence");
            for c in &st.caches {
                min_ep = min_ep.min(c.commit_epoch());
            }
        }
        self.commit_log.trim(min_ep);
    }

    /// Undrained commit depth per cache owner: one entry per timestep
    /// group plus the draft cache — the stall-guard diagnostic for the
    /// decide/commit protocol.
    fn pending_commit_depths(&self) -> (Vec<usize>, usize) {
        let per_group = self
            .groups_state
            .iter()
            .map(|st| match st {
                Some(st) => self.commit_log.depth(st.caches[0].commit_epoch()),
                None => 0, // on loan mid-timestep; not reachable from the guard
            })
            .collect();
        let draft = match &self.draft_cache {
            Some(c) => self.commit_log.depth(c.commit_epoch()),
            None => 0,
        };
        (per_group, draft)
    }

    /// Mint the next [`CacheCommit`] of this decode and either queue it
    /// for the owning workers (`overlap_sync`) or apply it to every cache
    /// at the sync point (the serial reference path). Returns the eager
    /// commit seconds (0 when deferred) so the caller can split
    /// `t_decide` from `t_commit`.
    fn issue_commit(&mut self, op: CommitOp, metrics: &mut Metrics) -> Result<f64> {
        let commit = self.commit_log.issue_with(|epoch| CacheCommit { epoch, op });
        if self.cfg.overlap_sync {
            self.commit_log.queue(commit);
            return Ok(0.0);
        }
        let t0 = Instant::now();
        let mut ops = 0usize;
        // eager path goes through each owner's StageContext (not a bare
        // cache walk) so the device mirrors replay the commit in place
        for st in self.groups_state.iter_mut() {
            let st = st.as_mut().expect("group state in residence");
            for cache in st.caches.iter_mut() {
                st.ctx.apply_commit(&self.rt, &self.target, cache, &commit)?;
                ops += 1;
            }
        }
        {
            let ctx = self.draft_ctx.as_mut().expect("draft ctx in residence");
            let cache = self.draft_cache.as_mut().expect("draft cache in residence");
            ctx.apply_commit(&self.rt, &self.draft, cache, &commit)?;
            ops += 1;
        }
        let secs = t0.elapsed().as_secs_f64();
        metrics.record("t_commit_s", secs);
        metrics.incr("commit_ops", ops as u64);
        Ok(secs)
    }
}

impl Engine for PipeDecEngine {
    fn kind(&self) -> EngineKind {
        EngineKind::PipeDec
    }

    fn config(&self) -> &EngineConfig {
        &self.cfg
    }

    /// Decode one request, streaming each verified token at its sync point.
    fn decode(&mut self, req: &DecodeRequest, sink: &mut dyn TokenSink) -> Result<DecodeOutput> {
        let (max_new, sampling, seed) = req.resolve(&self.cfg);
        anyhow::ensure!(max_new >= 1, "max_new_tokens must be >= 1");
        self.reset(seed);
        let mut metrics = Metrics::new();

        anyhow::ensure!(
            max_new + 2 < self.target.cfg.past_cap,
            "max_new_tokens {} exceeds the model context budget ({})",
            max_new,
            self.target.cfg.past_cap
        );
        let max_prompt = self.target.cfg.past_cap - max_new - 2;
        let mut prompt_ids = tokenizer::encode(&req.prompt);
        prompt_ids.truncate(max_prompt);
        anyhow::ensure!(!prompt_ids.is_empty(), "empty prompt");

        let hd_start = self.rt.stats().snapshot();
        let (first, prefill_s) = self.prefill(&prompt_ids, &sampling, &mut metrics)?;
        metrics.record("prefill_s", prefill_s);
        let hd_prefill = self.rt.stats().snapshot();
        {
            let d = hd_prefill.delta_since(&hd_start);
            metrics.incr("hd_prefill_up_bytes", d.up);
            metrics.incr("hd_prefill_down_bytes", d.down);
            metrics.incr("hd_prefill_saved_bytes", d.saved);
        }

        let budget = self.target.cfg.tree_cap.min(self.draft.cfg.tree_cap);
        let mut tree = PredictionTree::new(self.cfg.tree, budget, first, prompt_ids.len());
        let mut decoded = vec![first];
        sink.on_token(first);

        let groups = self.groups();
        let gs = self.cfg.group_size;
        let d_bytes = self.target.cfg.dim * self.target.cfg.width_cap * 4;
        let mut inputs: Vec<Option<DataFlow>> = vec![None; groups];
        inputs[0] = Some(DataFlow::root(&tree));

        let wall0 = Instant::now();
        let mut modeled_s = 0.0;
        let mut timesteps = 0u64;
        let (mut hits, mut misses) = (0u64, 0u64);
        // commit seconds applied inside jobs (the overlapped share of the
        // sync phase when a pool exists)
        let mut job_commit_s = 0.0f64;
        // wall-time the pipeline groups spent computing (occupancy numerator)
        let mut busy_group_s = 0.0f64;
        let max_timesteps = (max_new as u64 + 8) * (groups as u64 + 2);

        'outer: while decoded.len() < max_new {
            timesteps += 1;
            if timesteps > max_timesteps {
                let (pending, pending_draft) = self.pending_commit_depths();
                anyhow::bail!(
                    "timestep budget ({max_timesteps}) exceeded — engine stalled with \
                     {decoded_n}/{max_new} tokens decoded, {tree_n} tree nodes, \
                     {in_flight} in-flight flows, {hits} hits / {misses} misses, \
                     undrained commits per group {pending:?} + draft {pending_draft} \
                     (of {issued} issued), {spec_n} speculative generations in flight \
                     (gen, assumed epoch) {spec_inflight:?} at live epoch {spec_epoch}",
                    decoded_n = decoded.len(),
                    tree_n = tree.len(),
                    in_flight = inputs.iter().flatten().count(),
                    issued = self.commit_log.seq(),
                    spec_n = self.spec.depth(),
                    spec_inflight = self.spec.inflight(),
                    spec_epoch = self.spec.epoch(),
                );
            }
            let seq = timesteps;

            // ---- continuous speculation (ISSUE 10): a banked generation
            // that still applies to the live tree replaces this timestep's
            // draft dispatch — the pipeline gets its next layer for free.
            // Appending a BFS layer before the stage snapshot never
            // disturbs existing rows, so stage tasks are unaffected. ----
            let banked = if self.cfg.spec_inflight > 1 {
                self.spec.try_serve(&mut tree)
            } else {
                None
            };

            // ---- draft + stage phases: the timestep's task set, executed
            // concurrently on the worker pool (sequentially inline when
            // threads = 1); each group G_g runs its member stages
            // sequentially within its task (paper §3.1), draining its
            // caches' deferred sync commits first ----
            let (draft_oc, group_ocs, ts_commit_s) =
                self.run_timestep_tasks(&mut tree, &mut inputs, banked.is_none())?;
            if ts_commit_s > 0.0 {
                metrics.record("t_commit_s", ts_commit_s);
                job_commit_s += ts_commit_s;
            }

            // ---- deterministic post-order: transfer accounting and flow
            // routing in group index order, then the draft grant ----
            let mut next_inputs: Vec<Option<DataFlow>> = vec![None; groups];
            let mut exit_df: Option<DataFlow> = None;
            let mut group_times = vec![0.0f64; groups];
            let mut transfer_times: Vec<f64> = Vec::new();
            for (g, oc) in group_ocs.into_iter().enumerate() {
                let Some(oc) = oc else { continue };
                group_times[g] = oc.compute_s;
                for (src, dst) in oc.hops {
                    // intra-group hop: same timestep, scheduled transfer
                    group_times[g] += self.account_transfer(src, dst, d_bytes, seq);
                }
                let Some(out) = oc.flow else { continue };
                if g + 1 < groups {
                    let span_end = (g + 1) * gs;
                    transfer_times.push(self.account_transfer(
                        span_end,
                        span_end + 1,
                        d_bytes,
                        seq,
                    ));
                    next_inputs[g + 1] = Some(out);
                } else {
                    exit_df = Some(out);
                }
            }
            let draft_s = draft_oc.draft_s;
            if let Some((_, df)) = draft_oc.granted {
                // draft (rank 0) -> L_1: token ids only
                transfer_times.push(self.account_transfer(0, 1, df.entry_bytes(), seq));
                next_inputs[0] = Some(df);
            } else if let Some(df) = banked {
                // a served speculative generation enters the pipeline
                // exactly like a draft grant, minus the draft compute
                transfer_times.push(self.account_transfer(0, 1, df.entry_bytes(), seq));
                next_inputs[0] = Some(df);
            }

            // paper latency model: max(T_draft, C·max(T_group_i) + max(T_t,i))
            busy_group_s += group_times.iter().sum::<f64>();
            let max_group = group_times.iter().cloned().fold(0.0, f64::max);
            let max_tx = transfer_times.iter().cloned().fold(0.0, f64::max);
            modeled_s += draft_s.max(max_group + max_tx);
            metrics.record("timestep_draft_s", draft_s);
            metrics.record("timestep_max_group_s", max_group);
            metrics.incr(
                "active_group_timeslots",
                group_times.iter().filter(|t| **t > 0.0).count() as u64,
            );
            metrics.incr("group_timeslots", groups as u64);

            // ---- sync phase, split decide/commit (ISSUE 5): the
            // coordinator keeps only the cheap global decision —
            // verification, sampling/RNG, the prune — and issues the
            // per-cache maintenance as a CacheCommit that the owning
            // workers apply before their next forward (overlap_sync on)
            // or that applies right here (the serial reference path) ----
            if let Some(df) = exit_df {
                let decide0 = Instant::now();
                let head_t = Instant::now();
                let logits = self
                    .target
                    .head(&self.rt, df.hidden.as_ref().unwrap())?;
                modeled_s += head_t.elapsed().as_secs_f64();
                let root_id = tree.id(0);
                if let Some(row) = df.ids.iter().position(|&id| id == root_id) {
                    let v = self.target.cfg.vocab_size;
                    let x = select_token(&logits[row * v..(row + 1) * v], &sampling, &mut self.rng);
                    decoded.push(x);
                    sink.on_token(x);
                    let outcome = if self.cfg.ablate_tree_reuse {
                        crate::tree::PruneOutcome::Miss
                    } else {
                        tree.prune(x)
                    };
                    let commit_s;
                    match outcome {
                        PruneOutcome::Hit { kept_old, .. } => {
                            hits += 1;
                            commit_s = self.issue_commit(
                                CommitOp::Hit {
                                    kept_old: Arc::new(kept_old),
                                },
                                &mut metrics,
                            )?;
                        }
                        PruneOutcome::Miss => {
                            misses += 1;
                            // the tree is rebuilt from scratch: every banked
                            // speculative generation assumed state that no
                            // longer exists (ISSUE 10)
                            self.spec.bump_epoch();
                            commit_s = self.issue_commit(CommitOp::Miss, &mut metrics)?;
                            // authoritative past length without reading a
                            // cache that may still owe deferred commits:
                            // every decoded token after the first promoted
                            // exactly one root
                            let root_pos = prompt_ids.len() + decoded.len() - 1;
                            tree = PredictionTree::new(self.cfg.tree, budget, x, root_pos);
                            // in-flight data flows are stale: restart pipeline
                            next_inputs = vec![None; groups];
                            next_inputs[0] = Some(DataFlow::root(&tree));
                        }
                    }
                    metrics.record("t_decide_s", decide0.elapsed().as_secs_f64() - commit_s);
                    if x == tokenizer::EOS_ID {
                        break 'outer;
                    }
                }
                // stale exits (root pruned away earlier) are dropped
            }
            inputs = next_inputs;
        }

        let wall_s = wall0.elapsed().as_secs_f64();
        metrics.incr("tokens", decoded.len() as u64);
        metrics.incr("timesteps", timesteps);
        metrics.incr("hits", hits);
        metrics.incr("misses", misses);
        metrics.incr("worker_threads", self.worker_threads() as u64);
        // pipeline occupancy (ISSUE 10): the fraction of wall-clock group
        // slots that were busy computing or hopping. A free-running draft
        // keeps the entry group fed on timesteps lockstep would leave it
        // waiting for the draft, so occupancy rises with spec_inflight.
        let occupancy = if wall_s > 0.0 {
            (busy_group_s / (wall_s * groups as f64)).min(1.0)
        } else {
            0.0
        };
        metrics.record("occupancy", occupancy);
        metrics.record("bubble_fraction", 1.0 - occupancy);
        metrics.incr("stale_expansions_dropped", self.spec.stale_dropped());
        metrics.incr("spec_expansions_served", self.spec.served());
        // per-task timings the workers recorded concurrently
        metrics.merge(&self.worker_metrics.drain());
        // the commit seconds that ran inside jobs are the overlapped share
        // of the sync phase — but only a real pool makes them concurrent
        // with other tasks (inline jobs at threads=1 don't overlap)
        let sync_s = metrics.sample_sum("t_decide_s") + metrics.sample_sum("t_commit_s");
        metrics.record(
            "sync_overlap_ratio",
            if self.pool.is_some() && self.cfg.overlap_sync && sync_s > 0.0 {
                job_commit_s / sync_s
            } else {
                0.0
            },
        );
        // decode-loop host↔device traffic (excluding prefill): what the
        // device-resident path moved vs what argument-per-call marshalling
        // would have moved (BENCH_hotpath.json reads these)
        self.rt
            .stats()
            .snapshot()
            .delta_since(&hd_prefill)
            .record_hd_metrics(&mut metrics);
        Ok(DecodeOutput {
            text: tokenizer::decode(&decoded),
            tokens: decoded,
            wall_s,
            modeled_s,
            spec: Some(SpecStats {
                timesteps,
                rounds: 0,
                hits,
                misses,
                accepted_per_round: 0.0,
            }),
            metrics,
        })
    }
}
