//! The PipeDec engine (paper §3.2–§3.4): timestep-synchronous pipeline
//! decoding of a single request with the draft model integrated into the
//! pipeline and a dynamic prediction tree coordinating speculative state.
//! Served through the crate-wide [`Engine`] trait.
//!
//! Execution model: the engine executes the per-timestep task set
//! *sequentially but in dependency order* (the order the workflow DAG of
//! Appendix B admits), measuring each node's compute time. Because this host
//! has a single core, running stage threads would not change wall-clock;
//! instead the engine reconstructs the *parallel-schedule latency* of every
//! timestep from the measured per-node times exactly as the paper's latency
//! model prescribes (§2.4):
//!
//! ```text
//!   T_timestep = max(T_draft, max_i(T_group_i) + max_i(T_transfer_i))
//! ```
//!
//! and reports both raw wall time and the modeled parallel latency. The
//! distributed control plane itself (transmission scheduling, endpoint
//! conflicts) is exercised through [`crate::schedule::CentralScheduler`] on
//! every transfer.
//!
//! Per timestep (Fig. 2):
//! 1. **draft phase** — the draft node processes the newest tree layer it
//!    has not seen, proposes top-c children per frontier node, and the tree
//!    expands by one width-capped layer (§3.3.3);
//! 2. **stage phase** — every pipeline stage processes the data flow it
//!    received last timestep (dropping rows pruned while in flight);
//! 3. **sync phase** — when a data flow exits the last stage, the verified
//!    token is decoded from the current root's logits row, the tree is
//!    pruned (hit) or reinitialized (miss), KV caches promote the accepted
//!    root and compact (§3.4.3). Each verified token is streamed to the
//!    caller's [`TokenSink`] at this point.

use std::path::Path;
use std::time::Instant;

use anyhow::{Context, Result};

use super::pipeline::{self, DataFlow};
use super::sampling::{select_token, Sampling};
use crate::config::EngineConfig;
use crate::engine::{DecodeOutput, DecodeRequest, Engine, EngineKind, SpecStats, TokenSink};
use crate::kvcache::TwoLevelCache;
use crate::metrics::Metrics;
use crate::model::ModelHandles;
use crate::runtime::Runtime;
use crate::schedule::CentralScheduler;
use crate::tokenizer;
use crate::transport::{LinkModel, LinkStats};
use crate::tree::{PredictionTree, PruneOutcome};
use crate::util::XorShiftRng;

/// The PipeDec engine over AOT artifacts.
pub struct PipeDecEngine {
    rt: Runtime,
    target: ModelHandles,
    draft: ModelHandles,
    pub cfg: EngineConfig,
    layers_per_stage: usize,
    stage_caches: Vec<TwoLevelCache>,
    draft_cache: TwoLevelCache,
    link: LinkModel,
    pub link_stats: LinkStats,
    scheduler: CentralScheduler,
    rng: XorShiftRng,
}

impl PipeDecEngine {
    pub fn new(artifact_dir: &Path, mut cfg: EngineConfig) -> Result<Self> {
        cfg.validate()?;
        let rt = Runtime::cpu()?;
        // pick the narrowest artifact width bucket that fits the tree layer
        let target =
            ModelHandles::load_with_width(&rt, artifact_dir, "target", cfg.tree.max_width)?;
        let draft =
            ModelHandles::load_with_width(&rt, artifact_dir, "draft", cfg.tree.max_width)?;
        anyhow::ensure!(
            target.cfg.n_layers % cfg.stages == 0,
            "stages {} must divide target layers {}",
            cfg.stages,
            target.cfg.n_layers
        );
        let layers_per_stage = target.cfg.n_layers / cfg.stages;
        // the real engine is bounded by the artifact static shapes; wider
        // sweeps run in the cluster simulator (DESIGN.md)
        cfg.tree.max_width = cfg
            .tree
            .max_width
            .min(target.cfg.width_cap)
            .min(draft.cfg.width_cap);
        cfg.tree.max_children = cfg.tree.max_children.min(target.cfg.vocab_size);
        let tc = &target.cfg;
        let stage_caches = (0..cfg.stages)
            .map(|_| {
                TwoLevelCache::new(
                    layers_per_stage,
                    tc.n_heads,
                    tc.head_dim,
                    tc.past_cap,
                    tc.tree_cap,
                )
            })
            .collect();
        let dc = &draft.cfg;
        let draft_cache =
            TwoLevelCache::new(dc.n_layers, dc.n_heads, dc.head_dim, dc.past_cap, dc.tree_cap);
        let rng = XorShiftRng::new(cfg.seed);
        Ok(Self {
            rt,
            target,
            draft,
            cfg,
            layers_per_stage,
            stage_caches,
            draft_cache,
            link: LinkModel::pcie_p2p(),
            link_stats: LinkStats::default(),
            scheduler: CentralScheduler::new(),
            rng,
        })
    }

    pub fn stages(&self) -> usize {
        self.cfg.stages
    }

    /// Number of timestep groups G_i (paper §3.1).
    pub fn groups(&self) -> usize {
        self.cfg.stages / self.cfg.group_size
    }

    fn group_stages(&self, g: usize) -> std::ops::Range<usize> {
        g * self.cfg.group_size..(g + 1) * self.cfg.group_size
    }

    fn layer_range(&self, stage: usize) -> std::ops::Range<usize> {
        stage * self.layers_per_stage..(stage + 1) * self.layers_per_stage
    }

    fn reset(&mut self, seed: u64) {
        for c in &mut self.stage_caches {
            c.reset();
        }
        self.draft_cache.reset();
        self.rng = XorShiftRng::new(seed);
    }

    /// Pipeline prefill of the prompt through all target stages (the paper
    /// adopts plain sequential pre-filling, §3.4.1) plus draft prefill.
    /// Returns the first decoded token and the modeled prefill seconds.
    fn prefill(&mut self, prompt_ids: &[u32], sampling: &Sampling) -> Result<(u32, f64)> {
        let w = self.target.cfg.width_cap;
        let t0 = Instant::now();
        let mut last_h = None;
        let mut last_count = 0;
        for chunk in prompt_ids.chunks(w) {
            let start = self.stage_caches[0].past_len();
            let mut h = self.target.embed(&self.rt, chunk)?;
            for s in 0..self.cfg.stages {
                let range = self.layer_range(s);
                h = self.target.prefill_chunk(
                    &self.rt,
                    range,
                    &mut self.stage_caches[s],
                    h,
                    chunk.len(),
                    start,
                )?;
            }
            last_count = chunk.len();
            last_h = Some(h);
        }
        let h = last_h.context("empty prompt")?;
        let logits = self.target.head(&self.rt, &h)?;
        let v = self.target.cfg.vocab_size;
        let row = &logits[(last_count - 1) * v..last_count * v];
        let first = select_token(row, sampling, &mut self.rng);

        // draft prefill (runs in parallel with the target on the real
        // testbed; sequential here, and excluded from decode latency)
        self.draft.full_prefill(&self.rt, &mut self.draft_cache, prompt_ids)?;
        Ok((first, t0.elapsed().as_secs_f64()))
    }

    /// Draft phase: process the unprocessed BFS suffix (the frontier layer),
    /// expand the tree by one layer, and return the new layer's data flow.
    /// Thin wrapper over [`pipeline::draft_expand`], which SpecPipe-DB
    /// shares.
    fn draft_phase(&mut self, tree: &mut PredictionTree) -> Result<(Option<DataFlow>, f64)> {
        pipeline::draft_expand(
            &mut self.draft,
            &self.rt,
            &mut self.draft_cache,
            tree,
            self.cfg.tree.max_children,
        )
    }

    /// Stage phase for one stage: filter stale rows, run the layer span,
    /// return the outgoing data flow (None if everything was pruned away).
    /// Thin wrapper over [`pipeline::run_stage`], which SpecPipe-DB shares.
    fn stage_phase(
        &mut self,
        stage: usize,
        df: DataFlow,
        tree: &PredictionTree,
    ) -> Result<(Option<DataFlow>, f64)> {
        let range = self.layer_range(stage);
        pipeline::run_stage(
            &mut self.target,
            &self.rt,
            range,
            &mut self.stage_caches[stage],
            df,
            tree,
        )
    }

    /// Account one inter-node transfer through the central scheduler and the
    /// link model; returns the modeled wire seconds.
    fn account_transfer(&mut self, src: usize, dst: usize, bytes: usize, seq: u64) -> f64 {
        let id = self.scheduler.submit(src, dst, bytes, seq);
        let dispatched = self.scheduler.tick();
        debug_assert!(dispatched.iter().any(|d| d.task.id == id));
        self.scheduler.notify_finish(id);
        self.scheduler.tick();
        self.link_stats.record(bytes, &self.link);
        self.link.transfer_time(bytes)
    }
}

impl Engine for PipeDecEngine {
    fn kind(&self) -> EngineKind {
        EngineKind::PipeDec
    }

    fn config(&self) -> &EngineConfig {
        &self.cfg
    }

    /// Decode one request, streaming each verified token at its sync point.
    fn decode(&mut self, req: &DecodeRequest, sink: &mut dyn TokenSink) -> Result<DecodeOutput> {
        let (max_new, sampling, seed) = req.resolve(&self.cfg);
        anyhow::ensure!(max_new >= 1, "max_new_tokens must be >= 1");
        self.reset(seed);
        let mut metrics = Metrics::new();

        anyhow::ensure!(
            max_new + 2 < self.target.cfg.past_cap,
            "max_new_tokens {} exceeds the model context budget ({})",
            max_new,
            self.target.cfg.past_cap
        );
        let max_prompt = self.target.cfg.past_cap - max_new - 2;
        let mut prompt_ids = tokenizer::encode(&req.prompt);
        prompt_ids.truncate(max_prompt);
        anyhow::ensure!(!prompt_ids.is_empty(), "empty prompt");

        let hd_start = self.rt.stats().snapshot();
        let (first, prefill_s) = self.prefill(&prompt_ids, &sampling)?;
        metrics.record("prefill_s", prefill_s);
        let hd_prefill = self.rt.stats().snapshot();
        {
            let d = hd_prefill.delta_since(&hd_start);
            metrics.incr("hd_prefill_up_bytes", d.up);
            metrics.incr("hd_prefill_down_bytes", d.down);
            metrics.incr("hd_prefill_saved_bytes", d.saved);
        }

        let budget = self.target.cfg.tree_cap.min(self.draft.cfg.tree_cap);
        let mut tree = PredictionTree::new(self.cfg.tree, budget, first, prompt_ids.len());
        let mut decoded = vec![first];
        sink.on_token(first);

        let groups = self.groups();
        let d_bytes = self.target.cfg.dim * self.target.cfg.width_cap * 4;
        let mut inputs: Vec<Option<DataFlow>> = vec![None; groups];
        inputs[0] = Some(DataFlow {
            ids: vec![tree.id(0)],
            hidden: None,
        });

        let wall0 = Instant::now();
        let mut modeled_s = 0.0;
        let mut timesteps = 0u64;
        let (mut hits, mut misses) = (0u64, 0u64);
        let max_timesteps = (max_new as u64 + 8) * (groups as u64 + 2);

        'outer: while decoded.len() < max_new {
            timesteps += 1;
            if timesteps > max_timesteps {
                anyhow::bail!("timestep budget exceeded — engine stalled");
            }
            let seq = timesteps;

            // ---- draft phase ----
            let (draft_df, draft_s) = self.draft_phase(&mut tree)?;

            // ---- stage phase: each group G_g runs its member stages
            // sequentially within the timestep (paper §3.1); the group's
            // modeled time is the sum of its members' ----
            let mut next_inputs: Vec<Option<DataFlow>> = vec![None; groups];
            let mut exit_df: Option<DataFlow> = None;
            let mut group_times = vec![0.0f64; groups];
            let mut transfer_times: Vec<f64> = Vec::new();
            for g in 0..groups {
                let Some(df0) = inputs[g].take() else { continue };
                let span = self.group_stages(g);
                let mut df = Some(df0);
                for stage in span.clone() {
                    let Some(cur) = df.take() else { break };
                    let (out, secs) = self.stage_phase(stage, cur, &tree)?;
                    group_times[g] += secs;
                    if out.is_some() && stage + 1 < span.end {
                        // intra-group hop: same timestep, scheduled transfer
                        group_times[g] +=
                            self.account_transfer(stage + 1, stage + 2, d_bytes, seq);
                    }
                    df = out;
                }
                let Some(out) = df else { continue };
                if g + 1 < groups {
                    transfer_times.push(self.account_transfer(
                        span.end,
                        span.end + 1,
                        d_bytes,
                        seq,
                    ));
                    next_inputs[g + 1] = Some(out);
                } else {
                    exit_df = Some(out);
                }
            }
            if let Some(df) = draft_df {
                // draft (rank 0) -> L_1: token ids only
                transfer_times.push(self.account_transfer(0, 1, df.ids.len() * 8, seq));
                next_inputs[0] = Some(df);
            }

            // paper latency model: max(T_draft, C·max(T_group_i) + max(T_t,i))
            let max_group = group_times.iter().cloned().fold(0.0, f64::max);
            let max_tx = transfer_times.iter().cloned().fold(0.0, f64::max);
            modeled_s += draft_s.max(max_group + max_tx);
            metrics.record("timestep_draft_s", draft_s);
            metrics.record("timestep_max_group_s", max_group);
            metrics.incr(
                "active_group_timeslots",
                group_times.iter().filter(|t| **t > 0.0).count() as u64,
            );
            metrics.incr("group_timeslots", groups as u64);

            // ---- sync phase ----
            if let Some(df) = exit_df {
                let head_t = Instant::now();
                let logits = self
                    .target
                    .head(&self.rt, df.hidden.as_ref().unwrap())?;
                modeled_s += head_t.elapsed().as_secs_f64();
                let root_id = tree.id(0);
                if let Some(row) = df.ids.iter().position(|&id| id == root_id) {
                    let v = self.target.cfg.vocab_size;
                    let x = select_token(&logits[row * v..(row + 1) * v], &sampling, &mut self.rng);
                    decoded.push(x);
                    sink.on_token(x);
                    let outcome = if self.cfg.ablate_tree_reuse {
                        crate::tree::PruneOutcome::Miss
                    } else {
                        tree.prune(x)
                    };
                    match outcome {
                        PruneOutcome::Hit { kept_old, .. } => {
                            hits += 1;
                            for c in &mut self.stage_caches {
                                c.promote_root_to_past()?;
                                c.compact_tree(&kept_old);
                            }
                            self.draft_cache.promote_root_to_past()?;
                            self.draft_cache.compact_tree(&kept_old);
                        }
                        PruneOutcome::Miss => {
                            misses += 1;
                            for c in &mut self.stage_caches {
                                c.promote_root_to_past()?;
                                c.clear_tree();
                            }
                            self.draft_cache.promote_root_to_past()?;
                            self.draft_cache.clear_tree();
                            let root_pos = self.stage_caches[0].past_len();
                            tree = PredictionTree::new(self.cfg.tree, budget, x, root_pos);
                            // in-flight data flows are stale: restart pipeline
                            next_inputs = vec![None; groups];
                            next_inputs[0] = Some(DataFlow {
                                ids: vec![tree.id(0)],
                                hidden: None,
                            });
                        }
                    }
                    if x == tokenizer::EOS_ID {
                        break 'outer;
                    }
                }
                // stale exits (root pruned away earlier) are dropped
            }
            inputs = next_inputs;
        }

        let wall_s = wall0.elapsed().as_secs_f64();
        metrics.incr("tokens", decoded.len() as u64);
        metrics.incr("timesteps", timesteps);
        metrics.incr("hits", hits);
        metrics.incr("misses", misses);
        // decode-loop host↔device traffic (excluding prefill): what the
        // device-resident path moved vs what argument-per-call marshalling
        // would have moved (BENCH_hotpath.json reads these)
        self.rt
            .stats()
            .snapshot()
            .delta_since(&hd_prefill)
            .record_hd_metrics(&mut metrics);
        Ok(DecodeOutput {
            text: tokenizer::decode(&decoded),
            tokens: decoded,
            wall_s,
            modeled_s,
            spec: Some(SpecStats {
                timesteps,
                rounds: 0,
                hits,
                misses,
                accepted_per_round: 0.0,
            }),
            metrics,
        })
    }
}
