//! Token selection: greedy and stochastic (temperature / top-k / top-p)
//! decoding, matching the paper's §4.3.3 settings (temperature 0.6,
//! top-p 0.9, top-k 80).

use crate::util::{softmax_inplace, top_k_weighted, XorShiftRng};

#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Sampling {
    Greedy,
    Stochastic {
        temperature: f32,
        top_p: f32,
        top_k: usize,
    },
}

impl Sampling {
    pub fn from_engine(cfg: &crate::config::EngineConfig) -> Self {
        if cfg.temperature == 0.0 {
            Sampling::Greedy
        } else {
            Sampling::Stochastic {
                temperature: cfg.temperature,
                top_p: cfg.top_p,
                top_k: cfg.top_k,
            }
        }
    }

    /// Paper §4.3.3 parameters.
    pub fn llama_stochastic() -> Self {
        Sampling::Stochastic {
            temperature: 0.6,
            top_p: 0.9,
            top_k: 80,
        }
    }
}

/// Select a token from a logits row.
pub fn select_token(logits: &[f32], sampling: &Sampling, rng: &mut XorShiftRng) -> u32 {
    match *sampling {
        Sampling::Greedy => crate::util::top_k_indices(logits, 1)[0] as u32,
        Sampling::Stochastic {
            temperature,
            top_p,
            top_k,
        } => {
            let k = top_k.max(1).min(logits.len());
            let mut cands = top_k_weighted(logits, k);
            let mut probs: Vec<f32> =
                cands.iter().map(|(_, v)| v / temperature.max(1e-6)).collect();
            softmax_inplace(&mut probs);
            // nucleus: keep the smallest prefix with cumulative mass >= top_p
            let mut cum = 0.0;
            let mut cut = probs.len();
            for (i, p) in probs.iter().enumerate() {
                cum += p;
                if cum >= top_p {
                    cut = i + 1;
                    break;
                }
            }
            probs.truncate(cut);
            cands.truncate(cut);
            let pick = rng.weighted(&probs);
            cands[pick].0 as u32
        }
    }
}

/// Softmax probabilities of the top-c entries of a logits row — the draft
/// model's candidate distribution for tree expansion (§3.3.3). Probabilities
/// are normalized over the full row first, so cumulative tree probabilities
/// remain comparable across nodes.
pub fn top_candidates(logits: &[f32], c: usize) -> Vec<(u32, f32)> {
    let mut probs = logits.to_vec();
    softmax_inplace(&mut probs);
    top_k_weighted(&probs, c)
        .into_iter()
        .map(|(i, p)| (i as u32, p))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn greedy_takes_argmax() {
        let mut rng = XorShiftRng::new(1);
        let logits = [0.0f32, 3.0, 1.0];
        assert_eq!(select_token(&logits, &Sampling::Greedy, &mut rng), 1);
    }

    #[test]
    fn low_temperature_concentrates() {
        let mut rng = XorShiftRng::new(2);
        let s = Sampling::Stochastic {
            temperature: 0.05,
            top_p: 1.0,
            top_k: 10,
        };
        let logits = [0.0f32, 5.0, 1.0, 0.5];
        let hits = (0..200)
            .filter(|_| select_token(&logits, &s, &mut rng) == 1)
            .count();
        assert!(hits > 190, "hits={hits}");
    }

    #[test]
    fn top_p_cuts_tail() {
        let mut rng = XorShiftRng::new(3);
        // one dominant token: nucleus of 0.5 keeps only it
        let s = Sampling::Stochastic {
            temperature: 1.0,
            top_p: 0.5,
            top_k: 10,
        };
        let logits = [10.0f32, 0.0, 0.0, 0.0];
        for _ in 0..50 {
            assert_eq!(select_token(&logits, &s, &mut rng), 0);
        }
    }

    #[test]
    fn candidates_are_probabilities() {
        let logits = [1.0f32, 2.0, 3.0, 4.0];
        let cands = top_candidates(&logits, 2);
        assert_eq!(cands[0].0, 3);
        assert_eq!(cands[1].0, 2);
        assert!(cands[0].1 > cands[1].1);
        assert!(cands.iter().all(|&(_, p)| (0.0..=1.0).contains(&p)));
    }
}
