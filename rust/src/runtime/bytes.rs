//! Audited byte-view choke point for literal marshalling.
//!
//! The PJRT literal constructors take untyped `&[u8]` payloads, so the
//! runtime needs to view `&[f32]` / `&[i32]` as bytes. Before ISSUE 6 each
//! call site carried its own ad-hoc `std::slice::from_raw_parts` transmute;
//! this module is now the single place in the crate where that cast is
//! written, behind a sealed trait so it can only ever be instantiated at
//! types whose every bit pattern is a valid `u8` source.
//!
//! The unit tests below run under Miri (`cargo +nightly miri test
//! runtime::bytes`) — Strict Provenance and alignment are checked there,
//! which is the point of funnelling every cast through here.

/// Sealed marker for plain-old-data scalars that may be viewed as raw
/// bytes: no padding, no niches, no drop glue, any bit pattern valid.
///
/// The trait is sealed (private supertrait) so downstream code cannot
/// implement it for types that break the [`as_byte_slice`] safety
/// argument (e.g. types with padding bytes, which would read
/// uninitialized memory).
pub trait Scalar: sealed::Pod {}

impl Scalar for f32 {}
impl Scalar for i32 {}
impl Scalar for u32 {}
impl Scalar for u64 {}

mod sealed {
    /// Private supertrait: only the impls in this module exist, and each
    /// is a primitive numeric type with no padding or invalid values.
    pub trait Pod: Copy + 'static {}
    impl Pod for f32 {}
    impl Pod for i32 {}
    impl Pod for u32 {}
    impl Pod for u64 {}
}

/// View a scalar slice as its underlying little-endian byte buffer.
///
/// This is the crate's only scalar→byte transmute; everything else
/// (literal construction, checksums, serialization) goes through it.
pub fn as_byte_slice<T: Scalar>(data: &[T]) -> &[u8] {
    // SAFETY: `T: Scalar` is sealed to primitive numerics (f32/i32/u32/
    // u64), which have no padding bytes and no invalid bit patterns, so
    // every byte of the slice is initialized and valid at type `u8`.
    // The pointer comes from a live `&[T]`, so it is non-null, aligned
    // for `T` (u8 alignment is 1, always satisfied), and spans
    // `size_of_val(data)` readable bytes inside one allocation. The
    // returned slice borrows `data`, so the allocation outlives it, and
    // `&[u8]` is a shared view — no aliasing `&mut` can exist while it
    // lives. `size_of_val` computes `len * size_of::<T>()` without
    // overflow because the slice already exists.
    unsafe {
        std::slice::from_raw_parts(data.as_ptr().cast::<u8>(), std::mem::size_of_val(data))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn f32_bytes_match_le_encoding() {
        let data = [1.0f32, -2.5, 3.75];
        let bytes = as_byte_slice(&data);
        assert_eq!(bytes.len(), 12);
        for (i, v) in data.iter().enumerate() {
            assert_eq!(&bytes[i * 4..(i + 1) * 4], v.to_le_bytes());
        }
    }

    #[test]
    fn i32_bytes_match_le_encoding() {
        let data = [7i32, -8, i32::MAX, i32::MIN];
        let bytes = as_byte_slice(&data);
        assert_eq!(bytes.len(), 16);
        for (i, v) in data.iter().enumerate() {
            assert_eq!(&bytes[i * 4..(i + 1) * 4], v.to_le_bytes());
        }
    }

    #[test]
    fn u64_width() {
        let data = [u64::MAX, 0, 0x0102_0304_0506_0708];
        let bytes = as_byte_slice(&data);
        assert_eq!(bytes.len(), 24);
        assert_eq!(&bytes[16..24], 0x0102_0304_0506_0708u64.to_le_bytes());
    }

    #[test]
    fn empty_slice_is_empty() {
        let data: [f32; 0] = [];
        assert!(as_byte_slice(&data).is_empty());
    }

    #[test]
    fn view_borrows_without_copying() {
        let data = vec![42u32; 1024];
        let bytes = as_byte_slice(&data);
        assert_eq!(bytes.as_ptr(), data.as_ptr().cast::<u8>());
        assert_eq!(bytes.len(), 4096);
    }
}
