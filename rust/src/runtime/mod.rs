//! PJRT runtime: loads the HLO-text artifacts produced by
//! `python/compile/aot.py` and executes them on the CPU PJRT client.
//!
//! Interchange is HLO *text* — the published `xla` crate wraps
//! xla_extension 0.5.1, which rejects the 64-bit instruction ids in
//! serialized protos from jax >= 0.5; the text parser reassigns ids (see
//! /opt/xla-example/README.md and DESIGN.md).
//!
//! Model entry points are lowered with `return_tuple=True`, so their
//! executions return a tuple literal which [`Executable::run`] decomposes.
//! The KV update entry points (`python/compile/kvops.py`) are the
//! exception: they are lowered *untupled* with argument 0 donated, so
//! [`Executable::run_bufs_to_bufs`] can consume the donated
//! [`DeviceBuffer`] and hand back a device-resident output without any
//! host round trip.
//!
//! # Execution paths
//!
//! There are two ways to feed an entry point:
//!
//! * **literal path** ([`Executable::run`] / [`Executable::run_refs`]) —
//!   host [`xla::Literal`] arguments are shipped to the device on every
//!   call. Simple, but each call re-marshals every operand.
//! * **buffer path** ([`Executable::run_bufs`]) — arguments are
//!   device-resident [`DeviceBuffer`]s created once via
//!   [`Runtime::upload_f32`] / [`Runtime::upload_i32`] /
//!   [`Runtime::upload_literal`] and reused across calls. This is the hot
//!   path: weights, KV tensors, and bias rows stay on the device and only
//!   dirty regions are re-uploaded (EXPERIMENTS.md §Perf iteration 4).
//!
//! Host↔device traffic on both paths is tracked by [`TransferStats`]
//! (bytes uploaded, fetched, and — for cache-served arguments — the bytes
//! a naive re-upload would have moved), so benches and engines can report
//! the marshalling volume per decode.

pub mod bytes;
pub mod literal;

pub use literal::{lit_f32, lit_i32, scalar_i32, to_vec_f32};

use std::path::Path;

use anyhow::{Context, Result};

use crate::concurrency::sync::atomic::{AtomicU64, Ordering};

/// A device-resident PJRT value. Buffers are immutable once created;
/// "updating" one means uploading a replacement.
///
/// Newtype (not an alias) over the `xla` crate's buffer so this crate can
/// assert the thread-safety contract the pipeline workers rely on — see
/// the `unsafe impl Send/Sync` audit note below.
pub struct DeviceBuffer(xla::PjRtBuffer);

impl DeviceBuffer {
    /// Fetch the buffer back to a host literal (synchronous).
    pub fn to_literal_sync(&self) -> Result<xla::Literal> {
        self.0
            .to_literal_sync()
            .map_err(|e| anyhow::anyhow!("fetch buffer: {e:?}"))
    }
}

// Send/Sync audit (ISSUE 4; choke-pointed per ISSUE 6 — the full
// argument lives here and every `unsafe impl` below carries a one-line
// `SAFETY:` pointer back to it, so `clippy::undocumented_unsafe_blocks`
// enforces that no new impl appears without joining the audit).
//
// Two layers must be thread-safe for these impls to be sound, and both
// are part of the asserted contract:
//
// 1. **The PJRT C API** (what the handles ultimately point at) — this
//    layer is specified thread-safe:
//    * `PJRT_Buffer`s are immutable once created; concurrent reads
//      (`Execute`, `ToLiteralSync`) from any thread are allowed, and
//      xla_extension owns the underlying client state behind C++
//      `shared_ptr` (atomic refcounts);
//    * `PJRT_LoadedExecutable::Execute` is safe to call concurrently
//      from multiple threads (the CPU client dispatches onto its own
//      Eigen thread pool and serializes what it must internally);
//    * `PJRT_Client` itself is thread-safe for buffer creation and
//      compilation.
//
// 2. **The Rust `xla` wrapper's own handle plumbing** — the wrapper's
//    structs are FFI handles over layer 1 and must not smuggle shared
//    *non-atomic* host state (e.g. an `Rc`-held client clone inside
//    every buffer) across these impls; a wrapper that did so would make
//    concurrent buffer creation/drop race on the refcount regardless of
//    layer 1's guarantees. The vendored wrapper binds the C API
//    1:1 with raw handles (see /opt/xla-example and DESIGN.md), so its
//    per-object state is confined to the pointer itself. Note the `xla`
//    dependency is provided by the offline build environment rather
//    than pinned in Cargo.toml (seed-repo convention — see the module
//    header on HLO-text interchange), so this clause of the audit is a
//    contract on that environment. **If the wrapper is ever swapped for
//    one with `Rc`-based ownership, these impls must be revisited** —
//    `EngineConfig { threads: 1 }` is the escape hatch that keeps every
//    xla call on one thread, and the determinism suite
//    (`tests/async_stages.rs`) exercises cross-thread execution as a
//    smoke test.
//
// This crate only ever *reads* buffers/executables after construction
// (uploads create fresh buffers; "mutation" of cached state is modeled as
// replacement), so sharing them across the pipeline worker pool is sound
// under the contract above.
// SAFETY: per the audit above — the PJRT buffer handle is immutable
// after creation and the C API permits reads from any thread.
unsafe impl Send for DeviceBuffer {}
// SAFETY: per the audit above — concurrent `Execute`/`ToLiteralSync`
// reads of an immutable buffer are specified thread-safe.
unsafe impl Sync for DeviceBuffer {}

/// Monotonic host↔device transfer accounting for one [`Runtime`].
///
/// * `up` — bytes actually uploaded (host → device);
/// * `down` — bytes fetched back (device → host);
/// * `saved` — bytes an argument-per-call path would have uploaded but the
///   buffer cache served from device residency instead;
/// * `resident` — bytes currently pinned on the device by load-time weight
///   uploads (informational).
///
/// Counters only ever grow; consumers diff [`TransferStats::snapshot`]s.
#[derive(Debug, Default)]
pub struct TransferStats {
    up: AtomicU64,
    down: AtomicU64,
    saved: AtomicU64,
    saved_kv: AtomicU64,
    kv_appended: AtomicU64,
    kv_reuploaded: AtomicU64,
    resident: AtomicU64,
}

impl TransferStats {
    pub fn add_up(&self, bytes: usize) {
        self.up.fetch_add(bytes as u64, Ordering::Relaxed);
    }

    pub fn add_down(&self, bytes: usize) {
        self.down.fetch_add(bytes as u64, Ordering::Relaxed);
    }

    pub fn add_saved(&self, bytes: usize) {
        self.saved.fetch_add(bytes as u64, Ordering::Relaxed);
    }

    /// KV-mirror savings: counted into `saved` *and* a KV-specific bucket
    /// so benches can gate the mirror's effectiveness separately from the
    /// (much larger) resident-weight credit.
    pub fn add_saved_kv(&self, bytes: usize) {
        self.saved.fetch_add(bytes as u64, Ordering::Relaxed);
        self.saved_kv.fetch_add(bytes as u64, Ordering::Relaxed);
    }

    /// Bytes uploaded by the device mirror's in-place *append* fast path
    /// (only the new rows cross the bus). Subset of `up`.
    pub fn add_kv_appended(&self, bytes: usize) {
        self.kv_appended.fetch_add(bytes as u64, Ordering::Relaxed);
    }

    /// Bytes uploaded by the device mirror's full *re-upload* fallback
    /// (whole level tensors crossed the bus). Subset of `up`.
    pub fn add_kv_reuploaded(&self, bytes: usize) {
        self.kv_reuploaded.fetch_add(bytes as u64, Ordering::Relaxed);
    }

    pub fn add_resident(&self, bytes: usize) {
        self.resident.fetch_add(bytes as u64, Ordering::Relaxed);
    }

    pub fn resident_bytes(&self) -> u64 {
        self.resident.load(Ordering::Relaxed)
    }

    pub fn snapshot(&self) -> TransferSnapshot {
        TransferSnapshot {
            up: self.up.load(Ordering::Relaxed),
            down: self.down.load(Ordering::Relaxed),
            saved: self.saved.load(Ordering::Relaxed),
            saved_kv: self.saved_kv.load(Ordering::Relaxed),
            kv_appended: self.kv_appended.load(Ordering::Relaxed),
            kv_reuploaded: self.kv_reuploaded.load(Ordering::Relaxed),
        }
    }
}

/// Point-in-time copy of the transfer counters; subtract two to get the
/// traffic of a region of code.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct TransferSnapshot {
    pub up: u64,
    pub down: u64,
    pub saved: u64,
    /// Subset of `saved` credited by the KV device mirror.
    pub saved_kv: u64,
    /// Subset of `up` moved by the mirror's in-place append fast path.
    pub kv_appended: u64,
    /// Subset of `up` moved by the mirror's full re-upload fallback.
    pub kv_reuploaded: u64,
}

impl TransferSnapshot {
    /// Traffic since `earlier` (counters are monotonic).
    pub fn delta_since(&self, earlier: &TransferSnapshot) -> TransferSnapshot {
        TransferSnapshot {
            up: self.up - earlier.up,
            down: self.down - earlier.down,
            saved: self.saved - earlier.saved,
            saved_kv: self.saved_kv - earlier.saved_kv,
            kv_appended: self.kv_appended - earlier.kv_appended,
            kv_reuploaded: self.kv_reuploaded - earlier.kv_reuploaded,
        }
    }

    /// Bytes moved (up + down).
    pub fn moved(&self) -> u64 {
        self.up + self.down
    }

    /// Bytes the unoptimized argument-per-call path would have moved.
    pub fn unoptimized(&self) -> u64 {
        self.up + self.down + self.saved
    }

    /// Traffic reduction factor vs the unoptimized path (>= 1.0).
    pub fn reduction_factor(&self) -> f64 {
        if self.moved() == 0 {
            1.0
        } else {
            self.unoptimized() as f64 / self.moved() as f64
        }
    }

    /// Record this delta under the standard `hd_*` metric names every
    /// engine reports (the single definition of those counter names).
    pub fn record_hd_metrics(&self, metrics: &mut crate::metrics::Metrics) {
        metrics.incr("hd_up_bytes", self.up);
        metrics.incr("hd_down_bytes", self.down);
        metrics.incr("hd_saved_bytes", self.saved);
        metrics.incr("hd_saved_kv_bytes", self.saved_kv);
        metrics.incr("hd_kv_app_bytes", self.kv_appended);
        metrics.incr("hd_kv_reup_bytes", self.kv_reuploaded);
    }
}

/// Thin wrapper over the PJRT CPU client.
pub struct Runtime {
    client: xla::PjRtClient,
    stats: TransferStats,
}

// SAFETY: see the audit note on [`DeviceBuffer`] — the PJRT client is
// thread-safe for compilation, buffer creation, and execution, and
// [`TransferStats`] is all atomics. The pipeline worker pool shares one
// `Arc<Runtime>` across workers.
unsafe impl Send for Runtime {}
// SAFETY: same contract as the `Send` impl above — all client entry
// points this crate calls are safe to invoke concurrently.
unsafe impl Sync for Runtime {}

impl Runtime {
    pub fn cpu() -> Result<Self> {
        let client = xla::PjRtClient::cpu().context("create PJRT CPU client")?;
        Ok(Self {
            client,
            stats: TransferStats::default(),
        })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Host↔device transfer counters for this client.
    pub fn stats(&self) -> &TransferStats {
        &self.stats
    }

    /// Upload a host literal to a device buffer (counted by the caller when
    /// the size is known; see [`Runtime::upload_f32`]).
    pub fn upload_literal(&self, lit: &xla::Literal) -> Result<DeviceBuffer> {
        self.client
            .buffer_from_host_literal(None, lit)
            .map(DeviceBuffer)
            .map_err(|e| anyhow::anyhow!("upload literal: {e:?}"))
    }

    /// Upload row-major f32 data as a device buffer of the given shape.
    pub fn upload_f32(&self, data: &[f32], dims: &[usize]) -> Result<DeviceBuffer> {
        let lit = lit_f32(data, dims)?;
        self.stats.add_up(data.len() * 4);
        self.upload_literal(&lit)
    }

    /// Upload i32 data as a device buffer of the given shape (`&[]` for a
    /// scalar).
    pub fn upload_i32(&self, data: &[i32], dims: &[usize]) -> Result<DeviceBuffer> {
        let lit = lit_i32(data, dims)?;
        self.stats.add_up(data.len() * 4);
        self.upload_literal(&lit)
    }

    /// Load + compile one HLO text artifact.
    pub fn load_hlo_text(&self, path: &Path) -> Result<Executable> {
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("non-utf8 path")?,
        )
        .map_err(|e| anyhow::anyhow!("parse {}: {e:?}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow::anyhow!("compile {}: {e:?}", path.display()))?;
        Ok(Executable {
            exe,
            name: path
                .file_name()
                .map(|s| s.to_string_lossy().into_owned())
                .unwrap_or_default(),
        })
    }
}

/// A compiled entry point.
pub struct Executable {
    exe: xla::PjRtLoadedExecutable,
    name: String,
}

// SAFETY: see the audit note on [`DeviceBuffer`] — PJRT loaded
// executables support concurrent `Execute` calls; this crate never
// mutates an `Executable` after `Runtime::load_hlo_text` builds it.
unsafe impl Send for Executable {}
// SAFETY: same contract as the `Send` impl above — `Execute` is
// specified safe to call concurrently from multiple threads.
unsafe impl Sync for Executable {}

impl Executable {
    pub fn name(&self) -> &str {
        &self.name
    }

    /// Execute with host literals; returns the decomposed output tuple.
    pub fn run(&self, args: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        let refs: Vec<&xla::Literal> = args.iter().collect();
        self.run_refs(&refs)
    }

    /// Execute with borrowed literals — avoids deep `Literal::clone` of
    /// weight tensors on the hot path (EXPERIMENTS.md §Perf, L3 item 1).
    pub fn run_refs(&self, args: &[&xla::Literal]) -> Result<Vec<xla::Literal>> {
        let out = self
            .exe
            .execute::<&xla::Literal>(args)
            .map_err(|e| anyhow::anyhow!("execute {}: {e:?}", self.name))?;
        Self::decompose(&self.name, &out[0][0])
    }

    /// Execute with device-resident buffers — no argument marshalling at
    /// all; only the output tuple crosses back to the host
    /// (EXPERIMENTS.md §Perf iteration 4).
    pub fn run_bufs(&self, args: &[&DeviceBuffer]) -> Result<Vec<xla::Literal>> {
        let raw: Vec<&xla::PjRtBuffer> = args.iter().map(|b| &b.0).collect();
        let out = self
            .exe
            .execute_b::<&xla::PjRtBuffer>(&raw)
            .map_err(|e| anyhow::anyhow!("execute(buffers) {}: {e:?}", self.name))?;
        Self::decompose(&self.name, &out[0][0])
    }

    /// Execute a *donating* entry point (argument 0 lowered with
    /// `donate_argnums=(0,)`, untupled single output) entirely on the
    /// device: `donated` is moved in — PJRT may reuse its storage for the
    /// output — and the result stays resident as a fresh [`DeviceBuffer`].
    ///
    /// Ownership is the safety story (rust/CONCURRENCY.md §3): because
    /// `donated` is consumed by value, no other owner can observe the
    /// buffer after PJRT invalidates it, so donation never aliases live
    /// host state. `rest` arguments are borrowed read-only as usual.
    pub fn run_bufs_to_bufs(
        &self,
        donated: DeviceBuffer,
        rest: &[&DeviceBuffer],
    ) -> Result<DeviceBuffer> {
        let mut raw: Vec<&xla::PjRtBuffer> = Vec::with_capacity(1 + rest.len());
        raw.push(&donated.0);
        raw.extend(rest.iter().map(|b| &b.0));
        let out = self
            .exe
            .execute_b::<&xla::PjRtBuffer>(&raw)
            .map_err(|e| anyhow::anyhow!("execute(donated) {}: {e:?}", self.name))?;
        drop(donated); // donated storage now belongs to the output
        let mut per_device = out.into_iter();
        let replicas = per_device
            .next()
            .ok_or_else(|| anyhow::anyhow!("{}: no output device", self.name))?;
        let mut bufs = replicas.into_iter();
        let buf = bufs
            .next()
            .ok_or_else(|| anyhow::anyhow!("{}: empty output", self.name))?;
        Ok(DeviceBuffer(buf))
    }

    fn decompose(name: &str, buf: &xla::PjRtBuffer) -> Result<Vec<xla::Literal>> {
        let lit = buf
            .to_literal_sync()
            .map_err(|e| anyhow::anyhow!("fetch result of {name}: {e:?}"))?;
        lit.to_tuple()
            .map_err(|e| anyhow::anyhow!("decompose result of {name}: {e:?}"))
    }
}

// Note: the old `ArtifactSet` lazy registry was deleted — `ModelHandles`
// resolves its three entry points once at load time via `load_hlo_text`
// and keeps the [`Executable`]s directly (ISSUE 2 satellite: the registry
// path paid a `format!` + double `HashMap` lookup per layer call).

#[cfg(test)]
mod tests {
    use super::*;

    /// These tests need built artifacts; they are skipped (not failed) when
    /// `artifacts/` is absent so `cargo test` works pre-`make artifacts`.
    fn artifacts() -> Option<std::path::PathBuf> {
        let dir = crate::artifacts_dir();
        dir.join("target_config.txt").exists().then_some(dir)
    }

    // Every test here except `transfer_snapshot_arithmetic` crosses the
    // xla FFI boundary, which Miri cannot interpret.
    #[cfg_attr(miri, ignore)]
    #[test]
    fn cpu_client_boots() {
        let rt = Runtime::cpu().unwrap();
        assert!(!rt.platform().is_empty());
    }

    #[test]
    fn transfer_snapshot_arithmetic() {
        let s = TransferStats::default();
        s.add_up(100);
        s.add_down(50);
        let a = s.snapshot();
        s.add_up(20);
        s.add_saved(180);
        let b = s.snapshot();
        let d = b.delta_since(&a);
        assert_eq!(d.up, 20);
        assert_eq!(d.down, 0);
        assert_eq!(d.saved, 180);
        assert_eq!(d.moved(), 20);
        assert_eq!(d.unoptimized(), 200);
        assert!((d.reduction_factor() - 10.0).abs() < 1e-12);
    }

    #[cfg_attr(miri, ignore)]
    #[test]
    fn upload_roundtrips_through_device() {
        let Ok(rt) = Runtime::cpu() else {
            eprintln!("skipping: no PJRT client");
            return;
        };
        let data = vec![1.0f32, 2.0, 3.0, 4.0];
        let buf = rt.upload_f32(&data, &[2, 2]).unwrap();
        let lit = buf.to_literal_sync().unwrap();
        assert_eq!(to_vec_f32(&lit).unwrap(), data);
        assert_eq!(rt.stats().snapshot().up, 16);
    }

    #[cfg_attr(miri, ignore)]
    #[test]
    fn embed_artifact_runs() {
        let Some(dir) = artifacts() else {
            eprintln!("skipping: no artifacts");
            return;
        };
        let rt = Runtime::cpu().unwrap();
        let cfg =
            crate::config::ArtifactConfig::load(&dir.join("target_config.txt")).unwrap();
        let exe = rt.load_hlo_text(&dir.join("target_embed.hlo.txt")).unwrap();
        let weights =
            crate::weights::WeightMap::load(&dir.join("weights_target.pdw")).unwrap();
        let emb = weights.get("emb").unwrap();
        let emb_lit = lit_f32(&emb.data, &[cfg.vocab_size, cfg.dim]).unwrap();
        let tokens = vec![5i32; cfg.width_cap];
        let tok_lit = lit_i32(&tokens, &[cfg.width_cap]).unwrap();
        let out = exe.run(&[emb_lit, tok_lit]).unwrap();
        assert_eq!(out.len(), 1);
        let h = to_vec_f32(&out[0]).unwrap();
        assert_eq!(h.len(), cfg.width_cap * cfg.dim);
        // row 0 must equal emb[5]
        let row = &emb.data[5 * cfg.dim..6 * cfg.dim];
        for (a, b) in h[..cfg.dim].iter().zip(row) {
            assert!((a - b).abs() < 1e-6);
        }
    }

    #[cfg_attr(miri, ignore)]
    #[test]
    fn buffer_path_matches_literal_path() {
        let Some(dir) = artifacts() else {
            eprintln!("skipping: no artifacts");
            return;
        };
        let rt = Runtime::cpu().unwrap();
        let cfg =
            crate::config::ArtifactConfig::load(&dir.join("target_config.txt")).unwrap();
        let exe = rt.load_hlo_text(&dir.join("target_embed.hlo.txt")).unwrap();
        let weights =
            crate::weights::WeightMap::load(&dir.join("weights_target.pdw")).unwrap();
        let emb = weights.get("emb").unwrap();
        let tokens = vec![5i32; cfg.width_cap];

        let lit_out = exe
            .run(&[
                lit_f32(&emb.data, &[cfg.vocab_size, cfg.dim]).unwrap(),
                lit_i32(&tokens, &[cfg.width_cap]).unwrap(),
            ])
            .unwrap();
        let emb_buf = rt.upload_f32(&emb.data, &[cfg.vocab_size, cfg.dim]).unwrap();
        let tok_buf = rt.upload_i32(&tokens, &[cfg.width_cap]).unwrap();
        let buf_out = exe.run_bufs(&[&emb_buf, &tok_buf]).unwrap();
        assert_eq!(
            to_vec_f32(&lit_out[0]).unwrap(),
            to_vec_f32(&buf_out[0]).unwrap(),
            "device-resident execution diverged from the literal path"
        );
    }
}
