//! PJRT runtime: loads the HLO-text artifacts produced by
//! `python/compile/aot.py` and executes them on the CPU PJRT client.
//!
//! Interchange is HLO *text* — the published `xla` crate wraps
//! xla_extension 0.5.1, which rejects the 64-bit instruction ids in
//! serialized protos from jax >= 0.5; the text parser reassigns ids (see
//! /opt/xla-example/README.md and DESIGN.md).
//!
//! All entry points are lowered with `return_tuple=True`, so every
//! execution returns a tuple literal which [`Executable::run`] decomposes.

pub mod literal;

pub use literal::{lit_f32, lit_i32, scalar_i32, to_vec_f32};

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

/// Thin wrapper over the PJRT CPU client.
pub struct Runtime {
    client: xla::PjRtClient,
}

impl Runtime {
    pub fn cpu() -> Result<Self> {
        let client = xla::PjRtClient::cpu().context("create PJRT CPU client")?;
        Ok(Self { client })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load + compile one HLO text artifact.
    pub fn load_hlo_text(&self, path: &Path) -> Result<Executable> {
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("non-utf8 path")?,
        )
        .map_err(|e| anyhow::anyhow!("parse {}: {e:?}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow::anyhow!("compile {}: {e:?}", path.display()))?;
        Ok(Executable {
            exe,
            name: path
                .file_name()
                .map(|s| s.to_string_lossy().into_owned())
                .unwrap_or_default(),
        })
    }
}

/// A compiled entry point.
pub struct Executable {
    exe: xla::PjRtLoadedExecutable,
    name: String,
}

impl Executable {
    pub fn name(&self) -> &str {
        &self.name
    }

    /// Execute with host literals; returns the decomposed output tuple.
    pub fn run(&self, args: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        let refs: Vec<&xla::Literal> = args.iter().collect();
        self.run_refs(&refs)
    }

    /// Execute with borrowed literals — avoids deep `Literal::clone` of
    /// weight tensors on the hot path (EXPERIMENTS.md §Perf, L3 item 1).
    pub fn run_refs(&self, args: &[&xla::Literal]) -> Result<Vec<xla::Literal>> {
        let out = self
            .exe
            .execute::<&xla::Literal>(args)
            .map_err(|e| anyhow::anyhow!("execute {}: {e:?}", self.name))?;
        let lit = out[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow::anyhow!("fetch result of {}: {e:?}", self.name))?;
        lit.to_tuple()
            .map_err(|e| anyhow::anyhow!("decompose result of {}: {e:?}", self.name))
    }
}

/// Lazy registry of the artifact set for one model (`target` / `draft`).
pub struct ArtifactSet {
    dir: PathBuf,
    model: String,
    cache: HashMap<String, Executable>,
}

impl ArtifactSet {
    pub fn new(dir: &Path, model: &str) -> Self {
        Self {
            dir: dir.to_path_buf(),
            model: model.to_string(),
            cache: HashMap::new(),
        }
    }

    pub fn model(&self) -> &str {
        &self.model
    }

    /// Compile-once accessor for `{model}_{entry}.hlo.txt`.
    pub fn entry(&mut self, rt: &Runtime, entry: &str) -> Result<&Executable> {
        if !self.cache.contains_key(entry) {
            let path = self.dir.join(format!("{}_{entry}.hlo.txt", self.model));
            let exe = rt.load_hlo_text(&path)?;
            self.cache.insert(entry.to_string(), exe);
        }
        Ok(self.cache.get(entry).unwrap())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// These tests need built artifacts; they are skipped (not failed) when
    /// `artifacts/` is absent so `cargo test` works pre-`make artifacts`.
    fn artifacts() -> Option<PathBuf> {
        let dir = crate::artifacts_dir();
        dir.join("target_config.txt").exists().then_some(dir)
    }

    #[test]
    fn cpu_client_boots() {
        let rt = Runtime::cpu().unwrap();
        assert!(!rt.platform().is_empty());
    }

    #[test]
    fn embed_artifact_runs() {
        let Some(dir) = artifacts() else {
            eprintln!("skipping: no artifacts");
            return;
        };
        let rt = Runtime::cpu().unwrap();
        let cfg =
            crate::config::ArtifactConfig::load(&dir.join("target_config.txt")).unwrap();
        let exe = rt.load_hlo_text(&dir.join("target_embed.hlo.txt")).unwrap();
        let weights =
            crate::weights::WeightMap::load(&dir.join("weights_target.pdw")).unwrap();
        let emb = weights.get("emb").unwrap();
        let emb_lit = lit_f32(&emb.data, &[cfg.vocab_size, cfg.dim]).unwrap();
        let tokens = vec![5i32; cfg.width_cap];
        let tok_lit = lit_i32(&tokens, &[cfg.width_cap]).unwrap();
        let out = exe.run(&[emb_lit, tok_lit]).unwrap();
        assert_eq!(out.len(), 1);
        let h = to_vec_f32(&out[0]).unwrap();
        assert_eq!(h.len(), cfg.width_cap * cfg.dim);
        // row 0 must equal emb[5]
        let row = &emb.data[5 * cfg.dim..6 * cfg.dim];
        for (a, b) in h[..cfg.dim].iter().zip(row) {
            assert!((a - b).abs() < 1e-6);
        }
    }
}
