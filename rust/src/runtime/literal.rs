//! Literal construction/extraction helpers shared by the model wrappers.

use anyhow::{Context, Result};

use super::bytes::as_byte_slice;

/// f32 literal of the given shape from row-major data.
pub fn lit_f32(data: &[f32], dims: &[usize]) -> Result<xla::Literal> {
    let n: usize = dims.iter().product::<usize>().max(1);
    anyhow::ensure!(data.len() == n, "lit_f32: {} != {:?}", data.len(), dims);
    let bytes = as_byte_slice(data);
    xla::Literal::create_from_shape_and_untyped_data(xla::ElementType::F32, dims, bytes)
        .map_err(|e| anyhow::anyhow!("lit_f32: {e:?}"))
}

/// i32 literal of the given shape.
pub fn lit_i32(data: &[i32], dims: &[usize]) -> Result<xla::Literal> {
    let n: usize = dims.iter().product::<usize>().max(1);
    anyhow::ensure!(data.len() == n, "lit_i32: {} != {:?}", data.len(), dims);
    let bytes = as_byte_slice(data);
    xla::Literal::create_from_shape_and_untyped_data(xla::ElementType::S32, dims, bytes)
        .map_err(|e| anyhow::anyhow!("lit_i32: {e:?}"))
}

/// Scalar i32 literal (shape `()`).
pub fn scalar_i32(v: i32) -> Result<xla::Literal> {
    lit_i32(&[v], &[])
}

/// Extract f32 data from a literal.
pub fn to_vec_f32(lit: &xla::Literal) -> Result<Vec<f32>> {
    lit.to_vec::<f32>().context("literal to_vec::<f32>")
}

#[cfg(test)]
mod tests {
    use super::*;

    // The round-trip tests exercise the xla FFI, which Miri cannot
    // interpret; the byte-view cast they marshal through is covered under
    // Miri by `runtime::bytes::tests` instead.
    #[cfg_attr(miri, ignore)]
    #[test]
    fn f32_roundtrip() {
        let data = vec![1.0f32, 2.0, 3.0, 4.0, 5.0, 6.0];
        let lit = lit_f32(&data, &[2, 3]).unwrap();
        assert_eq!(to_vec_f32(&lit).unwrap(), data);
    }

    #[cfg_attr(miri, ignore)]
    #[test]
    fn i32_roundtrip() {
        let data = vec![7i32, -8];
        let lit = lit_i32(&data, &[2]).unwrap();
        assert_eq!(lit.to_vec::<i32>().unwrap(), data);
    }

    #[cfg_attr(miri, ignore)]
    #[test]
    fn scalar_shape() {
        let lit = scalar_i32(42).unwrap();
        assert_eq!(lit.get_first_element::<i32>().unwrap(), 42);
    }

    #[cfg_attr(miri, ignore)]
    #[test]
    fn shape_mismatch_rejected() {
        assert!(lit_f32(&[1.0], &[2]).is_err());
    }
}
