//! Workflow controller (paper Appendix B).
//!
//! A dynamic DAG of task tuples schedules the distributed computation:
//!
//! * transmission tasks `(T, src, dst, seq)`;
//! * computation tasks `(C, type, rank, seq)` with `type ∈ {pre, dec, sync}`;
//! * virtual tasks `(V, tag, target, seq)` used as control barriers.
//!
//! [`dag::Dag`] is the generic dependency engine (task insertion, dependency
//! edges, readiness, completion). [`controller::MetaUnit`] encodes the
//! firing rules [1]–[12] of Algorithm 4: given a completed task and the
//! pipeline topology it emits the tasks and dependency edges to schedule
//! next. The PipeDec engine drives its timestep loop through these rules;
//! the unit tests replay small pipelines and assert the execution order the
//! paper describes (Fig. 2).

pub mod controller;
pub mod dag;
pub mod task;

pub use controller::{MetaUnit, Topology};
pub use dag::{Dag, TaskState};
pub use task::{CompKind, TaskKey, VirtTarget};
