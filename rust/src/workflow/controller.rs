//! Meta-unit post-processing rules (paper Algorithm 4).
//!
//! Each rule [1]–[12] maps a completed task on node `x` at timestep `seq`
//! to the tasks scheduled next and their dependency edges, expressed against
//! the [`super::dag::Dag`]. Ranks: 0 = draft model S, 1..=n = pipeline nodes.
//!
//! The unit tests at the bottom replay a full pipeline purely through these
//! rules (a miniature distributed executor) and assert the ordering
//! properties of Fig. 2: prefill chains through the pipeline, decode
//! timesteps overlap across groups, every timestep's work is barriered by
//! its `(V,finish,all,seq)` task, and synchronization gates the next
//! timestep when the final group ran.

use super::dag::Dag;
use super::task::{CompKind, TaskKey};

/// Pipeline topology: `n` model nodes grouped into `d` contiguous groups
/// (paper §3.1: G_1..G_d). Ranks inside `groups` are 1-based; rank 0 is S.
#[derive(Debug, Clone)]
pub struct Topology {
    pub n: usize,
    pub groups: Vec<Vec<usize>>,
}

impl Topology {
    /// Equal-size grouping: n nodes into d groups (n % d == 0).
    pub fn uniform(n: usize, d: usize) -> Self {
        assert!(d >= 1 && n % d == 0, "n must be divisible by d");
        let per = n / d;
        let groups = (0..d)
            .map(|g| (1 + g * per..1 + (g + 1) * per).collect())
            .collect();
        Self { n, groups }
    }

    pub fn d(&self) -> usize {
        self.groups.len()
    }

    /// Is `rank` the last node of some group?
    pub fn is_group_last(&self, rank: usize) -> bool {
        self.groups.iter().any(|g| *g.last().unwrap() == rank)
    }

    /// Last nodes of groups 1..d-1 (excluding the final group) — the ranks
    /// whose output crosses a group boundary into the next timestep.
    pub fn inner_group_lasts(&self) -> Vec<usize> {
        self.groups[..self.d() - 1]
            .iter()
            .map(|g| *g.last().unwrap())
            .collect()
    }

    /// Group index (0-based) containing `rank`.
    pub fn group_of(&self, rank: usize) -> usize {
        self.groups
            .iter()
            .position(|g| g.contains(&rank))
            .expect("rank not in any group")
    }

    /// Whether pipeline node `rank` is active at timestep `seq` during
    /// pipeline fill: group g (0-based) first receives data at seq g+1.
    pub fn active_at(&self, rank: usize, seq: u64) -> bool {
        rank == 0 || seq >= self.group_of(rank) as u64 + 1
    }

    /// Whether the final group (and hence a SYNC) runs at `seq`.
    pub fn sync_at(&self, seq: u64) -> bool {
        seq >= self.d() as u64
    }
}

/// Algorithm 4, parameterized by topology.
#[derive(Debug, Clone)]
pub struct MetaUnit {
    pub topo: Topology,
}

impl MetaUnit {
    pub fn new(topo: Topology) -> Self {
        Self { topo }
    }

    /// Rule [1]: bootstrap at (x=0, seq=0) — prefill on S and L_1.
    pub fn bootstrap(&self, dag: &mut Dag) {
        dag.insert(TaskKey::compute(CompKind::Pre, 0, 0));
        dag.insert(TaskKey::compute(CompKind::Pre, 1, 0));
    }

    /// Rules [2]–[3]: a prefill completed on `x`.
    pub fn on_prefill_done(&self, dag: &mut Dag, x: usize) {
        let n = self.topo.n;
        if x != 0 && x != n {
            // [2] forward the prompt through the pipeline
            let t = TaskKey::transmit(x, x + 1, 0);
            dag.insert(t);
            dag.insert_with_dep(TaskKey::compute(CompKind::Pre, x + 1, 0), t);
        } else if x == n {
            // [3] prefill finished end-to-end: start decoding at S and L_1
            dag.insert_with_dep(
                TaskKey::compute(CompKind::Dec, 0, 1),
                TaskKey::compute(CompKind::Pre, 0, 0),
            );
            dag.insert_with_dep(
                TaskKey::compute(CompKind::Dec, 1, 1),
                TaskKey::compute(CompKind::Pre, 1, 0),
            );
        }
    }

    /// Rules [4]–[10]: a decode completed on `x` at `seq`.
    pub fn on_decode_done(&self, dag: &mut Dag, x: usize, seq: u64) {
        let topo = &self.topo;
        let n = topo.n;
        let sync = topo.sync_at(seq);
        let finish_all = TaskKey::finish_all(seq);

        if x != 0 && !topo.is_group_last(x) {
            // [4] intra-group forwarding within the same timestep
            let t = TaskKey::transmit(x, x + 1, seq);
            dag.insert(t);
            dag.insert_with_dep(TaskKey::compute(CompKind::Dec, x + 1, seq), t);
        }

        if x == 0 {
            // [5] the draft's next expansion waits for this timestep's barrier
            dag.insert_with_dep(TaskKey::compute(CompKind::Dec, 0, seq + 1), finish_all);
            // [6]/[7] wire the barrier to per-node finishes
            if !sync {
                for i in 0..=n {
                    if topo.active_at(i, seq) {
                        dag.insert_with_dep(finish_all, TaskKey::finish_node(i, seq));
                    }
                }
            } else {
                for i in 0..=n {
                    dag.insert_with_dep(finish_all, TaskKey::finish_node(i, seq));
                }
            }
        }

        // [8] group boundary without sync: output crosses into seq+1
        if (x == 0 || topo.inner_group_lasts().contains(&x)) && !sync {
            let t = TaskKey::transmit(x, x + 1, seq);
            dag.insert(t);
            let next = TaskKey::compute(CompKind::Dec, x + 1, seq + 1);
            dag.insert_with_dep(next, t);
            dag.insert_with_dep(next, finish_all);
        }

        // [9] the final node verified a token: synchronize everyone
        if x == n {
            for i in 0..=n {
                let s = TaskKey::compute(CompKind::Sync, i, seq);
                if topo.active_at(i, seq) {
                    dag.insert_with_dep(s, TaskKey::compute(CompKind::Dec, i, seq));
                } else {
                    dag.insert(s);
                }
            }
        }

        // [10] no sync phase this timestep: decode completion is the node's
        // finish event
        if !sync {
            dag.insert(TaskKey::finish_node(x, seq));
            dag.complete(TaskKey::finish_node(x, seq));
        }
    }

    /// Rules [11]–[12]: a sync completed on `x` at `seq`.
    pub fn on_sync_done(&self, dag: &mut Dag, x: usize, seq: u64, pruned_output_exists: bool) {
        // [11]
        dag.insert(TaskKey::finish_node(x, seq));
        dag.complete(TaskKey::finish_node(x, seq));

        // [12] forward pruned output across the group boundary
        if (x == 0 || self.topo.inner_group_lasts().contains(&x)) && pruned_output_exists {
            let t = TaskKey::transmit(x, x + 1, seq);
            dag.insert(t);
            let next = TaskKey::compute(CompKind::Dec, x + 1, seq + 1);
            dag.insert_with_dep(next, t);
            dag.insert_with_dep(next, TaskKey::finish_all(seq));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workflow::dag::TaskState;

    /// Replay the rules with an executor that completes every ready task in
    /// FIFO order, recording the execution log.
    fn run_pipeline(topo: Topology, max_seq: u64) -> Vec<TaskKey> {
        let mu = MetaUnit::new(topo);
        let mut dag = Dag::new();
        mu.bootstrap(&mut dag);
        let mut log = Vec::new();
        let mut guard = 0;
        while let Some(task) = dag.claim() {
            guard += 1;
            assert!(guard < 100_000, "runaway scheduler");
            log.push(task);
            dag.complete(task);
            match task {
                TaskKey::Compute { kind: CompKind::Pre, rank, .. } => {
                    mu.on_prefill_done(&mut dag, rank);
                }
                TaskKey::Compute { kind: CompKind::Dec, rank, seq } => {
                    if seq <= max_seq {
                        mu.on_decode_done(&mut dag, rank, seq);
                    }
                }
                TaskKey::Compute { kind: CompKind::Sync, rank, seq } => {
                    mu.on_sync_done(&mut dag, rank, seq, true);
                }
                _ => {}
            }
        }
        assert!(!dag.is_stuck(), "dag deadlocked");
        log
    }

    fn pos(log: &[TaskKey], key: TaskKey) -> usize {
        log.iter()
            .position(|k| *k == key)
            .unwrap_or_else(|| panic!("task {key} never executed"))
    }

    #[test]
    fn prefill_chains_through_pipeline() {
        let log = run_pipeline(Topology::uniform(3, 3), 2);
        let p0 = pos(&log, TaskKey::compute(CompKind::Pre, 0, 0));
        let p1 = pos(&log, TaskKey::compute(CompKind::Pre, 1, 0));
        let p2 = pos(&log, TaskKey::compute(CompKind::Pre, 2, 0));
        let p3 = pos(&log, TaskKey::compute(CompKind::Pre, 3, 0));
        assert!(p1 < p2 && p2 < p3);
        assert!(p0 < p3);
    }

    #[test]
    fn decode_starts_after_prefill() {
        let log = run_pipeline(Topology::uniform(3, 3), 2);
        let pre_n = pos(&log, TaskKey::compute(CompKind::Pre, 3, 0));
        let dec0 = pos(&log, TaskKey::compute(CompKind::Dec, 0, 1));
        let dec1 = pos(&log, TaskKey::compute(CompKind::Dec, 1, 1));
        assert!(pre_n < dec0 && pre_n < dec1);
    }

    #[test]
    fn transmissions_precede_dependent_decodes() {
        let log = run_pipeline(Topology::uniform(3, 3), 3);
        for seq in 1..=2u64 {
            let t = pos(&log, TaskKey::transmit(1, 2, seq));
            let d = pos(&log, TaskKey::compute(CompKind::Dec, 2, seq + 1));
            assert!(t < d, "seq {seq}: transmit after dependent decode");
        }
    }

    #[test]
    fn timestep_barrier_orders_draft_expansions() {
        let log = run_pipeline(Topology::uniform(3, 3), 4);
        for seq in 1..4u64 {
            let a = pos(&log, TaskKey::compute(CompKind::Dec, 0, seq));
            let b = pos(&log, TaskKey::compute(CompKind::Dec, 0, seq + 1));
            assert!(a < b);
        }
    }

    #[test]
    fn sync_runs_when_final_group_active() {
        let topo = Topology::uniform(3, 3);
        assert!(!topo.sync_at(2));
        assert!(topo.sync_at(3));
        let log = run_pipeline(topo, 4);
        // seq 3 is the first with the final group active -> syncs exist
        for i in 0..=3 {
            pos(&log, TaskKey::compute(CompKind::Sync, i, 3));
        }
        // and none at seq 2
        assert!(!log
            .iter()
            .any(|k| matches!(k, TaskKey::Compute { kind: CompKind::Sync, seq: 2, .. })));
    }

    #[test]
    fn sync_gates_next_timestep_decode() {
        let log = run_pipeline(Topology::uniform(3, 3), 4);
        // dec(1, 4) must come after sync(0, 3)'s transmit (rule 12)
        let s = pos(&log, TaskKey::compute(CompKind::Sync, 0, 3));
        let d = pos(&log, TaskKey::compute(CompKind::Dec, 1, 4));
        assert!(s < d);
    }

    #[test]
    fn grouped_topology_two_per_group() {
        let topo = Topology::uniform(4, 2);
        assert_eq!(topo.groups, vec![vec![1, 2], vec![3, 4]]);
        assert!(topo.is_group_last(2) && topo.is_group_last(4));
        assert!(!topo.is_group_last(1));
        assert_eq!(topo.inner_group_lasts(), vec![2]);
        let log = run_pipeline(topo, 3);
        // intra-group forwarding: dec(1,s) -> T(1,2,s) -> dec(2,s)
        let d1 = pos(&log, TaskKey::compute(CompKind::Dec, 1, 1));
        let t = pos(&log, TaskKey::transmit(1, 2, 1));
        let d2 = pos(&log, TaskKey::compute(CompKind::Dec, 2, 1));
        assert!(d1 < t && t < d2);
    }

    #[test]
    fn no_deadlock_long_run() {
        for d in [1usize, 2, 3] {
            let n = d * 2;
            let log = run_pipeline(Topology::uniform(n, d), 8);
            assert!(log.len() > 20);
        }
    }

    #[test]
    fn states_transition_cleanly() {
        let topo = Topology::uniform(2, 2);
        let mu = MetaUnit::new(topo);
        let mut dag = Dag::new();
        mu.bootstrap(&mut dag);
        let k = TaskKey::compute(CompKind::Pre, 0, 0);
        assert_eq!(dag.state_of(&k), Some(TaskState::Ready));
        let claimed = dag.claim().unwrap();
        assert_eq!(dag.state_of(&claimed), Some(TaskState::Running));
    }
}
