//! Generic dynamic task DAG: tasks become executable when all dependencies
//! are completed (paper Appendix B: "each task node x becomes executable
//! when all its dependent nodes pre_x are completed").
//!
//! Supports the paper's "flexible task insertion": a dependency may
//! reference a task that has not been inserted yet — the edge is honored
//! once the dependency completes. Completion of unknown tasks is recorded
//! so late-inserted dependents see it.

use std::collections::{HashMap, HashSet, VecDeque};

use super::task::TaskKey;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TaskState {
    /// Inserted, waiting for dependencies.
    Pending,
    /// All dependencies satisfied; waiting to be claimed.
    Ready,
    /// Claimed by an executor.
    Running,
    Done,
}

#[derive(Debug, Default)]
pub struct Dag {
    state: HashMap<TaskKey, TaskState>,
    /// dep -> dependents
    out_edges: HashMap<TaskKey, Vec<TaskKey>>,
    /// task -> unmet dependency count
    unmet: HashMap<TaskKey, usize>,
    /// completed tasks (including ones never inserted explicitly)
    done: HashSet<TaskKey>,
    ready: VecDeque<TaskKey>,
}

impl Dag {
    pub fn new() -> Self {
        Self::default()
    }

    /// Insert a task with no dependencies (immediately ready). No-op if the
    /// task already exists.
    pub fn insert(&mut self, key: TaskKey) {
        if self.state.contains_key(&key) || self.done.contains(&key) {
            return;
        }
        self.state.insert(key, TaskState::Ready);
        self.unmet.insert(key, 0);
        self.ready.push_back(key);
    }

    /// Insert `key` (if new) and add a dependency `key <- dep`
    /// (paper notation `S(key) -> dep`). Duplicate edges are ignored.
    pub fn insert_with_dep(&mut self, key: TaskKey, dep: TaskKey) {
        self.insert(key);
        if self.done.contains(&dep) {
            return; // already satisfied
        }
        let deps = self.out_edges.entry(dep).or_default();
        if deps.contains(&key) {
            return;
        }
        deps.push(key);
        let c = self.unmet.entry(key).or_insert(0);
        *c += 1;
        if *c == 1 {
            // task moved from ready back to pending
            self.state.insert(key, TaskState::Pending);
            self.ready.retain(|k| k != &key);
        }
    }

    pub fn state_of(&self, key: &TaskKey) -> Option<TaskState> {
        if self.done.contains(key) {
            return Some(TaskState::Done);
        }
        self.state.get(key).copied()
    }

    /// Claim the next ready task (FIFO).
    pub fn claim(&mut self) -> Option<TaskKey> {
        let key = self.ready.pop_front()?;
        self.state.insert(key, TaskState::Running);
        Some(key)
    }

    /// All currently ready tasks (without claiming).
    pub fn ready_tasks(&self) -> Vec<TaskKey> {
        self.ready.iter().copied().collect()
    }

    /// Mark a task complete, releasing dependents. Unknown tasks are
    /// recorded as done (supports virtual/externally-executed tasks).
    pub fn complete(&mut self, key: TaskKey) {
        self.state.remove(&key);
        self.unmet.remove(&key);
        self.done.insert(key);
        self.ready.retain(|k| k != &key);
        if let Some(dependents) = self.out_edges.remove(&key) {
            for d in dependents {
                if self.done.contains(&d) {
                    continue;
                }
                let c = self.unmet.entry(d).or_insert(0);
                *c = c.saturating_sub(1);
                if *c == 0 && self.state.get(&d) == Some(&TaskState::Pending) {
                    self.state.insert(d, TaskState::Ready);
                    self.ready.push_back(d);
                }
            }
        }
    }

    pub fn is_done(&self, key: &TaskKey) -> bool {
        self.done.contains(key)
    }

    /// Number of tasks not yet completed.
    pub fn open_count(&self) -> usize {
        self.state.len()
    }

    /// True if there are open tasks but nothing ready or running —
    /// a dependency deadlock (used by tests / debug assertions).
    pub fn is_stuck(&self) -> bool {
        !self.state.is_empty()
            && self
                .state
                .values()
                .all(|s| *s == TaskState::Pending)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workflow::task::CompKind;

    fn t(seq: u64) -> TaskKey {
        TaskKey::transmit(0, 1, seq)
    }

    fn c(rank: usize, seq: u64) -> TaskKey {
        TaskKey::compute(CompKind::Dec, rank, seq)
    }

    #[test]
    fn no_deps_is_ready() {
        let mut d = Dag::new();
        d.insert(t(0));
        assert_eq!(d.state_of(&t(0)), Some(TaskState::Ready));
        assert_eq!(d.claim(), Some(t(0)));
        d.complete(t(0));
        assert!(d.is_done(&t(0)));
    }

    #[test]
    fn dependency_gates_readiness() {
        let mut d = Dag::new();
        d.insert_with_dep(c(1, 0), t(0));
        assert_eq!(d.state_of(&c(1, 0)), Some(TaskState::Pending));
        assert_eq!(d.claim(), None);
        d.complete(t(0));
        assert_eq!(d.state_of(&c(1, 0)), Some(TaskState::Ready));
        assert_eq!(d.claim(), Some(c(1, 0)));
    }

    #[test]
    fn dep_completed_before_insert_is_satisfied() {
        let mut d = Dag::new();
        d.complete(t(0));
        d.insert_with_dep(c(1, 0), t(0));
        assert_eq!(d.state_of(&c(1, 0)), Some(TaskState::Ready));
    }

    #[test]
    fn multiple_deps_all_required() {
        let mut d = Dag::new();
        d.insert_with_dep(c(2, 1), t(0));
        d.insert_with_dep(c(2, 1), c(1, 0));
        d.complete(t(0));
        assert_eq!(d.state_of(&c(2, 1)), Some(TaskState::Pending));
        d.complete(c(1, 0));
        assert_eq!(d.state_of(&c(2, 1)), Some(TaskState::Ready));
    }

    #[test]
    fn duplicate_edges_ignored() {
        let mut d = Dag::new();
        d.insert_with_dep(c(1, 0), t(0));
        d.insert_with_dep(c(1, 0), t(0));
        d.complete(t(0));
        assert_eq!(d.state_of(&c(1, 0)), Some(TaskState::Ready));
    }

    #[test]
    fn stuck_detection() {
        let mut d = Dag::new();
        d.insert_with_dep(c(1, 0), t(9)); // t(9) never completes
        assert!(d.is_stuck());
        d.complete(t(9));
        assert!(!d.is_stuck());
    }
}
