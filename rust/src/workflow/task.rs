//! Task tuples of the workflow DAG (paper Appendix B).

/// Computation task type (paper: `type ∈ {pre, dec, sync}`).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum CompKind {
    Pre,
    Dec,
    Sync,
}

/// Target of a virtual (control) task.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum VirtTarget {
    All,
    Node(usize),
}

/// A task tuple. Ranks follow the paper: 0 is the draft model S, 1..=n are
/// the pipeline nodes L_1..L_n.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum TaskKey {
    /// (T, src, dst, seq)
    Transmit { src: usize, dst: usize, seq: u64 },
    /// (C, kind, rank, seq)
    Compute { kind: CompKind, rank: usize, seq: u64 },
    /// (V, finish, target, seq)
    Finish { target: VirtTarget, seq: u64 },
}

impl TaskKey {
    pub fn seq(&self) -> u64 {
        match *self {
            TaskKey::Transmit { seq, .. } => seq,
            TaskKey::Compute { seq, .. } => seq,
            TaskKey::Finish { seq, .. } => seq,
        }
    }

    pub fn transmit(src: usize, dst: usize, seq: u64) -> Self {
        TaskKey::Transmit { src, dst, seq }
    }

    pub fn compute(kind: CompKind, rank: usize, seq: u64) -> Self {
        TaskKey::Compute { kind, rank, seq }
    }

    pub fn finish_all(seq: u64) -> Self {
        TaskKey::Finish {
            target: VirtTarget::All,
            seq,
        }
    }

    pub fn finish_node(rank: usize, seq: u64) -> Self {
        TaskKey::Finish {
            target: VirtTarget::Node(rank),
            seq,
        }
    }
}

impl std::fmt::Display for TaskKey {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match *self {
            TaskKey::Transmit { src, dst, seq } => write!(f, "(T,{src},{dst},{seq})"),
            TaskKey::Compute { kind, rank, seq } => {
                let k = match kind {
                    CompKind::Pre => "pre",
                    CompKind::Dec => "dec",
                    CompKind::Sync => "sync",
                };
                write!(f, "(C,{k},{rank},{seq})")
            }
            TaskKey::Finish { target, seq } => match target {
                VirtTarget::All => write!(f, "(V,finish,all,{seq})"),
                VirtTarget::Node(r) => write!(f, "(V,finish,{r},{seq})"),
            },
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_matches_paper_tuples() {
        assert_eq!(TaskKey::transmit(1, 2, 3).to_string(), "(T,1,2,3)");
        assert_eq!(
            TaskKey::compute(CompKind::Dec, 4, 5).to_string(),
            "(C,dec,4,5)"
        );
        assert_eq!(TaskKey::finish_all(1).to_string(), "(V,finish,all,1)");
    }

    #[test]
    fn keys_hash_and_compare() {
        use std::collections::HashSet;
        let mut s = HashSet::new();
        s.insert(TaskKey::transmit(0, 1, 0));
        assert!(s.contains(&TaskKey::transmit(0, 1, 0)));
        assert!(!s.contains(&TaskKey::transmit(0, 1, 1)));
    }
}
