//! The engine registry: [`EngineKind`] names every decoding strategy in
//! the crate; [`build_engine`] constructs one behind `Box<dyn Engine>` and
//! [`build_scheduled_engine`] behind `Box<dyn ScheduledEngine>` (native
//! multi-session scheduling for SpecPipe-DB, the [`OneShotScheduler`]
//! adapter for everything else). This is the only place in the repo that
//! maps engine names to concrete types — CLI, server, examples, and
//! benches all go through it.

use std::fmt;
use std::path::Path;
use std::str::FromStr;

use anyhow::Result;

use super::session::{OneShotScheduler, ScheduledEngine};
use super::Engine;
use crate::baselines::{PpEngine, SlmEngine, StppEngine};
use crate::config::EngineConfig;
use crate::coordinator::{PipeDecDbEngine, PipeDecEngine};

/// Every decoding strategy the crate can serve.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum EngineKind {
    /// The paper's system: pipeline parallelism with the draft in the
    /// pipeline and a dynamic prediction tree (§3).
    PipeDec,
    /// SpecPipe-DB: PipeDec with dynamic batching — pipeline slots carry
    /// speculative tokens from *different* requests (multi-request
    /// variant).
    PipeDecDb,
    /// Standard pipeline parallelism, one token per traversal (§4.2).
    Pp,
    /// Static-tree pipeline speculative decoding (SpecInfer-style, §4.2).
    Stpp,
    /// The small model served standalone on one device (§4.2).
    Slm,
}

impl EngineKind {
    /// Registry order used by every "compare all engines" surface.
    pub const ALL: [EngineKind; 5] = [
        EngineKind::PipeDec,
        EngineKind::PipeDecDb,
        EngineKind::Pp,
        EngineKind::Stpp,
        EngineKind::Slm,
    ];

    /// Stable CLI string (`--engine <name>`).
    pub fn name(self) -> &'static str {
        match self {
            EngineKind::PipeDec => "pipedec",
            EngineKind::PipeDecDb => "pipedec-db",
            EngineKind::Pp => "pp",
            EngineKind::Stpp => "stpp",
            EngineKind::Slm => "slm",
        }
    }

    /// One-line description for usage text and bench banners.
    pub fn describe(self) -> &'static str {
        match self {
            EngineKind::PipeDec => "pipeline + draft-in-pipeline dynamic-tree speculation",
            EngineKind::PipeDecDb => {
                "SpecPipe-DB: continuous batching of concurrent requests into pipeline slots"
            }
            EngineKind::Pp => "plain pipeline parallelism, one token per traversal",
            EngineKind::Stpp => "static-tree pipeline speculative decoding",
            EngineKind::Slm => "draft-size model standalone on one device",
        }
    }

    /// Engines whose output must match PP's greedy prefix (losslessness).
    /// SLM runs a different (smaller) model, so it is excluded.
    pub fn is_speculative(self) -> bool {
        matches!(
            self,
            EngineKind::PipeDec | EngineKind::PipeDecDb | EngineKind::Stpp
        )
    }
}

impl fmt::Display for EngineKind {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.name())
    }
}

impl FromStr for EngineKind {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> Result<Self> {
        EngineKind::ALL
            .into_iter()
            .find(|k| k.name() == s)
            .ok_or_else(|| {
                anyhow::anyhow!(
                    "unknown engine {s:?} (expected one of: {})",
                    EngineKind::ALL.map(|k| k.name()).join(", ")
                )
            })
    }
}

/// Construct an engine of the given kind over the AOT artifacts in
/// `artifact_dir`.
///
/// The PipeDec engines (`PipeDec`, `PipeDecDb`) honor
/// `EngineConfig::threads`: `>= 2` (or `0` = auto on a multi-core host)
/// spins up the persistent pipeline worker pool
/// ([`crate::coordinator::workers`]), `1` keeps the sequential reference
/// path. Outputs are token-identical either way; the baselines are
/// single-device strategies and ignore the knob.
pub fn build_engine(
    kind: EngineKind,
    artifact_dir: &Path,
    cfg: EngineConfig,
) -> Result<Box<dyn Engine>> {
    Ok(match kind {
        EngineKind::PipeDec => Box::new(PipeDecEngine::new(artifact_dir, cfg)?),
        EngineKind::PipeDecDb => Box::new(PipeDecDbEngine::new(artifact_dir, cfg)?),
        EngineKind::Pp => Box::new(PpEngine::new(artifact_dir, cfg)?),
        EngineKind::Stpp => Box::new(StppEngine::new(artifact_dir, cfg)?),
        EngineKind::Slm => Box::new(SlmEngine::new(artifact_dir, cfg)?),
    })
}

/// Construct the step-driven scheduling surface for a kind: SpecPipe-DB
/// schedules many sessions natively; every other kind is wrapped in the
/// [`OneShotScheduler`] adapter (a degenerate one-session scheduler), so
/// the continuous-batching server serves the whole registry through one
/// code path.
pub fn build_scheduled_engine(
    kind: EngineKind,
    artifact_dir: &Path,
    cfg: EngineConfig,
) -> Result<Box<dyn ScheduledEngine>> {
    Ok(match kind {
        EngineKind::PipeDecDb => Box::new(PipeDecDbEngine::new(artifact_dir, cfg)?),
        _ => Box::new(OneShotScheduler::new(build_engine(kind, artifact_dir, cfg)?)),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn names_round_trip() {
        for kind in EngineKind::ALL {
            assert_eq!(kind.name().parse::<EngineKind>().unwrap(), kind);
        }
    }

    #[test]
    fn unknown_name_is_rejected_with_candidates() {
        let err = "warp-drive".parse::<EngineKind>().unwrap_err().to_string();
        assert!(err.contains("pipedec"), "error should list candidates: {err}");
        assert!(err.contains("pipedec-db"), "db variant must be listed: {err}");
    }

    #[test]
    fn registry_covers_speculative_split() {
        let spec: Vec<_> = EngineKind::ALL
            .into_iter()
            .filter(|k| k.is_speculative())
            .collect();
        assert_eq!(
            spec,
            vec![EngineKind::PipeDec, EngineKind::PipeDecDb, EngineKind::Stpp]
        );
    }
}
