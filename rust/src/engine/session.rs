//! Step-driven scheduling: the multi-request successor of the one-shot
//! [`Engine::decode`] call.
//!
//! `Engine::decode(&mut self, req, sink)` commits the engine to one request
//! from admission to completion, so a server built on it can only serve
//! FIFO one-at-a-time. The paper's multi-request variant (SpecPipe-DB)
//! instead fills pipeline slots with speculative tokens from *different*
//! requests — which needs an API where the caller owns the clock:
//!
//! * [`Session`] — per-request decode state: id, prompt tokens, the
//!   session's own KV caches, its streaming sink, and a
//!   [`SessionStatus`] lifecycle (`Queued → Running → Finished` or
//!   `Cancelled`).
//! * [`ScheduledEngine`] — `submit` / `step` / `cancel` / `poll`: submit
//!   enqueues a request and returns a [`SessionId`]; every `step` advances
//!   the pipeline one timestep across all live sessions and reports what
//!   happened as a [`StepReport`]; `poll` retrieves a finished session's
//!   [`DecodeOutput`].
//! * [`OneShotScheduler`] — the blanket adapter: wraps any existing
//!   `Box<dyn Engine>` (PipeDec, PP, STPP, SLM) as a *degenerate
//!   one-session scheduler* whose `step` serves exactly one queued session
//!   to completion. Every registry entry is therefore servable through the
//!   scheduled surface via [`crate::engine::build_scheduled_engine`]; the
//!   native multi-session implementation is
//!   [`crate::coordinator::PipeDecDbEngine`].
//!
//! The continuous-batching server loop ([`crate::server::serve_until_idle`])
//! is written against `dyn ScheduledEngine` only.

use std::collections::VecDeque;
use std::fmt;

use anyhow::Result;

use super::{DecodeOutput, DecodeRequest, Engine, EngineKind, TokenSink};
use crate::config::EngineConfig;
use crate::kvcache::TwoLevelCache;
use crate::tokenizer;

/// Identifier of one submitted request within a scheduler.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct SessionId(pub u64);

impl fmt::Display for SessionId {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "s{}", self.0)
    }
}

/// Lifecycle of a session: `Queued → Running → Finished`, or `Cancelled`
/// from either pre-terminal state, or `Failed` when the scheduler retires
/// the session on a fault, deadline, or admission error (ISSUE 9).
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum SessionStatus {
    /// Submitted, not yet admitted into the pipeline.
    Queued,
    /// Admitted; owns pipeline slots and KV caches.
    Running,
    /// Decode complete; output retrievable via `poll` exactly once.
    Finished,
    /// Cancelled via `cancel`; never emits another token and never yields
    /// an output.
    Cancelled,
    /// Retired by the scheduler on a fault confined to this session (task
    /// panic, model/device error, missed deadline, admission failure).
    /// The reason is human-readable; deadline retirements start with
    /// `"deadline"`. The partial output (tokens emitted before the fault)
    /// stays pollable exactly once, like `Finished`.
    Failed {
        reason: String,
    },
}

impl SessionStatus {
    /// True for states a session can never leave.
    pub fn is_terminal(&self) -> bool {
        !matches!(self, SessionStatus::Queued | SessionStatus::Running)
    }
}

/// Error returned by [`ScheduledEngine::submit`] when the scheduler's
/// admission queue is at capacity (load shedding,
/// `LimitsConfig::queue_cap`). Carries the queue depth at rejection so
/// the serving front end can report backpressure; the server loop
/// downcasts submit errors to this type to mint `Shed` completions
/// instead of aborting.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ShedError {
    /// Queue depth observed at the rejected submit.
    pub queue_depth: usize,
}

impl fmt::Display for ShedError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "shed: admission queue full (depth {})", self.queue_depth)
    }
}

impl std::error::Error for ShedError {}

/// Per-request decode state owned by a scheduler.
///
/// The KV caches live *in the session* (not the engine) so a scheduler can
/// interleave many requests over one set of model weights; engines that
/// keep engine-owned caches (the one-shot adapters) leave `caches` empty.
/// Schedulers that mint per-session caches must release the matching
/// device mirrors at teardown ([`crate::model::ModelHandles::release_cache`]).
pub struct Session {
    pub id: SessionId,
    pub req: DecodeRequest,
    /// Tokenized (and context-truncated) prompt.
    pub prompt_ids: Vec<u32>,
    /// Per-session KV caches (pipeline stage caches plus, for speculative
    /// schedulers, the draft cache last). Empty for one-shot adapters.
    pub caches: Vec<TwoLevelCache>,
    /// Streaming observer; receives every verified token exactly once, in
    /// order, as soon as it is produced.
    pub sink: Box<dyn TokenSink>,
    pub status: SessionStatus,
    /// Tokens emitted so far (always equals what the sink has seen).
    pub tokens: Vec<u32>,
    /// When the request was submitted — the anchor for the queue
    /// max-wait, TTFT, and total-wall deadlines (`LimitsConfig`).
    pub queued_at: std::time::Instant,
}

impl Session {
    pub fn new(id: SessionId, req: DecodeRequest, sink: Box<dyn TokenSink>) -> Self {
        let prompt_ids = tokenizer::encode(&req.prompt);
        Self {
            id,
            req,
            prompt_ids,
            caches: Vec::new(),
            sink,
            status: SessionStatus::Queued,
            tokens: Vec::new(),
            queued_at: std::time::Instant::now(),
        }
    }

    /// Stream one verified token to the session's sink and record it.
    pub fn emit(&mut self, token: u32) {
        self.sink.on_token(token);
        self.tokens.push(token);
    }

    /// Collapse into the terminal record a scheduler retains after the
    /// session leaves the queue/pipeline — the heavy state (sink, caches,
    /// prompt, token buffer) is dropped here, so a long-running scheduler
    /// accumulates only small records for cancelled / unpolled sessions.
    pub fn into_record(self, status: SessionStatus, output: Option<DecodeOutput>) -> SessionRecord {
        SessionRecord {
            id: self.id,
            status,
            output,
        }
    }
}

/// Terminal record of a retired session: id, final status, and (for
/// finished sessions) the output until it is polled.
#[derive(Debug)]
pub struct SessionRecord {
    pub id: SessionId,
    pub status: SessionStatus,
    pub output: Option<DecodeOutput>,
}

/// What one [`ScheduledEngine::step`] did.
#[derive(Debug, Clone, Default)]
pub struct StepReport {
    /// Sessions admitted from the queue into the pipeline this step, in
    /// admission (FIFO) order.
    pub admitted: Vec<SessionId>,
    /// Verified tokens emitted this step, in emission order.
    pub emitted: Vec<(SessionId, u32)>,
    /// Sessions that reached a pollable terminal state this step —
    /// `Finished`, or `Failed` (fault/deadline retirement, partial
    /// output). Callers distinguish the two via `status`.
    pub finished: Vec<SessionId>,
    /// Live (admitted, unfinished) sessions after the step.
    pub live: usize,
    /// Still-queued sessions after the step.
    pub queued: usize,
    /// Modeled parallel-schedule seconds this step cost (the paper's
    /// timestep latency model; a full decode for one-shot adapters).
    pub modeled_step_s: f64,
}

impl StepReport {
    /// True when the step admitted, emitted, or finished anything.
    pub fn made_progress(&self) -> bool {
        !self.admitted.is_empty() || !self.emitted.is_empty() || !self.finished.is_empty()
    }

    /// True when the scheduler holds no queued or live sessions.
    pub fn is_idle(&self) -> bool {
        self.live == 0 && self.queued == 0
    }
}

/// A decoding strategy driven one pipeline timestep at a time across many
/// concurrent sessions.
///
/// Contract (asserted by `rust/tests/scheduler.rs`):
/// * admission is FIFO in submission order;
/// * every non-cancelled session eventually finishes if `step` is called
///   repeatedly (no starvation);
/// * a cancelled session never emits another token and never yields an
///   output;
/// * under greedy sampling a session's output is independent of what else
///   is co-scheduled (equal to its solo decode).
pub trait ScheduledEngine {
    /// Which registry entry this scheduler serves.
    fn kind(&self) -> EngineKind;

    /// The engine's effective configuration (after artifact clamping).
    fn config(&self) -> &EngineConfig;

    /// Enqueue a request; tokens stream into `sink` as they are verified.
    fn submit(&mut self, req: DecodeRequest, sink: Box<dyn TokenSink>) -> Result<SessionId>;

    /// Advance the pipeline one timestep across all live sessions,
    /// admitting queued sessions into free pipeline slots first.
    fn step(&mut self) -> Result<StepReport>;

    /// Cancel a queued or running session. Returns true when the session
    /// was found in a pre-terminal state; it will never emit again.
    fn cancel(&mut self, id: SessionId) -> bool;

    /// Take a finished session's output. Returns `None` while the session
    /// is still queued/running, after cancellation, or on repeat polls;
    /// a successful poll forgets the session.
    fn poll(&mut self, id: SessionId) -> Option<DecodeOutput>;

    /// Current lifecycle state, `None` for unknown (or polled) sessions.
    fn status(&self, id: SessionId) -> Option<SessionStatus>;

    /// True while any session is queued or live.
    fn has_work(&self) -> bool;

    /// Stable CLI/registry name.
    fn name(&self) -> &'static str {
        self.kind().name()
    }
}

/// Forwards to the session's own sink while recording what was emitted so
/// the adapter can report it.
struct ForwardSink<'a> {
    sink: &'a mut dyn TokenSink,
    seen: &'a mut Vec<u32>,
}

impl TokenSink for ForwardSink<'_> {
    fn on_token(&mut self, token: u32) {
        self.sink.on_token(token);
        self.seen.push(token);
    }
}

/// Blanket adapter: any one-shot [`Engine`] served as a degenerate
/// one-session scheduler. `step` pops the FIFO queue and decodes that one
/// session to completion — single-task engines like PipeDec commit the
/// whole pipeline to a request, so one session per step *is* their honest
/// scheduling granularity (the paper's one-at-a-time baseline in Fig. 8).
pub struct OneShotScheduler {
    inner: Box<dyn Engine>,
    queue: VecDeque<Session>,
    done: Vec<SessionRecord>,
    next_id: u64,
}

impl OneShotScheduler {
    pub fn new(inner: Box<dyn Engine>) -> Self {
        Self {
            inner,
            queue: VecDeque::new(),
            done: Vec::new(),
            next_id: 0,
        }
    }
}

impl ScheduledEngine for OneShotScheduler {
    fn kind(&self) -> EngineKind {
        self.inner.kind()
    }

    fn config(&self) -> &EngineConfig {
        self.inner.config()
    }

    fn submit(&mut self, req: DecodeRequest, sink: Box<dyn TokenSink>) -> Result<SessionId> {
        let id = SessionId(self.next_id);
        self.next_id += 1;
        self.queue.push_back(Session::new(id, req, sink));
        Ok(id)
    }

    fn step(&mut self) -> Result<StepReport> {
        let mut report = StepReport::default();
        let Some(mut sess) = self.queue.pop_front() else {
            return Ok(report);
        };
        sess.status = SessionStatus::Running;
        report.admitted.push(sess.id);
        let mut fresh = Vec::new();
        let res = {
            let mut fwd = ForwardSink {
                sink: sess.sink.as_mut(),
                seen: &mut fresh,
            };
            self.inner.decode(&sess.req, &mut fwd)
        };
        sess.tokens.extend_from_slice(&fresh);
        report.emitted.extend(fresh.into_iter().map(|t| (sess.id, t)));
        report.finished.push(sess.id);
        match res {
            Ok(out) => {
                report.modeled_step_s = out.modeled_s;
                self.done
                    .push(sess.into_record(SessionStatus::Finished, Some(out)));
            }
            // Fault isolation (ISSUE 9): a failed decode retires only
            // this session — the partial output stays pollable and the
            // scheduler keeps serving the queue.
            Err(e) => {
                let out = DecodeOutput {
                    text: tokenizer::decode(&sess.tokens),
                    tokens: sess.tokens.clone(),
                    wall_s: 0.0,
                    modeled_s: 0.0,
                    spec: None,
                    metrics: crate::metrics::Metrics::new(),
                };
                let status = SessionStatus::Failed {
                    reason: format!("{e:#}"),
                };
                self.done.push(sess.into_record(status, Some(out)));
            }
        }
        report.queued = self.queue.len();
        Ok(report)
    }

    fn cancel(&mut self, id: SessionId) -> bool {
        let Some(qi) = self.queue.iter().position(|s| s.id == id) else {
            return false;
        };
        let sess = self.queue.remove(qi).expect("position is in bounds");
        self.done
            .push(sess.into_record(SessionStatus::Cancelled, None));
        true
    }

    fn poll(&mut self, id: SessionId) -> Option<DecodeOutput> {
        let i = self
            .done
            .iter()
            .position(|s| s.id == id && s.output.is_some())?;
        self.done.remove(i).output
    }

    fn status(&self, id: SessionId) -> Option<SessionStatus> {
        if self.queue.iter().any(|s| s.id == id) {
            return Some(SessionStatus::Queued);
        }
        self.done.iter().find(|s| s.id == id).map(|s| s.status.clone())
    }

    fn has_work(&self) -> bool {
        !self.queue.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::{NullSink, VecSink};
    use crate::metrics::Metrics;

    /// Test double: "decodes" by echoing the prompt's token ids.
    struct EchoEngine {
        cfg: EngineConfig,
    }

    impl EchoEngine {
        fn new() -> Self {
            Self {
                cfg: EngineConfig::default(),
            }
        }
    }

    impl Engine for EchoEngine {
        fn kind(&self) -> EngineKind {
            EngineKind::Pp
        }

        fn config(&self) -> &EngineConfig {
            &self.cfg
        }

        fn decode(
            &mut self,
            req: &DecodeRequest,
            sink: &mut dyn TokenSink,
        ) -> Result<DecodeOutput> {
            let (max_new, _, _) = req.resolve(&self.cfg);
            let mut tokens = tokenizer::encode(&req.prompt);
            tokens.truncate(max_new);
            for &t in &tokens {
                sink.on_token(t);
            }
            Ok(DecodeOutput {
                text: tokenizer::decode(&tokens),
                tokens,
                wall_s: 0.0,
                modeled_s: 0.1,
                spec: None,
                metrics: Metrics::new(),
            })
        }
    }

    #[test]
    fn adapter_serves_fifo_one_session_per_step() {
        let mut s = OneShotScheduler::new(Box::new(EchoEngine::new()));
        let a = s.submit(DecodeRequest::new("aa"), Box::new(NullSink)).unwrap();
        let b = s.submit(DecodeRequest::new("bb"), Box::new(NullSink)).unwrap();
        assert!(a < b);
        assert_eq!(s.status(a), Some(SessionStatus::Queued));
        let r1 = s.step().unwrap();
        assert_eq!(r1.admitted, vec![a]);
        assert_eq!(r1.finished, vec![a]);
        assert_eq!(r1.queued, 1);
        assert!(r1.made_progress());
        let r2 = s.step().unwrap();
        assert_eq!(r2.finished, vec![b]);
        assert!(r2.is_idle());
        assert!(!s.has_work());
        // idle steps are no-ops
        assert!(!s.step().unwrap().made_progress());
    }

    #[test]
    fn poll_takes_output_once_and_streams_through_session_sink() {
        let mut s = OneShotScheduler::new(Box::new(EchoEngine::new()));
        let sink = VecSink::new();
        let id = s.submit(DecodeRequest::new("hi"), Box::new(sink)).unwrap();
        let rep = s.step().unwrap();
        let emitted: Vec<u32> = rep.emitted.iter().map(|&(_, t)| t).collect();
        assert_eq!(emitted, tokenizer::encode("hi"));
        assert_eq!(s.status(id), Some(SessionStatus::Finished));
        let out = s.poll(id).expect("finished session must be pollable");
        assert_eq!(out.tokens, tokenizer::encode("hi"));
        assert!(s.poll(id).is_none(), "poll takes the output exactly once");
    }

    #[test]
    fn cancel_only_hits_queued_sessions() {
        let mut s = OneShotScheduler::new(Box::new(EchoEngine::new()));
        let a = s.submit(DecodeRequest::new("aa"), Box::new(NullSink)).unwrap();
        let b = s.submit(DecodeRequest::new("bb"), Box::new(NullSink)).unwrap();
        assert!(s.cancel(b), "queued session must be cancellable");
        assert_eq!(s.status(b), Some(SessionStatus::Cancelled));
        let rep = s.step().unwrap();
        assert_eq!(rep.finished, vec![a]);
        assert!(!s.cancel(a), "finished session is not cancellable");
        assert!(s.poll(b).is_none(), "cancelled session never yields output");
        assert!(!s.cancel(SessionId(99)), "unknown id");
    }

    #[test]
    fn per_request_overrides_apply_through_submit() {
        let mut s = OneShotScheduler::new(Box::new(EchoEngine::new()));
        let id = s
            .submit(
                DecodeRequest::new("hello world").with_max_new_tokens(3),
                Box::new(NullSink),
            )
            .unwrap();
        s.step().unwrap();
        assert_eq!(s.poll(id).unwrap().tokens.len(), 3);
    }
}
