//! The crate's public inference API: one trait, one request shape, one
//! output shape, for every decoding strategy in the repo.
//!
//! Historically `PipeDecEngine::decode` returned `DecodeResult` while the
//! three baselines returned `BaselineResult`, and every caller (CLI, server,
//! examples, figure benches) re-implemented engine selection by hand. This
//! module is the single seam they all go through instead:
//!
//! * [`Engine`] — `decode(&mut self, req, sink) -> DecodeOutput` plus
//!   `kind()` / `name()` / `config()`; implemented by
//!   [`crate::coordinator::PipeDecEngine`] and the three baselines.
//! * [`DecodeRequest`] — prompt plus *per-request* overrides
//!   (`max_new_tokens`, [`Sampling`], seed) resolved against the engine's
//!   [`EngineConfig`] at decode time, so one long-lived engine can serve
//!   heterogeneous requests.
//! * [`DecodeOutput`] — the merged result shape: tokens, text, wall and
//!   modeled (parallel-schedule) seconds, per-decode [`Metrics`], and an
//!   optional [`SpecStats`] block for speculative engines.
//! * [`TokenSink`] — streaming observer invoked once per *verified* token,
//!   in order, so front ends can emit tokens as they are produced instead
//!   of waiting for the full completion ([`NullSink`], [`VecSink`]).
//! * [`EngineKind`] + [`build_engine`] — the registry: callers iterate
//!   [`EngineKind::ALL`] or parse a kind from a CLI string and get a
//!   `Box<dyn Engine>`; nothing outside this module matches on engine
//!   names by hand.
//! * [`session`] — the step-driven scheduling surface on top:
//!   [`ScheduledEngine`] (`submit`/`step`/`cancel`/`poll` over
//!   [`Session`]s) with [`build_scheduled_engine`] serving every one-shot
//!   kind through the [`OneShotScheduler`] adapter and SpecPipe-DB
//!   ([`EngineKind::PipeDecDb`]) natively. The continuous-batching server
//!   loop is written against it.
//!
//! Future scaling work (async stage execution, alternative backends) lands
//! as new [`Engine`] / [`ScheduledEngine`] implementations behind the same
//! API — see ROADMAP.md.

pub mod factory;
pub mod session;
pub mod sink;

pub use factory::{build_engine, build_scheduled_engine, EngineKind};
pub use session::{
    OneShotScheduler, ScheduledEngine, Session, SessionId, SessionRecord, SessionStatus,
    ShedError, StepReport,
};
pub use sink::{FnSink, NullSink, TokenSink, VecSink};

use anyhow::Result;

use crate::config::EngineConfig;
use crate::coordinator::sampling::Sampling;
use crate::metrics::Metrics;

/// One decode request: a prompt plus optional per-request overrides of the
/// engine's configured limits. Fields left `None` fall back to the engine's
/// [`EngineConfig`] via [`DecodeRequest::resolve`].
#[derive(Debug, Clone, Default)]
pub struct DecodeRequest {
    pub prompt: String,
    /// Override of `EngineConfig::max_new_tokens` for this request only.
    pub max_new_tokens: Option<usize>,
    /// Override of the engine's configured sampling policy.
    pub sampling: Option<Sampling>,
    /// Override of the engine's RNG seed (stochastic sampling replay).
    pub seed: Option<u64>,
}

impl DecodeRequest {
    pub fn new(prompt: &str) -> Self {
        Self {
            prompt: prompt.to_string(),
            ..Self::default()
        }
    }

    pub fn with_max_new_tokens(mut self, n: usize) -> Self {
        self.max_new_tokens = Some(n);
        self
    }

    pub fn with_sampling(mut self, sampling: Sampling) -> Self {
        self.sampling = Some(sampling);
        self
    }

    pub fn with_seed(mut self, seed: u64) -> Self {
        self.seed = Some(seed);
        self
    }

    /// Resolve the request's overrides against an engine config, returning
    /// the effective `(max_new_tokens, sampling, seed)` for this decode.
    pub fn resolve(&self, cfg: &EngineConfig) -> (usize, Sampling, u64) {
        (
            self.max_new_tokens.unwrap_or(cfg.max_new_tokens),
            self.sampling.unwrap_or_else(|| Sampling::from_engine(cfg)),
            self.seed.unwrap_or(cfg.seed),
        )
    }
}

/// Speculation statistics, present on [`DecodeOutput`] only for engines
/// that speculate (PipeDec, PipeDec-DB, STPP).
///
/// Counters an engine's strategy has no notion of are zero — `timesteps`
/// and `rounds` are deliberately separate fields (they used to share one
/// slot, which made "timesteps" mean *pipeline timesteps* for PipeDec but
/// *verification rounds* for STPP and broke cross-engine comparisons).
#[derive(Debug, Clone, Copy, Default)]
pub struct SpecStats {
    /// Pipeline timesteps executed (PipeDec / PipeDec-DB; 0 for STPP).
    pub timesteps: u64,
    /// Serial draft-then-verify rounds (STPP; 0 for timestep-driven
    /// engines).
    pub rounds: u64,
    /// PipeDec family: sync points where the verified token was in the tree.
    pub hits: u64,
    /// PipeDec family: sync points that reinitialized the tree.
    pub misses: u64,
    /// STPP only: mean tokens accepted per verification round.
    pub accepted_per_round: f64,
}

impl SpecStats {
    /// PipeDec hit rate at sync points (0 when no syncs happened).
    pub fn accept_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            0.0
        } else {
            self.hits as f64 / total as f64
        }
    }
}

/// Result of decoding one request — the merged successor of the old
/// `DecodeResult` / `BaselineResult` pair.
#[derive(Debug, Clone)]
pub struct DecodeOutput {
    pub tokens: Vec<u32>,
    pub text: String,
    /// Wall-clock decode seconds (single-core sequential execution).
    pub wall_s: f64,
    /// Modeled parallel-schedule decode seconds (see the engine docs).
    pub modeled_s: f64,
    /// Speculation statistics; `None` for non-speculative engines (PP, SLM).
    pub spec: Option<SpecStats>,
    pub metrics: Metrics,
}

impl DecodeOutput {
    pub fn modeled_s_per_token(&self) -> f64 {
        if self.tokens.is_empty() {
            0.0
        } else {
            self.modeled_s / self.tokens.len() as f64
        }
    }

    /// PipeDec sync-point hit rate; 0 for engines without hit/miss syncs.
    pub fn accept_rate(&self) -> f64 {
        self.spec.map(|s| s.accept_rate()).unwrap_or(0.0)
    }

    /// STPP mean accepted tokens per round; 0 elsewhere.
    pub fn accepted_per_round(&self) -> f64 {
        self.spec.map(|s| s.accepted_per_round).unwrap_or(0.0)
    }

    /// Pipeline timesteps (PipeDec family); 0 elsewhere.
    pub fn timesteps(&self) -> u64 {
        self.spec.map(|s| s.timesteps).unwrap_or(0)
    }

    /// Draft-then-verify rounds (STPP); 0 elsewhere.
    pub fn rounds(&self) -> u64 {
        self.spec.map(|s| s.rounds).unwrap_or(0)
    }

    pub fn hits(&self) -> u64 {
        self.spec.map(|s| s.hits).unwrap_or(0)
    }

    pub fn misses(&self) -> u64 {
        self.spec.map(|s| s.misses).unwrap_or(0)
    }
}

/// A decoding strategy served behind one uniform surface.
///
/// Implementations must stream every token of the final output through the
/// sink, in order, as soon as it is verified — the conformance suite
/// (`rust/tests/engine_api.rs`) asserts `VecSink` contents equal
/// `DecodeOutput::tokens` for every kind.
pub trait Engine {
    /// Which registry entry this engine is.
    fn kind(&self) -> EngineKind;

    /// The engine's effective configuration (after artifact clamping).
    fn config(&self) -> &EngineConfig;

    /// Decode one request, streaming verified tokens into `sink`.
    fn decode(&mut self, req: &DecodeRequest, sink: &mut dyn TokenSink) -> Result<DecodeOutput>;

    /// Stable CLI/registry name (`pipedec`, `pp`, `stpp`, `slm`).
    fn name(&self) -> &'static str {
        self.kind().name()
    }

    /// Convenience: decode a bare prompt with no overrides and no
    /// streaming observer.
    fn decode_prompt(&mut self, prompt: &str) -> Result<DecodeOutput> {
        self.decode(&DecodeRequest::new(prompt), &mut NullSink)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn request_resolve_defaults_to_config() {
        let cfg = EngineConfig::default();
        let req = DecodeRequest::new("hi");
        let (max_new, sampling, seed) = req.resolve(&cfg);
        assert_eq!(max_new, cfg.max_new_tokens);
        assert_eq!(sampling, Sampling::Greedy);
        assert_eq!(seed, cfg.seed);
    }

    #[test]
    fn request_overrides_win() {
        let cfg = EngineConfig::default();
        let req = DecodeRequest::new("hi")
            .with_max_new_tokens(3)
            .with_sampling(Sampling::llama_stochastic())
            .with_seed(99);
        let (max_new, sampling, seed) = req.resolve(&cfg);
        assert_eq!(max_new, 3);
        assert_eq!(sampling, Sampling::llama_stochastic());
        assert_eq!(seed, 99);
    }

    #[test]
    fn spec_stats_accept_rate() {
        let s = SpecStats {
            hits: 3,
            misses: 1,
            ..SpecStats::default()
        };
        assert!((s.accept_rate() - 0.75).abs() < 1e-12);
        assert_eq!(SpecStats::default().accept_rate(), 0.0);
    }

    #[test]
    fn output_accessors_tolerate_missing_spec() {
        let out = DecodeOutput {
            tokens: vec![1, 2],
            text: String::new(),
            wall_s: 0.0,
            modeled_s: 1.0,
            spec: None,
            metrics: Metrics::new(),
        };
        assert_eq!(out.accept_rate(), 0.0);
        assert_eq!(out.timesteps(), 0);
        assert_eq!(out.rounds(), 0);
        assert!((out.modeled_s_per_token() - 0.5).abs() < 1e-12);
    }

    #[test]
    fn timesteps_and_rounds_are_independent_fields() {
        let spec = SpecStats {
            timesteps: 7,
            rounds: 3,
            ..SpecStats::default()
        };
        let out = DecodeOutput {
            tokens: vec![1],
            text: String::new(),
            wall_s: 0.0,
            modeled_s: 0.0,
            spec: Some(spec),
            metrics: Metrics::new(),
        };
        assert_eq!(out.timesteps(), 7);
        assert_eq!(out.rounds(), 3);
    }
}
