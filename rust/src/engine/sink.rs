//! Streaming token observers.
//!
//! Engines call [`TokenSink::on_token`] once per verified output token, in
//! emission order, from inside the decode loop — the stream always equals
//! the final `DecodeOutput::tokens`. Sinks let front ends surface tokens
//! with first-token latency instead of full-completion latency: the CLI
//! prints incrementally, the server records time-to-first-token.

/// Observer of verified tokens during a decode.
pub trait TokenSink {
    /// Called once per verified token, in output order. Implementations
    /// must be cheap: they run on the decode hot path.
    fn on_token(&mut self, token: u32);
}

/// Discards the stream (batch callers that only want the final output).
#[derive(Debug, Default, Clone, Copy)]
pub struct NullSink;

impl TokenSink for NullSink {
    fn on_token(&mut self, _token: u32) {}
}

/// Collects the stream — the conformance suite compares this against the
/// final `DecodeOutput::tokens`.
#[derive(Debug, Default, Clone)]
pub struct VecSink {
    tokens: Vec<u32>,
}

impl VecSink {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn tokens(&self) -> &[u32] {
        &self.tokens
    }

    pub fn into_tokens(self) -> Vec<u32> {
        self.tokens
    }
}

impl TokenSink for VecSink {
    fn on_token(&mut self, token: u32) {
        self.tokens.push(token);
    }
}

/// Adapter: any closure observes the stream.
pub struct FnSink<F: FnMut(u32)>(pub F);

impl<F: FnMut(u32)> TokenSink for FnSink<F> {
    fn on_token(&mut self, token: u32) {
        (self.0)(token)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn vec_sink_collects_in_order() {
        let mut s = VecSink::new();
        for t in [5u32, 7, 2] {
            s.on_token(t);
        }
        assert_eq!(s.tokens(), &[5, 7, 2]);
        assert_eq!(s.into_tokens(), vec![5, 7, 2]);
    }

    #[test]
    fn fn_sink_forwards() {
        let mut seen = Vec::new();
        {
            let mut s = FnSink(|t| seen.push(t));
            s.on_token(9);
            s.on_token(1);
        }
        assert_eq!(seen, vec![9, 1]);
    }
}
