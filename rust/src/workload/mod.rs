//! Evaluation workloads: the six synthetic domains standing in for the
//! paper's datasets (HumanEval, GSM8K, MMLU, WMT14, TriviaQA, DROP — see
//! DESIGN.md inventory row 13).
//!
//! Prompts are generated at artifact-build time by
//! `python/compile/corpus.py::domain_prompts` and shipped in
//! `artifacts/prompts_{domain}.txt` (`\n%%%\n`-separated) so Rust and Python
//! sample exactly the same items.

use std::path::Path;

use anyhow::{Context, Result};

use crate::util::XorShiftRng;

/// Domain names in paper order with their dataset analogues.
pub const DOMAINS: [(&str, &str); 6] = [
    ("code", "HumanEval"),
    ("math", "GSM8K"),
    ("qa", "MMLU"),
    ("translate", "WMT14 DE-EN"),
    ("trivia", "TriviaQA-Wiki"),
    ("reading", "DROP"),
];

/// One evaluation workload: a domain and its prompts.
#[derive(Debug, Clone)]
pub struct Workload {
    pub domain: String,
    pub dataset_analogue: String,
    pub prompts: Vec<String>,
}

impl Workload {
    pub fn load(artifact_dir: &Path, domain: &str) -> Result<Self> {
        let path = artifact_dir.join(format!("prompts_{domain}.txt"));
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("read {}", path.display()))?;
        let prompts: Vec<String> = text
            .split("\n%%%\n")
            .map(|s| s.to_string())
            .filter(|s| !s.trim().is_empty())
            .collect();
        anyhow::ensure!(!prompts.is_empty(), "no prompts in {domain}");
        let analogue = DOMAINS
            .iter()
            .find(|(d, _)| *d == domain)
            .map(|(_, a)| a.to_string())
            .unwrap_or_default();
        Ok(Self {
            domain: domain.to_string(),
            dataset_analogue: analogue,
            prompts,
        })
    }

    /// All six domains.
    pub fn load_all(artifact_dir: &Path) -> Result<Vec<Self>> {
        DOMAINS
            .iter()
            .map(|(d, _)| Self::load(artifact_dir, d))
            .collect()
    }

    /// Deterministic sample of up to `n` prompts (the paper samples 10 per
    /// dataset).
    pub fn sample(&self, n: usize, rng: &mut XorShiftRng) -> Vec<&str> {
        let mut idx: Vec<usize> = (0..self.prompts.len()).collect();
        for i in 0..idx.len() {
            let j = rng.range(i, idx.len());
            idx.swap(i, j);
        }
        idx.truncate(n.min(self.prompts.len()));
        idx.into_iter().map(|i| self.prompts[i].as_str()).collect()
    }
}

/// A mixed request stream for throughput runs (two per domain, as in the
/// paper's Fig. 8 setup).
pub fn mixed_stream(artifact_dir: &Path, per_domain: usize) -> Result<Vec<String>> {
    let mut rng = XorShiftRng::new(0xF168);
    let mut out = Vec::new();
    for wl in Workload::load_all(artifact_dir)? {
        for p in wl.sample(per_domain, &mut rng) {
            out.push(p.to_string());
        }
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts() -> Option<std::path::PathBuf> {
        let dir = crate::artifacts_dir();
        dir.join("prompts_code.txt").exists().then_some(dir)
    }

    #[test]
    fn all_domains_load() {
        let Some(dir) = artifacts() else {
            eprintln!("skipping: no artifacts");
            return;
        };
        let all = Workload::load_all(&dir).unwrap();
        assert_eq!(all.len(), 6);
        for wl in &all {
            assert!(wl.prompts.len() >= 6, "{} too few prompts", wl.domain);
            assert!(wl.prompts[0].starts_with(&format!("<{}>", wl.domain)));
        }
    }

    #[test]
    fn sampling_is_deterministic() {
        let Some(dir) = artifacts() else {
            eprintln!("skipping: no artifacts");
            return;
        };
        let wl = Workload::load(&dir, "math").unwrap();
        let mut r1 = XorShiftRng::new(5);
        let mut r2 = XorShiftRng::new(5);
        assert_eq!(wl.sample(4, &mut r1), wl.sample(4, &mut r2));
    }

    #[test]
    fn mixed_stream_interleaves_domains() {
        let Some(dir) = artifacts() else {
            eprintln!("skipping: no artifacts");
            return;
        };
        let s = mixed_stream(&dir, 2).unwrap();
        assert_eq!(s.len(), 12);
    }
}
