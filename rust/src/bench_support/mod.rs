//! Bench harness (the offline vendor set has no criterion): timed runs with
//! warmup, summary stats, and paper-style table output. Every
//! `rust/benches/fig*.rs` binary uses this module and writes its rows to
//! `bench_results/*.csv` alongside stdout.

use std::time::Instant;

use crate::metrics::Table;
use crate::util::Summary;

/// Time `f` `iters` times after `warmup` discarded runs.
pub fn time_fn<F: FnMut()>(warmup: usize, iters: usize, mut f: F) -> Summary {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_secs_f64());
    }
    Summary::from_samples(samples)
}

/// Standard bench banner.
pub fn banner(name: &str, what: &str) {
    println!("\n=== {name} ===");
    println!("{what}\n");
}

/// Print and persist a results table.
pub fn emit(name: &str, table: &Table) {
    println!("{}", table.render());
    let path = std::path::Path::new("bench_results").join(format!("{name}.csv"));
    if let Err(e) = table.write_csv(&path) {
        eprintln!("warning: could not write {}: {e}", path.display());
    } else {
        println!("[csv] {}", path.display());
    }
}

/// Format seconds as adaptive ms/us.
pub fn fmt_s(s: f64) -> String {
    if s >= 1.0 {
        format!("{s:.2}s")
    } else if s >= 1e-3 {
        format!("{:.2}ms", s * 1e3)
    } else {
        format!("{:.1}us", s * 1e6)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn time_fn_collects_iters() {
        let s = time_fn(1, 5, || {
            std::hint::black_box((0..100).sum::<u64>());
        });
        assert_eq!(s.len(), 5);
        assert!(s.mean() >= 0.0);
    }

    #[test]
    fn fmt_scales() {
        assert!(fmt_s(2.0).ends_with('s'));
        assert!(fmt_s(0.002).ends_with("ms"));
        assert!(fmt_s(2e-5).ends_with("us"));
    }
}
